#!/usr/bin/env python3
"""Bootstrap mirror of socket-lint for toolchain-less environments.

The Rust binary (`cargo run -p socket-lint`) is canonical; this script
re-implements the same lexer + rules so the baseline can be generated
and the gate exercised in containers that lack cargo. Keep the two in
lock-step: any rule change lands in both, and `ci.sh` prefers the Rust
binary whenever cargo exists.

Usage: python3 lint/selfcheck.py [ROOT] [--baseline FILE] [--write-baseline]
Exit:  0 clean, 1 findings/baseline problems, 2 usage/IO.
"""
import sys
import os

RULES = {
    "safety-comment", "ordering-rationale", "atomics-allowlist",
    "hot-path-panic", "hot-path-index", "alloc-in-into",
    "instant-in-kernel", "waiver-missing-reason", "waiver-unknown-rule",
}
ATOMICS_ALLOWLIST = ["util/pool.rs", "metrics/registry.rs", "server/", "server.rs",
                     "simd/dispatch.rs"]
HOT_PATHS = ["lsh/", "lsh.rs", "linalg/", "linalg.rs", "selector/", "selector.rs",
             "kvcache/", "kvcache.rs", "simd/"]
KERNEL_PATHS = ["lsh/", "lsh.rs", "linalg/", "linalg.rs", "selector/", "selector.rs",
                "simd/"]
ATOMIC_ORDERINGS = {"Relaxed", "SeqCst", "Acquire", "Release", "AcqRel"}
ORDERING_MARKERS = ["relaxed", "seqcst", "acquire", "release", "ordering"]
KEYWORDS = {
    "as", "break", "const", "continue", "crate", "dyn", "else", "enum", "extern",
    "false", "fn", "for", "if", "impl", "in", "let", "loop", "match", "mod",
    "move", "mut", "pub", "ref", "return", "self", "Self", "static", "struct",
    "super", "trait", "true", "type", "unsafe", "use", "where", "while",
    "async", "await",
}


def path_in(path, pats):
    return any(path.startswith(p) if p.endswith("/") else path == p for p in pats)


# --- lexer -----------------------------------------------------------------
# Token: (line, kind, text) with kind in {id, punct, lit, life}.
# Comment: (line, end_line, text).

def lex(src):
    toks, comments = [], []
    i, line, n = 0, 1, len(src)

    def peek(k=0):
        j = i + k
        return src[j] if j < n else ""

    while i < n:
        c = src[i]
        start = line
        if c == "\n":
            line += 1
            i += 1
        elif c.isspace():
            i += 1
        elif c == "/" and peek(1) == "/":
            j = src.find("\n", i)
            j = n if j < 0 else j
            comments.append((start, start, src[i:j]))
            i = j
        elif c == "/" and peek(1) == "*":
            depth, j = 0, i
            while j < n:
                if src[j : j + 2] == "/*":
                    depth += 1
                    j += 2
                elif src[j : j + 2] == "*/":
                    depth -= 1
                    j += 2
                    if depth == 0:
                        break
                else:
                    j += 1
            text = src[i:j]
            endl = start + text.count("\n")
            comments.append((start, endl, text))
            line = endl
            i = j
        elif c == '"':
            i += 1
            while i < n:
                if src[i] == "\\":
                    i += 2
                elif src[i] == '"':
                    i += 1
                    break
                else:
                    if src[i] == "\n":
                        line += 1
                    i += 1
            toks.append((start, "lit", ""))
        elif c == "'":
            c1, c2 = peek(1), peek(2)
            if (c1.isalnum() or c1 == "_") and c2 != "'":
                i += 1
                while i < n and (src[i].isalnum() or src[i] == "_"):
                    i += 1
                toks.append((start, "life", ""))
            else:
                i += 1
                while i < n:
                    if src[i] == "\\":
                        i += 2
                    elif src[i] == "'":
                        i += 1
                        break
                    else:
                        i += 1
                toks.append((start, "lit", ""))
        elif c in "rb" and _raw_prefix(src, i, n):
            i, line = _raw_lit(src, i, n, line)
            toks.append((start, "lit", ""))
        elif c.isalpha() or c == "_":
            j = i
            while j < n and (src[j].isalnum() or src[j] == "_"):
                j += 1
            toks.append((start, "id", src[i:j]))
            i = j
        elif c.isdigit():
            j = i
            while j < n and (src[j].isalnum() or src[j] in "_."):
                j += 1
            toks.append((start, "lit", ""))
            i = j
        else:
            toks.append((start, "punct", c))
            i += 1
    return toks, comments


def _raw_prefix(src, i, n):
    j = i
    if src[j] == "b":
        if j + 1 < n and src[j + 1] in "\"'":
            return True
        if j + 1 < n and src[j + 1] == "r":
            j += 1
        else:
            return False
    if src[j] != "r":
        return False
    j += 1
    while j < n and src[j] == "#":
        j += 1
    return j < n and src[j] == '"'


def _raw_lit(src, i, n, line):
    while i < n and src[i] in "rb":
        i += 1
    if i < n and src[i] == "'":
        i += 1
        while i < n:
            if src[i] == "\\":
                i += 2
            elif src[i] == "'":
                i += 1
                break
            else:
                i += 1
        return i, line
    hashes = 0
    while i < n and src[i] == "#":
        hashes += 1
        i += 1
    i += 1  # opening quote
    close = '"' + "#" * hashes
    j = src.find(close, i)
    j = n if j < 0 else j + len(close)
    line += src[i:j].count("\n")
    return j, line


# --- cfg(test) strip + fn spans -------------------------------------------

def match_delim(toks, open_i, oc, cc):
    depth = 0
    for j in range(open_i, len(toks)):
        k, t = toks[j][1], toks[j][2]
        if k == "punct" and t == oc:
            depth += 1
        elif k == "punct" and t == cc:
            depth -= 1
            if depth == 0:
                return j
    return len(toks) - 1


def is_punct(t, c):
    return t[1] == "punct" and t[2] == c


def strip_test(toks):
    out, i = [], 0
    while i < len(toks):
        if is_punct(toks[i], "#") and i + 1 < len(toks) and is_punct(toks[i + 1], "["):
            close = match_delim(toks, i + 1, "[", "]")
            attr = toks[i + 2 : close]
            ids = [t[2] for t in attr if t[1] == "id"]
            is_test = (ids[:1] == ["test"]) or (
                ids[:1] == ["cfg"] and "test" in ids and "not" not in ids
            )
            if is_test:
                i = skip_item(toks, close + 1)
                continue
            out.extend(toks[i : close + 1])
            i = close + 1
            continue
        out.append(toks[i])
        i += 1
    return out


def skip_item(toks, i):
    while i + 1 < len(toks) and is_punct(toks[i], "#") and is_punct(toks[i + 1], "["):
        i = match_delim(toks, i + 1, "[", "]") + 1
    while i < len(toks):
        if is_punct(toks[i], "{"):
            return match_delim(toks, i, "{", "}") + 1
        if is_punct(toks[i], ";"):
            return i + 1
        i += 1
    return i


def fn_spans(toks):
    spans = []
    for i, t in enumerate(toks):
        if t[1] == "id" and t[2] == "fn" and i + 1 < len(toks) and toks[i + 1][1] == "id":
            name, j = toks[i + 1][2], i + 2
            while j < len(toks):
                if is_punct(toks[j], "{"):
                    spans.append((name, t[0], j, match_delim(toks, j, "{", "}") + 1))
                    break
                if is_punct(toks[j], ";"):
                    break
                j += 1
    return spans


def enclosing_fn(spans, idx):
    best = None
    for s in spans:
        if s[2] <= idx < s[3] and (best is None or s[3] - s[2] < best[3] - best[2]):
            best = s
    return best


# --- comment queries -------------------------------------------------------

def comment_near(comments, line, window, pred):
    return any(c[0] <= line and c[1] + window >= line and pred(c[2]) for c in comments)


def header_block(comments, line):
    parts, want = [], line
    for c in reversed(comments):
        if c[1] >= want:
            continue
        if c[1] + 3 >= want:
            parts.append(c[2])
            want = c[0]
        else:
            break
    return "\n".join(reversed(parts)).lower()


# --- rules -----------------------------------------------------------------

def check_source(path, src):
    raw_toks, comments = lex(src)
    toks = strip_test(raw_toks)
    spans = fn_spans(toks)
    out = []

    for i, t in enumerate(toks):
        # safety-comment
        if t[1] == "id" and t[2] == "unsafe" and i + 1 < len(toks) and is_punct(toks[i + 1], "{"):
            if not comment_near(comments, t[0], 5, lambda s: "SAFETY:" in s):
                out.append(("safety-comment", path, t[0], "unsafe block without // SAFETY:"))
        # ordering
        if (
            t[1] == "id" and t[2] == "Ordering"
            and i + 3 < len(toks)
            and is_punct(toks[i + 1], ":") and is_punct(toks[i + 2], ":")
            and toks[i + 3][1] == "id" and toks[i + 3][2] in ATOMIC_ORDERINGS
        ):
            variant = toks[i + 3][2]
            if not path_in(path, ATOMICS_ALLOWLIST):
                out.append(("atomics-allowlist", path, t[0],
                            "Ordering::%s outside audited modules" % variant))
            near = comment_near(
                comments, t[0], 5,
                lambda s: any(m in s.lower() for m in ORDERING_MARKERS))
            if not near:
                f = enclosing_fn(spans, i)
                hdr = header_block(comments, f[1]) if f else ""
                near = any(m in hdr for m in ORDERING_MARKERS)
            if not near:
                out.append(("ordering-rationale", path, t[0],
                            "Ordering::%s with no rationale comment" % variant))
        # hot-path-panic
        if path_in(path, HOT_PATHS) and t[1] == "id":
            if t[2] in ("unwrap", "expect") and i > 0 and is_punct(toks[i - 1], "."):
                out.append(("hot-path-panic", path, t[0], "panicking call `%s`" % t[2]))
            if t[2] in ("panic", "unreachable", "todo", "unimplemented") and i + 1 < len(
                toks
            ) and is_punct(toks[i + 1], "!"):
                out.append(("hot-path-panic", path, t[0], "panicking call `%s!`" % t[2]))
        # hot-path-index
        if path_in(path, HOT_PATHS) and is_punct(t, "[") and i > 0:
            p = toks[i - 1]
            if (p[1] == "id" and p[2] not in KEYWORDS) or (
                p[1] == "punct" and p[2] in ")]"
            ):
                out.append(("hot-path-index", path, t[0], "panicking slice-index syntax"))
        # instant-in-kernel
        if (
            path_in(path, KERNEL_PATHS)
            and t[1] == "id" and t[2] == "Instant"
            and i + 3 < len(toks)
            and is_punct(toks[i + 1], ":") and is_punct(toks[i + 2], ":")
            and toks[i + 3][1] == "id" and toks[i + 3][2] == "now"
        ):
            out.append(("instant-in-kernel", path, t[0], "Instant::now in scoring kernel"))

    # alloc-in-into
    into = [s for s in spans if s[0].endswith("_into")]
    for s in into:
        nested = [g for g in into if g[2] > s[2] and g[3] <= s[3]]
        for i in range(s[2], s[3]):
            if any(g[2] <= i < g[3] for g in nested):
                continue
            what = alloc_at(toks, i)
            if what:
                out.append(("alloc-in-into", path, toks[i][0],
                            "allocation `%s` inside `%s`" % (what, s[0])))

    # waivers
    waivers = []
    for c in comments:
        for needle, file_wide in (("lint:allow-file(", True), ("lint:allow(", False)):
            at = c[2].find(needle)
            if at < 0:
                continue
            rest = c[2][at + len(needle):]
            close = rest.find(")")
            if close < 0:
                out.append(("waiver-missing-reason", path, c[0], "malformed waiver"))
                break
            names = [r.strip() for r in rest[:close].split(",")]
            after = rest[close + 1:].lstrip()
            reason = after[1:].strip() if after.startswith(":") else ""
            if not reason or reason.startswith("TODO"):
                out.append(("waiver-missing-reason", path, c[0], "waiver needs a reason"))
                break
            bad = [r for r in names if r not in RULES]
            if bad:
                for r in bad:
                    out.append(("waiver-unknown-rule", path, c[0],
                                "unknown rule `%s`" % r))
                break
            for r in names:
                # Comment plus 3 lines of slack (rustfmt reflow safety).
                waivers.append((r, None if file_wide else (c[0], c[1] + 3)))
            break

    def waived(f):
        return any(
            w[0] == f[0] and (w[1] is None or w[1][0] <= f[2] <= w[1][1]) for w in waivers
        )

    out = [f for f in out if not waived(f)]
    out.sort(key=lambda f: (f[2], f[0]))
    return out


def alloc_at(toks, i):
    t = toks[i]
    if t[1] != "id":
        return None
    if t[2] in ("Vec", "String", "Box"):
        if (
            i + 3 < len(toks)
            and is_punct(toks[i + 1], ":") and is_punct(toks[i + 2], ":")
            and toks[i + 3][1] == "id" and toks[i + 3][2] in ("new", "with_capacity", "from")
        ):
            return "%s::%s" % (t[2], toks[i + 3][2])
    if t[2] == "vec" and i + 1 < len(toks) and is_punct(toks[i + 1], "!"):
        return "vec!"
    if t[2] in ("collect", "to_vec", "to_owned", "to_string") and i > 0 and is_punct(
        toks[i - 1], "."
    ):
        return ".%s()" % t[2]
    return None


# --- baseline + main -------------------------------------------------------

def parse_baseline(text):
    entries = []
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        parts = line.split(None, 3)
        if len(parts) < 4:
            raise SystemExit("baseline line %d: expected `rule path count reason`" % lineno)
        rule, path, count, reason = parts[0], parts[1], parts[2], parts[3].strip()
        if rule not in RULES:
            raise SystemExit("baseline line %d: unknown rule `%s`" % (lineno, rule))
        if not count.isdigit() or int(count) == 0:
            raise SystemExit("baseline line %d: bad count `%s`" % (lineno, count))
        if not reason or reason.startswith("TODO"):
            raise SystemExit("baseline line %d: needs a real reason" % lineno)
        entries.append((rule, path, int(count), reason))
    return entries


def main(argv):
    root, baseline_path, write = "rust/src", None, False
    it = iter(argv)
    for a in it:
        if a == "--baseline":
            baseline_path = next(it, None)
        elif a == "--write-baseline":
            write = True
        elif not a.startswith("-"):
            root = a
        else:
            print("usage: selfcheck.py [ROOT] [--baseline FILE] [--write-baseline]")
            return 2

    findings = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        for name in sorted(filenames):
            if not name.endswith(".rs"):
                continue
            p = os.path.join(dirpath, name)
            rel = os.path.relpath(p, root).replace(os.sep, "/")
            with open(p, encoding="utf-8") as fh:
                findings.extend(check_source(rel, fh.read()))

    if write:
        counts = {}
        for f in findings:
            counts[(f[0], f[1])] = counts.get((f[0], f[1]), 0) + 1
        old = {}
        if baseline_path and os.path.exists(baseline_path):
            try:
                for e in parse_baseline(open(baseline_path, encoding="utf-8").read()):
                    old[(e[0], e[1])] = e[3]
            except SystemExit:
                pass
        lines = [
            "# socket-lint baseline: pre-existing debt, enumerated and ratcheted.",
            "# Format: rule path count reason. Counts may only go down; every",
            "# entry needs a real (non-TODO) reason or the gate fails.",
        ]
        for (rule, path), n in sorted(counts.items()):
            reason = old.get((rule, path), "TODO: explain or fix")
            lines.append("%s %s %d %s" % (rule, path, n, reason))
        text = "\n".join(lines) + "\n"
        if baseline_path:
            with open(baseline_path, "w", encoding="utf-8") as fh:
                fh.write(text)
            print("selfcheck: wrote %s (%d findings)" % (baseline_path, len(findings)))
        else:
            sys.stdout.write(text)
        return 0

    budget = {}
    if baseline_path and os.path.exists(baseline_path):
        for rule, path, count, _ in parse_baseline(
            open(baseline_path, encoding="utf-8").read()
        ):
            budget[(rule, path)] = budget.get((rule, path), 0) + count

    bad = 0
    for f in findings:
        key = (f[0], f[1])
        if budget.get(key, 0) > 0:
            budget[key] -= 1
            continue
        print("%s:%d: [%s] %s" % (f[1], f[2], f[0], f[3]))
        bad += 1
    for (rule, path), left in sorted(budget.items()):
        if left > 0:
            print("stale baseline: %s in %s overstates debt by %d" % (rule, path, left))
            bad += 1
    if bad:
        print("selfcheck: %d problem(s)" % bad)
        return 1
    print("selfcheck: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
