//! socket-lint: repo-native static analysis for SOCKET's rust/src.
//!
//! Walks a source root, lexes every `.rs` file, runs the invariant
//! rules (see `rules.rs` and `rust/docs/ANALYSIS.md`), subtracts the
//! checked-in baseline, and exits non-zero on any unwaived finding,
//! stale baseline entry, or malformed waiver.
//!
//! ```text
//! socket-lint [ROOT] [--baseline FILE] [--write-baseline] [--rules] [--quiet]
//! ```
//!
//! Exit codes: 0 clean · 1 findings/baseline problems · 2 usage/IO.

mod baseline;
mod lexer;
mod rules;

use std::path::{Path, PathBuf};
use std::process::ExitCode;

struct Args {
    root: PathBuf,
    baseline: Option<PathBuf>,
    write_baseline: bool,
    list_rules: bool,
    quiet: bool,
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args {
        root: PathBuf::from("rust/src"),
        baseline: None,
        write_baseline: false,
        list_rules: false,
        quiet: false,
    };
    let mut it = std::env::args().skip(1);
    let mut root_set = false;
    while let Some(a) = it.next() {
        match a.as_str() {
            "--baseline" => {
                let v = it.next().ok_or("--baseline needs a file path")?;
                args.baseline = Some(PathBuf::from(v));
            }
            "--write-baseline" => args.write_baseline = true,
            "--rules" => args.list_rules = true,
            "--quiet" | "-q" => args.quiet = true,
            "--help" | "-h" => {
                return Err("usage: socket-lint [ROOT] [--baseline FILE] [--write-baseline] \
                            [--rules] [--quiet]"
                    .into())
            }
            other if !other.starts_with('-') && !root_set => {
                args.root = PathBuf::from(other);
                root_set = true;
            }
            other => return Err(format!("unknown argument `{other}` (try --help)")),
        }
    }
    Ok(args)
}

/// Collect `.rs` files under `root`, depth-first, sorted for
/// deterministic output.
fn walk(root: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(root)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, p: &Path) -> String {
    p.strip_prefix(root)
        .unwrap_or(p)
        .components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return ExitCode::from(2);
        }
    };
    if args.list_rules {
        for (id, desc) in rules::RULES {
            println!("{id:<22} {desc}");
        }
        return ExitCode::SUCCESS;
    }

    let mut files = Vec::new();
    if let Err(e) = walk(&args.root, &mut files) {
        eprintln!("socket-lint: cannot walk {}: {e}", args.root.display());
        return ExitCode::from(2);
    }

    let mut findings = Vec::new();
    for p in &files {
        let src = match std::fs::read_to_string(p) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("socket-lint: cannot read {}: {e}", p.display());
                return ExitCode::from(2);
            }
        };
        findings.extend(rules::check_source(&rel_path(&args.root, p), &src));
    }

    // Load the baseline (parse errors are fatal — a bad baseline must
    // never silently grandfather debt).
    let old_entries = match &args.baseline {
        Some(bp) if bp.exists() => match std::fs::read_to_string(bp) {
            Ok(text) => match baseline::parse(&text) {
                Ok(e) => e,
                Err(err) => {
                    // --write-baseline may proceed from a baseline with
                    // TODO reasons (it is how reasons get filled in);
                    // checking may not.
                    if args.write_baseline {
                        Vec::new()
                    } else {
                        eprintln!("socket-lint: {}", err.0);
                        return ExitCode::from(1);
                    }
                }
            },
            Err(e) => {
                eprintln!("socket-lint: cannot read baseline {}: {e}", bp.display());
                return ExitCode::from(2);
            }
        },
        _ => Vec::new(),
    };

    if args.write_baseline {
        let text = baseline::render(&findings, &old_entries);
        match &args.baseline {
            Some(bp) => {
                if let Err(e) = std::fs::write(bp, text) {
                    eprintln!("socket-lint: cannot write {}: {e}", bp.display());
                    return ExitCode::from(2);
                }
                println!(
                    "socket-lint: wrote {} ({} findings enumerated)",
                    bp.display(),
                    findings.len()
                );
            }
            None => print!("{text}"),
        }
        return ExitCode::SUCCESS;
    }

    let applied = baseline::apply(findings, &old_entries);
    let n_files = files.len();
    let mut bad = 0usize;
    for f in &applied.fresh {
        println!("{}:{}: [{}] {}", f.path, f.line, f.rule, f.msg);
        bad += 1;
    }
    for s in &applied.stale {
        println!("{}", s.0);
        bad += 1;
    }
    if bad > 0 {
        println!(
            "socket-lint: {bad} problem(s) across {n_files} files \
             (waive with `// lint:allow(rule): reason` or fix; see rust/docs/ANALYSIS.md)"
        );
        return ExitCode::from(1);
    }
    if !args.quiet {
        println!(
            "socket-lint: clean ({n_files} files, {} baseline entries)",
            old_entries.len()
        );
    }
    ExitCode::SUCCESS
}
