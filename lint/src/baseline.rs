//! Baseline ("ratchet") file support: pre-existing debt is enumerated
//! per `(rule, path)` with a count and a mandatory reason, so new debt
//! fails CI immediately while old debt is visible and monotonically
//! burned down.
//!
//! Format (one entry per line, `#` comments and blanks ignored):
//!
//! ```text
//! rule path count reason text until end of line
//! ```
//!
//! Semantics when checking:
//! - findings are matched against entries; up to `count` findings per
//!   `(rule, path)` are suppressed;
//! - findings beyond `count` are NEW debt → reported, non-zero exit;
//! - fewer findings than `count` is a STALE entry → also non-zero exit
//!   (the ratchet: fixing debt must shrink the baseline in the same
//!   change, so the file never overstates reality);
//! - an entry with an empty or `TODO` reason is invalid → non-zero
//!   exit (debt must be explained, not grandfathered).
//!
//! `--write-baseline` regenerates counts from the current tree while
//! preserving reasons of surviving entries; brand-new entries get a
//! `TODO` reason that the checker rejects until a human writes one.

use crate::rules::{rule_exists, Finding};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One parsed baseline entry.
#[derive(Debug, Clone)]
pub struct Entry {
    pub rule: String,
    pub path: String,
    pub count: usize,
    pub reason: String,
}

/// A problem with the baseline file itself (bad syntax, bad reason,
/// stale count) — all are CI failures distinct from code findings.
#[derive(Debug, Clone, PartialEq)]
pub struct BaselineError(pub String);

pub fn parse(text: &str) -> Result<Vec<Entry>, BaselineError> {
    let mut entries = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let lineno = i + 1;
        // First three whitespace-delimited fields; the reason is the
        // raw remainder (runs of spaces inside it are preserved).
        let mut rest = line;
        let mut take = || {
            let r = rest.trim_start();
            let end = r.find(char::is_whitespace).unwrap_or(r.len());
            let (tok, tail) = r.split_at(end);
            rest = tail;
            tok
        };
        let (rule, path, count) = (take(), take(), take());
        let reason = rest.trim();
        if rule.is_empty() || path.is_empty() || count.is_empty() {
            return Err(BaselineError(format!(
                "baseline line {lineno}: expected `rule path count reason`, got `{line}`"
            )));
        }
        if !rule_exists(rule) {
            return Err(BaselineError(format!(
                "baseline line {lineno}: unknown rule `{rule}`"
            )));
        }
        let count: usize = count.parse().map_err(|_| {
            BaselineError(format!("baseline line {lineno}: bad count `{count}`"))
        })?;
        if count == 0 {
            return Err(BaselineError(format!(
                "baseline line {lineno}: count 0 — delete the entry instead"
            )));
        }
        if reason.is_empty() || reason.starts_with("TODO") {
            return Err(BaselineError(format!(
                "baseline line {lineno}: entry for {rule} in {path} needs a real reason \
                 (found `{reason}`)"
            )));
        }
        entries.push(Entry {
            rule: rule.to_string(),
            path: path.to_string(),
            count,
            reason: reason.to_string(),
        });
    }
    Ok(entries)
}

/// Outcome of applying a baseline to a finding set.
pub struct Applied {
    /// Findings NOT covered by the baseline (new debt).
    pub fresh: Vec<Finding>,
    /// Baseline problems: stale entries whose debt shrank.
    pub stale: Vec<BaselineError>,
}

pub fn apply(findings: Vec<Finding>, entries: &[Entry]) -> Applied {
    let mut budget: BTreeMap<(&str, &str), usize> =
        entries.iter().map(|e| ((e.rule.as_str(), e.path.as_str()), e.count)).collect();
    let mut fresh = Vec::new();
    for f in findings {
        match budget.get_mut(&(f.rule, f.path.as_str())) {
            Some(left) if *left > 0 => *left -= 1,
            _ => fresh.push(f),
        }
    }
    let stale = budget
        .iter()
        .filter(|(_, left)| **left > 0)
        .map(|((rule, path), left)| {
            BaselineError(format!(
                "stale baseline: {rule} in {path} overstates debt by {left} — \
                 ratchet the count down (or delete the entry)"
            ))
        })
        .collect();
    Applied { fresh, stale }
}

/// Render a fresh baseline from `findings`, keeping reasons from
/// `old` where the `(rule, path)` pair survives.
pub fn render(findings: &[Finding], old: &[Entry]) -> String {
    let mut counts: BTreeMap<(&str, &str), usize> = BTreeMap::new();
    for f in findings {
        *counts.entry((f.rule, f.path.as_str())).or_insert(0) += 1;
    }
    let mut out = String::from(
        "# socket-lint baseline: pre-existing debt, enumerated and ratcheted.\n\
         # Format: rule path count reason. Counts may only go down; every\n\
         # entry needs a real (non-TODO) reason or the gate fails.\n",
    );
    for ((rule, path), n) in &counts {
        let reason = old
            .iter()
            .find(|e| e.rule == *rule && e.path == *path)
            .map(|e| e.reason.as_str())
            .unwrap_or("TODO: explain or fix");
        let _ = writeln!(out, "{rule} {path} {n} {reason}");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(rule: &'static str, path: &str, line: u32) -> Finding {
        Finding { rule, path: path.to_string(), line, msg: String::new() }
    }

    #[test]
    fn parse_roundtrip() {
        let text = "# comment\n\nhot-path-index lsh/soft.rs 3 tight kernels, bounds asserted at entry\n";
        let e = parse(text).unwrap();
        assert_eq!(e.len(), 1);
        assert_eq!(e[0].rule, "hot-path-index");
        assert_eq!(e[0].count, 3);
        assert!(e[0].reason.starts_with("tight kernels"));
    }

    #[test]
    fn parse_rejects_bad_entries() {
        assert!(parse("hot-path-index lsh/soft.rs 3").is_err(), "missing reason");
        assert!(parse("hot-path-index lsh/soft.rs 3 TODO: later").is_err(), "TODO reason");
        assert!(parse("no-such-rule lsh/soft.rs 3 why").is_err(), "unknown rule");
        assert!(parse("hot-path-index lsh/soft.rs zero why").is_err(), "bad count");
        assert!(parse("hot-path-index lsh/soft.rs 0 why").is_err(), "zero count");
    }

    #[test]
    fn apply_budget_and_staleness() {
        let entries = parse("hot-path-index lsh/soft.rs 2 audited kernels\n").unwrap();
        // Exactly covered.
        let a = apply(
            vec![finding("hot-path-index", "lsh/soft.rs", 1), finding("hot-path-index", "lsh/soft.rs", 2)],
            &entries,
        );
        assert!(a.fresh.is_empty() && a.stale.is_empty());
        // One extra → fresh debt.
        let b = apply(
            vec![
                finding("hot-path-index", "lsh/soft.rs", 1),
                finding("hot-path-index", "lsh/soft.rs", 2),
                finding("hot-path-index", "lsh/soft.rs", 3),
            ],
            &entries,
        );
        assert_eq!(b.fresh.len(), 1);
        // One fewer → stale ratchet.
        let c = apply(vec![finding("hot-path-index", "lsh/soft.rs", 1)], &entries);
        assert!(c.fresh.is_empty());
        assert_eq!(c.stale.len(), 1);
        // Different path never borrows the budget.
        let d = apply(vec![finding("hot-path-index", "lsh/bnb.rs", 1)], &entries);
        assert_eq!(d.fresh.len(), 1);
    }

    #[test]
    fn render_preserves_reasons() {
        let old = parse("hot-path-index lsh/soft.rs 5 audited kernels\n").unwrap();
        let findings = vec![
            finding("hot-path-index", "lsh/soft.rs", 1),
            finding("hot-path-panic", "lsh/bnb.rs", 9),
        ];
        let text = render(&findings, &old);
        assert!(text.contains("hot-path-index lsh/soft.rs 1 audited kernels"));
        assert!(text.contains("hot-path-panic lsh/bnb.rs 1 TODO: explain or fix"));
    }
}
