//! The rule engine: walks the lexed token/comment streams of one file
//! and emits findings. Every rule is a repo invariant that PRs 4–6
//! established by review and that no compiler pass checks:
//!
//! | rule                | invariant                                             |
//! |---------------------|-------------------------------------------------------|
//! | `safety-comment`    | every `unsafe {` block carries a `// SAFETY:` comment |
//! | `ordering-rationale`| every atomic `Ordering::*` site carries or inherits a  |
//! |                     | comment naming the ordering and why it suffices        |
//! | `atomics-allowlist` | atomics only in modules audited for lock-free use      |
//! | `hot-path-panic`    | no `unwrap`/`expect`/`panic!`-family in hot modules    |
//! | `hot-path-index`    | no panicking slice-index syntax in hot modules         |
//! | `alloc-in-into`     | `*_into` functions (zero-alloc contract) never allocate|
//! | `instant-in-kernel` | scoring kernels never read the clock                   |
//!
//! Waivers: `// lint:allow(rule): reason` covers the next (or same)
//! line; `// lint:allow-file(rule): reason` covers the whole file. A
//! waiver without a reason is itself a finding
//! (`waiver-missing-reason`), as is one naming an unknown rule.
//!
//! Test code is exempt: items under `#[cfg(test)]` / `#[test]` are
//! stripped from the token stream before rules run (`cfg(not(test))`
//! is production code and is kept).

use crate::lexer::{lex, Comment, Lexed, Tok, TokKind};

/// Rule ids + one-line descriptions (also the `--rules` listing).
pub const RULES: &[(&str, &str)] = &[
    ("safety-comment", "unsafe block without a // SAFETY: rationale within 5 lines"),
    ("ordering-rationale", "atomic Ordering:: site with no ordering rationale comment in reach"),
    ("atomics-allowlist", "atomic Ordering:: site outside the audited lock-free modules"),
    ("hot-path-panic", "unwrap/expect/panic!-family call in a hot-path module"),
    ("hot-path-index", "panicking slice-index syntax in a hot-path module"),
    ("alloc-in-into", "allocation token inside a *_into (zero-alloc contract) function"),
    ("instant-in-kernel", "Instant::now in a scoring-kernel module"),
    ("waiver-missing-reason", "lint:allow waiver without a reason after the colon"),
    ("waiver-unknown-rule", "lint:allow waiver naming a rule that does not exist"),
];

pub fn rule_exists(id: &str) -> bool {
    RULES.iter().any(|(r, _)| *r == id)
}

/// One finding, pre-waiver.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    pub rule: &'static str,
    /// Path relative to the scanned root, forward slashes.
    pub path: String,
    pub line: u32,
    pub msg: String,
}

/// Modules audited for lock-free atomics (prefix or exact match on the
/// root-relative path). Everything else must route through these or
/// carry an explicit `lint:allow-file(atomics-allowlist)` waiver.
const ATOMICS_ALLOWLIST: &[&str] =
    &["util/pool.rs", "metrics/registry.rs", "server/", "server.rs", "simd/dispatch.rs"];

/// Hot-path modules: the decode/scoring path where a panic aborts a
/// serving turn and an allocation shows up in tail latency.
const HOT_PATHS: &[&str] = &["lsh/", "lsh.rs", "linalg/", "linalg.rs", "selector/", "selector.rs", "kvcache/", "kvcache.rs", "simd/"];

/// Scoring-kernel modules: no clock reads (timing lives in the bench
/// and serving layers, never inside the kernels being timed).
const KERNEL_PATHS: &[&str] = &["lsh/", "lsh.rs", "linalg/", "linalg.rs", "selector/", "selector.rs", "simd/"];

const ATOMIC_ORDERINGS: &[&str] = &["Relaxed", "SeqCst", "Acquire", "Release", "AcqRel"];

/// Comment markers accepted as an ordering rationale.
const ORDERING_MARKERS: &[&str] = &["relaxed", "seqcst", "acquire", "release", "ordering"];

fn path_in(path: &str, set: &[&str]) -> bool {
    set.iter().any(|p| {
        if p.ends_with('/') {
            path.starts_with(p)
        } else {
            path == *p
        }
    })
}

/// Check one file's source; returns findings sorted by line (waivers
/// already applied; waiver-syntax findings included).
pub fn check_source(rel_path: &str, src: &str) -> Vec<Finding> {
    let lexed = lex(src);
    let toks = strip_test_code(&lexed.toks);
    let fns = fn_spans(&toks);
    let ctx = Ctx { path: rel_path, toks: &toks, comments: &lexed.comments, fns: &fns };

    let mut findings = Vec::new();
    rule_safety_comment(&ctx, &mut findings);
    rule_ordering(&ctx, &mut findings);
    rule_hot_path_panic(&ctx, &mut findings);
    rule_hot_path_index(&ctx, &mut findings);
    rule_alloc_in_into(&ctx, &mut findings);
    rule_instant_in_kernel(&ctx, &mut findings);

    let waivers = parse_waivers(rel_path, &lexed.comments, &mut findings);
    findings.retain(|f| !waivers.covers(f));
    findings.sort_by_key(|f| (f.line, f.rule));
    findings
}

struct Ctx<'a> {
    path: &'a str,
    toks: &'a [Tok],
    comments: &'a [Comment],
    fns: &'a [FnSpan],
}

// ---------------------------------------------------------------------------
// cfg(test) stripping
// ---------------------------------------------------------------------------

/// Drop items gated behind `#[cfg(test)]` / `#[test]` from the token
/// stream. `#[cfg(not(test))]` is kept — that IS the production code.
pub fn strip_test_code(toks: &[Tok]) -> Vec<Tok> {
    let mut out = Vec::with_capacity(toks.len());
    let mut i = 0;
    while i < toks.len() {
        if toks[i].is_punct('#')
            && i + 1 < toks.len()
            && toks[i + 1].is_punct('[')
        {
            let close = match_delim(toks, i + 1, '[', ']');
            if attr_is_test(&toks[i + 2..close]) {
                i = skip_item(toks, close + 1);
                continue;
            }
            out.extend_from_slice(&toks[i..=close.min(toks.len() - 1)]);
            i = close + 1;
            continue;
        }
        out.push(toks[i].clone());
        i += 1;
    }
    out
}

/// Is this attribute body (`test`, `cfg(test)`, `cfg(any(test, ...))`)
/// a test gate? `not` anywhere means the cfg keeps production code.
fn attr_is_test(attr: &[Tok]) -> bool {
    let first = attr.first().and_then(|t| t.ident());
    match first {
        Some("test") => true,
        Some("cfg") => {
            attr.iter().any(|t| t.is_ident("test")) && !attr.iter().any(|t| t.is_ident("not"))
        }
        _ => false,
    }
}

/// Index just past the item starting at `i`: skips further attributes,
/// then consumes through the first balanced `{...}` body, or through a
/// `;` if one appears first (use decls, trait method signatures).
fn skip_item(toks: &[Tok], mut i: usize) -> usize {
    // Further attributes on the same item.
    while i + 1 < toks.len() && toks[i].is_punct('#') && toks[i + 1].is_punct('[') {
        i = match_delim(toks, i + 1, '[', ']') + 1;
    }
    while i < toks.len() {
        if toks[i].is_punct('{') {
            return match_delim(toks, i, '{', '}') + 1;
        }
        if toks[i].is_punct(';') {
            return i + 1;
        }
        i += 1;
    }
    i
}

/// Index of the delimiter closing the one at `open` (which must hold
/// `open_c`). Clamps to the last token on unbalanced input.
fn match_delim(toks: &[Tok], open: usize, open_c: char, close_c: char) -> usize {
    let mut depth = 0usize;
    for (j, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct(open_c) {
            depth += 1;
        } else if t.is_punct(close_c) {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
    }
    toks.len().saturating_sub(1)
}

// ---------------------------------------------------------------------------
// fn spans
// ---------------------------------------------------------------------------

/// A function item: name, the line of its `fn` keyword, and the token
/// range of its body (for "inside fn X" queries). Nested fns produce
/// nested spans; lookups pick the innermost.
#[derive(Debug)]
pub struct FnSpan {
    pub name: String,
    pub fn_line: u32,
    pub body: std::ops::Range<usize>,
}

fn fn_spans(toks: &[Tok]) -> Vec<FnSpan> {
    let mut spans = Vec::new();
    for i in 0..toks.len() {
        if !toks[i].is_ident("fn") {
            continue;
        }
        let Some(name) = toks.get(i + 1).and_then(|t| t.ident()) else { continue };
        // Body = first `{` before any top-level `;` (a `;` first means
        // a bodyless trait-method signature).
        let mut j = i + 2;
        let mut body = None;
        while j < toks.len() {
            if toks[j].is_punct('{') {
                body = Some(j..match_delim(toks, j, '{', '}') + 1);
                break;
            }
            if toks[j].is_punct(';') {
                break;
            }
            j += 1;
        }
        if let Some(body) = body {
            spans.push(FnSpan { name: name.to_string(), fn_line: toks[i].line, body });
        }
    }
    spans
}

/// Innermost fn span containing token index `idx`.
fn enclosing_fn<'a>(fns: &'a [FnSpan], idx: usize) -> Option<&'a FnSpan> {
    fns.iter()
        .filter(|f| f.body.contains(&idx))
        .min_by_key(|f| f.body.end - f.body.start)
}

// ---------------------------------------------------------------------------
// comment queries
// ---------------------------------------------------------------------------

/// Does any comment ending within `window` lines above (or trailing on)
/// `line` satisfy `pred`?
fn comment_near(comments: &[Comment], line: u32, window: u32, pred: impl Fn(&str) -> bool) -> bool {
    comments.iter().any(|c| {
        c.line <= line && c.end_line + window >= line && pred(&c.text)
    })
}

/// The contiguous comment block directly above `line` (doc comment
/// lines chain; up to 2 intervening non-comment lines — attributes —
/// are tolerated between the block and `line`). Joined text, lowercased.
fn header_block(comments: &[Comment], line: u32) -> String {
    let mut parts: Vec<&str> = Vec::new();
    let mut want = line;
    for c in comments.iter().rev() {
        if c.end_line >= want {
            continue; // trailing or below
        }
        if c.end_line + 3 >= want {
            parts.push(&c.text);
            want = c.line;
        } else if c.end_line < want {
            break;
        }
    }
    parts.reverse();
    parts.join("\n").to_lowercase()
}

// ---------------------------------------------------------------------------
// the rules
// ---------------------------------------------------------------------------

fn rule_safety_comment(ctx: &Ctx, out: &mut Vec<Finding>) {
    for (i, t) in ctx.toks.iter().enumerate() {
        if !t.is_ident("unsafe") {
            continue;
        }
        // Only `unsafe {` blocks; `unsafe fn`/`unsafe impl` are covered
        // by their own doc contracts and by unsafe_op_in_unsafe_fn.
        if !matches!(ctx.toks.get(i + 1), Some(n) if n.is_punct('{')) {
            continue;
        }
        let ok = comment_near(ctx.comments, t.line, 5, |text| text.contains("SAFETY:"));
        if !ok {
            out.push(Finding {
                rule: "safety-comment",
                path: ctx.path.to_string(),
                line: t.line,
                msg: "unsafe block without a // SAFETY: comment within 5 lines".into(),
            });
        }
    }
}

fn rule_ordering(ctx: &Ctx, out: &mut Vec<Finding>) {
    for i in 0..ctx.toks.len() {
        let Some(variant) = atomic_ordering_at(ctx.toks, i) else { continue };
        let line = ctx.toks[i].line;
        if !path_in(ctx.path, ATOMICS_ALLOWLIST) {
            out.push(Finding {
                rule: "atomics-allowlist",
                path: ctx.path.to_string(),
                line,
                msg: format!(
                    "Ordering::{variant} outside the audited lock-free modules ({})",
                    ATOMICS_ALLOWLIST.join(", ")
                ),
            });
        }
        let near = comment_near(ctx.comments, line, 5, |text| {
            let lower = text.to_lowercase();
            ORDERING_MARKERS.iter().any(|m| lower.contains(m))
        });
        let inherited = near
            || enclosing_fn(ctx.fns, i).is_some_and(|f| {
                let hdr = header_block(ctx.comments, f.fn_line);
                ORDERING_MARKERS.iter().any(|m| hdr.contains(m))
            });
        if !inherited {
            out.push(Finding {
                rule: "ordering-rationale",
                path: ctx.path.to_string(),
                line,
                msg: format!(
                    "Ordering::{variant} with no ordering rationale in a nearby comment \
                     or the enclosing fn's header"
                ),
            });
        }
    }
}

/// `Ordering :: <atomic variant>` at token `i` (filters out
/// `std::cmp::Ordering::Equal` and friends by variant name).
fn atomic_ordering_at(toks: &[Tok], i: usize) -> Option<&str> {
    if !toks[i].is_ident("Ordering") {
        return None;
    }
    if !(toks.get(i + 1)?.is_punct(':') && toks.get(i + 2)?.is_punct(':')) {
        return None;
    }
    let v = toks.get(i + 3)?.ident()?;
    ATOMIC_ORDERINGS.contains(&v).then_some(v)
}

fn rule_hot_path_panic(ctx: &Ctx, out: &mut Vec<Finding>) {
    if !path_in(ctx.path, HOT_PATHS) {
        return;
    }
    for (i, t) in ctx.toks.iter().enumerate() {
        let Some(id) = t.ident() else { continue };
        let hit = match id {
            // `.unwrap()` / `.expect(...)` — method calls only, so
            // `unwrap_or*` (distinct idents) never match.
            "unwrap" | "expect" => i > 0 && ctx.toks[i - 1].is_punct('.'),
            "panic" | "unreachable" | "todo" | "unimplemented" => {
                matches!(ctx.toks.get(i + 1), Some(n) if n.is_punct('!'))
            }
            _ => false,
        };
        if hit {
            out.push(Finding {
                rule: "hot-path-panic",
                path: ctx.path.to_string(),
                line: t.line,
                msg: format!("panicking call `{id}` in hot-path module"),
            });
        }
    }
}

fn rule_hot_path_index(ctx: &Ctx, out: &mut Vec<Finding>) {
    if !path_in(ctx.path, HOT_PATHS) {
        return;
    }
    for (i, t) in ctx.toks.iter().enumerate() {
        if !t.is_punct('[') || i == 0 {
            continue;
        }
        let indexing = match &ctx.toks[i - 1].kind {
            TokKind::Ident(s) => !is_keyword(s),
            TokKind::Punct(')') | TokKind::Punct(']') => true,
            _ => false,
        };
        if indexing {
            out.push(Finding {
                rule: "hot-path-index",
                path: ctx.path.to_string(),
                line: t.line,
                msg: "panicking slice-index syntax in hot-path module (prefer get/get_unchecked \
                      with a SAFETY argument, or iterators)"
                    .into(),
            });
        }
    }
}

fn rule_alloc_in_into(ctx: &Ctx, out: &mut Vec<Finding>) {
    for f in ctx.fns.iter().filter(|f| f.name.ends_with("_into")) {
        // Inner fns/closures inherit the contract: the whole body range
        // is scanned (innermost-span dedup not needed — nested `*_into`
        // fns would double-report, which we avoid by skipping tokens
        // owned by a nested *_into span).
        let nested: Vec<&FnSpan> = ctx
            .fns
            .iter()
            .filter(|g| {
                g.name.ends_with("_into")
                    && g.body.start > f.body.start
                    && g.body.end <= f.body.end
            })
            .collect();
        let toks = ctx.toks;
        let mut i = f.body.start;
        while i < f.body.end {
            if nested.iter().any(|g| g.body.contains(&i)) {
                i += 1;
                continue;
            }
            if let Some(what) = alloc_token_at(toks, i) {
                out.push(Finding {
                    rule: "alloc-in-into",
                    path: ctx.path.to_string(),
                    line: toks[i].line,
                    msg: format!("allocation `{what}` inside `{}` (zero-alloc contract)", f.name),
                });
            }
            i += 1;
        }
    }
}

fn alloc_token_at(toks: &[Tok], i: usize) -> Option<String> {
    let t = &toks[i];
    let id = t.ident()?;
    let next_path_seg = || -> Option<&str> {
        (toks.get(i + 1)?.is_punct(':') && toks.get(i + 2)?.is_punct(':'))
            .then(|| toks.get(i + 3).and_then(|t| t.ident()))
            .flatten()
    };
    match id {
        "Vec" | "String" | "Box" => {
            let seg = next_path_seg()?;
            matches!(seg, "new" | "with_capacity" | "from")
                .then(|| format!("{id}::{seg}"))
        }
        "vec" => {
            matches!(toks.get(i + 1), Some(n) if n.is_punct('!')).then(|| "vec!".to_string())
        }
        "collect" | "to_vec" | "to_owned" | "to_string" => {
            (i > 0 && toks[i - 1].is_punct('.')).then(|| format!(".{id}()"))
        }
        _ => None,
    }
}

fn rule_instant_in_kernel(ctx: &Ctx, out: &mut Vec<Finding>) {
    if !path_in(ctx.path, KERNEL_PATHS) {
        return;
    }
    for i in 0..ctx.toks.len() {
        if ctx.toks[i].is_ident("Instant")
            && matches!(ctx.toks.get(i + 1), Some(t) if t.is_punct(':'))
            && matches!(ctx.toks.get(i + 2), Some(t) if t.is_punct(':'))
            && matches!(ctx.toks.get(i + 3), Some(t) if t.is_ident("now"))
        {
            out.push(Finding {
                rule: "instant-in-kernel",
                path: ctx.path.to_string(),
                line: ctx.toks[i].line,
                msg: "Instant::now inside a scoring kernel (timing belongs in bench/serving \
                      layers)"
                    .into(),
            });
        }
    }
}

fn is_keyword(s: &str) -> bool {
    matches!(
        s,
        "as" | "break" | "const" | "continue" | "crate" | "dyn" | "else" | "enum" | "extern"
            | "false" | "fn" | "for" | "if" | "impl" | "in" | "let" | "loop" | "match" | "mod"
            | "move" | "mut" | "pub" | "ref" | "return" | "self" | "Self" | "static" | "struct"
            | "super" | "trait" | "true" | "type" | "unsafe" | "use" | "where" | "while"
            | "async" | "await"
    )
}

// ---------------------------------------------------------------------------
// waivers
// ---------------------------------------------------------------------------

struct Waivers {
    /// (rule, covered-line range inclusive). `None` range = whole file.
    entries: Vec<(String, Option<(u32, u32)>)>,
}

impl Waivers {
    fn covers(&self, f: &Finding) -> bool {
        self.entries.iter().any(|(rule, range)| {
            rule == f.rule
                && match range {
                    None => true,
                    Some((lo, hi)) => (*lo..=*hi).contains(&f.line),
                }
        })
    }
}

/// Parse `lint:allow(...)` / `lint:allow-file(...)` waivers out of the
/// comment stream. Malformed waivers (missing reason, unknown rule)
/// become findings themselves and do NOT suppress anything.
fn parse_waivers(path: &str, comments: &[Comment], out: &mut Vec<Finding>) -> Waivers {
    let mut entries = Vec::new();
    for c in comments {
        for (needle, file_wide) in [("lint:allow-file(", true), ("lint:allow(", false)] {
            let Some(at) = c.text.find(needle) else { continue };
            let rest = &c.text[at + needle.len()..];
            let Some(close) = rest.find(')') else {
                out.push(Finding {
                    rule: "waiver-missing-reason",
                    path: path.to_string(),
                    line: c.line,
                    msg: "malformed waiver: missing `)` after rule list".into(),
                });
                continue;
            };
            let rules: Vec<&str> = rest[..close].split(',').map(str::trim).collect();
            let after = rest[close + 1..].trim_start();
            let reason = after.strip_prefix(':').map(str::trim).unwrap_or("");
            if reason.is_empty() || reason.starts_with("TODO") {
                out.push(Finding {
                    rule: "waiver-missing-reason",
                    path: path.to_string(),
                    line: c.line,
                    msg: "waiver must carry a non-TODO reason: `// lint:allow(rule): why`".into(),
                });
                continue;
            }
            let mut ok = true;
            for r in &rules {
                if !rule_exists(r) {
                    out.push(Finding {
                        rule: "waiver-unknown-rule",
                        path: path.to_string(),
                        line: c.line,
                        msg: format!("waiver names unknown rule `{r}`"),
                    });
                    ok = false;
                }
            }
            if !ok {
                continue;
            }
            for r in rules {
                // A line waiver covers the comment itself plus the
                // following statement — 3 lines of slack so rustfmt
                // reflowing a binding doesn't strand the waiver.
                let range = if file_wide { None } else { Some((c.line, c.end_line + 3)) };
                entries.push((r.to_string(), range));
            }
            break; // one waiver per comment (allow-file matched first)
        }
    }
    Waivers { entries }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_hit(path: &str, src: &str) -> Vec<&'static str> {
        check_source(path, src).into_iter().map(|f| f.rule).collect()
    }

    #[test]
    fn unsafe_needs_safety_comment() {
        let bad = "fn f(p: *const u8) -> u8 { unsafe { *p } }";
        assert_eq!(rules_hit("util/other.rs", bad), vec!["safety-comment"]);
        let good = "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller guarantees p is valid.\n    unsafe { *p }\n}";
        assert!(rules_hit("util/other.rs", good).is_empty());
        let trailing = "fn f(p: *const u8) -> u8 { unsafe { *p } } // SAFETY: valid by contract";
        assert!(rules_hit("util/other.rs", trailing).is_empty());
    }

    #[test]
    fn safety_comment_window_is_five_lines() {
        let far = "fn f(p: *const u8) -> u8 {\n    // SAFETY: too far away.\n\n\n\n\n\n\n    unsafe { *p }\n}";
        assert_eq!(rules_hit("util/other.rs", far), vec!["safety-comment"]);
    }

    #[test]
    fn unsafe_fn_is_not_flagged_here() {
        // unsafe fn decls are covered by unsafe_op_in_unsafe_fn; this
        // rule only polices blocks.
        let src = "unsafe fn g(p: *const u8) -> u8 {\n    // SAFETY: p valid per contract.\n    unsafe { *p }\n}";
        assert!(rules_hit("util/other.rs", src).is_empty());
    }

    #[test]
    fn ordering_needs_rationale_and_allowlist() {
        let src = "use std::sync::atomic::{AtomicU64, Ordering};\nfn f(a: &AtomicU64) -> u64 { a.load(Ordering::Relaxed) }";
        let hits = rules_hit("lsh/foo.rs", src);
        assert!(hits.contains(&"atomics-allowlist"), "{hits:?}");
        assert!(hits.contains(&"ordering-rationale"), "{hits:?}");
        // Allowlisted path + same-line rationale → clean.
        let good = "use std::sync::atomic::{AtomicU64, Ordering};\nfn f(a: &AtomicU64) -> u64 { a.load(Ordering::Relaxed) } // Relaxed: independent counter";
        assert!(rules_hit("util/pool.rs", good).is_empty());
    }

    #[test]
    fn ordering_rationale_inherits_from_fn_header() {
        let src = "/// Counter bump. Relaxed atomics: samples are\n/// independent, no ordering needed.\nfn f(a: &std::sync::atomic::AtomicU64) {\n    a.fetch_add(1, Ordering::Relaxed);\n    a.fetch_add(2, Ordering::Relaxed);\n}";
        assert!(rules_hit("metrics/registry.rs", src).is_empty());
    }

    #[test]
    fn cmp_ordering_is_not_atomic() {
        let src = "fn f(a: u32, b: u32) -> std::cmp::Ordering { a.cmp(&b).then(Ordering::Equal) }";
        assert!(rules_hit("linalg/topk.rs", src).is_empty());
    }

    #[test]
    fn hot_path_panic_tokens() {
        let src = "fn f(v: &[u32]) -> u32 { *v.first().unwrap() }";
        assert_eq!(rules_hit("lsh/foo.rs", src), vec!["hot-path-panic"]);
        // unwrap_or is a different ident — never flagged.
        let ok = "fn f(v: &[u32]) -> u32 { v.first().copied().unwrap_or(0) }";
        assert!(rules_hit("lsh/foo.rs", ok).is_empty());
        // Outside hot paths, unwrap is allowed.
        assert!(rules_hit("util/foo.rs", src).is_empty());
        let mac = "fn f() { panic!(\"boom\") }";
        assert_eq!(rules_hit("selector/foo.rs", mac), vec!["hot-path-panic"]);
    }

    #[test]
    fn cfg_test_code_is_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { let v = vec![1]; v[0]; v.last().unwrap(); }\n}";
        assert!(rules_hit("lsh/foo.rs", src).is_empty());
        // cfg(not(test)) is production code: still flagged.
        let not_test = "#[cfg(not(test))]\nfn f(v: &[u32]) -> u32 { v.last().unwrap().clone() }";
        assert_eq!(rules_hit("lsh/foo.rs", not_test), vec!["hot-path-panic"]);
    }

    #[test]
    fn slice_index_heuristic() {
        assert_eq!(rules_hit("linalg/m.rs", "fn f(v: &[f32]) -> f32 { v[3] }"), vec!["hot-path-index"]);
        // Declarations, types, attributes, vec! are not indexing.
        let ok = "#[derive(Clone)]\nstruct S { a: [f32; 4] }\nfn f(x: &mut [f32]) -> Vec<[f32; 2]> { let _ = x; vec![] }";
        assert!(rules_hit("linalg/m.rs", ok).is_empty());
        // Chained: foo()[i] and x[i][j].
        assert_eq!(
            rules_hit("linalg/m.rs", "fn f(v: Vec<Vec<f32>>, i: usize) -> f32 { v[i][0] }"),
            vec!["hot-path-index", "hot-path-index"]
        );
    }

    #[test]
    fn alloc_in_into_fns() {
        let bad = "fn scores_into(out: &mut Vec<f32>) { let tmp: Vec<f32> = Vec::new(); out.extend(tmp); }";
        assert_eq!(rules_hit("util/x.rs", bad), vec!["alloc-in-into"]);
        let bad2 = "fn select_into(out: &mut Vec<u32>) { *out = (0..4).collect(); }";
        assert_eq!(rules_hit("util/x.rs", bad2), vec!["alloc-in-into"]);
        let ok = "fn select_into(out: &mut Vec<u32>) { out.clear(); out.extend(0..4); }\nfn other() -> Vec<u32> { Vec::new() }";
        assert!(rules_hit("util/x.rs", ok).is_empty());
    }

    #[test]
    fn instant_in_kernel() {
        let src = "fn score() { let _t = std::time::Instant::now(); }";
        assert_eq!(rules_hit("lsh/soft.rs", src), vec!["instant-in-kernel"]);
        assert!(rules_hit("bench/run.rs", src).is_empty());
    }

    #[test]
    fn waivers_suppress_with_reason() {
        let src = "// lint:allow(hot-path-panic): documented diagnostic API, panics by contract\nfn f(v: &[u32]) -> u32 { *v.first().unwrap() }";
        assert!(rules_hit("lsh/foo.rs", src).is_empty());
        // Same-line trailing waiver.
        let trail = "fn f(v: &[u32]) -> u32 { *v.first().unwrap() } // lint:allow(hot-path-panic): contract";
        assert!(rules_hit("lsh/foo.rs", trail).is_empty());
    }

    #[test]
    fn waiver_without_reason_is_a_finding() {
        let src = "// lint:allow(hot-path-panic):\nfn f(v: &[u32]) -> u32 { *v.first().unwrap() }";
        let hits = rules_hit("lsh/foo.rs", src);
        assert!(hits.contains(&"waiver-missing-reason"), "{hits:?}");
        assert!(hits.contains(&"hot-path-panic"), "un-reasoned waiver must not suppress: {hits:?}");
        let todo = "// lint:allow(hot-path-panic): TODO\nfn f(v: &[u32]) -> u32 { *v.first().unwrap() }";
        assert!(rules_hit("lsh/foo.rs", todo).contains(&"waiver-missing-reason"));
    }

    #[test]
    fn waiver_unknown_rule_is_a_finding() {
        let src = "// lint:allow(no-such-rule): because\nfn f() {}";
        assert_eq!(rules_hit("lsh/foo.rs", src), vec!["waiver-unknown-rule"]);
    }

    #[test]
    fn file_waiver_covers_everything() {
        let src = "// lint:allow-file(hot-path-panic): module is test-only diagnostics\nfn f(v: &[u32]) -> u32 { v.first().unwrap() + v.last().unwrap() }";
        assert!(rules_hit("lsh/foo.rs", src).is_empty());
    }

    #[test]
    fn fn_spans_nest() {
        let l = lex("fn outer() { fn inner_into() { } Vec::new(); }");
        let toks = strip_test_code(&l.toks);
        let fns = fn_spans(&toks);
        assert_eq!(fns.len(), 2);
        // Vec::new is in outer (not a *_into fn) → no finding.
        assert!(check_source("util/x.rs", "fn outer() { fn inner_into() { } let v: Vec<u32> = Vec::new(); let _ = v; }").is_empty());
    }
}
