//! A small, dependency-free Rust lexer — just enough fidelity for the
//! rule engine: identifiers, punctuation, and literals with line
//! numbers, plus the full comment stream (the rules read `// SAFETY:`
//! and `// lint:allow(...)` annotations out of comments).
//!
//! Deliberately NOT a full Rust grammar. The hard parts it does get
//! right, because getting them wrong corrupts every downstream rule:
//!
//! - line (`//`) and nested block (`/* /* */ */`) comments, including
//!   doc comments (`///`, `//!`, `/** */`) — captured, not discarded;
//! - string, raw-string (`r#"..."#`, any number of `#`s), byte-string
//!   and char literals — brackets/braces inside them must not confuse
//!   token matching;
//! - char literal vs. lifetime disambiguation (`'a'` vs `'a`);
//! - numeric literals, so `0..10` or `1.5e3` never masquerade as
//!   identifiers or stray punctuation that rules key on.

/// One lexed token.
#[derive(Debug, Clone, PartialEq)]
pub struct Tok {
    /// 1-based source line.
    pub line: u32,
    pub kind: TokKind,
}

#[derive(Debug, Clone, PartialEq)]
pub enum TokKind {
    /// Identifier or keyword (rules distinguish keywords themselves).
    Ident(String),
    /// Single punctuation character (`::` arrives as two `:` toks).
    Punct(char),
    /// String/char/byte/numeric literal. Payload is dropped — no rule
    /// inspects literal contents, only their presence.
    Literal,
    /// A lifetime such as `'a` or `'_` (distinct from a char literal).
    Lifetime,
}

impl Tok {
    pub fn ident(&self) -> Option<&str> {
        match &self.kind {
            TokKind::Ident(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn is_ident(&self, s: &str) -> bool {
        self.ident() == Some(s)
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct(c)
    }
}

/// One comment (line or block). Block comments may span lines.
#[derive(Debug, Clone)]
pub struct Comment {
    /// 1-based line where the comment starts.
    pub line: u32,
    /// 1-based line where the comment ends (== `line` for `//`).
    pub end_line: u32,
    /// Raw text including the `//` / `/*` sigils.
    pub text: String,
}

/// Lexer output: the token stream plus the parallel comment stream.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

pub fn lex(src: &str) -> Lexed {
    Lexer { chars: src.chars().collect(), pos: 0, line: 1, out: Lexed::default() }.run()
}

struct Lexer {
    chars: Vec<char>,
    pos: usize,
    line: u32,
    out: Lexed,
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.pos + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.pos).copied();
        if let Some(c) = c {
            self.pos += 1;
            if c == '\n' {
                self.line += 1;
            }
        }
        c
    }

    fn push_tok(&mut self, line: u32, kind: TokKind) {
        self.out.toks.push(Tok { line, kind });
    }

    fn run(mut self) -> Lexed {
        while let Some(c) = self.peek(0) {
            let line = self.line;
            match c {
                c if c.is_whitespace() => {
                    self.bump();
                }
                '/' if self.peek(1) == Some('/') => self.line_comment(),
                '/' if self.peek(1) == Some('*') => self.block_comment(),
                '"' => self.string_lit(line),
                '\'' => self.char_or_lifetime(line),
                'r' | 'b' if self.raw_or_byte_prefix() => self.raw_or_byte_lit(line),
                c if c.is_alphabetic() || c == '_' => self.ident(line),
                c if c.is_ascii_digit() => self.number(line),
                _ => {
                    self.bump();
                    self.push_tok(line, TokKind::Punct(c));
                }
            }
        }
        self.out
    }

    fn line_comment(&mut self) {
        let line = self.line;
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                break;
            }
            text.push(c);
            self.bump();
        }
        self.out.comments.push(Comment { line, end_line: line, text });
    }

    fn block_comment(&mut self) {
        let line = self.line;
        let mut text = String::new();
        let mut depth = 0usize;
        while let Some(c) = self.peek(0) {
            if c == '/' && self.peek(1) == Some('*') {
                depth += 1;
                text.push_str("/*");
                self.bump();
                self.bump();
            } else if c == '*' && self.peek(1) == Some('/') {
                depth -= 1;
                text.push_str("*/");
                self.bump();
                self.bump();
                if depth == 0 {
                    break;
                }
            } else {
                text.push(c);
                self.bump();
            }
        }
        self.out.comments.push(Comment { line, end_line: self.line, text });
    }

    /// Consume a `"..."` literal, honoring `\"` escapes.
    fn string_lit(&mut self, line: u32) {
        self.bump(); // opening quote
        while let Some(c) = self.bump() {
            match c {
                '\\' => {
                    self.bump();
                }
                '"' => break,
                _ => {}
            }
        }
        self.push_tok(line, TokKind::Literal);
    }

    /// `'a'` (char literal) vs `'a` / `'static` (lifetime). A quote
    /// followed by an identifier char is a lifetime unless the very
    /// next char closes the quote (`'x'`); `'\...'` is always a char.
    fn char_or_lifetime(&mut self, line: u32) {
        let c1 = self.peek(1);
        let c2 = self.peek(2);
        let is_lifetime = match c1 {
            Some(c) if c.is_alphanumeric() || c == '_' => c2 != Some('\''),
            _ => false,
        };
        self.bump(); // the quote
        if is_lifetime {
            while let Some(c) = self.peek(0) {
                if c.is_alphanumeric() || c == '_' {
                    self.bump();
                } else {
                    break;
                }
            }
            self.push_tok(line, TokKind::Lifetime);
        } else {
            while let Some(c) = self.bump() {
                match c {
                    '\\' => {
                        self.bump();
                    }
                    '\'' => break,
                    _ => {}
                }
            }
            self.push_tok(line, TokKind::Literal);
        }
    }

    /// Is the current `r`/`b` the prefix of a raw/byte string or byte
    /// char (`r"`, `r#"`, `br"`, `b"`, `b'`, `rb…` is not Rust)?
    fn raw_or_byte_prefix(&self) -> bool {
        let c0 = self.peek(0);
        match c0 {
            Some('r') => {
                // r"..." or r#"..."# (any number of #s). r#ident is a
                // raw identifier, not a string — require `"` after #s.
                let mut i = 1;
                while self.peek(i) == Some('#') {
                    i += 1;
                }
                self.peek(i) == Some('"')
            }
            Some('b') => match self.peek(1) {
                Some('"') | Some('\'') => true,
                Some('r') => {
                    let mut i = 2;
                    while self.peek(i) == Some('#') {
                        i += 1;
                    }
                    self.peek(i) == Some('"')
                }
                _ => false,
            },
            _ => false,
        }
    }

    fn raw_or_byte_lit(&mut self, line: u32) {
        // Consume the prefix letters.
        while matches!(self.peek(0), Some('r') | Some('b')) {
            self.bump();
        }
        if self.peek(0) == Some('\'') {
            // b'x' byte char — same rules as a char literal body.
            self.bump();
            while let Some(c) = self.bump() {
                match c {
                    '\\' => {
                        self.bump();
                    }
                    '\'' => break,
                    _ => {}
                }
            }
            self.push_tok(line, TokKind::Literal);
            return;
        }
        // Count #s, then consume until `"` followed by that many #s.
        let mut hashes = 0usize;
        while self.peek(0) == Some('#') {
            hashes += 1;
            self.bump();
        }
        self.bump(); // opening quote
        'outer: while let Some(c) = self.bump() {
            if c == '"' {
                for i in 0..hashes {
                    if self.peek(i) != Some('#') {
                        continue 'outer;
                    }
                }
                for _ in 0..hashes {
                    self.bump();
                }
                break;
            }
            // No escapes in raw strings.
        }
        self.push_tok(line, TokKind::Literal);
    }

    fn ident(&mut self, line: u32) {
        let mut s = String::new();
        while let Some(c) = self.peek(0) {
            if c.is_alphanumeric() || c == '_' {
                s.push(c);
                self.bump();
            } else {
                break;
            }
        }
        self.push_tok(line, TokKind::Ident(s));
    }

    /// Numbers are consumed greedily including `_`, `.`, hex digits and
    /// exponent letters; `0..10` therefore lexes as one Literal, which
    /// is fine — no rule keys on numeric internals, and it keeps range
    /// dots from surfacing as stray puncts before `[`.
    fn number(&mut self, line: u32) {
        while let Some(c) = self.peek(0) {
            if c.is_ascii_alphanumeric() || c == '_' || c == '.' {
                self.bump();
            } else {
                break;
            }
        }
        self.push_tok(line, TokKind::Literal);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(l: &Lexed) -> Vec<&str> {
        l.toks.iter().filter_map(|t| t.ident()).collect()
    }

    #[test]
    fn basic_tokens_and_lines() {
        let l = lex("fn main() {\n    let x = 1;\n}\n");
        assert_eq!(idents(&l), vec!["fn", "main", "let", "x"]);
        let x = l.toks.iter().find(|t| t.is_ident("x")).unwrap();
        assert_eq!(x.line, 2);
    }

    #[test]
    fn comments_are_captured_not_tokenized() {
        let l = lex("// SAFETY: fine\nunsafe { }\n/* block\nspans */ let y = 0;");
        assert_eq!(l.comments.len(), 2);
        assert!(l.comments[0].text.contains("SAFETY:"));
        assert_eq!(l.comments[0].line, 1);
        assert_eq!(l.comments[1].line, 3);
        assert_eq!(l.comments[1].end_line, 4);
        assert_eq!(idents(&l), vec!["unsafe", "let", "y"]);
    }

    #[test]
    fn nested_block_comment() {
        let l = lex("/* outer /* inner */ still */ fn f() {}");
        assert_eq!(l.comments.len(), 1);
        assert!(l.comments[0].text.contains("inner"));
        assert_eq!(idents(&l), vec!["fn", "f"]);
    }

    #[test]
    fn strings_hide_their_contents() {
        let l = lex(r#"let s = "unsafe { unwrap() } // no";"#);
        assert_eq!(idents(&l), vec!["let", "s"]);
        assert!(l.comments.is_empty());
    }

    #[test]
    fn raw_strings_with_hashes() {
        let l = lex(r###"let s = r#"quote " inside"#; let t = 1;"###);
        assert_eq!(idents(&l), vec!["let", "s", "let", "t"]);
    }

    #[test]
    fn char_vs_lifetime() {
        let l = lex("fn f<'a>(x: &'a str) { let c = 'x'; let n = '\\n'; }");
        let lifetimes = l.toks.iter().filter(|t| t.kind == TokKind::Lifetime).count();
        assert_eq!(lifetimes, 2);
        let lits = l.toks.iter().filter(|t| t.kind == TokKind::Literal).count();
        assert_eq!(lits, 2);
    }

    #[test]
    fn numbers_swallow_range_dots() {
        let l = lex("for i in 0..10 { a[i] += 1.5e3; }");
        // `0..10` is one literal; the only '[' is the indexing one.
        let brackets = l.toks.iter().filter(|t| t.is_punct('[')).count();
        assert_eq!(brackets, 1);
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        let l = lex(r##"let a = b"raw"; let b2 = b'\n'; let c = br#"x"#;"##);
        assert_eq!(idents(&l), vec!["let", "a", "let", "b2", "let", "c"]);
    }
}
