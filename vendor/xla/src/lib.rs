//! Offline API stub of the `xla` PJRT bindings.
//!
//! The build environment cannot fetch or link the real XLA/PJRT
//! bindings, so this crate mirrors exactly the API surface
//! `socket_attn::runtime::engine` uses and reports a descriptive error
//! from every operation that would need the native runtime. Swapping
//! this path dependency for the real bindings (and rebuilding with
//! `--features pjrt`) turns the same engine code into a working PJRT
//! runtime; nothing downstream changes.

use std::fmt;
use std::path::Path;

/// Error type standing in for the bindings' status/error enum.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} needs the native XLA/PJRT runtime; this build links the offline \
         stub (swap vendor/xla for the real bindings)"
    ))
}

/// Element types the stub can describe in literals.
pub trait NativeType: Copy + 'static {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}
impl NativeType for u16 {}
impl NativeType for u32 {}
impl NativeType for u64 {}

/// XLA primitive types (conversion targets).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    F32,
    F64,
}

/// Array element types (shape queries).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U8,
    U32,
    F32,
    F64,
}

/// Host-side literal. The stub only tracks the element count so shape
/// plumbing (vec1 → reshape) behaves; data never reaches a device.
#[derive(Debug, Clone)]
pub struct Literal {
    elems: usize,
}

impl Literal {
    /// Rank-1 literal over a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { elems: data.len() }
    }

    /// Reshape; `&[]` means scalar (rank 0, one element).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let want: i64 = dims.iter().product();
        let want = if dims.is_empty() { 1 } else { want };
        if want as usize == self.elems {
            Ok(self.clone())
        } else {
            Err(Error(format!("reshape to {dims:?} mismatches {} elements", self.elems)))
        }
    }

    /// Element-type conversion (identity in the stub).
    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal, Error> {
        Ok(self.clone())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        Err(unavailable("Literal::array_shape"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Shape of an array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Parsed HLO module.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto, Error> {
        Err(Error(format!(
            "cannot parse {}: HLO parsing needs the native runtime (offline stub build)",
            path.as_ref().display()
        )))
    }
}

/// Computation wrapper around a parsed module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Always fails in the stub: there is no PJRT plugin to load.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute over device buffers; returns per-device output buffers.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_plumbing() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(lit.reshape(&[2, 3]).is_ok());
        assert!(lit.reshape(&[4, 2]).is_err());
        let scalar = Literal::vec1(&[7i32]);
        assert!(scalar.reshape(&[]).is_ok());
        assert!(scalar.convert(PrimitiveType::Pred).is_ok());
    }

    #[test]
    fn runtime_operations_report_stub() {
        assert!(PjRtClient::cpu().is_err());
        let err = HloModuleProto::from_text_file("artifacts/x.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("stub"));
        let err = Literal::vec1(&[0u8]).to_vec::<u8>().unwrap_err();
        assert!(err.to_string().contains("offline stub"));
    }
}
