//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so the real `anyhow`
//! cannot resolve. This vendored crate implements the small subset the
//! workspace uses — [`Error`], [`Result`], the [`anyhow!`] macro and the
//! [`Context`] extension trait — with the same call-site syntax, so the
//! path dependency can be swapped for the real crate without touching
//! downstream code.

use std::fmt;

/// A string-backed error with context chaining.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow: Error deliberately does not implement
// std::error::Error, which keeps this blanket conversion coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

/// Drop-in alias defaulting the error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context()` / `.with_context()` to `Result`
/// and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Format an ad-hoc [`Error`], mirroring `anyhow::anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn macro_formats() {
        let x = 3;
        let e = anyhow!("value was {x}");
        assert_eq!(e.to_string(), "value was 3");
        let e = anyhow!("{} and {}", 1, 2);
        assert_eq!(e.to_string(), "1 and 2");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err::<(), std::io::Error>(io_err())?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("missing"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading artifact").unwrap_err();
        assert!(e.to_string().starts_with("reading artifact: "));
        let o: Option<u8> = None;
        let e = o.with_context(|| format!("slot {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "slot 7");
    }
}
