"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes/dtypes per the repo convention; fixed-seed
numpy generates the data (deterministic, no flaky tolerances).
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.hash_keys import BLOCK_N as HASH_BLOCK, hash_keys
from compile.kernels.socket_score import BLOCK_N as SCORE_BLOCK, socket_score
from compile.kernels.soft_probs import soft_probs
from compile.kernels.sparse_decode import BLOCK_K, sparse_decode


def rand(rs, *shape):
    return jnp.asarray(rs.randn(*shape), jnp.float32)


# ---------- hash_keys (Algorithm 1) ----------


@settings(max_examples=12, deadline=None)
@given(
    n_blocks=st.integers(1, 3),
    d=st.sampled_from([8, 32, 128]),
    l=st.integers(1, 8),
    p=st.integers(1, 10),
    seed=st.integers(0, 2**16),
)
def test_hash_keys_matches_ref(n_blocks, d, l, p, seed):
    rs = np.random.RandomState(seed)
    keys = rand(rs, n_blocks * HASH_BLOCK, d)
    planes = rand(rs, l, p, d)
    got = hash_keys(keys, planes)
    want = ref.hash_keys_ref(keys, planes)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hash_keys_bucket_range():
    rs = np.random.RandomState(0)
    keys = rand(rs, HASH_BLOCK, 16)
    planes = rand(rs, 4, 6, 16)
    ids = np.asarray(hash_keys(keys, planes))
    assert ids.min() >= 0 and ids.max() < 2**6


def test_hash_keys_rejects_ragged_n():
    rs = np.random.RandomState(0)
    with pytest.raises(AssertionError):
        hash_keys(rand(rs, HASH_BLOCK + 1, 8), rand(rs, 2, 4, 8))


# ---------- soft_probs (Algorithm 2) ----------


@settings(max_examples=12, deadline=None)
@given(
    d=st.sampled_from([8, 64, 128]),
    l=st.integers(1, 8),
    p=st.integers(1, 10),
    tau=st.sampled_from([0.1, 0.5, 2.0]),
    seed=st.integers(0, 2**16),
)
def test_soft_probs_matches_ref(d, l, p, tau, seed):
    rs = np.random.RandomState(seed)
    q = rand(rs, d)
    planes = rand(rs, l, p, d)
    got = soft_probs(q, planes, tau)
    want = ref.soft_probs_ref(q, planes, tau)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_soft_probs_rows_are_distributions():
    rs = np.random.RandomState(3)
    probs = np.asarray(soft_probs(rand(rs, 32), rand(rs, 6, 8, 32), 0.5))
    assert (probs >= 0).all()
    np.testing.assert_allclose(probs.sum(axis=-1), 1.0, atol=1e-5)


def test_soft_probs_argmax_is_hard_bucket():
    # Section B.1: the dominant soft bucket equals the hard SRP bucket.
    rs = np.random.RandomState(4)
    q = rand(rs, 48)
    planes = rand(rs, 10, 7, 48)
    probs = np.asarray(soft_probs(q, planes, 0.3))
    hard = np.asarray(ref.hash_keys_ref(q[None, :], planes))[0]
    np.testing.assert_array_equal(probs.argmax(axis=-1), hard)


# ---------- socket_score (Algorithm 4) ----------


@settings(max_examples=12, deadline=None)
@given(
    n_blocks=st.integers(1, 4),
    l=st.integers(1, 12),
    p=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
def test_socket_score_matches_ref(n_blocks, l, p, seed):
    rs = np.random.RandomState(seed)
    n = n_blocks * SCORE_BLOCK
    r = 2**p
    probs = jnp.asarray(rs.dirichlet(np.ones(r), size=l), jnp.float32)
    ids = jnp.asarray(rs.randint(0, r, (n, l)), jnp.int32)
    vnorms = jnp.asarray(np.abs(rs.randn(n)), jnp.float32)
    mask = jnp.asarray(rs.rand(n) > 0.2)
    got = socket_score(probs, ids, vnorms, mask)
    want = ref.socket_score_ref(probs, ids, vnorms, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_socket_score_mask_is_neg_inf():
    rs = np.random.RandomState(1)
    n, l, p = SCORE_BLOCK, 4, 4
    probs = jnp.asarray(rs.dirichlet(np.ones(2**p), size=l), jnp.float32)
    ids = jnp.asarray(rs.randint(0, 2**p, (n, l)), jnp.int32)
    vnorms = jnp.ones((n,), jnp.float32)
    mask = jnp.zeros((n,), bool).at[0].set(True)
    s = np.asarray(socket_score(probs, ids, vnorms, mask))
    assert np.isfinite(s[0])
    assert np.isneginf(s[1:]).all()


def test_socket_score_bounded_by_l():
    rs = np.random.RandomState(2)
    n, l, p = SCORE_BLOCK, 8, 6
    probs = jnp.asarray(rs.dirichlet(np.ones(2**p), size=l), jnp.float32)
    ids = jnp.asarray(rs.randint(0, 2**p, (n, l)), jnp.int32)
    vnorms = jnp.ones((n,), jnp.float32)
    s = np.asarray(socket_score(probs, ids, vnorms, jnp.ones((n,), bool)))
    assert (s >= 0).all() and (s <= l).all()


# ---------- sparse_decode (flash decode) ----------


@settings(max_examples=12, deadline=None)
@given(
    k_blocks=st.integers(1, 4),
    d=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**16),
)
def test_sparse_decode_matches_ref(k_blocks, d, seed):
    rs = np.random.RandomState(seed)
    k = k_blocks * BLOCK_K
    q = rand(rs, d)
    keys = rand(rs, k, d)
    values = rand(rs, k, d)
    mask = jnp.asarray(rs.rand(k) > 0.3)
    if not bool(mask.any()):
        mask = mask.at[0].set(True)
    scale = 1.0 / np.sqrt(d)
    got = sparse_decode(q, keys, values, mask, scale)
    want = ref.masked_attention_ref(q, keys, values, scale, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_sparse_decode_extreme_logits_stable():
    d = 16
    q = jnp.zeros((d,)).at[0].set(1.0)
    keys = jnp.zeros((2 * BLOCK_K, d)).at[0, 0].set(90.0).at[BLOCK_K + 5, 0].set(90.0)
    values = jnp.zeros((2 * BLOCK_K, d)).at[0, 0].set(7.0).at[BLOCK_K + 5, 0].set(9.0)
    out = np.asarray(sparse_decode(q, keys, values, jnp.ones((2 * BLOCK_K,), bool), 1.0))
    assert abs(out[0] - 8.0) < 1e-3  # mean of the two spikes
    assert np.isfinite(out).all()


def test_sparse_decode_single_valid_token():
    d = 8
    rs = np.random.RandomState(5)
    keys = rand(rs, BLOCK_K, d)
    values = rand(rs, BLOCK_K, d)
    mask = jnp.zeros((BLOCK_K,), bool).at[17].set(True)
    out = np.asarray(sparse_decode(rand(rs, d), keys, values, mask, 0.5))
    np.testing.assert_allclose(out, np.asarray(values[17]), rtol=1e-5)


# ---------- end-to-end kernel pipeline ----------


def test_full_socket_pipeline_retrieves_planted_key():
    """Alg. 1 -> Alg. 2 -> Alg. 4 -> top-k -> flash decode: a planted
    near-duplicate key must rank first and dominate the output."""
    rs = np.random.RandomState(9)
    n, d, l, p = 2 * SCORE_BLOCK, 64, 20, 8
    q = rand(rs, d)
    keys = rand(rs, n, d)
    keys = keys.at[37].set(3.0 * q)
    values = rand(rs, n, d)
    planes = rand(rs, l, p, d)
    ids = ref.hash_keys_ref(keys, planes)
    vnorms = ref.value_norms_ref(values)
    probs = soft_probs(q, planes, 0.5)
    scores = socket_score(probs, ids, vnorms, jnp.ones((n,), bool))
    _, top = jax.lax.top_k(scores, 32)
    assert 37 in np.asarray(top), f"planted key missing from top-32"
    sel_mask = jnp.ones((32,), bool)
    # pad gathered set to BLOCK_K
    pad = BLOCK_K - 32
    gk = jnp.concatenate([keys[top], jnp.zeros((pad, d))])
    gv = jnp.concatenate([values[top], jnp.zeros((pad, d))])
    m = jnp.concatenate([sel_mask, jnp.zeros((pad,), bool)])
    out = sparse_decode(q, gk, gv, m, 1.0)
    dense = ref.attention_ref(q, keys, values, 1.0)
    rel = float(jnp.linalg.norm(out - dense) / jnp.linalg.norm(dense))
    assert rel < 0.05, f"rel err {rel}"
