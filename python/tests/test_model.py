"""L2 model tests: shapes, prefill/decode consistency, SOCKET-vs-dense
closeness on the tiny transformer."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def params():
    return jax.jit(model.init_params)(jnp.int32(0))


@pytest.fixture(scope="module")
def caches(params):
    tokens = jnp.asarray(np.random.RandomState(0).randint(0, model.CFG.vocab, 256), jnp.int32)
    return jax.jit(model.prefill)(params, tokens)


def test_param_count_and_order(params):
    assert len(params) == len(model.PARAM_NAMES)
    assert params[0].shape == (model.CFG.vocab, model.CFG.d_model)
    assert params[-1].shape == (
        model.CFG.n_layers,
        model.CFG.n_kv_heads,
        model.CFG.lsh_l,
        model.CFG.lsh_p,
        model.CFG.head_dim,
    )
    total = sum(int(np.prod(p.shape)) for p in params)
    assert 3_000_000 < total < 8_000_000


def test_init_deterministic():
    a = jax.jit(model.init_params)(jnp.int32(7))
    b = jax.jit(model.init_params)(jnp.int32(7))
    c = jax.jit(model.init_params)(jnp.int32(8))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
    assert not np.array_equal(np.asarray(a[1 + 1]), np.asarray(c[1 + 1]))


def test_prefill_shapes_and_length(caches):
    c = model.CFG
    k_cache, v_cache, ids_cache, vn_cache, length = caches
    assert k_cache.shape == (c.n_layers, c.n_kv_heads, c.cap, c.head_dim)
    assert ids_cache.shape == (c.n_layers, c.n_kv_heads, c.cap, c.lsh_l)
    assert int(length) == 256
    # Slots beyond length stay zero.
    assert float(jnp.abs(k_cache[:, :, 256:]).max()) == 0.0
    # Bucket ids within range.
    ids = np.asarray(ids_cache[:, :, :256])
    assert ids.min() >= 0 and ids.max() < 2**c.lsh_p


def test_prefill_hashes_match_ref(params, caches):
    from compile.kernels import ref

    k_cache, _, ids_cache, vn_cache, length = caches
    planes = params[-1]
    n = int(length)
    for i in [0, model.CFG.n_layers - 1]:
        for kv in range(model.CFG.n_kv_heads):
            want = ref.hash_keys_ref(k_cache[i, kv, :n], planes[i, kv])
            np.testing.assert_array_equal(np.asarray(ids_cache[i, kv, :n]), np.asarray(want))


def test_decode_appends_and_advances(params, caches):
    step = jax.jit(model.decode_step_socket)
    logits, k2, v2, ids2, vn2, len2 = step(params, *caches, jnp.int32(3))
    assert logits.shape == (model.CFG.vocab,)
    assert int(len2) == int(caches[-1]) + 1
    # New slot is now populated.
    assert float(jnp.abs(k2[:, :, int(caches[-1])]).max()) > 0.0


def test_socket_decode_close_to_dense(params, caches):
    ls, *_ = jax.jit(model.decode_step_socket)(params, *caches, jnp.int32(3))
    ld, *_ = jax.jit(model.decode_step_dense)(params, *caches, jnp.int32(3))
    rel = float(jnp.linalg.norm(ls - ld) / jnp.linalg.norm(ld))
    assert rel < 0.6, f"rel logits err {rel}"
    # Random (untrained) weights make argmax brittle; require strong
    # overall agreement of the logit vectors instead.
    corr = float(jnp.corrcoef(ls, ld)[0, 1])
    assert corr > 0.7, f"logit correlation {corr}"


def test_multi_step_decode_chain(params, caches):
    step = jax.jit(model.decode_step_socket)
    state = caches
    tok = jnp.int32(1)
    for s in range(4):
        logits, *state = step(params, *state, tok)
        tok = jnp.argmax(logits).astype(jnp.int32)
    assert int(state[-1]) == 260
    assert np.isfinite(np.asarray(logits)).all()
