"""AOT lowering sanity: every artifact lowers to parseable HLO text with
the expected parameter counts, and the HLO-text path round-trips through
XlaComputation (the exact interchange the Rust runtime consumes)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import pytest

from compile import aot, model


def test_param_specs_match_init():
    specs = aot.param_specs()
    assert len(specs) == len(model.PARAM_NAMES)


@pytest.mark.parametrize("name", ["soft_probs.hlo.txt", "socket_score.hlo.txt"])
def test_kernel_artifacts_lower(name):
    text = aot.ARTIFACTS[name]()
    assert text.startswith("HloModule"), text[:60]
    assert "ENTRY" in text


def test_fused_decode_artifact_contains_topk_and_scoring():
    text = aot.ARTIFACTS["socket_decode.hlo.txt"]()
    assert text.startswith("HloModule")
    # The fused module returns (attention out f32[128], top-k ids s32[512]).
    assert "s32[512]" in text, "top-k index output missing"
    assert "f32[128]" in text
    assert "gather" in text or "dynamic-slice" in text


def test_artifact_registry_is_complete():
    names = set(aot.ARTIFACTS)
    for required in [
        "hash_keys.hlo.txt",
        "soft_probs.hlo.txt",
        "socket_score.hlo.txt",
        "sparse_decode.hlo.txt",
        "dense_decode.hlo.txt",
        "socket_decode.hlo.txt",
        "model_init.hlo.txt",
        "model_prefill.hlo.txt",
        "model_decode_socket.hlo.txt",
        "model_decode_dense.hlo.txt",
    ]:
        assert required in names
