"""AOT lowering: JAX -> HLO text artifacts for the Rust PJRT runtime.

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
All entry points are lowered with ``return_tuple=True`` — the Rust side
unwraps with ``to_tuple``.

Usage: ``python -m compile.aot --out ../artifacts`` (from python/).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.hash_keys import hash_keys
from .kernels.socket_score import socket_score
from .kernels.soft_probs import soft_probs
from .kernels.sparse_decode import sparse_decode
from .kernels import ref

# Paper-scale head shapes for the standalone kernel artifacts.
KN = 2048  # context tokens
KD = 128  # head dim
KL = 60  # hash tables
KP = 10  # hyperplanes/table
KR = 2**KP
KSEL = 512  # retrieved tokens

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(fn, *example_args):
    # keep_unused=True: the Rust runtime passes the full canonical
    # parameter tuple to every entry point; jit must not prune the
    # arguments an entry point happens not to read (e.g. ln_f in
    # prefill), or the call ABIs would diverge per artifact.
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---- standalone kernel entry points (always return tuples) ----


def hash_keys_entry(keys, planes):
    return (hash_keys(keys, planes), ref.value_norms_ref(keys))


def soft_probs_entry(q, planes):
    return (soft_probs(q, planes, 0.5),)


def socket_score_entry(probs, ids, vnorms, mask):
    return (socket_score(probs, ids, vnorms, mask),)


def sparse_decode_entry(q, keys, values, mask):
    return (sparse_decode(q, keys, values, mask, KD**-0.5),)


def dense_decode_entry(q, keys, values, mask):
    return (ref.masked_attention_ref(q, keys, values, KD**-0.5, mask),)


def socket_select_decode_entry(q, planes, ids, vnorms, mask, keys, values):
    """The fused decode hot path: Alg. 2 -> Alg. 4 -> top-k -> flash
    decode over the gathered subset. One HLO module, zero host round
    trips between stages."""
    probs = soft_probs(q, planes, 0.5)
    scores = socket_score(probs, ids, vnorms, mask)
    top_idx = model.top_k_indices(scores, KSEL)
    sel_mask = jnp.take(scores, top_idx) > -jnp.inf
    out = sparse_decode(q, keys[top_idx], values[top_idx], sel_mask, KD**-0.5)
    return (out, top_idx)


# ---- model entry points ----


def model_init_entry(seed):
    return model.init_params(seed)


def model_prefill_entry(*args):
    params = args[:-1]
    tokens = args[-1]
    return model.prefill(params, tokens)


def model_decode_socket_entry(*args):
    params = args[: len(model.PARAM_NAMES)]
    k_cache, v_cache, ids_cache, vn_cache, length, token = args[len(model.PARAM_NAMES) :]
    return model.decode_step_socket(params, k_cache, v_cache, ids_cache, vn_cache, length, token)


def model_decode_dense_entry(*args):
    params = args[: len(model.PARAM_NAMES)]
    k_cache, v_cache, ids_cache, vn_cache, length, token = args[len(model.PARAM_NAMES) :]
    return model.decode_step_dense(params, k_cache, v_cache, ids_cache, vn_cache, length, token)


def param_specs():
    params = jax.eval_shape(model.init_params, jnp.int32(0))
    return [spec(p.shape, p.dtype) for p in params]


def cache_specs():
    c = model.CFG
    return [
        spec((c.n_layers, c.n_kv_heads, c.cap, c.head_dim)),  # k
        spec((c.n_layers, c.n_kv_heads, c.cap, c.head_dim)),  # v
        spec((c.n_layers, c.n_kv_heads, c.cap, c.lsh_l), I32),  # ids
        spec((c.n_layers, c.n_kv_heads, c.cap)),  # vnorms
        spec((), I32),  # length
    ]


PREFILL_N = 1024

ARTIFACTS = {
    "hash_keys.hlo.txt": lambda: to_hlo_text(
        hash_keys_entry, spec((KN, KD)), spec((KL, KP, KD))
    ),
    "soft_probs.hlo.txt": lambda: to_hlo_text(
        soft_probs_entry, spec((KD,)), spec((KL, KP, KD))
    ),
    "socket_score.hlo.txt": lambda: to_hlo_text(
        socket_score_entry,
        spec((KL, KR)),
        spec((KN, KL), I32),
        spec((KN,)),
        spec((KN,), jnp.bool_),
    ),
    "sparse_decode.hlo.txt": lambda: to_hlo_text(
        sparse_decode_entry,
        spec((KD,)),
        spec((KSEL, KD)),
        spec((KSEL, KD)),
        spec((KSEL,), jnp.bool_),
    ),
    "dense_decode.hlo.txt": lambda: to_hlo_text(
        dense_decode_entry,
        spec((KD,)),
        spec((KN, KD)),
        spec((KN, KD)),
        spec((KN,), jnp.bool_),
    ),
    "socket_decode.hlo.txt": lambda: to_hlo_text(
        socket_select_decode_entry,
        spec((KD,)),
        spec((KL, KP, KD)),
        spec((KN, KL), I32),
        spec((KN,)),
        spec((KN,), jnp.bool_),
        spec((KN, KD)),
        spec((KN, KD)),
    ),
    "model_init.hlo.txt": lambda: to_hlo_text(model_init_entry, spec((), I32)),
    "model_prefill.hlo.txt": lambda: to_hlo_text(
        model_prefill_entry, *param_specs(), spec((PREFILL_N,), I32)
    ),
    "model_decode_socket.hlo.txt": lambda: to_hlo_text(
        model_decode_socket_entry, *param_specs(), *cache_specs(), spec((), I32)
    ),
    "model_decode_dense.hlo.txt": lambda: to_hlo_text(
        model_decode_dense_entry, *param_specs(), *cache_specs(), spec((), I32)
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None
    for name, build in ARTIFACTS.items():
        if only and name not in only:
            continue
        path = os.path.join(args.out, name)
        text = build()
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text) / 1e6:.2f} MB)")


if __name__ == "__main__":
    main()
