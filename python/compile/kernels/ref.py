"""Pure-jnp reference implementations (the correctness oracles).

Every Pallas kernel in this package is checked against these functions by
``python/tests/test_kernels.py`` (exact algorithms of the paper's
Alg. 1, 2 and 4, plus the flash-decode attention used for the retrieved
subset). Shapes and conventions:

* keys/values: ``(N, d)`` f32          * planes: ``(L, P, d)`` f32
* bucket ids:  ``(N, L)`` int32        * probs:  ``(L, R)`` f32, R = 2**P
* value norms: ``(N,)`` f32            * scores: ``(N,)`` f32
"""

import jax.numpy as jnp


def hash_keys_ref(keys, planes):
    """Algorithm 1: hard SRP bucket ids of every key in every table.

    Bit i of the id is set iff ``planes[l, i] . key >= 0`` (matching the
    Rust ``pack_signs``).
    """
    # proj: (L, P, N)
    proj = jnp.einsum("lpd,nd->lpn", planes, keys)
    bits = (proj >= 0).astype(jnp.int32)
    p = planes.shape[1]
    weights = (2 ** jnp.arange(p, dtype=jnp.int32))[None, :, None]
    ids = jnp.sum(bits * weights, axis=1)  # (L, N)
    return ids.T.astype(jnp.int32)  # (N, L)


def value_norms_ref(values):
    """Algorithm 1: cached ||v_j||_2."""
    return jnp.sqrt(jnp.sum(values * values, axis=-1))


def corners(p):
    """The R = 2**P hypercube corners c_r in {-1, +1}^P (bit i of r ->
    coordinate i), matching the Rust ``corner``."""
    r = 2**p
    idx = jnp.arange(r)[:, None]
    bits = (idx >> jnp.arange(p)[None, :]) & 1
    return (2.0 * bits - 1.0).astype(jnp.float32)  # (R, P)


def soft_probs_ref(q, planes, tau):
    """Algorithm 2: per-table soft bucket distributions of the query.

    u = tanh(W^(l) q) / sqrt(d); logits_r = u . c_r / tau; softmax.
    Returns (L, R).
    """
    d = q.shape[-1]
    u = jnp.tanh(planes @ q) / jnp.sqrt(jnp.float32(d))  # (L, P)
    c = corners(planes.shape[1])  # (R, P)
    logits = (u @ c.T) / tau  # (L, R)
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def socket_score_ref(probs, bucket_ids, vnorms, mask=None):
    """Algorithm 4: value-aware soft collision scores.

    w_hat[j] = ||v_j|| * sum_l probs[l, bucket_ids[j, l]]; masked-out
    keys score -inf.
    """
    ll = probs.shape[0]
    gathered = probs[jnp.arange(ll)[None, :], bucket_ids]  # (N, L)
    w = vnorms * jnp.sum(gathered, axis=-1)
    if mask is not None:
        w = jnp.where(mask, w, -jnp.inf)
    return w


def hard_score_ref(q_ids, bucket_ids, vnorms):
    """Traditional LSH collision counting (the ablation baseline)."""
    coll = (bucket_ids == q_ids[None, :]).astype(jnp.float32)
    return vnorms * jnp.sum(coll, axis=-1)


def attention_ref(q, keys, values, scale):
    """Exact SDPA for one query (the flash-decode oracle)."""
    logits = keys @ q * scale
    a = jnp.exp(logits - jnp.max(logits))
    a = a / jnp.sum(a)
    return a @ values


def masked_attention_ref(q, keys, values, scale, mask):
    """SDPA restricted to ``mask`` (selected tokens)."""
    logits = jnp.where(mask, keys @ q * scale, -jnp.inf)
    m = jnp.max(logits)
    a = jnp.exp(logits - m)
    a = a / jnp.sum(a)
    return a @ values
