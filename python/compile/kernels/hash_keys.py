"""Layer-1 Pallas kernel: Algorithm 1 (prefill key hashing).

Grid: one program per block of ``BLOCK_N`` tokens. Per program:

* the key block ``(BLOCK_N, d)`` is staged HBM -> VMEM by BlockSpec;
* ALL hyperplanes ``(L*P, d)`` stay VMEM-resident across programs (for
  the paper's setting L=60, P=10, d=128 that is 300 KB — far below the
  ~16 MB VMEM budget), so the projection is one ``(BLOCK_N, d) x
  (d, L*P)`` MXU matmul per block;
* sign bits are packed into int32 bucket ids with a ``(L*P -> L)``
  weighted reduction on the VPU (no scatter/gather).

TPU adaptation note (DESIGN.md §Hardware-Adaptation): the CUDA version
launches one thread per token; here the token axis is tiled into MXU-
sized blocks and the "per-thread" bit-packing becomes a vectorized
reduction over the P axis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 256


def _hash_kernel(keys_ref, planes_ref, ids_ref, *, l_tables, p_planes):
    keys = keys_ref[...]  # (BLOCK_N, d)
    planes = planes_ref[...]  # (L*P, d)
    proj = jax.lax.dot_general(
        keys,
        planes,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (BLOCK_N, L*P)
    bits = (proj >= 0.0).astype(jnp.int32)
    bits = bits.reshape(keys.shape[0], l_tables, p_planes)
    weights = (2 ** jnp.arange(p_planes, dtype=jnp.int32))[None, None, :]
    ids_ref[...] = jnp.sum(bits * weights, axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hash_keys(keys, planes, interpret=True):
    """Bucket ids (N, L) int32 of ``keys`` (N, d) under ``planes``
    (L, P, d). N must be a multiple of BLOCK_N (pad upstream)."""
    n, d = keys.shape
    l_tables, p_planes, _ = planes.shape
    assert n % BLOCK_N == 0, f"N={n} must be a multiple of {BLOCK_N}"
    flat_planes = planes.reshape(l_tables * p_planes, d)
    kernel = functools.partial(_hash_kernel, l_tables=l_tables, p_planes=p_planes)
    return pl.pallas_call(
        kernel,
        grid=(n // BLOCK_N,),
        in_specs=[
            pl.BlockSpec((BLOCK_N, d), lambda i: (i, 0)),
            pl.BlockSpec((l_tables * p_planes, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N, l_tables), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, l_tables), jnp.int32),
        interpret=interpret,
    )(keys, flat_planes)


def value_norms(values):
    """||v_j||_2 — fused into the surrounding jit; no kernel needed."""
    return jnp.sqrt(jnp.sum(values * values, axis=-1))
