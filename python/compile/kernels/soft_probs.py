"""Layer-1 Pallas kernel: Algorithm 2 (query-side soft bucket probs).

Single program (the whole computation is tiny and latency-bound at
decode time): ``u = tanh(W q)/sqrt(d)`` is an ``(L*P, d) x (d,)``
matvec on the MXU, the corner logits are one ``(L, P) x (P, R)`` matmul
against the +-1 corner matrix (VMEM-resident, R = 2**P <= 1024), and
the per-table softmax is a VPU row reduction. Everything fits VMEM:
planes 300 KB + corners 40 KB + probs 240 KB for the paper setting.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _soft_probs_kernel(q_ref, planes_ref, corners_ref, probs_ref, *, l_tables, p_planes, tau, dim):
    q = q_ref[...]  # (d,)
    planes = planes_ref[...]  # (L*P, d)
    proj = jnp.dot(planes, q, preferred_element_type=jnp.float32)  # (L*P,)
    u = jnp.tanh(proj) * (1.0 / jnp.sqrt(jnp.float32(dim)))
    u = u.reshape(l_tables, p_planes)
    corners = corners_ref[...]  # (R, P)
    logits = jax.lax.dot_general(
        u, corners, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * (1.0 / tau)  # (L, R)
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits)
    probs_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("tau", "interpret"))
def soft_probs(q, planes, tau, interpret=True):
    """Soft bucket distributions (L, R) for query ``q`` (d,)."""
    l_tables, p_planes, d = planes.shape
    r = 2**p_planes
    corners = ref.corners(p_planes)  # (R, P)
    kernel = functools.partial(
        _soft_probs_kernel, l_tables=l_tables, p_planes=p_planes, tau=float(tau), dim=d
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((l_tables, r), jnp.float32),
        interpret=interpret,
    )(q, planes.reshape(l_tables * p_planes, d), corners)
