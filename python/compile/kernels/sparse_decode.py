"""Layer-1 Pallas kernel: flash-decode over the retrieved top-k keys.

The TPU analog of the paper's Flash Decode Triton backend: one query
attends over the gathered K/V ``(k_sel, d)`` with a single pass of
online softmax. The K/V tiles stream HBM -> VMEM in ``BLOCK_K``-token
chunks via a ``fori_loop`` over VMEM slices while the running
``(max, sum, acc)`` state lives in registers/VMEM — the same schedule
``attention::flash`` implements on the Rust side.

Invalid rows (gather padding) are masked to -inf before the softmax.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_K = 128


def _decode_kernel(q_ref, keys_ref, values_ref, mask_ref, out_ref, *, scale, n_keys):
    q = q_ref[...]  # (d,)
    n_blocks = n_keys // BLOCK_K

    def body(i, carry):
        m, s, acc = carry
        ks = keys_ref[pl.dslice(i * BLOCK_K, BLOCK_K), :]  # (BLOCK_K, d)
        vs = values_ref[pl.dslice(i * BLOCK_K, BLOCK_K), :]
        valid = mask_ref[pl.dslice(i * BLOCK_K, BLOCK_K)]
        logits = jnp.dot(ks, q, preferred_element_type=jnp.float32) * scale
        logits = jnp.where(valid, logits, -jnp.inf)
        tile_max = jnp.max(logits)
        new_m = jnp.maximum(m, tile_max)
        # Guard the all-masked case: keep the old running state.
        corr = jnp.where(jnp.isfinite(new_m), jnp.exp(m - new_m), 1.0)
        w = jnp.where(valid, jnp.exp(logits - new_m), 0.0)
        s_new = s * corr + jnp.sum(w)
        acc_new = acc * corr + jnp.dot(w, vs, preferred_element_type=jnp.float32)
        return new_m, s_new, acc_new

    d = q.shape[0]
    init = (-jnp.inf, jnp.float32(0.0), jnp.zeros((d,), jnp.float32))
    _, s, acc = jax.lax.fori_loop(0, n_blocks, body, init)
    out_ref[...] = acc / jnp.maximum(s, 1e-30)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def sparse_decode(q, keys, values, mask, scale, interpret=True):
    """Attention output (d,) of ``q`` over masked rows of keys/values.

    keys/values: (k_sel, d) with k_sel a multiple of BLOCK_K.
    """
    k_sel, d = keys.shape
    assert k_sel % BLOCK_K == 0, f"k_sel={k_sel} must be a multiple of {BLOCK_K}"
    kernel = functools.partial(_decode_kernel, scale=float(scale), n_keys=k_sel)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=interpret,
    )(q, keys, values, mask)
