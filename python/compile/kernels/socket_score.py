"""Layer-1 Pallas kernel: Algorithm 4 (the custom scoring kernel).

This is the paper's CUDA hot-spot, rethought for TPU
(DESIGN.md §Hardware-Adaptation):

* the bucket-probability table ``(L, R)`` is flattened to ``(L*R,)``
  and kept VMEM-resident for the whole sweep (240 KB at L=60, R=1024 —
  the CUDA kernel streams it through L2 instead);
* the token axis is tiled: each program stages a ``(BLOCK_N, L)``
  bucket-id block and the matching value-norm block into VMEM;
* per block, scores are a take + row-reduction:
  ``score[j] = ||v_j|| * sum_l probs_flat[l*R + b[j,l]]`` — the gather
  is over a VMEM-resident table (fast), the reduction is a VPU sum.
  Masked (invalid) tokens score -inf so top-k never selects them.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 128


def _score_kernel(ids_ref, vnorm_ref, mask_ref, probs_ref, out_ref, *, r_buckets):
    ids = ids_ref[...]  # (BLOCK_N, L) int32
    l_tables = ids.shape[1]
    table_base = (jnp.arange(l_tables, dtype=jnp.int32) * r_buckets)[None, :]
    flat_idx = ids + table_base  # (BLOCK_N, L)
    probs = probs_ref[...]  # (L*R,)
    gathered = jnp.take(probs, flat_idx, axis=0)  # (BLOCK_N, L)
    score = vnorm_ref[...] * jnp.sum(gathered, axis=-1)
    out_ref[...] = jnp.where(mask_ref[...], score, -jnp.inf)


@functools.partial(jax.jit, static_argnames=("interpret",))
def socket_score(probs, bucket_ids, vnorms, mask, interpret=True):
    """Value-aware soft collision scores (N,) — Algorithm 4.

    probs: (L, R) f32; bucket_ids: (N, L) int32; vnorms/mask: (N,).
    N must be a multiple of BLOCK_N (pad with mask=False upstream).
    """
    n, l_tables = bucket_ids.shape
    l2, r = probs.shape
    assert l2 == l_tables
    assert n % BLOCK_N == 0, f"N={n} must be a multiple of {BLOCK_N}"
    kernel = functools.partial(_score_kernel, r_buckets=r)
    return pl.pallas_call(
        kernel,
        grid=(n // BLOCK_N,),
        in_specs=[
            pl.BlockSpec((BLOCK_N, l_tables), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec((l_tables * r,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(bucket_ids, vnorms, mask, probs.reshape(-1))
