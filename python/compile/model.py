"""Layer-2: the tiny transformer decode graph with SOCKET attention.

A ~4M-parameter GQA transformer (RMSNorm, RoPE, SwiGLU) mirroring
``rust/src/model/mod.rs::ModelConfig::tiny``. Three jit-able entry
points are lowered by ``aot.py``:

* ``init_params(seed)``        -> flat tuple of parameter arrays
* ``prefill(params, tokens)``  -> KV caches + SOCKET hash caches
* ``decode_step(params, caches, token, length)``
                               -> logits + updated caches

``decode_step`` calls the Pallas kernels (Algorithms 2 and 4 + flash
decode) so they lower into the same HLO the Rust runtime executes —
Python never runs at serving time.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.socket_score import socket_score
from .kernels.soft_probs import soft_probs
from .kernels.sparse_decode import sparse_decode


@dataclasses.dataclass(frozen=True)
class Config:
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    n_kv_heads: int = 2
    head_dim: int = 32
    vocab: int = 512
    # KV-cache capacity (context + decode headroom).
    cap: int = 1152
    # SOCKET hash parameters (small L for the tiny model; the paper's
    # (10, 60) applies at d=128).
    lsh_l: int = 16
    lsh_p: int = 8
    tau: float = 0.5
    # Retrieved tokens per decode step (multiple of BLOCK_K=128).
    k_sel: int = 128

    @property
    def group(self):
        return self.n_heads // self.n_kv_heads


CFG = Config()

# Canonical parameter order (flat tuple) — the Rust runtime relies on it.
PARAM_NAMES = (
    ["embed"]
    + [
        f"l{i}.{name}"
        for i in range(CFG.n_layers)
        for name in ["ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd"]
    ]
    + ["ln_f", "out"]
    + ["planes"]  # (n_layers, n_kv_heads, L, P, head_dim) hash planes
)


def init_params(seed):
    """Deterministic parameter tuple from a scalar int32 seed."""
    c = CFG
    key = jax.random.PRNGKey(seed)

    def normal(key, shape, scale):
        return jax.random.normal(key, shape, jnp.float32) * scale

    params = []
    keys = jax.random.split(key, len(PARAM_NAMES))
    ki = iter(range(len(PARAM_NAMES)))
    params.append(normal(keys[next(ki)], (c.vocab, c.d_model), 0.02))  # embed
    for _ in range(c.n_layers):
        params.append(jnp.ones((c.d_model,), jnp.float32))  # ln1
        next(ki)
        params.append(normal(keys[next(ki)], (c.d_model, c.n_heads * c.head_dim), c.d_model**-0.5))
        params.append(normal(keys[next(ki)], (c.d_model, c.n_kv_heads * c.head_dim), c.d_model**-0.5))
        params.append(normal(keys[next(ki)], (c.d_model, c.n_kv_heads * c.head_dim), c.d_model**-0.5))
        params.append(normal(keys[next(ki)], (c.n_heads * c.head_dim, c.d_model), c.d_model**-0.5))
        params.append(jnp.ones((c.d_model,), jnp.float32))  # ln2
        next(ki)
        params.append(normal(keys[next(ki)], (c.d_model, 4 * c.d_model), c.d_model**-0.5))
        params.append(normal(keys[next(ki)], (c.d_model, 4 * c.d_model), c.d_model**-0.5))
        params.append(normal(keys[next(ki)], (4 * c.d_model, c.d_model), (4 * c.d_model) ** -0.5))
    params.append(jnp.ones((c.d_model,), jnp.float32))  # ln_f
    next(ki)
    params.append(normal(keys[next(ki)], (c.d_model, c.vocab), c.d_model**-0.5))  # out
    params.append(
        normal(keys[next(ki)], (c.n_layers, c.n_kv_heads, c.lsh_l, c.lsh_p, c.head_dim), 1.0)
    )
    return tuple(params)


def top_k_indices(scores, k):
    """Top-k indices via a full descending sort.

    ``jax.lax.top_k`` lowers to the new `topk` HLO instruction whose
    text form (`largest=true`) the xla_extension 0.5.1 parser rejects;
    `argsort` lowers to the classic `sort` op, which round-trips.
    """
    return jnp.argsort(-scores)[:k]


def _layer_params(params, i):
    base = 1 + i * 9
    return params[base : base + 9]


def _rms_norm(x, g):
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _rope(x, pos):
    """Rotary embedding for (..., head_dim) at position(s) ``pos``."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / half))
    angle = pos[..., None] * freqs  # (..., half)
    cos, sin = jnp.cos(angle), jnp.sin(angle)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def prefill(params, tokens):
    """Process a full context (N tokens) with dense causal attention.

    Returns (k_cache, v_cache, ids_cache, vnorm_cache, length) with the
    caches zero-padded to CFG.cap — ready for ``decode_step``.

    Shapes: k/v (layers, kv, cap, hd); ids (layers, kv, cap, L) int32;
    vnorms (layers, kv, cap).
    """
    c = CFG
    n = tokens.shape[0]
    embed = params[0]
    planes = params[-1]
    x = embed[tokens]  # (N, d_model)
    pos = jnp.arange(n, dtype=jnp.float32)
    causal = jnp.tril(jnp.ones((n, n), bool))
    k_cache = jnp.zeros((c.n_layers, c.n_kv_heads, c.cap, c.head_dim), jnp.float32)
    v_cache = jnp.zeros_like(k_cache)
    ids_cache = jnp.zeros((c.n_layers, c.n_kv_heads, c.cap, c.lsh_l), jnp.int32)
    vn_cache = jnp.zeros((c.n_layers, c.n_kv_heads, c.cap), jnp.float32)
    for i in range(c.n_layers):
        ln1, wq, wk, wv, wo, ln2, wg, wu, wd = _layer_params(params, i)
        h = _rms_norm(x, ln1)
        q = (h @ wq).reshape(n, c.n_heads, c.head_dim)
        k = (h @ wk).reshape(n, c.n_kv_heads, c.head_dim)
        v = (h @ wv).reshape(n, c.n_kv_heads, c.head_dim)
        q = _rope(q.transpose(1, 0, 2), pos).transpose(1, 0, 2)
        k = _rope(k.transpose(1, 0, 2), pos).transpose(1, 0, 2)
        # Dense causal attention (following the paper's protocol the
        # context is processed densely; sparsity applies at decode).
        scale = c.head_dim**-0.5
        kk = jnp.repeat(k, c.group, axis=1)  # (N, n_heads, hd)
        vv = jnp.repeat(v, c.group, axis=1)
        logits = jnp.einsum("qhd,khd->hqk", q, kk) * scale
        logits = jnp.where(causal[None, :, :], logits, -jnp.inf)
        a = jax.nn.softmax(logits, axis=-1)
        attn = jnp.einsum("hqk,khd->qhd", a, vv).reshape(n, -1)
        x = x + attn @ wo
        h2 = _rms_norm(x, ln2)
        x = x + (jax.nn.silu(h2 @ wg) * (h2 @ wu)) @ wd
        # SOCKET Algorithm 1: hash this layer's keys, cache norms.
        for kv in range(c.n_kv_heads):
            ids = ref.hash_keys_ref(k[:, kv, :], planes[i, kv])  # (N, L)
            vn = ref.value_norms_ref(v[:, kv, :])
            k_cache = k_cache.at[i, kv, :n].set(k[:, kv, :])
            v_cache = v_cache.at[i, kv, :n].set(v[:, kv, :])
            ids_cache = ids_cache.at[i, kv, :n].set(ids)
            vn_cache = vn_cache.at[i, kv, :n].set(vn)
    return k_cache, v_cache, ids_cache, vn_cache, jnp.int32(n)


def decode_step(params, k_cache, v_cache, ids_cache, vn_cache, length, token, sparse):
    """One decode step. ``sparse`` statically selects SOCKET vs dense.

    Returns (logits, k_cache, v_cache, ids_cache, vn_cache, length+1).
    """
    c = CFG
    embed = params[0]
    planes = params[-1]
    x = embed[token]  # (d_model,)
    pos = length.astype(jnp.float32)
    scale = c.head_dim**-0.5
    positions = jnp.arange(c.cap)
    valid = positions < length
    for i in range(c.n_layers):
        ln1, wq, wk, wv, wo, ln2, wg, wu, wd = _layer_params(params, i)
        h = _rms_norm(x, ln1)
        q = (h @ wq).reshape(c.n_heads, c.head_dim)
        k_new = (h @ wk).reshape(c.n_kv_heads, c.head_dim)
        v_new = (h @ wv).reshape(c.n_kv_heads, c.head_dim)
        q = _rope(q, jnp.full((c.n_heads,), pos))
        k_new = _rope(k_new, jnp.full((c.n_kv_heads,), pos))
        heads_out = []
        for kv in range(c.n_kv_heads):
            keys = k_cache[i, kv]  # (cap, hd)
            vals = v_cache[i, kv]
            for g in range(c.group):
                hq = q[kv * c.group + g]
                if sparse:
                    # Algorithms 2 + 4 + 3 via the Pallas kernels.
                    probs = soft_probs(hq, planes[i, kv], c.tau)
                    scores = socket_score(probs, ids_cache[i, kv], vn_cache[i, kv], valid)
                    top_idx = top_k_indices(scores, c.k_sel)
                    sel_mask = jnp.take(scores, top_idx) > -jnp.inf
                    out = sparse_decode(hq, keys[top_idx], vals[top_idx], sel_mask, scale)
                else:
                    out = ref.masked_attention_ref(hq, keys, vals, scale, valid)
                heads_out.append(out)
        attn = jnp.concatenate(heads_out, axis=-1)  # (n_heads*hd,)
        x = x + attn @ wo
        h2 = _rms_norm(x, ln2)
        x = x + (jax.nn.silu(h2 @ wg) * (h2 @ wu)) @ wd
        # Append the new token's K/V + hash signature (Alg. 1 online).
        for kv in range(c.n_kv_heads):
            k_cache = k_cache.at[i, kv, length].set(k_new[kv])
            v_cache = v_cache.at[i, kv, length].set(v_new[kv])
            ids = ref.hash_keys_ref(k_new[kv][None, :], planes[i, kv])[0]
            ids_cache = ids_cache.at[i, kv, length].set(ids)
            vn_cache = vn_cache.at[i, kv, length].set(jnp.sqrt(jnp.sum(v_new[kv] * v_new[kv])))
    logits = _rms_norm(x, params[-3]) @ params[-2]
    return logits, k_cache, v_cache, ids_cache, vn_cache, length + 1


decode_step_socket = functools.partial(decode_step, sparse=True)
decode_step_dense = functools.partial(decode_step, sparse=False)
