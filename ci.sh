#!/usr/bin/env bash
# Tier-1 gate in one command: formatting + lints first (fail fast,
# before the expensive build), then release build, offline tests
# (default and pjrt feature), bench compile + smoke perf artifact.
# Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")"

# --smoke: build + boot the server + scripted session/stream/metrics
# probe, then the memory-pressure probe (chunked prefill + preemption
# on a tiny pool) — seconds, not minutes. The full run executes
# everything AND both smokes.
SMOKE=0
for arg in "$@"; do
    case "$arg" in
        --smoke) SMOKE=1 ;;
        *) echo "unknown argument: $arg (supported: --smoke)"; exit 2 ;;
    esac
done

# Boot target/release/socketd on a free port and drive the serving
# surface end-to-end over TCP: a streaming multi-turn session (turn 2
# must resume with zero prefill), then an {"op":"metrics"} scrape whose
# histogram/pool/prune/session fields are all asserted. Skips when
# python3 is unavailable (no other way to script a TCP client here).
serving_smoke() {
    if ! command -v python3 >/dev/null 2>&1; then
        echo "    python3 absent; skipping serving smoke"
        return 0
    fi
    local bin="$PWD/target/release/socketd"
    if [ ! -x "$bin" ]; then
        echo "    $bin missing (build step must run first)"
        return 1
    fi
    local port
    port=$(python3 -c 'import socket; s=socket.socket(); s.bind(("127.0.0.1",0)); print(s.getsockname()[1]); s.close()')
    "$bin" serve --port "$port" --workers 2 --capacity-pages 4096 &
    local pid=$!
    local status=0
    python3 - "$port" <<'PY' || status=$?
import json, socket, sys, time

port = int(sys.argv[1])
deadline = time.time() + 30
while True:
    try:
        conn = socket.create_connection(("127.0.0.1", port), timeout=5)
        break
    except OSError:
        if time.time() > deadline:
            sys.exit("serving smoke: server never came up")
        time.sleep(0.2)
conn.settimeout(120)
rfile = conn.makefile("r")
wfile = conn.makefile("w")

def send(obj):
    wfile.write(json.dumps(obj) + "\n")
    wfile.flush()

def recv():
    line = rfile.readline()
    assert line, "connection closed early"
    return json.loads(line)

# Turn 1: streaming session prefill — one line per token, then summary.
send({"op": "generate", "session": "ci", "context_len": 256,
      "decode_len": 4, "stream": True})
tokens = []
while True:
    msg = recv()
    if "token" in msg:
        tokens.append(msg["token"])
        continue
    break
assert tokens == [0, 1, 2, 3], f"token lines {tokens}"
assert msg.get("ok") and msg.get("done") and msg.get("turn") == 1, msg

# Turn 2: resumed — appends 64 context tokens, zero prefill.
send({"op": "generate", "session": "ci", "context_len": 64, "decode_len": 2})
msg = recv()
assert msg.get("ok") and msg.get("turn") == 2, msg
assert msg.get("session_tokens") == 256 + 4 + 64 + 2, msg

# Metrics scrape: the whole schema, with the zero-prefill proof.
send({"op": "metrics"})
m = recv()
assert m.get("ok"), m
sched = m["scheduler"]
assert sched["prefill_tokens"] == 256, sched
assert sched["session_tokens"] == 64, sched
assert sched["resumed_turns"] == 1, sched
series = m["methods"]["socket"]
assert series["served"] == 2, series
for section in ("ttft_ms", "tbt_ms"):
    for field in ("count", "mean_ms", "p50_ms", "p95_ms", "p99_ms", "max_ms"):
        assert field in series[section], (section, field, series)
assert series["ttft_ms"]["count"] == 2, series
pool = m["pool"]
assert pool["used_pages"] + pool["free_pages"] == pool["total_pages"], pool
assert pool["used_pages"] > 0, pool  # the parked session holds pages
assert m["prune"]["blocks"] > 0, m["prune"]
assert m["sessions"]["active"] == 1, m["sessions"]

# Prefix cache: two one-shots declaring the same prompt — the second
# must hit and skip its whole prefill.
for _ in range(2):
    send({"op": "generate", "context_len": 128, "decode_len": 1,
          "prompt": "ci shared system prompt"})
    assert recv().get("ok"), "prompted generate failed"
send({"op": "metrics"})
m = recv()
prefix = m["prefix"]
assert prefix["lookups"] == 2 and prefix["hits"] == 1, prefix
assert prefix["prefill_tokens_saved"] == 128, prefix
assert 0.0 < prefix["shared_page_ratio"] <= 1.0, prefix
config = m["config"]
assert config["default_method"] and config["default_sparsity"] >= 1, config
assert config["session_ttl_secs"] > 0 and config["reloads"] == 0, config

# Degradation schema: the pressure counters are always emitted (all
# zero on this amply-provisioned server) and the per-class latency
# section exists.
pressure = m["pressure"]
for field in ("preemptions", "chunked_prefills", "shed", "deadline_missed"):
    assert pressure.get(field) == 0, (field, pressure)
assert "classes" in m, sorted(m)

# Priority + deadline ride the wire: a served interactive request shows
# up in the per-class section; a bogus class is a typed error.
send({"op": "generate", "context_len": 64, "decode_len": 1,
      "priority": "interactive", "deadline_ms": 60000})
assert recv().get("ok"), "interactive generate failed"
send({"op": "generate", "context_len": 64, "decode_len": 1, "priority": "vip"})
err = recv()
assert not err.get("ok") and "priority" in err.get("error", ""), err
send({"op": "metrics"})
m = recv()
assert "interactive" in m["classes"] and "normal" in m["classes"], sorted(m["classes"])
print("    serving smoke OK: stream + session resume + prefix cache + "
      "priority wire + metrics scrape")
PY
    kill "$pid" 2>/dev/null || true
    wait "$pid" 2>/dev/null || true
    return "$status"
}

# Boot a second, deliberately tiny socketd (80 KV pages, 64-token
# prefill budget installed through the hot-reload config) and drive the
# degradation machinery over live TCP: a chunked prefill (context ~5x
# the budget), then an interactive request that cannot fit beside a
# long batch-priority decode and must preempt it — both complete, the
# preempted stream stays gapless, the pressure counters prove the paths
# fired, and the pool drains back to zero pages (no leak).
pressure_smoke() {
    if ! command -v python3 >/dev/null 2>&1; then
        echo "    python3 absent; skipping pressure smoke"
        return 0
    fi
    local bin="$PWD/target/release/socketd"
    if [ ! -x "$bin" ]; then
        echo "    $bin missing (build step must run first)"
        return 1
    fi
    local cfgdir cfg port
    cfgdir=$(mktemp -d)
    cfg="$cfgdir/reload.json"
    printf '{"batch":{"prefill_token_budget":64}}\n' > "$cfg"
    port=$(python3 -c 'import socket; s=socket.socket(); s.bind(("127.0.0.1",0)); print(s.getsockname()[1]); s.close()')
    "$bin" serve --port "$port" --workers 2 --capacity-pages 80 --config "$cfg" &
    local pid=$!
    local status=0
    python3 - "$port" <<'PY' || status=$?
import json, socket, sys, time

port = int(sys.argv[1])

def connect():
    deadline = time.time() + 30
    while True:
        try:
            conn = socket.create_connection(("127.0.0.1", port), timeout=5)
            break
        except OSError:
            if time.time() > deadline:
                sys.exit("pressure smoke: server never came up")
            time.sleep(0.2)
    conn.settimeout(120)
    return conn.makefile("r"), conn.makefile("w")

def send(wfile, obj):
    wfile.write(json.dumps(obj) + "\n")
    wfile.flush()

def recv(rfile):
    line = rfile.readline()
    assert line, "connection closed early"
    return json.loads(line)

rfile, wfile = connect()

# Wait for the hot-reload watcher to install the 64-token prefill
# budget (it applies within ~200 ms of boot; poll the config gauge).
deadline = time.time() + 30
while True:
    send(wfile, {"op": "metrics"})
    m = recv(rfile)
    if m.get("config", {}).get("reloads", 0) >= 1:
        break
    assert time.time() < deadline, "prefill-budget reload never applied"
    time.sleep(0.1)

# Chunked-prefill round trip: 300 context tokens against the 64-token
# budget prefill in ~5 chunks, and the request still completes.
send(wfile, {"op": "generate", "context_len": 300, "decode_len": 1})
assert recv(rfile).get("ok"), "chunked generate failed"
send(wfile, {"op": "metrics"})
m = recv(rfile)
assert m["pressure"]["chunked_prefills"] >= 1, m["pressure"]

# Preemption round trip: the streaming batch-priority decode commits 66
# of the 80 pages; the interactive request needs 18 more, so admission
# must preempt the batch sequence, serve the interactive one, then
# readmit and finish the victim.
send(wfile, {"op": "generate", "context_len": 128, "decode_len": 400,
             "priority": "batch", "stream": True})
first = recv(rfile)
assert first.get("token") == 0, first

rfile2, wfile2 = connect()
send(wfile2, {"op": "generate", "context_len": 128, "decode_len": 2,
              "priority": "interactive", "deadline_ms": 60000})
msg = recv(rfile2)
assert msg.get("ok"), msg

# The preempted stream must arrive gapless and duplicate-free: the
# victim re-prefills after readmission but never re-emits a token line.
tokens = [first["token"]]
while True:
    msg = recv(rfile)
    if "token" in msg:
        tokens.append(msg["token"])
        continue
    break
assert msg.get("ok"), msg
assert tokens == list(range(400)), f"stream gapped: {len(tokens)} lines, tail {tokens[-5:]}"

# Pressure counters prove the paths fired; the pool drains back to
# empty (all degradation paths release their pages).
deadline = time.time() + 10
while True:
    send(wfile, {"op": "metrics"})
    m = recv(rfile)
    if m["pool"]["used_pages"] == 0:
        break
    assert time.time() < deadline, m["pool"]
    time.sleep(0.05)
pressure = m["pressure"]
assert pressure["preemptions"] >= 1, pressure
assert pressure["chunked_prefills"] >= 1, pressure
classes = m["classes"]
assert "interactive" in classes and "batch" in classes, sorted(classes)
print("    pressure smoke OK: chunked prefill + preemption + gapless "
      "stream + zero-leak pool over TCP")
PY
    kill "$pid" 2>/dev/null || true
    wait "$pid" 2>/dev/null || true
    rm -rf "$cfgdir"
    return "$status"
}

if [ "$SMOKE" = 1 ]; then
    echo "==> cargo build --release (smoke)"
    cargo build --release
    echo "==> serving smoke"
    serving_smoke
    echo "==> pressure smoke"
    pressure_smoke
    echo "OK: smoke green"
    exit 0
fi

# socket-lint runs first: the repo-native analysis gate (SAFETY
# comments on unsafe, ordering rationale on atomics, no panics or
# allocation on hot paths — see rust/docs/ANALYSIS.md) is the cheapest
# check in the pipeline and carries a ratcheted baseline, so fresh
# findings fail in seconds. When cargo is absent (analysis-only
# containers) the Python mirror runs the identical rule set.
echo "==> socket-lint (rust/src vs lint/baseline.txt)"
if command -v cargo >/dev/null 2>&1; then
    cargo run --release -p socket-lint -- rust/src --baseline lint/baseline.txt
elif command -v python3 >/dev/null 2>&1; then
    python3 lint/selfcheck.py rust/src --baseline lint/baseline.txt
else
    echo "    neither cargo nor python3 available; cannot run socket-lint"
    exit 1
fi

# Remaining lint gates still run ahead of the build so style/lint
# fallout fails in seconds, not after a full compile. Both skip
# gracefully when the component is not installed (offline containers
# vary).
if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --check"
    cargo fmt --check
else
    echo "==> rustfmt not installed; skipping format check"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "==> cargo clippy --all-targets -- -D warnings -D clippy::undocumented_unsafe_blocks"
    cargo clippy --all-targets -- -D warnings -D clippy::undocumented_unsafe_blocks
else
    echo "==> clippy not installed; skipping lint step"
fi

echo "==> cargo build --release"
cargo build --release

# The schedule-exploring race harness gates early: if the bounded
# model checker's own invariants or the modeled concurrency properties
# (ThresholdCell monotonicity, histogram snapshot consistency, the
# scheduler drain protocol) break, fail before the full suite runs.
echo "==> interleave harness (exhaustive schedule enumeration)"
cargo test -q -p socket-attn -- interleave model_all_schedules

echo "==> cargo test -q"
cargo test -q

# Second pass with SIMD dispatch pinned to the scalar reference: the
# per-kernel bit-identity properties compare tiers *within* a process,
# this run proves the whole suite also holds when every kernel takes
# the scalar path from the start (the env override in simd::dispatch).
echo "==> cargo test -q (SOCKET_SIMD=scalar)"
SOCKET_SIMD=scalar cargo test -q

echo "==> cargo test -q --features pjrt"
cargo test -q --features pjrt

# Miri exercises the two modules with real lock-free/atomic code under
# the interpreter's data-race and UB detector. It needs a nightly
# toolchain with the miri component — absent in most offline
# containers, so skip (the interleave harness above still model-checks
# the same properties on stable).
if cargo +nightly miri --version >/dev/null 2>&1; then
    echo "==> cargo +nightly miri test (util::pool, metrics::registry)"
    MIRIFLAGS="-Zmiri-disable-isolation" \
        cargo +nightly miri test -p socket-attn -- util::pool metrics::registry
else
    echo "==> miri (nightly) not installed; skipping interpreter pass"
fi

echo "==> serving smoke (sessions + streaming + metrics over TCP)"
serving_smoke

echo "==> pressure smoke (chunked prefill + preemption over TCP)"
pressure_smoke

echo "==> cargo bench --no-run"
cargo bench --no-run

echo "==> bench_throughput smoke (gather-vs-paged + per-method artifact)"
cargo bench --bench bench_throughput -- --smoke --json-out "$PWD/BENCH_throughput.json"
echo "    artifact: $PWD/BENCH_throughput.json"

# Bench-regression guard: compare the scoring_lane rows of the fresh
# artifact against the checked-in BENCH_baseline.json (10% tolerance,
# matched by context/group/variant). Only rows present in BOTH
# artifacts are compared — a baseline recorded at full (non-smoke)
# scale carries contexts the smoke artifact never measures, and that
# must not turn CI permanently red; mismatched coverage is a warning.
# Record the baseline with this script (same machine, same smoke
# scale) so absolute selections/s are comparable. Skips gracefully
# when the baseline has not been recorded yet (no toolchain container
# has run the bench) or python3 is unavailable.
echo "==> bench regression guard (scoring_lane vs BENCH_baseline.json)"
if [ -f "$PWD/BENCH_baseline.json" ] && command -v python3 >/dev/null 2>&1; then
    python3 - "$PWD/BENCH_throughput.json" "$PWD/BENCH_baseline.json" <<'PY'
import json, sys

new_doc, base_doc = (json.load(open(p)) for p in sys.argv[1:3])

def rows(doc):
    lane = doc.get("scoring_lane", {}).get("rows", [])
    return {(r.get("context"), r.get("group"), r.get("variant")): r for r in lane}

TOLERANCE = 0.10
new, base = rows(new_doc), rows(base_doc)
failures = []
compared = 0
for key, b in sorted(base.items(), key=str):
    r = new.get(key)
    if r is None:
        # Coverage mismatch (e.g. full-scale baseline vs smoke
        # artifact) is not a regression.
        print(f"  warning: baseline row {key} not in fresh artifact; skipping")
        continue
    want = b.get("sps") or 0.0
    got = r.get("sps") or 0.0
    if want <= 0.0:
        continue
    compared += 1
    if got < (1.0 - TOLERANCE) * want:
        failures.append(
            f"{key}: {got:.1f} sel/s < {100 * (1 - TOLERANCE):.0f}% of baseline {want:.1f}"
        )
if failures:
    print("bench regression guard FAILED:")
    for f in failures:
        print(f"  {f}")
    sys.exit(1)
print(f"bench regression guard OK: {compared} rows within {int(TOLERANCE * 100)}% of baseline")
PY
else
    echo "    BENCH_baseline.json or python3 absent; skipping guard"
    echo "    (record a baseline by copying a trusted BENCH_throughput.json)"
fi

echo "OK: tier-1 green"
