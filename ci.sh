#!/usr/bin/env bash
# Tier-1 gate in one command: release build, offline tests (default and
# pjrt feature), and clippy with warnings denied. Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo test -q --features pjrt"
cargo test -q --features pjrt

if cargo clippy --version >/dev/null 2>&1; then
    echo "==> cargo clippy --all-targets -- -D warnings"
    cargo clippy --all-targets -- -D warnings
else
    echo "==> clippy not installed; skipping lint step"
fi

echo "OK: tier-1 green"
