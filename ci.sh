#!/usr/bin/env bash
# Tier-1 gate in one command: formatting + lints first (fail fast,
# before the expensive build), then release build, offline tests
# (default and pjrt feature), bench compile + smoke perf artifact.
# Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")"

# Lint gates run ahead of the build so style/lint fallout fails in
# seconds, not after a full compile. Both skip gracefully when the
# component is not installed (offline containers vary).
if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --check"
    cargo fmt --check
else
    echo "==> rustfmt not installed; skipping format check"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "==> cargo clippy --all-targets -- -D warnings"
    cargo clippy --all-targets -- -D warnings
else
    echo "==> clippy not installed; skipping lint step"
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo test -q --features pjrt"
cargo test -q --features pjrt

echo "==> cargo bench --no-run"
cargo bench --no-run

echo "==> bench_throughput smoke (gather-vs-paged + per-method artifact)"
cargo bench --bench bench_throughput -- --smoke --json-out "$PWD/BENCH_throughput.json"
echo "    artifact: $PWD/BENCH_throughput.json"

echo "OK: tier-1 green"
