#!/usr/bin/env bash
# Tier-1 gate in one command: formatting + lints first (fail fast,
# before the expensive build), then release build, offline tests
# (default and pjrt feature), bench compile + smoke perf artifact.
# Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")"

# Lint gates run ahead of the build so style/lint fallout fails in
# seconds, not after a full compile. Both skip gracefully when the
# component is not installed (offline containers vary).
if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --check"
    cargo fmt --check
else
    echo "==> rustfmt not installed; skipping format check"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "==> cargo clippy --all-targets -- -D warnings"
    cargo clippy --all-targets -- -D warnings
else
    echo "==> clippy not installed; skipping lint step"
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo test -q --features pjrt"
cargo test -q --features pjrt

echo "==> cargo bench --no-run"
cargo bench --no-run

echo "==> bench_throughput smoke (gather-vs-paged + per-method artifact)"
cargo bench --bench bench_throughput -- --smoke --json-out "$PWD/BENCH_throughput.json"
echo "    artifact: $PWD/BENCH_throughput.json"

# Bench-regression guard: compare the scoring_lane rows of the fresh
# artifact against the checked-in BENCH_baseline.json (10% tolerance,
# matched by context/group/variant). Only rows present in BOTH
# artifacts are compared — a baseline recorded at full (non-smoke)
# scale carries contexts the smoke artifact never measures, and that
# must not turn CI permanently red; mismatched coverage is a warning.
# Record the baseline with this script (same machine, same smoke
# scale) so absolute selections/s are comparable. Skips gracefully
# when the baseline has not been recorded yet (no toolchain container
# has run the bench) or python3 is unavailable.
echo "==> bench regression guard (scoring_lane vs BENCH_baseline.json)"
if [ -f "$PWD/BENCH_baseline.json" ] && command -v python3 >/dev/null 2>&1; then
    python3 - "$PWD/BENCH_throughput.json" "$PWD/BENCH_baseline.json" <<'PY'
import json, sys

new_doc, base_doc = (json.load(open(p)) for p in sys.argv[1:3])

def rows(doc):
    lane = doc.get("scoring_lane", {}).get("rows", [])
    return {(r.get("context"), r.get("group"), r.get("variant")): r for r in lane}

TOLERANCE = 0.10
new, base = rows(new_doc), rows(base_doc)
failures = []
compared = 0
for key, b in sorted(base.items(), key=str):
    r = new.get(key)
    if r is None:
        # Coverage mismatch (e.g. full-scale baseline vs smoke
        # artifact) is not a regression.
        print(f"  warning: baseline row {key} not in fresh artifact; skipping")
        continue
    want = b.get("sps") or 0.0
    got = r.get("sps") or 0.0
    if want <= 0.0:
        continue
    compared += 1
    if got < (1.0 - TOLERANCE) * want:
        failures.append(
            f"{key}: {got:.1f} sel/s < {100 * (1 - TOLERANCE):.0f}% of baseline {want:.1f}"
        )
if failures:
    print("bench regression guard FAILED:")
    for f in failures:
        print(f"  {f}")
    sys.exit(1)
print(f"bench regression guard OK: {compared} rows within {int(TOLERANCE * 100)}% of baseline")
PY
else
    echo "    BENCH_baseline.json or python3 absent; skipping guard"
    echo "    (record a baseline by copying a trusted BENCH_throughput.json)"
fi

echo "OK: tier-1 green"
