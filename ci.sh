#!/usr/bin/env bash
# Tier-1 gate in one command: release build, offline tests (default and
# pjrt feature), bench compile + smoke perf artifact, and clippy with
# warnings denied. Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo test -q --features pjrt"
cargo test -q --features pjrt

echo "==> cargo bench --no-run"
cargo bench --no-run

echo "==> bench_throughput smoke (gather-vs-paged artifact)"
cargo bench --bench bench_throughput -- --smoke --json-out "$PWD/BENCH_throughput.json"
echo "    artifact: $PWD/BENCH_throughput.json"

if cargo clippy --version >/dev/null 2>&1; then
    echo "==> cargo clippy --all-targets -- -D warnings"
    cargo clippy --all-targets -- -D warnings
else
    echo "==> clippy not installed; skipping lint step"
fi

echo "OK: tier-1 green"
