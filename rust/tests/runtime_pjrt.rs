//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! Compiled only with `--features pjrt` (the whole file is cfg'd out of
//! the default offline build, which links the stub engine). Each test
//! checks its own artifact set and skips itself — with a message — when
//! `make artifacts` has not run, so `cargo test --features pjrt` stays
//! green on a fresh checkout while any subset of artifacts exercises
//! the matching subset of tests.
#![cfg(feature = "pjrt")]

use socket_attn::linalg::Matrix;
use socket_attn::runtime::{artifact_available, artifacts_dir, Engine};
use socket_attn::util::rng::Pcg64;

/// Per-test skip helper: `None` (after printing which artifact is
/// missing) unless every artifact the calling test needs is present.
fn engine_with(artifacts: &[&str]) -> Option<Engine> {
    let missing: Vec<&str> =
        artifacts.iter().copied().filter(|a| !artifact_available(a)).collect();
    if !missing.is_empty() {
        eprintln!("skipping: artifacts {missing:?} missing (run `make artifacts`)");
        return None;
    }
    // The vendored xla stub (offline builds) has no PJRT client even
    // with the feature on: a failed client or compile also skips, with
    // the reason, rather than failing the suite.
    let mut e = match Engine::cpu(artifacts_dir()) {
        Ok(e) => e,
        Err(err) => {
            eprintln!("skipping: PJRT client unavailable ({err})");
            return None;
        }
    };
    for a in artifacts {
        if let Err(err) = e.load(a) {
            eprintln!("skipping: load+compile {a} failed ({err})");
            return None;
        }
    }
    Some(e)
}

/// sparse_decode.hlo.txt computes masked attention over (512, 128)
/// gathered K/V — must match the Rust flash_decode bit-for-bit-ish.
#[test]
fn sparse_decode_artifact_matches_rust_flash_decode() {
    let Some(engine) = engine_with(&["sparse_decode.hlo.txt"]) else {
        return;
    };
    let (k_sel, d) = (512usize, 128usize);
    let mut rng = Pcg64::seeded(11);
    let q = rng.normal_vec(d);
    let keys = Matrix::gaussian(k_sel, d, &mut rng);
    let values = Matrix::gaussian(k_sel, d, &mut rng);
    // Mask: first 400 valid (pred input -> Input::Bool).
    use socket_attn::runtime::engine::Input;
    let mask: Vec<bool> = (0..k_sel).map(|i| i < 400).collect();
    let out = engine
        .run_with(
            "sparse_decode.hlo.txt",
            &[
                Input::F32(vec![d as i64], q.clone()),
                Input::F32(vec![k_sel as i64, d as i64], keys.data.clone()),
                Input::F32(vec![k_sel as i64, d as i64], values.data.clone()),
                Input::Bool(vec![k_sel as i64], mask),
            ],
        )
        .expect("execute");
    assert_eq!(out.len(), 1);
    let got = out[0].f32s().to_vec();
    let selected: Vec<usize> = (0..400).collect();
    let scale = 1.0 / (d as f32).sqrt();
    let want = socket_attn::attention::flash_decode(&q, &keys, &values, Some(&selected), scale);
    for i in 0..d {
        assert!(
            (got[i] - want[i]).abs() < 1e-4,
            "i={i}: pjrt {} vs rust {}",
            got[i],
            want[i]
        );
    }
}

/// socket_score.hlo.txt implements Algorithm 4; verify against a direct
/// computation from the same inputs.
#[test]
fn socket_score_artifact_matches_reference() {
    let Some(engine) = engine_with(&["socket_score.hlo.txt"]) else {
        return;
    };
    let (n, l, r) = (2048usize, 60usize, 1024usize);
    let mut rng = Pcg64::seeded(3);
    // Random per-table distributions.
    let mut probs = vec![0.0f32; l * r];
    for t in 0..l {
        let mut row: Vec<f32> = (0..r).map(|_| rng.next_f32() + 1e-3).collect();
        let s: f32 = row.iter().sum();
        for x in row.iter_mut() {
            *x /= s;
        }
        probs[t * r..(t + 1) * r].copy_from_slice(&row);
    }
    let bucket_ids: Vec<i32> = (0..n * l).map(|_| rng.below(r as u64) as i32).collect();
    let vnorms: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.1).collect();
    let mask: Vec<f32> = (0..n).map(|i| if i % 7 == 0 { 0.0 } else { 1.0 }).collect();
    // Engine inputs: probs (L,R) f32; ids (N,L) i32 — TensorSpec is
    // f32-only, so ids/mask go through the i32/bool conversion helpers.
    let out = engine
        .run_with(
            "socket_score.hlo.txt",
            &[
                socket_attn::runtime::engine::Input::F32(vec![l as i64, r as i64], probs.clone()),
                socket_attn::runtime::engine::Input::I32(vec![n as i64, l as i64], bucket_ids.clone()),
                socket_attn::runtime::engine::Input::F32(vec![n as i64], vnorms.clone()),
                socket_attn::runtime::engine::Input::Bool(
                    vec![n as i64],
                    mask.iter().map(|&m| m > 0.5).collect(),
                ),
            ],
        )
        .expect("execute");
    let got = out[0].f32s();
    for j in (0..n).step_by(97) {
        let mut want = 0.0f32;
        for t in 0..l {
            want += probs[t * r + bucket_ids[j * l + t] as usize];
        }
        want *= vnorms[j];
        if mask[j] < 0.5 {
            assert_eq!(got[j], f32::NEG_INFINITY, "masked j={j}");
        } else {
            assert!((got[j] - want).abs() < 1e-4, "j={j}: {} vs {want}", got[j]);
        }
    }
}

/// Full model path: init -> prefill -> a few decode steps, SOCKET vs
/// dense logits must be strongly correlated.
#[test]
fn model_pipeline_end_to_end() {
    let arts = [
        "model_init.hlo.txt",
        "model_prefill.hlo.txt",
        "model_decode_socket.hlo.txt",
        "model_decode_dense.hlo.txt",
    ];
    let Some(engine) = engine_with(&arts) else {
        return;
    };
    use socket_attn::runtime::engine::Input;
    let params = engine
        .run_with("model_init.hlo.txt", &[Input::I32(vec![], vec![0])])
        .expect("init");
    assert_eq!(params.len(), 40, "param tuple arity");
    // Prefill 1024 tokens.
    let tokens: Vec<i32> = (0..1024).map(|i| (i * 37 % 512) as i32).collect();
    let mut inputs: Vec<Input> = params.iter().map(Input::from_tensor).collect();
    inputs.push(Input::I32(vec![1024], tokens));
    let caches = engine.run_with("model_prefill.hlo.txt", &inputs).expect("prefill");
    assert_eq!(caches.len(), 5);
    // One decode step on both paths.
    let mut dec_inputs: Vec<Input> = params.iter().map(Input::from_tensor).collect();
    dec_inputs.extend(caches.iter().map(Input::from_tensor));
    dec_inputs.push(Input::I32(vec![], vec![7]));
    let socket_out = engine.run_with("model_decode_socket.hlo.txt", &dec_inputs).expect("socket");
    let dense_out = engine.run_with("model_decode_dense.hlo.txt", &dec_inputs).expect("dense");
    let ls = socket_out[0].f32s();
    let ld = dense_out[0].f32s();
    assert_eq!(ls.len(), 512);
    let corr = socket_attn::util::stats::pearson(
        &ls.iter().map(|&x| x as f64).collect::<Vec<_>>(),
        &ld.iter().map(|&x| x as f64).collect::<Vec<_>>(),
    );
    assert!(corr > 0.55, "SOCKET/dense logit correlation {corr}");
    // Length advanced.
    let len_out = socket_out.last().unwrap();
    assert_eq!(len_out.i32s()[0], 1025);
}
