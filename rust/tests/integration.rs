//! Cross-module integration tests on the pure-Rust engine (no PJRT
//! artifacts needed): full SOCKET pipeline vs dense, coordinator under
//! a trace, baseline comparisons on shared workloads.

use socket_attn::attention::{dense_attention, flash_decode, SelectionPolicy};
use socket_attn::selector::{Selector, SocketSelector};
use socket_attn::coordinator::{
    AttentionMode, BatchPolicy, Coordinator, EngineConfig,
};
use socket_attn::linalg::Matrix;
use socket_attn::lsh::LshParams;
use socket_attn::metrics::{attention_mass_recall, output_relative_error};
use socket_attn::model::ModelConfig;
use socket_attn::util::rng::Pcg64;
use socket_attn::workload::ruler::{RulerTask, SPAN_LEN};
use socket_attn::workload::trace::{TraceConfig, TraceGenerator};

/// SOCKET top-k selection captures most dense attention mass and the
/// sparse output approximates dense — the system's core contract, on
/// the heavy-hitter workload of a trained model's attention.
#[test]
fn socket_pipeline_attention_fidelity() {
    let (n, dim) = (4096usize, 64usize);
    let model = socket_attn::model::SyntheticModel::new(
        ModelConfig { head_dim: dim, ..ModelConfig::tiny() },
        42,
    );
    let (keys, values) = model.kv_matrix(0, n);
    let q = model.query_at(0, 0);
    let mut sel = SocketSelector::new(LshParams::paper_default(), dim, 7);
    sel.build_dense(&keys, &values);
    let policy = SelectionPolicy::from_sparsity(n, 10.0, 16, 16);
    let top = sel.select(&q, policy.k).expect("selector built");
    let selected = policy.merge(&top, n);
    let scale = 1.0 / (dim as f32).sqrt();
    let recall = attention_mass_recall(&q, &keys, &selected, scale);
    assert!(recall > 0.8, "attention-mass recall {recall}");
    let yd = dense_attention(&q, &keys, &values, scale);
    let ys = flash_decode(&q, &keys, &values, Some(&selected), scale);
    let rel = output_relative_error(&ys, &yd);
    assert!(rel < 0.25, "rel output err {rel}");
}

/// Needle spans survive the full pipeline at paper sparsity.
#[test]
fn needle_retrieval_at_20x() {
    let (n, dim) = (4096usize, 64usize);
    let mut rng = Pcg64::seeded(1);
    let task = RulerTask::by_name("vt").unwrap();
    let inst = task.generate(n, dim, &mut rng);
    let mut sel = SocketSelector::new(LshParams::paper_default(), dim, 5);
    sel.build_dense(&inst.keys, &inst.values);
    let k = n / 20;
    let got = sel.select(&inst.query, k).expect("selector built");
    let score = task.score(&got, &inst.needles);
    assert!(score > 0.6 * task.ceiling, "vt score {score} of {}", task.ceiling);
    let _ = SPAN_LEN;
}

/// Coordinator serves a bursty trace to completion with SOCKET decode.
#[test]
fn coordinator_serves_trace() {
    let config = EngineConfig {
        model: ModelConfig { head_dim: 16, n_kv_heads: 1, ..ModelConfig::tiny() },
        lsh: LshParams { p: 6, l: 8, tau: 0.5 },
        mode: AttentionMode::socket(8.0),
        capacity_pages: 8192,
        sink: 4,
        local: 4,
    };
    let coord = Coordinator::spawn(config, BatchPolicy::default());
    let mut gen = TraceGenerator::new(
        TraceConfig { rate_rps: 100.0, context_min: 32, context_max: 256, decode_min: 2, decode_max: 6 },
        3,
    );
    let reqs = gen.take(20);
    let handles: Vec<_> = reqs.iter().map(|r| coord.submit(r.clone())).collect();
    let mut total_tokens = 0usize;
    for h in handles {
        let c = h.wait();
        assert!(c.ok, "{:?}", c.error);
        assert!(c.ttft_ms <= c.total_ms + 1e-6);
        total_tokens += c.decode_len;
    }
    let stats = coord.shutdown();
    assert_eq!(stats.completed, 20);
    assert_eq!(stats.decode_steps as usize, total_tokens);
}

/// Dense vs SOCKET coordinator modes produce close outputs for the same
/// sequence (the serving-level analog of the kernel fidelity test).
#[test]
fn serving_modes_agree() {
    let base = EngineConfig {
        model: ModelConfig { head_dim: 32, n_kv_heads: 2, ..ModelConfig::tiny() },
        lsh: LshParams { p: 10, l: 48, tau: 0.5 },
        mode: AttentionMode::Dense,
        capacity_pages: 4096,
        sink: 8,
        local: 8,
    };
    let mut dense = socket_attn::coordinator::DecodeEngine::new(base.clone());
    let mut sparse = socket_attn::coordinator::DecodeEngine::new(EngineConfig {
        mode: AttentionMode::socket(8.0),
        ..base
    });
    assert!(dense.prefill(1, 512, 4));
    assert!(sparse.prefill(1, 512, 4));
    for _ in 0..3 {
        let yd = dense.decode_step(1);
        let ys = sparse.decode_step(1);
        for h in 0..yd.len() {
            let rel = output_relative_error(&ys[h], &yd[h]);
            assert!(rel < 0.5, "head {h} rel {rel}");
        }
    }
}

/// All baselines run on one shared instance and return valid selections.
#[test]
fn all_selectors_produce_valid_selections() {
    use socket_attn::experiments::Method;
    let (n, dim) = (1024usize, 64usize);
    let mut rng = Pcg64::seeded(9);
    let keys = Matrix::gaussian(n, dim, &mut rng);
    let vals = Matrix::gaussian(n, dim, &mut rng);
    let q = rng.normal_vec(dim);
    for method in [
        Method::PqCache,
        Method::Quest,
        Method::DoubleSparsity,
        Method::HashAttention,
        Method::MagicPig,
        Method::Socket,
        Method::HardLsh,
        Method::Oracle,
    ] {
        let mut sel = method.build(dim, 3);
        sel.build_dense(&keys, &vals);
        let got = sel.select(&q, 64).expect("selector built");
        assert!(!got.is_empty(), "{} empty", method.name());
        assert!(got.iter().all(|&i| i < n), "{} out of range", method.name());
        let mut dedup = got.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), got.len(), "{} duplicates", method.name());
    }
}
