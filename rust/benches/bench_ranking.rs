//! Figure 2 — Precision/Jaccard/NDCG vs top-k at matched memory budget.
use socket_attn::experiments::{ranking, Scale};
use socket_attn::util::Args;

fn main() {
    let scale = Scale::from_args(&Args::from_env());
    ranking::table(&ranking::run(scale)).print();
}
