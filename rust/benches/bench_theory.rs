//! Theorem 3 + Lemma 4 empirical validation (error rates, bias, and the
//! hard-vs-soft correlation identity).
use socket_attn::experiments::{theory, Scale};
use socket_attn::util::{fnum, Args, Table};

fn main() {
    let scale = Scale::from_args(&Args::from_env());
    theory::finite_l_table(&theory::finite_l_sweep(scale, &[5, 10, 20, 40, 80, 160], 0.5, 6)).print();
    theory::lemma4_table(&theory::lemma4_check(scale, &[2, 4, 8, 16])).print();

    let mut t = Table::new("epsilon_tau(q) vs tau (P=8, R=256): bias -> 0 as tau -> 0", &["tau", "eps_tau"]);
    for (tau, eps) in theory::epsilon_tau(scale, 8, &[0.05, 0.1, 0.2, 0.5, 1.0, 5.0, 100.0]) {
        t.row(vec![format!("{tau}"), fnum(eps, 4)]);
    }
    t.print();

    let mut t = Table::new("sampling estimator error vs M (Lemma 7: ~ M^-1/2)", &["M", "err", "err*sqrt(M)"]);
    for (m, err) in theory::sampling_sweep(scale, &[8, 32, 128, 512, 2048]) {
        t.row(vec![m.to_string(), fnum(err, 4), fnum(err * (m as f64).sqrt(), 3)]);
    }
    t.print();

    let mut t = Table::new("soft-count vs angular attention gap vs L (Thm 3, no sampling)", &["L", "gap"]);
    for (l, gap) in theory::angular_gap(scale, &[4, 16, 64, 256]) {
        t.row(vec![l.to_string(), fnum(gap, 5)]);
    }
    t.print();
}
