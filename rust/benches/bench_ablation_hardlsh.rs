//! Table 7 — hard-LSH ablations (P, L incl. larger budgets).
use socket_attn::experiments::{ablation, Scale};
use socket_attn::util::Args;

fn main() {
    let scale = Scale::from_args(&Args::from_env());
    ablation::table("Table 7a: hard LSH varying P (L=60)", "P", &ablation::hard_vary_p(scale)).print();
    ablation::table("Table 7b/c: hard LSH varying L (P=2)", "L", &ablation::hard_vary_l(scale)).print();
}
