//! Table 3 — correlation & estimator variance, SOCKET vs hard LSH.
use socket_attn::experiments::{correlation, Scale};
use socket_attn::util::Args;

fn main() {
    let scale = Scale::from_args(&Args::from_env());
    correlation::table(&correlation::run(scale)).print();
}
