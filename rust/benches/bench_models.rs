//! Tables 10/11/12 — RULER-16K method comparison + model-scale sweeps.
use socket_attn::experiments::{models, Scale};
use socket_attn::util::Args;

fn main() {
    let scale = Scale::from_args(&Args::from_env());
    models::table("Table 10: RULER-16K methods (10x)", &models::run_ruler16k(scale)).print();
    for m in models::MODELS.iter().skip(1) {
        models::table(
            &format!("Tables 11/12: SOCKET across sparsity ({})", m.name),
            &models::run_model_sweep(scale, m, &[5.0, 10.0, 20.0, 50.0]),
        )
        .print();
    }
}
