//! Tables 4/5/9 — LongBench proxy across methods and sparsity.
use socket_attn::experiments::{longbench, Scale};
use socket_attn::util::Args;

fn main() {
    let scale = Scale::from_args(&Args::from_env());
    longbench::table(&longbench::run(scale), "Llama-3.1-8B-analog").print();
}
