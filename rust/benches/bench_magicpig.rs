//! Table 8 — MagicPIG evaluation settings vs SOCKET.
use socket_attn::experiments::{magicpig, Scale};
use socket_attn::util::Args;

fn main() {
    let scale = Scale::from_args(&Args::from_env());
    magicpig::table(&magicpig::run(scale)).print();
}
