//! Figures 3b/3c — decode throughput vs context length, SOCKET @33x vs
//! dense FlashAttention-style decode, on the Rust substrate — plus the
//! serial-vs-pooled scoring comparison for the shared worker pool.
use socket_attn::experiments::{throughput, Scale};
use socket_attn::util::Args;

fn main() {
    let args = Args::from_env();
    let mut scale = Scale::from_args(&args);
    scale.dim = args.usize_or("dim", 128); // paper head dim
    let ctxs = [4 * 1024, 16 * 1024, 32 * 1024, 64 * 1024, 128 * 1024];
    let sparsity = args.f64_or("sparsity", 33.0);
    let pts = throughput::run(scale, &ctxs, sparsity);
    throughput::table(&pts, "CPU substrate, 33x sparsity").print();

    // Worker-pool scoring: the same SOCKET selection, one query at a
    // time on one thread vs a batch fanned across the pool.
    let batch = args.usize_or("batch", 16);
    let pool_ctxs = [4 * 1024, 16 * 1024, 64 * 1024];
    let modes = throughput::run_scoring_modes(scale, &pool_ctxs, batch, sparsity);
    throughput::scoring_modes_table(&modes).print();
}
