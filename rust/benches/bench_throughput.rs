//! Figures 3b/3c — decode throughput vs context length, SOCKET @33x vs
//! dense FlashAttention-style decode, on the Rust substrate — plus the
//! serial-vs-pooled scoring comparison for the shared worker pool, the
//! gather-vs-paged KV hot-path comparison (KvView acceptance
//! measurement), the scoring-engine lane (exhaustive vs serial_pruned
//! vs parallel_pruned vs parallel_pruned_ordered vs GQA-fused SOCKET
//! selection + prune rate + threshold warmup), the per-kernel dispatch
//! lane (the four SIMD'd hot kernels under forced-scalar vs auto
//! dispatch — bit-identical outputs, so the ratio is pure vectorization
//! gain), and the per-method
//! serving lane (decode tokens/s for every `selector::registry` method
//! over the paged pool at the paper's sparsity budget), the serving
//! lane (sessions + streaming + the metrics scrape through the real
//! server), the prefix lane (a Zipf shared-prefix workload with the
//! prefix cache live vs opted out), and the saturation lane (a
//! mixed-priority overload burst exercising chunked prefill,
//! preemption, and load shedding). Writes the gather-vs-paged,
//! scoring-lane, per-method, serving, prefix, and saturation tables to
//! a `BENCH_*.json` artifact for the perf trajectory
//! (`--json-out <path>`, empty string to skip). `--smoke` shrinks every
//! sweep so ci.sh can emit the artifact in seconds.
use socket_attn::experiments::{throughput, Scale};
use socket_attn::util::{Args, Json};
use socket_attn::workload::trace::{SaturationConfig, SharedPrefixConfig, TraceConfig};

fn main() {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let mut scale = Scale::from_args(&args);
    scale.dim = args.usize_or("dim", 128); // paper head dim
    let sparsity = args.f64_or("sparsity", 33.0);
    let batch = args.usize_or("batch", 16);
    println!("simd dispatch: {}", socket_attn::simd::tier_name());

    let ctxs: &[usize] = if smoke {
        &[2 * 1024, 8 * 1024]
    } else {
        &[4 * 1024, 16 * 1024, 32 * 1024, 64 * 1024, 128 * 1024]
    };
    let pts = throughput::run(scale, ctxs, sparsity);
    throughput::table(&pts, "CPU substrate, 33x sparsity").print();

    // Worker-pool scoring: the same SOCKET selection, one query at a
    // time on one thread vs a batch fanned across the pool.
    let pool_ctxs: &[usize] =
        if smoke { &[2 * 1024, 8 * 1024] } else { &[4 * 1024, 16 * 1024, 64 * 1024] };
    let modes = throughput::run_scoring_modes(scale, pool_ctxs, batch, sparsity);
    throughput::scoring_modes_table(&modes).print();

    // Gather vs paged-view KV hot path (serial + pooled lanes). Same
    // selections, bit-identical outputs; the delta is gather overhead.
    let pg_batch = args.usize_or("lanes", 8);
    let pg = throughput::run_paged_vs_gather(scale, pool_ctxs, pg_batch, sparsity);
    throughput::paged_vs_gather_table(&pg).print();

    // Scoring engines: exhaustive vs the branch-and-bound matrix
    // (serial / parallel / parallel+bound-ordered / GQA-fused) over one
    // SOCKET index — bit-identical selections; wall-clock, prune rate,
    // and threshold-warmup blocks are the parallel-pruning acceptance
    // numbers.
    let group = args.usize_or("group", 4).max(1);
    let sl_ctxs: &[usize] =
        if smoke { &[2 * 1024, 8 * 1024] } else { &[8 * 1024, 32 * 1024, 128 * 1024] };
    let sl_steps = if smoke { 2 } else { 8 };
    let sl = throughput::run_scoring_lane(scale, sl_ctxs, sparsity, group, sl_steps);
    throughput::scoring_lane_table(&sl, sparsity).print();

    // Per-kernel dispatch lane: the four SIMD'd hot kernels under
    // forced-scalar vs auto dispatch — bit-identical outputs, so the
    // ratio is pure vectorization gain. Rows merge into the
    // scoring-lane artifact (variant `kernel[tier]`) so the ci.sh
    // regression guard covers each cell.
    let kl_steps = if smoke { 2 } else { 4 };
    let kl = throughput::run_kernel_lane(scale, sl_ctxs, kl_steps);
    throughput::kernel_lane_table(&kl).print();

    // Per-method serving lane: every registered selector decoding over
    // the paged pool (index build at prefill + per-step select/attend/
    // append). PQCache's k-means build dominates the large-context
    // rows, which is exactly the TTFT contrast Fig. 3a reports.
    let lane_ctxs: &[usize] = if smoke { &[2 * 1024] } else { &[4 * 1024, 16 * 1024] };
    let lane_steps = if smoke { 4 } else { 16 };
    let lane = throughput::run_method_lane(scale, lane_ctxs, sparsity, lane_steps);
    throughput::method_lane_table(&lane, sparsity).print();

    // Serving lane: the full server surface in process — one-shots,
    // a streaming multi-turn session (turn 2 resumes, zero prefill),
    // and the {"op":"metrics"} scrape (TTFT/TBT quantiles, pool
    // utilization, prune gauges) snapshotted into the artifact.
    let (srv_ctx, srv_dec, srv_turns) = if smoke { (512, 4, 2) } else { (4 * 1024, 16, 3) };
    let serving = throughput::run_serving_lane(scale, srv_ctx, srv_dec, srv_turns);
    println!(
        "Serving lane: ctx {srv_ctx}, {srv_turns} turns, {} streamed token lines",
        serving.get("stream_token_lines").and_then(|v| v.as_usize()).unwrap_or(0)
    );

    // Prefix lane: the same Zipf shared-prefix workload served with the
    // prefix cache live and with it opted out — wall-clock delta plus
    // hit-rate / prefill-tokens-saved gauges.
    let prefix_cfg = SharedPrefixConfig {
        base: TraceConfig {
            context_min: if smoke { 256 } else { 2 * 1024 },
            context_max: if smoke { 1024 } else { 8 * 1024 },
            decode_min: 1,
            decode_max: if smoke { 2 } else { 8 },
            rate_rps: 100.0,
        },
        n_prefixes: 4,
        zipf_s: 1.1,
        prefix_len: if smoke { 256 } else { 2 * 1024 },
    };
    let prefix_n = if smoke { 8 } else { 32 };
    let prefix = throughput::run_prefix_lane(scale, prefix_n, prefix_cfg);
    println!(
        "Prefix lane: {prefix_n} requests, {} prefill tokens saved, {}x vs cold",
        prefix
            .get("cached")
            .and_then(|c| c.get("prefix"))
            .and_then(|p| p.get("prefill_tokens_saved"))
            .and_then(|v| v.as_usize())
            .unwrap_or(0),
        prefix.get("speedup").and_then(|v| v.as_f64()).unwrap_or(0.0)
    );

    // Saturation lane: a Poisson × Zipf-context × mixed-priority burst
    // over an undersized page pool — chunked prefill, preemption, and
    // load shedding all engage; goodput + pressure counters + per-class
    // latency quantiles land in the artifact.
    let sat_cfg = SaturationConfig {
        base: TraceConfig {
            context_min: if smoke { 64 } else { 512 },
            context_max: if smoke { 1024 } else { 16 * 1024 },
            decode_min: 1,
            decode_max: if smoke { 3 } else { 8 },
            rate_rps: 200.0,
        },
        zipf_s: 1.1,
        context_rungs: if smoke { 4 } else { 8 },
        class_mix: [1.0, 2.0, 1.0],
        interactive_deadline_ms: Some(30_000.0),
    };
    let sat_n = if smoke { 16 } else { 64 };
    let saturation = throughput::run_saturation_lane(scale, sat_n, sat_cfg);
    println!(
        "Saturation lane: {sat_n} requests — {} served, {} shed, {} deadline-missed, {} tok/s goodput",
        saturation.get("served").and_then(|v| v.as_usize()).unwrap_or(0),
        saturation.get("shed").and_then(|v| v.as_usize()).unwrap_or(0),
        saturation.get("deadline_missed").and_then(|v| v.as_usize()).unwrap_or(0),
        saturation.get("goodput_tps").and_then(|v| v.as_f64()).unwrap_or(0.0).round()
    );

    let artifact = args.get_or("json-out", "BENCH_throughput.json");
    if !artifact.is_empty() {
        // Merge the per-kernel dispatch rows into the scoring lane so
        // the ci.sh regression guard keys over them too.
        let scoring = throughput::scoring_lane_json(&sl);
        let mut rows = scoring.get("rows").and_then(|r| r.as_arr()).unwrap_or(&[]).to_vec();
        rows.extend(throughput::kernel_lane_rows(&kl));
        let scoring = scoring.set("rows", Json::Arr(rows));
        let doc = Json::obj()
            .set("bench", "throughput")
            .set("smoke", smoke)
            .set("dim", scale.dim)
            .set("sparsity", sparsity)
            .set("dispatch", socket_attn::simd::tier_name())
            .set("paged_vs_gather", throughput::paged_vs_gather_json(&pg))
            .set("scoring_lane", scoring)
            .set("method_lane", throughput::method_lane_json(&lane))
            .set("serving_lane", serving)
            .set("prefix_lane", prefix)
            .set("saturation_lane", saturation);
        match std::fs::write(&artifact, doc.dumps() + "\n") {
            Ok(()) => println!("wrote {artifact}"),
            Err(e) => eprintln!("could not write {artifact}: {e}"),
        }
    }
}
