//! Figures 3b/3c — decode throughput vs context length, SOCKET @33x vs
//! dense FlashAttention-style decode, on the Rust substrate.
use socket_attn::experiments::{throughput, Scale};
use socket_attn::util::Args;

fn main() {
    let args = Args::from_env();
    let mut scale = Scale::from_args(&args);
    scale.dim = args.usize_or("dim", 128); // paper head dim
    let ctxs = [4 * 1024, 16 * 1024, 32 * 1024, 64 * 1024, 128 * 1024];
    let pts = throughput::run(scale, &ctxs, args.f64_or("sparsity", 33.0));
    throughput::table(&pts, "CPU substrate, 33x sparsity").print();
}
