//! Table 2 — retrieval compute/memory overhead, SOCKET vs hard LSH.
use socket_attn::experiments::{overhead, Scale};
use socket_attn::util::Args;

fn main() {
    let scale = Scale::from_args(&Args::from_env());
    overhead::table(&overhead::run(scale)).print();
}
