//! Table 1 — RULER-HARD across sparsity levels, six methods.
use socket_attn::experiments::{ruler, Scale};
use socket_attn::util::Args;

fn main() {
    let scale = Scale::from_args(&Args::from_env());
    ruler::reproduce(scale).print();
}
