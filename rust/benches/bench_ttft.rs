//! Figure 3a — indexer TTFT: SOCKET hashing vs PQCache k-means.
use socket_attn::experiments::{ttft, Scale};
use socket_attn::util::Args;

fn main() {
    let scale = Scale::from_args(&Args::from_env());
    let ctxs = [1024, 4096, 16 * 1024, 32 * 1024];
    ttft::table(&ttft::run(scale, &ctxs)).print();
}
