//! Table 6 — SOCKET hyperparameter ablations (P, L, tau).
use socket_attn::experiments::{ablation, Scale};
use socket_attn::util::Args;

fn main() {
    let scale = Scale::from_args(&Args::from_env());
    ablation::table("Table 6a: SOCKET varying P (tau=0.4, L=60)", "P", &ablation::socket_vary_p(scale)).print();
    ablation::table("Table 6b: SOCKET varying L (tau=0.5, P=10)", "L", &ablation::socket_vary_l(scale)).print();
    ablation::table("Table 6c: SOCKET varying tau (P=10, L=60)", "tau", &ablation::socket_vary_tau(scale)).print();
}
