//! Paged KV storage (vLLM-style): fixed-size token pages allocated from
//! a shared pool, so many sequences share GPU/host memory without
//! fragmentation. The coordinator maps logical token positions to
//! physical pages through a per-sequence [`PageTable`].
//!
//! Pages are **refcounted**: the prefix cache ([`crate::kvcache::prefix`])
//! maps one physical page into many page tables, and writes go through a
//! copy-on-write guard ([`PagedKvCache::ensure_private_tail`]) so a
//! mid-decode append to a shared tail page copies it private first.

/// Tokens per page. 16 matches vLLM's default block size.
pub const PAGE_TOKENS: usize = 16;

// The lsh scoring blocks are a whole number of KV pages, so a hash
// block never straddles a page boundary: pruning a block skips an
// exact set of pages, and a page's tokens always share one block's
// summaries.
const _: () = assert!(
    crate::lsh::BLOCK_TOKENS % PAGE_TOKENS == 0,
    "lsh::BLOCK_TOKENS must be a whole number of KV pages"
);

/// Physical page pool holding K and V for all sequences.
#[derive(Debug)]
pub struct PagedKvCache {
    /// Head dimension (per-token K/V width).
    pub dim: usize,
    /// Number of physical pages.
    capacity_pages: usize,
    /// K storage: capacity_pages x PAGE_TOKENS x dim.
    k: Vec<f32>,
    /// V storage, same layout.
    v: Vec<f32>,
    free_list: Vec<usize>,
    /// Reference count per physical page: 0 = free, 1 = exclusively
    /// owned (by one table or the prefix tree), >1 = shared.
    refs: Vec<u32>,
}

/// Per-sequence logical→physical mapping plus the token count.
#[derive(Clone, Debug, Default)]
pub struct PageTable {
    pub pages: Vec<usize>,
    pub n_tokens: usize,
}

impl PageTable {
    /// Physical (page, slot) of a logical token index.
    #[inline]
    pub fn locate(&self, token: usize) -> (usize, usize) {
        assert!(token < self.n_tokens, "token {token} out of range {}", self.n_tokens);
        // SAFETY: the assert above gives token < n_tokens, and a table
        // always holds ceil(n_tokens / PAGE_TOKENS) pages (append and
        // map_shared keep that invariant), so token / PAGE_TOKENS is in
        // range.
        let page = unsafe { *self.pages.get_unchecked(token / PAGE_TOKENS) };
        (page, token % PAGE_TOKENS)
    }
}

impl PagedKvCache {
    pub fn new(capacity_pages: usize, dim: usize) -> PagedKvCache {
        PagedKvCache {
            dim,
            capacity_pages,
            k: vec![0.0; capacity_pages * PAGE_TOKENS * dim],
            v: vec![0.0; capacity_pages * PAGE_TOKENS * dim],
            free_list: (0..capacity_pages).rev().collect(),
            refs: vec![0; capacity_pages],
        }
    }

    pub fn free_pages(&self) -> usize {
        self.free_list.len()
    }

    pub fn total_pages(&self) -> usize {
        self.capacity_pages
    }

    /// Pages currently allocated (refcount > 0).
    pub fn pages_in_use(&self) -> usize {
        self.capacity_pages - self.free_list.len()
    }

    /// Pages needed to hold `n` tokens.
    pub fn pages_for(n: usize) -> usize {
        n.div_ceil(PAGE_TOKENS)
    }

    /// Reference count of one physical page (0 = free / out of range).
    pub fn ref_count(&self, page: usize) -> u32 {
        match self.refs.get(page) {
            Some(&r) => r,
            None => 0,
        }
    }

    /// Sum of all page refcounts — the pool-accounting invariant checked
    /// after scheduler drains: it must equal the prefix tree's held refs
    /// plus every live sequence's mapped-page count.
    pub fn total_refs(&self) -> usize {
        self.refs.iter().map(|&r| r as usize).sum()
    }

    /// Pop a free page and mark it exclusively owned.
    fn alloc_page(&mut self) -> Option<usize> {
        let page = self.free_list.pop()?;
        if let Some(r) = self.refs.get_mut(page) {
            debug_assert_eq!(*r, 0, "free-listed page {page} had refs");
            *r = 1;
        }
        Some(page)
    }

    /// Add a reference to an allocated page.
    pub fn incref(&mut self, page: usize) {
        assert!(page < self.capacity_pages, "page {page} out of range");
        if let Some(r) = self.refs.get_mut(page) {
            assert!(*r > 0, "incref on free page {page}");
            *r += 1;
        }
    }

    /// Drop a reference; the page returns to the free list at zero.
    pub fn decref(&mut self, page: usize) {
        assert!(page < self.capacity_pages, "page {page} out of range");
        if let Some(r) = self.refs.get_mut(page) {
            assert!(*r > 0, "decref on free page {page}");
            *r -= 1;
            if *r == 0 {
                self.free_list.push(page);
            }
        }
    }

    /// Flat offset of (page, slot) in the K/V buffers.
    #[inline]
    fn offset(&self, page: usize, slot: usize) -> usize {
        debug_assert!(page < self.capacity_pages, "page {page} out of range");
        debug_assert!(slot < PAGE_TOKENS);
        (page * PAGE_TOKENS + slot) * self.dim
    }

    /// Append one token's K/V to a sequence, allocating a page on
    /// boundary crossings. Returns false (and leaves state unchanged) if
    /// the pool is exhausted — the backpressure signal the scheduler
    /// watches. If the sequence's tail page is shared (prefix-cache
    /// partial-tail hit), the write copies it private first
    /// (copy-on-write), which can also exhaust the pool.
    pub fn append(&mut self, table: &mut PageTable, key: &[f32], value: &[f32]) -> bool {
        assert_eq!(key.len(), self.dim);
        assert_eq!(value.len(), self.dim);
        let slot = table.n_tokens % PAGE_TOKENS;
        if slot == 0 {
            match self.alloc_page() {
                Some(p) => table.pages.push(p),
                None => return false,
            }
        } else if !self.ensure_private_tail(table) {
            return false;
        }
        // A page always exists here: slot != 0 means an earlier append
        // or map_shared opened it; slot == 0 just pushed one (or
        // returned false).
        let Some(&page) = table.pages.last() else { return false };
        let off = self.offset(page, slot);
        let dim = self.dim;
        // SAFETY: `page` came from this pool's free list (alloc_page /
        // ensure_private_tail), so page < capacity_pages, and
        // slot < PAGE_TOKENS; hence off + dim <= k.len() == v.len() by
        // construction in `new`.
        let dst = unsafe { self.k.get_unchecked_mut(off..off + dim) };
        dst.copy_from_slice(key);
        // SAFETY: same range argument as the K write above.
        let dst = unsafe { self.v.get_unchecked_mut(off..off + dim) };
        dst.copy_from_slice(value);
        table.n_tokens += 1;
        true
    }

    /// Bulk prefill append; returns tokens actually written.
    pub fn append_many(&mut self, table: &mut PageTable, keys: &[f32], values: &[f32]) -> usize {
        assert_eq!(
            keys.len() % self.dim,
            0,
            "keys length {} is not a multiple of dim {}",
            keys.len(),
            self.dim
        );
        assert_eq!(
            values.len() % self.dim,
            0,
            "values length {} is not a multiple of dim {}",
            values.len(),
            self.dim
        );
        assert_eq!(keys.len(), values.len(), "keys/values length mismatch");
        let n = keys.len() / self.dim;
        for (t, (key, value)) in keys.chunks_exact(self.dim).zip(values.chunks_exact(self.dim)).enumerate() {
            if !self.append(table, key, value) {
                return t;
            }
        }
        n
    }

    /// Map an already-resident page into `table` by reference — the
    /// prefix cache's hit path. The first `tokens` slots of the page
    /// become visible through the table (a full page for interior prefix
    /// pages, fewer for a shared partial tail). Shared pages are only
    /// ever mapped onto a page-aligned table, before any private append.
    pub fn map_shared(&mut self, table: &mut PageTable, page: usize, tokens: usize) {
        assert!(tokens >= 1 && tokens <= PAGE_TOKENS, "shared map of {tokens} tokens");
        assert_eq!(table.n_tokens % PAGE_TOKENS, 0, "shared pages map on page boundaries");
        self.incref(page);
        table.pages.push(page);
        table.n_tokens += tokens;
    }

    /// Copy-on-write guard: if the table's last page is shared, replace
    /// it with a private copy before a write lands. Returns false when
    /// the pool has no page left for the copy (state unchanged).
    pub fn ensure_private_tail(&mut self, table: &mut PageTable) -> bool {
        let Some(&page) = table.pages.last() else { return true };
        if self.ref_count(page) <= 1 {
            return true;
        }
        let Some(fresh) = self.alloc_page() else { return false };
        let len = PAGE_TOKENS * self.dim;
        self.k.copy_within(page * len..(page + 1) * len, fresh * len);
        self.v.copy_within(page * len..(page + 1) * len, fresh * len);
        self.decref(page);
        if let Some(last) = table.pages.last_mut() {
            *last = fresh;
        }
        true
    }

    #[inline]
    pub fn key(&self, table: &PageTable, token: usize) -> &[f32] {
        let (page, slot) = table.locate(token);
        let off = self.offset(page, slot);
        // SAFETY: tables are only populated by this pool's append /
        // map_shared, so page < capacity_pages and slot < PAGE_TOKENS
        // (from locate); off + dim <= k.len() by construction.
        unsafe { self.k.get_unchecked(off..off + self.dim) }
    }

    #[inline]
    pub fn value(&self, table: &PageTable, token: usize) -> &[f32] {
        let (page, slot) = table.locate(token);
        let off = self.offset(page, slot);
        // SAFETY: same range argument as `key`.
        unsafe { self.v.get_unchecked(off..off + self.dim) }
    }

    /// Release a sequence's pages: each loses one reference and returns
    /// to the pool only when nothing else (another table, the prefix
    /// tree) still maps it.
    pub fn release(&mut self, table: &mut PageTable) {
        for page in table.pages.drain(..) {
            assert!(page < self.capacity_pages, "page {page} out of range");
            if let Some(r) = self.refs.get_mut(page) {
                assert!(*r > 0, "release of free page {page}");
                *r -= 1;
                if *r == 0 {
                    self.free_list.push(page);
                }
            }
        }
        table.n_tokens = 0;
    }

    /// Zero-copy read view of one sequence — the decode hot path's
    /// input. Replaces [`PagedKvCache::gather`] on the serving path:
    /// attention kernels address pages through the table in place
    /// instead of copying selected rows into dense matrices.
    pub fn view<'a>(&'a self, table: &'a PageTable) -> KvView<'a> {
        KvView { k: &self.k, v: &self.v, table, dim: self.dim }
    }

    /// Gather selected tokens' K/V into dense matrices (the pre-KvView
    /// hot-path layout; kept as the equivalence reference and for
    /// callers that need an owned dense copy).
    pub fn gather(
        &self,
        table: &PageTable,
        selected: &[usize],
    ) -> (crate::linalg::Matrix, crate::linalg::Matrix) {
        let mut keys = crate::linalg::Matrix::zeros(selected.len(), self.dim);
        let mut values = crate::linalg::Matrix::zeros(selected.len(), self.dim);
        for (i, &t) in selected.iter().enumerate() {
            keys.row_mut(i).copy_from_slice(self.key(table, t));
            values.row_mut(i).copy_from_slice(self.value(table, t));
        }
        (keys, values)
    }
}

/// Zero-copy view of one sequence's K/V in the paged pool: per-token
/// addressing through the page table plus contiguous-run access for
/// tiled kernels. Borrowed from [`PagedKvCache`] for the duration of a
/// read-only compute phase; implements `attention::KvSource`, so
/// `flash_decode_into` / `sparse_attention_into` consume pages in place
/// — no gather, no per-step dense allocation.
#[derive(Clone, Copy, Debug)]
pub struct KvView<'a> {
    k: &'a [f32],
    v: &'a [f32],
    table: &'a PageTable,
    dim: usize,
}

impl<'a> KvView<'a> {
    /// Tokens visible through the view.
    pub fn len(&self) -> usize {
        self.table.n_tokens
    }

    pub fn is_empty(&self) -> bool {
        self.table.n_tokens == 0
    }

    /// Per-token K/V width.
    pub fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    fn offset(&self, t: usize) -> usize {
        // `locate` hard-asserts t < n_tokens: a stale selection index
        // would otherwise silently read another sequence's recycled
        // slot in the last page's tail.
        let (page, slot) = self.table.locate(t);
        (page * PAGE_TOKENS + slot) * self.dim
    }

    /// Key vector of logical token `t`.
    #[inline]
    pub fn key(&self, t: usize) -> &'a [f32] {
        let off = self.offset(t);
        // SAFETY: offset() locates a (page, slot) that append /
        // map_shared put in the table, so the page is inside the pool's
        // buffers and off + dim is in range by pool construction.
        unsafe { self.k.get_unchecked(off..off + self.dim) }
    }

    /// Value vector of logical token `t`.
    #[inline]
    pub fn value(&self, t: usize) -> &'a [f32] {
        let off = self.offset(t);
        // SAFETY: same range argument as `key`.
        unsafe { self.v.get_unchecked(off..off + self.dim) }
    }

    /// Length (in tokens, capped at `max`) of the physically contiguous
    /// run starting at `t`: to the end of `t`'s page, extended across
    /// physically adjacent pages — the common layout right after a
    /// prefill burst, where one sequence takes consecutive pages. The
    /// cap bounds the adjacency scan to what the caller will consume
    /// (tiled kernels pass their tile remainder), keeping the per-tile
    /// cost O(max / PAGE_TOKENS) instead of O(total pages).
    pub fn run_len(&self, t: usize, max: usize) -> usize {
        debug_assert!(max >= 1);
        let pages = &self.table.pages;
        let cap = t.saturating_add(max).min(self.table.n_tokens);
        let mut p = t / PAGE_TOKENS;
        let mut end = ((p + 1) * PAGE_TOKENS).min(cap);
        while end < cap {
            let adjacent = match (pages.get(p), pages.get(p + 1)) {
                (Some(&a), Some(&b)) => b == a + 1,
                _ => false,
            };
            if !adjacent {
                break;
            }
            p += 1;
            end = ((p + 1) * PAGE_TOKENS).min(cap);
        }
        end - t
    }

    /// Keys of the contiguous run starting at `t` (at most `max`
    /// tokens), as a `(slice, len)` pair with `slice.len() == len * dim`.
    pub fn key_run(&self, t: usize, max: usize) -> (&'a [f32], usize) {
        let len = self.run_len(t, max);
        let off = self.offset(t);
        // SAFETY: run_len only extends across physically adjacent pages
        // of this pool, so off + len * dim stays inside the K buffer.
        let run = unsafe { self.k.get_unchecked(off..off + len * self.dim) };
        (run, len)
    }

    /// Values of the contiguous run starting at `t` (at most `max`
    /// tokens).
    pub fn value_run(&self, t: usize, max: usize) -> (&'a [f32], usize) {
        let len = self.run_len(t, max);
        let off = self.offset(t);
        // SAFETY: same range argument as `key_run`.
        let run = unsafe { self.v.get_unchecked(off..off + len * self.dim) };
        (run, len)
    }
}

impl crate::attention::KvSource for KvView<'_> {
    #[inline]
    fn n_tokens(&self) -> usize {
        self.table.n_tokens
    }

    #[inline]
    fn key_dim(&self) -> usize {
        self.dim
    }

    #[inline]
    fn value_dim(&self) -> usize {
        self.dim
    }

    #[inline]
    fn key(&self, t: usize) -> &[f32] {
        KvView::key(self, t)
    }

    #[inline]
    fn value(&self, t: usize) -> &[f32] {
        KvView::value(self, t)
    }

    #[inline]
    fn key_run(&self, t: usize, max: usize) -> (&[f32], usize) {
        KvView::key_run(self, t, max)
    }

    #[inline]
    fn value_run(&self, t: usize, max: usize) -> (&[f32], usize) {
        KvView::value_run(self, t, max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::check_default;
    use crate::util::rng::Pcg64;

    #[test]
    fn append_and_read_back() {
        let mut cache = PagedKvCache::new(4, 8);
        let mut table = PageTable::default();
        let mut rng = Pcg64::seeded(1);
        let mut expected = Vec::new();
        for _ in 0..40 {
            let k = rng.normal_vec(8);
            let v = rng.normal_vec(8);
            assert!(cache.append(&mut table, &k, &v));
            expected.push((k, v));
        }
        for (t, (k, v)) in expected.iter().enumerate() {
            assert_eq!(cache.key(&table, t), k.as_slice());
            assert_eq!(cache.value(&table, t), v.as_slice());
        }
        assert_eq!(table.pages.len(), 3); // ceil(40/16)
        assert_eq!(cache.free_pages(), 1);
    }

    #[test]
    fn exhaustion_returns_false_and_preserves_state() {
        let mut cache = PagedKvCache::new(1, 4);
        let mut table = PageTable::default();
        let k = [0.0; 4];
        for _ in 0..PAGE_TOKENS {
            assert!(cache.append(&mut table, &k, &k));
        }
        assert!(!cache.append(&mut table, &k, &k));
        assert_eq!(table.n_tokens, PAGE_TOKENS);
    }

    #[test]
    fn release_recycles_pages() {
        let mut cache = PagedKvCache::new(2, 4);
        let mut a = PageTable::default();
        let k = [1.0; 4];
        for _ in 0..32 {
            assert!(cache.append(&mut a, &k, &k));
        }
        assert_eq!(cache.free_pages(), 0);
        cache.release(&mut a);
        assert_eq!(cache.free_pages(), 2);
        assert_eq!(a.n_tokens, 0);
        // Reuse by another sequence.
        let mut b = PageTable::default();
        assert!(cache.append(&mut b, &k, &k));
    }

    #[test]
    fn gather_selected() {
        let mut cache = PagedKvCache::new(4, 2);
        let mut table = PageTable::default();
        for t in 0..20 {
            let k = [t as f32, 0.0];
            cache.append(&mut table, &k, &k);
        }
        let (keys, _vals) = cache.gather(&table, &[0, 7, 19]);
        assert_eq!(keys.get(0, 0), 0.0);
        assert_eq!(keys.get(1, 0), 7.0);
        assert_eq!(keys.get(2, 0), 19.0);
    }

    #[test]
    #[should_panic(expected = "not a multiple of dim")]
    fn append_many_rejects_partial_key_rows() {
        let mut cache = PagedKvCache::new(2, 4);
        let mut table = PageTable::default();
        let keys = [0.0; 6]; // 1.5 rows at dim 4
        let values = [0.0; 6];
        cache.append_many(&mut table, &keys, &values);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn append_many_rejects_key_value_mismatch() {
        let mut cache = PagedKvCache::new(2, 4);
        let mut table = PageTable::default();
        let keys = [0.0; 8];
        let values = [0.0; 4];
        cache.append_many(&mut table, &keys, &values);
    }

    #[test]
    fn view_addresses_tokens_across_page_boundaries() {
        let dim = 8;
        let mut cache = PagedKvCache::new(4, dim);
        let mut table = PageTable::default();
        let mut rng = Pcg64::seeded(7);
        let mut expected = Vec::new();
        for _ in 0..40 {
            // 3 pages, last one partial
            let k = rng.normal_vec(dim);
            let v = rng.normal_vec(dim);
            assert!(cache.append(&mut table, &k, &v));
            expected.push((k, v));
        }
        let view = cache.view(&table);
        assert_eq!(view.len(), 40);
        assert_eq!(view.dim(), dim);
        for (t, (k, v)) in expected.iter().enumerate() {
            assert_eq!(view.key(t), k.as_slice(), "key {t}");
            assert_eq!(view.value(t), v.as_slice(), "value {t}");
        }
        // Pages allocated back-to-back are physically adjacent, so the
        // whole sequence is one run from token 0...
        let (ks, len) = view.key_run(0, 64);
        assert_eq!(len, 40);
        assert_eq!(ks.len(), 40 * dim);
        assert_eq!(&ks[17 * dim..18 * dim], expected[17].0.as_slice());
        // ...and a mid-page start (page 1, slot 1) runs to the end.
        let (vs, len17) = view.value_run(17, 64);
        assert_eq!(len17, 23);
        assert_eq!(&vs[0..dim], expected[17].1.as_slice());
        // The caller's cap bounds both the run and the adjacency scan.
        let (_, capped) = view.key_run(3, 10);
        assert_eq!(capped, 10);
    }

    #[test]
    fn view_runs_break_at_non_adjacent_pages() {
        let dim = 2;
        let mut cache = PagedKvCache::new(4, dim);
        let mut a = PageTable::default();
        let mut b = PageTable::default();
        // a takes page 0, b takes page 1, a takes page 2: a's pages are
        // physically non-adjacent, so its runs must break at the page
        // boundary while addressing stays correct.
        for t in 0..PAGE_TOKENS {
            assert!(cache.append(&mut a, &[t as f32, 0.0], &[t as f32, 1.0]));
        }
        for _ in 0..PAGE_TOKENS {
            assert!(cache.append(&mut b, &[9.0, 9.0], &[9.0, 9.0]));
        }
        for t in PAGE_TOKENS..PAGE_TOKENS + 5 {
            assert!(cache.append(&mut a, &[t as f32, 0.0], &[t as f32, 1.0]));
        }
        let view = cache.view(&a);
        assert_eq!(view.len(), PAGE_TOKENS + 5);
        let (_, run0) = view.key_run(0, 100);
        assert_eq!(run0, PAGE_TOKENS, "run must stop at the non-adjacent page");
        let (ks, run1) = view.key_run(PAGE_TOKENS, 100);
        assert_eq!(run1, 5);
        assert_eq!(ks[0], PAGE_TOKENS as f32);
        // Per-token addressing crosses the gap transparently.
        assert_eq!(view.key(PAGE_TOKENS - 1)[0], (PAGE_TOKENS - 1) as f32);
        assert_eq!(view.key(PAGE_TOKENS)[0], PAGE_TOKENS as f32);
        assert_eq!(view.value(PAGE_TOKENS + 4), [(PAGE_TOKENS + 4) as f32, 1.0]);
    }

    #[test]
    fn shared_map_reads_and_cow_appends() {
        let dim = 4;
        let mut cache = PagedKvCache::new(8, dim);
        let mut a = PageTable::default();
        let mut rows = Vec::new();
        for t in 0..20 {
            let k = vec![t as f32; dim];
            let v = vec![-(t as f32); dim];
            assert!(cache.append(&mut a, &k, &v));
            rows.push((k, v));
        }
        let (p0, p1) = (a.pages[0], a.pages[1]);
        let mut b = PageTable::default();
        cache.map_shared(&mut b, p0, PAGE_TOKENS);
        cache.map_shared(&mut b, p1, 4);
        assert_eq!(b.n_tokens, 20);
        assert_eq!(cache.ref_count(p0), 2);
        assert_eq!(cache.ref_count(p1), 2);
        for (t, (k, v)) in rows.iter().enumerate() {
            assert_eq!(cache.key(&b, t), k.as_slice(), "shared key {t}");
            assert_eq!(cache.value(&b, t), v.as_slice(), "shared value {t}");
        }
        // Appending to b copies the shared partial tail before writing.
        let k_new = vec![99.0; dim];
        assert!(cache.append(&mut b, &k_new, &k_new));
        assert_ne!(b.pages[1], p1, "COW must copy the shared tail page");
        assert_eq!(cache.ref_count(p1), 1, "a keeps the original tail");
        assert_eq!(cache.key(&b, 20), k_new.as_slice());
        assert_eq!(cache.key(&b, 19), rows[19].0.as_slice(), "copied slots survive");
        assert_eq!(cache.key(&a, 19), rows[19].0.as_slice(), "a is untouched");
        assert_eq!(a.n_tokens, 20);
        // Releases drop refs; pages free only at refcount zero.
        cache.release(&mut b);
        assert_eq!(cache.ref_count(p0), 1);
        cache.release(&mut a);
        assert_eq!(cache.free_pages(), 8);
        assert_eq!(cache.total_refs(), 0);
    }

    #[test]
    fn cow_with_exhausted_pool_fails_cleanly() {
        let dim = 2;
        let mut cache = PagedKvCache::new(1, dim);
        let mut a = PageTable::default();
        let k = [1.0; 2];
        assert!(cache.append(&mut a, &k, &k));
        let mut b = PageTable::default();
        cache.map_shared(&mut b, a.pages[0], 1);
        assert!(!cache.append(&mut b, &k, &k), "no page left for the COW copy");
        assert_eq!(b.n_tokens, 1);
        assert_eq!(cache.ref_count(a.pages[0]), 2);
    }

    #[test]
    fn append_after_full_shared_page_opens_private_page() {
        let dim = 2;
        let mut cache = PagedKvCache::new(3, dim);
        let mut a = PageTable::default();
        for t in 0..PAGE_TOKENS {
            assert!(cache.append(&mut a, &[t as f32, 0.0], &[t as f32, 0.0]));
        }
        let shared = a.pages[0];
        let mut b = PageTable::default();
        cache.map_shared(&mut b, shared, PAGE_TOKENS);
        // The shared page is full, so the append opens a fresh private
        // page — no COW, the shared page keeps both references.
        assert!(cache.append(&mut b, &[7.0, 7.0], &[7.0, 7.0]));
        assert_eq!(b.pages.len(), 2);
        assert_eq!(b.pages[0], shared);
        assert_eq!(cache.ref_count(shared), 2);
        assert_eq!(cache.key(&b, PAGE_TOKENS), [7.0, 7.0]);
        assert_eq!(cache.key(&b, 3), [3.0, 0.0], "shared slots still visible");
    }

    #[test]
    fn prop_interleaved_sequences_do_not_corrupt() {
        check_default("paged-isolation", |rng, _| {
            let dim = 4;
            let mut cache = PagedKvCache::new(64, dim);
            let mut tables = vec![PageTable::default(), PageTable::default(), PageTable::default()];
            let mut logs: Vec<Vec<Vec<f32>>> = vec![Vec::new(); 3];
            for _ in 0..200 {
                let s = rng.below_usize(3);
                let k = rng.normal_vec(dim);
                if cache.append(&mut tables[s], &k, &k) {
                    logs[s].push(k);
                }
            }
            for s in 0..3 {
                for (t, k) in logs[s].iter().enumerate() {
                    prop_assert!(
                        cache.key(&tables[s], t) == k.as_slice(),
                        "seq {s} token {t} corrupted"
                    );
                }
            }
            Ok(())
        });
    }
}
