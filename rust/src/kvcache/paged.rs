//! Paged KV storage (vLLM-style): fixed-size token pages allocated from
//! a shared pool, so many sequences share GPU/host memory without
//! fragmentation. The coordinator maps logical token positions to
//! physical pages through a per-sequence [`PageTable`].

/// Tokens per page. 16 matches vLLM's default block size.
pub const PAGE_TOKENS: usize = 16;

/// Physical page pool holding K and V for all sequences.
#[derive(Debug)]
pub struct PagedKvCache {
    /// Head dimension (per-token K/V width).
    pub dim: usize,
    /// Number of physical pages.
    capacity_pages: usize,
    /// K storage: capacity_pages x PAGE_TOKENS x dim.
    k: Vec<f32>,
    /// V storage, same layout.
    v: Vec<f32>,
    free_list: Vec<usize>,
}

/// Per-sequence logical→physical mapping plus the token count.
#[derive(Clone, Debug, Default)]
pub struct PageTable {
    pub pages: Vec<usize>,
    pub n_tokens: usize,
}

impl PageTable {
    /// Physical (page, slot) of a logical token index.
    #[inline]
    pub fn locate(&self, token: usize) -> (usize, usize) {
        assert!(token < self.n_tokens, "token {token} out of range {}", self.n_tokens);
        (self.pages[token / PAGE_TOKENS], token % PAGE_TOKENS)
    }
}

impl PagedKvCache {
    pub fn new(capacity_pages: usize, dim: usize) -> PagedKvCache {
        PagedKvCache {
            dim,
            capacity_pages,
            k: vec![0.0; capacity_pages * PAGE_TOKENS * dim],
            v: vec![0.0; capacity_pages * PAGE_TOKENS * dim],
            free_list: (0..capacity_pages).rev().collect(),
        }
    }

    pub fn free_pages(&self) -> usize {
        self.free_list.len()
    }

    pub fn total_pages(&self) -> usize {
        self.capacity_pages
    }

    /// Pages needed to hold `n` tokens.
    pub fn pages_for(n: usize) -> usize {
        n.div_ceil(PAGE_TOKENS)
    }

    /// Append one token's K/V to a sequence, allocating a page on
    /// boundary crossings. Returns false (and leaves state unchanged) if
    /// the pool is exhausted — the backpressure signal the scheduler
    /// watches.
    pub fn append(&mut self, table: &mut PageTable, key: &[f32], value: &[f32]) -> bool {
        assert_eq!(key.len(), self.dim);
        assert_eq!(value.len(), self.dim);
        let slot = table.n_tokens % PAGE_TOKENS;
        if slot == 0 {
            match self.free_list.pop() {
                Some(p) => table.pages.push(p),
                None => return false,
            }
        }
        let page = *table.pages.last().unwrap();
        let off = (page * PAGE_TOKENS + slot) * self.dim;
        self.k[off..off + self.dim].copy_from_slice(key);
        self.v[off..off + self.dim].copy_from_slice(value);
        table.n_tokens += 1;
        true
    }

    /// Bulk prefill append; returns tokens actually written.
    pub fn append_many(&mut self, table: &mut PageTable, keys: &[f32], values: &[f32]) -> usize {
        let n = keys.len() / self.dim;
        for t in 0..n {
            if !self.append(table, &keys[t * self.dim..(t + 1) * self.dim], &values[t * self.dim..(t + 1) * self.dim]) {
                return t;
            }
        }
        n
    }

    #[inline]
    pub fn key(&self, table: &PageTable, token: usize) -> &[f32] {
        let (page, slot) = table.locate(token);
        let off = (page * PAGE_TOKENS + slot) * self.dim;
        &self.k[off..off + self.dim]
    }

    #[inline]
    pub fn value(&self, table: &PageTable, token: usize) -> &[f32] {
        let (page, slot) = table.locate(token);
        let off = (page * PAGE_TOKENS + slot) * self.dim;
        &self.v[off..off + self.dim]
    }

    /// Release a sequence's pages back to the pool.
    pub fn release(&mut self, table: &mut PageTable) {
        self.free_list.extend(table.pages.drain(..));
        table.n_tokens = 0;
    }

    /// Gather selected tokens' K/V into dense matrices (what the sparse
    /// attention kernel consumes).
    pub fn gather(
        &self,
        table: &PageTable,
        selected: &[usize],
    ) -> (crate::linalg::Matrix, crate::linalg::Matrix) {
        let mut keys = crate::linalg::Matrix::zeros(selected.len(), self.dim);
        let mut values = crate::linalg::Matrix::zeros(selected.len(), self.dim);
        for (i, &t) in selected.iter().enumerate() {
            keys.row_mut(i).copy_from_slice(self.key(table, t));
            values.row_mut(i).copy_from_slice(self.value(table, t));
        }
        (keys, values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::check_default;
    use crate::util::rng::Pcg64;

    #[test]
    fn append_and_read_back() {
        let mut cache = PagedKvCache::new(4, 8);
        let mut table = PageTable::default();
        let mut rng = Pcg64::seeded(1);
        let mut expected = Vec::new();
        for _ in 0..40 {
            let k = rng.normal_vec(8);
            let v = rng.normal_vec(8);
            assert!(cache.append(&mut table, &k, &v));
            expected.push((k, v));
        }
        for (t, (k, v)) in expected.iter().enumerate() {
            assert_eq!(cache.key(&table, t), k.as_slice());
            assert_eq!(cache.value(&table, t), v.as_slice());
        }
        assert_eq!(table.pages.len(), 3); // ceil(40/16)
        assert_eq!(cache.free_pages(), 1);
    }

    #[test]
    fn exhaustion_returns_false_and_preserves_state() {
        let mut cache = PagedKvCache::new(1, 4);
        let mut table = PageTable::default();
        let k = [0.0; 4];
        for _ in 0..PAGE_TOKENS {
            assert!(cache.append(&mut table, &k, &k));
        }
        assert!(!cache.append(&mut table, &k, &k));
        assert_eq!(table.n_tokens, PAGE_TOKENS);
    }

    #[test]
    fn release_recycles_pages() {
        let mut cache = PagedKvCache::new(2, 4);
        let mut a = PageTable::default();
        let k = [1.0; 4];
        for _ in 0..32 {
            assert!(cache.append(&mut a, &k, &k));
        }
        assert_eq!(cache.free_pages(), 0);
        cache.release(&mut a);
        assert_eq!(cache.free_pages(), 2);
        assert_eq!(a.n_tokens, 0);
        // Reuse by another sequence.
        let mut b = PageTable::default();
        assert!(cache.append(&mut b, &k, &k));
    }

    #[test]
    fn gather_selected() {
        let mut cache = PagedKvCache::new(4, 2);
        let mut table = PageTable::default();
        for t in 0..20 {
            let k = [t as f32, 0.0];
            cache.append(&mut table, &k, &k);
        }
        let (keys, _vals) = cache.gather(&table, &[0, 7, 19]);
        assert_eq!(keys.get(0, 0), 0.0);
        assert_eq!(keys.get(1, 0), 7.0);
        assert_eq!(keys.get(2, 0), 19.0);
    }

    #[test]
    fn prop_interleaved_sequences_do_not_corrupt() {
        check_default("paged-isolation", |rng, _| {
            let dim = 4;
            let mut cache = PagedKvCache::new(64, dim);
            let mut tables = vec![PageTable::default(), PageTable::default(), PageTable::default()];
            let mut logs: Vec<Vec<Vec<f32>>> = vec![Vec::new(); 3];
            for _ in 0..200 {
                let s = rng.below_usize(3);
                let k = rng.normal_vec(dim);
                if cache.append(&mut tables[s], &k, &k) {
                    logs[s].push(k);
                }
            }
            for s in 0..3 {
                for (t, k) in logs[s].iter().enumerate() {
                    prop_assert!(
                        cache.key(&tables[s], t) == k.as_slice(),
                        "seq {s} token {t} corrupted"
                    );
                }
            }
            Ok(())
        });
    }
}
