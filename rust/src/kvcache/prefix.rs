//! Prefix-sharing index over the paged KV pool: a radix tree over
//! token-aligned prompt prefixes whose nodes own one physical page per
//! KV head, held alive by page refcounts ([`PagedKvCache`] refcounting).
//!
//! Content identity. The synthetic model derives token `t`'s K/V from
//! `(seed, t)` alone, so a page's content is identified exactly by the
//! seeds governing its 16 slots — that 16-seed vector is the tree's
//! radix key ([`PageKey`]). Requests opt in by carrying a
//! [`PromptSpec`]: an ordered list of `(seed, len)` segments (a shared
//! system prompt is one popular segment followed by a request-private
//! tail). Two requests agreeing on every seed of a page position have
//! bit-identical K/V there, so the engine maps the tree's page into the
//! new request's table by incref instead of recomputing prefill.
//!
//! Sharing rules (mirrored by `DecodeEngine::prefill_opts`):
//! * whole pages match down the tree from the root; the walk stops at
//!   the first divergent page — everything after is private;
//! * a request whose context ends mid-page may share a tree page's
//!   leading slots ([`PrefixTree::partial_tail`]); its first append
//!   then triggers copy-on-write in the pool;
//! * nodes on 4-page boundaries also carry the frozen selector hash
//!   block for their 64-token run ([`crate::lsh::HashBlock`]), so a
//!   prefix hit skips Algorithm-1 hashing as well as prefill attention;
//! * under pool pressure, least-recently-hit leaves whose pages are
//!   tree-exclusive (refcount 1) are evicted ([`PrefixTree::evict_lru`]).

use std::collections::HashMap;
use std::sync::Arc;

use crate::kvcache::paged::{PagedKvCache, PAGE_TOKENS};
use crate::lsh::HashBlock;

/// One prompt segment: `len` tokens whose content is keyed on `seed`
/// and the token's global position.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PromptSegment {
    pub seed: u64,
    pub len: usize,
}

/// A request's prompt content: ordered segments covering the context,
/// plus the per-request opt-out for the prefix cache.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct PromptSpec {
    pub segments: Vec<PromptSegment>,
    /// False disables prefix-cache participation (`"cache":"off"`):
    /// the request neither reads nor populates the tree.
    pub cache: bool,
}

impl PromptSpec {
    /// A single-segment prompt from an explicit content seed.
    pub fn from_seed(seed: u64, len: usize) -> PromptSpec {
        PromptSpec { segments: vec![PromptSegment { seed, len }], cache: true }
    }

    /// A single-segment prompt whose seed is a stable hash of `text` —
    /// the server's `"prompt":"..."` path. FNV-1a, so identical prompt
    /// strings collide into identical content streams across requests.
    pub fn from_text(text: &str, len: usize) -> PromptSpec {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in text.as_bytes() {
            h ^= u64::from(*b);
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        PromptSpec::from_seed(h, len)
    }

    /// Total tokens covered by the segments.
    pub fn total_len(&self) -> usize {
        self.segments.iter().map(|s| s.len).sum()
    }

    /// The content seed governing position `t`, or None past the end.
    pub fn seed_at(&self, t: usize) -> Option<u64> {
        let mut start = 0usize;
        for seg in &self.segments {
            let end = start + seg.len;
            if t < end {
                return Some(seg.seed);
            }
            start = end;
        }
        None
    }

    /// Segments as `(seed, len)` pairs for `SyntheticModel::with_segments`.
    pub fn segment_pairs(&self) -> Vec<(u64, usize)> {
        self.segments.iter().map(|s| (s.seed, s.len)).collect()
    }

    /// Content key of page `page`, if the prompt fully covers it.
    pub fn page_key(&self, page: usize) -> Option<PageKey> {
        let mut seeds = [0u64; PAGE_TOKENS];
        for (slot, out) in seeds.iter_mut().enumerate() {
            *out = self.seed_at(page * PAGE_TOKENS + slot)?;
        }
        Some(PageKey { seeds })
    }

    /// Content key of a *partially* covered tail page: the first
    /// `tokens` slots carry real seeds, the rest are zero-padded (a
    /// tail node's match is clamped to its fill, so the padding is
    /// never compared against prompt content).
    pub fn tail_key(&self, page: usize, tokens: usize) -> Option<PageKey> {
        assert!(tokens >= 1 && tokens <= PAGE_TOKENS, "tail of {tokens} tokens");
        let mut seeds = [0u64; PAGE_TOKENS];
        for (slot, out) in seeds.iter_mut().take(tokens).enumerate() {
            *out = self.seed_at(page * PAGE_TOKENS + slot)?;
        }
        Some(PageKey { seeds })
    }
}

/// Exact content identity of one KV page: the seed governing each of
/// its 16 token slots. Equal keys ⇒ bit-identical page content (the
/// model derives K/V from `(seed, position)` alone, and tree position
/// fixes the page's position).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct PageKey {
    seeds: [u64; PAGE_TOKENS],
}

impl PageKey {
    /// Seed of one slot (0 for out-of-range slots).
    pub fn seed_at(&self, slot: usize) -> u64 {
        match self.seeds.get(slot) {
            Some(&s) => s,
            None => 0,
        }
    }
}

struct Node {
    key: PageKey,
    /// Valid token slots of this node's pages. `PAGE_TOKENS` for full
    /// interior/leaf pages; less for a frozen partial tail (the pool's
    /// COW guard keeps the remaining slots forever unwritten while the
    /// tree holds its reference).
    filled: usize,
    /// One physical page per KV head, head order.
    pages: Vec<usize>,
    children: HashMap<PageKey, usize>,
    parent: Option<usize>,
    /// Frozen selector hash block per head; populated only on nodes
    /// that end a 64-token hash block (every 4th page of a prefix).
    hash_blocks: Vec<Option<Arc<HashBlock>>>,
    /// Logical clock of the last walk that traversed this node.
    last_hit: u64,
}

/// Radix tree over page-aligned prompt prefixes. Each resident node
/// holds one refcount on each of its per-head pages; eviction is the
/// only way the tree gives them back.
pub struct PrefixTree {
    n_kv_heads: usize,
    roots: HashMap<PageKey, usize>,
    nodes: Vec<Option<Node>>,
    free_slots: Vec<usize>,
    clock: u64,
}

impl PrefixTree {
    pub fn new(n_kv_heads: usize) -> PrefixTree {
        assert!(n_kv_heads > 0, "prefix tree needs at least one kv head");
        PrefixTree { n_kv_heads, roots: HashMap::new(), nodes: Vec::new(), free_slots: Vec::new(), clock: 0 }
    }

    fn node(&self, id: usize) -> Option<&Node> {
        self.nodes.get(id).and_then(|slot| slot.as_ref())
    }

    fn node_mut(&mut self, id: usize) -> Option<&mut Node> {
        self.nodes.get_mut(id).and_then(|slot| slot.as_mut())
    }

    /// Resident nodes.
    pub fn n_nodes(&self) -> usize {
        self.nodes.iter().flatten().count()
    }

    /// Page references the tree holds (nodes x kv heads) — the tree's
    /// side of the pool-accounting invariant.
    pub fn held_refs(&self) -> usize {
        self.nodes.iter().flatten().map(|n| n.pages.len()).sum()
    }

    /// Visit every physical page the tree references.
    pub fn for_each_held_page(&self, mut f: impl FnMut(usize)) {
        for n in self.nodes.iter().flatten() {
            for &p in &n.pages {
                f(p);
            }
        }
    }

    /// Walk the prompt's whole-page keys from the root, returning the
    /// node ids of the longest matching prefix (at most `max_pages`).
    /// Matched nodes are touched for LRU.
    pub fn walk(&mut self, spec: &PromptSpec, max_pages: usize) -> Vec<usize> {
        self.clock += 1;
        let clock = self.clock;
        let mut path = Vec::new();
        let mut cursor: Option<usize> = None;
        for page in 0..max_pages {
            let Some(key) = spec.page_key(page) else { break };
            let next = match cursor {
                None => self.roots.get(&key).copied(),
                Some(id) => self.node(id).and_then(|n| n.children.get(&key).copied()),
            };
            let Some(id) = next else { break };
            // A key collision with a zero-padded tail node must not
            // extend the full-page walk: tails are terminal.
            match self.node(id) {
                Some(n) if n.filled == PAGE_TOKENS => {}
                _ => break,
            }
            if let Some(n) = self.node_mut(id) {
                n.last_hit = clock;
            }
            path.push(id);
            cursor = Some(id);
        }
        path
    }

    /// After a full-page walk matched everything up to `page`, find a
    /// child of `parent` whose first `tokens` slot seeds agree with the
    /// prompt at page `page` — a shareable partial tail (the pool's COW
    /// guard makes later appends safe).
    pub fn partial_tail(&self, parent: Option<usize>, spec: &PromptSpec, page: usize, tokens: usize) -> Option<usize> {
        assert!(tokens >= 1 && tokens <= PAGE_TOKENS, "partial tail of {tokens} tokens");
        let children = match parent {
            None => &self.roots,
            Some(id) => &self.node(id)?.children,
        };
        'candidates: for (key, &id) in children {
            // The node must actually hold content for every slot the
            // request wants (a frozen partial tail's padding slots were
            // never written).
            match self.node(id) {
                Some(n) if n.filled >= tokens => {}
                _ => continue,
            }
            for slot in 0..tokens {
                if spec.seed_at(page * PAGE_TOKENS + slot) != Some(key.seed_at(slot)) {
                    continue 'candidates;
                }
            }
            return Some(id);
        }
        None
    }

    /// Insert a freshly written full page run under `parent` (None =
    /// root), taking one reference on each per-head page. Returns the
    /// new node id.
    pub fn insert_child(
        &mut self,
        parent: Option<usize>,
        key: PageKey,
        pages: &[usize],
        kv: &mut PagedKvCache,
    ) -> usize {
        self.insert_node(parent, key, PAGE_TOKENS, pages, kv)
    }

    /// Insert a frozen *partial* tail page (`filled < PAGE_TOKENS` valid
    /// leading slots) under `parent`. The tree's reference makes any
    /// later append through a mapping table copy-on-write, so the
    /// node's content stays immutable at `filled` tokens. Tail nodes
    /// are terminal: `walk` never descends into them and they carry no
    /// hash blocks.
    pub fn insert_tail(
        &mut self,
        parent: Option<usize>,
        key: PageKey,
        filled: usize,
        pages: &[usize],
        kv: &mut PagedKvCache,
    ) -> usize {
        assert!(filled >= 1 && filled < PAGE_TOKENS, "tail fill {filled} out of range");
        self.insert_node(parent, key, filled, pages, kv)
    }

    fn insert_node(
        &mut self,
        parent: Option<usize>,
        key: PageKey,
        filled: usize,
        pages: &[usize],
        kv: &mut PagedKvCache,
    ) -> usize {
        assert_eq!(pages.len(), self.n_kv_heads, "one page per kv head");
        for &p in pages {
            kv.incref(p);
        }
        let node = Node {
            key,
            filled,
            pages: pages.to_vec(),
            children: HashMap::new(),
            parent,
            hash_blocks: vec![None; self.n_kv_heads],
            last_hit: self.clock,
        };
        let id = match self.free_slots.pop() {
            Some(slot) => {
                if let Some(cell) = self.nodes.get_mut(slot) {
                    *cell = Some(node);
                }
                slot
            }
            None => {
                self.nodes.push(Some(node));
                self.nodes.len() - 1
            }
        };
        let prev = match parent {
            None => self.roots.insert(key, id),
            Some(pid) => match self.node_mut(pid) {
                Some(p) => p.children.insert(key, id),
                None => None,
            },
        };
        assert!(prev.is_none(), "duplicate prefix node for an already-resident page key");
        id
    }

    /// Per-head pages of a node (empty if the id is stale).
    pub fn node_pages(&self, id: usize) -> &[usize] {
        match self.node(id) {
            Some(n) => &n.pages,
            None => &[],
        }
    }

    /// The frozen hash block head `head` of node `id` carries, if any.
    pub fn hash_block(&self, id: usize, head: usize) -> Option<Arc<HashBlock>> {
        self.node(id).and_then(|n| n.hash_blocks.get(head).cloned().flatten())
    }

    /// Attach a frozen hash block to a node (idempotent: first writer
    /// wins, later identical freezes are dropped).
    pub fn set_hash_block(&mut self, id: usize, head: usize, block: Arc<HashBlock>) {
        if let Some(n) = self.node_mut(id) {
            if let Some(slot) = n.hash_blocks.get_mut(head) {
                if slot.is_none() {
                    *slot = Some(block);
                }
            }
        }
    }

    /// Evict least-recently-hit leaves whose pages are tree-exclusive
    /// (refcount 1 — no live sequence maps them) until `want_pages`
    /// physical pages have been freed or nothing evictable remains.
    /// Returns pages actually freed.
    pub fn evict_lru(&mut self, kv: &mut PagedKvCache, want_pages: usize) -> usize {
        let mut freed = 0usize;
        while freed < want_pages {
            let mut best: Option<(u64, usize)> = None;
            for (id, slot) in self.nodes.iter().enumerate() {
                let Some(n) = slot else { continue };
                if !n.children.is_empty() {
                    continue; // interior nodes keep the radix paths intact
                }
                if n.pages.iter().any(|&p| kv.ref_count(p) != 1) {
                    continue; // a live sequence still maps this run
                }
                let better = match best {
                    None => true,
                    Some((t, _)) => n.last_hit < t,
                };
                if better {
                    best = Some((n.last_hit, id));
                }
            }
            let Some((_, id)) = best else { break };
            freed += self.remove_leaf(id, kv);
        }
        freed
    }

    /// Detach a leaf, dropping its page references. Returns pages freed.
    fn remove_leaf(&mut self, id: usize, kv: &mut PagedKvCache) -> usize {
        let Some(node) = self.nodes.get_mut(id).and_then(Option::take) else { return 0 };
        assert!(node.children.is_empty(), "evicting an interior prefix node");
        let freed = node.pages.len();
        for &p in &node.pages {
            kv.decref(p);
        }
        match node.parent {
            None => {
                self.roots.remove(&node.key);
            }
            Some(pid) => {
                if let Some(p) = self.node_mut(pid) {
                    p.children.remove(&node.key);
                }
            }
        }
        self.free_slots.push(id);
        freed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::PageTable;

    fn fill_pages(kv: &mut PagedKvCache, n_pages: usize) -> Vec<usize> {
        // Allocate pages through a scratch table, then strip the table's
        // reference so the test can hand them to the tree as the sole owner
        // after map_shared balancing. Simpler: append directly per page.
        let mut table = PageTable::default();
        let dim = kv.dim;
        for t in 0..n_pages * PAGE_TOKENS {
            let row = vec![t as f32; dim];
            assert!(kv.append(&mut table, &row, &row));
        }
        table.pages.clone()
    }

    #[test]
    fn prompt_spec_segments_cover_positions() {
        let spec = PromptSpec { segments: vec![PromptSegment { seed: 7, len: 20 }, PromptSegment { seed: 9, len: 12 }], cache: true };
        assert_eq!(spec.total_len(), 32);
        assert_eq!(spec.seed_at(0), Some(7));
        assert_eq!(spec.seed_at(19), Some(7));
        assert_eq!(spec.seed_at(20), Some(9));
        assert_eq!(spec.seed_at(31), Some(9));
        assert_eq!(spec.seed_at(32), None);
        // Page 0 is pure seed 7; page 1 mixes 7 and 9.
        let k0 = spec.page_key(0).unwrap();
        assert!((0..PAGE_TOKENS).all(|s| k0.seed_at(s) == 7));
        let k1 = spec.page_key(1).unwrap();
        assert_eq!(k1.seed_at(3), 7);
        assert_eq!(k1.seed_at(4), 9);
        // Page 2 is not fully covered.
        assert_eq!(spec.page_key(2), None);
    }

    #[test]
    fn text_prompts_hash_deterministically() {
        let a = PromptSpec::from_text("system prompt", 64);
        let b = PromptSpec::from_text("system prompt", 64);
        let c = PromptSpec::from_text("other prompt", 64);
        assert_eq!(a, b);
        assert_ne!(a.segments[0].seed, c.segments[0].seed);
        assert!(a.cache);
    }

    #[test]
    fn walk_insert_and_rewalk_share_pages() {
        let mut kv = PagedKvCache::new(16, 2);
        let mut tree = PrefixTree::new(1);
        let spec = PromptSpec::from_seed(42, 3 * PAGE_TOKENS);
        assert!(tree.walk(&spec, 3).is_empty(), "cold tree has no prefix");
        let pages = fill_pages(&mut kv, 3);
        let mut parent = None;
        for page in 0..3 {
            let key = spec.page_key(page).unwrap();
            let id = tree.insert_child(parent, key, &pages[page..page + 1], &mut kv);
            parent = Some(id);
        }
        assert_eq!(tree.n_nodes(), 3);
        assert_eq!(tree.held_refs(), 3);
        // Each page now has the filling table's ref + the tree's ref.
        assert!(pages.iter().all(|&p| kv.ref_count(p) == 2));
        let path = tree.walk(&spec, 3);
        assert_eq!(path.len(), 3);
        assert_eq!(tree.node_pages(path[0]), &pages[0..1]);
        // A prompt diverging at page 1 matches only page 0.
        let fork = PromptSpec {
            segments: vec![PromptSegment { seed: 42, len: PAGE_TOKENS }, PromptSegment { seed: 5, len: 2 * PAGE_TOKENS }],
            cache: true,
        };
        assert_eq!(tree.walk(&fork, 3).len(), 1);
    }

    #[test]
    fn partial_tail_matches_leading_slots() {
        let mut kv = PagedKvCache::new(4, 2);
        let mut tree = PrefixTree::new(1);
        let spec = PromptSpec::from_seed(11, PAGE_TOKENS);
        let pages = fill_pages(&mut kv, 1);
        tree.insert_child(None, spec.page_key(0).unwrap(), &pages, &mut kv);
        // A shorter prompt with the same seed shares the page's head.
        let short = PromptSpec::from_seed(11, 10);
        let hit = tree.partial_tail(None, &short, 0, 10);
        assert!(hit.is_some());
        // A different seed does not.
        let other = PromptSpec::from_seed(12, 10);
        assert!(tree.partial_tail(None, &other, 0, 10).is_none());
    }

    #[test]
    fn tail_nodes_match_up_to_fill_and_stay_out_of_walks() {
        let mut kv = PagedKvCache::new(4, 2);
        let mut tree = PrefixTree::new(1);
        // A 10-token frozen tail at the root.
        let spec = PromptSpec::from_seed(21, 10);
        let mut table = PageTable::default();
        for t in 0..10 {
            let row = [t as f32, 0.0];
            assert!(kv.append(&mut table, &row, &row));
        }
        let key = spec.tail_key(0, 10).unwrap();
        tree.insert_tail(None, key, 10, &table.pages, &mut kv);
        // Shorter same-seed tails share it; longer ones cannot (slots
        // beyond the fill were never written).
        assert!(tree.partial_tail(None, &PromptSpec::from_seed(21, 7), 0, 7).is_some());
        assert!(tree.partial_tail(None, &spec, 0, 10).is_some());
        assert!(
            tree.partial_tail(None, &PromptSpec::from_seed(21, 14), 0, 14).is_none(),
            "a 14-token tail cannot share a 10-token snapshot"
        );
        // Full-page walks never traverse a tail node, even on a padded
        // key collision (seed 0 beyond the fill).
        let zero_pad = PromptSpec {
            segments: vec![
                PromptSegment { seed: 21, len: 10 },
                PromptSegment { seed: 0, len: PAGE_TOKENS - 10 },
            ],
            cache: true,
        };
        assert_eq!(zero_pad.page_key(0).unwrap(), key, "padded keys collide by construction");
        assert!(tree.walk(&zero_pad, 1).is_empty(), "tails are terminal");
    }

    #[test]
    fn evict_frees_only_exclusive_leaves_in_lru_order() {
        let mut kv = PagedKvCache::new(8, 2);
        let mut tree = PrefixTree::new(1);
        // Two independent single-page prefixes.
        let spec_a = PromptSpec::from_seed(1, PAGE_TOKENS);
        let spec_b = PromptSpec::from_seed(2, PAGE_TOKENS);
        let mut table_a = PageTable::default();
        let mut table_b = PageTable::default();
        for t in 0..PAGE_TOKENS {
            let row = [t as f32, 0.0];
            assert!(kv.append(&mut table_a, &row, &row));
            assert!(kv.append(&mut table_b, &row, &row));
        }
        let a = tree.insert_child(None, spec_a.page_key(0).unwrap(), &table_a.pages, &mut kv);
        tree.insert_child(None, spec_b.page_key(0).unwrap(), &table_b.pages, &mut kv);
        // While the filling tables still map the pages, nothing is evictable.
        assert_eq!(tree.evict_lru(&mut kv, 2), 0);
        kv.release(&mut table_a);
        kv.release(&mut table_b);
        // Touch a so b is the LRU leaf.
        tree.walk(&spec_a, 1);
        assert_eq!(tree.evict_lru(&mut kv, 1), 1);
        assert_eq!(tree.n_nodes(), 1);
        assert!(tree.walk(&spec_b, 1).is_empty(), "b was evicted");
        assert_eq!(tree.walk(&spec_a, 1), vec![a], "a survived");
        // Evicting the rest empties the tree and the pool.
        assert_eq!(tree.evict_lru(&mut kv, 1), 1);
        assert_eq!(tree.held_refs(), 0);
        assert_eq!(kv.free_pages(), 8);
    }

    #[test]
    fn hash_blocks_attach_once() {
        let mut kv = PagedKvCache::new(4, 2);
        let mut tree = PrefixTree::new(1);
        let spec = PromptSpec::from_seed(3, PAGE_TOKENS);
        let pages = fill_pages(&mut kv, 1);
        let id = tree.insert_child(None, spec.page_key(0).unwrap(), &pages, &mut kv);
        assert!(tree.hash_block(id, 0).is_none());
        let block = Arc::new(HashBlock::fresh(2));
        tree.set_hash_block(id, 0, block.clone());
        assert!(tree.hash_block(id, 0).is_some());
        // First writer wins; a second attach is dropped.
        let other = Arc::new(HashBlock::fresh(2));
        tree.set_hash_block(id, 0, other);
        assert!(Arc::ptr_eq(&tree.hash_block(id, 0).unwrap(), &block));
    }
}
