//! KV-cache management: paged storage for keys/values plus the SOCKET
//! side-cars (packed hash signatures and value norms) that Algorithm 1
//! caches at prefill and extends at every decode step.

pub mod paged;
pub mod prefix;
pub mod store;

pub use paged::{KvView, PageTable, PagedKvCache, PAGE_TOKENS};
pub use prefix::{PageKey, PrefixTree, PromptSegment, PromptSpec};
pub use store::{HashStore, LayerCache, SequenceCache};
