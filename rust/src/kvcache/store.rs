//! SOCKET cache side-cars: per-sequence packed hash signatures + value
//! norms (Algorithm 1 outputs), layered per attention layer / KV head.

use crate::linalg::Matrix;
use crate::lsh::{KeyHashes, LshParams, SoftScorer};

/// Packed hash signatures for one (layer, head) stream of one sequence.
/// Thin wrapper around [`KeyHashes`] with incremental append.
#[derive(Clone, Debug)]
pub struct HashStore {
    pub hashes: KeyHashes,
}

impl HashStore {
    /// An empty store for `l` tables over a bucket space of size `r`
    /// (= 2^P; appended ids are validated against it).
    pub fn empty(l: usize, r: usize) -> HashStore {
        HashStore { hashes: KeyHashes::empty(l, r) }
    }

    pub fn len(&self) -> usize {
        self.hashes.n
    }

    pub fn is_empty(&self) -> bool {
        self.hashes.n == 0
    }

    /// Bits used by the signatures (paper's memory accounting).
    pub fn bits(&self, params: &LshParams) -> usize {
        self.hashes.n * params.memory().bits_per_token
    }
}

/// All SOCKET state of one attention layer for one sequence: the scorer
/// (shared hyperplanes) plus the hash store.
pub struct LayerCache {
    pub scorer: SoftScorer,
    pub store: HashStore,
}

impl LayerCache {
    pub fn new(params: LshParams, dim: usize, seed: u64) -> LayerCache {
        LayerCache {
            scorer: SoftScorer::new(params, dim, seed),
            store: HashStore::empty(params.l, params.buckets()),
        }
    }

    /// Prefill: hash a block of keys (Algorithm 1).
    pub fn prefill(&mut self, keys: &Matrix, values: &Matrix) {
        let hashed = self.scorer.hash_keys(keys, values);
        if self.store.is_empty() {
            self.store.hashes = hashed;
        } else {
            self.store.hashes.extend_from(&hashed);
        }
    }

    /// Decode: hash the single new token's key and append.
    pub fn append_token(&mut self, key: &[f32], value: &[f32]) {
        let buckets = self.scorer.hasher.simhash().hash_one(key);
        let norm = crate::linalg::l2_norm(value);
        self.store.hashes.push(&buckets, norm);
    }

    /// Top-k selection against the current store (Algorithms 2–4).
    pub fn select(&self, q: &[f32], k: usize) -> Vec<usize> {
        self.scorer.select_top_k(q, &self.store.hashes, k)
    }
}

/// Full-model SOCKET state of one sequence: one [`LayerCache`] per
/// (layer x kv-head) stream.
pub struct SequenceCache {
    pub layers: Vec<LayerCache>,
    pub n_layers: usize,
    pub n_kv_heads: usize,
}

impl SequenceCache {
    pub fn new(params: LshParams, head_dim: usize, n_layers: usize, n_kv_heads: usize, seed: u64) -> SequenceCache {
        let mut layers = Vec::with_capacity(n_layers * n_kv_heads);
        for l in 0..n_layers {
            for h in 0..n_kv_heads {
                // Hyperplanes differ per stream (independent tables).
                layers.push(LayerCache::new(params, head_dim, seed ^ ((l * 1009 + h) as u64) << 17));
            }
        }
        SequenceCache { layers, n_layers, n_kv_heads }
    }

    /// Flat stream index of (layer, head), bounds-asserted once so the
    /// accessors below can skip the slice check.
    #[inline]
    fn stream_index(&self, layer: usize, head: usize) -> usize {
        assert!(layer < self.n_layers, "layer {layer} out of range {}", self.n_layers);
        assert!(head < self.n_kv_heads, "head {head} out of range {}", self.n_kv_heads);
        layer * self.n_kv_heads + head
    }

    #[inline]
    pub fn layer(&mut self, layer: usize, head: usize) -> &mut LayerCache {
        let idx = self.stream_index(layer, head);
        // SAFETY: stream_index asserts layer/head in range, and `layers`
        // holds exactly n_layers * n_kv_heads entries from construction.
        unsafe { self.layers.get_unchecked_mut(idx) }
    }

    #[inline]
    pub fn layer_ref(&self, layer: usize, head: usize) -> &LayerCache {
        let idx = self.stream_index(layer, head);
        // SAFETY: same range argument as `layer`.
        unsafe { self.layers.get_unchecked(idx) }
    }

    /// Total signature memory in bits (≈15% of KV in the paper's setup).
    pub fn total_bits(&self, params: &LshParams) -> usize {
        self.layers.iter().map(|lc| lc.store.bits(params)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn params() -> LshParams {
        LshParams { p: 6, l: 8, tau: 0.5 }
    }

    #[test]
    fn prefill_then_append_consistent() {
        let dim = 16;
        let mut lc = LayerCache::new(params(), dim, 9);
        let mut rng = Pcg64::seeded(1);
        let keys = Matrix::gaussian(10, dim, &mut rng);
        let vals = Matrix::gaussian(10, dim, &mut rng);
        lc.prefill(&keys, &vals);
        assert_eq!(lc.store.len(), 10);
        let k_new = rng.normal_vec(dim);
        let v_new = rng.normal_vec(dim);
        lc.append_token(&k_new, &v_new);
        assert_eq!(lc.store.len(), 11);
        // The appended signature equals a fresh hash of the same key.
        let expect = lc.scorer.hasher.simhash().hash_one(&k_new);
        assert_eq!(lc.store.hashes.key_row(10), expect.as_slice());
    }

    #[test]
    fn incremental_prefill_matches_bulk() {
        let dim = 8;
        let mut rng = Pcg64::seeded(2);
        let keys = Matrix::gaussian(20, dim, &mut rng);
        let vals = Matrix::gaussian(20, dim, &mut rng);
        let mut bulk = LayerCache::new(params(), dim, 5);
        bulk.prefill(&keys, &vals);
        let mut inc = LayerCache::new(params(), dim, 5);
        // two chunks
        let k1 = Matrix::from_vec(12, dim, keys.data[..12 * dim].to_vec());
        let v1 = Matrix::from_vec(12, dim, vals.data[..12 * dim].to_vec());
        let k2 = Matrix::from_vec(8, dim, keys.data[12 * dim..].to_vec());
        let v2 = Matrix::from_vec(8, dim, vals.data[12 * dim..].to_vec());
        inc.prefill(&k1, &v1);
        inc.prefill(&k2, &v2);
        assert_eq!(bulk.store.hashes.to_row_major(), inc.store.hashes.to_row_major());
    }

    #[test]
    fn select_uses_all_tokens() {
        let dim = 16;
        let mut lc = LayerCache::new(params(), dim, 3);
        let mut rng = Pcg64::seeded(3);
        let keys = Matrix::gaussian(30, dim, &mut rng);
        let vals = Matrix::gaussian(30, dim, &mut rng);
        lc.prefill(&keys, &vals);
        let sel = lc.select(&rng.normal_vec(dim), 5);
        assert_eq!(sel.len(), 5);
        assert!(sel.iter().all(|&i| i < 30));
    }

    #[test]
    fn sequence_cache_streams_are_independent() {
        let mut sc = SequenceCache::new(params(), 8, 2, 2, 11);
        let mut rng = Pcg64::seeded(4);
        let keys = Matrix::gaussian(5, 8, &mut rng);
        let vals = Matrix::gaussian(5, 8, &mut rng);
        sc.layer(0, 0).prefill(&keys, &vals);
        assert_eq!(sc.layer_ref(0, 0).store.len(), 5);
        assert_eq!(sc.layer_ref(1, 1).store.len(), 0);
        // Different streams draw different hyperplanes.
        let q = rng.normal_vec(8);
        let b00 = sc.layer_ref(0, 0).scorer.hasher.simhash().hash_one(&q);
        let b11 = sc.layer_ref(1, 1).scorer.hasher.simhash().hash_one(&q);
        assert_ne!(b00, b11);
    }

    #[test]
    fn memory_accounting_scales_with_tokens() {
        let p = params();
        let mut lc = LayerCache::new(p, 8, 1);
        let mut rng = Pcg64::seeded(5);
        let keys = Matrix::gaussian(100, 8, &mut rng);
        let vals = Matrix::gaussian(100, 8, &mut rng);
        lc.prefill(&keys, &vals);
        assert_eq!(lc.store.bits(&p), 100 * 48); // P*L = 48 bits/token
    }
}
