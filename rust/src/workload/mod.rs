//! Synthetic workloads standing in for the paper's datasets.
//!
//! The paper evaluates on RULER (synthetic long-context retrieval) and
//! LongBench (natural long-context tasks) using Llama/Qwen checkpoints.
//! Neither models nor datasets are reachable offline, so we build
//! *planted-signal attention problems* that measure the same quantity
//! the paper's scores measure: whether a sparse scorer retrieves the
//! keys that dominate the attention computation (see DESIGN.md §2 for
//! the substitution argument).
//!
//! * [`ruler`] — per-task analogs of RULER-HARD (nm2, nm3, vt, fwe,
//!   qa1, qa2) with task-matched difficulty profiles.
//! * [`longbench`] — a 15-task proxy suite scored by attention fidelity
//!   and span retrieval under heavy-tailed score distributions.
//! * [`trace`] — request traces (arrivals, context lengths) for the
//!   serving benches.

pub mod longbench;
pub mod ruler;
pub mod trace;

pub use ruler::{RulerInstance, RulerTask, RULER_TASKS};
pub use trace::{Request, TraceConfig, TraceGenerator};
