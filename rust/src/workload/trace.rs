//! Serving request traces for the throughput / latency benches
//! (Fig. 3b/c) and the coordinator integration tests.

use crate::selector::AttentionMode;
use crate::util::rng::Pcg64;

/// A single inference request.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time, milliseconds from trace start.
    pub arrival_ms: f64,
    /// Prompt (context) length in tokens.
    pub context_len: usize,
    /// Decode length in tokens.
    pub decode_len: usize,
    /// Per-request attention mode (`None` = the engine's default). Any
    /// method in `selector::registry` is servable by name.
    pub mode: Option<AttentionMode>,
}

/// Trace parameters.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    /// Mean arrival rate, requests/second (Poisson process).
    pub rate_rps: f64,
    /// Log-uniform context length range.
    pub context_min: usize,
    pub context_max: usize,
    /// Uniform decode length range.
    pub decode_min: usize,
    pub decode_max: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { rate_rps: 4.0, context_min: 1024, context_max: 32 * 1024, decode_min: 16, decode_max: 256 }
    }
}

/// Deterministic Poisson-arrival trace generator.
pub struct TraceGenerator {
    cfg: TraceConfig,
    rng: Pcg64,
    next_id: u64,
    clock_ms: f64,
}

impl TraceGenerator {
    pub fn new(cfg: TraceConfig, seed: u64) -> TraceGenerator {
        TraceGenerator { cfg, rng: Pcg64::new(seed, 31), next_id: 0, clock_ms: 0.0 }
    }

    /// Next request in the trace.
    pub fn next(&mut self) -> Request {
        // Exponential inter-arrival.
        let u = (1.0 - self.rng.next_f64()).max(1e-12);
        self.clock_ms += -u.ln() / self.cfg.rate_rps * 1e3;
        // Log-uniform context length.
        let lo = (self.cfg.context_min as f64).ln();
        let hi = (self.cfg.context_max as f64).ln();
        let ctx = (lo + (hi - lo) * self.rng.next_f64()).exp().round() as usize;
        let dec = self.cfg.decode_min
            + self.rng.below_usize(self.cfg.decode_max - self.cfg.decode_min + 1);
        let req = Request {
            id: self.next_id,
            arrival_ms: self.clock_ms,
            context_len: ctx.clamp(self.cfg.context_min, self.cfg.context_max),
            decode_len: dec,
            mode: None,
        };
        self.next_id += 1;
        req
    }

    /// Generate a fixed-size batch of requests.
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_monotone() {
        let mut g = TraceGenerator::new(TraceConfig::default(), 1);
        let reqs = g.take(100);
        for w in reqs.windows(2) {
            assert!(w[1].arrival_ms >= w[0].arrival_ms);
            assert!(w[1].id == w[0].id + 1);
        }
    }

    #[test]
    fn lengths_within_bounds() {
        let cfg = TraceConfig { context_min: 100, context_max: 1000, decode_min: 5, decode_max: 10, rate_rps: 10.0 };
        let mut g = TraceGenerator::new(cfg, 2);
        for r in g.take(500) {
            assert!((100..=1000).contains(&r.context_len));
            assert!((5..=10).contains(&r.decode_len));
        }
    }

    #[test]
    fn mean_rate_approximates_config() {
        let cfg = TraceConfig { rate_rps: 20.0, ..Default::default() };
        let mut g = TraceGenerator::new(cfg, 3);
        let reqs = g.take(2000);
        let span_s = reqs.last().unwrap().arrival_ms / 1e3;
        let rate = 2000.0 / span_s;
        assert!((rate - 20.0).abs() < 2.0, "rate={rate}");
    }

    #[test]
    fn deterministic_in_seed() {
        let mut a = TraceGenerator::new(TraceConfig::default(), 7);
        let mut b = TraceGenerator::new(TraceConfig::default(), 7);
        assert_eq!(a.take(50), b.take(50));
    }
}
