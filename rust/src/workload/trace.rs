//! Serving request traces for the throughput / latency benches
//! (Fig. 3b/c) and the coordinator integration tests.

use crate::kvcache::{PromptSegment, PromptSpec};
use crate::selector::AttentionMode;
use crate::util::rng::Pcg64;

/// A single inference request.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time, milliseconds from trace start.
    pub arrival_ms: f64,
    /// Prompt (context) length in tokens.
    pub context_len: usize,
    /// Decode length in tokens.
    pub decode_len: usize,
    /// Per-request attention mode (`None` = the engine's default). Any
    /// method in `selector::registry` is servable by name.
    pub mode: Option<AttentionMode>,
    /// Declared prompt content (`None` = anonymous content, ineligible
    /// for prefix-cache sharing). Requests carrying specs with equal
    /// leading segments share KV pages and hash blocks in the engine.
    pub prompt: Option<PromptSpec>,
}

/// Trace parameters.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    /// Mean arrival rate, requests/second (Poisson process).
    pub rate_rps: f64,
    /// Log-uniform context length range.
    pub context_min: usize,
    pub context_max: usize,
    /// Uniform decode length range.
    pub decode_min: usize,
    pub decode_max: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { rate_rps: 4.0, context_min: 1024, context_max: 32 * 1024, decode_min: 16, decode_max: 256 }
    }
}

/// Deterministic Poisson-arrival trace generator.
pub struct TraceGenerator {
    cfg: TraceConfig,
    rng: Pcg64,
    next_id: u64,
    clock_ms: f64,
}

impl TraceGenerator {
    pub fn new(cfg: TraceConfig, seed: u64) -> TraceGenerator {
        TraceGenerator { cfg, rng: Pcg64::new(seed, 31), next_id: 0, clock_ms: 0.0 }
    }

    /// Next request in the trace.
    pub fn next(&mut self) -> Request {
        // Exponential inter-arrival.
        let u = (1.0 - self.rng.next_f64()).max(1e-12);
        self.clock_ms += -u.ln() / self.cfg.rate_rps * 1e3;
        // Log-uniform context length.
        let lo = (self.cfg.context_min as f64).ln();
        let hi = (self.cfg.context_max as f64).ln();
        let ctx = (lo + (hi - lo) * self.rng.next_f64()).exp().round() as usize;
        let dec = self.cfg.decode_min
            + self.rng.below_usize(self.cfg.decode_max - self.cfg.decode_min + 1);
        let req = Request {
            id: self.next_id,
            arrival_ms: self.clock_ms,
            context_len: ctx.clamp(self.cfg.context_min, self.cfg.context_max),
            decode_len: dec,
            mode: None,
            prompt: None,
        };
        self.next_id += 1;
        req
    }

    /// Generate a fixed-size batch of requests.
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next()).collect()
    }
}

/// Shared-prefix trace parameters: a pool of "system prompts" with
/// Zipf-distributed popularity, prepended to otherwise-unique requests —
/// the multi-tenant serving shape prefix caching exists for.
#[derive(Clone, Copy, Debug)]
pub struct SharedPrefixConfig {
    pub base: TraceConfig,
    /// Distinct shared prefixes in the pool.
    pub n_prefixes: usize,
    /// Zipf exponent over prefix popularity (0 = uniform; larger skews
    /// traffic onto the first prefixes).
    pub zipf_s: f64,
    /// Tokens each shared prefix contributes (clamped to the request's
    /// sampled context when it is shorter).
    pub prefix_len: usize,
}

impl Default for SharedPrefixConfig {
    fn default() -> Self {
        SharedPrefixConfig {
            base: TraceConfig::default(),
            n_prefixes: 8,
            zipf_s: 1.1,
            prefix_len: 1024,
        }
    }
}

/// Deterministic shared-prefix trace generator: arrivals and lengths
/// from the base [`TraceGenerator`], plus a two-segment [`PromptSpec`]
/// per request — a Zipf-sampled shared prefix and a per-request-unique
/// suffix.
pub struct SharedPrefixTrace {
    cfg: SharedPrefixConfig,
    inner: TraceGenerator,
    rng: Pcg64,
    /// Zipf CDF over prefix ranks, precomputed at construction.
    cdf: Vec<f64>,
}

impl SharedPrefixTrace {
    pub fn new(cfg: SharedPrefixConfig, seed: u64) -> SharedPrefixTrace {
        assert!(cfg.n_prefixes > 0, "shared-prefix trace needs at least one prefix");
        assert!(cfg.prefix_len > 0, "shared prefixes must be non-empty");
        let weights: Vec<f64> =
            (0..cfg.n_prefixes).map(|k| 1.0 / ((k + 1) as f64).powf(cfg.zipf_s)).collect();
        let total: f64 = weights.iter().sum();
        let mut cum = 0.0;
        let cdf = weights
            .iter()
            .map(|w| {
                cum += w / total;
                cum
            })
            .collect();
        SharedPrefixTrace {
            inner: TraceGenerator::new(cfg.base, seed),
            rng: Pcg64::new(seed, 47),
            cfg,
            cdf,
        }
    }

    /// The stable content seed of prefix rank `k` (what every request
    /// sampling rank `k` shares).
    pub fn prefix_seed(&self, k: usize) -> u64 {
        0x5EED_0000_0000_0000 | k as u64
    }

    /// Next request, with its two-segment prompt spec attached.
    pub fn next(&mut self) -> Request {
        let mut req = self.inner.next();
        let u = self.rng.next_f64();
        let rank = self.cdf.iter().position(|&c| u <= c).unwrap_or(self.cfg.n_prefixes - 1);
        let shared = self.cfg.prefix_len.min(req.context_len);
        let mut segments = vec![PromptSegment { seed: self.prefix_seed(rank), len: shared }];
        if req.context_len > shared {
            // Unique suffix: a seed no other request draws.
            segments.push(PromptSegment {
                seed: 0xA10E_0000_0000_0000 | req.id,
                len: req.context_len - shared,
            });
        }
        req.prompt = Some(PromptSpec { segments, cache: true });
        req
    }

    /// Generate a fixed-size batch of requests.
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_monotone() {
        let mut g = TraceGenerator::new(TraceConfig::default(), 1);
        let reqs = g.take(100);
        for w in reqs.windows(2) {
            assert!(w[1].arrival_ms >= w[0].arrival_ms);
            assert!(w[1].id == w[0].id + 1);
        }
    }

    #[test]
    fn lengths_within_bounds() {
        let cfg = TraceConfig { context_min: 100, context_max: 1000, decode_min: 5, decode_max: 10, rate_rps: 10.0 };
        let mut g = TraceGenerator::new(cfg, 2);
        for r in g.take(500) {
            assert!((100..=1000).contains(&r.context_len));
            assert!((5..=10).contains(&r.decode_len));
        }
    }

    #[test]
    fn mean_rate_approximates_config() {
        let cfg = TraceConfig { rate_rps: 20.0, ..Default::default() };
        let mut g = TraceGenerator::new(cfg, 3);
        let reqs = g.take(2000);
        let span_s = reqs.last().unwrap().arrival_ms / 1e3;
        let rate = 2000.0 / span_s;
        assert!((rate - 20.0).abs() < 2.0, "rate={rate}");
    }

    #[test]
    fn deterministic_in_seed() {
        let mut a = TraceGenerator::new(TraceConfig::default(), 7);
        let mut b = TraceGenerator::new(TraceConfig::default(), 7);
        assert_eq!(a.take(50), b.take(50));
    }

    fn shared_cfg() -> SharedPrefixConfig {
        SharedPrefixConfig {
            base: TraceConfig {
                context_min: 200,
                context_max: 2000,
                decode_min: 2,
                decode_max: 8,
                rate_rps: 10.0,
            },
            n_prefixes: 4,
            zipf_s: 1.2,
            prefix_len: 256,
        }
    }

    #[test]
    fn shared_prefix_prompts_cover_the_context() {
        let mut g = SharedPrefixTrace::new(shared_cfg(), 5);
        for r in g.take(200) {
            let p = r.prompt.as_ref().expect("every request carries a spec");
            assert!(p.cache);
            assert_eq!(p.total_len(), r.context_len, "segments must cover the context");
            assert!(p.segments[0].len <= 256);
            assert!(p.segments.len() <= 2);
        }
    }

    #[test]
    fn shared_prefix_popularity_is_zipf_skewed() {
        let mut g = SharedPrefixTrace::new(shared_cfg(), 11);
        let head = g.prefix_seed(0);
        let reqs = g.take(400);
        let head_share = reqs
            .iter()
            .filter(|r| r.prompt.as_ref().unwrap().segments[0].seed == head)
            .count();
        // Rank 0 carries ~46% of traffic at s=1.2 over 4 prefixes; a
        // uniform draw would give 25%.
        assert!(head_share > 120, "rank-0 prefix drew only {head_share}/400");
        // Every sampled seed is from the pool.
        let pool: Vec<u64> = (0..4).map(|k| g.prefix_seed(k)).collect();
        assert!(reqs.iter().all(|r| pool.contains(&r.prompt.as_ref().unwrap().segments[0].seed)));
    }

    #[test]
    fn shared_prefix_suffixes_are_unique_and_deterministic() {
        let mut a = SharedPrefixTrace::new(shared_cfg(), 9);
        let mut b = SharedPrefixTrace::new(shared_cfg(), 9);
        let reqs = a.take(100);
        assert_eq!(reqs, b.take(100), "same seed, same trace");
        let mut suffix_seeds: Vec<u64> = reqs
            .iter()
            .filter_map(|r| r.prompt.as_ref().unwrap().segments.get(1).map(|s| s.seed))
            .collect();
        let n = suffix_seeds.len();
        suffix_seeds.sort_unstable();
        suffix_seeds.dedup();
        assert_eq!(suffix_seeds.len(), n, "suffix seeds must never collide");
    }
}
