//! Serving request traces for the throughput / latency benches
//! (Fig. 3b/c) and the coordinator integration tests.

use crate::kvcache::{PromptSegment, PromptSpec};
use crate::selector::AttentionMode;
use crate::util::rng::Pcg64;

/// Scheduling priority class. Declared lowest-first so the derived
/// `Ord` matches scheduling order: the scheduler preempts strictly
/// lower classes under page exhaustion and weights admission toward
/// higher ones.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Throughput-oriented background work — first preempted, last
    /// admitted under contention.
    Batch,
    /// The default class.
    #[default]
    Normal,
    /// Latency-sensitive traffic — weighted ahead at admission and
    /// never preempted by lower classes.
    Interactive,
}

impl Priority {
    /// Every class, in `index()` order.
    pub const ALL: [Priority; 3] = [Priority::Batch, Priority::Normal, Priority::Interactive];

    /// Dense table index: 0 = batch, 1 = normal, 2 = interactive.
    pub fn index(self) -> usize {
        self as usize
    }

    /// Wire / metrics label.
    pub fn label(self) -> &'static str {
        match self {
            Priority::Batch => "batch",
            Priority::Normal => "normal",
            Priority::Interactive => "interactive",
        }
    }

    /// Parse a wire name (case-insensitive).
    pub fn parse(name: &str) -> Result<Priority, String> {
        for p in Priority::ALL {
            if name.eq_ignore_ascii_case(p.label()) {
                return Ok(p);
            }
        }
        Err(format!("unknown priority '{name}' (expected interactive, normal, or batch)"))
    }
}

/// A single inference request.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time, milliseconds from trace start.
    pub arrival_ms: f64,
    /// Prompt (context) length in tokens.
    pub context_len: usize,
    /// Decode length in tokens.
    pub decode_len: usize,
    /// Per-request attention mode (`None` = the engine's default). Any
    /// method in `selector::registry` is servable by name.
    pub mode: Option<AttentionMode>,
    /// Declared prompt content (`None` = anonymous content, ineligible
    /// for prefix-cache sharing). Requests carrying specs with equal
    /// leading segments share KV pages and hash blocks in the engine.
    pub prompt: Option<PromptSpec>,
    /// Scheduling class (admission weighting + preemption order).
    pub priority: Priority,
    /// Optional time-to-first-schedule bound, milliseconds from
    /// submission: a request still *waiting* (not yet prefilling) when
    /// its deadline expires is shed with a `deadline_missed` error
    /// instead of occupying the queue.
    pub deadline_ms: Option<f64>,
}

impl Default for Request {
    fn default() -> Self {
        Request {
            id: 0,
            arrival_ms: 0.0,
            context_len: 0,
            decode_len: 0,
            mode: None,
            prompt: None,
            priority: Priority::default(),
            deadline_ms: None,
        }
    }
}

/// Trace parameters.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    /// Mean arrival rate, requests/second (Poisson process).
    pub rate_rps: f64,
    /// Log-uniform context length range.
    pub context_min: usize,
    pub context_max: usize,
    /// Uniform decode length range.
    pub decode_min: usize,
    pub decode_max: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { rate_rps: 4.0, context_min: 1024, context_max: 32 * 1024, decode_min: 16, decode_max: 256 }
    }
}

/// Deterministic Poisson-arrival trace generator.
pub struct TraceGenerator {
    cfg: TraceConfig,
    rng: Pcg64,
    next_id: u64,
    clock_ms: f64,
}

impl TraceGenerator {
    pub fn new(cfg: TraceConfig, seed: u64) -> TraceGenerator {
        TraceGenerator { cfg, rng: Pcg64::new(seed, 31), next_id: 0, clock_ms: 0.0 }
    }

    /// Next request in the trace.
    pub fn next(&mut self) -> Request {
        // Exponential inter-arrival.
        let u = (1.0 - self.rng.next_f64()).max(1e-12);
        self.clock_ms += -u.ln() / self.cfg.rate_rps * 1e3;
        // Log-uniform context length.
        let lo = (self.cfg.context_min as f64).ln();
        let hi = (self.cfg.context_max as f64).ln();
        let ctx = (lo + (hi - lo) * self.rng.next_f64()).exp().round() as usize;
        let dec = self.cfg.decode_min
            + self.rng.below_usize(self.cfg.decode_max - self.cfg.decode_min + 1);
        let req = Request {
            id: self.next_id,
            arrival_ms: self.clock_ms,
            context_len: ctx.clamp(self.cfg.context_min, self.cfg.context_max),
            decode_len: dec,
            ..Request::default()
        };
        self.next_id += 1;
        req
    }

    /// Generate a fixed-size batch of requests.
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next()).collect()
    }
}

/// Shared-prefix trace parameters: a pool of "system prompts" with
/// Zipf-distributed popularity, prepended to otherwise-unique requests —
/// the multi-tenant serving shape prefix caching exists for.
#[derive(Clone, Copy, Debug)]
pub struct SharedPrefixConfig {
    pub base: TraceConfig,
    /// Distinct shared prefixes in the pool.
    pub n_prefixes: usize,
    /// Zipf exponent over prefix popularity (0 = uniform; larger skews
    /// traffic onto the first prefixes).
    pub zipf_s: f64,
    /// Tokens each shared prefix contributes (clamped to the request's
    /// sampled context when it is shorter).
    pub prefix_len: usize,
}

impl Default for SharedPrefixConfig {
    fn default() -> Self {
        SharedPrefixConfig {
            base: TraceConfig::default(),
            n_prefixes: 8,
            zipf_s: 1.1,
            prefix_len: 1024,
        }
    }
}

/// Deterministic shared-prefix trace generator: arrivals and lengths
/// from the base [`TraceGenerator`], plus a two-segment [`PromptSpec`]
/// per request — a Zipf-sampled shared prefix and a per-request-unique
/// suffix.
pub struct SharedPrefixTrace {
    cfg: SharedPrefixConfig,
    inner: TraceGenerator,
    rng: Pcg64,
    /// Zipf CDF over prefix ranks, precomputed at construction.
    cdf: Vec<f64>,
}

impl SharedPrefixTrace {
    pub fn new(cfg: SharedPrefixConfig, seed: u64) -> SharedPrefixTrace {
        assert!(cfg.n_prefixes > 0, "shared-prefix trace needs at least one prefix");
        assert!(cfg.prefix_len > 0, "shared prefixes must be non-empty");
        let weights: Vec<f64> =
            (0..cfg.n_prefixes).map(|k| 1.0 / ((k + 1) as f64).powf(cfg.zipf_s)).collect();
        let total: f64 = weights.iter().sum();
        let mut cum = 0.0;
        let cdf = weights
            .iter()
            .map(|w| {
                cum += w / total;
                cum
            })
            .collect();
        SharedPrefixTrace {
            inner: TraceGenerator::new(cfg.base, seed),
            rng: Pcg64::new(seed, 47),
            cfg,
            cdf,
        }
    }

    /// The stable content seed of prefix rank `k` (what every request
    /// sampling rank `k` shares).
    pub fn prefix_seed(&self, k: usize) -> u64 {
        0x5EED_0000_0000_0000 | k as u64
    }

    /// Next request, with its two-segment prompt spec attached.
    pub fn next(&mut self) -> Request {
        let mut req = self.inner.next();
        let u = self.rng.next_f64();
        let rank = self.cdf.iter().position(|&c| u <= c).unwrap_or(self.cfg.n_prefixes - 1);
        let shared = self.cfg.prefix_len.min(req.context_len);
        let mut segments = vec![PromptSegment { seed: self.prefix_seed(rank), len: shared }];
        if req.context_len > shared {
            // Unique suffix: a seed no other request draws.
            segments.push(PromptSegment {
                seed: 0xA10E_0000_0000_0000 | req.id,
                len: req.context_len - shared,
            });
        }
        req.prompt = Some(PromptSpec { segments, cache: true });
        req
    }

    /// Generate a fixed-size batch of requests.
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next()).collect()
    }
}

/// Saturation-trace parameters: Poisson arrivals, Zipf-distributed
/// context lengths (most requests short, a heavy tail of long
/// prefills), and a mixed-priority population — the overload shape the
/// scheduler's degradation machinery (chunked prefill, preemption,
/// shedding) is measured against.
#[derive(Clone, Copy, Debug)]
pub struct SaturationConfig {
    /// Arrival rate + decode range. The context range bounds the Zipf
    /// length ladder below (log-uniform sampling is *not* used).
    pub base: TraceConfig,
    /// Zipf exponent over the context-length ladder (rank 0 — the
    /// shortest length — is the most popular; larger `s` skews harder).
    pub zipf_s: f64,
    /// Rungs on the geometric context-length ladder between
    /// `context_min` and `context_max`.
    pub context_rungs: usize,
    /// Relative traffic weight of [batch, normal, interactive]
    /// (indexed by [`Priority::index`]; normalized internally).
    pub class_mix: [f64; 3],
    /// Deadline attached to *interactive* requests (`None` = no
    /// deadlines anywhere — nothing can be shed for lateness).
    pub interactive_deadline_ms: Option<f64>,
}

impl Default for SaturationConfig {
    fn default() -> Self {
        SaturationConfig {
            base: TraceConfig::default(),
            zipf_s: 1.1,
            context_rungs: 8,
            class_mix: [1.0, 2.0, 1.0],
            interactive_deadline_ms: None,
        }
    }
}

/// Deterministic saturation trace generator. Arrival times and decode
/// lengths come from the base [`TraceGenerator`]; context lengths are
/// redrawn from a Zipf-popular geometric ladder and each request is
/// assigned a priority class from the configured mix.
pub struct SaturationTrace {
    cfg: SaturationConfig,
    inner: TraceGenerator,
    rng: Pcg64,
    /// Zipf CDF over context-length rungs.
    ctx_cdf: Vec<f64>,
    /// CDF over [batch, normal, interactive].
    class_cdf: [f64; 3],
}

impl SaturationTrace {
    pub fn new(cfg: SaturationConfig, seed: u64) -> SaturationTrace {
        assert!(cfg.context_rungs > 0, "saturation trace needs at least one context rung");
        assert!(cfg.class_mix.iter().all(|&w| w >= 0.0), "class weights must be non-negative");
        let total_mix: f64 = cfg.class_mix.iter().sum();
        assert!(total_mix > 0.0, "class mix must have positive total weight");
        let weights: Vec<f64> =
            (0..cfg.context_rungs).map(|k| 1.0 / ((k + 1) as f64).powf(cfg.zipf_s)).collect();
        let total: f64 = weights.iter().sum();
        let mut cum = 0.0;
        let ctx_cdf = weights
            .iter()
            .map(|w| {
                cum += w / total;
                cum
            })
            .collect();
        let mut class_cdf = [0.0; 3];
        let mut cum = 0.0;
        for (i, &w) in cfg.class_mix.iter().enumerate() {
            cum += w / total_mix;
            class_cdf[i] = cum;
        }
        SaturationTrace {
            inner: TraceGenerator::new(cfg.base, seed),
            rng: Pcg64::new(seed, 61),
            cfg,
            ctx_cdf,
            class_cdf,
        }
    }

    /// Context length of ladder rung `k`: geometric interpolation from
    /// `context_min` (rung 0, most popular) to `context_max`.
    pub fn rung_len(&self, k: usize) -> usize {
        let (lo, hi) = (self.cfg.base.context_min as f64, self.cfg.base.context_max as f64);
        if self.cfg.context_rungs == 1 {
            return lo.round() as usize;
        }
        let t = k as f64 / (self.cfg.context_rungs - 1) as f64;
        (lo * (hi / lo).powf(t)).round() as usize
    }

    /// Next request: Zipf context rung + sampled priority class.
    pub fn next(&mut self) -> Request {
        let mut req = self.inner.next();
        let u = self.rng.next_f64();
        let rung = self.ctx_cdf.iter().position(|&c| u <= c).unwrap_or(self.cfg.context_rungs - 1);
        req.context_len = self.rung_len(rung);
        let u = self.rng.next_f64();
        let class = self.class_cdf.iter().position(|&c| u <= c).unwrap_or(2);
        req.priority = Priority::ALL[class];
        if req.priority == Priority::Interactive {
            req.deadline_ms = self.cfg.interactive_deadline_ms;
        }
        req
    }

    /// Generate a fixed-size batch of requests.
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_monotone() {
        let mut g = TraceGenerator::new(TraceConfig::default(), 1);
        let reqs = g.take(100);
        for w in reqs.windows(2) {
            assert!(w[1].arrival_ms >= w[0].arrival_ms);
            assert!(w[1].id == w[0].id + 1);
        }
    }

    #[test]
    fn lengths_within_bounds() {
        let cfg = TraceConfig { context_min: 100, context_max: 1000, decode_min: 5, decode_max: 10, rate_rps: 10.0 };
        let mut g = TraceGenerator::new(cfg, 2);
        for r in g.take(500) {
            assert!((100..=1000).contains(&r.context_len));
            assert!((5..=10).contains(&r.decode_len));
        }
    }

    #[test]
    fn mean_rate_approximates_config() {
        let cfg = TraceConfig { rate_rps: 20.0, ..Default::default() };
        let mut g = TraceGenerator::new(cfg, 3);
        let reqs = g.take(2000);
        let span_s = reqs.last().unwrap().arrival_ms / 1e3;
        let rate = 2000.0 / span_s;
        assert!((rate - 20.0).abs() < 2.0, "rate={rate}");
    }

    #[test]
    fn deterministic_in_seed() {
        let mut a = TraceGenerator::new(TraceConfig::default(), 7);
        let mut b = TraceGenerator::new(TraceConfig::default(), 7);
        assert_eq!(a.take(50), b.take(50));
    }

    fn shared_cfg() -> SharedPrefixConfig {
        SharedPrefixConfig {
            base: TraceConfig {
                context_min: 200,
                context_max: 2000,
                decode_min: 2,
                decode_max: 8,
                rate_rps: 10.0,
            },
            n_prefixes: 4,
            zipf_s: 1.2,
            prefix_len: 256,
        }
    }

    #[test]
    fn shared_prefix_prompts_cover_the_context() {
        let mut g = SharedPrefixTrace::new(shared_cfg(), 5);
        for r in g.take(200) {
            let p = r.prompt.as_ref().expect("every request carries a spec");
            assert!(p.cache);
            assert_eq!(p.total_len(), r.context_len, "segments must cover the context");
            assert!(p.segments[0].len <= 256);
            assert!(p.segments.len() <= 2);
        }
    }

    #[test]
    fn shared_prefix_popularity_is_zipf_skewed() {
        let mut g = SharedPrefixTrace::new(shared_cfg(), 11);
        let head = g.prefix_seed(0);
        let reqs = g.take(400);
        let head_share = reqs
            .iter()
            .filter(|r| r.prompt.as_ref().unwrap().segments[0].seed == head)
            .count();
        // Rank 0 carries ~46% of traffic at s=1.2 over 4 prefixes; a
        // uniform draw would give 25%.
        assert!(head_share > 120, "rank-0 prefix drew only {head_share}/400");
        // Every sampled seed is from the pool.
        let pool: Vec<u64> = (0..4).map(|k| g.prefix_seed(k)).collect();
        assert!(reqs.iter().all(|r| pool.contains(&r.prompt.as_ref().unwrap().segments[0].seed)));
    }

    #[test]
    fn shared_prefix_suffixes_are_unique_and_deterministic() {
        let mut a = SharedPrefixTrace::new(shared_cfg(), 9);
        let mut b = SharedPrefixTrace::new(shared_cfg(), 9);
        let reqs = a.take(100);
        assert_eq!(reqs, b.take(100), "same seed, same trace");
        let mut suffix_seeds: Vec<u64> = reqs
            .iter()
            .filter_map(|r| r.prompt.as_ref().unwrap().segments.get(1).map(|s| s.seed))
            .collect();
        let n = suffix_seeds.len();
        suffix_seeds.sort_unstable();
        suffix_seeds.dedup();
        assert_eq!(suffix_seeds.len(), n, "suffix seeds must never collide");
    }

    #[test]
    fn priority_orders_parses_and_labels() {
        assert!(Priority::Batch < Priority::Normal);
        assert!(Priority::Normal < Priority::Interactive);
        assert_eq!(Priority::default(), Priority::Normal);
        for p in Priority::ALL {
            assert_eq!(Priority::parse(p.label()).unwrap(), p);
            assert_eq!(Priority::ALL[p.index()], p);
        }
        assert_eq!(Priority::parse("INTERACTIVE").unwrap(), Priority::Interactive);
        assert!(Priority::parse("urgent").is_err());
    }

    fn sat_cfg() -> SaturationConfig {
        SaturationConfig {
            base: TraceConfig {
                rate_rps: 50.0,
                context_min: 64,
                context_max: 4096,
                decode_min: 2,
                decode_max: 8,
            },
            zipf_s: 1.2,
            context_rungs: 6,
            class_mix: [1.0, 2.0, 1.0],
            interactive_deadline_ms: Some(500.0),
        }
    }

    #[test]
    fn saturation_trace_is_deterministic_and_in_bounds() {
        let mut a = SaturationTrace::new(sat_cfg(), 13);
        let mut b = SaturationTrace::new(sat_cfg(), 13);
        let reqs = a.take(300);
        assert_eq!(reqs, b.take(300), "same seed, same trace");
        let rungs: Vec<usize> = (0..6).map(|k| a.rung_len(k)).collect();
        for r in &reqs {
            assert!(rungs.contains(&r.context_len), "ctx {} off the ladder", r.context_len);
            assert!((2..=8).contains(&r.decode_len));
            match r.priority {
                Priority::Interactive => assert_eq!(r.deadline_ms, Some(500.0)),
                _ => assert_eq!(r.deadline_ms, None, "only interactive carries a deadline"),
            }
        }
        for w in reqs.windows(2) {
            assert!(w[1].arrival_ms >= w[0].arrival_ms);
        }
    }

    #[test]
    fn saturation_context_lengths_are_zipf_skewed_and_classes_mixed() {
        let mut g = SaturationTrace::new(sat_cfg(), 29);
        let shortest = g.rung_len(0);
        let reqs = g.take(600);
        let short_share = reqs.iter().filter(|r| r.context_len == shortest).count();
        // Rank 0 carries ~38% of traffic at s=1.2 over 6 rungs; uniform
        // would give ~17%.
        assert!(short_share > 150, "shortest rung drew only {short_share}/600");
        let mut by_class = [0usize; 3];
        for r in &reqs {
            by_class[r.priority.index()] += 1;
        }
        assert!(by_class.iter().all(|&n| n > 60), "all classes must appear: {by_class:?}");
        assert!(
            by_class[Priority::Normal.index()] > by_class[Priority::Batch.index()],
            "normal is weighted 2x batch: {by_class:?}"
        );
    }
}
