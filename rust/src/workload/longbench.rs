//! LongBench proxy suite (Tables 4, 5, 9).
//!
//! LongBench's 15 natural-language tasks cannot run offline; the proxy
//! scores each task as a ceiling-scaled mixture of two measurable
//! components that jointly determine downstream accuracy for a sparse
//! attention method:
//!
//! * **retrieval** — needle recall (QA/retrieval-style tasks live or die
//!   by whether answer spans are attended);
//! * **fidelity** — 1 − relative L2 error of the sparse attention output
//!   vs dense (summarization/code tasks depend on broad, diffuse
//!   attention where output fidelity matters more than any single span).
//!
//! Per-task weights/ceilings follow each task's character; e.g. GOV/
//! QMSUM/MNews are fidelity-heavy, Retrieval/Trivia are needle-heavy.

use crate::attention::{dense_attention, sparse_attention};
use crate::metrics::output_relative_error;
use crate::util::rng::Pcg64;
use crate::workload::ruler::RulerTask;

/// A LongBench-analog task profile.
#[derive(Clone, Copy, Debug)]
pub struct LongBenchTask {
    pub name: &'static str,
    /// Weight of the retrieval component (rest = fidelity).
    pub retrieval_weight: f64,
    /// Underlying needle profile.
    pub needles: usize,
    pub needle_cos: f32,
    /// Dense-model ceiling on this task (matches Table 4's baseline row
    /// for Llama-3.1-8B so the proxy reports on the paper's scale).
    pub ceiling: f64,
}

/// The 15 LongBench tasks of Tables 4/5/9 (ceilings = Table 4 baseline).
pub const LONGBENCH_TASKS: [LongBenchTask; 15] = [
    LongBenchTask { name: "NQA", retrieval_weight: 0.7, needles: 4, needle_cos: 0.66, ceiling: 31.05 },
    LongBenchTask { name: "QAS", retrieval_weight: 0.7, needles: 4, needle_cos: 0.68, ceiling: 44.67 },
    LongBenchTask { name: "MFQA", retrieval_weight: 0.6, needles: 6, needle_cos: 0.70, ceiling: 55.97 },
    LongBenchTask { name: "HPQA", retrieval_weight: 0.7, needles: 5, needle_cos: 0.67, ceiling: 55.40 },
    LongBenchTask { name: "WIKI", retrieval_weight: 0.6, needles: 5, needle_cos: 0.69, ceiling: 55.13 },
    LongBenchTask { name: "MUS", retrieval_weight: 0.7, needles: 6, needle_cos: 0.63, ceiling: 29.41 },
    LongBenchTask { name: "GOV", retrieval_weight: 0.2, needles: 16, needle_cos: 0.60, ceiling: 34.77 },
    LongBenchTask { name: "QMSUM", retrieval_weight: 0.2, needles: 16, needle_cos: 0.58, ceiling: 25.14 },
    LongBenchTask { name: "MNews", retrieval_weight: 0.2, needles: 12, needle_cos: 0.60, ceiling: 26.90 },
    LongBenchTask { name: "LCC", retrieval_weight: 0.4, needles: 8, needle_cos: 0.72, ceiling: 59.80 },
    LongBenchTask { name: "Trivia", retrieval_weight: 0.8, needles: 3, needle_cos: 0.74, ceiling: 91.16 },
    LongBenchTask { name: "SamSUM", retrieval_weight: 0.3, needles: 10, needle_cos: 0.64, ceiling: 43.24 },
    LongBenchTask { name: "Count", retrieval_weight: 0.5, needles: 20, needle_cos: 0.55, ceiling: 10.0 },
    LongBenchTask { name: "Retrieval", retrieval_weight: 0.9, needles: 1, needle_cos: 0.85, ceiling: 99.0 },
    LongBenchTask { name: "Repo", retrieval_weight: 0.5, needles: 8, needle_cos: 0.66, ceiling: 53.92 },
];

impl LongBenchTask {
    /// Evaluate a selector on this task: mean over `instances`.
    pub fn evaluate(
        &self,
        selector: &mut dyn crate::selector::Selector,
        n: usize,
        dim: usize,
        k: usize,
        instances: usize,
        seed: u64,
    ) -> f64 {
        // Reuse the RULER generator with this task's needle profile.
        let gen_task = RulerTask {
            name: self.name,
            n_needles: self.needles,
            needle_cos: self.needle_cos,
            n_distractors: 3 * self.needles + 16,
            distractor_cos: (self.needle_cos - 0.08).max(0.2),
            ceiling: 100.0,
        };
        let mut total = 0.0;
        for i in 0..instances {
            let mut rng = Pcg64::new(seed, i as u64 * 104729 + 3);
            let inst = gen_task.generate(n, dim, &mut rng);
            selector.build_dense(&inst.keys, &inst.values);
            let selected = selector.select(&inst.query, k).expect("selector built");
            // Retrieval component: needle recall.
            let recall = gen_task.score(&selected, &inst.needles) / 100.0;
            // Fidelity component: sparse-vs-dense output error with the
            // selected set (plus standard scale 1/sqrt(d)).
            let scale = 1.0 / (dim as f32).sqrt();
            let yd = dense_attention(&inst.query, &inst.keys, &inst.values, scale);
            let ys = sparse_attention(&inst.query, &inst.keys, &inst.values, &selected, scale);
            let fid = (1.0 - output_relative_error(&ys, &yd)).max(0.0);
            total += self.ceiling
                * (self.retrieval_weight * recall + (1.0 - self.retrieval_weight) * fid);
        }
        total / instances as f64
    }
}

pub fn task_by_name(name: &str) -> Option<LongBenchTask> {
    LONGBENCH_TASKS.iter().find(|t| t.name == name).copied()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selector::OracleSelector;

    #[test]
    fn fifteen_unique_tasks() {
        let mut names: Vec<&str> = LONGBENCH_TASKS.iter().map(|t| t.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 15);
    }

    #[test]
    fn oracle_near_ceiling_on_retrieval_task() {
        let t = task_by_name("Retrieval").unwrap();
        let mut oracle = OracleSelector::new(false);
        let score = t.evaluate(&mut oracle, 256, 32, 64, 4, 11);
        assert!(score > 0.8 * t.ceiling, "score={score} ceiling={}", t.ceiling);
    }

    #[test]
    fn bigger_budget_never_much_worse() {
        let t = task_by_name("GOV").unwrap();
        let mut oracle = OracleSelector::new(false);
        let small = t.evaluate(&mut oracle, 256, 32, 8, 4, 5);
        let large = t.evaluate(&mut oracle, 256, 32, 128, 4, 5);
        assert!(large >= small - 1.0, "small={small} large={large}");
    }
}
