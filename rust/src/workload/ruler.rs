//! RULER-HARD analogs: planted-needle attention retrieval tasks.
//!
//! Each task generates a long context of key/value vectors in which a
//! small set of **needle** tokens carries the answer: needle keys have a
//! task-specific cosine similarity to the query, embedded among
//! *distractors* (near-needle similarity — multi-key confusion) and
//! diffuse background tokens. A sparse method's task score is the
//! (ceiling-scaled) recall of the needles within its selected set — the
//! exact quantity RULER's string-matching accuracy measures one level
//! up the stack: if the needle tokens are not attended, the model
//! cannot emit the answer.
//!
//! Task profiles are tuned so that *dense/oracle* attains roughly the
//! paper's dense baselines (e.g. qa2 ≈ 50 even when retrieval is easy:
//! the ceiling encodes the model's intrinsic task ability).

use crate::linalg::Matrix;
use crate::testing::gen;
use crate::util::rng::Pcg64;

/// A RULER-analog task profile.
#[derive(Clone, Copy, Debug)]
pub struct RulerTask {
    pub name: &'static str,
    /// Number of answer-carrying tokens.
    pub n_needles: usize,
    /// Cosine similarity of needle keys to the query.
    pub needle_cos: f32,
    /// Number of distractor tokens (confusable near-needles).
    pub n_distractors: usize,
    /// Cosine similarity of distractors to the query.
    pub distractor_cos: f32,
    /// Max achievable score (dense-model task ability).
    pub ceiling: f64,
}

/// The six RULER-HARD-32K tasks of Table 1.
///
/// Profiles ordered by observed difficulty in the paper: nm2/nm3 are
/// single-needle multikey tasks (nm3 with tighter margin — it is the
/// first to collapse), vt tracks a 5-hop chain, fwe needs ~30 frequent
/// tokens, qa1/qa2 are QA tasks whose dense ceiling is itself limited.
pub const RULER_TASKS: [RulerTask; 6] = [
    RulerTask { name: "nm2", n_needles: 1, needle_cos: 0.82, n_distractors: 24, distractor_cos: 0.58, ceiling: 100.0 },
    RulerTask { name: "nm3", n_needles: 1, needle_cos: 0.74, n_distractors: 48, distractor_cos: 0.60, ceiling: 100.0 },
    RulerTask { name: "vt", n_needles: 5, needle_cos: 0.78, n_distractors: 32, distractor_cos: 0.55, ceiling: 98.0 },
    RulerTask { name: "fwe", n_needles: 30, needle_cos: 0.72, n_distractors: 60, distractor_cos: 0.52, ceiling: 94.0 },
    RulerTask { name: "qa1", n_needles: 4, needle_cos: 0.70, n_distractors: 80, distractor_cos: 0.58, ceiling: 85.0 },
    RulerTask { name: "qa2", n_needles: 4, needle_cos: 0.62, n_distractors: 120, distractor_cos: 0.55, ceiling: 55.0 },
];

/// Tokens per planted span (a RULER needle is a sentence, not a token).
pub const SPAN_LEN: usize = 4;

/// One generated task instance.
pub struct RulerInstance {
    pub keys: Matrix,
    pub values: Matrix,
    pub query: Vec<f32>,
    /// Token indices of the needles.
    pub needles: Vec<usize>,
}

impl RulerTask {
    pub fn by_name(name: &str) -> Option<RulerTask> {
        RULER_TASKS.iter().find(|t| t.name == name).copied()
    }

    /// Generate an instance with `n` context tokens of dimension `dim`.
    ///
    /// Realism notes (these matter for baseline fairness):
    /// * background keys follow an AR(1) process over positions
    ///   (adjacent tokens are correlated, like real hidden states) — this
    ///   is what makes page-level methods (Quest) viable;
    /// * each needle/distractor is a contiguous *span* of
    ///   [`SPAN_LEN`] tokens (RULER needles are sentences); the needle
    ///   set contains every token of every needle span.
    pub fn generate(&self, n: usize, dim: usize, rng: &mut Pcg64) -> RulerInstance {
        let span = SPAN_LEN;
        // Needle/distractor counts are in *tokens*; group them into
        // contiguous spans (a RULER needle is a sentence). Distractor
        // density scales with context length (task profiles are tuned
        // at 2048 tokens) so difficulty is roughly n-invariant.
        let mult = (n / 2048).max(1);
        let needle_spans = self.n_needles.div_ceil(span);
        let distractor_spans = (self.n_distractors * mult).div_ceil(span);
        let n_special = needle_spans + distractor_spans;
        assert!(n > n_special * span * 2, "context too small for task");
        let query = gen::unit_vec(rng, dim);
        let mut keys = Matrix::zeros(n, dim);
        let mut values = Matrix::zeros(n, dim);
        let scale = (dim as f32).sqrt();
        // Background: AR(1) token locality, unit-direction keys at
        // norm ~sqrt(d) like the planted spans.
        let rho = 0.85f32;
        let mut prev = gen::unit_vec(rng, dim);
        for j in 0..n {
            let noise = gen::unit_vec(rng, dim);
            let mut dir = vec![0.0f32; dim];
            for c in 0..dim {
                dir[c] = rho * prev[c] + (1.0 - rho * rho).sqrt() * noise[c];
            }
            crate::linalg::normalize(&mut dir);
            for c in 0..dim {
                keys.set(j, c, dir[c] * scale);
            }
            prev = dir;
            let v = rng.normal_vec(dim);
            values.row_mut(j).copy_from_slice(&v);
        }
        // Pick non-overlapping span starts.
        let slots = n / span;
        let starts = rng.sample_indices(slots, n_special);
        let (needle_slots, distractor_slots) = starts.split_at(needle_spans);
        let mut needles = Vec::with_capacity(needle_spans * span);
        for &slot in needle_slots {
            // Slight per-needle cosine jitter models phrasing variation.
            let base = (self.needle_cos + rng.range_f32(-0.03, 0.03)).clamp(0.05, 0.99);
            for t in 0..span {
                let j = slot * span + t;
                let cos = (base + rng.range_f32(-0.02, 0.02)).clamp(0.05, 0.99);
                let k = gen::key_with_cosine(rng, &query, cos);
                for c in 0..dim {
                    keys.set(j, c, k[c] * scale);
                }
                // Answer tokens carry above-average value norm.
                let mut v = rng.normal_vec(dim);
                for x in v.iter_mut() {
                    *x *= 1.4;
                }
                values.row_mut(j).copy_from_slice(&v);
                needles.push(j);
            }
        }
        for &slot in distractor_slots {
            let base = (self.distractor_cos + rng.range_f32(-0.05, 0.05)).clamp(0.0, 0.95);
            for t in 0..span {
                let j = slot * span + t;
                let k = gen::key_with_cosine(rng, &query, base);
                for c in 0..dim {
                    keys.set(j, c, k[c] * scale);
                }
            }
        }
        needles.sort_unstable();
        RulerInstance { keys, values, query, needles }
    }

    /// Score a selection: ceiling-scaled needle recall.
    pub fn score(&self, selected: &[usize], needles: &[usize]) -> f64 {
        if needles.is_empty() {
            return self.ceiling;
        }
        let sel: std::collections::HashSet<usize> = selected.iter().copied().collect();
        let hit = needles.iter().filter(|i| sel.contains(i)).count();
        self.ceiling * hit as f64 / needles.len() as f64
    }
}

/// Evaluate a [`crate::selector::Selector`] on a task: mean score over
/// `instances` independently generated instances of `n` tokens.
pub fn evaluate_selector(
    task: &RulerTask,
    selector: &mut dyn crate::selector::Selector,
    n: usize,
    dim: usize,
    k: usize,
    instances: usize,
    seed: u64,
) -> f64 {
    let mut total = 0.0;
    for i in 0..instances {
        let mut rng = Pcg64::new(seed, i as u64 * 7919 + 1);
        let inst = task.generate(n, dim, &mut rng);
        selector.build_dense(&inst.keys, &inst.values);
        let selected = selector.select(&inst.query, k).expect("selector built");
        total += task.score(&selected, &inst.needles);
    }
    total / instances as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selector::OracleSelector;

    #[test]
    fn tasks_have_unique_names() {
        let mut names: Vec<&str> = RULER_TASKS.iter().map(|t| t.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 6);
        assert!(RulerTask::by_name("vt").is_some());
        assert!(RulerTask::by_name("bogus").is_none());
    }

    #[test]
    fn instance_shape_and_needles() {
        let mut rng = Pcg64::seeded(1);
        let t = RulerTask::by_name("vt").unwrap();
        let inst = t.generate(512, 32, &mut rng);
        assert_eq!(inst.keys.rows, 512);
        // vt has 5 needle tokens -> ceil(5/4)=2 spans -> 8 tokens.
        assert_eq!(inst.needles.len(), 5usize.div_ceil(SPAN_LEN) * SPAN_LEN);
        assert!(inst.needles.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn needles_have_high_cosine() {
        let mut rng = Pcg64::seeded(2);
        let t = RULER_TASKS[0]; // nm2
        let inst = t.generate(256, 48, &mut rng);
        let j = inst.needles[0];
        let k = inst.keys.row(j);
        let cos = crate::linalg::dot(k, &inst.query) / crate::linalg::l2_norm(k);
        assert!(cos > 0.7, "needle cos={cos}");
    }

    #[test]
    fn score_is_ceiling_scaled_recall() {
        let t = RULER_TASKS[2]; // vt, 5 needles, ceiling 98
        assert_eq!(t.score(&[1, 2, 3, 4, 5], &[1, 2, 3, 4, 5]), 98.0);
        assert!((t.score(&[1, 2], &[1, 2, 3, 4, 5]) - 98.0 * 0.4).abs() < 1e-9);
        assert_eq!(t.score(&[9], &[1]), 0.0);
    }

    #[test]
    fn oracle_scores_near_ceiling_on_easy_task() {
        let t = RulerTask::by_name("nm2").unwrap();
        let mut oracle = OracleSelector::new(false);
        let score = evaluate_selector(&t, &mut oracle, 512, 48, 64, 8, 42);
        assert!(score > 0.85 * t.ceiling, "oracle score {score} vs ceiling {}", t.ceiling);
    }

    #[test]
    fn tiny_budget_hurts() {
        let t = RulerTask::by_name("qa2").unwrap();
        let mut oracle = OracleSelector::new(false);
        let generous = evaluate_selector(&t, &mut oracle, 512, 48, 128, 6, 7);
        let starved = evaluate_selector(&t, &mut oracle, 512, 48, 2, 6, 7);
        assert!(generous > starved, "generous={generous} starved={starved}");
    }
}
