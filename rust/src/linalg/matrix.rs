//! Row-major matrix wrapper used for keys/values/projection planes.

use crate::util::rng::Pcg64;

/// Row-major dense matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    /// i.i.d. standard Gaussian entries — the SimHash hyperplane draw.
    pub fn gaussian(rows: usize, cols: usize, rng: &mut Pcg64) -> Matrix {
        Matrix { rows, cols, data: rng.normal_vec(rows * cols) }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// `self (rows x cols) * v (cols)` -> rows.
    pub fn matvec(&self, v: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.rows];
        super::ops::matvec(&self.data, self.rows, self.cols, v, &mut out);
        out
    }

    /// Dense matmul (small sizes only; used in tests and reference paths).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.get(i, k);
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out.data[i * other.cols + j] += a * other.get(k, j);
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.set(c, r, self.get(r, c));
            }
        }
        out
    }

    /// Per-row L2 norms.
    pub fn row_norms(&self) -> Vec<f32> {
        (0..self.rows).map(|r| super::ops::l2_norm(self.row(r))).collect()
    }

    /// Spectral norm estimate by power iteration (used for ||V||_2 in the
    /// Theorem-3 validation bench).
    pub fn spectral_norm(&self, iters: usize, rng: &mut Pcg64) -> f32 {
        let mut v = rng.normal_vec(self.cols);
        super::ops::normalize(&mut v);
        for _ in 0..iters {
            // v <- A^T A v / ||.|| (power iteration on A^T A).
            let u = self.matvec(&v);
            let mut vt = vec![0.0; self.cols];
            for r in 0..self.rows {
                let ur = u[r];
                if ur != 0.0 {
                    for c in 0..self.cols {
                        vt[c] += ur * self.get(r, c);
                    }
                }
            }
            let n = super::ops::l2_norm(&vt);
            if n == 0.0 {
                return 0.0;
            }
            for c in 0..self.cols {
                vt[c] /= n;
            }
            v = vt;
        }
        // sigma = ||A v|| at the converged right singular vector.
        let u = self.matvec(&v);
        super::ops::l2_norm(&u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let id = Matrix::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(a.matmul(&id), a);
    }

    #[test]
    fn matvec_known() {
        let a = Matrix::from_vec(2, 3, vec![1.0, 0.0, 2.0, 0.0, 1.0, -1.0]);
        let y = a.matvec(&[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![7.0, -1.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seeded(2);
        let a = Matrix::gaussian(3, 5, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn spectral_norm_of_scaled_identity() {
        let mut rng = Pcg64::seeded(4);
        let mut a = Matrix::zeros(4, 4);
        for i in 0..4 {
            a.set(i, i, 3.0);
        }
        let s = a.spectral_norm(50, &mut rng);
        assert!((s - 3.0).abs() < 1e-3, "s={s}");
    }

    #[test]
    fn row_norms_match() {
        let a = Matrix::from_vec(2, 2, vec![3.0, 4.0, 0.0, 2.0]);
        let n = a.row_norms();
        assert!((n[0] - 5.0).abs() < 1e-6);
        assert!((n[1] - 2.0).abs() < 1e-6);
    }
}
