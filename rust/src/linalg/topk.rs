//! Top-k selection.
//!
//! The decode hot path selects the k highest-scoring keys out of N
//! (N up to 128K+). We keep a bounded min-heap of size k: O(N log k),
//! no full sort, no allocation beyond the heap itself.

use crate::util::pool::ThresholdCell;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// (score, index) entry ordered so the BinaryHeap acts as a *min*-heap on
/// score (Reverse semantics folded into Ord).
#[derive(Clone, Copy, Debug)]
struct Entry {
    score: f32,
    index: usize,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.score == other.score && self.index == other.index
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse so that the heap's "max" is the smallest score; ties
        // broken by larger index first so pops are deterministic.
        other
            .score
            .partial_cmp(&self.score)
            .unwrap_or(Ordering::Equal)
            .then_with(|| self.index.cmp(&other.index))
    }
}

/// Streaming bounded top-k selector.
#[derive(Debug)]
pub struct TopK {
    k: usize,
    heap: BinaryHeap<Entry>,
}

impl TopK {
    pub fn new(k: usize) -> TopK {
        assert!(k > 0, "k must be positive");
        TopK { k, heap: BinaryHeap::with_capacity(k + 1) }
    }

    /// Offer a candidate. NaN scores are ignored.
    ///
    /// The replacement test is **tie-aware**: a candidate enters a full
    /// heap when it beats the current worst entry under the total order
    /// (score desc, index asc) — strictly higher score, or an equal
    /// score with a lower index. That makes the held set the exact
    /// top-k of everything pushed so far *regardless of push order*,
    /// which is what lets the parallel / bound-ordered block walks
    /// select bit-identically to the storage-order scan. For
    /// ascending-index feeds (every pre-existing caller) the tie clause
    /// can never fire, so behaviour there is unchanged.
    #[inline]
    pub fn push(&mut self, score: f32, index: usize) {
        if score.is_nan() {
            return;
        }
        if self.heap.len() < self.k {
            self.heap.push(Entry { score, index });
        } else if let Some(min) = self.heap.peek() {
            if score > min.score || (score == min.score && index < min.index) {
                self.heap.pop();
                self.heap.push(Entry { score, index });
            }
        }
    }

    /// Current threshold (smallest kept score), if k candidates are held.
    pub fn threshold(&self) -> Option<f32> {
        if self.heap.len() == self.k {
            self.heap.peek().map(|e| e.score)
        } else {
            None
        }
    }

    /// The worst held entry under the total order (score desc, index
    /// asc) — the lowest kept score, largest index among equals — if k
    /// candidates are held. The tie-break half is what the
    /// order-independent pruning predicate needs.
    pub fn worst(&self) -> Option<(f32, usize)> {
        if self.heap.len() == self.k {
            self.heap.peek().map(|e| (e.score, e.index))
        } else {
            None
        }
    }

    /// Reset to an empty selector of size `k`, keeping the heap's
    /// allocation — the per-worker scratch reuse entry point.
    pub fn reset(&mut self, k: usize) {
        assert!(k > 0, "k must be positive");
        self.k = k;
        self.heap.clear();
    }

    /// Drain the held (index, score) pairs in unspecified order into a
    /// reusable buffer (cleared first), keeping both the heap's and the
    /// buffer's allocations. Order-independent consumers (the parallel
    /// walk's exact merge) use this instead of the consuming
    /// [`TopK::into_sorted`] so the decode hot path stays allocation-free
    /// at steady state.
    pub fn drain_into(&mut self, out: &mut Vec<(usize, f32)>) {
        out.clear();
        out.extend(self.heap.drain().map(|e| (e.index, e.score)));
    }

    /// The selection size this heap was built for.
    pub fn k(&self) -> usize {
        self.k
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Extract (index, score) pairs sorted by descending score.
    pub fn into_sorted(self) -> Vec<(usize, f32)> {
        let mut v: Vec<(usize, f32)> = self.heap.into_iter().map(|e| (e.index, e.score)).collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(Ordering::Equal).then(a.0.cmp(&b.0)));
        v
    }

    /// Extract just the indices, sorted by descending score.
    pub fn into_indices(self) -> Vec<usize> {
        self.into_sorted().into_iter().map(|(i, _)| i).collect()
    }
}

/// Branch-and-bound top-k: a [`TopK`] plus the streaming k-th-score
/// pruning threshold. Block-pruned scoring kernels test each candidate
/// block's admissible score upper bound against [`BoundHeap::prunes`]
/// and skip the block when no member could enter the selection — the
/// skipped keys are exactly keys a plain `TopK` fed every score would
/// have rejected (its `push` requires a *strictly* greater score), so
/// the surviving selection is bit-identical to the exhaustive one.
#[derive(Debug)]
pub struct BoundHeap {
    tk: TopK,
}

impl BoundHeap {
    pub fn new(k: usize) -> BoundHeap {
        BoundHeap { tk: TopK::new(k) }
    }

    /// Offer a candidate (NaN scores are ignored, as in [`TopK`]).
    #[inline]
    pub fn push(&mut self, score: f32, index: usize) {
        self.tk.push(score, index);
    }

    /// Whether k candidates are held — only then may anything be
    /// pruned (an unfilled heap accepts every score, even -inf).
    #[inline]
    pub fn is_full(&self) -> bool {
        self.tk.len() == self.tk.k()
    }

    /// The current pruning threshold: the k-th best score seen so far,
    /// or -inf while fewer than k candidates are held.
    #[inline]
    pub fn bound(&self) -> f32 {
        self.tk.threshold().unwrap_or(f32::NEG_INFINITY)
    }

    /// True when a candidate set whose scores are all `<= ub` cannot
    /// change the selection: the heap is full and even `ub` itself
    /// would be rejected (push requires strictly beating the
    /// threshold, so `ub == threshold` still prunes). Only exact for
    /// ascending-index traversals — an `ub == threshold` block visited
    /// *out of order* could still hold an index-tie winner; those
    /// traversals use [`BoundHeap::prunes_at`] instead.
    #[inline]
    pub fn prunes(&self, ub: f32) -> bool {
        match self.tk.threshold() {
            Some(t) => ub <= t,
            None => false,
        }
    }

    /// Traversal-order-independent pruning predicate: true when no
    /// candidate from a block whose scores are all `<= ub` and whose
    /// indices are all `>= base` can enter the selection. The best
    /// conceivable block member is `(ub, base)`; if that does not beat
    /// the worst kept entry under (score desc, index asc), nothing in
    /// the block does. For ascending-index traversals (`base` beyond
    /// every held index) this degrades to exactly [`BoundHeap::prunes`].
    #[inline]
    pub fn prunes_at(&self, ub: f32, base: usize) -> bool {
        match self.tk.worst() {
            Some((w, i)) => ub < w || (ub == w && base >= i),
            None => false,
        }
    }

    /// The worst held entry under (score desc, index asc), if full.
    #[inline]
    pub fn worst(&self) -> Option<(f32, usize)> {
        self.tk.worst()
    }

    /// Reset to an empty heap of size `k`, keeping allocations.
    pub fn reset(&mut self, k: usize) {
        self.tk.reset(k);
    }

    /// Drain the held (index, score) pairs in unspecified order into a
    /// reusable buffer (see [`TopK::drain_into`]).
    pub fn drain_into(&mut self, out: &mut Vec<(usize, f32)>) {
        self.tk.drain_into(out);
    }

    /// Extract (index, score) pairs sorted by descending score.
    pub fn into_sorted(self) -> Vec<(usize, f32)> {
        self.tk.into_sorted()
    }
}

/// A [`BoundHeap`] wired to a shared monotone threshold: the worker-side
/// half of the pool-parallel branch-and-bound walk (`lsh::bnb`). Every
/// push that leaves the local heap full publishes the local k-th score
/// into the [`ThresholdCell`] all workers share; the pruning predicate
/// then combines the exact tie-aware local test with a strict
/// (`ub < shared`) test against the freshest published score. A stale
/// read only sees an *older, lower* threshold — the cell is monotone —
/// so staleness weakens pruning but can never drop a true top-k
/// candidate; see `ThresholdCell` for why the f32-bits-as-u32 `fetch_max`
/// is order-preserving for the non-negative collision scores.
pub struct SharedBoundHeap<'a> {
    heap: &'a mut BoundHeap,
    cell: &'a ThresholdCell,
}

impl<'a> SharedBoundHeap<'a> {
    pub fn new(heap: &'a mut BoundHeap, cell: &'a ThresholdCell) -> SharedBoundHeap<'a> {
        SharedBoundHeap { heap, cell }
    }

    /// Offer a candidate; publishes the local k-th score so sibling
    /// workers can prune against it — but only when that score actually
    /// changed (heap just filled, or a replacement raised the min), so
    /// rejected offers and tie-break swaps cost no shared-cache-line
    /// RMW on the scoring inner loop.
    #[inline]
    pub fn push(&mut self, score: f32, index: usize) {
        let before = self.heap.worst().map(|(w, _)| w);
        self.heap.push(score, index);
        if let Some((w, _)) = self.heap.worst() {
            if before != Some(w) {
                self.cell.publish(w);
            }
        }
    }

    /// Whether a block with score bound `ub` and first index `base` can
    /// be skipped: exact against the local heap ([`BoundHeap::prunes_at`])
    /// or strictly below the shared published threshold. Both tests are
    /// individually lossless, so their union is too.
    #[inline]
    pub fn prunes_block(&self, ub: f32, base: usize) -> bool {
        self.heap.prunes_at(ub, base) || ub < self.cell.get()
    }

    /// True when k candidates are held locally.
    #[inline]
    pub fn is_full(&self) -> bool {
        self.heap.is_full()
    }
}

/// Top-k indices of a score slice, descending by score.
pub fn top_k_indices(scores: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(scores.len());
    if k == 0 {
        return Vec::new();
    }
    let mut tk = TopK::new(k);
    for (i, &s) in scores.iter().enumerate() {
        tk.push(s, i);
    }
    tk.into_indices()
}

/// [`top_k_indices`] into a reusable buffer: the indices of the `k`
/// highest scores, descending (ties toward lower indices), written to a
/// cleared `out`. Identical selection and order; the bounded O(k) heap
/// is the only transient allocation — the decode hot path's entry
/// point.
pub fn top_k_into(scores: &[f32], k: usize, out: &mut Vec<usize>) {
    out.clear();
    let k = k.min(scores.len());
    if k == 0 {
        return;
    }
    let mut tk = TopK::new(k);
    for (i, &s) in scores.iter().enumerate() {
        tk.push(s, i);
    }
    for (i, _) in tk.into_sorted() {
        out.push(i);
    }
}

/// The k-th largest value (the selection threshold), or -inf if k == 0.
pub fn top_k_threshold(scores: &[f32], k: usize) -> f32 {
    if k == 0 {
        return f32::NEG_INFINITY;
    }
    let idx = top_k_indices(scores, k);
    idx.last().map(|&i| scores[i]).unwrap_or(f32::NEG_INFINITY)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check_default, gen};
    use crate::prop_assert;

    #[test]
    fn selects_largest() {
        let s = [0.1, 5.0, 3.0, 4.0, -1.0];
        assert_eq!(top_k_indices(&s, 3), vec![1, 3, 2]);
    }

    #[test]
    fn k_larger_than_n() {
        let s = [2.0, 1.0];
        assert_eq!(top_k_indices(&s, 10), vec![0, 1]);
    }

    #[test]
    fn nan_ignored() {
        let s = [f32::NAN, 1.0, 2.0];
        assert_eq!(top_k_indices(&s, 2), vec![2, 1]);
    }

    #[test]
    fn threshold_matches_kth() {
        let s = [9.0, 7.0, 8.0, 1.0];
        assert_eq!(top_k_threshold(&s, 2), 8.0);
        assert_eq!(top_k_threshold(&s, 0), f32::NEG_INFINITY);
    }

    #[test]
    fn ties_are_deterministic() {
        let s = [1.0, 1.0, 1.0, 1.0];
        let a = top_k_indices(&s, 2);
        let b = top_k_indices(&s, 2);
        assert_eq!(a, b);
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn prop_matches_full_sort() {
        check_default("topk-vs-sort", |rng, _| {
            let n = gen::size(rng, 1, 2000);
            let k = 1 + rng.below_usize(n);
            let scores: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let got = top_k_indices(&scores, k);
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b)));
            idx.truncate(k);
            // Compare score multisets (ties may order differently but
            // selected score values must agree).
            let mut gs: Vec<f32> = got.iter().map(|&i| scores[i]).collect();
            let mut es: Vec<f32> = idx.iter().map(|&i| scores[i]).collect();
            gs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            es.sort_by(|a, b| a.partial_cmp(b).unwrap());
            prop_assert!(gs == es, "n={n} k={k}");
            Ok(())
        });
    }

    #[test]
    fn prop_tie_stability_prefers_low_indices() {
        // Scores drawn from a 3-value set force heavy ties; selection
        // must resolve them deterministically toward lower indices
        // (first-seen wins at the threshold) and order the output by
        // (score desc, index asc) — i.e. exactly the stable full sort.
        check_default("topk-tie-stability", |rng, _| {
            let n = gen::size(rng, 2, 300);
            let k = 1 + rng.below_usize(n);
            let vals = [0.0f32, 1.0, 2.0];
            let scores: Vec<f32> = (0..n).map(|_| vals[rng.below_usize(3)]).collect();
            let got = top_k_indices(&scores, k);
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b)));
            idx.truncate(k);
            prop_assert!(got == idx, "n={n} k={k}: {got:?} vs {idx:?}");
            Ok(())
        });
    }

    #[test]
    fn prop_top_k_into_matches_top_k_indices() {
        check_default("topk-into-vs-alloc", |rng, _| {
            let n = gen::size(rng, 1, 400);
            let k = rng.below_usize(n + 10); // may exceed n or be 0
            let scores: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut out = vec![77usize; 3]; // stale buffer
            top_k_into(&scores, k, &mut out);
            prop_assert!(out == top_k_indices(&scores, k), "n={n} k={k}");
            Ok(())
        });
    }

    #[test]
    fn all_equal_scores_select_first_k_indices() {
        let s = [3.0f32; 7];
        assert_eq!(top_k_indices(&s, 3), vec![0, 1, 2]);
    }

    #[test]
    fn bound_heap_threshold_streams() {
        let mut bh = BoundHeap::new(2);
        assert!(!bh.is_full());
        assert_eq!(bh.bound(), f32::NEG_INFINITY);
        assert!(!bh.prunes(f32::NEG_INFINITY), "unfilled heap may never prune");
        bh.push(1.0, 0);
        bh.push(3.0, 1);
        assert!(bh.is_full());
        assert_eq!(bh.bound(), 1.0);
        assert!(bh.prunes(1.0), "ub == threshold prunes: push requires strict >");
        assert!(!bh.prunes(1.0 + 1e-6));
        bh.push(2.0, 2);
        assert_eq!(bh.bound(), 2.0);
        assert_eq!(bh.into_sorted(), vec![(1, 3.0), (2, 2.0)]);
    }

    #[test]
    fn prop_bound_heap_pruning_is_lossless() {
        // Feeding every score vs skipping whole chunks whose true max
        // is ≤ the streaming threshold must yield identical selections
        // — the branch-and-bound identity the scoring kernels rely on.
        check_default("bound-heap-lossless", |rng, _| {
            let n = gen::size(rng, 1, 600);
            let k = 1 + rng.below_usize(n);
            let scores: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut plain = TopK::new(k);
            for (i, &s) in scores.iter().enumerate() {
                plain.push(s, i);
            }
            let mut bh = BoundHeap::new(k);
            for (c, chunk) in scores.chunks(7).enumerate() {
                let ub = chunk.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                if bh.is_full() && bh.prunes(ub) {
                    continue;
                }
                for (i, &s) in chunk.iter().enumerate() {
                    bh.push(s, c * 7 + i);
                }
            }
            prop_assert!(bh.into_sorted() == plain.into_sorted(), "n={n} k={k}");
            Ok(())
        });
    }

    #[test]
    fn prop_push_order_is_irrelevant() {
        // The tie-aware push makes TopK order-independent: feeding the
        // same (score, index) pairs in any permutation must hold the
        // same set — exactly the stable (score desc, index asc) top-k.
        // Heavy ties (3-value score set) stress the tie clause.
        check_default("topk-order-independent", |rng, _| {
            let n = gen::size(rng, 1, 300);
            let k = 1 + rng.below_usize(n);
            let vals = [0.0f32, 1.0, 2.0];
            let scores: Vec<f32> = (0..n).map(|_| vals[rng.below_usize(3)]).collect();
            let mut perm: Vec<usize> = (0..n).collect();
            // Fisher-Yates shuffle.
            for i in (1..n).rev() {
                perm.swap(i, rng.below_usize(i + 1));
            }
            let mut fwd = TopK::new(k);
            for (i, &s) in scores.iter().enumerate() {
                fwd.push(s, i);
            }
            let mut shuffled = TopK::new(k);
            for &i in &perm {
                shuffled.push(scores[i], i);
            }
            let want = fwd.into_sorted();
            prop_assert!(shuffled.into_sorted() == want, "n={n} k={k}");
            Ok(())
        });
    }

    #[test]
    fn worst_reports_score_and_largest_tied_index() {
        let mut tk = TopK::new(2);
        assert_eq!(tk.worst(), None);
        tk.push(1.0, 4);
        assert_eq!(tk.worst(), None, "not full yet");
        tk.push(1.0, 2);
        // Worst under (score desc, index asc) is the larger index.
        assert_eq!(tk.worst(), Some((1.0, 4)));
        tk.push(1.0, 1); // ties with worst but lower index: replaces it
        assert_eq!(tk.worst(), Some((1.0, 2)));
        tk.push(1.0, 3); // ties but higher index than worst: rejected
        assert_eq!(tk.into_sorted(), vec![(1, 1.0), (2, 1.0)]);
    }

    #[test]
    fn reset_and_drain_reuse_the_heap() {
        let mut tk = TopK::new(3);
        for (i, s) in [5.0f32, 1.0, 3.0, 4.0].into_iter().enumerate() {
            tk.push(s, i);
        }
        let mut got = vec![(99usize, 0.0f32)]; // stale buffer
        tk.drain_into(&mut got);
        got.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        assert_eq!(got, vec![(0, 5.0), (3, 4.0), (2, 3.0)]);
        tk.reset(1);
        tk.push(2.0, 9);
        tk.push(7.0, 1);
        assert_eq!(tk.into_sorted(), vec![(1, 7.0)]);
    }

    #[test]
    fn prunes_at_is_tie_break_aware() {
        let mut bh = BoundHeap::new(1);
        assert!(!bh.prunes_at(f32::INFINITY, 0), "unfilled heap never prunes");
        bh.push(5.0, 10);
        // Equal bound, block starting below the held index: a member
        // could win the index tie-break, so the block must be scored.
        assert!(!bh.prunes_at(5.0, 3));
        // Equal bound, block wholly above the held index: prune.
        assert!(bh.prunes_at(5.0, 11));
        // Strictly lower bound prunes regardless of position.
        assert!(bh.prunes_at(4.9, 0));
        assert!(!bh.prunes_at(5.1, 999));
    }

    #[test]
    fn shared_bound_heap_publishes_and_prunes_across_heaps() {
        let cell = ThresholdCell::new();
        let mut a = BoundHeap::new(2);
        let mut b = BoundHeap::new(2);
        {
            let mut sa = SharedBoundHeap::new(&mut a, &cell);
            assert!(!sa.prunes_block(0.0, 0), "nothing published yet");
            sa.push(3.0, 0);
            assert!(!sa.is_full());
            sa.push(5.0, 1); // full: publishes k-th score 3.0
        }
        {
            let sb = SharedBoundHeap::new(&mut b, &cell);
            // b is empty, but the shared threshold prunes strictly-below
            // blocks on its behalf.
            assert!(sb.prunes_block(2.9, 0));
            assert!(!sb.prunes_block(3.0, 0), "shared test is strict at equality");
        }
        {
            let mut sa = SharedBoundHeap::new(&mut a, &cell);
            sa.push(4.0, 2); // threshold rises to 4.0
        }
        let sb = SharedBoundHeap::new(&mut b, &cell);
        assert!(sb.prunes_block(3.5, 0));
    }

    #[test]
    fn prop_threshold_is_kth_order_stat() {
        check_default("topk-threshold", |rng, _| {
            let n = gen::size(rng, 1, 500);
            let k = 1 + rng.below_usize(n);
            let scores: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let t = top_k_threshold(&scores, k);
            let above = scores.iter().filter(|&&s| s > t).count();
            prop_assert!(above < k, "above={above} k={k}");
            Ok(())
        });
    }
}
