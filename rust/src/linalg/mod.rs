//! Dense linear algebra helpers: row-major matrices, vector ops,
//! numerically stable softmax, and top-k selection.

pub mod matrix;
pub mod ops;
pub mod topk;

pub use matrix::Matrix;
pub use ops::{add_scaled, argmax, dot, l1_norm, l2_norm, matvec, normalize, scale, softmax, softmax_inplace};
pub use topk::{top_k_indices, top_k_into, top_k_threshold, BoundHeap, SharedBoundHeap, TopK};
