//! Vector primitives. All hot-path loops are written over slices so the
//! compiler can autovectorize; there are no allocations except where a
//! result vector is returned.

/// Inner product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    // 4-lane manual unroll — measurably faster than the naive loop on
    // the scoring hot path (see EXPERIMENTS.md §Perf).
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    for j in chunks * 4..a.len() {
        acc += a[j] * b[j];
    }
    acc + s0 + s1 + s2 + s3
}

/// Euclidean norm.
#[inline]
pub fn l2_norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// L1 norm.
#[inline]
pub fn l1_norm(a: &[f32]) -> f32 {
    a.iter().map(|x| x.abs()).sum()
}

/// In-place scale.
pub fn scale(a: &mut [f32], s: f32) {
    for x in a.iter_mut() {
        *x *= s;
    }
}

/// `out += s * a`.
pub fn add_scaled(out: &mut [f32], a: &[f32], s: f32) {
    debug_assert_eq!(out.len(), a.len());
    for i in 0..out.len() {
        out[i] += s * a[i];
    }
}

/// Normalize to unit L2 norm (no-op on zero vectors).
pub fn normalize(a: &mut [f32]) {
    let n = l2_norm(a);
    if n > 0.0 {
        scale(a, 1.0 / n);
    }
}

/// Index of the maximum element (first on ties). Panics on empty input.
pub fn argmax(a: &[f32]) -> usize {
    assert!(!a.is_empty());
    let mut best = 0;
    for i in 1..a.len() {
        if a[i] > a[best] {
            best = i;
        }
    }
    best
}

/// Numerically stable softmax, returned as a new vector.
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let mut out = logits.to_vec();
    softmax_inplace(&mut out);
    out
}

/// Numerically stable in-place softmax.
pub fn softmax_inplace(x: &mut [f32]) {
    if x.is_empty() {
        return;
    }
    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    if sum > 0.0 {
        for v in x.iter_mut() {
            *v /= sum;
        }
    }
}

/// Dense matrix-vector product: `m` is row-major (rows x cols).
pub fn matvec(m: &[f32], rows: usize, cols: usize, v: &[f32], out: &mut [f32]) {
    debug_assert_eq!(m.len(), rows * cols);
    debug_assert_eq!(v.len(), cols);
    debug_assert_eq!(out.len(), rows);
    for r in 0..rows {
        out[r] = dot(&m[r * cols..(r + 1) * cols], v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..13).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..13).map(|i| (13 - i) as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-4);
    }

    #[test]
    fn norms() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
        assert!((l1_norm(&[-3.0, 4.0]) - 7.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_sums_to_one_and_is_shift_invariant() {
        let a = softmax(&[1.0, 2.0, 3.0]);
        let b = softmax(&[1001.0, 1002.0, 1003.0]);
        assert!((a.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        for i in 0..3 {
            assert!((a[i] - b[i]).abs() < 1e-6);
        }
        assert!(a[2] > a[1] && a[1] > a[0]);
    }

    #[test]
    fn softmax_handles_neg_infinity_mask() {
        let a = softmax(&[0.0, f32::NEG_INFINITY, 0.0]);
        assert_eq!(a[1], 0.0);
        assert!((a[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn matvec_identity() {
        let m = [1.0, 0.0, 0.0, 1.0];
        let mut out = [0.0; 2];
        matvec(&m, 2, 2, &[7.0, -2.0], &mut out);
        assert_eq!(out, [7.0, -2.0]);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
    }

    #[test]
    fn normalize_zero_safe() {
        let mut z = [0.0f32; 4];
        normalize(&mut z);
        assert_eq!(z, [0.0; 4]);
        let mut v = [0.0f32, 2.0];
        normalize(&mut v);
        assert!((l2_norm(&v) - 1.0).abs() < 1e-6);
    }
}
