//! Vector primitives. The reduction and elementwise loops dispatch
//! through `crate::simd` (AVX2/NEON behind runtime detection, with a
//! bit-identical fixed-lane scalar reference); the remaining loops are
//! written over slices so the compiler can autovectorize. There are no
//! allocations except where a result vector is returned.

use crate::simd;

/// Inner product (8-lane tree-reduction order in every dispatch tier —
/// see `simd` module docs).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    simd::dot(a, b)
}

/// Euclidean norm.
#[inline]
pub fn l2_norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// L1 norm.
#[inline]
pub fn l1_norm(a: &[f32]) -> f32 {
    a.iter().map(|x| x.abs()).sum()
}

/// In-place scale.
pub fn scale(a: &mut [f32], s: f32) {
    simd::scale(a, s);
}

/// `out += s * a`.
pub fn add_scaled(out: &mut [f32], a: &[f32], s: f32) {
    debug_assert_eq!(out.len(), a.len());
    simd::axpy(out, a, s);
}

/// Normalize to unit L2 norm (no-op on zero vectors).
pub fn normalize(a: &mut [f32]) {
    let n = l2_norm(a);
    if n > 0.0 {
        scale(a, 1.0 / n);
    }
}

/// Index of the maximum element (first on ties). Panics on empty input.
pub fn argmax(a: &[f32]) -> usize {
    assert!(!a.is_empty());
    let mut best = 0;
    let mut best_val = a.first().copied().unwrap_or(f32::NEG_INFINITY);
    for (i, &v) in a.iter().enumerate().skip(1) {
        if v > best_val {
            best = i;
            best_val = v;
        }
    }
    best
}

/// Numerically stable softmax, returned as a new vector.
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let mut out = logits.to_vec();
    softmax_inplace(&mut out);
    out
}

/// Numerically stable in-place softmax.
pub fn softmax_inplace(x: &mut [f32]) {
    if x.is_empty() {
        return;
    }
    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    if sum > 0.0 {
        for v in x.iter_mut() {
            *v /= sum;
        }
    }
}

/// Dense matrix-vector product: `m` is row-major (rows x cols).
pub fn matvec(m: &[f32], rows: usize, cols: usize, v: &[f32], out: &mut [f32]) {
    debug_assert_eq!(m.len(), rows * cols);
    debug_assert_eq!(v.len(), cols);
    debug_assert_eq!(out.len(), rows);
    if cols == 0 {
        out.fill(0.0);
        return;
    }
    for (o, row) in out.iter_mut().zip(m.chunks_exact(cols)) {
        *o = dot(row, v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..13).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..13).map(|i| (13 - i) as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-4);
    }

    #[test]
    fn norms() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
        assert!((l1_norm(&[-3.0, 4.0]) - 7.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_sums_to_one_and_is_shift_invariant() {
        let a = softmax(&[1.0, 2.0, 3.0]);
        let b = softmax(&[1001.0, 1002.0, 1003.0]);
        assert!((a.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        for i in 0..3 {
            assert!((a[i] - b[i]).abs() < 1e-6);
        }
        assert!(a[2] > a[1] && a[1] > a[0]);
    }

    #[test]
    fn softmax_handles_neg_infinity_mask() {
        let a = softmax(&[0.0, f32::NEG_INFINITY, 0.0]);
        assert_eq!(a[1], 0.0);
        assert!((a[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn matvec_identity() {
        let m = [1.0, 0.0, 0.0, 1.0];
        let mut out = [0.0; 2];
        matvec(&m, 2, 2, &[7.0, -2.0], &mut out);
        assert_eq!(out, [7.0, -2.0]);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
    }

    #[test]
    fn normalize_zero_safe() {
        let mut z = [0.0f32; 4];
        normalize(&mut z);
        assert_eq!(z, [0.0; 4]);
        let mut v = [0.0f32, 2.0];
        normalize(&mut v);
        assert!((l2_norm(&v) - 1.0).abs() < 1e-6);
    }
}
