//! NEON implementations of the [`super`] kernels (aarch64, where NEON
//! is a baseline feature). The 8-lane virtual width of the scalar
//! reference maps onto two `float32x4_t` accumulators; reductions fold
//! the high register onto the low one (lane j + lane j+4), then pair
//! (0,2)/(1,3) with `vextq`, then join lanes 0 and 1 — the same tree
//! as the AVX2 `hsum`/`hmax`, so results are bit-identical to both
//! other tiers. `vmaxq_f32` is NOT used for the running max: its NaN
//! semantics differ from `maxps`, so max is compare (`vcgtq_f32`) +
//! select (`vbslq_f32`), matching the scalar `max2` exactly. No FMA
//! (`vfmaq`) anywhere — multiply then add, two roundings, like the
//! other tiers. There is no NEON gather instruction, so the
//! soft-collision gather stays on the scalar loop (elementwise, hence
//! still bit-identical).

#![cfg(target_arch = "aarch64")]

use core::arch::aarch64::*;

use super::LANES;

/// Lane-wise `max2`: keep `a` only where strictly greater, else `b`.
///
/// # Safety
///
/// NEON is baseline on aarch64; pure register arithmetic.
#[inline]
unsafe fn vmax2q_f32(a: float32x4_t, b: float32x4_t) -> float32x4_t {
    // SAFETY: register-only NEON ops, no memory access.
    unsafe { vbslq_f32(vcgtq_f32(a, b), a, b) }
}

/// # Safety
///
/// NEON is baseline on aarch64 (caller dispatch contract).
pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: every offset below stays under n = min(a.len(), b.len()).
    unsafe {
        let n = a.len().min(b.len());
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let body = (n / LANES) * LANES;
        let mut acc_lo = vdupq_n_f32(0.0);
        let mut acc_hi = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i < body {
            acc_lo = vaddq_f32(acc_lo, vmulq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i))));
            acc_hi = vaddq_f32(
                acc_hi,
                vmulq_f32(vld1q_f32(pa.add(i + 4)), vld1q_f32(pb.add(i + 4))),
            );
            i += LANES;
        }
        // Tree: s_j = l_j + l_{j+4}; then (s0+s2, s1+s3); then join.
        let s = vaddq_f32(acc_lo, acc_hi);
        let t = vaddq_f32(s, vextq_f32::<2>(s, s));
        let mut total = vgetq_lane_f32::<0>(t) + vgetq_lane_f32::<1>(t);
        while i < n {
            total += *pa.add(i) * *pb.add(i);
            i += 1;
        }
        total
    }
}

/// # Safety
///
/// NEON is baseline on aarch64 (caller dispatch contract).
pub(super) unsafe fn max(a: &[f32]) -> f32 {
    // SAFETY: every offset below stays under a.len().
    unsafe {
        let n = a.len();
        let pa = a.as_ptr();
        let body = (n / LANES) * LANES;
        let mut acc_lo = vdupq_n_f32(f32::NEG_INFINITY);
        let mut acc_hi = vdupq_n_f32(f32::NEG_INFINITY);
        let mut i = 0usize;
        while i < body {
            acc_lo = vmax2q_f32(acc_lo, vld1q_f32(pa.add(i)));
            acc_hi = vmax2q_f32(acc_hi, vld1q_f32(pa.add(i + 4)));
            i += LANES;
        }
        let s = vmax2q_f32(acc_lo, acc_hi);
        let t = vmax2q_f32(s, vextq_f32::<2>(s, s));
        let t0 = vgetq_lane_f32::<0>(t);
        let t1 = vgetq_lane_f32::<1>(t);
        let mut m = if t0 > t1 { t0 } else { t1 };
        while i < n {
            let x = *pa.add(i);
            m = if m > x { m } else { x };
            i += 1;
        }
        m
    }
}

/// # Safety
///
/// NEON is baseline on aarch64 (caller dispatch contract).
pub(super) unsafe fn axpy(out: &mut [f32], a: &[f32], s: f32) {
    // SAFETY: every offset below stays under n = min(out.len(), a.len()).
    unsafe {
        let n = out.len().min(a.len());
        let po = out.as_mut_ptr();
        let pa = a.as_ptr();
        let vs = vdupq_n_f32(s);
        let body = (n / 4) * 4;
        let mut i = 0usize;
        while i < body {
            let vo = vld1q_f32(po.add(i));
            let va = vld1q_f32(pa.add(i));
            // mul+add, not vfmaq: matches the two-rounding scalar tier.
            vst1q_f32(po.add(i), vaddq_f32(vo, vmulq_f32(vs, va)));
            i += 4;
        }
        while i < n {
            *po.add(i) += s * *pa.add(i);
            i += 1;
        }
    }
}

/// # Safety
///
/// NEON is baseline on aarch64 (caller dispatch contract).
pub(super) unsafe fn scale(a: &mut [f32], s: f32) {
    // SAFETY: every offset below stays under a.len().
    unsafe {
        let n = a.len();
        let pa = a.as_mut_ptr();
        let vs = vdupq_n_f32(s);
        let body = (n / 4) * 4;
        let mut i = 0usize;
        while i < body {
            vst1q_f32(pa.add(i), vmulq_f32(vld1q_f32(pa.add(i)), vs));
            i += 4;
        }
        while i < n {
            *pa.add(i) *= s;
            i += 1;
        }
    }
}

/// # Safety
///
/// NEON is baseline on aarch64 (caller dispatch contract).
pub(super) unsafe fn div(a: &mut [f32], s: f32) {
    // SAFETY: every offset below stays under a.len().
    unsafe {
        let n = a.len();
        let pa = a.as_mut_ptr();
        let vs = vdupq_n_f32(s);
        let body = (n / 4) * 4;
        let mut i = 0usize;
        while i < body {
            vst1q_f32(pa.add(i), vdivq_f32(vld1q_f32(pa.add(i)), vs));
            i += 4;
        }
        while i < n {
            *pa.add(i) /= s;
            i += 1;
        }
    }
}

/// # Safety
///
/// NEON is baseline on aarch64 (caller dispatch contract).
pub(super) unsafe fn mul_assign(a: &mut [f32], b: &[f32]) {
    // SAFETY: every offset below stays under n = min(a.len(), b.len()).
    unsafe {
        let n = a.len().min(b.len());
        let pa = a.as_mut_ptr();
        let pb = b.as_ptr();
        let body = (n / 4) * 4;
        let mut i = 0usize;
        while i < body {
            vst1q_f32(pa.add(i), vmulq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i))));
            i += 4;
        }
        while i < n {
            *pa.add(i) *= *pb.add(i);
            i += 1;
        }
    }
}

/// Compare-and-count 8 u16 bucket ids per iteration: `vceqq_u16` →
/// mask-and-1 → widen both halves to u32 → convert to f32 → add.
///
/// # Safety
///
/// NEON is baseline on aarch64; requires `row.len() >= counts.len()`.
pub(super) unsafe fn count_eq(counts: &mut [f32], row: &[u16], bucket: u16) {
    // SAFETY: offsets stay under n = min(counts.len(), row.len()); the
    // 8-wide body only runs while i + 8 <= n.
    unsafe {
        let n = counts.len().min(row.len());
        let pc = counts.as_mut_ptr();
        let pr = row.as_ptr();
        let target = vdupq_n_u16(bucket);
        let one = vdupq_n_u16(1);
        let body = (n / 8) * 8;
        let mut i = 0usize;
        while i < body {
            let hits = vandq_u16(vceqq_u16(vld1q_u16(pr.add(i)), target), one);
            let lo = vcvtq_f32_u32(vmovl_u16(vget_low_u16(hits)));
            let hi = vcvtq_f32_u32(vmovl_u16(vget_high_u16(hits)));
            vst1q_f32(pc.add(i), vaddq_f32(vld1q_f32(pc.add(i)), lo));
            vst1q_f32(pc.add(i + 4), vaddq_f32(vld1q_f32(pc.add(i + 4)), hi));
            i += 8;
        }
        while i < n {
            *pc.add(i) += (*pr.add(i) == bucket) as u32 as f32;
            i += 1;
        }
    }
}
