//! AVX2 implementations of the [`super`] kernels. Every function here
//! is `#[target_feature(enable = "avx2")]` and must only be reached
//! through [`super::dispatch`] after runtime detection. Reductions use
//! the exact horizontal-op sequences the scalar reference mirrors
//! (see the module docs in `simd`): `hsum`/`hmax` fold lane j onto
//! lane j+4 via `extractf128`, pair (0,2)/(1,3) via `movehl`, and join
//! with a final `shuffle` — so results are bit-identical to scalar.
//! No FMA instructions are used (multiply then add), matching the
//! scalar tier's rounding exactly.

#![cfg(target_arch = "x86_64")]

use core::arch::x86_64::*;

use super::LANES;

/// Horizontal sum of one `__m256` in the canonical tree order.
///
/// # Safety
///
/// Requires AVX2 (guaranteed by the caller's dispatch).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum(v: __m256) -> f32 {
    // SAFETY: pure register arithmetic; AVX2 availability is the
    // caller's contract.
    unsafe {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let s = _mm_add_ps(lo, hi);
        let h = _mm_add_ps(s, _mm_movehl_ps(s, s));
        _mm_cvtss_f32(_mm_add_ss(h, _mm_shuffle_ps::<1>(h, h)))
    }
}

/// Horizontal max of one `__m256` in the same tree shape as [`hsum`].
///
/// # Safety
///
/// Requires AVX2 (guaranteed by the caller's dispatch).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hmax(v: __m256) -> f32 {
    // SAFETY: pure register arithmetic; AVX2 availability is the
    // caller's contract.
    unsafe {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let s = _mm_max_ps(lo, hi);
        let h = _mm_max_ps(s, _mm_movehl_ps(s, s));
        _mm_cvtss_f32(_mm_max_ss(h, _mm_shuffle_ps::<1>(h, h)))
    }
}

/// # Safety
///
/// Requires AVX2 (guaranteed by the caller's dispatch).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: AVX2 is the caller's contract; every offset below stays
    // under n = min(a.len(), b.len()).
    unsafe {
        let n = a.len().min(b.len());
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let body = (n / LANES) * LANES;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i < body {
            let va = _mm256_loadu_ps(pa.add(i));
            let vb = _mm256_loadu_ps(pb.add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
            i += LANES;
        }
        let mut total = hsum(acc);
        while i < n {
            total += *pa.add(i) * *pb.add(i);
            i += 1;
        }
        total
    }
}

/// # Safety
///
/// Requires AVX2 (guaranteed by the caller's dispatch).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn max(a: &[f32]) -> f32 {
    // SAFETY: AVX2 is the caller's contract; every offset below stays
    // under a.len().
    unsafe {
        let n = a.len();
        let pa = a.as_ptr();
        let body = (n / LANES) * LANES;
        let mut acc = _mm256_set1_ps(f32::NEG_INFINITY);
        let mut i = 0usize;
        while i < body {
            // maxps keeps acc only when strictly greater — the same
            // convention as the scalar max2.
            acc = _mm256_max_ps(acc, _mm256_loadu_ps(pa.add(i)));
            i += LANES;
        }
        let mut m = hmax(acc);
        while i < n {
            let x = *pa.add(i);
            m = if m > x { m } else { x };
            i += 1;
        }
        m
    }
}

/// # Safety
///
/// Requires AVX2 (guaranteed by the caller's dispatch).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn axpy(out: &mut [f32], a: &[f32], s: f32) {
    // SAFETY: AVX2 is the caller's contract; every offset below stays
    // under n = min(out.len(), a.len()).
    unsafe {
        let n = out.len().min(a.len());
        let po = out.as_mut_ptr();
        let pa = a.as_ptr();
        let vs = _mm256_set1_ps(s);
        let body = (n / LANES) * LANES;
        let mut i = 0usize;
        while i < body {
            let vo = _mm256_loadu_ps(po.add(i));
            let va = _mm256_loadu_ps(pa.add(i));
            // mul+add, not FMA: matches the scalar tier's two roundings.
            _mm256_storeu_ps(po.add(i), _mm256_add_ps(vo, _mm256_mul_ps(vs, va)));
            i += LANES;
        }
        while i < n {
            *po.add(i) += s * *pa.add(i);
            i += 1;
        }
    }
}

/// # Safety
///
/// Requires AVX2 (guaranteed by the caller's dispatch).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn scale(a: &mut [f32], s: f32) {
    // SAFETY: AVX2 is the caller's contract; every offset below stays
    // under a.len().
    unsafe {
        let n = a.len();
        let pa = a.as_mut_ptr();
        let vs = _mm256_set1_ps(s);
        let body = (n / LANES) * LANES;
        let mut i = 0usize;
        while i < body {
            _mm256_storeu_ps(pa.add(i), _mm256_mul_ps(_mm256_loadu_ps(pa.add(i)), vs));
            i += LANES;
        }
        while i < n {
            *pa.add(i) *= s;
            i += 1;
        }
    }
}

/// # Safety
///
/// Requires AVX2 (guaranteed by the caller's dispatch).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn div(a: &mut [f32], s: f32) {
    // SAFETY: AVX2 is the caller's contract; every offset below stays
    // under a.len().
    unsafe {
        let n = a.len();
        let pa = a.as_mut_ptr();
        let vs = _mm256_set1_ps(s);
        let body = (n / LANES) * LANES;
        let mut i = 0usize;
        while i < body {
            _mm256_storeu_ps(pa.add(i), _mm256_div_ps(_mm256_loadu_ps(pa.add(i)), vs));
            i += LANES;
        }
        while i < n {
            *pa.add(i) /= s;
            i += 1;
        }
    }
}

/// # Safety
///
/// Requires AVX2 (guaranteed by the caller's dispatch).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn mul_assign(a: &mut [f32], b: &[f32]) {
    // SAFETY: AVX2 is the caller's contract; every offset below stays
    // under n = min(a.len(), b.len()).
    unsafe {
        let n = a.len().min(b.len());
        let pa = a.as_mut_ptr();
        let pb = b.as_ptr();
        let body = (n / LANES) * LANES;
        let mut i = 0usize;
        while i < body {
            let va = _mm256_loadu_ps(pa.add(i));
            let vb = _mm256_loadu_ps(pb.add(i));
            _mm256_storeu_ps(pa.add(i), _mm256_mul_ps(va, vb));
            i += LANES;
        }
        while i < n {
            *pa.add(i) *= *pb.add(i);
            i += 1;
        }
    }
}

/// Compare-and-count 16 u16 bucket ids per iteration:
/// `cmpeq_epi16` → mask-and-1 → widen both halves to i32 → convert to
/// f32 → add into the counts.
///
/// # Safety
///
/// Requires AVX2 and `row.len() >= counts.len()`.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn count_eq(counts: &mut [f32], row: &[u16], bucket: u16) {
    // SAFETY: AVX2 is the caller's contract; offsets stay under
    // n = min(counts.len(), row.len()), and the 16-wide body only runs
    // while i + 16 <= n.
    unsafe {
        let n = counts.len().min(row.len());
        let pc = counts.as_mut_ptr();
        let pr = row.as_ptr();
        let target = _mm256_set1_epi16(bucket as i16);
        let one = _mm256_set1_epi16(1);
        let body = (n / 16) * 16;
        let mut i = 0usize;
        while i < body {
            let ids = _mm256_loadu_si256(pr.add(i) as *const __m256i);
            let hits = _mm256_and_si256(_mm256_cmpeq_epi16(ids, target), one);
            let lo = _mm256_cvtepu16_epi32(_mm256_castsi256_si128(hits));
            let hi = _mm256_cvtepu16_epi32(_mm256_extracti128_si256::<1>(hits));
            let c0 = _mm256_loadu_ps(pc.add(i));
            let c1 = _mm256_loadu_ps(pc.add(i + 8));
            _mm256_storeu_ps(pc.add(i), _mm256_add_ps(c0, _mm256_cvtepi32_ps(lo)));
            _mm256_storeu_ps(pc.add(i + 8), _mm256_add_ps(c1, _mm256_cvtepi32_ps(hi)));
            i += 16;
        }
        while i < n {
            *pc.add(i) += (*pr.add(i) == bucket) as u32 as f32;
            i += 1;
        }
    }
}

/// Soft-collision probability gather: widen 8 u16 bucket ids to i32
/// and `vgatherdps` the probability row.
///
/// # Safety
///
/// Requires AVX2, `ids.len() >= acc.len()`, and every id in the
/// accumulated prefix in bounds for `probs`.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn gather_accumulate(acc: &mut [f32], ids: &[u16], probs: &[f32]) {
    // SAFETY: AVX2 is the caller's contract; offsets stay under
    // n = min(acc.len(), ids.len()), and the gather indices are valid
    // for probs by the caller's contract (ids validated < R at
    // KeyHashes construction, probs rows exactly R wide).
    unsafe {
        let n = acc.len().min(ids.len());
        let pa = acc.as_mut_ptr();
        let pi = ids.as_ptr();
        let pp = probs.as_ptr();
        let body = (n / LANES) * LANES;
        let mut i = 0usize;
        while i < body {
            let vid = _mm_loadu_si128(pi.add(i) as *const __m128i);
            let vidx = _mm256_cvtepu16_epi32(vid);
            let g = _mm256_i32gather_ps::<4>(pp, vidx);
            let va = _mm256_loadu_ps(pa.add(i));
            _mm256_storeu_ps(pa.add(i), _mm256_add_ps(va, g));
            i += LANES;
        }
        while i < n {
            *pa.add(i) += *pp.add(*pi.add(i) as usize);
            i += 1;
        }
    }
}
