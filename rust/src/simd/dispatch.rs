//! Runtime dispatch tier for the SIMD kernels.
//!
//! The kernels in `simd` each have one vector implementation per
//! architecture plus a scalar reference written in the same fixed-lane
//! tree-reduction order, so every tier produces bit-identical f32
//! outputs. Which tier runs is decided once per process: the CPU is
//! probed (`is_x86_feature_detected!` on x86_64; NEON is baseline on
//! aarch64), the `SOCKET_SIMD=scalar` environment override is folded
//! in, and the result is cached. Tests flip [`force_scalar`] to pin the
//! reference path without touching the cache — because the paths are
//! bit-identical, flipping mid-run never changes any result.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};

/// Which kernel implementation family is running.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// The fixed-lane scalar reference (also the non-x86/ARM fallback).
    Scalar,
    /// x86-64 AVX2 (runtime-detected).
    Avx2,
    /// aarch64 NEON (baseline on that architecture).
    Neon,
}

impl Tier {
    /// Stable lowercase name (bench artifacts, metrics, logs).
    pub fn name(self) -> &'static str {
        match self {
            Tier::Scalar => "scalar",
            Tier::Avx2 => "avx2",
            Tier::Neon => "neon",
        }
    }
}

const UNKNOWN: u8 = 0;
const SCALAR: u8 = 1;
const AVX2: u8 = 2;
const NEON: u8 = 3;

/// Cached detection result. Ordering rationale: Relaxed everywhere —
/// the cell is a write-once memo of a pure, idempotent probe (every
/// racing writer stores the same value), no other memory is published
/// through it, so no acquire/release pairing is needed.
static DETECTED: AtomicU8 = AtomicU8::new(UNKNOWN);

/// Test/bench override pinning the scalar reference path. Ordering
/// rationale: Relaxed — an independent boolean flag read at kernel
/// entry; it synchronizes nothing, and both settings produce
/// bit-identical results, so staleness is harmless.
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Pin (or release) the scalar reference path for this process. The
/// dispatch bit-identity tests and the bench kernel lane run each
/// kernel under both settings; results are bit-identical by
/// construction, so flipping while other threads run kernels is safe.
pub fn force_scalar(on: bool) {
    // Ordering rationale: Relaxed — see FORCE_SCALAR.
    FORCE_SCALAR.store(on, Ordering::Relaxed);
}

/// Whether [`force_scalar`] is currently pinning the scalar path.
pub fn forced_scalar() -> bool {
    // Ordering rationale: Relaxed — see FORCE_SCALAR.
    FORCE_SCALAR.load(Ordering::Relaxed)
}

/// RAII handle from [`scoped_force_scalar`]: restores the override
/// state it replaced on drop, panic included.
pub struct ForceScalarGuard {
    prev: bool,
}

impl Drop for ForceScalarGuard {
    fn drop(&mut self) {
        force_scalar(self.prev);
    }
}

/// Pin (or release) the scalar path for a scope. The returned guard
/// restores the previous override when dropped, so a panic mid-scope
/// never leaves the whole process pinned to one tier. The flag itself
/// is still process-global: callers that measure (rather than just
/// compute) must not run concurrently with other override writers —
/// tests serialize through [`test_guard`], and the bench kernel lane
/// runs on the bench binary's single thread.
pub fn scoped_force_scalar(on: bool) -> ForceScalarGuard {
    let prev = forced_scalar();
    force_scalar(on);
    ForceScalarGuard { prev }
}

/// The tier the hardware (and architecture) supports, ignoring every
/// override.
fn native_tier() -> Tier {
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("avx2") {
            Tier::Avx2
        } else {
            Tier::Scalar
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        Tier::Neon
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        Tier::Scalar
    }
}

/// Pure dispatch policy: fold the `SOCKET_SIMD` environment override
/// into the natively detected tier. Split out so the policy is unit
/// testable without mutating process environment or the cache.
pub fn tier_from(env: Option<&str>, native: Tier) -> Tier {
    match env {
        Some(v) if v.trim().eq_ignore_ascii_case("scalar") => Tier::Scalar,
        _ => native,
    }
}

fn encode(t: Tier) -> u8 {
    match t {
        Tier::Scalar => SCALAR,
        Tier::Avx2 => AVX2,
        Tier::Neon => NEON,
    }
}

/// The cached `(env override, CPU probe)` dispatch decision.
fn detected() -> Tier {
    // Ordering rationale: Relaxed — see DETECTED (idempotent memo).
    match DETECTED.load(Ordering::Relaxed) {
        SCALAR => Tier::Scalar,
        AVX2 => Tier::Avx2,
        NEON => Tier::Neon,
        _ => {
            let env = std::env::var("SOCKET_SIMD").ok();
            let t = tier_from(env.as_deref(), native_tier());
            // Ordering rationale: Relaxed — see DETECTED.
            DETECTED.store(encode(t), Ordering::Relaxed);
            t
        }
    }
}

/// The tier the kernels will dispatch to right now.
#[inline]
pub fn tier() -> Tier {
    if forced_scalar() {
        return Tier::Scalar;
    }
    detected()
}

/// [`Tier::name`] of the active tier — what the bench lanes report.
pub fn tier_name() -> &'static str {
    tier().name()
}

/// Serializes tests that assert on the active tier (the flag is
/// process-global). Poisoning is ignored: the flag is always reset by
/// the guard in [`with_forced_scalar`], and a poisoned lock only means
/// an unrelated assertion failed.
#[cfg(test)]
pub fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `f` with the scalar reference path pinned, restoring
/// auto-dispatch afterwards (also on panic).
#[cfg(test)]
pub fn with_forced_scalar<T>(f: impl FnOnce() -> T) -> T {
    let _g = test_guard();
    let _reset = scoped_force_scalar(true);
    f()
}

/// Run `f` under auto-dispatch, holding the same lock as
/// [`with_forced_scalar`] so a concurrent test cannot pin the scalar
/// path mid-measurement.
#[cfg(test)]
pub fn with_auto<T>(f: impl FnOnce() -> T) -> T {
    let _g = test_guard();
    f()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_from_env_override() {
        assert_eq!(tier_from(Some("scalar"), Tier::Avx2), Tier::Scalar);
        assert_eq!(tier_from(Some("SCALAR"), Tier::Neon), Tier::Scalar);
        assert_eq!(tier_from(Some(" scalar "), Tier::Avx2), Tier::Scalar);
        assert_eq!(tier_from(Some("avx2"), Tier::Avx2), Tier::Avx2);
        assert_eq!(tier_from(Some("garbage"), Tier::Scalar), Tier::Scalar);
        assert_eq!(tier_from(None, Tier::Avx2), Tier::Avx2);
        assert_eq!(tier_from(None, Tier::Scalar), Tier::Scalar);
    }

    #[test]
    fn force_scalar_override_engages() {
        let _g = test_guard();
        struct Reset;
        impl Drop for Reset {
            fn drop(&mut self) {
                force_scalar(false);
            }
        }
        let _reset = Reset;
        let auto = tier();
        force_scalar(true);
        assert_eq!(tier(), Tier::Scalar, "override must pin the scalar path");
        assert!(forced_scalar());
        force_scalar(false);
        assert_eq!(tier(), auto, "releasing the override restores auto-dispatch");
        assert!(!forced_scalar());
    }

    #[test]
    fn tier_name_is_stable() {
        assert_eq!(Tier::Scalar.name(), "scalar");
        assert_eq!(Tier::Avx2.name(), "avx2");
        assert_eq!(Tier::Neon.name(), "neon");
        assert_eq!(tier_name(), tier().name());
    }
}
