//! SIMD kernels for the four hot inner loops — dot products (SimHash
//! Alg.-1 projections, flash-decode logits), online-softmax reductions
//! (`max`, rescale, weighted accumulate), hard-LSH bucket
//! compare-and-count, and the soft-collision probability gather — with
//! runtime dispatch ([`dispatch`]) between an AVX2 path, a NEON path,
//! and a scalar reference.
//!
//! # Bit-identity contract
//!
//! Every tier of every kernel produces **bit-identical** f32 output,
//! not merely ulp-close. Elementwise kernels (`axpy`, `scale`, `div`,
//! `mul_assign`, `count_eq`, `gather_accumulate`) are trivially
//! bit-identical: each output lane is the same correctly-rounded
//! scalar expression no matter how many run per instruction. The two
//! reductions (`dot`, `max`) are where order matters, so the scalar
//! reference is written in the exact fixed-lane shape the vector paths
//! use: [`LANES`] independent accumulators filled in stride order,
//! combined by the tree `s_j = l_j + l_{j+4}` then
//! `(s_0 + s_2) + (s_1 + s_3)` — precisely the AVX2 horizontal-sum
//! sequence (`extractf128` / `movehl` / `shuffle`) and the NEON
//! two-register `vextq` pairwise reduce — followed by a sequential
//! tail. No FMA anywhere (multiply then add in every tier), `exp`
//! stays scalar libm, and `max` uses the `maxps` operand convention
//! (`if acc > x { acc } else { x }`). Because of this contract the
//! existing paged-vs-dense and pruned-vs-exhaustive property suites
//! double as SIMD correctness proofs, and `SOCKET_SIMD=scalar` (or
//! [`dispatch::force_scalar`]) can flip mid-run without changing any
//! result.

pub mod dispatch;
#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "x86_64")]
mod x86;

pub use dispatch::{force_scalar, scoped_force_scalar, tier, tier_name, Tier};

/// Virtual lane count of every kernel: 8 f32 (one AVX2 register, two
/// NEON registers). The scalar reference uses the same width so its
/// reduction trees match the vector paths bit-for-bit.
pub const LANES: usize = 8;

/// Combine 8 lane accumulators in the AVX2 horizontal-sum order:
/// `extractf128`+`add` folds lane j onto lane j+4, `movehl`+`add`
/// pairs (0,2) and (1,3), the final `shuffle`+`add_ss` joins those.
#[inline]
fn reduce_add(lanes: [f32; LANES]) -> f32 {
    let [l0, l1, l2, l3, l4, l5, l6, l7] = lanes;
    let s0 = l0 + l4;
    let s1 = l1 + l5;
    let s2 = l2 + l6;
    let s3 = l3 + l7;
    (s0 + s2) + (s1 + s3)
}

/// The `maxps` operand convention: keep `acc` only when strictly
/// greater, otherwise take `x` (ties and NaN `acc` resolve to `x`).
#[inline]
fn max2(acc: f32, x: f32) -> f32 {
    if acc > x {
        acc
    } else {
        x
    }
}

/// Combine 8 lane maxima in the same tree shape as [`reduce_add`],
/// with [`max2`] as the join.
#[inline]
fn reduce_max(lanes: [f32; LANES]) -> f32 {
    let [l0, l1, l2, l3, l4, l5, l6, l7] = lanes;
    let s0 = max2(l0, l4);
    let s1 = max2(l1, l5);
    let s2 = max2(l2, l6);
    let s3 = max2(l3, l7);
    max2(max2(s0, s2), max2(s1, s3))
}

/// Dot product of `a` and `b` (extra tail elements of the longer slice
/// are ignored, matching the vector paths' `min(len)` bound).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match dispatch::tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only returned after `is_x86_feature_detected!`
        // confirmed AVX2 on this CPU.
        Tier::Avx2 => unsafe { x86::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Neon is only returned on aarch64, where NEON is a
        // baseline feature.
        Tier::Neon => unsafe { neon::dot(a, b) },
        _ => dot_scalar(a, b),
    }
}

/// Maximum element of `a` (`f32::NEG_INFINITY` when empty), reduced in
/// the fixed-lane tree order.
#[inline]
pub fn max(a: &[f32]) -> f32 {
    match dispatch::tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only returned after `is_x86_feature_detected!`
        // confirmed AVX2 on this CPU.
        Tier::Avx2 => unsafe { x86::max(a) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Neon is only returned on aarch64, where NEON is a
        // baseline feature.
        Tier::Neon => unsafe { neon::max(a) },
        _ => max_scalar(a),
    }
}

/// `out[i] += s * a[i]` over the common prefix (flash-decode weighted
/// value accumulate; no FMA — multiply then add in every tier).
#[inline]
pub fn axpy(out: &mut [f32], a: &[f32], s: f32) {
    debug_assert_eq!(out.len(), a.len());
    match dispatch::tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only returned after `is_x86_feature_detected!`
        // confirmed AVX2 on this CPU.
        Tier::Avx2 => unsafe { x86::axpy(out, a, s) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Neon is only returned on aarch64, where NEON is a
        // baseline feature.
        Tier::Neon => unsafe { neon::axpy(out, a, s) },
        _ => axpy_scalar(out, a, s),
    }
}

/// `a[i] *= s` (flash-decode running-max rescale).
#[inline]
pub fn scale(a: &mut [f32], s: f32) {
    match dispatch::tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only returned after `is_x86_feature_detected!`
        // confirmed AVX2 on this CPU.
        Tier::Avx2 => unsafe { x86::scale(a, s) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Neon is only returned on aarch64, where NEON is a
        // baseline feature.
        Tier::Neon => unsafe { neon::scale(a, s) },
        _ => scale_scalar(a, s),
    }
}

/// `a[i] /= s` (flash-decode final normalization; kept as a true
/// division in every tier — no reciprocal-multiply).
#[inline]
pub fn div(a: &mut [f32], s: f32) {
    match dispatch::tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only returned after `is_x86_feature_detected!`
        // confirmed AVX2 on this CPU.
        Tier::Avx2 => unsafe { x86::div(a, s) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Neon is only returned on aarch64, where NEON is a
        // baseline feature.
        Tier::Neon => unsafe { neon::div(a, s) },
        _ => div_scalar(a, s),
    }
}

/// `a[i] *= b[i]` over the common prefix (value-norm score weighting).
#[inline]
pub fn mul_assign(a: &mut [f32], b: &[f32]) {
    match dispatch::tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only returned after `is_x86_feature_detected!`
        // confirmed AVX2 on this CPU.
        Tier::Avx2 => unsafe { x86::mul_assign(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Neon is only returned on aarch64, where NEON is a
        // baseline feature.
        Tier::Neon => unsafe { neon::mul_assign(a, b) },
        _ => mul_assign_scalar(a, b),
    }
}

/// `counts[i] += (row[i] == bucket) as f32` over `counts.len()` keys —
/// the hard-LSH collision count against one table's bucket-id row.
/// Requires `row.len() >= counts.len()` (the SoA block rows are always
/// `BLOCK_TOKENS` wide; `counts` is the possibly-short tail prefix).
#[inline]
pub fn count_eq(counts: &mut [f32], row: &[u16], bucket: u16) {
    debug_assert!(row.len() >= counts.len());
    match dispatch::tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only returned after `is_x86_feature_detected!`
        // confirmed AVX2 on this CPU.
        Tier::Avx2 => unsafe { x86::count_eq(counts, row, bucket) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Neon is only returned on aarch64, where NEON is a
        // baseline feature.
        Tier::Neon => unsafe { neon::count_eq(counts, row, bucket) },
        _ => count_eq_scalar(counts, row, bucket),
    }
}

/// `acc[i] += probs[ids[i] as usize]` over `acc.len()` keys — the
/// soft-collision probability gather against one table's bucket-id row
/// (AVX2 `vgatherdps`; NEON has no gather, so it runs the scalar loop,
/// which is bit-identical because the kernel is elementwise).
///
/// # Safety
///
/// Requires `ids.len() >= acc.len()` and every `ids[i]` (for
/// `i < acc.len()`) in bounds for `probs`. `KeyHashes` validates every
/// stored bucket id against `R` at construction, and callers pass
/// per-table probability rows of exactly `R` entries.
#[inline]
pub unsafe fn gather_accumulate(acc: &mut [f32], ids: &[u16], probs: &[f32]) {
    debug_assert!(ids.len() >= acc.len());
    #[cfg(target_arch = "x86_64")]
    if dispatch::tier() == Tier::Avx2 {
        // SAFETY: Avx2 is only returned after `is_x86_feature_detected!`
        // confirmed AVX2 on this CPU; index validity is the caller's
        // contract, forwarded unchanged.
        return unsafe { x86::gather_accumulate(acc, ids, probs) };
    }
    // SAFETY: index validity is the caller's contract, forwarded
    // unchanged (NEON has no gather instruction, so every non-AVX2
    // tier runs the scalar loop — elementwise, hence bit-identical).
    unsafe { gather_accumulate_scalar(acc, ids, probs) }
}

fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let mut lanes = [0.0f32; LANES];
    let a_blocks = a.chunks_exact(LANES);
    let b_blocks = b.chunks_exact(LANES);
    let a_tail = a_blocks.remainder();
    let b_tail = b_blocks.remainder();
    for (ca, cb) in a_blocks.zip(b_blocks) {
        for ((lane, &x), &y) in lanes.iter_mut().zip(ca).zip(cb) {
            *lane += x * y;
        }
    }
    let mut acc = reduce_add(lanes);
    for (&x, &y) in a_tail.iter().zip(b_tail) {
        acc += x * y;
    }
    acc
}

fn max_scalar(a: &[f32]) -> f32 {
    let mut lanes = [f32::NEG_INFINITY; LANES];
    let blocks = a.chunks_exact(LANES);
    let tail = blocks.remainder();
    for chunk in blocks {
        for (lane, &x) in lanes.iter_mut().zip(chunk) {
            *lane = max2(*lane, x);
        }
    }
    let mut m = reduce_max(lanes);
    for &x in tail {
        m = max2(m, x);
    }
    m
}

fn axpy_scalar(out: &mut [f32], a: &[f32], s: f32) {
    for (o, &x) in out.iter_mut().zip(a) {
        *o += s * x;
    }
}

fn scale_scalar(a: &mut [f32], s: f32) {
    for x in a.iter_mut() {
        *x *= s;
    }
}

fn div_scalar(a: &mut [f32], s: f32) {
    for x in a.iter_mut() {
        *x /= s;
    }
}

fn mul_assign_scalar(a: &mut [f32], b: &[f32]) {
    for (x, &y) in a.iter_mut().zip(b) {
        *x *= y;
    }
}

fn count_eq_scalar(counts: &mut [f32], row: &[u16], bucket: u16) {
    for (c, &id) in counts.iter_mut().zip(row) {
        *c += (id == bucket) as u32 as f32;
    }
}

/// # Safety
///
/// Same contract as [`gather_accumulate`].
unsafe fn gather_accumulate_scalar(acc: &mut [f32], ids: &[u16], probs: &[f32]) {
    // SAFETY: caller guarantees ids.len() >= acc.len() and every id in
    // the accumulated prefix indexes inside probs.
    unsafe {
        for (i, a) in acc.iter_mut().enumerate() {
            *a += *probs.get_unchecked(*ids.get_unchecked(i) as usize);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::{check_default, gen};
    use crate::util::rng::Pcg64;

    fn vec_of(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.range_f32(-4.0, 4.0)).collect()
    }

    #[test]
    fn reduce_add_matches_documented_tree() {
        let lanes = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];
        assert_eq!(reduce_add(lanes), ((1.0 + 16.0) + (4.0 + 64.0)) + ((2.0 + 32.0) + (8.0 + 128.0)));
    }

    #[test]
    fn max_handles_edge_cases() {
        assert_eq!(max_scalar(&[]), f32::NEG_INFINITY);
        assert_eq!(max_scalar(&[-3.0]), -3.0);
        let v: Vec<f32> = (0..19).map(|i| -(i as f32)).collect();
        assert_eq!(max_scalar(&v), 0.0);
        assert_eq!(dispatch::with_auto(|| max(&v)), 0.0);
    }

    #[test]
    fn prop_dot_bit_identical_across_tiers() {
        check_default("simd-dot-tiers", |rng, _| {
            let n = gen::size(rng, 1, 300);
            let a = vec_of(rng, n);
            let b = vec_of(rng, n);
            let auto = dispatch::with_auto(|| (dot(&a, &b), max(&a)));
            let scalar = dispatch::with_forced_scalar(|| (dot(&a, &b), max(&a)));
            prop_assert!(
                auto.0.to_bits() == scalar.0.to_bits(),
                "dot diverges at n={n}: {} vs {}",
                auto.0,
                scalar.0
            );
            prop_assert!(
                auto.1.to_bits() == scalar.1.to_bits(),
                "max diverges at n={n}: {} vs {}",
                auto.1,
                scalar.1
            );
            Ok(())
        });
    }

    #[test]
    fn prop_elementwise_kernels_bit_identical_across_tiers() {
        check_default("simd-elementwise-tiers", |rng, _| {
            let n = gen::size(rng, 1, 300);
            let a = vec_of(rng, n);
            let b = vec_of(rng, n);
            let s = rng.range_f32(-2.0, 2.0);
            let run = |forced: bool| {
                let body = || {
                    let mut x = a.clone();
                    axpy(&mut x, &b, s);
                    scale(&mut x, s);
                    mul_assign(&mut x, &b);
                    div(&mut x, if s == 0.0 { 1.0 } else { s });
                    x
                };
                if forced {
                    dispatch::with_forced_scalar(body)
                } else {
                    dispatch::with_auto(body)
                }
            };
            let auto = run(false);
            let scalar = run(true);
            for (i, (x, y)) in auto.iter().zip(&scalar).enumerate() {
                prop_assert!(
                    x.to_bits() == y.to_bits(),
                    "elementwise chain diverges at {i}/{n}: {x} vs {y}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_count_eq_bit_identical_and_correct() {
        check_default("simd-count-eq-tiers", |rng, _| {
            let blen = gen::size(rng, 1, 64);
            let row: Vec<u16> = (0..64).map(|_| (rng.next_u64() % 7) as u16).collect();
            let bucket = (rng.next_u64() % 7) as u16;
            let base = vec_of(rng, blen);
            let run = |forced: bool| {
                let body = || {
                    let mut c = base.clone();
                    count_eq(&mut c, &row, bucket);
                    c
                };
                if forced {
                    dispatch::with_forced_scalar(body)
                } else {
                    dispatch::with_auto(body)
                }
            };
            let auto = run(false);
            let scalar = run(true);
            for (i, ((x, y), (&b, &id))) in
                auto.iter().zip(&scalar).zip(base.iter().zip(&row)).enumerate()
            {
                prop_assert!(x.to_bits() == y.to_bits(), "count_eq diverges at {i}");
                let want = b + (id == bucket) as u32 as f32;
                prop_assert!(x.to_bits() == want.to_bits(), "count_eq wrong at {i}");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_gather_bit_identical_and_correct() {
        check_default("simd-gather-tiers", |rng, _| {
            let r = gen::size(rng, 1, 40);
            let blen = gen::size(rng, 1, 64);
            let ids: Vec<u16> = (0..64).map(|_| (rng.next_u64() as usize % r) as u16).collect();
            let probs = vec_of(rng, r);
            let base = vec_of(rng, blen);
            let run = |forced: bool| {
                let body = || {
                    let mut acc = base.clone();
                    // SAFETY: ids are generated modulo r = probs.len()
                    // and ids.len() = 64 >= acc.len().
                    unsafe { gather_accumulate(&mut acc, &ids, &probs) };
                    acc
                };
                if forced {
                    dispatch::with_forced_scalar(body)
                } else {
                    dispatch::with_auto(body)
                }
            };
            let auto = run(false);
            let scalar = run(true);
            for (i, ((x, y), (&b, &id))) in
                auto.iter().zip(&scalar).zip(base.iter().zip(&ids)).enumerate()
            {
                prop_assert!(x.to_bits() == y.to_bits(), "gather diverges at {i}");
                let want = b + probs.get(id as usize).copied().unwrap_or(f32::NAN);
                prop_assert!(x.to_bits() == want.to_bits(), "gather wrong at {i}");
            }
            Ok(())
        });
    }

    #[test]
    fn dot_tail_lengths_cover_every_remainder() {
        for n in 0..=(3 * LANES + 1) {
            let a: Vec<f32> = (0..n).map(|i| (i as f32) * 0.25 + 1.0).collect();
            let b: Vec<f32> = (0..n).map(|i| 2.0 - (i as f32) * 0.125).collect();
            let auto = dispatch::with_auto(|| dot(&a, &b));
            let scalar = dispatch::with_forced_scalar(|| dot(&a, &b));
            assert_eq!(auto.to_bits(), scalar.to_bits(), "n={n}");
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((auto - naive).abs() <= 1e-3 * naive.abs().max(1.0), "n={n}");
        }
    }
}
