//! TCP line-protocol front-end over the coordinator.
//!
//! Protocol: one JSON object per line.
//! Request:  `{"op":"generate","context_len":N,"decode_len":M}`
//!           with optional `"method":"quest"|"magicpig"|...|"dense"`
//!           (any `selector::registry` name; default = engine config)
//!           and `"sparsity":S` (default = engine config),
//!           `{"op":"stats"}` · `{"op":"ping"}`
//! Response: `{"ok":true, ...}` or `{"ok":false,"error":"..."}`.
//! `stats` reports total served plus a per-method breakdown.
//!
//! std::net + a small thread pool (tokio is unavailable offline); each
//! connection is handled by a pool worker, requests route through the
//! shared [`Coordinator`]. Selector misuse (an unknown method name, a
//! bad sparsity) is a JSON error, never a worker panic.

use crate::coordinator::{BatchPolicy, Coordinator, EngineConfig};
use crate::selector::{self, AttentionMode};
use crate::util::Json;
use crate::workload::trace::Request;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Server state shared across connection handlers.
pub struct Server {
    coordinator: Arc<Coordinator>,
    next_id: Arc<AtomicU64>,
    served: Arc<AtomicU64>,
    /// Successful generates per method label (the `stats` breakdown).
    served_by_method: Arc<Mutex<BTreeMap<String, u64>>>,
    /// Label of the engine's default mode (used when a request names
    /// no method).
    default_label: String,
    /// Sparsity applied when a request names a method without one.
    default_sparsity: f64,
}

impl Server {
    pub fn new(config: EngineConfig, policy: BatchPolicy) -> Server {
        // Canonicalize the default label through the registry so stats
        // never split one method across an alias and its canonical name
        // (e.g. a server configured with "PQ" vs requests naming
        // "pqcache").
        let default_label = match &config.mode {
            AttentionMode::Dense => "dense".to_string(),
            AttentionMode::Sparse { method, .. } => selector::lookup(method)
                .map(|spec| spec.name.to_string())
                .unwrap_or_else(|_| method.clone()),
        };
        let default_sparsity = match &config.mode {
            AttentionMode::Sparse { sparsity, .. } => *sparsity,
            AttentionMode::Dense => 33.0, // the paper's headline budget
        };
        Server {
            coordinator: Arc::new(Coordinator::spawn(config, policy)),
            next_id: Arc::new(AtomicU64::new(1)),
            served: Arc::new(AtomicU64::new(0)),
            served_by_method: Arc::new(Mutex::new(BTreeMap::new())),
            default_label,
            default_sparsity,
        }
    }

    /// Resolve a request's optional `"method"`/`"sparsity"` fields into
    /// a per-request [`AttentionMode`] override plus its stats label.
    /// A bare `"sparsity"` (no method) re-budgets the server's default
    /// sparse method; it is an error against a dense default.
    fn request_mode(&self, msg: &Json) -> Result<(Option<AttentionMode>, String), String> {
        let sparsity = match msg.get("sparsity") {
            None => None,
            // A present-but-non-numeric sparsity is a client error, not
            // something to silently serve at the default budget.
            Some(v) => match v.as_f64() {
                Some(s) if s.is_nan() || s < 1.0 => {
                    return Err(format!("sparsity must be a number >= 1, got {s}"));
                }
                Some(s) => Some(s),
                None => return Err(format!("sparsity must be a number >= 1, got {v}")),
            },
        };
        let method = match msg.get("method").and_then(|m| m.as_str()) {
            None => match sparsity {
                // No overrides at all: engine default.
                None => return Ok((None, self.default_label.clone())),
                // Sparsity-only override: the default method re-budgeted.
                Some(s) => {
                    if self.default_label == "dense" {
                        return Err(format!(
                            "sparsity {s} requires a \"method\" (server default is dense)"
                        ));
                    }
                    let label = self.default_label.clone();
                    return Ok((
                        Some(AttentionMode::Sparse { method: label.clone(), sparsity: s }),
                        label,
                    ));
                }
            },
            Some(m) => m,
        };
        if method.eq_ignore_ascii_case("dense") {
            if let Some(s) = sparsity {
                return Err(format!("sparsity {s} is meaningless for method \"dense\""));
            }
            return Ok((Some(AttentionMode::Dense), "dense".to_string()));
        }
        let spec = selector::lookup(method).map_err(|e| e.to_string())?;
        let label = spec.name.to_string();
        let sparsity = sparsity.unwrap_or(self.default_sparsity);
        Ok((Some(AttentionMode::Sparse { method: label.clone(), sparsity }), label))
    }

    /// Handle one already-parsed request object (also used directly by
    /// unit tests — the wire layer is a thin shell around this).
    pub fn handle(&self, msg: &Json) -> Json {
        match msg.get("op").and_then(|o| o.as_str()) {
            Some("ping") => Json::obj().set("ok", true).set("pong", true),
            Some("stats") => {
                let mut methods = Json::obj();
                for (name, count) in self.served_by_method.lock().unwrap().iter() {
                    methods = methods.set(name, *count);
                }
                Json::obj()
                    .set("ok", true)
                    .set("served", self.served.load(Ordering::Relaxed))
                    .set("methods", methods)
            }
            Some("generate") => {
                let ctx = msg.get("context_len").and_then(|v| v.as_usize()).unwrap_or(0);
                let dec = msg.get("decode_len").and_then(|v| v.as_usize()).unwrap_or(0);
                if ctx == 0 || dec == 0 {
                    return Json::obj().set("ok", false).set("error", "context_len and decode_len must be positive");
                }
                let (mode, label) = match self.request_mode(msg) {
                    Ok(resolved) => resolved,
                    // Unknown method / bad sparsity: a typed JSON error
                    // straight from the registry, no queue round-trip.
                    Err(e) => return Json::obj().set("ok", false).set("error", e),
                };
                let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                let handle = self.coordinator.submit(Request {
                    id,
                    arrival_ms: 0.0,
                    context_len: ctx,
                    decode_len: dec,
                    mode,
                });
                let c = handle.wait();
                if !c.ok {
                    // Failed admission (e.g. request larger than the KV
                    // pool) — surface the scheduler's reason.
                    return Json::obj()
                        .set("ok", false)
                        .set("id", c.id)
                        .set("error", c.error.unwrap_or_else(|| "request rejected".to_string()));
                }
                self.served.fetch_add(1, Ordering::Relaxed);
                *self.served_by_method.lock().unwrap().entry(label.clone()).or_insert(0) += 1;
                Json::obj()
                    .set("ok", true)
                    .set("id", c.id)
                    .set("method", label)
                    .set("ttft_ms", c.ttft_ms)
                    .set("total_ms", c.total_ms)
                    .set("decode_len", c.decode_len)
            }
            Some(other) => Json::obj().set("ok", false).set("error", format!("unknown op '{other}'")),
            None => Json::obj().set("ok", false).set("error", "missing 'op'"),
        }
    }

    fn handle_line(&self, line: &str) -> Json {
        match Json::parse(line) {
            Ok(msg) => self.handle(&msg),
            Err(e) => Json::obj().set("ok", false).set("error", format!("bad json: {e}")),
        }
    }

    fn serve_conn(&self, stream: TcpStream) {
        let peer = stream.peer_addr().ok();
        let mut writer = match stream.try_clone() {
            Ok(w) => w,
            Err(_) => return,
        };
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let line = match line {
                Ok(l) => l,
                Err(_) => break,
            };
            if line.trim().is_empty() {
                continue;
            }
            let resp = self.handle_line(&line);
            if writeln!(writer, "{resp}").is_err() {
                break;
            }
        }
        let _ = peer;
    }

    /// Serve on `addr` with `n_workers` connection-handler threads until
    /// `stop` is set. Returns the bound local address.
    pub fn serve(
        self: Arc<Self>,
        addr: &str,
        n_workers: usize,
        stop: Arc<AtomicBool>,
    ) -> std::io::Result<std::net::SocketAddr> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        // Worker pool pulling accepted connections.
        for _ in 0..n_workers {
            let server = Arc::clone(&self);
            let conns = Arc::clone(&conns);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || loop {
                let conn = conns.lock().unwrap().pop();
                match conn {
                    Some(c) => server.serve_conn(c),
                    None => {
                        if stop.load(Ordering::Relaxed) {
                            return;
                        }
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                }
            });
        }
        // Acceptor thread.
        let stop_acc = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop_acc.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => conns.lock().unwrap().push(stream),
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(local)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::AttentionMode;
    use crate::lsh::LshParams;
    use crate::model::ModelConfig;

    fn server() -> Server {
        let config = EngineConfig {
            model: ModelConfig { head_dim: 16, n_kv_heads: 1, ..ModelConfig::tiny() },
            lsh: LshParams { p: 6, l: 8, tau: 0.5 },
            mode: AttentionMode::socket(8.0),
            capacity_pages: 1024,
            sink: 4,
            local: 4,
        };
        Server::new(config, BatchPolicy::default())
    }

    #[test]
    fn ping_and_stats() {
        let s = server();
        let pong = s.handle(&Json::parse(r#"{"op":"ping"}"#).unwrap());
        assert_eq!(pong.get("ok").unwrap().as_bool(), Some(true));
        let stats = s.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
        assert_eq!(stats.get("served").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn generate_round_trip() {
        let s = server();
        let resp = s.handle(&Json::parse(r#"{"op":"generate","context_len":64,"decode_len":2}"#).unwrap());
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        assert!(resp.get("total_ms").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(resp.get("method").unwrap().as_str(), Some("socket"));
        let stats = s.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
        assert_eq!(stats.get("served").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn per_request_methods_round_trip_with_stats() {
        // Quest and MagicPIG served end-to-end through the scheduler by
        // naming them in the request — plus the per-method breakdown.
        let s = server();
        for (method, times) in [("quest", 2usize), ("magicpig", 1), ("dense", 1)] {
            for _ in 0..times {
                let line = format!(
                    r#"{{"op":"generate","context_len":96,"decode_len":2,"method":"{method}"}}"#
                );
                let resp = s.handle(&Json::parse(&line).unwrap());
                assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{method}: {resp}");
                assert_eq!(resp.get("method").unwrap().as_str(), Some(method));
            }
        }
        // One request on the engine default (socket).
        let resp =
            s.handle(&Json::parse(r#"{"op":"generate","context_len":64,"decode_len":1}"#).unwrap());
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        let stats = s.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
        assert_eq!(stats.get("served").unwrap().as_usize(), Some(5));
        let methods = stats.get("methods").unwrap();
        assert_eq!(methods.get("quest").unwrap().as_usize(), Some(2));
        assert_eq!(methods.get("magicpig").unwrap().as_usize(), Some(1));
        assert_eq!(methods.get("dense").unwrap().as_usize(), Some(1));
        assert_eq!(methods.get("socket").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn unknown_method_and_bad_sparsity_are_json_errors() {
        let s = server();
        let resp = s.handle(
            &Json::parse(r#"{"op":"generate","context_len":64,"decode_len":2,"method":"zzz"}"#)
                .unwrap(),
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        let err = resp.get("error").unwrap().as_str().unwrap();
        assert!(err.contains("unknown method 'zzz'"), "{err}");
        assert!(err.contains("socket"), "error should list registered methods: {err}");

        let resp = s.handle(
            &Json::parse(
                r#"{"op":"generate","context_len":64,"decode_len":2,"method":"quest","sparsity":0.5}"#,
            )
            .unwrap(),
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("sparsity"), "{resp}");
        // Bare sparsity is validated too (no method field to hide behind).
        let resp = s.handle(
            &Json::parse(r#"{"op":"generate","context_len":64,"decode_len":2,"sparsity":0.5}"#)
                .unwrap(),
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        // ...as is a non-numeric sparsity (not silently dropped).
        let resp = s.handle(
            &Json::parse(
                r#"{"op":"generate","context_len":64,"decode_len":2,"method":"quest","sparsity":"64"}"#,
            )
            .unwrap(),
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("sparsity"), "{resp}");
        // Nothing was served or counted.
        let stats = s.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
        assert_eq!(stats.get("served").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn bare_sparsity_rebudgets_the_default_method() {
        // {"sparsity": S} without "method" re-budgets the server's
        // default sparse method instead of being silently dropped.
        let s = server();
        let resp = s.handle(
            &Json::parse(r#"{"op":"generate","context_len":64,"decode_len":1,"sparsity":4}"#)
                .unwrap(),
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        assert_eq!(resp.get("method").unwrap().as_str(), Some("socket"));
        let stats = s.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
        assert_eq!(stats.get("methods").unwrap().get("socket").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn errors_are_reported() {
        let s = server();
        for bad in [
            r#"{"op":"generate","context_len":0,"decode_len":2}"#,
            r#"{"op":"nonsense"}"#,
            r#"{"no_op":1}"#,
        ] {
            let resp = s.handle(&Json::parse(bad).unwrap());
            assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{bad}");
        }
        let resp = s.handle_line("not json at all");
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn oversized_generate_returns_error_not_hang() {
        let config = EngineConfig {
            model: ModelConfig { head_dim: 16, n_kv_heads: 1, ..ModelConfig::tiny() },
            lsh: LshParams { p: 6, l: 8, tau: 0.5 },
            mode: AttentionMode::socket(8.0),
            capacity_pages: 8, // 128 cacheable tokens
            sink: 4,
            local: 4,
        };
        let s = Server::new(config, BatchPolicy::default());
        let resp =
            s.handle(&Json::parse(r#"{"op":"generate","context_len":4096,"decode_len":2}"#).unwrap());
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("never admittable"));
        // The pool is untouched: a small request still succeeds.
        let small =
            s.handle(&Json::parse(r#"{"op":"generate","context_len":48,"decode_len":1}"#).unwrap());
        assert_eq!(small.get("ok").unwrap().as_bool(), Some(true), "{small}");
    }

    #[test]
    fn tcp_round_trip() {
        use std::io::{BufRead, BufReader, Write};
        let s = Arc::new(server());
        let stop = Arc::new(AtomicBool::new(false));
        let addr = Arc::clone(&s).serve("127.0.0.1:0", 2, Arc::clone(&stop)).unwrap();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        writeln!(conn, r#"{{"op":"generate","context_len":48,"decode_len":1}}"#).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{line}");
        stop.store(true, Ordering::Relaxed);
    }
}
