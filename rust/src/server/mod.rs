//! TCP line-protocol front-end over the coordinator.
//!
//! Protocol: one JSON object per line.
//! Request:  `{"op":"generate","context_len":N,"decode_len":M}`
//!           `{"op":"stats"}` · `{"op":"ping"}`
//! Response: `{"ok":true, ...}` or `{"ok":false,"error":"..."}`.
//!
//! std::net + a small thread pool (tokio is unavailable offline); each
//! connection is handled by a pool worker, requests route through the
//! shared [`Coordinator`].

use crate::coordinator::{BatchPolicy, Coordinator, EngineConfig};
use crate::util::Json;
use crate::workload::trace::Request;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Server state shared across connection handlers.
pub struct Server {
    coordinator: Arc<Coordinator>,
    next_id: Arc<AtomicU64>,
    served: Arc<AtomicU64>,
}

impl Server {
    pub fn new(config: EngineConfig, policy: BatchPolicy) -> Server {
        Server {
            coordinator: Arc::new(Coordinator::spawn(config, policy)),
            next_id: Arc::new(AtomicU64::new(1)),
            served: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Handle one already-parsed request object (also used directly by
    /// unit tests — the wire layer is a thin shell around this).
    pub fn handle(&self, msg: &Json) -> Json {
        match msg.get("op").and_then(|o| o.as_str()) {
            Some("ping") => Json::obj().set("ok", true).set("pong", true),
            Some("stats") => Json::obj()
                .set("ok", true)
                .set("served", self.served.load(Ordering::Relaxed)),
            Some("generate") => {
                let ctx = msg.get("context_len").and_then(|v| v.as_usize()).unwrap_or(0);
                let dec = msg.get("decode_len").and_then(|v| v.as_usize()).unwrap_or(0);
                if ctx == 0 || dec == 0 {
                    return Json::obj().set("ok", false).set("error", "context_len and decode_len must be positive");
                }
                let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                let handle = self.coordinator.submit(Request {
                    id,
                    arrival_ms: 0.0,
                    context_len: ctx,
                    decode_len: dec,
                });
                let c = handle.wait();
                if !c.ok {
                    // Failed admission (e.g. request larger than the KV
                    // pool) — surface the scheduler's reason.
                    return Json::obj()
                        .set("ok", false)
                        .set("id", c.id)
                        .set("error", c.error.unwrap_or_else(|| "request rejected".to_string()));
                }
                self.served.fetch_add(1, Ordering::Relaxed);
                Json::obj()
                    .set("ok", true)
                    .set("id", c.id)
                    .set("ttft_ms", c.ttft_ms)
                    .set("total_ms", c.total_ms)
                    .set("decode_len", c.decode_len)
            }
            Some(other) => Json::obj().set("ok", false).set("error", format!("unknown op '{other}'")),
            None => Json::obj().set("ok", false).set("error", "missing 'op'"),
        }
    }

    fn handle_line(&self, line: &str) -> Json {
        match Json::parse(line) {
            Ok(msg) => self.handle(&msg),
            Err(e) => Json::obj().set("ok", false).set("error", format!("bad json: {e}")),
        }
    }

    fn serve_conn(&self, stream: TcpStream) {
        let peer = stream.peer_addr().ok();
        let mut writer = match stream.try_clone() {
            Ok(w) => w,
            Err(_) => return,
        };
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let line = match line {
                Ok(l) => l,
                Err(_) => break,
            };
            if line.trim().is_empty() {
                continue;
            }
            let resp = self.handle_line(&line);
            if writeln!(writer, "{resp}").is_err() {
                break;
            }
        }
        let _ = peer;
    }

    /// Serve on `addr` with `n_workers` connection-handler threads until
    /// `stop` is set. Returns the bound local address.
    pub fn serve(
        self: Arc<Self>,
        addr: &str,
        n_workers: usize,
        stop: Arc<AtomicBool>,
    ) -> std::io::Result<std::net::SocketAddr> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        // Worker pool pulling accepted connections.
        for _ in 0..n_workers {
            let server = Arc::clone(&self);
            let conns = Arc::clone(&conns);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || loop {
                let conn = conns.lock().unwrap().pop();
                match conn {
                    Some(c) => server.serve_conn(c),
                    None => {
                        if stop.load(Ordering::Relaxed) {
                            return;
                        }
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                }
            });
        }
        // Acceptor thread.
        let stop_acc = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop_acc.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => conns.lock().unwrap().push(stream),
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(local)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::AttentionMode;
    use crate::lsh::LshParams;
    use crate::model::ModelConfig;

    fn server() -> Server {
        let config = EngineConfig {
            model: ModelConfig { head_dim: 16, n_kv_heads: 1, ..ModelConfig::tiny() },
            lsh: LshParams { p: 6, l: 8, tau: 0.5 },
            mode: AttentionMode::Socket { sparsity: 8.0 },
            capacity_pages: 1024,
            sink: 4,
            local: 4,
        };
        Server::new(config, BatchPolicy::default())
    }

    #[test]
    fn ping_and_stats() {
        let s = server();
        let pong = s.handle(&Json::parse(r#"{"op":"ping"}"#).unwrap());
        assert_eq!(pong.get("ok").unwrap().as_bool(), Some(true));
        let stats = s.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
        assert_eq!(stats.get("served").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn generate_round_trip() {
        let s = server();
        let resp = s.handle(&Json::parse(r#"{"op":"generate","context_len":64,"decode_len":2}"#).unwrap());
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        assert!(resp.get("total_ms").unwrap().as_f64().unwrap() >= 0.0);
        let stats = s.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
        assert_eq!(stats.get("served").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn errors_are_reported() {
        let s = server();
        for bad in [
            r#"{"op":"generate","context_len":0,"decode_len":2}"#,
            r#"{"op":"nonsense"}"#,
            r#"{"no_op":1}"#,
        ] {
            let resp = s.handle(&Json::parse(bad).unwrap());
            assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{bad}");
        }
        let resp = s.handle_line("not json at all");
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn oversized_generate_returns_error_not_hang() {
        let config = EngineConfig {
            model: ModelConfig { head_dim: 16, n_kv_heads: 1, ..ModelConfig::tiny() },
            lsh: LshParams { p: 6, l: 8, tau: 0.5 },
            mode: AttentionMode::Socket { sparsity: 8.0 },
            capacity_pages: 8, // 128 cacheable tokens
            sink: 4,
            local: 4,
        };
        let s = Server::new(config, BatchPolicy::default());
        let resp =
            s.handle(&Json::parse(r#"{"op":"generate","context_len":4096,"decode_len":2}"#).unwrap());
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("never admittable"));
        // The pool is untouched: a small request still succeeds.
        let small =
            s.handle(&Json::parse(r#"{"op":"generate","context_len":48,"decode_len":1}"#).unwrap());
        assert_eq!(small.get("ok").unwrap().as_bool(), Some(true), "{small}");
    }

    #[test]
    fn tcp_round_trip() {
        use std::io::{BufRead, BufReader, Write};
        let s = Arc::new(server());
        let stop = Arc::new(AtomicBool::new(false));
        let addr = Arc::clone(&s).serve("127.0.0.1:0", 2, Arc::clone(&stop)).unwrap();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        writeln!(conn, r#"{{"op":"generate","context_len":48,"decode_len":1}}"#).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{line}");
        stop.store(true, Ordering::Relaxed);
    }
}
