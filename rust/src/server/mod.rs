//! TCP line-protocol front-end over the coordinator.
//!
//! Protocol: one JSON object per line, one or more JSON lines back.
//!
//! * `{"op":"generate","context_len":N,"decode_len":M}` — serve one
//!   request. Optional fields:
//!   - `"method":"quest"|"magicpig"|...|"dense"` (any
//!     `selector::registry` name; default = engine config) and
//!     `"sparsity":S` (default = engine config).
//!   - `"session":"<id>"` — multi-turn session. The first turn on an id
//!     prefills and *parks* the sequence (KV pages + selector index stay
//!     live in the scheduler); follow-up turns on the same id append
//!     `context_len` new context tokens (0 = just keep decoding) and
//!     decode — **zero prefill tokens** on resumed turns. A session's
//!     attention mode is fixed at its first turn; idle sessions are
//!     evicted after `session_ttl` and their pages returned to the pool.
//!   - `"stream":true` — emit one `{"token":i,"ms":t}` line per decoded
//!     token, then the usual summary line with `"done":true`.
//!   - `"priority":"interactive"|"normal"|"batch"` — scheduling class
//!     (default `normal`). Admission is weighted toward higher classes,
//!     and under page exhaustion the scheduler preempts strictly lower
//!     ones. `"deadline_ms":D` bounds time-to-first-schedule: a request
//!     still queued when D elapses is shed with a `deadline_missed`
//!     error.
//! * `{"op":"stats"}` — totals served plus a per-method breakdown.
//! * `{"op":"metrics"}` — the full serving telemetry snapshot:
//!   per-method TTFT/TBT histograms (p50/p95/p99), KV pool utilization,
//!   scheduler counters (prefill vs session tokens, resumed turns),
//!   session table occupancy, and the prune-rate/threshold-warmup
//!   gauges fed from the scoring engine's `PruneStats`.
//! * `{"op":"ping"}` — liveness.
//!
//! Responses are `{"ok":true, ...}` or `{"ok":false,"error":"..."}`.
//!
//! std::net + a small thread pool (tokio is unavailable offline).
//! Accepted connections are handed to workers over an mpsc channel —
//! FIFO, so no connection starves behind later arrivals, and workers
//! block on the channel instead of spinning. Each request line runs
//! under `catch_unwind`: a handler panic answers with a JSON error and
//! the connection (and worker) live on; shared stats tolerate lock
//! poisoning. Shutdown propagates into every read loop, and
//! [`ServerHandle::shutdown`] joins all threads.

pub mod reloader;

use crate::coordinator::{BatchPolicy, Completion, Coordinator, EngineConfig, Submission};
use crate::kvcache::{PromptSegment, PromptSpec};
use crate::selector::{self, AttentionMode};
use crate::util::Json;
use crate::workload::trace::{Priority, Request};
use std::collections::{BTreeMap, HashMap};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Lock that survives poisoning: a panicking handler must not take the
/// stats/session tables down with it (the counters are plain integers —
/// every partial update is still a coherent value).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn err_json(msg: impl Into<String>) -> Json {
    Json::obj().set("ok", false).set("error", msg.into())
}

/// One live session: the parked sequence it owns plus bookkeeping for
/// TTL eviction and the stats surface.
struct SessionEntry {
    seq_id: u64,
    /// Canonical method label, fixed at the first turn.
    method: String,
    /// Context + decoded tokens accumulated across turns.
    tokens: usize,
    turns: u64,
    last_active: Instant,
    /// A turn is in flight — concurrent turns on one sequence are
    /// refused, and the sweeper never evicts a busy session.
    busy: bool,
}

/// Serving defaults a reload may swap at runtime (one lock so the
/// method/sparsity pair is always read coherently).
struct ServingDefaults {
    /// Label of the default mode (used when a request names no method).
    label: String,
    /// Sparsity applied when a request names a method without one.
    sparsity: f64,
}

/// Server state shared across connection handlers.
pub struct Server {
    coordinator: Coordinator,
    next_id: AtomicU64,
    served: AtomicU64,
    /// Successful generates per method label (the `stats` breakdown).
    served_by_method: Mutex<BTreeMap<String, u64>>,
    /// Hot-reloadable serving defaults (see [`reloader`]).
    defaults: Mutex<ServingDefaults>,
    /// Session-id → parked sequence. Guards every state transition of
    /// the session lifecycle (first turn, resume, evict).
    sessions: Mutex<HashMap<String, SessionEntry>>,
    sessions_evicted: AtomicU64,
    /// Idle sessions older than this are evicted by the sweeper.
    /// Mutexed so a config reload retunes the sweeper without restart
    /// (each sweep re-reads it).
    session_ttl: Mutex<Duration>,
    /// Config reloads applied so far (the `config` metrics section).
    reloads: AtomicU64,
}

impl Server {
    pub fn new(config: EngineConfig, policy: BatchPolicy) -> Server {
        // Canonicalize the default label through the registry so stats
        // never split one method across an alias and its canonical name
        // (e.g. a server configured with "PQ" vs requests naming
        // "pqcache").
        let default_label = match &config.mode {
            AttentionMode::Dense => "dense".to_string(),
            AttentionMode::Sparse { method, .. } => selector::lookup(method)
                .map(|spec| spec.name.to_string())
                .unwrap_or_else(|_| method.clone()),
        };
        let default_sparsity = match &config.mode {
            AttentionMode::Sparse { sparsity, .. } => *sparsity,
            AttentionMode::Dense => 33.0, // the paper's headline budget
        };
        Server {
            coordinator: Coordinator::spawn(config, policy),
            next_id: AtomicU64::new(1),
            served: AtomicU64::new(0),
            served_by_method: Mutex::new(BTreeMap::new()),
            defaults: Mutex::new(ServingDefaults { label: default_label, sparsity: default_sparsity }),
            sessions: Mutex::new(HashMap::new()),
            sessions_evicted: AtomicU64::new(0),
            session_ttl: Mutex::new(Duration::from_secs(300)),
            reloads: AtomicU64::new(0),
        }
    }

    /// Override the idle-session eviction TTL (default 300 s).
    pub fn with_session_ttl(self, ttl: Duration) -> Server {
        *lock(&self.session_ttl) = ttl;
        self
    }

    /// Apply a hot-reloaded serving config: batch policy swaps through
    /// the scheduler queue, defaults and TTL swap under their locks.
    /// Running requests and parked sessions are untouched.
    pub fn apply_reload(&self, cfg: &reloader::ReloadConfig) {
        if let Some(policy) = cfg.policy {
            self.coordinator.set_policy(policy);
        }
        {
            let mut d = lock(&self.defaults);
            if let Some(label) = &cfg.default_method {
                d.label = label.clone();
            }
            if let Some(s) = cfg.default_sparsity {
                d.sparsity = s;
            }
        }
        if let Some(ttl) = cfg.session_ttl {
            *lock(&self.session_ttl) = ttl;
        }
        // Relaxed: reload gauge for the metrics scrape only.
        self.reloads.fetch_add(1, Ordering::Relaxed);
    }

    /// Resolve a request's optional `"method"`/`"sparsity"` fields into
    /// a per-request [`AttentionMode`] override plus its stats label.
    /// A bare `"sparsity"` (no method) re-budgets the server's default
    /// sparse method; it is an error against a dense default.
    fn request_mode(&self, msg: &Json) -> Result<(Option<AttentionMode>, String), String> {
        let (default_label, default_sparsity) = {
            let d = lock(&self.defaults);
            (d.label.clone(), d.sparsity)
        };
        let sparsity = match msg.get("sparsity") {
            None => None,
            // A present-but-non-numeric sparsity is a client error, not
            // something to silently serve at the default budget.
            Some(v) => match v.as_f64() {
                Some(s) if s.is_nan() || s < 1.0 => {
                    return Err(format!("sparsity must be a number >= 1, got {s}"));
                }
                Some(s) => Some(s),
                None => return Err(format!("sparsity must be a number >= 1, got {v}")),
            },
        };
        let method = match msg.get("method").and_then(|m| m.as_str()) {
            None => match sparsity {
                // No overrides at all: the (reloadable) serving default.
                None => {
                    if default_label == "dense" {
                        return Ok((Some(AttentionMode::Dense), default_label));
                    }
                    // A reloaded default may differ from the engine's
                    // spawn-time mode, so resolve it explicitly rather
                    // than passing `None` through to the engine.
                    return Ok((
                        Some(AttentionMode::Sparse {
                            method: default_label.clone(),
                            sparsity: default_sparsity,
                        }),
                        default_label,
                    ));
                }
                // Sparsity-only override: the default method re-budgeted.
                Some(s) => {
                    if default_label == "dense" {
                        return Err(format!(
                            "sparsity {s} requires a \"method\" (server default is dense)"
                        ));
                    }
                    return Ok((
                        Some(AttentionMode::Sparse { method: default_label.clone(), sparsity: s }),
                        default_label,
                    ));
                }
            },
            Some(m) => m,
        };
        if method.eq_ignore_ascii_case("dense") {
            if let Some(s) = sparsity {
                return Err(format!("sparsity {s} is meaningless for method \"dense\""));
            }
            return Ok((Some(AttentionMode::Dense), "dense".to_string()));
        }
        let spec = selector::lookup(method).map_err(|e| e.to_string())?;
        let label = spec.name.to_string();
        let sparsity = sparsity.unwrap_or(default_sparsity);
        Ok((Some(AttentionMode::Sparse { method: label.clone(), sparsity }), label))
    }

    /// Parse the optional `"prompt"` field: a string (hashed into one
    /// content segment covering the context) or an array of
    /// `{"seed":N,"len":N}` segments summing to `context_len`.
    /// `"cache":"off"` opts the request out of prefix sharing while
    /// keeping its declared content identity.
    fn request_prompt(msg: &Json, ctx: usize) -> Result<Option<PromptSpec>, String> {
        let cache = match msg.get("cache").and_then(|v| v.as_str()) {
            Some("off") => false,
            Some(other) if other != "on" => {
                return Err(format!("cache must be \"on\" or \"off\", got \"{other}\""));
            }
            _ => true,
        };
        let p = match msg.get("prompt") {
            None => return Ok(None),
            Some(p) => p,
        };
        if let Some(text) = p.as_str() {
            return Ok(Some(PromptSpec { cache, ..PromptSpec::from_text(text, ctx) }));
        }
        let arr = p
            .as_arr()
            .ok_or("prompt must be a string or an array of {seed,len} segments")?;
        let mut segments = Vec::with_capacity(arr.len());
        for seg in arr {
            let seed = seg
                .get("seed")
                .and_then(|v| v.as_usize())
                .ok_or("prompt segment needs a non-negative integer \"seed\"")?;
            let len = seg
                .get("len")
                .and_then(|v| v.as_usize())
                .filter(|&l| l > 0)
                .ok_or("prompt segment needs a positive \"len\"")?;
            segments.push(PromptSegment { seed: seed as u64, len });
        }
        let spec = PromptSpec { segments, cache };
        if spec.total_len() != ctx {
            return Err(format!(
                "prompt segments cover {} tokens but context_len is {ctx}",
                spec.total_len()
            ));
        }
        Ok(Some(spec))
    }

    /// Parse the scheduling knobs shared by every generate shape:
    /// `"priority"` (scheduling class) and `"deadline_ms"` (a finite
    /// non-negative time-to-first-schedule bound).
    fn request_scheduling(msg: &Json) -> Result<(Priority, Option<f64>), String> {
        let priority = match msg.get("priority") {
            None => Priority::default(),
            Some(v) => match v.as_str() {
                Some(name) => Priority::parse(name)?,
                None => return Err(format!("priority must be a string, got {v}")),
            },
        };
        let deadline_ms = match msg.get("deadline_ms") {
            None => None,
            Some(v) => match v.as_f64() {
                Some(ms) if ms.is_finite() && ms >= 0.0 => Some(ms),
                _ => {
                    return Err(format!(
                        "deadline_ms must be a finite non-negative number, got {v}"
                    ));
                }
            },
        };
        Ok((priority, deadline_ms))
    }

    /// Submit one turn and await its completion. With `stream` set, the
    /// scheduler's per-token events are emitted as JSON lines while the
    /// turn decodes; the token channel disconnects only after the
    /// completion is delivered, so draining it to exhaustion loses
    /// nothing.
    fn run_turn(
        &self,
        req: Request,
        keep_alive: bool,
        resume: bool,
        stream: bool,
        emit: &mut dyn FnMut(Json),
    ) -> Completion {
        let (tokens, token_rx) = if stream {
            let (tx, rx) = channel();
            (Some(tx), Some(rx))
        } else {
            (None, None)
        };
        let handle = self.coordinator.submit_opts(Submission { req, keep_alive, resume, tokens });
        if let Some(rx) = token_rx {
            while let Ok(ev) = rx.recv() {
                emit(Json::obj().set("token", ev.index).set("ms", ev.ms));
            }
        }
        handle.wait()
    }

    /// Relaxed add: the served counter is a gauge for the stats
    /// endpoint; nothing synchronizes through it.
    fn count_served(&self, label: &str) {
        self.served.fetch_add(1, Ordering::Relaxed);
        *lock(&self.served_by_method).entry(label.to_string()).or_insert(0) += 1;
    }

    fn summary(c: &Completion, label: &str, stream: bool) -> Json {
        let mut resp = Json::obj()
            .set("ok", true)
            .set("id", c.id)
            .set("method", label)
            .set("ttft_ms", c.ttft_ms)
            .set("total_ms", c.total_ms)
            .set("decode_len", c.decode_len);
        if stream {
            resp = resp.set("done", true);
        }
        resp
    }

    fn generate_oneshot(
        &self,
        msg: &Json,
        dec: usize,
        stream: bool,
        emit: &mut dyn FnMut(Json),
    ) -> Json {
        let ctx = msg.get("context_len").and_then(|v| v.as_usize()).unwrap_or(0);
        if ctx == 0 || dec == 0 {
            return err_json("context_len and decode_len must be positive");
        }
        let (mode, label) = match self.request_mode(msg) {
            Ok(resolved) => resolved,
            // Unknown method / bad sparsity: a typed JSON error
            // straight from the registry, no queue round-trip.
            Err(e) => return err_json(e),
        };
        let prompt = match Self::request_prompt(msg, ctx) {
            Ok(p) => p,
            Err(e) => return err_json(e),
        };
        let (priority, deadline_ms) = match Self::request_scheduling(msg) {
            Ok(s) => s,
            Err(e) => return err_json(e),
        };
        // Relaxed id allocation: fetch_add is atomic at any ordering,
        // so ids stay unique; nothing else hangs off this cell.
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            id,
            context_len: ctx,
            decode_len: dec,
            mode,
            prompt,
            priority,
            deadline_ms,
            ..Request::default()
        };
        let c = self.run_turn(req, false, false, stream, emit);
        if !c.ok {
            // Failed admission (e.g. request larger than the KV
            // pool) — surface the scheduler's reason.
            return err_json(c.error.unwrap_or_else(|| "request rejected".to_string()))
                .set("id", c.id);
        }
        self.count_served(&label);
        Self::summary(&c, &label, stream)
    }

    fn generate_session(
        &self,
        msg: &Json,
        sid: &str,
        dec: usize,
        stream: bool,
        emit: &mut dyn FnMut(Json),
    ) -> Json {
        if dec == 0 {
            return err_json("decode_len must be positive");
        }
        let ctx = msg.get("context_len").and_then(|v| v.as_usize()).unwrap_or(0);
        // Resolve the session under the table lock; mark it busy before
        // releasing so concurrent turns and the TTL sweeper stay out.
        let mut sessions = lock(&self.sessions);
        if let Some(entry) = sessions.get_mut(sid) {
            if entry.busy {
                return err_json(format!("session '{sid}' already has a turn in flight"));
            }
            if msg.get("method").is_some() || msg.get("sparsity").is_some() {
                return err_json(
                    "a session's attention mode is fixed at its first turn; \
                     drop \"method\"/\"sparsity\" on resumed turns",
                );
            }
            entry.busy = true;
            let seq = entry.seq_id;
            let label = entry.method.clone();
            drop(sessions);
            // Resumed turn: the scheduler appends `ctx` tokens to the
            // parked index — zero prefill tokens, and no prompt spec
            // (prefix sharing applies to prefills only).
            let (priority, deadline_ms) = match Self::request_scheduling(msg) {
                Ok(s) => s,
                Err(e) => {
                    if let Some(entry) = lock(&self.sessions).get_mut(sid) {
                        entry.busy = false;
                    }
                    return err_json(e);
                }
            };
            let req = Request {
                id: seq,
                context_len: ctx,
                decode_len: dec,
                priority,
                deadline_ms,
                ..Request::default()
            };
            let c = self.run_turn(req, true, true, stream, emit);
            let (turns, toks) = {
                let mut sessions = lock(&self.sessions);
                match sessions.get_mut(sid) {
                    Some(entry) => {
                        entry.busy = false;
                        entry.last_active = Instant::now();
                        if c.ok {
                            entry.tokens += ctx + dec;
                            entry.turns += 1;
                        }
                        (entry.turns, entry.tokens)
                    }
                    None => (0, 0),
                }
            };
            if !c.ok {
                // The scheduler re-parked the sequence; the session
                // survives a failed (e.g. oversized) turn.
                return err_json(c.error.unwrap_or_else(|| "request rejected".to_string()))
                    .set("id", c.id)
                    .set("session", sid);
            }
            self.count_served(&label);
            Self::summary(&c, &label, stream)
                .set("session", sid)
                .set("turn", turns)
                .set("session_tokens", toks)
        } else {
            // First turn: prefill + park.
            if ctx == 0 {
                return err_json("context_len must be positive on a session's first turn");
            }
            let (mode, label) = match self.request_mode(msg) {
                Ok(resolved) => resolved,
                Err(e) => return err_json(e),
            };
            // Relaxed id allocation: atomicity alone guarantees unique
            // session seq ids; no ordering is required.
            let seq = self.next_id.fetch_add(1, Ordering::Relaxed);
            sessions.insert(
                sid.to_string(),
                SessionEntry {
                    seq_id: seq,
                    method: label.clone(),
                    tokens: 0,
                    turns: 0,
                    last_active: Instant::now(),
                    busy: true,
                },
            );
            drop(sessions);
            let prompt = match Self::request_prompt(msg, ctx) {
                Ok(p) => p,
                Err(e) => {
                    lock(&self.sessions).remove(sid);
                    return err_json(e);
                }
            };
            let (priority, deadline_ms) = match Self::request_scheduling(msg) {
                Ok(s) => s,
                Err(e) => {
                    lock(&self.sessions).remove(sid);
                    return err_json(e);
                }
            };
            let req = Request {
                id: seq,
                context_len: ctx,
                decode_len: dec,
                mode,
                prompt,
                priority,
                deadline_ms,
                ..Request::default()
            };
            let c = self.run_turn(req, true, false, stream, emit);
            let mut sessions = lock(&self.sessions);
            if !c.ok {
                // Nothing was parked — drop the stillborn session.
                sessions.remove(sid);
                return err_json(c.error.unwrap_or_else(|| "request rejected".to_string()))
                    .set("id", c.id)
                    .set("session", sid);
            }
            let (turns, toks) = match sessions.get_mut(sid) {
                Some(entry) => {
                    entry.busy = false;
                    entry.last_active = Instant::now();
                    entry.tokens = ctx + dec;
                    entry.turns = 1;
                    (entry.turns, entry.tokens)
                }
                None => (1, ctx + dec),
            };
            drop(sessions);
            self.count_served(&label);
            Self::summary(&c, &label, stream)
                .set("session", sid)
                .set("turn", turns)
                .set("session_tokens", toks)
        }
    }

    /// The `metrics` op: serving telemetry snapshot (see module doc for
    /// the schema).
    fn metrics_json(&self) -> Json {
        let snap = match self.coordinator.snapshot() {
            Some(s) => s,
            None => return err_json("scheduler unavailable"),
        };
        let used = snap.total_pages - snap.free_pages;
        let pool = Json::obj()
            .set("free_pages", snap.free_pages)
            .set("total_pages", snap.total_pages)
            .set("used_pages", used)
            .set("utilization", used as f64 / snap.total_pages.max(1) as f64);
        let sessions = Json::obj()
            .set("active", lock(&self.sessions).len())
            .set("parked", snap.parked_sessions)
            // Relaxed gauge read: best-effort scrape, exact at rest.
            .set("evicted", self.sessions_evicted.load(Ordering::Relaxed));
        let registry = self.coordinator.metrics();
        let config = {
            let d = lock(&self.defaults);
            Json::obj()
                .set("default_method", d.label.clone())
                .set("default_sparsity", d.sparsity)
                .set("session_ttl_secs", lock(&self.session_ttl).as_secs_f64())
                // Relaxed gauge read: best-effort scrape, exact at rest.
                .set("reloads", self.reloads.load(Ordering::Relaxed))
        };
        Json::obj()
            .set("ok", true)
            .set("pool", pool)
            .set("scheduler", snap.stats.to_json())
            .set("sessions", sessions)
            .set("methods", registry.methods_json())
            .set("classes", registry.classes_json())
            .set("pressure", registry.pressure_json())
            .set("prune", registry.prune_json())
            .set("prefix", registry.prefix_json())
            .set("config", config)
    }

    /// Handle one already-parsed request object, emitting one or more
    /// response objects (streaming generates emit a line per token
    /// before the summary). Also used directly by unit tests — the wire
    /// layer is a thin shell around this.
    pub fn handle_with(&self, msg: &Json, emit: &mut dyn FnMut(Json)) {
        let resp = match msg.get("op").and_then(|o| o.as_str()) {
            Some("ping") => Json::obj().set("ok", true).set("pong", true),
            Some("stats") => {
                let mut methods = Json::obj();
                for (name, count) in lock(&self.served_by_method).iter() {
                    methods = methods.set(name, *count);
                }
                Json::obj()
                    .set("ok", true)
                    // Relaxed gauge read: stats scrape, best effort.
                    .set("served", self.served.load(Ordering::Relaxed))
                    .set("methods", methods)
                    .set("sessions", lock(&self.sessions).len())
            }
            Some("metrics") => self.metrics_json(),
            Some("generate") => {
                let stream = msg.get("stream").and_then(|v| v.as_bool()).unwrap_or(false);
                let dec = msg.get("decode_len").and_then(|v| v.as_usize()).unwrap_or(0);
                match msg.get("session").and_then(|s| s.as_str()) {
                    Some(sid) => self.generate_session(msg, sid, dec, stream, emit),
                    None => self.generate_oneshot(msg, dec, stream, emit),
                }
            }
            // Test hook for the panic-isolation path: dies while
            // holding the stats lock, poisoning it on purpose.
            Some("__test_panic") if cfg!(test) => {
                let _guard = self.served_by_method.lock();
                panic!("test-induced handler panic");
            }
            Some(other) => err_json(format!("unknown op '{other}'")),
            None => err_json("missing 'op'"),
        };
        emit(resp);
    }

    /// Single-response convenience over [`Server::handle_with`]: returns
    /// the final (summary) object, discarding streamed token lines.
    pub fn handle(&self, msg: &Json) -> Json {
        let mut last = None;
        self.handle_with(msg, &mut |resp| last = Some(resp));
        last.unwrap_or_else(|| err_json("no response"))
    }

    /// Parse + handle one request line (single-response form).
    pub fn handle_line(&self, line: &str) -> Json {
        match Json::parse(line) {
            Ok(msg) => self.handle(&msg),
            Err(e) => err_json(format!("bad json: {e}")),
        }
    }

    /// Run one request line against the connection, panic-isolated: a
    /// panicking handler answers with a JSON error instead of killing
    /// the worker thread. Returns `false` when the connection is dead
    /// (write failed).
    fn dispatch_line(&self, line: &str, writer: &mut TcpStream) -> bool {
        let mut write_failed = false;
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut emit = |resp: Json| {
                if writeln!(writer, "{resp}").is_err() {
                    write_failed = true;
                }
            };
            match Json::parse(line) {
                Ok(msg) => self.handle_with(&msg, &mut emit),
                Err(e) => emit(err_json(format!("bad json: {e}"))),
            }
        }));
        if outcome.is_err()
            && writeln!(writer, "{}", err_json("internal error: handler panicked")).is_err()
        {
            return false;
        }
        !write_failed
    }

    /// Handle one connection until EOF, error, or server stop. The read
    /// loop ticks on a short timeout so a stop request terminates even
    /// while an idle client keeps the connection open. Lines are
    /// reassembled from raw bytes (a read timeout can split a line —
    /// including mid-codepoint — so no BufReader::read_line here).
    fn serve_conn(&self, mut stream: TcpStream, stop: &AtomicBool) {
        let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
        let mut writer = match stream.try_clone() {
            Ok(w) => w,
            Err(_) => return,
        };
        let mut buf: Vec<u8> = Vec::new();
        let mut chunk = [0u8; 4096];
        loop {
            while let Some(pos) = buf.iter().position(|&b| b == b'\n') {
                let raw: Vec<u8> = buf.drain(..=pos).collect();
                let line = String::from_utf8_lossy(&raw[..raw.len() - 1]);
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                if !self.dispatch_line(line, &mut writer) {
                    return;
                }
            }
            // Relaxed stop-flag read: shutdown latency is bounded by
            // the 100ms read timeout, not by memory-ordering fences.
            if stop.load(Ordering::Relaxed) {
                return;
            }
            match stream.read(&mut chunk) {
                Ok(0) => return, // EOF
                Ok(n) => buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    // Timeout tick: loop back and re-check `stop`.
                }
                Err(_) => return,
            }
        }
    }

    /// Evict sessions idle for at least `ttl`, releasing their parked
    /// sequences' pages back to the pool. Returns how many were
    /// evicted. (Called periodically by the sweeper thread; exposed for
    /// tests and embedders driving their own clock.)
    pub fn evict_idle_sessions(&self, ttl: Duration) -> usize {
        let expired: Vec<u64> = {
            let mut sessions = lock(&self.sessions);
            let keys: Vec<String> = sessions
                .iter()
                .filter(|(_, e)| !e.busy && e.last_active.elapsed() >= ttl)
                .map(|(k, _)| k.clone())
                .collect();
            keys.iter().map(|k| sessions.remove(k).unwrap().seq_id).collect()
        };
        for seq in &expired {
            self.coordinator.release(*seq);
        }
        // Relaxed add: eviction gauge for the stats scrape only.
        self.sessions_evicted.fetch_add(expired.len() as u64, Ordering::Relaxed);
        expired.len()
    }

    /// Serve on `addr` with `n_workers` connection-handler threads.
    /// Returns a [`ServerHandle`]; dropping it (or calling
    /// [`ServerHandle::shutdown`]) stops and joins every thread —
    /// acceptor, workers, and the session sweeper.
    pub fn serve(self: &Arc<Self>, addr: &str, n_workers: usize) -> std::io::Result<ServerHandle> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        // FIFO connection queue: the acceptor feeds, workers block on
        // recv. No busy-wait, and — unlike the LIFO stack this replaced
        // — a burst of connections drains oldest-first, so an early
        // connection can no longer starve behind every later arrival.
        let (conn_tx, conn_rx) = channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut threads = Vec::with_capacity(n_workers + 2);
        for i in 0..n_workers.max(1) {
            let server = Arc::clone(self);
            let conn_rx = Arc::clone(&conn_rx);
            let stop = Arc::clone(&stop);
            let worker = std::thread::Builder::new()
                .name(format!("socketd-worker-{i}"))
                .spawn(move || loop {
                    // Holding the mutex while blocked in recv is fine:
                    // channel handoff wakes exactly one waiter, and the
                    // guard drops before the connection is served.
                    let conn = lock(&conn_rx).recv();
                    match conn {
                        Ok(c) => server.serve_conn(c, &stop),
                        // Acceptor gone (shutdown): queue is drained.
                        Err(_) => return,
                    }
                })?;
            threads.push(worker);
        }
        // Acceptor: blocking accept — shutdown wakes it with a
        // self-connection, after which it drops `conn_tx` and the
        // workers drain out.
        let stop_acc = Arc::clone(&stop);
        let acceptor = std::thread::Builder::new().name("socketd-acceptor".into()).spawn(
            move || loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        // Relaxed stop-flag reads (here and below): the
                        // unblocking connect provides the wakeup; no
                        // ordering is needed, only eventual visibility.
                        if stop_acc.load(Ordering::Relaxed) {
                            return;
                        }
                        if conn_tx.send(stream).is_err() {
                            return;
                        }
                    }
                    Err(_) => {
                        // Relaxed: same stop-flag protocol as above.
                        if stop_acc.load(Ordering::Relaxed) {
                            return;
                        }
                        std::thread::sleep(Duration::from_millis(10));
                    }
                }
            },
        )?;
        threads.push(acceptor);
        // Sweeper: periodic idle-session TTL eviction. Ticks every
        // 100 ms so shutdown is prompt; sweeps at most ~1/s.
        let sweeper_srv = Arc::clone(self);
        let stop_sweep = Arc::clone(&stop);
        let sweeper =
            std::thread::Builder::new().name("socketd-sweeper".into()).spawn(move || {
                let tick = Duration::from_millis(100);
                let mut since_sweep = Duration::ZERO;
                // Relaxed stop-flag read: visibility within one 100ms
                // tick suffices; no ordering with the sweep itself.
                while !stop_sweep.load(Ordering::Relaxed) {
                    std::thread::sleep(tick);
                    since_sweep += tick;
                    // Re-read the TTL every tick so a hot reload
                    // retunes both the cadence and the eviction bar.
                    let ttl = *lock(&sweeper_srv.session_ttl);
                    let cadence = Duration::from_secs(1).min(ttl).max(tick);
                    if since_sweep >= cadence {
                        sweeper_srv.evict_idle_sessions(ttl);
                        since_sweep = Duration::ZERO;
                    }
                }
            })?;
        threads.push(sweeper);
        Ok(ServerHandle { addr: local, stop, threads })
    }
}

/// Running server: bound address + every spawned thread. Dropping the
/// handle performs a graceful shutdown — stop flag, acceptor wake-up,
/// and a join of acceptor, workers (their read loops tick the stop
/// flag, so idle open connections don't wedge them), and sweeper.
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the server and join all threads.
    pub fn shutdown(self) {
        // Drop impl does the work.
    }

    /// Block until the server exits on its own (it doesn't, absent a
    /// signal — this parks the main thread of a daemon binary).
    pub fn wait(mut self) {
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        // Relaxed stop-flag store: readers poll on timeouts, and the
        // thread joins below are full synchronization points anyway.
        self.stop.store(true, Ordering::Relaxed);
        // Wake the acceptor out of its blocking accept.
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::AttentionMode;
    use crate::lsh::LshParams;
    use crate::model::ModelConfig;

    fn server() -> Server {
        let config = EngineConfig {
            model: ModelConfig { head_dim: 16, n_kv_heads: 1, ..ModelConfig::tiny() },
            lsh: LshParams { p: 6, l: 8, tau: 0.5 },
            mode: AttentionMode::socket(8.0),
            capacity_pages: 1024,
            sink: 4,
            local: 4,
        };
        Server::new(config, BatchPolicy::default())
    }

    #[test]
    fn ping_and_stats() {
        let s = server();
        let pong = s.handle(&Json::parse(r#"{"op":"ping"}"#).unwrap());
        assert_eq!(pong.get("ok").unwrap().as_bool(), Some(true));
        let stats = s.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
        assert_eq!(stats.get("served").unwrap().as_usize(), Some(0));
        assert_eq!(stats.get("sessions").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn generate_round_trip() {
        let s = server();
        let resp = s.handle(&Json::parse(r#"{"op":"generate","context_len":64,"decode_len":2}"#).unwrap());
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        assert!(resp.get("total_ms").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(resp.get("method").unwrap().as_str(), Some("socket"));
        let stats = s.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
        assert_eq!(stats.get("served").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn per_request_methods_round_trip_with_stats() {
        // Quest and MagicPIG served end-to-end through the scheduler by
        // naming them in the request — plus the per-method breakdown.
        let s = server();
        for (method, times) in [("quest", 2usize), ("magicpig", 1), ("dense", 1)] {
            for _ in 0..times {
                let line = format!(
                    r#"{{"op":"generate","context_len":96,"decode_len":2,"method":"{method}"}}"#
                );
                let resp = s.handle(&Json::parse(&line).unwrap());
                assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{method}: {resp}");
                assert_eq!(resp.get("method").unwrap().as_str(), Some(method));
            }
        }
        // One request on the engine default (socket).
        let resp =
            s.handle(&Json::parse(r#"{"op":"generate","context_len":64,"decode_len":1}"#).unwrap());
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        let stats = s.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
        assert_eq!(stats.get("served").unwrap().as_usize(), Some(5));
        let methods = stats.get("methods").unwrap();
        assert_eq!(methods.get("quest").unwrap().as_usize(), Some(2));
        assert_eq!(methods.get("magicpig").unwrap().as_usize(), Some(1));
        assert_eq!(methods.get("dense").unwrap().as_usize(), Some(1));
        assert_eq!(methods.get("socket").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn unknown_method_and_bad_sparsity_are_json_errors() {
        let s = server();
        let resp = s.handle(
            &Json::parse(r#"{"op":"generate","context_len":64,"decode_len":2,"method":"zzz"}"#)
                .unwrap(),
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        let err = resp.get("error").unwrap().as_str().unwrap();
        assert!(err.contains("unknown method 'zzz'"), "{err}");
        assert!(err.contains("socket"), "error should list registered methods: {err}");

        let resp = s.handle(
            &Json::parse(
                r#"{"op":"generate","context_len":64,"decode_len":2,"method":"quest","sparsity":0.5}"#,
            )
            .unwrap(),
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("sparsity"), "{resp}");
        // Bare sparsity is validated too (no method field to hide behind).
        let resp = s.handle(
            &Json::parse(r#"{"op":"generate","context_len":64,"decode_len":2,"sparsity":0.5}"#)
                .unwrap(),
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        // ...as is a non-numeric sparsity (not silently dropped).
        let resp = s.handle(
            &Json::parse(
                r#"{"op":"generate","context_len":64,"decode_len":2,"method":"quest","sparsity":"64"}"#,
            )
            .unwrap(),
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("sparsity"), "{resp}");
        // Nothing was served or counted.
        let stats = s.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
        assert_eq!(stats.get("served").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn bare_sparsity_rebudgets_the_default_method() {
        // {"sparsity": S} without "method" re-budgets the server's
        // default sparse method instead of being silently dropped.
        let s = server();
        let resp = s.handle(
            &Json::parse(r#"{"op":"generate","context_len":64,"decode_len":1,"sparsity":4}"#)
                .unwrap(),
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        assert_eq!(resp.get("method").unwrap().as_str(), Some("socket"));
        let stats = s.handle(&Json::parse(r#"{"op":"stats"}"#).unwrap());
        assert_eq!(stats.get("methods").unwrap().get("socket").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn priority_and_deadline_ride_the_wire() {
        let s = server();
        // Every class name is accepted (case-insensitive), with or
        // without a deadline.
        for prio in ["interactive", "normal", "batch", "Interactive"] {
            let line = format!(
                r#"{{"op":"generate","context_len":48,"decode_len":1,"priority":"{prio}","deadline_ms":60000}}"#
            );
            let resp = s.handle(&Json::parse(&line).unwrap());
            assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{prio}: {resp}");
        }
        // Served requests feed the per-class latency series.
        let m = s.handle(&Json::parse(r#"{"op":"metrics"}"#).unwrap());
        let classes = m.get("classes").expect("metrics must carry a classes section");
        assert!(classes.get("interactive").is_some(), "{m}");
        assert!(classes.get("batch").is_some(), "{m}");
        // The pressure schema is complete even when every counter is 0.
        let pressure = m.get("pressure").expect("metrics must carry a pressure section");
        for key in ["preemptions", "chunked_prefills", "shed", "deadline_missed"] {
            assert_eq!(pressure.get(key).and_then(|v| v.as_usize()), Some(0), "{m}");
        }
        // Bad values are typed client errors, not silently defaulted.
        let resp = s.handle(
            &Json::parse(r#"{"op":"generate","context_len":48,"decode_len":1,"priority":"vip"}"#)
                .unwrap(),
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("priority"), "{resp}");
        let resp = s.handle(
            &Json::parse(r#"{"op":"generate","context_len":48,"decode_len":1,"deadline_ms":-5}"#)
                .unwrap(),
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("deadline_ms"), "{resp}");
        // A session turn rejects bad knobs without wedging the session.
        let t1 = s.handle(
            &Json::parse(r#"{"op":"generate","session":"p","context_len":48,"decode_len":1}"#)
                .unwrap(),
        );
        assert_eq!(t1.get("ok").unwrap().as_bool(), Some(true), "{t1}");
        let bad = s.handle(
            &Json::parse(
                r#"{"op":"generate","session":"p","context_len":16,"decode_len":1,"priority":7}"#,
            )
            .unwrap(),
        );
        assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false), "{bad}");
        let t2 = s.handle(
            &Json::parse(
                r#"{"op":"generate","session":"p","context_len":16,"decode_len":1,"priority":"interactive"}"#,
            )
            .unwrap(),
        );
        assert_eq!(t2.get("ok").unwrap().as_bool(), Some(true), "session must survive: {t2}");
    }

    #[test]
    fn errors_are_reported() {
        let s = server();
        for bad in [
            r#"{"op":"generate","context_len":0,"decode_len":2}"#,
            r#"{"op":"nonsense"}"#,
            r#"{"no_op":1}"#,
        ] {
            let resp = s.handle(&Json::parse(bad).unwrap());
            assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{bad}");
        }
        let resp = s.handle_line("not json at all");
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn oversized_generate_returns_error_not_hang() {
        let config = EngineConfig {
            model: ModelConfig { head_dim: 16, n_kv_heads: 1, ..ModelConfig::tiny() },
            lsh: LshParams { p: 6, l: 8, tau: 0.5 },
            mode: AttentionMode::socket(8.0),
            capacity_pages: 8, // 128 cacheable tokens
            sink: 4,
            local: 4,
        };
        let s = Server::new(config, BatchPolicy::default());
        let resp =
            s.handle(&Json::parse(r#"{"op":"generate","context_len":4096,"decode_len":2}"#).unwrap());
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("never admittable"));
        // The pool is untouched: a small request still succeeds.
        let small =
            s.handle(&Json::parse(r#"{"op":"generate","context_len":48,"decode_len":1}"#).unwrap());
        assert_eq!(small.get("ok").unwrap().as_bool(), Some(true), "{small}");
    }

    #[test]
    fn session_two_turns_resume_with_zero_prefill() {
        // The tentpole: turn 2 on a live session appends context instead
        // of re-prefilling — asserted via the scheduler's own counters.
        let s = server();
        let t1 = s.handle(
            &Json::parse(r#"{"op":"generate","session":"chat-1","context_len":128,"decode_len":2}"#)
                .unwrap(),
        );
        assert_eq!(t1.get("ok").unwrap().as_bool(), Some(true), "{t1}");
        assert_eq!(t1.get("session").unwrap().as_str(), Some("chat-1"));
        assert_eq!(t1.get("turn").unwrap().as_usize(), Some(1));
        assert_eq!(t1.get("session_tokens").unwrap().as_usize(), Some(130));

        let t2 = s.handle(
            &Json::parse(r#"{"op":"generate","session":"chat-1","context_len":64,"decode_len":2}"#)
                .unwrap(),
        );
        assert_eq!(t2.get("ok").unwrap().as_bool(), Some(true), "{t2}");
        assert_eq!(t2.get("turn").unwrap().as_usize(), Some(2));
        assert_eq!(t2.get("session_tokens").unwrap().as_usize(), Some(196));

        let m = s.handle(&Json::parse(r#"{"op":"metrics"}"#).unwrap());
        assert_eq!(m.get("ok").unwrap().as_bool(), Some(true), "{m}");
        let sched = m.get("scheduler").unwrap();
        assert_eq!(sched.get("prefill_tokens").unwrap().as_usize(), Some(128), "{m}");
        assert_eq!(sched.get("session_tokens").unwrap().as_usize(), Some(64), "{m}");
        assert_eq!(sched.get("resumed_turns").unwrap().as_usize(), Some(1), "{m}");
        let sessions = m.get("sessions").unwrap();
        assert_eq!(sessions.get("active").unwrap().as_usize(), Some(1));
        assert_eq!(sessions.get("parked").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn session_ttl_eviction_returns_pages_to_pool() {
        let s = server();
        let baseline = s
            .handle(&Json::parse(r#"{"op":"metrics"}"#).unwrap())
            .get("pool")
            .unwrap()
            .get("free_pages")
            .unwrap()
            .as_usize()
            .unwrap();
        let t1 = s.handle(
            &Json::parse(r#"{"op":"generate","session":"idle","context_len":96,"decode_len":1}"#)
                .unwrap(),
        );
        assert_eq!(t1.get("ok").unwrap().as_bool(), Some(true), "{t1}");
        let held = s
            .handle(&Json::parse(r#"{"op":"metrics"}"#).unwrap())
            .get("pool")
            .unwrap()
            .get("free_pages")
            .unwrap()
            .as_usize()
            .unwrap();
        assert!(held < baseline, "parked session must hold pages ({held} vs {baseline})");

        assert_eq!(s.evict_idle_sessions(Duration::ZERO), 1);
        let m = s.handle(&Json::parse(r#"{"op":"metrics"}"#).unwrap());
        let freed = m.get("pool").unwrap().get("free_pages").unwrap().as_usize().unwrap();
        assert_eq!(freed, baseline, "eviction must return every page");
        let sessions = m.get("sessions").unwrap();
        assert_eq!(sessions.get("active").unwrap().as_usize(), Some(0));
        assert_eq!(sessions.get("evicted").unwrap().as_usize(), Some(1));
        assert_eq!(
            m.get("scheduler").unwrap().get("sessions_released").unwrap().as_usize(),
            Some(1)
        );
        // The evicted id starts a fresh session.
        let t = s.handle(
            &Json::parse(r#"{"op":"generate","session":"idle","context_len":32,"decode_len":1}"#)
                .unwrap(),
        );
        assert_eq!(t.get("ok").unwrap().as_bool(), Some(true), "{t}");
        assert_eq!(t.get("turn").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn streaming_emits_one_line_per_token_then_summary() {
        let s = server();
        let mut lines = Vec::new();
        s.handle_with(
            &Json::parse(r#"{"op":"generate","context_len":64,"decode_len":4,"stream":true}"#)
                .unwrap(),
            &mut |resp| lines.push(resp),
        );
        assert_eq!(lines.len(), 5, "decode_len token lines + 1 summary: {lines:?}");
        for (i, line) in lines[..4].iter().enumerate() {
            assert_eq!(line.get("token").unwrap().as_usize(), Some(i), "{line}");
            assert!(line.get("ms").unwrap().as_f64().unwrap() >= 0.0);
        }
        let summary = &lines[4];
        assert_eq!(summary.get("ok").unwrap().as_bool(), Some(true), "{summary}");
        assert_eq!(summary.get("done").unwrap().as_bool(), Some(true));
        assert_eq!(summary.get("decode_len").unwrap().as_usize(), Some(4));
    }

    #[test]
    fn busy_session_and_mode_change_are_refused() {
        let s = server();
        let t1 = s.handle(
            &Json::parse(r#"{"op":"generate","session":"s","context_len":48,"decode_len":1}"#)
                .unwrap(),
        );
        assert_eq!(t1.get("ok").unwrap().as_bool(), Some(true), "{t1}");
        // A resumed turn may not change the attention mode.
        let resp = s.handle(
            &Json::parse(
                r#"{"op":"generate","session":"s","context_len":16,"decode_len":1,"method":"quest"}"#,
            )
            .unwrap(),
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("fixed"), "{resp}");
        // Concurrent turns on one session are refused.
        lock(&s.sessions).get_mut("s").unwrap().busy = true;
        let resp = s.handle(
            &Json::parse(r#"{"op":"generate","session":"s","context_len":16,"decode_len":1}"#)
                .unwrap(),
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("in flight"), "{resp}");
        lock(&s.sessions).get_mut("s").unwrap().busy = false;
        let t2 = s.handle(
            &Json::parse(r#"{"op":"generate","session":"s","context_len":16,"decode_len":1}"#)
                .unwrap(),
        );
        assert_eq!(t2.get("ok").unwrap().as_bool(), Some(true), "{t2}");
        assert_eq!(t2.get("turn").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn tcp_round_trip() {
        use std::io::{BufRead, BufReader, Write};
        let s = Arc::new(server());
        let handle = s.serve("127.0.0.1:0", 2).unwrap();
        let mut conn = std::net::TcpStream::connect(handle.addr()).unwrap();
        writeln!(conn, r#"{{"op":"generate","context_len":48,"decode_len":1}}"#).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{line}");
        handle.shutdown();
    }

    #[test]
    fn many_connections_on_few_workers_all_get_served() {
        // Regression for the LIFO + busy-wait pool: with more
        // concurrent connections than workers, every connection must be
        // answered in bounded time (FIFO queue — no starvation).
        let s = Arc::new(server());
        let handle = s.serve("127.0.0.1:0", 2).unwrap();
        let addr = handle.addr();
        let clients: Vec<_> = (0..6)
            .map(|i| {
                std::thread::spawn(move || {
                    use std::io::{BufRead, BufReader, Write};
                    let mut conn = std::net::TcpStream::connect(addr).unwrap();
                    conn.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
                    writeln!(conn, r#"{{"op":"generate","context_len":32,"decode_len":1}}"#)
                        .unwrap();
                    let mut reader = BufReader::new(conn);
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    let resp = Json::parse(line.trim()).unwrap();
                    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "client {i}: {line}");
                })
            })
            .collect();
        for c in clients {
            c.join().expect("every client must be served");
        }
        handle.shutdown();
    }

    #[test]
    fn shutdown_joins_even_with_an_idle_connection_open() {
        // Regression: serve_conn never checked `stop`, so a worker
        // stuck reading an idle connection outlived shutdown forever.
        let s = Arc::new(server());
        let handle = s.serve("127.0.0.1:0", 2).unwrap();
        // Open a connection and send nothing: the handler is parked in
        // its read loop when shutdown hits.
        let idle = std::net::TcpStream::connect(handle.addr()).unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let start = Instant::now();
        handle.shutdown(); // joins acceptor + workers + sweeper
        assert!(
            start.elapsed() < Duration::from_secs(10),
            "shutdown must not hang on the idle connection"
        );
        drop(idle);
    }

    #[test]
    fn handler_panic_answers_error_and_connection_survives() {
        use std::io::{BufRead, BufReader, Write};
        let s = Arc::new(server());
        let handle = s.serve("127.0.0.1:0", 1).unwrap();
        let mut conn = std::net::TcpStream::connect(handle.addr()).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        // Panic while holding the stats lock (poisons it on purpose).
        writeln!(conn, r#"{{"op":"__test_panic"}}"#).unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("handler panicked"), "{line}");
        // Same connection, same (sole) worker: still alive.
        for probe in [r#"{"op":"ping"}"#, r#"{"op":"generate","context_len":32,"decode_len":1}"#] {
            writeln!(conn, "{probe}").unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            let resp = Json::parse(line.trim()).unwrap();
            assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{probe}: {line}");
        }
        // The poisoned stats lock is tolerated, not fatal.
        writeln!(conn, r#"{{"op":"stats"}}"#).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let stats = Json::parse(line.trim()).unwrap();
        assert_eq!(stats.get("ok").unwrap().as_bool(), Some(true), "{line}");
        assert_eq!(stats.get("served").unwrap().as_usize(), Some(1));
        handle.shutdown();
    }

    #[test]
    fn malformed_and_bomb_lines_answered_over_tcp() {
        use std::io::{BufRead, BufReader, Write};
        let s = Arc::new(server());
        let handle = s.serve("127.0.0.1:0", 1).unwrap();
        let mut conn = std::net::TcpStream::connect(handle.addr()).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        // A line that is not JSON at all must be answered, not dropped.
        writeln!(conn, "GET / HTTP/1.1").unwrap();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{line}");
        assert!(line.contains("bad json"), "{line}");
        // A deep-nesting bomb must hit the parser's depth limit and come
        // back as an error line instead of overflowing the worker's stack.
        writeln!(conn, "{}", "[".repeat(100_000)).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{line}");
        assert!(line.contains("bad json"), "{line}");
        assert!(line.contains("nesting"), "{line}");
        // Same connection, sole worker: both malformed lines were survived.
        writeln!(conn, r#"{{"op":"generate","context_len":32,"decode_len":1}}"#).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{line}");
        handle.shutdown();
    }

    #[test]
    fn prompted_requests_share_the_prefix_cache() {
        let s = server();
        let line = r#"{"op":"generate","context_len":128,"decode_len":1,
                       "prompt":"You are a helpful assistant."}"#;
        for _ in 0..2 {
            let resp = s.handle(&Json::parse(line).unwrap());
            assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        }
        let m = s.handle(&Json::parse(r#"{"op":"metrics"}"#).unwrap());
        let prefix = m.get("prefix").unwrap();
        assert_eq!(prefix.get("lookups").unwrap().as_usize(), Some(2), "{m}");
        assert_eq!(prefix.get("hits").unwrap().as_usize(), Some(1), "{m}");
        assert_eq!(prefix.get("prefill_tokens_saved").unwrap().as_usize(), Some(128), "{m}");
        assert_eq!(prefix.get("hit_rate").unwrap().as_f64(), Some(0.5), "{m}");
        // "cache":"off" serves the same content without touching the cache.
        let off = s.handle(
            &Json::parse(
                r#"{"op":"generate","context_len":128,"decode_len":1,
                    "prompt":"You are a helpful assistant.","cache":"off"}"#,
            )
            .unwrap(),
        );
        assert_eq!(off.get("ok").unwrap().as_bool(), Some(true), "{off}");
        let m = s.handle(&Json::parse(r#"{"op":"metrics"}"#).unwrap());
        assert_eq!(m.get("prefix").unwrap().get("lookups").unwrap().as_usize(), Some(2), "{m}");
    }

    #[test]
    fn segment_array_prompts_round_trip_and_share() {
        // Two requests sharing a leading {seed,len} segment but with
        // different suffixes: a partial hit on the shared pages.
        let s = server();
        let a = r#"{"op":"generate","context_len":96,"decode_len":1,
                    "prompt":[{"seed":7,"len":64},{"seed":100,"len":32}]}"#;
        let b = r#"{"op":"generate","context_len":96,"decode_len":1,
                    "prompt":[{"seed":7,"len":64},{"seed":101,"len":32}]}"#;
        for line in [a, b] {
            let resp = s.handle(&Json::parse(line).unwrap());
            assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        }
        let m = s.handle(&Json::parse(r#"{"op":"metrics"}"#).unwrap());
        let prefix = m.get("prefix").unwrap();
        assert_eq!(prefix.get("hits").unwrap().as_usize(), Some(1), "{m}");
        assert_eq!(prefix.get("prefill_tokens_saved").unwrap().as_usize(), Some(64), "{m}");
        // Sessions carry prompts on their first turn too.
        let t1 = s.handle(
            &Json::parse(
                r#"{"op":"generate","session":"sp","context_len":96,"decode_len":1,
                    "prompt":[{"seed":7,"len":64},{"seed":102,"len":32}]}"#,
            )
            .unwrap(),
        );
        assert_eq!(t1.get("ok").unwrap().as_bool(), Some(true), "{t1}");
        let m = s.handle(&Json::parse(r#"{"op":"metrics"}"#).unwrap());
        assert_eq!(m.get("prefix").unwrap().get("hits").unwrap().as_usize(), Some(2), "{m}");
    }

    #[test]
    fn bad_prompts_are_json_errors_and_sessions_are_not_stillborn() {
        let s = server();
        for bad in [
            // Segments don't cover the context.
            r#"{"op":"generate","context_len":96,"decode_len":1,
                "prompt":[{"seed":7,"len":64}]}"#,
            // Zero-length segment.
            r#"{"op":"generate","context_len":96,"decode_len":1,
                "prompt":[{"seed":7,"len":0},{"seed":8,"len":96}]}"#,
            // Missing seed.
            r#"{"op":"generate","context_len":96,"decode_len":1,"prompt":[{"len":96}]}"#,
            // Prompt is neither string nor array.
            r#"{"op":"generate","context_len":96,"decode_len":1,"prompt":7}"#,
            // Bad cache flag.
            r#"{"op":"generate","context_len":96,"decode_len":1,
                "prompt":"hi","cache":"maybe"}"#,
        ] {
            let resp = s.handle(&Json::parse(bad).unwrap());
            assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{bad}");
        }
        // A session first turn with a bad prompt must not leave a
        // stillborn entry behind...
        let resp = s.handle(
            &Json::parse(
                r#"{"op":"generate","session":"sb","context_len":96,"decode_len":1,
                    "prompt":[{"seed":1,"len":10}]}"#,
            )
            .unwrap(),
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        assert_eq!(lock(&s.sessions).len(), 0, "stillborn session must be removed");
        // ...and the id is reusable for a well-formed first turn.
        let t1 = s.handle(
            &Json::parse(r#"{"op":"generate","session":"sb","context_len":32,"decode_len":1}"#)
                .unwrap(),
        );
        assert_eq!(t1.get("ok").unwrap().as_bool(), Some(true), "{t1}");
        assert_eq!(t1.get("turn").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn apply_reload_swaps_defaults_policy_and_ttl_live() {
        let s = server();
        // Pre-reload: the engine default (socket) serves.
        let resp =
            s.handle(&Json::parse(r#"{"op":"generate","context_len":32,"decode_len":1}"#).unwrap());
        assert_eq!(resp.get("method").unwrap().as_str(), Some("socket"), "{resp}");
        let cfg = reloader::ReloadConfig::parse(
            r#"{"batch":{"max_prefills":1},"default_method":"quest",
                "default_sparsity":4.0,"session_ttl_secs":7}"#,
        )
        .unwrap();
        s.apply_reload(&cfg);
        // Post-reload: a method-less request serves on the new default.
        let resp =
            s.handle(&Json::parse(r#"{"op":"generate","context_len":32,"decode_len":1}"#).unwrap());
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        assert_eq!(resp.get("method").unwrap().as_str(), Some("quest"), "{resp}");
        let m = s.handle(&Json::parse(r#"{"op":"metrics"}"#).unwrap());
        let config = m.get("config").unwrap();
        assert_eq!(config.get("default_method").unwrap().as_str(), Some("quest"), "{m}");
        assert_eq!(config.get("default_sparsity").unwrap().as_f64(), Some(4.0), "{m}");
        assert_eq!(config.get("session_ttl_secs").unwrap().as_f64(), Some(7.0), "{m}");
        assert_eq!(config.get("reloads").unwrap().as_usize(), Some(1), "{m}");
        // A partial reload leaves untouched fields alone.
        s.apply_reload(&reloader::ReloadConfig::parse(r#"{"session_ttl_secs":9}"#).unwrap());
        let m = s.handle(&Json::parse(r#"{"op":"metrics"}"#).unwrap());
        let config = m.get("config").unwrap();
        assert_eq!(config.get("default_method").unwrap().as_str(), Some("quest"), "{m}");
        assert_eq!(config.get("session_ttl_secs").unwrap().as_f64(), Some(9.0), "{m}");
        assert_eq!(config.get("reloads").unwrap().as_usize(), Some(2), "{m}");
    }

    #[test]
    fn config_file_hot_reloads_a_live_tcp_server() {
        use std::io::{BufRead, BufReader, Write};
        let s = Arc::new(server());
        let handle = s.serve("127.0.0.1:0", 2).unwrap();
        let path = std::env::temp_dir().join(format!("socketd-reload-{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let watcher =
            reloader::watch(Arc::clone(&s), path.clone(), Duration::from_millis(20)).unwrap();

        let mut conn = std::net::TcpStream::connect(handle.addr()).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut ask = |line: &str| -> Json {
            writeln!(conn, "{line}").unwrap();
            let mut out = String::new();
            reader.read_line(&mut out).unwrap();
            Json::parse(out.trim()).unwrap()
        };
        let resp = ask(r#"{"op":"generate","context_len":32,"decode_len":1}"#);
        assert_eq!(resp.get("method").unwrap().as_str(), Some("socket"), "{resp}");

        // Atomic publish (write + rename) so the watcher never reads a
        // partial file.
        let publish = |text: &str| {
            let tmp = path.with_extension("tmp");
            std::fs::write(&tmp, text).unwrap();
            std::fs::rename(&tmp, &path).unwrap();
        };
        publish(r#"{"default_method":"quest","session_ttl_secs":11}"#);
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let m = ask(r#"{"op":"metrics"}"#);
            let reloads = m.get("config").unwrap().get("reloads").unwrap().as_usize().unwrap();
            if reloads >= 1 {
                assert_eq!(
                    m.get("config").unwrap().get("default_method").unwrap().as_str(),
                    Some("quest"),
                    "{m}"
                );
                break;
            }
            assert!(Instant::now() < deadline, "reload never applied: {m}");
            std::thread::sleep(Duration::from_millis(20));
        }
        // The running server now serves the reloaded default — no
        // restart, same connection.
        let resp = ask(r#"{"op":"generate","context_len":32,"decode_len":1}"#);
        assert_eq!(resp.get("method").unwrap().as_str(), Some("quest"), "{resp}");

        // A fat-fingered edit is rejected and the last good config
        // stays in force.
        publish(r#"{"default_method":"zzz"}"#);
        let deadline = Instant::now() + Duration::from_secs(10);
        while watcher.rejected() == 0 {
            assert!(Instant::now() < deadline, "bad config never rejected");
            std::thread::sleep(Duration::from_millis(20));
        }
        let m = ask(r#"{"op":"metrics"}"#);
        let config = m.get("config").unwrap();
        assert_eq!(config.get("default_method").unwrap().as_str(), Some("quest"), "{m}");
        assert_eq!(config.get("reloads").unwrap().as_usize(), Some(1), "{m}");

        watcher.shutdown();
        handle.shutdown();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn streaming_session_over_tcp() {
        use std::io::{BufRead, BufReader, Write};
        let s = Arc::new(server());
        let handle = s.serve("127.0.0.1:0", 2).unwrap();
        let mut conn = std::net::TcpStream::connect(handle.addr()).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        writeln!(
            conn,
            r#"{{"op":"generate","session":"tcp","context_len":64,"decode_len":3,"stream":true}}"#
        )
        .unwrap();
        let mut lines = Vec::new();
        for _ in 0..4 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            lines.push(Json::parse(line.trim()).unwrap());
        }
        for (i, l) in lines[..3].iter().enumerate() {
            assert_eq!(l.get("token").unwrap().as_usize(), Some(i), "{l}");
        }
        assert_eq!(lines[3].get("done").unwrap().as_bool(), Some(true), "{:?}", lines[3]);
        assert_eq!(lines[3].get("session").unwrap().as_str(), Some("tcp"));
        handle.shutdown();
    }
}
