//! Hot-reloadable serving config: a watcher thread polls a JSON config
//! file and applies changes to a running [`Server`](super::Server) —
//! batch policy, default method/sparsity, and the session TTL swap in
//! place without dropping a connection or restarting the scheduler.
//!
//! Config file shape (every field optional — absent fields leave the
//! current value untouched):
//!
//! ```json
//! {
//!   "batch": {"max_decode_batch": 16, "prefill_token_budget": 8192, "max_prefills": 2,
//!             "max_waiting": 1024},
//!   "default_method": "quest",
//!   "default_sparsity": 8.0,
//!   "session_ttl_secs": 60
//! }
//! ```
//!
//! The watcher re-reads the file on a short cadence and applies it only
//! when the content actually changed *and* parses + validates cleanly;
//! a malformed edit is counted and skipped, leaving the last good
//! config in force (a fat-fingered reload must never take serving
//! down).

use super::Server;
use crate::coordinator::BatchPolicy;
use crate::selector;
use crate::util::Json;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// A parsed + validated reload request. `None` fields mean "keep the
/// server's current value".
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReloadConfig {
    pub policy: Option<BatchPolicy>,
    pub default_method: Option<String>,
    pub default_sparsity: Option<f64>,
    pub session_ttl: Option<Duration>,
}

impl ReloadConfig {
    /// Parse one config document. Unknown top-level fields are ignored
    /// (forward compatibility); present-but-invalid values are errors —
    /// a reload applies entirely or not at all.
    pub fn parse(text: &str) -> Result<ReloadConfig, String> {
        let msg = Json::parse(text).map_err(|e| format!("bad config json: {e}"))?;
        let mut cfg = ReloadConfig::default();
        if let Some(batch) = msg.get("batch") {
            let base = BatchPolicy::default();
            let field = |name: &str, dflt: usize| -> Result<usize, String> {
                match batch.get(name) {
                    None => Ok(dflt),
                    Some(v) => v
                        .as_usize()
                        .filter(|&n| n >= 1)
                        .ok_or(format!("batch.{name} must be a positive integer, got {v}")),
                }
            };
            cfg.policy = Some(BatchPolicy {
                max_decode_batch: field("max_decode_batch", base.max_decode_batch)?,
                prefill_token_budget: field("prefill_token_budget", base.prefill_token_budget)?,
                max_prefills: field("max_prefills", base.max_prefills)?,
                max_waiting: field("max_waiting", base.max_waiting)?,
            });
        }
        if let Some(m) = msg.get("default_method") {
            let name = m
                .as_str()
                .ok_or(format!("default_method must be a string, got {m}"))?;
            if name.eq_ignore_ascii_case("dense") {
                cfg.default_method = Some("dense".to_string());
            } else {
                // Canonicalize through the registry so a reload cannot
                // install an unservable default.
                let spec = selector::lookup(name).map_err(|e| e.to_string())?;
                cfg.default_method = Some(spec.name.to_string());
            }
        }
        if let Some(s) = msg.get("default_sparsity") {
            match s.as_f64() {
                Some(v) if v.is_finite() && v >= 1.0 => cfg.default_sparsity = Some(v),
                _ => return Err(format!("default_sparsity must be a number >= 1, got {s}")),
            }
        }
        if let Some(t) = msg.get("session_ttl_secs") {
            match t.as_f64() {
                Some(v) if v.is_finite() && v > 0.0 => {
                    cfg.session_ttl = Some(Duration::from_secs_f64(v));
                }
                _ => return Err(format!("session_ttl_secs must be a positive number, got {t}")),
            }
        }
        Ok(cfg)
    }
}

/// Handle to a running config watcher. Dropping it stops and joins the
/// watcher thread.
pub struct ReloadWatcher {
    stop: Arc<AtomicBool>,
    /// Reload attempts that failed to parse/validate (skipped, last
    /// good config stays in force).
    rejected: Arc<AtomicU64>,
    thread: Option<JoinHandle<()>>,
}

impl ReloadWatcher {
    /// Config edits rejected so far. Relaxed gauge read (test/ops
    /// surface; exact once the writer quiesces).
    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Stop the watcher and join its thread.
    pub fn shutdown(self) {
        // Drop impl does the work.
    }
}

impl Drop for ReloadWatcher {
    fn drop(&mut self) {
        // Relaxed stop-flag store: the watcher polls on a timeout, and
        // the join below is a full synchronization point anyway.
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Watch `path` and apply changed configs to `server` every `interval`
/// (clamped to at least 10 ms). A missing file is not an error — the
/// watcher waits for it to appear; content is compared byte-for-byte,
/// so `touch` alone never triggers a reload.
pub fn watch(server: Arc<Server>, path: PathBuf, interval: Duration) -> std::io::Result<ReloadWatcher> {
    let stop = Arc::new(AtomicBool::new(false));
    let rejected = Arc::new(AtomicU64::new(0));
    let stop_w = Arc::clone(&stop);
    let rejected_w = Arc::clone(&rejected);
    let interval = interval.max(Duration::from_millis(10));
    let thread = std::thread::Builder::new().name("socketd-reloader".into()).spawn(move || {
        let mut last_seen: Option<String> = None;
        // Relaxed stop-flag read: shutdown latency is bounded by the
        // poll interval, not by memory-ordering fences.
        while !stop_w.load(Ordering::Relaxed) {
            std::thread::sleep(interval);
            let Ok(text) = std::fs::read_to_string(&path) else { continue };
            if last_seen.as_deref() == Some(text.as_str()) {
                continue;
            }
            // Remember invalid content too: re-parsing the same bad
            // file every tick would spin the rejected counter.
            match ReloadConfig::parse(&text) {
                Ok(cfg) => server.apply_reload(&cfg),
                Err(_) => {
                    // Relaxed counter bump: a plain statistic read by
                    // tests/metrics, never used to synchronize state.
                    rejected_w.fetch_add(1, Ordering::Relaxed);
                }
            }
            last_seen = Some(text);
        }
    })?;
    Ok(ReloadWatcher { stop, rejected, thread: Some(thread) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_and_partial_configs() {
        let cfg = ReloadConfig::parse(
            r#"{"batch":{"max_decode_batch":4,"prefill_token_budget":512,"max_prefills":1,
                         "max_waiting":64},
                "default_method":"quest","default_sparsity":4.0,"session_ttl_secs":0.5}"#,
        )
        .unwrap();
        let p = cfg.policy.unwrap();
        assert_eq!(
            (p.max_decode_batch, p.prefill_token_budget, p.max_prefills, p.max_waiting),
            (4, 512, 1, 64)
        );
        assert_eq!(cfg.default_method.as_deref(), Some("quest"));
        assert_eq!(cfg.default_sparsity, Some(4.0));
        assert_eq!(cfg.session_ttl, Some(Duration::from_millis(500)));

        // Partial: absent fields stay None (keep current values);
        // absent batch fields take the stock defaults.
        let cfg = ReloadConfig::parse(r#"{"batch":{"max_prefills":3}}"#).unwrap();
        let p = cfg.policy.unwrap();
        assert_eq!(p.max_prefills, 3);
        assert_eq!(p.max_decode_batch, BatchPolicy::default().max_decode_batch);
        assert_eq!(p.max_waiting, BatchPolicy::default().max_waiting);
        assert!(cfg.default_method.is_none());
        assert!(cfg.session_ttl.is_none());

        // Method names canonicalize through the registry.
        let cfg = ReloadConfig::parse(r#"{"default_method":"DENSE"}"#).unwrap();
        assert_eq!(cfg.default_method.as_deref(), Some("dense"));
    }

    #[test]
    fn invalid_configs_are_rejected_whole() {
        for bad in [
            "not json",
            r#"{"batch":{"max_prefills":0}}"#,
            r#"{"batch":{"max_waiting":0}}"#,
            r#"{"default_method":"zzz"}"#,
            r#"{"default_method":7}"#,
            r#"{"default_sparsity":0.5}"#,
            r#"{"session_ttl_secs":-1}"#,
            r#"{"session_ttl_secs":"soon"}"#,
        ] {
            assert!(ReloadConfig::parse(bad).is_err(), "{bad} must be rejected");
        }
    }
}
