//! Pool-parallel branch-and-bound top-k: the single traversal engine
//! behind `SoftScorer::select_pruned_group_with` and
//! `HardScorer::select_pruned_with`.
//!
//! The walk shards the hash blocks across the worker pool in a strided
//! order over a (possibly bound-sorted) visit permutation — striding
//! means *every* worker starts near the top of the bound order, so the
//! thresholds warm in the first few visits everywhere. Each worker runs
//! branch-and-bound with its own per-lane [`BoundHeap`] (reused via
//! per-worker scratch) and prunes against two tests at once:
//!
//! * **local, tie-aware** — `BoundHeap::prunes_at(ub, base)`: exact
//!   under the (score desc, index asc) total order, so equal-bound
//!   blocks that could still win an index tie-break are never skipped;
//! * **shared, strict** — `ub < ThresholdCell::get()`: any worker whose
//!   heap fills publishes its k-th score through a relaxed monotone
//!   atomic (f32 bits as u32 — order-preserving for the non-negative
//!   collision scores), so one worker's warm threshold prunes for all.
//!   A stale read only weakens pruning, never correctness.
//!
//! The final per-lane top-k is an **exact merge** of the per-worker
//! candidate sets under the same total order. Every key skipped by
//! either test is provably outside the global top-k, every key evicted
//! from a local heap is beaten by k keys of its own shard, and the
//! tie-aware [`TopK`] is push-order independent — so selections (indices
//! AND scores) are bit-identical to exhaustive scoring for every pool
//! size, lane count, and traversal order (property-tested across pool
//! sizes 1/2/8, both orderings, and GQA groups in `lsh::soft` /
//! `lsh::hard`).

use crate::linalg::{SharedBoundHeap, TopK};
use crate::lsh::simhash::{KeyHashes, BLOCK_TOKENS};
use crate::lsh::soft::PruneStats;
use crate::util::pool::{self, ThresholdCell, WorkerPool};

/// Fill `order` with the identity block permutation (storage-order
/// walks).
pub fn identity_order(n_blocks: usize, order: &mut Vec<u32>) {
    order.clear();
    order.extend(0..n_blocks as u32);
}

/// Fill `order` with the permutation visiting blocks in descending
/// `agg` (the per-block bound aggregate), ties toward lower block ids —
/// the deterministic bound-descending visit order both scorers hand to
/// [`run_walk`]. Any permutation selects identically; this one warms
/// the pruning thresholds fastest.
pub fn bound_order(agg: &[f32], order: &mut Vec<u32>) {
    identity_order(agg.len(), order);
    order.sort_by(|&a, &b| {
        agg[b as usize]
            .partial_cmp(&agg[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
}

/// Reusable caller-side storage of one walk invocation: the per-lane
/// shared threshold cells and the per-(job, lane) candidate buffers.
/// Owned by `util::pool::BnbPlanScratch` so every buffer's capacity
/// persists across decode steps — `run_walk`'s only unavoidable
/// steady-state allocations are the boxed job closures handed to
/// `WorkerPool::run_all` (the same cost every pooled fill pays) and the
/// O(k) merge heap per lane (matching the pre-parallel walk).
#[derive(Debug, Default)]
pub struct WalkScratch {
    /// One shared threshold cell per lane (reset per walk).
    cells: Vec<ThresholdCell>,
    /// Per-job pruning telemetry.
    stats: Vec<PruneStats>,
    /// Flat per-(job, job-lane) drained candidate buffers,
    /// `lanes_per_job` wide per job.
    cands: Vec<Vec<(usize, f32)>>,
}

/// One job's disjoint view into [`WalkScratch`].
struct JobSlot<'a> {
    stats: &'a mut PruneStats,
    cands: &'a mut [Vec<(usize, f32)>],
}

/// Run the pool-parallel branch-and-bound walk.
///
/// * `bounds` — admissible per-(lane, block) score upper bounds,
///   lane-major (`outs.len() * n_blocks`): `bounds[g * n_blocks + b]`
///   must dominate the computed f32 score of every key in block `b`
///   under lane `g`. Scores must be non-negative (the shared threshold
///   cell relies on it).
/// * `order` — the block visit permutation (identity for storage
///   order, bound-descending for the warm-start walk). Any permutation
///   yields the same selection; only the prune rate differs.
/// * `score_block(lane, blk, acc)` — fill `acc[..block_len(blk)]` with
///   the final (value-weighted) scores of the block's resident keys,
///   accumulated exactly like the exhaustive kernel so scores stay
///   bit-identical.
/// * `outs` — one `(indices, scores)` pair per lane; receives the
///   exact top-k, descending score, ties toward lower indices.
#[allow(clippy::too_many_arguments)]
pub fn run_walk<F>(
    hashes: &KeyHashes,
    k: usize,
    bounds: &[f32],
    order: &[u32],
    pool: &WorkerPool,
    score_block: F,
    outs: &mut [(&mut Vec<usize>, &mut Vec<f32>)],
    scratch: &mut WalkScratch,
) -> PruneStats
where
    F: Fn(usize, usize, &mut [f32; BLOCK_TOKENS]) + Sync,
{
    let n = hashes.n;
    let n_lanes = outs.len();
    for (indices, scores) in outs.iter_mut() {
        indices.clear();
        scores.clear();
    }
    if n == 0 || k == 0 || n_lanes == 0 {
        return PruneStats::default();
    }
    let n_blocks = hashes.n_blocks();
    assert_eq!(bounds.len(), n_lanes * n_blocks, "bounds shape mismatch");
    assert_eq!(order.len(), n_blocks, "order permutation length mismatch");
    let k = k.min(n);

    // Tiling over the blocks x lanes grid: stride blocks across jobs
    // first (keeps every lane's pass over a block cache-hot inside one
    // job, and hands each job early high-bound blocks), splitting lanes
    // only when blocks alone cannot feed the pool. Inside a pool worker
    // the walk runs as one inline job — the cores are already busy.
    let threads = if WorkerPool::in_worker() { 1 } else { pool.threads() };
    let target = if threads > 1 { threads * 2 } else { 1 };
    let block_jobs = n_blocks.min(target).max(1);
    let lane_jobs =
        if block_jobs < target { n_lanes.min(target / block_jobs).max(1) } else { 1 };
    let lanes_per_job = n_lanes.div_ceil(lane_jobs);
    let lane_jobs = n_lanes.div_ceil(lanes_per_job);
    let n_jobs = block_jobs * lane_jobs;

    // Reusable storage: cells reset per walk, candidate buffers keep
    // their capacity across decode steps.
    if scratch.cells.len() < n_lanes {
        scratch.cells.resize_with(n_lanes, ThresholdCell::new);
    }
    for cell in scratch.cells[..n_lanes].iter_mut() {
        cell.reset();
    }
    scratch.stats.clear();
    scratch.stats.resize(n_jobs, PruneStats::default());
    if scratch.cands.len() < n_jobs * lanes_per_job {
        scratch.cands.resize_with(n_jobs * lanes_per_job, Vec::new);
    }

    {
        let cells = &scratch.cells[..n_lanes];
        let score_block = &score_block;
        let run_job = move |j: usize, slot: JobSlot<'_>| {
            let jb = j % block_jobs;
            let lane_lo = (j / block_jobs) * lanes_per_job;
            let lane_hi = (lane_lo + lanes_per_job).min(n_lanes);
            let job_lanes = lane_hi - lane_lo;
            let mut acc = [0.0f32; BLOCK_TOKENS];
            pool::with_bnb_worker(|w| {
                let (heaps, seen_prune) = w.lanes(job_lanes, k);
                for &ob in order.iter().skip(jb).step_by(block_jobs) {
                    let blk = ob as usize;
                    let blen = hashes.block_len(blk);
                    let base = blk * BLOCK_TOKENS;
                    for li in 0..job_lanes {
                        let lane = lane_lo + li;
                        slot.stats.blocks += 1;
                        let mut heap = SharedBoundHeap::new(&mut heaps[li], &cells[lane]);
                        if heap.prunes_block(bounds[lane * n_blocks + blk], base) {
                            slot.stats.pruned += 1;
                            seen_prune[li] = true;
                            continue;
                        }
                        if !seen_prune[li] {
                            slot.stats.warmup += 1;
                        }
                        score_block(lane, blk, &mut acc);
                        for (off, &s) in acc[..blen].iter().enumerate() {
                            heap.push(s, base + off);
                        }
                    }
                }
                for (h, cand) in heaps.iter_mut().zip(slot.cands.iter_mut()) {
                    h.drain_into(cand);
                }
                // Unused trailing buffers of a short final lane chunk
                // must not leak a previous walk's candidates.
                for cand in slot.cands.iter_mut().skip(job_lanes) {
                    cand.clear();
                }
            });
        };
        let mut slots: Vec<JobSlot<'_>> = scratch
            .stats
            .iter_mut()
            .zip(scratch.cands.chunks_mut(lanes_per_job))
            .map(|(stats, cands)| JobSlot { stats, cands })
            .collect();
        if n_jobs == 1 {
            // `slots` was built with exactly n_jobs == 1 entries.
            if let Some(slot) = slots.pop() {
                run_job(0, slot);
            }
        } else {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = slots
                .into_iter()
                .enumerate()
                .map(|(j, slot)| {
                    let run_job = &run_job;
                    let job: Box<dyn FnOnce() + Send + '_> =
                        Box::new(move || run_job(j, slot));
                    job
                })
                .collect();
            pool.run_all(jobs);
        }
    }

    // Exact merge: per lane, the global top-k of the union of its
    // jobs' candidate sets under (score desc, index asc). The tie-aware
    // TopK is push-order independent, so the merge result — and with it
    // the whole walk — is bit-identical to the exhaustive scan.
    let mut stats = PruneStats::default();
    for (lane, (indices, scores)) in outs.iter_mut().enumerate() {
        let mut merge = TopK::new(k);
        for j in 0..n_jobs {
            // Job j's lane range, recomputed from the same tiling
            // arithmetic the jobs used.
            let lane_lo = (j / block_jobs) * lanes_per_job;
            if lane >= lane_lo && lane < (lane_lo + lanes_per_job).min(n_lanes) {
                for &(i, s) in &scratch.cands[j * lanes_per_job + (lane - lane_lo)] {
                    merge.push(s, i);
                }
            }
        }
        for (i, s) in merge.into_sorted() {
            indices.push(i);
            scores.push(s);
        }
    }
    for job_stats in scratch.stats.iter() {
        stats.blocks += job_stats.blocks;
        stats.pruned += job_stats.pruned;
        stats.warmup += job_stats.warmup;
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Hashes whose "score" for lane g is `(id of table 0) + g`, with
    /// unit norms — enough to drive the driver directly.
    fn toy_hashes(n: usize, r: usize, rng: &mut Pcg64) -> KeyHashes {
        let ids: Vec<u16> = (0..n).map(|_| rng.below(r as u64) as u16).collect();
        KeyHashes::from_row_major(1, r, &ids, vec![1.0; n])
    }

    fn walk(
        hashes: &KeyHashes,
        k: usize,
        lanes: usize,
        pool: &WorkerPool,
    ) -> (Vec<Vec<usize>>, Vec<Vec<f32>>, PruneStats) {
        let mut scratch = WalkScratch::default();
        walk_with(hashes, k, lanes, pool, &mut scratch)
    }

    fn walk_with(
        hashes: &KeyHashes,
        k: usize,
        lanes: usize,
        pool: &WorkerPool,
        scratch: &mut WalkScratch,
    ) -> (Vec<Vec<usize>>, Vec<Vec<f32>>, PruneStats) {
        let n_blocks = hashes.n_blocks();
        let mut bounds = vec![0.0f32; lanes * n_blocks];
        for g in 0..lanes {
            for blk in 0..n_blocks {
                let mut m = 0.0f32;
                for j in blk * BLOCK_TOKENS..blk * BLOCK_TOKENS + hashes.block_len(blk) {
                    m = m.max(hashes.bucket(j, 0) as f32 + g as f32);
                }
                bounds[g * n_blocks + blk] = m;
            }
        }
        let order: Vec<u32> = (0..n_blocks as u32).collect();
        let mut idx = vec![Vec::new(); lanes];
        let mut sc = vec![Vec::new(); lanes];
        let stats = {
            let mut outs: Vec<(&mut Vec<usize>, &mut Vec<f32>)> =
                idx.iter_mut().zip(sc.iter_mut()).map(|(i, s)| (i, s)).collect();
            run_walk(
                hashes,
                k,
                &bounds,
                &order,
                pool,
                |g, blk, acc| {
                    let blen = hashes.block_len(blk);
                    for (off, slot) in acc[..blen].iter_mut().enumerate() {
                        *slot = hashes.bucket(blk * BLOCK_TOKENS + off, 0) as f32 + g as f32;
                    }
                },
                &mut outs,
                scratch,
            )
        };
        (idx, sc, stats)
    }

    #[test]
    fn walk_matches_plain_topk_across_pool_sizes_and_lanes() {
        let mut rng = Pcg64::seeded(0xB4B);
        let hashes = toy_hashes(3 * BLOCK_TOKENS + 11, 32, &mut rng);
        let pools = [WorkerPool::new(1), WorkerPool::new(3), WorkerPool::new(8)];
        for k in [1usize, 7, 64, 500] {
            for lanes in [1usize, 2, 5] {
                // Reference: exhaustive tie-aware top-k per lane.
                let mut want: Vec<Vec<(usize, f32)>> = Vec::new();
                for g in 0..lanes {
                    let mut tk = TopK::new(k.min(hashes.n));
                    for j in 0..hashes.n {
                        tk.push(hashes.bucket(j, 0) as f32 + g as f32, j);
                    }
                    want.push(tk.into_sorted());
                }
                for pool in &pools {
                    let (idx, sc, _) = walk(&hashes, k, lanes, pool);
                    for g in 0..lanes {
                        let got: Vec<(usize, f32)> =
                            idx[g].iter().copied().zip(sc[g].iter().copied()).collect();
                        assert_eq!(
                            got, want[g],
                            "k={k} lanes={lanes} threads={} lane {g}",
                            pool.threads()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn k_equals_n_visits_every_block_and_prunes_nothing() {
        // With k == n no heap can reject a candidate, so nothing may be
        // pruned and every (lane, block) pair must be visited exactly
        // once — the coverage invariant of the tiling.
        let mut rng = Pcg64::seeded(7);
        let hashes = toy_hashes(2 * BLOCK_TOKENS + 5, 16, &mut rng);
        let pool = WorkerPool::new(4);
        let lanes = 3;
        let (idx, _, stats) = walk(&hashes, hashes.n, lanes, &pool);
        assert_eq!(stats.blocks, hashes.n_blocks() * lanes);
        assert_eq!(stats.pruned, 0);
        for lane_idx in idx {
            assert_eq!(lane_idx.len(), hashes.n);
        }
    }

    #[test]
    fn walk_scratch_reuse_is_stateless() {
        // One WalkScratch reused across walks of shrinking shapes
        // (fewer lanes, smaller k, fewer keys) must select exactly what
        // fresh scratch selects — stale candidate buffers, thresholds,
        // or job slots from the bigger walk must not leak in.
        let mut rng = Pcg64::seeded(0x5C8A);
        let big = toy_hashes(3 * BLOCK_TOKENS + 9, 64, &mut rng);
        let small = toy_hashes(BLOCK_TOKENS / 2, 16, &mut rng);
        let pool = WorkerPool::new(4);
        let mut scratch = WalkScratch::default();
        let _ = walk_with(&big, 100, 6, &pool, &mut scratch);
        for (hashes, k, lanes) in [(&small, 5usize, 2usize), (&big, 1, 1), (&small, 40, 3)] {
            let got = walk_with(hashes, k, lanes, &pool, &mut scratch);
            let want = walk(hashes, k, lanes, &pool);
            assert_eq!(got.0, want.0, "indices leak (k={k} lanes={lanes})");
            assert_eq!(got.1, want.1, "scores leak (k={k} lanes={lanes})");
        }
    }

    #[test]
    fn empty_inputs_clear_outputs() {
        let mut rng = Pcg64::seeded(9);
        let hashes = toy_hashes(10, 8, &mut rng);
        let pool = WorkerPool::new(2);
        let (idx, sc, stats) = walk(&hashes, 0, 2, &pool);
        assert_eq!(stats, PruneStats::default());
        assert!(idx.iter().all(Vec::is_empty) && sc.iter().all(Vec::is_empty));
    }
}
