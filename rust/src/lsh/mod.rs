//! Locality-sensitive hashing: SimHash tables, hard collision scoring,
//! and the paper's contribution — the **soft collision kernel** (SOCKET).
//!
//! Layout follows the paper's Algorithms 1–4:
//! * [`SimHash`] — `L` tables of `P` Gaussian hyperplanes (Alg. 1).
//! * [`soft::SoftHasher`] — query-side soft bucket probabilities (Alg. 2).
//! * [`soft::SoftScorer`] — value-aware soft collision scores + top-k
//!   selection (Alg. 3 / Alg. 4).
//! * [`hard`] — traditional hard-LSH collision counting (the paper's main
//!   ablation baseline, Table 2 / Table 7 / Fig. 2).

pub mod bnb;
pub mod hard;
pub mod params;
pub mod simhash;
pub mod soft;

pub use hard::HardScorer;
pub use params::{LshParams, MemoryBudget};
pub use simhash::{HashBlock, KeyHashes, SimHash, BLOCK_TOKENS, SUMMARY_CAP};
pub use soft::{GroupLane, PruneStats, SoftHasher, SoftScorer};
