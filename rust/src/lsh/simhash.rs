//! SimHash (sign-random-projection) tables — the paper's Algorithm 1.
//!
//! Each of the `L` tables draws a `P x d` Gaussian hyperplane matrix
//! `W^(ℓ)`. A key `k` hashes to the bucket whose id is the packed sign
//! pattern of `W^(ℓ) k`. Bucket ids are stored packed (`P ≤ 16` bits per
//! table), giving the paper's `L·P` bits/token memory footprint.
//!
//! Storage is **table-major SoA blocks** ([`BLOCK_TOKENS`] keys per
//! block, a whole number of paged-KV pages): within a block, one table's
//! bucket ids for all keys are contiguous, so the scoring hot paths
//! stream table-outer/key-inner instead of gathering an `L`-wide row per
//! key. Each block additionally carries a per-table summary (the set of
//! distinct bucket ids present, capped at [`SUMMARY_CAP`] with a
//! saturating "use the table-wide max" fallback) plus the block's max
//! value norm, from which the scorers compute *admissible* per-block
//! score upper bounds — the branch-and-bound pruning of
//! `SoftScorer::select_pruned_into` and `HardScorer::select_pruned_into`.

use crate::linalg::Matrix;
use crate::lsh::params::LshParams;
use crate::util::pool::WorkerPool;
use crate::util::rng::Pcg64;

/// Keys per SoA hash block. A multiple of the paged-KV page size
/// (`kvcache::PAGE_TOKENS`, asserted there), so block boundaries always
/// land on page boundaries and a page never straddles two blocks.
pub const BLOCK_TOKENS: usize = 64;

/// Distinct-bucket budget of one (block, table) summary. Uncapped
/// summaries cost a worst-case `BLOCK_TOKENS` u16 per cell — doubling
/// the signature bytes; the cap cuts that to `SUMMARY_CAP / BLOCK_TOKENS`
/// (4x less). A cell whose distinct-id count overflows the budget
/// **saturates**: its summary is dropped and the scorers fall back to
/// the table-wide max probability (soft) / an unconditional collision
/// (hard) for that term — still admissible, because the table-wide max
/// dominates every bucket's probability. Blocks diverse enough to
/// overflow had near-table-max bounds anyway; the blocks pruning
/// actually wins on (temporally clustered keys sharing buckets) stay
/// under the cap.
pub const SUMMARY_CAP: usize = 16;

/// `lens` sentinel marking a saturated (block, table) summary.
const SUMMARY_SATURATED: u16 = u16::MAX;

/// The hyperplanes of `L` independent SimHash tables.
#[derive(Clone, Debug)]
pub struct SimHash {
    pub params: LshParams,
    pub dim: usize,
    /// One `P x dim` Gaussian matrix per table.
    planes: Vec<Matrix>,
}

/// Packed bucket ids for a set of keys in table-major SoA blocks, plus
/// cached value norms and per-block pruning summaries.
///
/// Key `j`'s bucket in table `t` lives at
/// `data[(j / B) * L * B + t * B + j % B]` with `B = BLOCK_TOKENS`: the
/// `B` ids of one (block, table) pair are contiguous. `data` always
/// holds whole blocks (the tail block is allocated full-size and filled
/// as keys arrive), so per-block slices are always in range.
///
/// Every stored id is validated against the bucket-space size `R = 2^P`
/// once, at construction / [`KeyHashes::push`] — the scoring kernels'
/// unchecked gathers rely on this invariant instead of re-masking ids
/// on the hot path.
#[derive(Clone, Debug)]
pub struct KeyHashes {
    pub n: usize,
    pub l: usize,
    /// Bucket-space size (`2^P`); every id in `data` is `< r`.
    r: usize,
    /// Table-major SoA blocks (see type docs).
    data: Vec<u16>,
    /// ‖v_j‖₂ cached at prefill (Alg. 1 returns these).
    pub value_norms: Vec<f32>,
    summaries: BlockSummaries,
}

/// Per-block pruning summaries: for each (block, table) the distinct
/// bucket ids present (insertion-ordered, stride [`SUMMARY_CAP`], with
/// overflow saturating to "no summary — use the table-wide max"), and
/// per block the max cached value norm. Maintained incrementally by
/// [`KeyHashes::push`]; the scorers reduce them to admissible per-block
/// score upper bounds.
#[derive(Clone, Debug, Default)]
struct BlockSummaries {
    /// Distinct ids of (block, table) at
    /// `ids[(blk * l + t) * SUMMARY_CAP..][..lens[blk * l + t]]`.
    ids: Vec<u16>,
    /// Distinct-id count per (block, table); [`SUMMARY_SATURATED`]
    /// marks an overflowed cell.
    lens: Vec<u16>,
    /// Max ‖v‖₂ per block (0.0 for a block with no keys yet).
    max_norm: Vec<f32>,
    /// Whether any cell has saturated (tells the scorers to compute
    /// table-wide maxima for the fallback bound).
    saturated: bool,
}

impl BlockSummaries {
    /// The distinct ids of (blk, table), or `None` once the cell's
    /// budget overflowed (bound falls back to the table-wide max).
    #[inline]
    fn table_ids(&self, blk: usize, table: usize, l: usize) -> Option<&[u16]> {
        let cell = blk * l + table;
        let len = self.lens[cell];
        if len == SUMMARY_SATURATED {
            return None;
        }
        let base = cell * SUMMARY_CAP;
        Some(&self.ids[base..base + len as usize])
    }

    /// Record one key's id in (blk, table); dedups against the ids
    /// already present, saturating when a new distinct id would exceed
    /// the [`SUMMARY_CAP`] budget.
    #[inline]
    fn note(&mut self, blk: usize, table: usize, l: usize, id: u16) {
        let cell = blk * l + table;
        let len = self.lens[cell];
        if len == SUMMARY_SATURATED {
            return;
        }
        let len = len as usize;
        let base = cell * SUMMARY_CAP;
        if self.ids[base..base + len].contains(&id) {
            return;
        }
        if len == SUMMARY_CAP {
            self.lens[cell] = SUMMARY_SATURATED;
            self.saturated = true;
            return;
        }
        self.ids[base + len] = id;
        self.lens[cell] = (len + 1) as u16;
    }

    /// Extend the summary arrays with one fresh (all-empty) block.
    fn grow_block(&mut self, l: usize) {
        self.ids.resize(self.ids.len() + l * SUMMARY_CAP, 0);
        self.lens.resize(self.lens.len() + l, 0);
        self.max_norm.push(0.0);
    }
}

impl KeyHashes {
    /// An empty store for `l` tables over a bucket space of size `r`.
    pub fn empty(l: usize, r: usize) -> KeyHashes {
        assert!(l > 0, "L must be positive");
        assert!(r > 0 && r <= 1 << 16, "bucket space {r} out of u16 range");
        KeyHashes {
            n: 0,
            l,
            r,
            data: Vec::new(),
            value_norms: Vec::new(),
            summaries: BlockSummaries::default(),
        }
    }

    /// Build from a row-major `n x L` id table (the layout the pooled
    /// hashing fills, one key row per job). Validates every id against
    /// `r` once, here — the scoring kernels then gather unchecked.
    pub fn from_row_major(
        l: usize,
        r: usize,
        row_major: &[u16],
        value_norms: Vec<f32>,
    ) -> KeyHashes {
        let mut kh = KeyHashes::empty(l, r);
        assert_eq!(row_major.len() % l, 0, "id table is not n x L");
        let n = row_major.len() / l;
        assert_eq!(value_norms.len(), n, "value norms length mismatch");
        kh.data = vec![0u16; n.div_ceil(BLOCK_TOKENS) * l * BLOCK_TOKENS];
        for blk in 0..n.div_ceil(BLOCK_TOKENS) {
            kh.summaries.grow_block(l);
            let base = blk * BLOCK_TOKENS;
            for slot in 0..BLOCK_TOKENS.min(n - base) {
                let j = base + slot;
                let row = &row_major[j * l..(j + 1) * l];
                for (t, &b) in row.iter().enumerate() {
                    assert!((b as usize) < r, "bucket id {b} out of range for R={r}");
                    kh.data[(blk * l + t) * BLOCK_TOKENS + slot] = b;
                    kh.summaries.note(blk, t, l, b);
                }
                let norm = value_norms[j];
                kh.summaries.max_norm[blk] = kh.summaries.max_norm[blk].max(norm);
            }
        }
        kh.n = n;
        kh.value_norms = value_norms;
        kh
    }

    /// Bucket-space size (`2^P`) the stored ids were validated against.
    #[inline]
    pub fn r(&self) -> usize {
        self.r
    }

    #[inline]
    fn slot_of(&self, key: usize, table: usize) -> usize {
        (key / BLOCK_TOKENS) * self.l * BLOCK_TOKENS + table * BLOCK_TOKENS + key % BLOCK_TOKENS
    }

    #[inline]
    pub fn bucket(&self, key: usize, table: usize) -> u16 {
        self.data[self.slot_of(key, table)]
    }

    /// All L bucket ids of one key, gathered out of the SoA blocks.
    /// (Allocates — a compat/diagnostic view, not a hot path; the
    /// scoring kernels iterate blocks directly.)
    pub fn key_row(&self, key: usize) -> Vec<u16> {
        (0..self.l).map(|t| self.bucket(key, t)).collect()
    }

    /// The full id table in the legacy row-major `n x L` layout
    /// (equivalence tests against the pre-SoA reference).
    pub fn to_row_major(&self) -> Vec<u16> {
        let mut out = Vec::with_capacity(self.n * self.l);
        for j in 0..self.n {
            for t in 0..self.l {
                out.push(self.bucket(j, t));
            }
        }
        out
    }

    /// Number of SoA blocks currently allocated.
    #[inline]
    pub fn n_blocks(&self) -> usize {
        self.n.div_ceil(BLOCK_TOKENS)
    }

    /// Keys resident in block `blk` (the tail block may be partial).
    #[inline]
    pub fn block_len(&self, blk: usize) -> usize {
        (self.n - blk * BLOCK_TOKENS).min(BLOCK_TOKENS)
    }

    /// Block `blk`'s full `L x BLOCK_TOKENS` id storage (table-major;
    /// only the first [`KeyHashes::block_len`] slots of each table row
    /// hold live keys).
    #[inline]
    pub fn block_data(&self, blk: usize) -> &[u16] {
        let base = blk * self.l * BLOCK_TOKENS;
        &self.data[base..base + self.l * BLOCK_TOKENS]
    }

    /// The distinct bucket ids block `blk` occupies in `table`
    /// (insertion-ordered), or `None` once the cell's
    /// [`SUMMARY_CAP`] budget overflowed. While `Some`, every live
    /// key's id is a member — the invariant the pruning bounds rest on;
    /// on `None` the scorers substitute the table-wide max, which
    /// dominates every bucket and keeps the bound admissible.
    #[inline]
    pub fn block_table_ids(&self, blk: usize, table: usize) -> Option<&[u16]> {
        self.summaries.table_ids(blk, table, self.l)
    }

    /// Whether any (block, table) summary has saturated — tells the
    /// soft scorer to precompute per-table max probabilities for the
    /// fallback bound.
    #[inline]
    pub fn summaries_saturated(&self) -> bool {
        self.summaries.saturated
    }

    /// Max cached value norm of block `blk`.
    #[inline]
    pub fn block_max_norm(&self, blk: usize) -> f32 {
        self.summaries.max_norm[blk]
    }

    /// Append a single new key (decode-time cache extension), extending
    /// the tail block's storage and summaries in place. Ids are
    /// validated here — the scoring kernels gather unchecked.
    pub fn push(&mut self, buckets: &[u16], value_norm: f32) {
        assert_eq!(buckets.len(), self.l);
        let slot = self.n % BLOCK_TOKENS;
        if slot == 0 {
            self.data.resize(self.data.len() + self.l * BLOCK_TOKENS, 0);
            self.summaries.grow_block(self.l);
        }
        let blk = self.n / BLOCK_TOKENS;
        for (t, &b) in buckets.iter().enumerate() {
            assert!((b as usize) < self.r, "bucket id {b} out of range for R={}", self.r);
            self.data[(blk * self.l + t) * BLOCK_TOKENS + slot] = b;
            self.summaries.note(blk, t, self.l, b);
        }
        self.summaries.max_norm[blk] = self.summaries.max_norm[blk].max(value_norm);
        self.value_norms.push(value_norm);
        self.n += 1;
    }

    /// Append every key of `other` (same L and bucket space) — the
    /// incremental-prefill path. One reusable row buffer instead of a
    /// per-key allocation.
    pub fn extend_from(&mut self, other: &KeyHashes) {
        assert_eq!(self.l, other.l, "table count mismatch");
        assert_eq!(self.r, other.r, "bucket space mismatch");
        let mut row = vec![0u16; self.l];
        for j in 0..other.n {
            for (t, slot) in row.iter_mut().enumerate() {
                *slot = other.bucket(j, t);
            }
            self.push(&row, other.value_norms[j]);
        }
    }

    /// Per-key table-collision counts against a query's bucket row
    /// (`q_buckets[t]` = the query's bucket in table t), written into a
    /// reusable buffer as f32 (counts ≤ L are exact in f32). The shared
    /// kernel of hard-LSH scoring and MagicPIG candidate sampling —
    /// streams the SoA blocks table-outer/key-inner.
    pub fn collision_counts_into(&self, q_buckets: &[u16], out: &mut Vec<f32>) {
        assert_eq!(q_buckets.len(), self.l);
        out.clear();
        out.resize(self.n, 0.0);
        for blk in 0..self.n_blocks() {
            let blen = self.block_len(blk);
            self.block_collision_counts(blk, q_buckets, &mut out[blk * BLOCK_TOKENS..][..blen]);
        }
    }

    /// Collision counts of block `blk`'s resident keys against
    /// `q_buckets`, written to `counts[..block_len(blk)]` — the shared
    /// per-block kernel of [`KeyHashes::collision_counts_into`] and the
    /// pruned hard-LSH walk (counts accumulate in t order; ≤ L, exact
    /// in f32).
    pub fn block_collision_counts(&self, blk: usize, q_buckets: &[u16], counts: &mut [f32]) {
        assert_eq!(q_buckets.len(), self.l);
        let blen = self.block_len(blk);
        let block = self.block_data(blk);
        let counts = &mut counts[..blen];
        counts.fill(0.0);
        for (t, &qb) in q_buckets.iter().enumerate() {
            let row = &block[t * BLOCK_TOKENS..t * BLOCK_TOKENS + blen];
            for (c, &b) in counts.iter_mut().zip(row) {
                *c += (b == qb) as u32 as f32;
            }
        }
    }

    /// Upper bound on any key-in-block collision count against
    /// `q_buckets`: the number of tables whose block summary contains
    /// the query's bucket. Admissible because a key can only collide in
    /// table t if its id — a summary member — equals `q_buckets[t]`; a
    /// saturated summary conservatively counts as containing it.
    pub fn block_collision_bound(&self, blk: usize, q_buckets: &[u16]) -> f32 {
        let mut c = 0u32;
        for (t, &qb) in q_buckets.iter().enumerate() {
            c += match self.block_table_ids(blk, t) {
                Some(ids) => ids.contains(&qb) as u32,
                None => 1,
            };
        }
        c as f32
    }
}

impl SimHash {
    /// Draw the hyperplanes. Deterministic in (seed, params, dim).
    pub fn new(params: LshParams, dim: usize, seed: u64) -> SimHash {
        // lint:allow(hot-path-panic): construction-time config check,
        // never on the decode path (selectors validate via Result).
        params.validate().expect("invalid LSH params");
        let mut planes = Vec::with_capacity(params.l);
        for table in 0..params.l {
            let mut rng = Pcg64::new(seed, table as u64 + 1);
            planes.push(Matrix::gaussian(params.p, dim, &mut rng));
        }
        SimHash { params, dim, planes }
    }

    /// Hyperplane matrix of table ℓ.
    pub fn plane(&self, table: usize) -> &Matrix {
        &self.planes[table]
    }

    /// Signed projections of `x` in table ℓ (the pre-sign values — the
    /// soft hasher consumes these directly).
    pub fn project(&self, table: usize, x: &[f32]) -> Vec<f32> {
        self.planes[table].matvec(x)
    }

    /// Hard bucket id of `x` in table ℓ: packed sign bits, bit i set iff
    /// `w_i · x >= 0`.
    pub fn bucket_of(&self, table: usize, x: &[f32]) -> u16 {
        let proj = self.project(table, x);
        pack_signs(&proj)
    }

    /// All-table bucket ids of a single vector.
    pub fn hash_one(&self, x: &[f32]) -> Vec<u16> {
        (0..self.params.l).map(|t| self.bucket_of(t, x)).collect()
    }

    /// Algorithm 1: hash every key, cache bucket ids + value norms.
    pub fn hash_keys(&self, keys: &Matrix, values: &Matrix) -> KeyHashes {
        assert_eq!(keys.cols, self.dim);
        assert_eq!(keys.rows, values.rows);
        let n = keys.rows;
        let l = self.params.l;
        let mut bucket_ids = vec![0u16; n * l];
        for j in 0..n {
            let key = keys.row(j);
            for t in 0..l {
                bucket_ids[j * l + t] = self.bucket_of(t, key);
            }
        }
        KeyHashes::from_row_major(l, self.params.buckets(), &bucket_ids, values.row_norms())
    }

    /// Algorithm 1 across a worker pool: each key's `L`-table signature
    /// row is independent, so threads hash disjoint key ranges. Output
    /// is bit-identical to [`SimHash::hash_keys`].
    pub fn hash_keys_with(&self, keys: &Matrix, values: &Matrix, pool: &WorkerPool) -> KeyHashes {
        assert_eq!(keys.cols, self.dim);
        assert_eq!(keys.rows, values.rows);
        let n = keys.rows;
        let l = self.params.l;
        let mut bucket_ids = vec![0u16; n * l];
        pool.fill_rows(&mut bucket_ids, l, |j, row| {
            let key = keys.row(j);
            for (t, slot) in row.iter_mut().enumerate() {
                *slot = self.bucket_of(t, key);
            }
        });
        KeyHashes::from_row_major(l, self.params.buckets(), &bucket_ids, values.row_norms())
    }

    /// Theoretical SimHash collision probability for one plane:
    /// `1 - θ/π` where θ is the angle between x and y. The P-plane
    /// bucket-collision probability is this to the P-th power — the
    /// angular kernel `w_j` of the paper's Section 5 (eq. 4).
    pub fn collision_probability(&self, cosine: f32) -> f64 {
        let c = cosine.clamp(-1.0, 1.0) as f64;
        let per_plane = 1.0 - c.acos() / std::f64::consts::PI;
        per_plane.powi(self.params.p as i32)
    }
}

/// Pack sign bits: bit i of the result is set iff proj[i] >= 0.
#[inline]
pub fn pack_signs(proj: &[f32]) -> u16 {
    debug_assert!(proj.len() <= 16);
    let mut b = 0u16;
    for (i, &v) in proj.iter().enumerate() {
        if v >= 0.0 {
            b |= 1 << i;
        }
    }
    b
}

/// The ±1 corner vector of bucket `r` for P planes: coordinate i is +1 if
/// bit i of r is set else -1. These are the `c_r` of Algorithm 2.
pub fn corner(r: u16, p: usize) -> Vec<f32> {
    (0..p).map(|i| if r >> i & 1 == 1 { 1.0 } else { -1.0 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::{check_default, gen};

    fn small() -> SimHash {
        SimHash::new(LshParams { p: 6, l: 20, tau: 0.5 }, 32, 42)
    }

    #[test]
    fn deterministic_in_seed() {
        let a = SimHash::new(LshParams::paper_default(), 16, 7);
        let b = SimHash::new(LshParams::paper_default(), 16, 7);
        let mut rng = Pcg64::seeded(1);
        let x = rng.normal_vec(16);
        assert_eq!(a.hash_one(&x), b.hash_one(&x));
    }

    #[test]
    fn tables_are_independent() {
        let h = small();
        let mut rng = Pcg64::seeded(2);
        let x = rng.normal_vec(32);
        let ids = h.hash_one(&x);
        let distinct: std::collections::HashSet<u16> = ids.iter().copied().collect();
        assert!(distinct.len() > 5, "tables should disagree: {distinct:?}");
    }

    #[test]
    fn same_vector_always_collides() {
        let h = small();
        let mut rng = Pcg64::seeded(3);
        let x = rng.normal_vec(32);
        let kx = Matrix::from_vec(1, 32, x.clone());
        let hashes = h.hash_keys(&kx, &kx);
        for t in 0..h.params.l {
            assert_eq!(hashes.bucket(0, t), h.bucket_of(t, &x));
        }
    }

    #[test]
    fn negated_vector_lands_in_complement_bucket() {
        let h = small();
        let mut rng = Pcg64::seeded(4);
        let x = rng.normal_vec(32);
        let neg: Vec<f32> = x.iter().map(|v| -v).collect();
        // Probability of a zero projection is nil; complement bits.
        let mask = (1u16 << h.params.p) - 1;
        for t in 0..h.params.l {
            assert_eq!(h.bucket_of(t, &neg), !h.bucket_of(t, &x) & mask);
        }
    }

    #[test]
    fn pack_signs_known() {
        assert_eq!(pack_signs(&[1.0, -1.0, 0.5]), 0b101);
        assert_eq!(pack_signs(&[-1.0, -2.0]), 0);
        // sign(0) counts as +.
        assert_eq!(pack_signs(&[0.0]), 1);
    }

    #[test]
    fn corner_roundtrip() {
        for r in 0..16u16 {
            let c = corner(r, 4);
            let packed = pack_signs(&c);
            assert_eq!(packed, r);
        }
    }

    #[test]
    fn collision_prob_monotone_in_cosine() {
        let h = small();
        let p1 = h.collision_probability(0.9);
        let p2 = h.collision_probability(0.5);
        let p3 = h.collision_probability(-0.5);
        assert!(p1 > p2 && p2 > p3);
        assert!((h.collision_probability(1.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empirical_collision_rate_matches_theory() {
        // Monte-Carlo check of the SimHash identity Pr[collide] =
        // (1 - θ/π)^P over random query/key pairs at fixed cosine.
        let params = LshParams { p: 4, l: 400, tau: 0.5 };
        let h = SimHash::new(params, 48, 99);
        let mut rng = Pcg64::seeded(5);
        for &cos in &[0.8f32, 0.3, 0.0] {
            let q = gen::unit_vec(&mut rng, 48);
            let k = gen::key_with_cosine(&mut rng, &q, cos);
            let qb = h.hash_one(&q);
            let kb = h.hash_one(&k);
            let collisions = qb.iter().zip(&kb).filter(|(a, b)| a == b).count();
            let emp = collisions as f64 / params.l as f64;
            let theo = h.collision_probability(cos);
            assert!(
                (emp - theo).abs() < 0.08,
                "cos={cos} empirical={emp:.3} theoretical={theo:.3}"
            );
        }
    }

    #[test]
    fn prop_bucket_ids_in_range(){
        check_default("bucket-range", |rng, _| {
            let p = 1 + rng.below_usize(12);
            let l = 1 + rng.below_usize(8);
            let d = gen::size(rng, 2, 64);
            let h = SimHash::new(LshParams { p, l, tau: 0.5 }, d, rng.next_u64());
            let x = rng.normal_vec(d);
            for b in h.hash_one(&x) {
                prop_assert!((b as usize) < (1 << p), "b={b} p={p}");
            }
            Ok(())
        });
    }

    #[test]
    fn pooled_hash_keys_matches_serial() {
        let h = SimHash::new(LshParams { p: 8, l: 12, tau: 0.5 }, 24, 7);
        let mut rng = Pcg64::seeded(8);
        let keys = Matrix::gaussian(300, 24, &mut rng);
        let vals = Matrix::gaussian(300, 24, &mut rng);
        let pool = WorkerPool::new(4);
        let serial = h.hash_keys(&keys, &vals);
        let pooled = h.hash_keys_with(&keys, &vals, &pool);
        assert_eq!(serial.to_row_major(), pooled.to_row_major());
        assert_eq!(serial.value_norms, pooled.value_norms);
    }

    #[test]
    fn key_hashes_push_appends() {
        let h = small();
        let mut rng = Pcg64::seeded(6);
        let keys = Matrix::gaussian(4, 32, &mut rng);
        let vals = Matrix::gaussian(4, 32, &mut rng);
        let mut kh = h.hash_keys(&keys, &vals);
        let newk = rng.normal_vec(32);
        let buckets = h.hash_one(&newk);
        kh.push(&buckets, 2.5);
        assert_eq!(kh.n, 5);
        assert_eq!(kh.key_row(4), buckets);
        assert_eq!(kh.value_norms[4], 2.5);
    }

    #[test]
    fn soa_layout_round_trips_row_major() {
        // from_row_major / bucket / key_row / to_row_major all agree,
        // across multiple blocks and a partial tail.
        let l = 5;
        let r = 32;
        let n = 2 * BLOCK_TOKENS + 17;
        let mut rng = Pcg64::seeded(9);
        let ids: Vec<u16> = (0..n * l).map(|_| rng.below(r as u64) as u16).collect();
        let norms: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let kh = KeyHashes::from_row_major(l, r, &ids, norms.clone());
        assert_eq!(kh.n, n);
        assert_eq!(kh.n_blocks(), 3);
        assert_eq!(kh.block_len(2), 17);
        assert_eq!(kh.to_row_major(), ids);
        for j in [0, 1, BLOCK_TOKENS - 1, BLOCK_TOKENS, n - 1] {
            assert_eq!(kh.key_row(j), ids[j * l..(j + 1) * l].to_vec(), "key {j}");
        }
        assert_eq!(kh.value_norms, norms);
    }

    #[test]
    fn push_matches_bulk_construction() {
        // Incremental pushes and from_row_major must agree on layout,
        // summaries, and norms — including a tail block mutated in
        // place across a block boundary.
        let l = 4;
        let r = 64;
        let n = BLOCK_TOKENS + 9;
        let mut rng = Pcg64::seeded(10);
        let ids: Vec<u16> = (0..n * l).map(|_| rng.below(r as u64) as u16).collect();
        let norms: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.1).collect();
        let bulk = KeyHashes::from_row_major(l, r, &ids, norms.clone());
        let mut inc = KeyHashes::empty(l, r);
        for j in 0..n {
            inc.push(&ids[j * l..(j + 1) * l], norms[j]);
        }
        assert_eq!(inc.n, bulk.n);
        assert_eq!(inc.to_row_major(), bulk.to_row_major());
        for blk in 0..bulk.n_blocks() {
            assert_eq!(inc.block_max_norm(blk), bulk.block_max_norm(blk), "block {blk}");
            for t in 0..l {
                assert_eq!(inc.block_table_ids(blk, t), bulk.block_table_ids(blk, t));
            }
        }
    }

    #[test]
    fn extend_from_equals_bulk_hash_of_concatenation() {
        let h = small();
        let mut rng = Pcg64::seeded(14);
        let k1 = Matrix::gaussian(70, 32, &mut rng);
        let v1 = Matrix::gaussian(70, 32, &mut rng);
        let k2 = Matrix::gaussian(30, 32, &mut rng);
        let v2 = Matrix::gaussian(30, 32, &mut rng);
        let mut inc = h.hash_keys(&k1, &v1);
        inc.extend_from(&h.hash_keys(&k2, &v2));
        let kall = Matrix::from_vec(100, 32, [k1.data, k2.data].concat());
        let vall = Matrix::from_vec(100, 32, [v1.data, v2.data].concat());
        let bulk = h.hash_keys(&kall, &vall);
        assert_eq!(inc.n, 100);
        assert_eq!(inc.to_row_major(), bulk.to_row_major());
        assert_eq!(inc.value_norms, bulk.value_norms);
        for blk in 0..bulk.n_blocks() {
            assert_eq!(inc.block_max_norm(blk), bulk.block_max_norm(blk), "block {blk}");
            for t in 0..bulk.l {
                assert_eq!(inc.block_table_ids(blk, t), bulk.block_table_ids(blk, t));
            }
        }
    }

    #[test]
    fn block_summaries_cover_every_resident_id() {
        // The pruning invariant: every live key's id is a member of its
        // block's per-table summary, and the block max norm dominates
        // every resident norm.
        let h = small();
        let mut rng = Pcg64::seeded(11);
        let n = BLOCK_TOKENS + 21;
        let keys = Matrix::gaussian(n, 32, &mut rng);
        let vals = Matrix::gaussian(n, 32, &mut rng);
        let kh = h.hash_keys(&keys, &vals);
        for j in 0..n {
            let blk = j / BLOCK_TOKENS;
            for t in 0..kh.l {
                match kh.block_table_ids(blk, t) {
                    Some(ids) => assert!(
                        ids.contains(&kh.bucket(j, t)),
                        "key {j} table {t} missing from summary"
                    ),
                    // Saturated: covered by the table-wide fallback.
                    None => assert!(kh.summaries_saturated()),
                }
            }
            assert!(kh.block_max_norm(blk) >= kh.value_norms[j], "key {j} norm");
        }
    }

    #[test]
    fn summary_saturates_at_cap_and_stays_saturated() {
        // One table, bucket space wide enough to overflow the budget:
        // the first SUMMARY_CAP distinct ids are tracked, the next one
        // saturates the cell, and later ids (new or repeated) are
        // no-ops.
        let r = 4 * SUMMARY_CAP;
        let mut kh = KeyHashes::empty(1, r);
        for id in 0..SUMMARY_CAP as u16 {
            kh.push(&[id], 1.0);
        }
        assert!(!kh.summaries_saturated());
        let ids = kh.block_table_ids(0, 0).expect("under budget");
        assert_eq!(ids.len(), SUMMARY_CAP);
        kh.push(&[SUMMARY_CAP as u16], 1.0); // budget overflow
        assert!(kh.summaries_saturated());
        assert_eq!(kh.block_table_ids(0, 0), None);
        kh.push(&[0], 2.0); // repeat id after saturation: still None
        assert_eq!(kh.block_table_ids(0, 0), None);
        assert_eq!(kh.block_max_norm(0), 2.0, "norms keep folding in");
        // The hard bound conservatively counts the saturated table.
        assert_eq!(kh.block_collision_bound(0, &[(r - 1) as u16]), 1.0);
    }

    #[test]
    fn narrow_bucket_spaces_never_saturate() {
        // r <= SUMMARY_CAP cannot overflow the budget: there are at
        // most r distinct ids.
        let r = SUMMARY_CAP;
        let mut kh = KeyHashes::empty(1, r);
        for j in 0..2 * BLOCK_TOKENS {
            kh.push(&[(j % r) as u16], 1.0);
        }
        assert!(!kh.summaries_saturated());
        assert_eq!(kh.block_table_ids(0, 0).expect("full space").len(), r);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn push_rejects_out_of_range_ids() {
        // The satellite fix: out-of-range ids used to be silently
        // masked by the release-mode gather; now they fail loudly at
        // the single validated entry point.
        let mut kh = KeyHashes::empty(3, 16);
        kh.push(&[1, 2, 16], 1.0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn from_row_major_rejects_out_of_range_ids() {
        let _ = KeyHashes::from_row_major(2, 8, &[0, 7, 8, 1], vec![1.0, 1.0]);
    }

    #[test]
    fn collision_counts_match_scalar_reference() {
        // The blocked SoA kernel against the obvious per-key scalar
        // loop, across block boundaries and a partial tail.
        let h = small();
        let mut rng = Pcg64::seeded(12);
        let n = 2 * BLOCK_TOKENS + 5;
        let keys = Matrix::gaussian(n, 32, &mut rng);
        let kh = h.hash_keys(&keys, &keys);
        let q = rng.normal_vec(32);
        let qb = h.hash_one(&q);
        let mut got = vec![9.0f32; 3]; // stale, wrong size
        kh.collision_counts_into(&qb, &mut got);
        assert_eq!(got.len(), n);
        for j in 0..n {
            let want = (0..kh.l).filter(|&t| kh.bucket(j, t) == qb[t]).count() as f32;
            assert_eq!(got[j], want, "key {j}");
        }
    }

    #[test]
    fn collision_bound_dominates_block_counts() {
        let h = small();
        let mut rng = Pcg64::seeded(13);
        let n = BLOCK_TOKENS + 30;
        let keys = Matrix::gaussian(n, 32, &mut rng);
        let kh = h.hash_keys(&keys, &keys);
        let q = rng.normal_vec(32);
        let qb = h.hash_one(&q);
        let mut counts = Vec::new();
        kh.collision_counts_into(&qb, &mut counts);
        for blk in 0..kh.n_blocks() {
            let ub = kh.block_collision_bound(blk, &qb);
            let base = blk * BLOCK_TOKENS;
            for j in base..base + kh.block_len(blk) {
                assert!(counts[j] <= ub, "key {j}: count {} > bound {ub}", counts[j]);
            }
        }
    }
}
