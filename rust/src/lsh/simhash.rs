//! SimHash (sign-random-projection) tables — the paper's Algorithm 1.
//!
//! Each of the `L` tables draws a `P x d` Gaussian hyperplane matrix
//! `W^(ℓ)`. A key `k` hashes to the bucket whose id is the packed sign
//! pattern of `W^(ℓ) k`. Bucket ids are stored packed (`P ≤ 16` bits per
//! table), giving the paper's `L·P` bits/token memory footprint.

use crate::linalg::Matrix;
use crate::lsh::params::LshParams;
use crate::util::pool::WorkerPool;
use crate::util::rng::Pcg64;

/// The hyperplanes of `L` independent SimHash tables.
#[derive(Clone, Debug)]
pub struct SimHash {
    pub params: LshParams,
    pub dim: usize,
    /// One `P x dim` Gaussian matrix per table.
    planes: Vec<Matrix>,
}

/// Packed bucket ids for a set of keys: `ids[j * L + ℓ]` is key j's
/// bucket in table ℓ (a value in `0..2^P`), plus cached value norms.
#[derive(Clone, Debug)]
pub struct KeyHashes {
    pub n: usize,
    pub l: usize,
    /// Row-major `n x L` bucket ids. u16 suffices for P <= 16.
    pub bucket_ids: Vec<u16>,
    /// ‖v_j‖₂ cached at prefill (Alg. 1 returns these).
    pub value_norms: Vec<f32>,
}

impl KeyHashes {
    #[inline]
    pub fn bucket(&self, key: usize, table: usize) -> u16 {
        self.bucket_ids[key * self.l + table]
    }

    /// All L bucket ids of one key.
    #[inline]
    pub fn key_row(&self, key: usize) -> &[u16] {
        &self.bucket_ids[key * self.l..(key + 1) * self.l]
    }

    /// Append a single new key (decode-time cache extension).
    pub fn push(&mut self, buckets: &[u16], value_norm: f32) {
        assert_eq!(buckets.len(), self.l);
        self.bucket_ids.extend_from_slice(buckets);
        self.value_norms.push(value_norm);
        self.n += 1;
    }

    /// Per-key table-collision counts against a query's bucket row
    /// (`q_buckets[t]` = the query's bucket in table t), written into a
    /// reusable buffer as f32 (counts ≤ L are exact in f32). The shared
    /// kernel of hard-LSH scoring and MagicPIG candidate sampling.
    pub fn collision_counts_into(&self, q_buckets: &[u16], out: &mut Vec<f32>) {
        assert_eq!(q_buckets.len(), self.l);
        out.clear();
        out.resize(self.n, 0.0);
        for (j, slot) in out.iter_mut().enumerate() {
            let row = self.key_row(j);
            let mut c = 0u32;
            for t in 0..self.l {
                c += (row[t] == q_buckets[t]) as u32;
            }
            *slot = c as f32;
        }
    }
}

impl SimHash {
    /// Draw the hyperplanes. Deterministic in (seed, params, dim).
    pub fn new(params: LshParams, dim: usize, seed: u64) -> SimHash {
        params.validate().expect("invalid LSH params");
        let mut planes = Vec::with_capacity(params.l);
        for table in 0..params.l {
            let mut rng = Pcg64::new(seed, table as u64 + 1);
            planes.push(Matrix::gaussian(params.p, dim, &mut rng));
        }
        SimHash { params, dim, planes }
    }

    /// Hyperplane matrix of table ℓ.
    pub fn plane(&self, table: usize) -> &Matrix {
        &self.planes[table]
    }

    /// Signed projections of `x` in table ℓ (the pre-sign values — the
    /// soft hasher consumes these directly).
    pub fn project(&self, table: usize, x: &[f32]) -> Vec<f32> {
        self.planes[table].matvec(x)
    }

    /// Hard bucket id of `x` in table ℓ: packed sign bits, bit i set iff
    /// `w_i · x >= 0`.
    pub fn bucket_of(&self, table: usize, x: &[f32]) -> u16 {
        let proj = self.project(table, x);
        pack_signs(&proj)
    }

    /// All-table bucket ids of a single vector.
    pub fn hash_one(&self, x: &[f32]) -> Vec<u16> {
        (0..self.params.l).map(|t| self.bucket_of(t, x)).collect()
    }

    /// Algorithm 1: hash every key, cache bucket ids + value norms.
    pub fn hash_keys(&self, keys: &Matrix, values: &Matrix) -> KeyHashes {
        assert_eq!(keys.cols, self.dim);
        assert_eq!(keys.rows, values.rows);
        let n = keys.rows;
        let l = self.params.l;
        let mut bucket_ids = vec![0u16; n * l];
        for j in 0..n {
            let key = keys.row(j);
            for t in 0..l {
                bucket_ids[j * l + t] = self.bucket_of(t, key);
            }
        }
        KeyHashes { n, l, bucket_ids, value_norms: values.row_norms() }
    }

    /// Algorithm 1 across a worker pool: each key's `L`-table signature
    /// row is independent, so threads hash disjoint key ranges. Output
    /// is bit-identical to [`SimHash::hash_keys`].
    pub fn hash_keys_with(&self, keys: &Matrix, values: &Matrix, pool: &WorkerPool) -> KeyHashes {
        assert_eq!(keys.cols, self.dim);
        assert_eq!(keys.rows, values.rows);
        let n = keys.rows;
        let l = self.params.l;
        let mut bucket_ids = vec![0u16; n * l];
        pool.fill_rows(&mut bucket_ids, l, |j, row| {
            let key = keys.row(j);
            for (t, slot) in row.iter_mut().enumerate() {
                *slot = self.bucket_of(t, key);
            }
        });
        KeyHashes { n, l, bucket_ids, value_norms: values.row_norms() }
    }

    /// Theoretical SimHash collision probability for one plane:
    /// `1 - θ/π` where θ is the angle between x and y. The P-plane
    /// bucket-collision probability is this to the P-th power — the
    /// angular kernel `w_j` of the paper's Section 5 (eq. 4).
    pub fn collision_probability(&self, cosine: f32) -> f64 {
        let c = cosine.clamp(-1.0, 1.0) as f64;
        let per_plane = 1.0 - c.acos() / std::f64::consts::PI;
        per_plane.powi(self.params.p as i32)
    }
}

/// Pack sign bits: bit i of the result is set iff proj[i] >= 0.
#[inline]
pub fn pack_signs(proj: &[f32]) -> u16 {
    debug_assert!(proj.len() <= 16);
    let mut b = 0u16;
    for (i, &v) in proj.iter().enumerate() {
        if v >= 0.0 {
            b |= 1 << i;
        }
    }
    b
}

/// The ±1 corner vector of bucket `r` for P planes: coordinate i is +1 if
/// bit i of r is set else -1. These are the `c_r` of Algorithm 2.
pub fn corner(r: u16, p: usize) -> Vec<f32> {
    (0..p).map(|i| if r >> i & 1 == 1 { 1.0 } else { -1.0 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::{check_default, gen};

    fn small() -> SimHash {
        SimHash::new(LshParams { p: 6, l: 20, tau: 0.5 }, 32, 42)
    }

    #[test]
    fn deterministic_in_seed() {
        let a = SimHash::new(LshParams::paper_default(), 16, 7);
        let b = SimHash::new(LshParams::paper_default(), 16, 7);
        let mut rng = Pcg64::seeded(1);
        let x = rng.normal_vec(16);
        assert_eq!(a.hash_one(&x), b.hash_one(&x));
    }

    #[test]
    fn tables_are_independent() {
        let h = small();
        let mut rng = Pcg64::seeded(2);
        let x = rng.normal_vec(32);
        let ids = h.hash_one(&x);
        let distinct: std::collections::HashSet<u16> = ids.iter().copied().collect();
        assert!(distinct.len() > 5, "tables should disagree: {distinct:?}");
    }

    #[test]
    fn same_vector_always_collides() {
        let h = small();
        let mut rng = Pcg64::seeded(3);
        let x = rng.normal_vec(32);
        let kx = Matrix::from_vec(1, 32, x.clone());
        let hashes = h.hash_keys(&kx, &kx);
        for t in 0..h.params.l {
            assert_eq!(hashes.bucket(0, t), h.bucket_of(t, &x));
        }
    }

    #[test]
    fn negated_vector_lands_in_complement_bucket() {
        let h = small();
        let mut rng = Pcg64::seeded(4);
        let x = rng.normal_vec(32);
        let neg: Vec<f32> = x.iter().map(|v| -v).collect();
        // Probability of a zero projection is nil; complement bits.
        let mask = (1u16 << h.params.p) - 1;
        for t in 0..h.params.l {
            assert_eq!(h.bucket_of(t, &neg), !h.bucket_of(t, &x) & mask);
        }
    }

    #[test]
    fn pack_signs_known() {
        assert_eq!(pack_signs(&[1.0, -1.0, 0.5]), 0b101);
        assert_eq!(pack_signs(&[-1.0, -2.0]), 0);
        // sign(0) counts as +.
        assert_eq!(pack_signs(&[0.0]), 1);
    }

    #[test]
    fn corner_roundtrip() {
        for r in 0..16u16 {
            let c = corner(r, 4);
            let packed = pack_signs(&c);
            assert_eq!(packed, r);
        }
    }

    #[test]
    fn collision_prob_monotone_in_cosine() {
        let h = small();
        let p1 = h.collision_probability(0.9);
        let p2 = h.collision_probability(0.5);
        let p3 = h.collision_probability(-0.5);
        assert!(p1 > p2 && p2 > p3);
        assert!((h.collision_probability(1.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empirical_collision_rate_matches_theory() {
        // Monte-Carlo check of the SimHash identity Pr[collide] =
        // (1 - θ/π)^P over random query/key pairs at fixed cosine.
        let params = LshParams { p: 4, l: 400, tau: 0.5 };
        let h = SimHash::new(params, 48, 99);
        let mut rng = Pcg64::seeded(5);
        for &cos in &[0.8f32, 0.3, 0.0] {
            let q = gen::unit_vec(&mut rng, 48);
            let k = gen::key_with_cosine(&mut rng, &q, cos);
            let qb = h.hash_one(&q);
            let kb = h.hash_one(&k);
            let collisions = qb.iter().zip(&kb).filter(|(a, b)| a == b).count();
            let emp = collisions as f64 / params.l as f64;
            let theo = h.collision_probability(cos);
            assert!(
                (emp - theo).abs() < 0.08,
                "cos={cos} empirical={emp:.3} theoretical={theo:.3}"
            );
        }
    }

    #[test]
    fn prop_bucket_ids_in_range(){
        check_default("bucket-range", |rng, _| {
            let p = 1 + rng.below_usize(12);
            let l = 1 + rng.below_usize(8);
            let d = gen::size(rng, 2, 64);
            let h = SimHash::new(LshParams { p, l, tau: 0.5 }, d, rng.next_u64());
            let x = rng.normal_vec(d);
            for b in h.hash_one(&x) {
                prop_assert!((b as usize) < (1 << p), "b={b} p={p}");
            }
            Ok(())
        });
    }

    #[test]
    fn pooled_hash_keys_matches_serial() {
        let h = SimHash::new(LshParams { p: 8, l: 12, tau: 0.5 }, 24, 7);
        let mut rng = Pcg64::seeded(8);
        let keys = Matrix::gaussian(300, 24, &mut rng);
        let vals = Matrix::gaussian(300, 24, &mut rng);
        let pool = WorkerPool::new(4);
        let serial = h.hash_keys(&keys, &vals);
        let pooled = h.hash_keys_with(&keys, &vals, &pool);
        assert_eq!(serial.bucket_ids, pooled.bucket_ids);
        assert_eq!(serial.value_norms, pooled.value_norms);
    }

    #[test]
    fn key_hashes_push_appends() {
        let h = small();
        let mut rng = Pcg64::seeded(6);
        let keys = Matrix::gaussian(4, 32, &mut rng);
        let vals = Matrix::gaussian(4, 32, &mut rng);
        let mut kh = h.hash_keys(&keys, &vals);
        let newk = rng.normal_vec(32);
        let buckets = h.hash_one(&newk);
        kh.push(&buckets, 2.5);
        assert_eq!(kh.n, 5);
        assert_eq!(kh.key_row(4), buckets.as_slice());
        assert_eq!(kh.value_norms[4], 2.5);
    }
}
