//! SimHash (sign-random-projection) tables — the paper's Algorithm 1.
//!
//! Each of the `L` tables draws a `P x d` Gaussian hyperplane matrix
//! `W^(ℓ)`. A key `k` hashes to the bucket whose id is the packed sign
//! pattern of `W^(ℓ) k`. Bucket ids are stored packed (`P ≤ 16` bits per
//! table), giving the paper's `L·P` bits/token memory footprint.
//!
//! Storage is **table-major SoA blocks** ([`BLOCK_TOKENS`] keys per
//! block, a whole number of paged-KV pages): within a block, one table's
//! bucket ids for all keys are contiguous, so the scoring hot paths
//! stream table-outer/key-inner instead of gathering an `L`-wide row per
//! key. Each block ([`HashBlock`]) additionally carries a per-table
//! summary (the set of distinct bucket ids present, capped at
//! [`SUMMARY_CAP`] with a saturating "use the table-wide max" fallback)
//! plus the block's max value norm, from which the scorers compute
//! *admissible* per-block score upper bounds — the branch-and-bound
//! pruning of `SoftScorer::select_pruned_into` and
//! `HardScorer::select_pruned_into`.
//!
//! Blocks are held either **owned** (the mutable tail, privately built
//! runs) or **shared** (`Arc<HashBlock>` — an immutable full block
//! published to the prefix cache's block arena, see `kvcache::prefix`).
//! A full block never mutates, so sharing is transparent: a prefix-hit
//! request attaches the arena's handles ([`KeyHashes::attach_shared`])
//! and hashes only its private tail, bit-identical to hashing from
//! scratch.

use std::sync::Arc;

use crate::linalg::Matrix;
use crate::lsh::params::LshParams;
use crate::util::pool::WorkerPool;
use crate::util::rng::Pcg64;

/// Keys per SoA hash block. A multiple of the paged-KV page size
/// (`kvcache::PAGE_TOKENS`, asserted there), so block boundaries always
/// land on page boundaries and a page never straddles two blocks.
pub const BLOCK_TOKENS: usize = 64;

/// Distinct-bucket budget of one (block, table) summary. Uncapped
/// summaries cost a worst-case `BLOCK_TOKENS` u16 per cell — doubling
/// the signature bytes; the cap cuts that to `SUMMARY_CAP / BLOCK_TOKENS`
/// (4x less). A cell whose distinct-id count overflows the budget
/// **saturates**: its summary is dropped and the scorers fall back to
/// the table-wide max probability (soft) / an unconditional collision
/// (hard) for that term — still admissible, because the table-wide max
/// dominates every bucket's probability. Blocks diverse enough to
/// overflow had near-table-max bounds anyway; the blocks pruning
/// actually wins on (temporally clustered keys sharing buckets) stay
/// under the cap.
pub const SUMMARY_CAP: usize = 16;

/// `sum_lens` sentinel marking a saturated (block, table) summary.
const SUMMARY_SATURATED: u16 = u16::MAX;

/// The hyperplanes of `L` independent SimHash tables.
#[derive(Clone, Debug)]
pub struct SimHash {
    pub params: LshParams,
    pub dim: usize,
    /// One `P x dim` Gaussian matrix per table.
    planes: Vec<Matrix>,
}

/// One [`BLOCK_TOKENS`]-key SoA hash block: table-major bucket ids
/// (table `t`'s slots at `t * BLOCK_TOKENS`), the per-table distinct-id
/// summaries, the block's max value norm, and the resident value norms
/// (carried per block so a shared block can reconstitute a request's
/// contiguous norm vector). Storage is always allocated full-size; the
/// resident count is `len()`. Immutable once full — the prefix cache
/// shares full blocks across requests through `Arc<HashBlock>`.
#[derive(Clone, Debug)]
pub struct HashBlock {
    /// Tables (L) this block was built for.
    l: usize,
    /// Table-major ids (`l * BLOCK_TOKENS`).
    data: Vec<u16>,
    /// Distinct ids of table t at `sum_ids[t * SUMMARY_CAP..][..sum_lens[t]]`.
    sum_ids: Vec<u16>,
    /// Distinct-id count per table; [`SUMMARY_SATURATED`] marks overflow.
    sum_lens: Vec<u16>,
    /// Max ‖v‖₂ among resident keys (0.0 while empty).
    max_norm: f32,
    /// Whether any table summary overflowed its budget.
    saturated: bool,
    /// ‖v_j‖₂ of the resident keys, slot order.
    norms: Vec<f32>,
}

impl HashBlock {
    /// A fresh all-empty block for `l` tables.
    pub fn fresh(l: usize) -> HashBlock {
        HashBlock {
            l,
            data: vec![0; l * BLOCK_TOKENS],
            sum_ids: vec![0; l * SUMMARY_CAP],
            sum_lens: vec![0; l],
            max_norm: 0.0,
            saturated: false,
            norms: Vec::new(),
        }
    }

    /// Resident keys.
    pub fn len(&self) -> usize {
        self.norms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.norms.is_empty()
    }

    /// Whether every slot holds a key — only full blocks are shareable.
    pub fn is_full(&self) -> bool {
        self.norms.len() == BLOCK_TOKENS
    }

    #[inline]
    fn id_at(&self, table: usize, slot: usize) -> u16 {
        debug_assert!(table < self.l && slot < BLOCK_TOKENS);
        // SAFETY: `data` holds l * BLOCK_TOKENS ids from construction;
        // callers come through KeyHashes::bucket, which asserts
        // table < l, and slot = key % BLOCK_TOKENS < BLOCK_TOKENS.
        unsafe { *self.data.get_unchecked(table * BLOCK_TOKENS + slot) }
    }

    #[inline]
    fn set_id(&mut self, table: usize, slot: usize, id: u16) {
        if let Some(cell) = self.data.get_mut(table * BLOCK_TOKENS + slot) {
            *cell = id;
        }
    }

    /// Distinct ids of one table, or `None` once its budget overflowed.
    #[inline]
    fn table_ids(&self, table: usize) -> Option<&[u16]> {
        let len = *self.sum_lens.get(table)?;
        if len == SUMMARY_SATURATED {
            return None;
        }
        let base = table * SUMMARY_CAP;
        self.sum_ids.get(base..base + len as usize)
    }

    /// Record one key's id in `table`'s summary: dedups against the ids
    /// already present, saturating when a new distinct id would exceed
    /// the [`SUMMARY_CAP`] budget.
    #[inline]
    fn note(&mut self, table: usize, id: u16) {
        let Some(len_slot) = self.sum_lens.get_mut(table) else { return };
        if *len_slot == SUMMARY_SATURATED {
            return;
        }
        let len = *len_slot as usize;
        let base = table * SUMMARY_CAP;
        let Some(seen) = self.sum_ids.get(base..base + len) else { return };
        if seen.contains(&id) {
            return;
        }
        if len == SUMMARY_CAP {
            *len_slot = SUMMARY_SATURATED;
            self.saturated = true;
            return;
        }
        if let Some(cell) = self.sum_ids.get_mut(base + len) {
            *cell = id;
        }
        *len_slot = (len + 1) as u16;
    }
}

/// Block storage slot: owned (the mutable tail, privately built runs)
/// or shared (an immutable full block from the prefix cache's arena).
#[derive(Clone, Debug)]
enum BlockStore {
    Owned(HashBlock),
    Shared(Arc<HashBlock>),
}

impl BlockStore {
    #[inline]
    fn block(&self) -> &HashBlock {
        match self {
            BlockStore::Owned(b) => b,
            BlockStore::Shared(a) => a,
        }
    }
}

/// Packed bucket ids for a set of keys in table-major SoA blocks, plus
/// cached value norms and per-block pruning summaries.
///
/// Key `j`'s bucket in table `t` lives in block `j / BLOCK_TOKENS` at
/// `(t, j % BLOCK_TOKENS)` — see [`HashBlock`]. Blocks always hold
/// full-size storage (the tail block is allocated full and filled as
/// keys arrive), so per-block slices are always in range.
///
/// Every stored id is validated against the bucket-space size `R = 2^P`
/// once, at construction / [`KeyHashes::push`] — the scoring kernels'
/// unchecked gathers rely on this invariant instead of re-masking ids
/// on the hot path. Shared blocks were validated by the store that
/// built them; callers attach only blocks built with identical LSH
/// params (same L and bucket space).
#[derive(Clone, Debug)]
pub struct KeyHashes {
    pub n: usize,
    pub l: usize,
    /// Bucket-space size (`2^P`); every id in the blocks is `< r`.
    r: usize,
    blocks: Vec<BlockStore>,
    /// ‖v_j‖₂ cached at prefill (Alg. 1 returns these), contiguous
    /// across blocks — the scorers consume it as one slice.
    pub value_norms: Vec<f32>,
    /// Whether any (block, table) summary has saturated (tells the
    /// scorers to compute table-wide maxima for the fallback bound).
    saturated: bool,
}

impl KeyHashes {
    /// An empty store for `l` tables over a bucket space of size `r`.
    pub fn empty(l: usize, r: usize) -> KeyHashes {
        assert!(l > 0, "L must be positive");
        assert!(r > 0 && r <= 1 << 16, "bucket space {r} out of u16 range");
        KeyHashes { n: 0, l, r, blocks: Vec::new(), value_norms: Vec::new(), saturated: false }
    }

    /// Build from a row-major `n x L` id table (the layout the pooled
    /// hashing fills, one key row per job). Validates every id against
    /// `r` once, here — the scoring kernels then gather unchecked.
    pub fn from_row_major(
        l: usize,
        r: usize,
        row_major: &[u16],
        value_norms: Vec<f32>,
    ) -> KeyHashes {
        let mut kh = KeyHashes::empty(l, r);
        assert_eq!(row_major.len() % l, 0, "id table is not n x L");
        let n = row_major.len() / l;
        assert_eq!(value_norms.len(), n, "value norms length mismatch");
        for (row, &norm) in row_major.chunks_exact(l).zip(value_norms.iter()) {
            kh.push(row, norm);
        }
        kh
    }

    /// Build a store whose leading blocks are shared handles — the
    /// prefix-cache hit path. The caller then pushes only the private
    /// tail keys; the result is bit-identical to hashing everything.
    pub fn from_shared(l: usize, r: usize, shared: &[Arc<HashBlock>]) -> KeyHashes {
        let mut kh = KeyHashes::empty(l, r);
        for block in shared {
            kh.attach_shared(block.clone());
        }
        kh
    }

    /// Map an immutable shared block as this store's next block: its
    /// [`BLOCK_TOKENS`] keys become resident without re-hashing.
    pub fn attach_shared(&mut self, block: Arc<HashBlock>) {
        assert_eq!(self.n % BLOCK_TOKENS, 0, "shared blocks attach on block boundaries");
        assert!(block.is_full(), "only full hash blocks are shareable");
        assert_eq!(block.l, self.l, "table count mismatch");
        self.saturated |= block.saturated;
        self.value_norms.extend_from_slice(&block.norms);
        self.n += BLOCK_TOKENS;
        self.blocks.push(BlockStore::Shared(block));
    }

    /// Convert every full owned block into a shared handle in place,
    /// returning the newly frozen `(block_index, handle)` pairs so the
    /// caller can publish them to the prefix cache's block arena.
    /// Already-shared blocks are skipped; the partial tail stays owned
    /// (mutable). Reads are unaffected — full blocks never mutate.
    pub fn freeze_full_blocks(&mut self) -> Vec<(usize, Arc<HashBlock>)> {
        let mut frozen = Vec::new();
        for (i, slot) in self.blocks.iter_mut().enumerate() {
            if let BlockStore::Owned(b) = slot {
                if b.is_full() {
                    let arc = Arc::new(std::mem::replace(b, HashBlock::fresh(0)));
                    *slot = BlockStore::Shared(arc.clone());
                    frozen.push((i, arc));
                }
            }
        }
        frozen
    }

    /// Bucket-space size (`2^P`) the stored ids were validated against.
    #[inline]
    pub fn r(&self) -> usize {
        self.r
    }

    #[inline]
    fn block_ref(&self, blk: usize) -> &HashBlock {
        assert!(blk < self.blocks.len(), "block {blk} out of range");
        // SAFETY: asserted in range just above.
        unsafe { self.blocks.get_unchecked(blk) }.block()
    }

    #[inline]
    pub fn bucket(&self, key: usize, table: usize) -> u16 {
        assert!(key < self.n, "key {key} out of range {}", self.n);
        assert!(table < self.l, "table {table} out of range {}", self.l);
        self.block_ref(key / BLOCK_TOKENS).id_at(table, key % BLOCK_TOKENS)
    }

    /// All L bucket ids of one key, gathered out of the SoA blocks.
    /// (Allocates — a compat/diagnostic view, not a hot path; the
    /// scoring kernels iterate blocks directly.)
    pub fn key_row(&self, key: usize) -> Vec<u16> {
        (0..self.l).map(|t| self.bucket(key, t)).collect()
    }

    /// The full id table in the legacy row-major `n x L` layout
    /// (equivalence tests against the pre-SoA reference).
    pub fn to_row_major(&self) -> Vec<u16> {
        let mut out = Vec::with_capacity(self.n * self.l);
        for j in 0..self.n {
            for t in 0..self.l {
                out.push(self.bucket(j, t));
            }
        }
        out
    }

    /// Number of SoA blocks currently allocated.
    #[inline]
    pub fn n_blocks(&self) -> usize {
        self.n.div_ceil(BLOCK_TOKENS)
    }

    /// Keys resident in block `blk` (the tail block may be partial).
    #[inline]
    pub fn block_len(&self, blk: usize) -> usize {
        (self.n - blk * BLOCK_TOKENS).min(BLOCK_TOKENS)
    }

    /// Block `blk`'s full `L x BLOCK_TOKENS` id storage (table-major;
    /// only the first [`KeyHashes::block_len`] slots of each table row
    /// hold live keys).
    #[inline]
    pub fn block_data(&self, blk: usize) -> &[u16] {
        &self.block_ref(blk).data
    }

    /// The distinct bucket ids block `blk` occupies in `table`
    /// (insertion-ordered), or `None` once the cell's
    /// [`SUMMARY_CAP`] budget overflowed. While `Some`, every live
    /// key's id is a member — the invariant the pruning bounds rest on;
    /// on `None` the scorers substitute the table-wide max, which
    /// dominates every bucket and keeps the bound admissible.
    #[inline]
    pub fn block_table_ids(&self, blk: usize, table: usize) -> Option<&[u16]> {
        self.block_ref(blk).table_ids(table)
    }

    /// Whether any (block, table) summary has saturated — tells the
    /// soft scorer to precompute per-table max probabilities for the
    /// fallback bound.
    #[inline]
    pub fn summaries_saturated(&self) -> bool {
        self.saturated
    }

    /// Max cached value norm of block `blk`.
    #[inline]
    pub fn block_max_norm(&self, blk: usize) -> f32 {
        self.block_ref(blk).max_norm
    }

    /// Append a single new key (decode-time cache extension), extending
    /// the tail block's storage and summaries in place. Ids are
    /// validated here — the scoring kernels gather unchecked.
    pub fn push(&mut self, buckets: &[u16], value_norm: f32) {
        assert_eq!(buckets.len(), self.l);
        let slot = self.n % BLOCK_TOKENS;
        if slot == 0 {
            self.blocks.push(BlockStore::Owned(HashBlock::fresh(self.l)));
        }
        // A shared block is always full (asserted at attach), so the
        // tail either predates any sharing or was just pushed above.
        assert!(
            matches!(self.blocks.last(), Some(BlockStore::Owned(_))),
            "tail block must be owned"
        );
        let r = self.r;
        let Some(BlockStore::Owned(tail)) = self.blocks.last_mut() else { return };
        for (t, &b) in buckets.iter().enumerate() {
            assert!((b as usize) < r, "bucket id {b} out of range for R={r}");
            tail.set_id(t, slot, b);
            tail.note(t, b);
        }
        tail.max_norm = tail.max_norm.max(value_norm);
        tail.norms.push(value_norm);
        self.saturated |= tail.saturated;
        self.value_norms.push(value_norm);
        self.n += 1;
    }

    /// Append every key of `other` (same L and bucket space) — the
    /// incremental-prefill path. One reusable row buffer instead of a
    /// per-key allocation.
    pub fn extend_from(&mut self, other: &KeyHashes) {
        assert_eq!(self.l, other.l, "table count mismatch");
        assert_eq!(self.r, other.r, "bucket space mismatch");
        let mut row = vec![0u16; self.l];
        for (j, &norm) in other.value_norms.iter().enumerate() {
            for (t, slot) in row.iter_mut().enumerate() {
                *slot = other.bucket(j, t);
            }
            self.push(&row, norm);
        }
    }

    /// Per-key table-collision counts against a query's bucket row
    /// (`q_buckets[t]` = the query's bucket in table t), written into a
    /// reusable buffer as f32 (counts ≤ L are exact in f32). The shared
    /// kernel of hard-LSH scoring and MagicPIG candidate sampling —
    /// streams the SoA blocks table-outer/key-inner.
    pub fn collision_counts_into(&self, q_buckets: &[u16], out: &mut Vec<f32>) {
        assert_eq!(q_buckets.len(), self.l);
        out.clear();
        out.resize(self.n, 0.0);
        for (blk, chunk) in out.chunks_mut(BLOCK_TOKENS).enumerate() {
            self.block_collision_counts(blk, q_buckets, chunk);
        }
    }

    /// Collision counts of block `blk`'s resident keys against
    /// `q_buckets`, written to `counts[..block_len(blk)]` — the shared
    /// per-block kernel of [`KeyHashes::collision_counts_into`] and the
    /// pruned hard-LSH walk (counts accumulate in t order; ≤ L, exact
    /// in f32). Each table row is one `simd::count_eq` u16
    /// compare-and-count over the SoA block (AVX2 `cmpeq_epi16` /
    /// NEON `vceqq_u16`; bit-identical scalar fallback).
    pub fn block_collision_counts(&self, blk: usize, q_buckets: &[u16], counts: &mut [f32]) {
        assert_eq!(q_buckets.len(), self.l);
        let blen = self.block_len(blk);
        let block = self.block_data(blk);
        let (counts, _) = counts.split_at_mut(blen);
        counts.fill(0.0);
        for (qb, row) in q_buckets.iter().zip(block.chunks_exact(BLOCK_TOKENS)) {
            crate::simd::count_eq(counts, row, *qb);
        }
    }

    /// Upper bound on any key-in-block collision count against
    /// `q_buckets`: the number of tables whose block summary contains
    /// the query's bucket. Admissible because a key can only collide in
    /// table t if its id — a summary member — equals `q_buckets[t]`; a
    /// saturated summary conservatively counts as containing it.
    pub fn block_collision_bound(&self, blk: usize, q_buckets: &[u16]) -> f32 {
        let mut c = 0u32;
        for (t, &qb) in q_buckets.iter().enumerate() {
            c += match self.block_table_ids(blk, t) {
                Some(ids) => ids.contains(&qb) as u32,
                None => 1,
            };
        }
        c as f32
    }
}

impl SimHash {
    /// Draw the hyperplanes. Deterministic in (seed, params, dim).
    pub fn new(params: LshParams, dim: usize, seed: u64) -> SimHash {
        // lint:allow(hot-path-panic): construction-time config check,
        // never on the decode path (selectors validate via Result).
        params.validate().expect("invalid LSH params");
        let mut planes = Vec::with_capacity(params.l);
        for table in 0..params.l {
            let mut rng = Pcg64::new(seed, table as u64 + 1);
            planes.push(Matrix::gaussian(params.p, dim, &mut rng));
        }
        SimHash { params, dim, planes }
    }

    /// Hyperplane matrix of table ℓ.
    pub fn plane(&self, table: usize) -> &Matrix {
        assert!(table < self.planes.len(), "table {table} out of range");
        // SAFETY: asserted in range just above.
        unsafe { self.planes.get_unchecked(table) }
    }

    /// Signed projections of `x` in table ℓ (the pre-sign values — the
    /// soft hasher consumes these directly). The Alg.-1 inner products
    /// run through `linalg::dot`, which dispatches to the SIMD layer
    /// (AVX2/NEON behind runtime detection, bit-identical scalar
    /// fallback).
    pub fn project(&self, table: usize, x: &[f32]) -> Vec<f32> {
        self.plane(table).matvec(x)
    }

    /// Hard bucket id of `x` in table ℓ: packed sign bits, bit i set iff
    /// `w_i · x >= 0`.
    pub fn bucket_of(&self, table: usize, x: &[f32]) -> u16 {
        let proj = self.project(table, x);
        pack_signs(&proj)
    }

    /// All-table bucket ids of a single vector.
    pub fn hash_one(&self, x: &[f32]) -> Vec<u16> {
        (0..self.params.l).map(|t| self.bucket_of(t, x)).collect()
    }

    /// Algorithm 1: hash every key, cache bucket ids + value norms.
    pub fn hash_keys(&self, keys: &Matrix, values: &Matrix) -> KeyHashes {
        assert_eq!(keys.cols, self.dim);
        assert_eq!(keys.rows, values.rows);
        let n = keys.rows;
        let l = self.params.l;
        let mut bucket_ids = vec![0u16; n * l];
        for (j, row) in bucket_ids.chunks_exact_mut(l).enumerate() {
            let key = keys.row(j);
            for (t, slot) in row.iter_mut().enumerate() {
                *slot = self.bucket_of(t, key);
            }
        }
        KeyHashes::from_row_major(l, self.params.buckets(), &bucket_ids, values.row_norms())
    }

    /// Algorithm 1 across a worker pool: each key's `L`-table signature
    /// row is independent, so threads hash disjoint key ranges. Output
    /// is bit-identical to [`SimHash::hash_keys`].
    pub fn hash_keys_with(&self, keys: &Matrix, values: &Matrix, pool: &WorkerPool) -> KeyHashes {
        assert_eq!(keys.cols, self.dim);
        assert_eq!(keys.rows, values.rows);
        let n = keys.rows;
        let l = self.params.l;
        let mut bucket_ids = vec![0u16; n * l];
        pool.fill_rows(&mut bucket_ids, l, |j, row| {
            let key = keys.row(j);
            for (t, slot) in row.iter_mut().enumerate() {
                *slot = self.bucket_of(t, key);
            }
        });
        KeyHashes::from_row_major(l, self.params.buckets(), &bucket_ids, values.row_norms())
    }

    /// Theoretical SimHash collision probability for one plane:
    /// `1 - θ/π` where θ is the angle between x and y. The P-plane
    /// bucket-collision probability is this to the P-th power — the
    /// angular kernel `w_j` of the paper's Section 5 (eq. 4).
    pub fn collision_probability(&self, cosine: f32) -> f64 {
        let c = cosine.clamp(-1.0, 1.0) as f64;
        let per_plane = 1.0 - c.acos() / std::f64::consts::PI;
        per_plane.powi(self.params.p as i32)
    }
}

/// Pack sign bits: bit i of the result is set iff proj[i] >= 0.
#[inline]
pub fn pack_signs(proj: &[f32]) -> u16 {
    debug_assert!(proj.len() <= 16);
    let mut b = 0u16;
    for (i, &v) in proj.iter().enumerate() {
        if v >= 0.0 {
            b |= 1 << i;
        }
    }
    b
}

/// The ±1 corner vector of bucket `r` for P planes: coordinate i is +1 if
/// bit i of r is set else -1. These are the `c_r` of Algorithm 2.
pub fn corner(r: u16, p: usize) -> Vec<f32> {
    (0..p).map(|i| if r >> i & 1 == 1 { 1.0 } else { -1.0 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::{check_default, gen};

    fn small() -> SimHash {
        SimHash::new(LshParams { p: 6, l: 20, tau: 0.5 }, 32, 42)
    }

    #[test]
    fn deterministic_in_seed() {
        let a = SimHash::new(LshParams::paper_default(), 16, 7);
        let b = SimHash::new(LshParams::paper_default(), 16, 7);
        let mut rng = Pcg64::seeded(1);
        let x = rng.normal_vec(16);
        assert_eq!(a.hash_one(&x), b.hash_one(&x));
    }

    #[test]
    fn tables_are_independent() {
        let h = small();
        let mut rng = Pcg64::seeded(2);
        let x = rng.normal_vec(32);
        let ids = h.hash_one(&x);
        let distinct: std::collections::HashSet<u16> = ids.iter().copied().collect();
        assert!(distinct.len() > 5, "tables should disagree: {distinct:?}");
    }

    #[test]
    fn same_vector_always_collides() {
        let h = small();
        let mut rng = Pcg64::seeded(3);
        let x = rng.normal_vec(32);
        let kx = Matrix::from_vec(1, 32, x.clone());
        let hashes = h.hash_keys(&kx, &kx);
        for t in 0..h.params.l {
            assert_eq!(hashes.bucket(0, t), h.bucket_of(t, &x));
        }
    }

    #[test]
    fn negated_vector_lands_in_complement_bucket() {
        let h = small();
        let mut rng = Pcg64::seeded(4);
        let x = rng.normal_vec(32);
        let neg: Vec<f32> = x.iter().map(|v| -v).collect();
        // Probability of a zero projection is nil; complement bits.
        let mask = (1u16 << h.params.p) - 1;
        for t in 0..h.params.l {
            assert_eq!(h.bucket_of(t, &neg), !h.bucket_of(t, &x) & mask);
        }
    }

    #[test]
    fn pack_signs_known() {
        assert_eq!(pack_signs(&[1.0, -1.0, 0.5]), 0b101);
        assert_eq!(pack_signs(&[-1.0, -2.0]), 0);
        // sign(0) counts as +.
        assert_eq!(pack_signs(&[0.0]), 1);
    }

    #[test]
    fn corner_roundtrip() {
        for r in 0..16u16 {
            let c = corner(r, 4);
            let packed = pack_signs(&c);
            assert_eq!(packed, r);
        }
    }

    #[test]
    fn collision_prob_monotone_in_cosine() {
        let h = small();
        let p1 = h.collision_probability(0.9);
        let p2 = h.collision_probability(0.5);
        let p3 = h.collision_probability(-0.5);
        assert!(p1 > p2 && p2 > p3);
        assert!((h.collision_probability(1.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empirical_collision_rate_matches_theory() {
        // Monte-Carlo check of the SimHash identity Pr[collide] =
        // (1 - θ/π)^P over random query/key pairs at fixed cosine.
        let params = LshParams { p: 4, l: 400, tau: 0.5 };
        let h = SimHash::new(params, 48, 99);
        let mut rng = Pcg64::seeded(5);
        for &cos in &[0.8f32, 0.3, 0.0] {
            let q = gen::unit_vec(&mut rng, 48);
            let k = gen::key_with_cosine(&mut rng, &q, cos);
            let qb = h.hash_one(&q);
            let kb = h.hash_one(&k);
            let collisions = qb.iter().zip(&kb).filter(|(a, b)| a == b).count();
            let emp = collisions as f64 / params.l as f64;
            let theo = h.collision_probability(cos);
            assert!(
                (emp - theo).abs() < 0.08,
                "cos={cos} empirical={emp:.3} theoretical={theo:.3}"
            );
        }
    }

    #[test]
    fn prop_bucket_ids_in_range(){
        check_default("bucket-range", |rng, _| {
            let p = 1 + rng.below_usize(12);
            let l = 1 + rng.below_usize(8);
            let d = gen::size(rng, 2, 64);
            let h = SimHash::new(LshParams { p, l, tau: 0.5 }, d, rng.next_u64());
            let x = rng.normal_vec(d);
            for b in h.hash_one(&x) {
                prop_assert!((b as usize) < (1 << p), "b={b} p={p}");
            }
            Ok(())
        });
    }

    #[test]
    fn pooled_hash_keys_matches_serial() {
        let h = SimHash::new(LshParams { p: 8, l: 12, tau: 0.5 }, 24, 7);
        let mut rng = Pcg64::seeded(8);
        let keys = Matrix::gaussian(300, 24, &mut rng);
        let vals = Matrix::gaussian(300, 24, &mut rng);
        let pool = WorkerPool::new(4);
        let serial = h.hash_keys(&keys, &vals);
        let pooled = h.hash_keys_with(&keys, &vals, &pool);
        assert_eq!(serial.to_row_major(), pooled.to_row_major());
        assert_eq!(serial.value_norms, pooled.value_norms);
    }

    #[test]
    fn key_hashes_push_appends() {
        let h = small();
        let mut rng = Pcg64::seeded(6);
        let keys = Matrix::gaussian(4, 32, &mut rng);
        let vals = Matrix::gaussian(4, 32, &mut rng);
        let mut kh = h.hash_keys(&keys, &vals);
        let newk = rng.normal_vec(32);
        let buckets = h.hash_one(&newk);
        kh.push(&buckets, 2.5);
        assert_eq!(kh.n, 5);
        assert_eq!(kh.key_row(4), buckets);
        assert_eq!(kh.value_norms[4], 2.5);
    }

    #[test]
    fn soa_layout_round_trips_row_major() {
        // from_row_major / bucket / key_row / to_row_major all agree,
        // across multiple blocks and a partial tail.
        let l = 5;
        let r = 32;
        let n = 2 * BLOCK_TOKENS + 17;
        let mut rng = Pcg64::seeded(9);
        let ids: Vec<u16> = (0..n * l).map(|_| rng.below(r as u64) as u16).collect();
        let norms: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let kh = KeyHashes::from_row_major(l, r, &ids, norms.clone());
        assert_eq!(kh.n, n);
        assert_eq!(kh.n_blocks(), 3);
        assert_eq!(kh.block_len(2), 17);
        assert_eq!(kh.to_row_major(), ids);
        for j in [0, 1, BLOCK_TOKENS - 1, BLOCK_TOKENS, n - 1] {
            assert_eq!(kh.key_row(j), ids[j * l..(j + 1) * l].to_vec(), "key {j}");
        }
        assert_eq!(kh.value_norms, norms);
    }

    #[test]
    fn push_matches_bulk_construction() {
        // Incremental pushes and from_row_major must agree on layout,
        // summaries, and norms — including a tail block mutated in
        // place across a block boundary.
        let l = 4;
        let r = 64;
        let n = BLOCK_TOKENS + 9;
        let mut rng = Pcg64::seeded(10);
        let ids: Vec<u16> = (0..n * l).map(|_| rng.below(r as u64) as u16).collect();
        let norms: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.1).collect();
        let bulk = KeyHashes::from_row_major(l, r, &ids, norms.clone());
        let mut inc = KeyHashes::empty(l, r);
        for j in 0..n {
            inc.push(&ids[j * l..(j + 1) * l], norms[j]);
        }
        assert_eq!(inc.n, bulk.n);
        assert_eq!(inc.to_row_major(), bulk.to_row_major());
        for blk in 0..bulk.n_blocks() {
            assert_eq!(inc.block_max_norm(blk), bulk.block_max_norm(blk), "block {blk}");
            for t in 0..l {
                assert_eq!(inc.block_table_ids(blk, t), bulk.block_table_ids(blk, t));
            }
        }
    }

    #[test]
    fn extend_from_equals_bulk_hash_of_concatenation() {
        let h = small();
        let mut rng = Pcg64::seeded(14);
        let k1 = Matrix::gaussian(70, 32, &mut rng);
        let v1 = Matrix::gaussian(70, 32, &mut rng);
        let k2 = Matrix::gaussian(30, 32, &mut rng);
        let v2 = Matrix::gaussian(30, 32, &mut rng);
        let mut inc = h.hash_keys(&k1, &v1);
        inc.extend_from(&h.hash_keys(&k2, &v2));
        let kall = Matrix::from_vec(100, 32, [k1.data, k2.data].concat());
        let vall = Matrix::from_vec(100, 32, [v1.data, v2.data].concat());
        let bulk = h.hash_keys(&kall, &vall);
        assert_eq!(inc.n, 100);
        assert_eq!(inc.to_row_major(), bulk.to_row_major());
        assert_eq!(inc.value_norms, bulk.value_norms);
        for blk in 0..bulk.n_blocks() {
            assert_eq!(inc.block_max_norm(blk), bulk.block_max_norm(blk), "block {blk}");
            for t in 0..bulk.l {
                assert_eq!(inc.block_table_ids(blk, t), bulk.block_table_ids(blk, t));
            }
        }
    }

    #[test]
    fn block_summaries_cover_every_resident_id() {
        // The pruning invariant: every live key's id is a member of its
        // block's per-table summary, and the block max norm dominates
        // every resident norm.
        let h = small();
        let mut rng = Pcg64::seeded(11);
        let n = BLOCK_TOKENS + 21;
        let keys = Matrix::gaussian(n, 32, &mut rng);
        let vals = Matrix::gaussian(n, 32, &mut rng);
        let kh = h.hash_keys(&keys, &vals);
        for j in 0..n {
            let blk = j / BLOCK_TOKENS;
            for t in 0..kh.l {
                match kh.block_table_ids(blk, t) {
                    Some(ids) => assert!(
                        ids.contains(&kh.bucket(j, t)),
                        "key {j} table {t} missing from summary"
                    ),
                    // Saturated: covered by the table-wide fallback.
                    None => assert!(kh.summaries_saturated()),
                }
            }
            assert!(kh.block_max_norm(blk) >= kh.value_norms[j], "key {j} norm");
        }
    }

    #[test]
    fn summary_saturates_at_cap_and_stays_saturated() {
        // One table, bucket space wide enough to overflow the budget:
        // the first SUMMARY_CAP distinct ids are tracked, the next one
        // saturates the cell, and later ids (new or repeated) are
        // no-ops.
        let r = 4 * SUMMARY_CAP;
        let mut kh = KeyHashes::empty(1, r);
        for id in 0..SUMMARY_CAP as u16 {
            kh.push(&[id], 1.0);
        }
        assert!(!kh.summaries_saturated());
        let ids = kh.block_table_ids(0, 0).expect("under budget");
        assert_eq!(ids.len(), SUMMARY_CAP);
        kh.push(&[SUMMARY_CAP as u16], 1.0); // budget overflow
        assert!(kh.summaries_saturated());
        assert_eq!(kh.block_table_ids(0, 0), None);
        kh.push(&[0], 2.0); // repeat id after saturation: still None
        assert_eq!(kh.block_table_ids(0, 0), None);
        assert_eq!(kh.block_max_norm(0), 2.0, "norms keep folding in");
        // The hard bound conservatively counts the saturated table.
        assert_eq!(kh.block_collision_bound(0, &[(r - 1) as u16]), 1.0);
    }

    #[test]
    fn narrow_bucket_spaces_never_saturate() {
        // r <= SUMMARY_CAP cannot overflow the budget: there are at
        // most r distinct ids.
        let r = SUMMARY_CAP;
        let mut kh = KeyHashes::empty(1, r);
        for j in 0..2 * BLOCK_TOKENS {
            kh.push(&[(j % r) as u16], 1.0);
        }
        assert!(!kh.summaries_saturated());
        assert_eq!(kh.block_table_ids(0, 0).expect("full space").len(), r);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn push_rejects_out_of_range_ids() {
        // The satellite fix: out-of-range ids used to be silently
        // masked by the release-mode gather; now they fail loudly at
        // the single validated entry point.
        let mut kh = KeyHashes::empty(3, 16);
        kh.push(&[1, 2, 16], 1.0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn from_row_major_rejects_out_of_range_ids() {
        let _ = KeyHashes::from_row_major(2, 8, &[0, 7, 8, 1], vec![1.0, 1.0]);
    }

    #[test]
    fn collision_counts_match_scalar_reference() {
        // The blocked SoA kernel against the obvious per-key scalar
        // loop, across block boundaries and a partial tail.
        let h = small();
        let mut rng = Pcg64::seeded(12);
        let n = 2 * BLOCK_TOKENS + 5;
        let keys = Matrix::gaussian(n, 32, &mut rng);
        let kh = h.hash_keys(&keys, &keys);
        let q = rng.normal_vec(32);
        let qb = h.hash_one(&q);
        let mut got = vec![9.0f32; 3]; // stale, wrong size
        kh.collision_counts_into(&qb, &mut got);
        assert_eq!(got.len(), n);
        for j in 0..n {
            let want = (0..kh.l).filter(|&t| kh.bucket(j, t) == qb[t]).count() as f32;
            assert_eq!(got[j], want, "key {j}");
        }
    }

    #[test]
    fn collision_bound_dominates_block_counts() {
        let h = small();
        let mut rng = Pcg64::seeded(13);
        let n = BLOCK_TOKENS + 30;
        let keys = Matrix::gaussian(n, 32, &mut rng);
        let kh = h.hash_keys(&keys, &keys);
        let q = rng.normal_vec(32);
        let qb = h.hash_one(&q);
        let mut counts = Vec::new();
        kh.collision_counts_into(&qb, &mut counts);
        for blk in 0..kh.n_blocks() {
            let ub = kh.block_collision_bound(blk, &qb);
            let base = blk * BLOCK_TOKENS;
            for j in base..base + kh.block_len(blk) {
                assert!(counts[j] <= ub, "key {j}: count {} > bound {ub}", counts[j]);
            }
        }
    }

    #[test]
    fn shared_blocks_match_owned_construction() {
        // The prefix-cache identity: freeze a store's full blocks,
        // attach them to a fresh store, push the tail — every public
        // read (layout, norms, summaries, bounds) is bit-identical to
        // the fully owned build, and the donor is unaffected.
        let h = small();
        let mut rng = Pcg64::seeded(21);
        let n = 2 * BLOCK_TOKENS + 10;
        let keys = Matrix::gaussian(n, 32, &mut rng);
        let vals = Matrix::gaussian(n, 32, &mut rng);
        let full = h.hash_keys(&keys, &vals);
        let mut donor = h.hash_keys(&keys, &vals);
        let frozen = donor.freeze_full_blocks();
        assert_eq!(frozen.len(), 2, "two full blocks freeze; the tail stays owned");
        assert_eq!(frozen[0].0, 0);
        assert_eq!(frozen[1].0, 1);
        let handles: Vec<Arc<HashBlock>> = frozen.iter().map(|(_, b)| b.clone()).collect();
        let mut kh = KeyHashes::from_shared(full.l, full.r(), &handles);
        assert_eq!(kh.n, 2 * BLOCK_TOKENS);
        for j in 2 * BLOCK_TOKENS..n {
            kh.push(&full.key_row(j), full.value_norms[j]);
        }
        assert_eq!(kh.n, full.n);
        assert_eq!(kh.to_row_major(), full.to_row_major());
        assert_eq!(kh.value_norms, full.value_norms);
        assert_eq!(kh.summaries_saturated(), full.summaries_saturated());
        for blk in 0..full.n_blocks() {
            assert_eq!(kh.block_max_norm(blk), full.block_max_norm(blk), "block {blk}");
            assert_eq!(kh.block_data(blk), full.block_data(blk), "block {blk}");
            for t in 0..full.l {
                assert_eq!(kh.block_table_ids(blk, t), full.block_table_ids(blk, t));
            }
        }
        // The donor reads identically through its now-shared blocks.
        assert_eq!(donor.to_row_major(), full.to_row_major());
        // A second freeze returns nothing new (tail still partial).
        assert!(donor.freeze_full_blocks().is_empty());
    }

    #[test]
    fn push_after_attached_shared_blocks_extends_privately() {
        let l = 2;
        let r = 16;
        let mut donor = KeyHashes::empty(l, r);
        for j in 0..BLOCK_TOKENS {
            donor.push(&[(j % r) as u16, ((j + 1) % r) as u16], 1.0 + j as f32);
        }
        let frozen = donor.freeze_full_blocks();
        assert_eq!(frozen.len(), 1);
        let mut kh = KeyHashes::empty(l, r);
        kh.attach_shared(frozen[0].1.clone());
        kh.push(&[3, 4], 9.0);
        assert_eq!(kh.n, BLOCK_TOKENS + 1);
        assert_eq!(kh.n_blocks(), 2);
        assert_eq!(kh.block_len(1), 1);
        assert_eq!(kh.bucket(BLOCK_TOKENS, 0), 3);
        assert_eq!(kh.block_max_norm(1), 9.0);
        // The shared block is untouched by the private push.
        assert_eq!(kh.block_max_norm(0), donor.block_max_norm(0));
        assert_eq!(donor.n, BLOCK_TOKENS, "donor unchanged");
    }

    #[test]
    fn prop_dispatch_modes_bit_identical() {
        // Alg.-1 hashing (simd::dot projections) and hard-collision
        // counting (simd::count_eq) under auto-dispatch vs the forced
        // scalar reference: bucket ids, value norms, and counts must be
        // bit-identical, not merely close.
        check_default("simhash-dispatch-modes", |rng, _| {
            let h = small();
            let n = gen::size(rng, 1, 3 * BLOCK_TOKENS);
            let keys = Matrix::gaussian(n, 32, rng);
            let vals = Matrix::gaussian(n, 32, rng);
            let q = rng.normal_vec(32);
            let build = || {
                let kh = h.hash_keys(&keys, &vals);
                let qb = h.hash_one(&q);
                let mut counts = Vec::new();
                kh.collision_counts_into(&qb, &mut counts);
                (kh.to_row_major(), kh.value_norms.clone(), qb, counts)
            };
            let auto = crate::simd::dispatch::with_auto(&build);
            let scalar = crate::simd::dispatch::with_forced_scalar(&build);
            prop_assert!(auto.0 == scalar.0, "bucket ids diverge (n={n})");
            prop_assert!(
                auto.1.iter().zip(&scalar.1).all(|(a, b)| a.to_bits() == b.to_bits()),
                "value norms diverge (n={n})"
            );
            prop_assert!(auto.2 == scalar.2, "query buckets diverge (n={n})");
            prop_assert!(
                auto.3.len() == scalar.3.len()
                    && auto.3.iter().zip(&scalar.3).all(|(a, b)| a.to_bits() == b.to_bits()),
                "collision counts diverge (n={n})"
            );
            Ok(())
        });
    }
}
