//! Hyperparameters and memory accounting for the LSH schemes.
//!
//! The paper reports "Mem = additional bits/token beyond the KV cache"
//! (Table 1, Table 2, Fig. 2); [`MemoryBudget`] reproduces exactly that
//! accounting: each key stores `P` sign bits per table (`L·P` bits) plus
//! one value-norm scalar.

/// Parameters of an SRP (sign-random-projection) LSH scheme.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LshParams {
    /// Hyperplanes per table. Buckets per table R = 2^P.
    pub p: usize,
    /// Number of independent hash tables.
    pub l: usize,
    /// Soft-hash temperature (ignored by hard LSH).
    pub tau: f32,
}

impl LshParams {
    /// The paper's main-experiment setting (RULER): P=10, L=60, τ=0.5.
    pub fn paper_default() -> LshParams {
        LshParams { p: 10, l: 60, tau: 0.5 }
    }

    /// The paper's LongBench setting: P=8, L=60.
    pub fn longbench_default() -> LshParams {
        LshParams { p: 8, l: 60, tau: 0.5 }
    }

    /// Buckets per table.
    pub fn buckets(&self) -> usize {
        1usize << self.p
    }

    /// Memory accounting for these parameters.
    pub fn memory(&self) -> MemoryBudget {
        MemoryBudget { bits_per_token: self.p * self.l }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.p == 0 || self.p > 16 {
            return Err(format!("P={} out of supported range 1..=16", self.p));
        }
        if self.l == 0 {
            return Err("L must be positive".into());
        }
        if !(self.tau > 0.0) {
            return Err(format!("tau={} must be > 0", self.tau));
        }
        Ok(())
    }
}

/// Additional memory per token beyond the KV cache, in bits — the unit
/// the paper's tables use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemoryBudget {
    pub bits_per_token: usize,
}

impl MemoryBudget {
    /// Bytes to store hash signatures for `n` tokens (packed).
    pub fn bytes_for(&self, n: usize) -> usize {
        (self.bits_per_token * n).div_ceil(8)
    }

    /// GB for `n` tokens across `heads` KV heads and `layers` layers —
    /// Table 2's "Memory (GB)" column shape.
    pub fn gb_for(&self, n: usize, heads: usize, layers: usize) -> f64 {
        self.bytes_for(n) as f64 * heads as f64 * layers as f64 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_is_600_bits() {
        // P=10, L=60 → 600 bits/token, matching Table 1's "Mem 600".
        let p = LshParams::paper_default();
        assert_eq!(p.memory().bits_per_token, 600);
        assert_eq!(p.buckets(), 1024);
    }

    #[test]
    fn hard_lsh_table2_settings() {
        // Table 2's hard-LSH rows: (2, 300) = 600 bits, (2, 500) = 1000.
        assert_eq!(LshParams { p: 2, l: 300, tau: 0.5 }.memory().bits_per_token, 600);
        assert_eq!(LshParams { p: 2, l: 500, tau: 0.5 }.memory().bits_per_token, 1000);
    }

    #[test]
    fn validation_rejects_bad_params() {
        assert!(LshParams { p: 0, l: 60, tau: 0.5 }.validate().is_err());
        assert!(LshParams { p: 17, l: 60, tau: 0.5 }.validate().is_err());
        assert!(LshParams { p: 10, l: 0, tau: 0.5 }.validate().is_err());
        assert!(LshParams { p: 10, l: 60, tau: 0.0 }.validate().is_err());
        assert!(LshParams::paper_default().validate().is_ok());
    }

    #[test]
    fn byte_packing_rounds_up() {
        let m = MemoryBudget { bits_per_token: 600 };
        assert_eq!(m.bytes_for(1), 75);
        let m = MemoryBudget { bits_per_token: 3 };
        assert_eq!(m.bytes_for(3), 2); // 9 bits -> 2 bytes
    }
}
