//! The SOCKET soft collision kernel (Algorithms 2–4).
//!
//! * [`SoftHasher::bucket_probs`] — Algorithm 2: the query induces a
//!   softmax distribution over the `R = 2^P` buckets of each table,
//!   `p_τ(r | q) ∝ exp(u·c_r / τ)` with `u = tanh(Wq)/√d`.
//! * [`SoftScorer::scores`] — Algorithm 4: every key's score is the
//!   probability mass its cached buckets receive, summed over tables and
//!   weighted by the value norm.
//! * [`SoftScorer::select_top_k`] — Algorithm 3: deterministic top-k over
//!   `ŵ_j · ‖v_j‖₂`.

use crate::linalg::TopK;
use crate::lsh::bnb;
use crate::lsh::params::LshParams;
use crate::lsh::simhash::{KeyHashes, SimHash, BLOCK_TOKENS};
use crate::simd;
use crate::util::pool::{self, WorkerPool};

/// Query-side soft hashing (Algorithm 2).
#[derive(Clone, Debug)]
pub struct SoftHasher {
    hash: SimHash,
}

/// The per-table bucket distributions of one query: row-major `L x R`.
#[derive(Clone, Debug)]
pub struct BucketProbs {
    pub l: usize,
    pub r: usize,
    pub probs: Vec<f32>,
}

impl BucketProbs {
    #[inline]
    pub fn table(&self, t: usize) -> &[f32] {
        let base = t * self.r;
        assert!(base + self.r <= self.probs.len(), "table {t} out of range");
        // SAFETY: asserted in range just above.
        unsafe { self.probs.get_unchecked(base..base + self.r) }
    }
}

impl SoftHasher {
    pub fn new(hash: SimHash) -> SoftHasher {
        SoftHasher { hash }
    }

    pub fn simhash(&self) -> &SimHash {
        &self.hash
    }

    pub fn params(&self) -> LshParams {
        self.hash.params
    }

    /// Algorithm 2 for one table ℓ: `u = tanh(W^(ℓ) q) / √d`,
    /// `logit_r = u·c_r / τ`, softmax over r, written into `w` (len R).
    ///
    /// The corner inner products are computed without materializing the
    /// `P x R` corner matrix: a Gray-code-free butterfly — logit over
    /// corners is separable, `u·c_r = Σ_i ±u_i` — built by iterative
    /// doubling in O(R·P) adds but cache-friendly (R ≤ 2^16).
    fn table_probs(&self, t: usize, q: &[f32], w: &mut [f32]) {
        let p = self.hash.params.p;
        let tau = self.hash.params.tau;
        let inv_sqrt_d = 1.0 / (self.hash.dim as f32).sqrt();
        let proj = self.hash.project(t, q);
        // Multiplicative butterfly: exp(Σ ±u_i/τ) = Π exp(±u_i/τ),
        // so only 2P exps are needed per table instead of R = 2^P —
        // after step i, w[0..2^(i+1)] hold all sign combinations of
        // u_0..u_i. Safe without max-subtraction: |u_i| ≤ 1/√d, so
        // every factor is bounded by e^(P/(√d·τ)).
        // (§Perf: 3.2x faster scoring at (P=10, L=60); see
        // EXPERIMENTS.md.)
        if let Some(head) = w.first_mut() {
            *head = 1.0;
        }
        let mut width = 1usize;
        for &x in proj.iter().take(p) {
            let u = x.tanh() * inv_sqrt_d / tau;
            // Normalize the pair so factors are ≤ 1: equivalent up
            // to the final normalization, and overflow-free even at
            // tiny τ (the dominated corner underflows to 0, which
            // is its correct limit).
            let e_plus = (u - u.abs()).exp();
            let e_minus = (-u - u.abs()).exp();
            // Doubling step over w[..2*width]: hi = lo * e_plus first,
            // then lo *= e_minus — the same per-slot op order as the
            // classic indexed loop, so the products are bit-identical.
            let (lo, hi) = w.split_at_mut(width);
            for (wl, wh) in lo.iter_mut().zip(hi) {
                // bit i set => +u ; cleared => -u.
                *wh = *wl * e_plus;
                *wl *= e_minus;
            }
            width *= 2;
        }
        let sum: f32 = w.iter().sum();
        let inv = 1.0 / sum;
        simd::scale(w, inv);
    }

    /// Algorithm 2: the per-table bucket distributions of one query.
    pub fn bucket_probs(&self, q: &[f32]) -> BucketProbs {
        let l = self.hash.params.l;
        let r = 1usize << self.hash.params.p;
        let mut probs = vec![0.0f32; l * r];
        for (t, w) in probs.chunks_mut(r).enumerate() {
            self.table_probs(t, q, w);
        }
        BucketProbs { l, r, probs }
    }

    /// Algorithm 2 across a worker pool: tables are independent, so
    /// threads fill disjoint blocks of per-table distributions. Output
    /// is bit-identical to [`SoftHasher::bucket_probs`].
    pub fn bucket_probs_with(&self, q: &[f32], pool: &WorkerPool) -> BucketProbs {
        let mut probs = Vec::new();
        let (l, r) = self.bucket_probs_into(q, &mut probs, pool);
        BucketProbs { l, r, probs }
    }

    /// Algorithm 2 into a reusable buffer: fills `out` with the
    /// flattened `L x R` per-table distributions (capacity persists
    /// across calls — the decode hot path's zero-alloc entry point).
    /// Returns `(L, R)`. Bit-identical to [`SoftHasher::bucket_probs`].
    pub fn bucket_probs_into(
        &self,
        q: &[f32],
        out: &mut Vec<f32>,
        pool: &WorkerPool,
    ) -> (usize, usize) {
        let l = self.hash.params.l;
        let r = 1usize << self.hash.params.p;
        out.clear();
        out.resize(l * r, 0.0);
        pool.fill_rows(out, r, |t, w| self.table_probs(t, q, w));
        (l, r)
    }
}

/// Pruning telemetry of one block-pruned selection pass: how many
/// (lane, block) visits there were, how many the admissible bound
/// skipped without scoring, and how long the pruning threshold took to
/// warm up. Telemetry only — with a parallel walk the counts depend on
/// shared-threshold timing and are not deterministic; the *selection*
/// always is.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PruneStats {
    /// (lane, block) pairs visited.
    pub blocks: usize,
    /// (lane, block) pairs pruned by the bound.
    pub pruned: usize,
    /// (lane, block) pairs *scored* before each (job, lane)'s first
    /// prune — all of its scored visits when it never pruned. (A
    /// parallel walk runs ~2 jobs per worker, so this counts job-local
    /// ramps, not per-thread ones.) The threshold warm-up cost that
    /// bound-ordered traversal exists to shrink.
    pub warmup: usize,
}

impl PruneStats {
    /// Fold another pass's counts into this one (metrics aggregation).
    pub fn absorb(&mut self, other: PruneStats) {
        self.blocks += other.blocks;
        self.pruned += other.pruned;
        self.warmup += other.warmup;
    }
}

/// One lane of [`SoftScorer::select_pruned_group_into`]: a query's
/// flattened `L x R` prob table plus the buffers receiving its
/// selection.
pub struct GroupLane<'a> {
    /// This lane's per-table bucket distributions (as filled by
    /// [`SoftHasher::bucket_probs_into`]).
    pub probs: &'a [f32],
    /// Receives the selected token ids, descending score.
    pub indices: &'a mut Vec<usize>,
    /// Receives the selected scores, parallel to `indices`.
    pub scores: &'a mut Vec<f32>,
}

/// Key scoring + selection over a hashed KV cache (Algorithms 3–4).
#[derive(Clone, Debug)]
pub struct SoftScorer {
    pub hasher: SoftHasher,
}

impl SoftScorer {
    pub fn new(params: LshParams, dim: usize, seed: u64) -> SoftScorer {
        SoftScorer { hasher: SoftHasher::new(SimHash::new(params, dim, seed)) }
    }

    pub fn params(&self) -> LshParams {
        self.hasher.params()
    }

    /// Algorithm 1 delegate: hash keys at prefill.
    pub fn hash_keys(
        &self,
        keys: &crate::linalg::Matrix,
        values: &crate::linalg::Matrix,
    ) -> KeyHashes {
        self.hasher.simhash().hash_keys(keys, values)
    }

    /// One key's soft collision mass against a query's prob table.
    /// `table` is the flattened `L x R` distributions; the key's bucket
    /// ids are gathered out of its SoA block. Bounds checks are
    /// hoisted: every stored id was validated `< R` at [`KeyHashes`]
    /// construction/push (the satellite fix for the old silent
    /// release-mode id masking), and the block slice is always a full
    /// `L x BLOCK_TOKENS` allocation, so the unchecked accesses are
    /// provably in range (§Perf, see EXPERIMENTS.md).
    #[inline]
    fn score_key(table: &[f32], r: usize, hashes: &KeyHashes, j: usize) -> f32 {
        let block = hashes.block_data(j / BLOCK_TOKENS);
        let slot = j % BLOCK_TOKENS;
        let mut acc = 0.0f32;
        for t in 0..hashes.l {
            // SAFETY: block.len() == L * BLOCK_TOKENS and slot <
            // BLOCK_TOKENS; the loaded id is < r by construction and
            // the caller asserts table.len() == L * r.
            acc += unsafe {
                let b = *block.get_unchecked(t * BLOCK_TOKENS + slot) as usize;
                *table.get_unchecked(t * r + b)
            };
        }
        acc
    }

    /// Raw soft collision scores `ŵ_j = Σ_ℓ p_τ(b_j^(ℓ) | q)` (eq. 3),
    /// *without* the value-norm weighting.
    pub fn raw_scores(&self, probs: &BucketProbs, hashes: &KeyHashes) -> Vec<f32> {
        assert_eq!(probs.l, hashes.l);
        assert_eq!(probs.r, hashes.r());
        let r = probs.r;
        let table = probs.probs.as_slice();
        let mut out = vec![0.0f32; hashes.n];
        // Stream the SoA blocks table-outer / key-inner: one (table,
        // block) id row is contiguous, and the per-key accumulation
        // order (t = 0..L) matches the per-key gather exactly, so the
        // sums are bit-identical to [`SoftScorer::score_key`] — in
        // every dispatch tier, since the probability gather
        // (`simd::gather_accumulate`) is elementwise per key.
        for (blk, acc) in out.chunks_mut(BLOCK_TOKENS).enumerate() {
            let block = hashes.block_data(blk);
            for (row, ptab) in block.chunks_exact(BLOCK_TOKENS).zip(table.chunks_exact(r)) {
                // SAFETY: ids validated < r at KeyHashes construction;
                // ptab is exactly r wide and acc.len() <= row.len().
                unsafe { simd::gather_accumulate(acc, row, ptab) };
            }
        }
        out
    }

    /// [`SoftScorer::raw_scores`] across a worker pool: keys are
    /// independent and the `L x R` prob table is read-only, so threads
    /// score disjoint key ranges. Output is bit-identical to the serial
    /// path (no cross-chunk reductions).
    pub fn raw_scores_with(
        &self,
        probs: &BucketProbs,
        hashes: &KeyHashes,
        pool: &WorkerPool,
    ) -> Vec<f32> {
        assert_eq!(probs.l, hashes.l);
        assert_eq!(probs.r, hashes.r());
        let r = probs.r;
        assert_eq!(probs.probs.len(), hashes.l * r);
        let table = probs.probs.as_slice();
        let mut out = vec![0.0f32; hashes.n];
        pool.fill(&mut out, |j| Self::score_key(table, r, hashes, j));
        out
    }

    /// Apply Algorithm 4's value-norm weighting + optional validity mask
    /// (`false` entries score -inf) to raw scores, in place.
    fn weight_scores(s: &mut [f32], hashes: &KeyHashes, mask: Option<&[bool]>) {
        match mask {
            Some(m) => {
                for ((x, &norm), &valid) in s.iter_mut().zip(&hashes.value_norms).zip(m) {
                    *x = if valid { *x * norm } else { f32::NEG_INFINITY };
                }
            }
            // Unmasked hot path: one elementwise SIMD multiply (`x *
            // norm` is the identical rounding in every tier).
            None => simd::mul_assign(s, &hashes.value_norms),
        }
    }

    /// Algorithm 4: value-aware scores `ŵ_j · ‖v_j‖₂`, with an optional
    /// validity mask (`false` entries score -inf).
    pub fn scores(&self, probs: &BucketProbs, hashes: &KeyHashes, mask: Option<&[bool]>) -> Vec<f32> {
        let mut s = self.raw_scores(probs, hashes);
        Self::weight_scores(&mut s, hashes, mask);
        s
    }

    /// Algorithm 4 into a reusable buffer: value-norm-weighted soft
    /// collision scores over a flattened `L x R` prob table (as filled
    /// by [`SoftHasher::bucket_probs_into`]), pooled. Bit-identical to
    /// [`SoftScorer::scores_with`] without the per-call allocation —
    /// the selector hot path's entry point.
    pub fn scores_into(
        &self,
        probs: &[f32],
        r: usize,
        hashes: &KeyHashes,
        pool: &WorkerPool,
        out: &mut Vec<f32>,
    ) {
        let l = hashes.l;
        assert_eq!(probs.len(), l * r, "prob table shape mismatch");
        assert_eq!(r, hashes.r(), "prob-table bucket space != hash bucket space");
        out.clear();
        out.resize(hashes.n, 0.0);
        pool.fill(out, |j| Self::score_key(probs, r, hashes, j));
        Self::weight_scores(out, hashes, None);
    }

    /// [`SoftScorer::scores`] with the scoring loop on a worker pool.
    pub fn scores_with(
        &self,
        probs: &BucketProbs,
        hashes: &KeyHashes,
        mask: Option<&[bool]>,
        pool: &WorkerPool,
    ) -> Vec<f32> {
        let mut s = self.raw_scores_with(probs, hashes, pool);
        Self::weight_scores(&mut s, hashes, mask);
        s
    }

    /// Admissible score upper bound for every key in block `blk`:
    /// `(Σ_t max_{b ∈ S_t} p_t(b|q)) · max_{j ∈ blk} ‖v_j‖`, where
    /// `S_t` is the block's distinct-bucket summary for table t. Each
    /// per-table max dominates the corresponding term of every resident
    /// key's score (the key's bucket is a summary member), the sums add
    /// term-for-term in the same t order, and f32 addition and
    /// multiplication are monotone on non-negative operands — so the
    /// bound dominates every resident key's *computed f32* score, not
    /// just its real-arithmetic value. That is the exactness guarantee
    /// of the branch-and-bound selection.
    ///
    /// A saturated summary (distinct-id count overflowed
    /// `lsh::SUMMARY_CAP`) contributes the *table-wide* max probability
    /// instead — it dominates every bucket, so the bound stays
    /// admissible. `table_max` supplies those `L` maxima precomputed
    /// (the pre-pass path); with `None` they are computed inline.
    pub fn block_bound_with(
        hashes: &KeyHashes,
        blk: usize,
        probs: &[f32],
        r: usize,
        table_max: Option<&[f32]>,
    ) -> f32 {
        // The unchecked reads below are only in range for the bucket
        // space the ids were validated against — enforce it here too,
        // not just in the kernels, since this is a public entry point.
        assert_eq!(r, hashes.r(), "prob-table bucket space != hash bucket space");
        assert!(probs.len() >= hashes.l * r, "prob table shape mismatch");
        let mut sum = 0.0f32;
        for (t, ptab) in probs.chunks_exact(r).enumerate().take(hashes.l) {
            let m = match hashes.block_table_ids(blk, t) {
                Some(ids) => {
                    let mut m = 0.0f32;
                    for &b in ids {
                        // SAFETY: summary ids validated < r at construction.
                        let p = unsafe { *ptab.get_unchecked(b as usize) };
                        if p > m {
                            m = p;
                        }
                    }
                    m
                }
                None => match table_max {
                    // +inf on a malformed (too-short) table_max keeps
                    // the bound admissible instead of panicking.
                    Some(tm) => tm.get(t).copied().unwrap_or(f32::INFINITY),
                    None => simd::max(ptab),
                },
            };
            sum += m;
        }
        sum * hashes.block_max_norm(blk)
    }

    /// [`SoftScorer::block_bound_with`] computing any saturated-summary
    /// fallback maxima inline.
    pub fn block_bound(hashes: &KeyHashes, blk: usize, probs: &[f32], r: usize) -> f32 {
        Self::block_bound_with(hashes, blk, probs, r, None)
    }

    /// Per-table max probability of a flattened `L x R` prob table —
    /// the saturated-summary fallback terms, computed once per lane by
    /// the pre-pass. `out` must be `l` long.
    pub fn table_maxes(probs: &[f32], l: usize, r: usize, out: &mut [f32]) {
        assert_eq!(probs.len(), l * r, "prob table shape mismatch");
        assert_eq!(out.len(), l, "one max per table");
        // simd::max of a probability row equals the sequential fold
        // exactly (max over a fixed set is reduction-order-free for
        // the non-negative, non-NaN values a softmax produces), so
        // this stays interchangeable with the inline fallback in
        // `block_bound_with`.
        for (slot, ptab) in out.iter_mut().zip(probs.chunks_exact(r)) {
            *slot = simd::max(ptab);
        }
    }

    /// Algorithms 4→3 with block pruning: exact top-k over
    /// `ŵ_j · ‖v_j‖₂` that skips whole hash blocks whose admissible
    /// upper bound cannot beat the branch-and-bound threshold. Writes
    /// the selected indices (descending score) and their scores; both
    /// are **bit-identical** to the exhaustive
    /// [`SoftScorer::scores_into`] + `top_k_into` pipeline (see
    /// [`SoftScorer::block_bound_with`] and `lsh::bnb` for why pruning
    /// is lossless). Runs the pool-parallel bound-ordered walk on the
    /// shared global pool; returns pruning telemetry.
    pub fn select_pruned_into(
        &self,
        probs: &[f32],
        r: usize,
        hashes: &KeyHashes,
        k: usize,
        indices: &mut Vec<usize>,
        scores: &mut Vec<f32>,
    ) -> PruneStats {
        self.select_pruned_with(probs, r, hashes, k, indices, scores, pool::global(), true)
    }

    /// [`SoftScorer::select_pruned_into`] with an explicit pool and
    /// traversal order — the bench/test surface for comparing the
    /// serial, parallel, and bound-ordered engines (selections are
    /// bit-identical across all of them; only wall-clock and the prune
    /// telemetry differ).
    #[allow(clippy::too_many_arguments)]
    pub fn select_pruned_with(
        &self,
        probs: &[f32],
        r: usize,
        hashes: &KeyHashes,
        k: usize,
        indices: &mut Vec<usize>,
        scores: &mut Vec<f32>,
        pool: &WorkerPool,
        ordered: bool,
    ) -> PruneStats {
        let mut lanes = [GroupLane { probs, indices, scores }];
        self.select_pruned_group_with(r, hashes, k, &mut lanes, pool, ordered)
    }

    /// The GQA lane: [`SoftScorer::select_pruned_into`] for a *group*
    /// of queries sharing one KV stream. Each worker's pass loads a
    /// block's id rows once and scores them for every lane while
    /// cache-hot, amortizing the table walk across the query heads of a
    /// GQA group; per-lane results are bit-identical to per-query
    /// [`SoftScorer::select_pruned_into`] calls. Runs bound-ordered on
    /// the shared global pool.
    pub fn select_pruned_group_into(
        &self,
        r: usize,
        hashes: &KeyHashes,
        k: usize,
        lanes: &mut [GroupLane<'_>],
    ) -> PruneStats {
        self.select_pruned_group_with(r, hashes, k, lanes, pool::global(), true)
    }

    /// The full engine behind every soft selection: a pool-parallel
    /// branch-and-bound walk over the hash blocks (`lsh::bnb`).
    ///
    /// The pre-pass computes every (lane, block) admissible bound into
    /// per-thread plan scratch and — when `ordered` — sorts a block
    /// visit permutation by descending summed bound, so the first
    /// visits everywhere are the blocks most likely to hold top-k keys
    /// and the pruning thresholds warm immediately. The walk itself
    /// shards `blocks x lanes` across `pool`'s workers, each pruning
    /// against its tie-aware local heap plus the shared monotone
    /// threshold, and the per-worker candidate sets merge exactly —
    /// selections (indices AND scores) are bit-identical to exhaustive
    /// scoring for every pool size and either ordering (property-tested
    /// across pool sizes 1/2/8). Inside a pool worker the walk runs
    /// inline (cores are already busy); on a free caller thread it fans
    /// out — one engine, parallel everywhere it can be.
    pub fn select_pruned_group_with(
        &self,
        r: usize,
        hashes: &KeyHashes,
        k: usize,
        lanes: &mut [GroupLane<'_>],
        pool: &WorkerPool,
        ordered: bool,
    ) -> PruneStats {
        let l = hashes.l;
        assert_eq!(r, hashes.r(), "prob-table bucket space != hash bucket space");
        for lane in lanes.iter_mut() {
            assert_eq!(lane.probs.len(), l * r, "prob table shape mismatch");
            lane.indices.clear();
            lane.scores.clear();
        }
        let n = hashes.n;
        if n == 0 || k == 0 || lanes.is_empty() {
            return PruneStats::default();
        }
        let n_lanes = lanes.len();
        let n_blocks = hashes.n_blocks();
        // Split the lanes into the shared prob tables (read by the
        // score/bound closures) and the output buffers (written by the
        // walk) so both can be borrowed at once.
        let mut probs_by_lane: Vec<&[f32]> = Vec::with_capacity(n_lanes);
        for lane in lanes.iter() {
            probs_by_lane.push(lane.probs);
        }
        let mut outs: Vec<(&mut Vec<usize>, &mut Vec<f32>)> = Vec::with_capacity(n_lanes);
        for lane in lanes.iter_mut() {
            outs.push((&mut *lane.indices, &mut *lane.scores));
        }
        pool::with_bnb_plan(|plan| {
            let crate::util::pool::BnbPlanScratch { bounds, agg, order, table_max, walk } = plan;
            // Saturated-summary fallbacks: one table-max row per lane.
            table_max.clear();
            let saturated = hashes.summaries_saturated();
            if saturated {
                table_max.resize(n_lanes * l, 0.0);
                for (probs, row) in probs_by_lane.iter().zip(table_max.chunks_exact_mut(l)) {
                    Self::table_maxes(probs, l, r, row);
                }
            }
            // Bound pre-pass: every (lane, block) admissible bound,
            // fanned element-wise over the pool — cell granularity (not
            // lane rows) so the dominant single-lane select_into path
            // parallelizes across blocks too. Pure per-cell computation,
            // so the parallel fill is bit-identical to a serial loop.
            bounds.clear();
            bounds.resize(n_lanes * n_blocks, 0.0);
            {
                let table_max = &*table_max;
                let probs_by_lane = &probs_by_lane;
                pool.fill(bounds, |i| {
                    let (g, blk) = (i / n_blocks, i % n_blocks);
                    // lint:allow(hot-path-index): g < n_lanes since bounds
                    // has n_lanes * n_blocks cells; an invariant breach
                    // must panic, not silently zero a bound.
                    let probs = probs_by_lane[g];
                    // Empty when !saturated (table_max stays cleared),
                    // the per-lane row otherwise.
                    let tm = table_max.get(g * l..(g + 1) * l);
                    Self::block_bound_with(hashes, blk, probs, r, tm)
                });
            }
            // Visit order: descending summed bound warms every lane's
            // threshold in the first few blocks; identity otherwise.
            if ordered && n_blocks > 1 {
                agg.clear();
                agg.resize(n_blocks, 0.0);
                for lane_bounds in bounds.chunks_exact(n_blocks) {
                    simd::axpy(agg, lane_bounds, 1.0);
                }
                bnb::bound_order(agg, order);
            } else {
                bnb::identity_order(n_blocks, order);
            }
            // Score the block table-outer / key-inner; per key the
            // accumulation order (t = 0..L) and the final norm product
            // match the exhaustive gather exactly, so scores are
            // bit-identical — in every dispatch tier, since both the
            // probability gather and the norm weighting are elementwise.
            let norms = &hashes.value_norms;
            let score_block = |g: usize, blk: usize, acc: &mut [f32; BLOCK_TOKENS]| {
                let blen = hashes.block_len(blk);
                let base = blk * BLOCK_TOKENS;
                let block = hashes.block_data(blk);
                // lint:allow(hot-path-index): the walk only hands out
                // lanes < n_lanes; an invariant breach must panic, not
                // leave stale scratch scores behind an early return.
                let probs = probs_by_lane[g];
                let (acc, _) = acc.split_at_mut(blen);
                acc.fill(0.0);
                for (row, ptab) in block.chunks_exact(BLOCK_TOKENS).zip(probs.chunks_exact(r))
                {
                    // SAFETY: ids validated < r at construction; ptab is
                    // exactly r wide and acc.len() <= row.len().
                    unsafe { simd::gather_accumulate(acc, row, ptab) };
                }
                debug_assert!(norms.len() >= base + blen);
                // lint:allow(hot-path-index): one norm per key, asserted
                // above; a length mismatch must panic, not silently
                // skip the value-norm weighting.
                simd::mul_assign(acc, &norms[base..base + blen]);
            };
            bnb::run_walk(hashes, k, bounds, order, pool, score_block, &mut outs, walk)
        })
    }

    /// Full decode-side pipeline (Algorithms 2→4→3): soft-hash the query,
    /// score every key, return the top-k key indices (descending score).
    pub fn select_top_k(&self, q: &[f32], hashes: &KeyHashes, k: usize) -> Vec<usize> {
        let probs = self.hasher.bucket_probs(q);
        let scores = self.scores(&probs, hashes, None);
        Self::top_k_of(&scores, k, hashes.n)
    }

    /// [`SoftScorer::select_top_k`] with soft-hashing and scoring
    /// parallelized on `pool` — the serving hot path. Selection is
    /// identical to the serial pipeline (chunked fills reduce nothing
    /// across threads, and top-k stays serial).
    pub fn select_top_k_with(
        &self,
        q: &[f32],
        hashes: &KeyHashes,
        k: usize,
        pool: &WorkerPool,
    ) -> Vec<usize> {
        let probs = self.hasher.bucket_probs_with(q, pool);
        let scores = self.scores_with(&probs, hashes, None, pool);
        Self::top_k_of(&scores, k, hashes.n)
    }

    fn top_k_of(scores: &[f32], k: usize, n: usize) -> Vec<usize> {
        let mut tk = TopK::new(k.min(n).max(1));
        for (j, &s) in scores.iter().enumerate() {
            tk.push(s, j);
        }
        tk.into_indices()
    }

    /// Normalized soft weights `ã_j = w̃_j / Z̃` (Section 5.1) — the proxy
    /// attention distribution used by the sampling estimator and the
    /// Theorem-3 validation bench.
    pub fn normalized_weights(&self, q: &[f32], hashes: &KeyHashes) -> Vec<f32> {
        let probs = self.hasher.bucket_probs(q);
        let mut w = self.raw_scores(&probs, hashes);
        let l = hashes.l as f32;
        let mut z = 0.0f32;
        for x in w.iter_mut() {
            *x /= l;
            z += *x;
        }
        if z > 0.0 {
            for x in w.iter_mut() {
                *x /= z;
            }
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::prop_assert;
    use crate::testing::{check, check_default, gen, PropConfig};
    use crate::util::rng::Pcg64;

    fn scorer(p: usize, l: usize, tau: f32, dim: usize) -> SoftScorer {
        SoftScorer::new(LshParams { p, l, tau }, dim, 1234)
    }

    #[test]
    fn bucket_probs_are_distributions() {
        let s = scorer(8, 10, 0.5, 64);
        let mut rng = Pcg64::seeded(1);
        let q = rng.normal_vec(64);
        let probs = s.hasher.bucket_probs(&q);
        assert_eq!(probs.l, 10);
        assert_eq!(probs.r, 256);
        for t in 0..probs.l {
            let sum: f32 = probs.table(t).iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "table {t} sums to {sum}");
            assert!(probs.table(t).iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn dominant_soft_bucket_is_hard_bucket() {
        // Section B.1: argmax_r p_τ(r|q) must equal the hard SRP bucket
        // because tanh is strictly increasing.
        let s = scorer(10, 30, 0.4, 48);
        let mut rng = Pcg64::seeded(2);
        for _ in 0..20 {
            let q = rng.normal_vec(48);
            let probs = s.hasher.bucket_probs(&q);
            for t in 0..probs.l {
                let hard = s.hasher.simhash().bucket_of(t, &q) as usize;
                let soft_argmax = crate::linalg::argmax(probs.table(t));
                assert_eq!(soft_argmax, hard, "table {t}");
            }
        }
    }

    #[test]
    fn tau_to_zero_recovers_hard_lsh() {
        // As τ→0 the soft distribution peaks on the hard bucket (ε_τ→0).
        let dim = 32;
        let mut rng = Pcg64::seeded(3);
        let q = rng.normal_vec(dim);
        let sharp = scorer(6, 5, 0.01, dim);
        let probs = sharp.hasher.bucket_probs(&q);
        for t in 0..probs.l {
            let hard = sharp.hasher.simhash().bucket_of(t, &q) as usize;
            assert!(probs.table(t)[hard] > 0.95, "mass={}", probs.table(t)[hard]);
        }
    }

    #[test]
    fn tau_to_infinity_uniformizes() {
        let dim = 32;
        let mut rng = Pcg64::seeded(4);
        let q = rng.normal_vec(dim);
        let smooth = scorer(6, 5, 1e4, dim);
        let probs = smooth.hasher.bucket_probs(&q);
        let r = probs.r as f32;
        for t in 0..probs.l {
            for &p in probs.table(t) {
                assert!((p - 1.0 / r).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn raw_scores_bounded_by_l() {
        // Each per-table contribution is a probability, so 0 ≤ ŵ_j ≤ L.
        let s = scorer(8, 24, 0.5, 32);
        let mut rng = Pcg64::seeded(5);
        let keys = Matrix::gaussian(100, 32, &mut rng);
        let vals = Matrix::gaussian(100, 32, &mut rng);
        let hashes = s.hash_keys(&keys, &vals);
        let q = rng.normal_vec(32);
        let probs = s.hasher.bucket_probs(&q);
        for &w in &s.raw_scores(&probs, &hashes) {
            assert!((0.0..=24.0).contains(&w), "w={w}");
        }
    }

    #[test]
    fn closer_key_scores_higher() {
        // Fig. 1's claim: score(q,k1) > score(q,k2) when cos(q,k1) >
        // cos(q,k2). Holds in expectation; test with a wide margin.
        let dim = 64;
        let s = scorer(10, 60, 0.5, dim);
        let mut rng = Pcg64::seeded(6);
        let q = gen::unit_vec(&mut rng, dim);
        let k_near = gen::key_with_cosine(&mut rng, &q, 0.9);
        let k_far = gen::key_with_cosine(&mut rng, &q, 0.1);
        let mut keys = Matrix::zeros(2, dim);
        keys.row_mut(0).copy_from_slice(&k_near);
        keys.row_mut(1).copy_from_slice(&k_far);
        let vals = Matrix::from_vec(2, dim, vec![1.0; 2 * dim]); // equal norms
        let hashes = s.hash_keys(&keys, &vals);
        let probs = s.hasher.bucket_probs(&q);
        let w = s.raw_scores(&probs, &hashes);
        assert!(w[0] > w[1], "near={} far={}", w[0], w[1]);
    }

    #[test]
    fn value_norm_weighting_applies() {
        let dim = 16;
        let s = scorer(6, 12, 0.5, dim);
        let mut rng = Pcg64::seeded(7);
        let key = rng.normal_vec(dim);
        let mut keys = Matrix::zeros(2, dim);
        keys.row_mut(0).copy_from_slice(&key);
        keys.row_mut(1).copy_from_slice(&key); // identical keys
        let mut vals = Matrix::zeros(2, dim);
        vals.set(0, 0, 1.0);
        vals.set(1, 0, 5.0); // 5x larger value norm
        let hashes = s.hash_keys(&keys, &vals);
        let q = rng.normal_vec(dim);
        let probs = s.hasher.bucket_probs(&q);
        let sc = s.scores(&probs, &hashes, None);
        assert!((sc[1] / sc[0] - 5.0).abs() < 1e-3, "ratio={}", sc[1] / sc[0]);
    }

    #[test]
    fn mask_excludes_keys() {
        let dim = 16;
        let s = scorer(6, 12, 0.5, dim);
        let mut rng = Pcg64::seeded(8);
        let keys = Matrix::gaussian(5, dim, &mut rng);
        let vals = Matrix::gaussian(5, dim, &mut rng);
        let hashes = s.hash_keys(&keys, &vals);
        let q = rng.normal_vec(dim);
        let probs = s.hasher.bucket_probs(&q);
        let mask = [true, false, true, false, true];
        let sc = s.scores(&probs, &hashes, Some(&mask));
        assert_eq!(sc[1], f32::NEG_INFINITY);
        assert_eq!(sc[3], f32::NEG_INFINITY);
        assert!(sc[0].is_finite());
    }

    #[test]
    fn select_top_k_returns_k_distinct() {
        let dim = 32;
        let s = scorer(8, 20, 0.5, dim);
        let mut rng = Pcg64::seeded(9);
        let keys = Matrix::gaussian(200, dim, &mut rng);
        let vals = Matrix::gaussian(200, dim, &mut rng);
        let hashes = s.hash_keys(&keys, &vals);
        let q = rng.normal_vec(dim);
        let sel = s.select_top_k(&q, &hashes, 16);
        assert_eq!(sel.len(), 16);
        let distinct: std::collections::HashSet<usize> = sel.iter().copied().collect();
        assert_eq!(distinct.len(), 16);
    }

    #[test]
    fn normalized_weights_form_distribution() {
        let dim = 24;
        let s = scorer(6, 15, 0.5, dim);
        let mut rng = Pcg64::seeded(10);
        let keys = Matrix::gaussian(64, dim, &mut rng);
        let vals = Matrix::gaussian(64, dim, &mut rng);
        let hashes = s.hash_keys(&keys, &vals);
        let q = rng.normal_vec(dim);
        let a = s.normalized_weights(&q, &hashes);
        let sum: f32 = a.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4);
        assert!(a.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn prop_butterfly_matches_naive_corners() {
        // The iterative-doubling logit construction must equal the naive
        // u·c_r computation for every corner.
        check_default("butterfly-vs-naive", |rng, _| {
            let p = 1 + rng.below_usize(8);
            let dim = gen::size(rng, 2, 48);
            let tau = rng.range_f32(0.1, 2.0);
            let s = SoftScorer::new(LshParams { p, l: 1, tau }, dim, rng.next_u64());
            let q = rng.normal_vec(dim);
            let probs = s.hasher.bucket_probs(&q);
            // Naive reference.
            let proj = s.hasher.simhash().project(0, &q);
            let inv = 1.0 / (dim as f32).sqrt();
            let u: Vec<f32> = proj.iter().map(|x| x.tanh() * inv).collect();
            let r = 1usize << p;
            let mut logits = vec![0.0f32; r];
            for cid in 0..r {
                let c = crate::lsh::simhash::corner(cid as u16, p);
                logits[cid] = u.iter().zip(&c).map(|(a, b)| a * b).sum::<f32>() / tau;
            }
            crate::linalg::softmax_inplace(&mut logits);
            for cid in 0..r {
                prop_assert!(
                    (probs.table(0)[cid] - logits[cid]).abs() < 1e-4,
                    "p={p} corner={cid}: {} vs {}",
                    probs.table(0)[cid],
                    logits[cid]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_collision_mass_monotone_in_cosine() {
        // Theorem 1's substance: the expected soft collision mass grows
        // with cos(q, k). With a wide cosine gap and many tables the
        // ordering holds for every seeded draw, not just on average.
        check("soft-monotone-cosine", PropConfig { cases: 24, seed: 0x50F7 }, |rng, _| {
            let dim = gen::size(rng, 24, 64);
            let params =
                LshParams { p: 6 + rng.below_usize(4), l: 150, tau: rng.range_f32(0.3, 0.8) };
            let s = SoftScorer::new(params, dim, rng.next_u64());
            let q = gen::unit_vec(rng, dim);
            let c_hi = rng.range_f32(0.85, 0.95);
            let c_lo = rng.range_f32(-0.1, 0.15);
            let mut keys = Matrix::zeros(2, dim);
            keys.row_mut(0).copy_from_slice(&gen::key_with_cosine(rng, &q, c_hi));
            keys.row_mut(1).copy_from_slice(&gen::key_with_cosine(rng, &q, c_lo));
            let vals = Matrix::from_vec(2, dim, vec![1.0; 2 * dim]);
            let hashes = s.hash_keys(&keys, &vals);
            let probs = s.hasher.bucket_probs(&q);
            let w = s.raw_scores(&probs, &hashes);
            prop_assert!(
                w[0] > w[1],
                "cos {c_hi:.2} scored {} <= cos {c_lo:.2} scored {}",
                w[0],
                w[1]
            );
            Ok(())
        });
    }

    #[test]
    fn prop_negated_query_mirrors_buckets() {
        // Exact symmetry of the soft kernel: tanh is odd, so
        // p_τ(r | -q) = p_τ(~r | q) (bitwise-complement bucket), table
        // by table — the soft analog of SimHash's antipodal symmetry.
        check_default("soft-sign-symmetry", |rng, _| {
            let p = 1 + rng.below_usize(8);
            let dim = gen::size(rng, 2, 48);
            let tau = rng.range_f32(0.1, 2.0);
            let s = SoftScorer::new(LshParams { p, l: 3, tau }, dim, rng.next_u64());
            let q = rng.normal_vec(dim);
            let neg: Vec<f32> = q.iter().map(|x| -x).collect();
            let pq = s.hasher.bucket_probs(&q);
            let pn = s.hasher.bucket_probs(&neg);
            let r = 1usize << p;
            for t in 0..3 {
                for b in 0..r {
                    let mirrored = pn.table(t)[b ^ (r - 1)];
                    prop_assert!(
                        (pq.table(t)[b] - mirrored).abs() < 1e-4,
                        "t={t} b={b}: {} vs {}",
                        pq.table(t)[b],
                        mirrored
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_collision_kernel_symmetric_in_expectation() {
        // κ(q, k) = κ(k, q): swapping the query and key roles yields the
        // same collision mass up to finite-L fluctuation. Coarse buckets
        // (P=3) and many tables keep the fluctuation far below the slack.
        check("soft-exchange-symmetry", PropConfig { cases: 12, seed: 0xE4C4 }, |rng, _| {
            let dim = gen::size(rng, 16, 48);
            let params = LshParams { p: 3, l: 600, tau: 0.7 };
            let s = SoftScorer::new(params, dim, rng.next_u64());
            let q = gen::unit_vec(rng, dim);
            let k = gen::key_with_cosine(rng, &q, rng.range_f32(0.4, 0.8));
            let mass = |query: &[f32], key: &[f32]| -> f32 {
                let keys = Matrix::from_vec(1, dim, key.to_vec());
                let vals = Matrix::from_vec(1, dim, vec![1.0; dim]);
                let hashes = s.hash_keys(&keys, &vals);
                let probs = s.hasher.bucket_probs(query);
                s.raw_scores(&probs, &hashes)[0]
            };
            let qk = mass(&q, &k);
            let kq = mass(&k, &q);
            let mid = 0.5 * (qk + kq);
            prop_assert!((qk - kq).abs() < 0.5 * mid + 5.0, "w(q,k)={qk} w(k,q)={kq}");
            Ok(())
        });
    }

    #[test]
    fn prop_tau_boundary_behaviour() {
        // τ→0 recovers hard LSH (all mass on the hard bucket); τ→∞ is
        // the uniform distribution — the two ends of Section 4's knob.
        check("tau-boundary", PropConfig { cases: 32, seed: 0x7A0 }, |rng, _| {
            let dim = gen::size(rng, 8, 48);
            let p = 2 + rng.below_usize(6);
            let seed = rng.next_u64();
            let q = rng.normal_vec(dim);
            let r = 1usize << p;
            // Sharp limit. Tables where the smallest |u_i| leaves less
            // than e^-28 of margin are skipped: a near-zero projection
            // genuinely splits mass between two adjacent buckets.
            let tau_sharp = 1e-3f32;
            let sharp = SoftScorer::new(LshParams { p, l: 6, tau: tau_sharp }, dim, seed);
            let probs = sharp.hasher.bucket_probs(&q);
            let inv_sqrt_d = 1.0 / (dim as f32).sqrt();
            for t in 0..6 {
                let proj = sharp.hasher.simhash().project(t, &q);
                let min_u = proj
                    .iter()
                    .map(|x| x.tanh().abs() * inv_sqrt_d)
                    .fold(f32::INFINITY, f32::min);
                if min_u / tau_sharp < 14.0 {
                    continue;
                }
                let hard = sharp.hasher.simhash().bucket_of(t, &q) as usize;
                prop_assert!(probs.table(t)[hard] > 0.99, "t={t} mass={}", probs.table(t)[hard]);
            }
            // Smooth limit: every bucket within 1% of uniform.
            let smooth = SoftScorer::new(LshParams { p, l: 6, tau: 1e5 }, dim, seed);
            let probs = smooth.hasher.bucket_probs(&q);
            for t in 0..6 {
                for &pr in probs.table(t) {
                    prop_assert!((pr * r as f32 - 1.0).abs() < 1e-2, "t={t} p={pr}");
                }
            }
            Ok(())
        });
    }

    /// Exhaustive reference: Alg. 2 + Alg. 4 scores over every key,
    /// then a plain TopK — the pre-pruning pipeline, kept as the
    /// bit-identity oracle.
    fn exhaustive_reference(
        s: &SoftScorer,
        q: &[f32],
        hashes: &KeyHashes,
        k: usize,
    ) -> (Vec<usize>, Vec<f32>) {
        let probs = s.hasher.bucket_probs(q);
        let scores = s.scores(&probs, hashes, None);
        let mut tk = TopK::new(k.min(hashes.n).max(1));
        for (j, &x) in scores.iter().enumerate() {
            tk.push(x, j);
        }
        let sorted = tk.into_sorted();
        (sorted.iter().map(|p| p.0).collect(), sorted.iter().map(|p| p.1).collect())
    }

    fn pruned(
        s: &SoftScorer,
        q: &[f32],
        hashes: &KeyHashes,
        k: usize,
    ) -> (Vec<usize>, Vec<f32>, PruneStats) {
        let probs = s.hasher.bucket_probs(q);
        let mut idx = vec![77usize; 2]; // stale
        let mut sc = vec![-3.0f32; 5];
        let stats = s.select_pruned_into(&probs.probs, probs.r, hashes, k, &mut idx, &mut sc);
        (idx, sc, stats)
    }

    fn pruned_with(
        s: &SoftScorer,
        q: &[f32],
        hashes: &KeyHashes,
        k: usize,
        pool: &WorkerPool,
        ordered: bool,
    ) -> (Vec<usize>, Vec<f32>, PruneStats) {
        let probs = s.hasher.bucket_probs(q);
        let mut idx = vec![77usize; 2]; // stale
        let mut sc = vec![-3.0f32; 5];
        let stats = s.select_pruned_with(
            &probs.probs,
            probs.r,
            hashes,
            k,
            &mut idx,
            &mut sc,
            pool,
            ordered,
        );
        (idx, sc, stats)
    }

    /// The tentpole's engine matrix: serial, 2-way, and 8-way pools,
    /// each in storage order and bound order.
    fn engine_pools() -> Vec<WorkerPool> {
        vec![WorkerPool::new(1), WorkerPool::new(2), WorkerPool::new(8)]
    }

    #[test]
    fn prop_pruned_select_bit_identical_to_exhaustive() {
        // The tentpole acceptance bar: branch-and-bound selection over
        // the SoA blocks returns exactly the exhaustive top-k — indices
        // AND scores — across τ extremes, non-block-aligned tails, and
        // adversarial bucket/norm distributions.
        let pools = engine_pools();
        check("pruned-vs-exhaustive", PropConfig { cases: 40, seed: 0xB10C }, |rng, _| {
            let dim = gen::size(rng, 4, 48);
            let p = 1 + rng.below_usize(8);
            let tau = [0.01f32, 0.3, 1.0, 1e4][rng.below_usize(4)];
            let l = 1 + rng.below_usize(12);
            let s = SoftScorer::new(LshParams { p, l, tau }, dim, rng.next_u64());
            // Span multiple blocks with a ragged tail more often than not.
            let n = 1 + rng.below_usize(3 * crate::lsh::simhash::BLOCK_TOKENS + 7);
            let adversarial = rng.below_usize(3) == 0;
            let mut keys = Matrix::gaussian(n, dim, rng);
            let mut vals = Matrix::gaussian(n, dim, rng);
            if adversarial {
                // Every key identical (one bucket per table) and one
                // huge-norm outlier: the degenerate distributions that
                // stress tie handling and the norm-weighted bound.
                let proto = rng.normal_vec(dim);
                for j in 0..n {
                    keys.row_mut(j).copy_from_slice(&proto);
                }
                let outlier = rng.below_usize(n);
                for x in vals.row_mut(outlier) {
                    *x *= 1000.0;
                }
            }
            let mut hashes = s.hash_keys(&keys, &vals);
            let q = rng.normal_vec(dim);
            let k = 1 + rng.below_usize(n + 3);
            let (want_i, want_s) = exhaustive_reference(&s, &q, &hashes, k);
            let (got_i, got_s, _) = pruned(&s, &q, &hashes, k);
            prop_assert!(
                got_i == want_i,
                "indices diverge (n={n} k={k} tau={tau}): {got_i:?} vs {want_i:?}"
            );
            prop_assert!(got_s == want_s, "scores diverge (n={n} k={k} tau={tau})");
            // The engine matrix: every pool size x traversal order must
            // select exactly the exhaustive top-k, indices and scores.
            for pool in &pools {
                for ordered in [false, true] {
                    let (got_i, got_s, _) = pruned_with(&s, &q, &hashes, k, pool, ordered);
                    prop_assert!(
                        got_i == want_i && got_s == want_s,
                        "threads={} ordered={ordered} diverges (n={n} k={k} tau={tau})",
                        pool.threads()
                    );
                }
            }
            // Mid-decode appends mutate the tail block's summary in
            // place; equivalence must survive them.
            for _ in 0..1 + rng.below_usize(20) {
                let nk = rng.normal_vec(dim);
                let buckets = s.hasher.simhash().hash_one(&nk);
                hashes.push(&buckets, rng.next_f32() * 2.0);
            }
            let (want_i, want_s) = exhaustive_reference(&s, &q, &hashes, k);
            let (got_i, got_s, _) = pruned(&s, &q, &hashes, k);
            prop_assert!(got_i == want_i, "post-append indices diverge (n={} k={k})", hashes.n);
            prop_assert!(got_s == want_s, "post-append scores diverge");
            for pool in &pools {
                for ordered in [false, true] {
                    let (got_i, got_s, _) = pruned_with(&s, &q, &hashes, k, pool, ordered);
                    prop_assert!(
                        got_i == want_i && got_s == want_s,
                        "post-append threads={} ordered={ordered} diverges",
                        pool.threads()
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_tie_breaks_identical_across_traversals() {
        // The adversarial tie-break property: all-equal-score and
        // duplicate-key distributions must produce identical (indices
        // AND scores) selections under storage-order, bound-order, and
        // parallel traversal — the regime where a naive `ub <= t` prune
        // of an out-of-order block would drop an index-tie winner.
        let pools = engine_pools();
        check("pruned-tie-breaks", PropConfig { cases: 24, seed: 0x71EB }, |rng, _| {
            let dim = gen::size(rng, 4, 32);
            let p = 1 + rng.below_usize(6);
            let l = 1 + rng.below_usize(8);
            let s = SoftScorer::new(LshParams { p, l, tau: 0.5 }, dim, rng.next_u64());
            let n = 1 + rng.below_usize(3 * crate::lsh::simhash::BLOCK_TOKENS + 7);
            let mut keys = Matrix::zeros(n, dim);
            let mut vals = Matrix::zeros(n, dim);
            if rng.below_usize(2) == 0 {
                // Every key identical, every norm identical: every
                // score ties, so the selection is decided purely by the
                // index tie-break.
                let proto = rng.normal_vec(dim);
                for j in 0..n {
                    keys.row_mut(j).copy_from_slice(&proto);
                    vals.set(j, 0, 2.0);
                }
            } else {
                // A few distinct (key, norm) prototypes cycled across
                // blocks: heavy cross-block duplicate ties.
                let protos: Vec<Vec<f32>> =
                    (0..1 + rng.below_usize(3)).map(|_| rng.normal_vec(dim)).collect();
                for j in 0..n {
                    let which = j % protos.len();
                    keys.row_mut(j).copy_from_slice(&protos[which]);
                    vals.set(j, 0, 1.0 + which as f32);
                }
            }
            let hashes = s.hash_keys(&keys, &vals);
            let q = rng.normal_vec(dim);
            let k = 1 + rng.below_usize(n + 2);
            let (want_i, want_s) = exhaustive_reference(&s, &q, &hashes, k);
            for pool in &pools {
                for ordered in [false, true] {
                    let (got_i, got_s, _) = pruned_with(&s, &q, &hashes, k, pool, ordered);
                    prop_assert!(
                        got_i == want_i,
                        "threads={} ordered={ordered}: {got_i:?} vs {want_i:?} (n={n} k={k})",
                        pool.threads()
                    );
                    prop_assert!(
                        got_s == want_s,
                        "threads={} ordered={ordered} scores diverge (n={n} k={k})",
                        pool.threads()
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_capped_summaries_never_prune_a_true_topk_block() {
        // The summary-cap satellite: hashes crafted so every full
        // (block, table) cell overflows SUMMARY_CAP and saturates. The
        // fallback bound (table-wide max) must stay admissible and the
        // pruned walk bit-identical to exhaustive — i.e. capping never
        // prunes a block holding a true top-k key.
        let pools = engine_pools();
        check("capped-summaries-lossless", PropConfig { cases: 24, seed: 0xCA9 }, |rng, _| {
            let dim = gen::size(rng, 4, 24);
            let p = 7 + rng.below_usize(3); // r = 128..512 >> SUMMARY_CAP
            let l = 1 + rng.below_usize(4);
            let s = SoftScorer::new(LshParams { p, l, tau: 0.5 }, dim, rng.next_u64());
            let r = 1usize << p;
            let bt = crate::lsh::simhash::BLOCK_TOKENS;
            let n = bt + 1 + rng.below_usize(2 * bt);
            // Craft the id table directly: key j occupies bucket
            // (j * stride + t) % r, marching through > SUMMARY_CAP
            // distinct ids per (block, table).
            let stride = 1 + 2 * rng.below_usize(16); // odd: full period
            let ids: Vec<u16> =
                (0..n * l).map(|c| (((c / l) * stride + c % l) % r) as u16).collect();
            let norms: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.1).collect();
            let hashes = KeyHashes::from_row_major(l, r, &ids, norms);
            prop_assert!(
                hashes.summaries_saturated(),
                "cap must overflow (n={n} r={r} stride={stride})"
            );
            let q = rng.normal_vec(dim);
            let probs = s.hasher.bucket_probs(&q);
            // Admissibility incl. the table-max fallback, both the
            // precomputed and the inline path.
            let scores = s.scores(&probs, &hashes, None);
            let mut tmax = vec![0.0f32; l];
            SoftScorer::table_maxes(&probs.probs, l, r, &mut tmax);
            for blk in 0..hashes.n_blocks() {
                let ub = SoftScorer::block_bound(&hashes, blk, &probs.probs, r);
                let ub_pre =
                    SoftScorer::block_bound_with(&hashes, blk, &probs.probs, r, Some(&tmax));
                prop_assert!(ub == ub_pre, "inline vs precomputed fallback diverge");
                for j in blk * bt..blk * bt + hashes.block_len(blk) {
                    prop_assert!(
                        scores[j] <= ub,
                        "block {blk} key {j}: score {} > capped bound {ub}",
                        scores[j]
                    );
                }
            }
            // And the walk stays lossless on saturated summaries.
            let k = 1 + rng.below_usize(n);
            let (want_i, want_s) = exhaustive_reference(&s, &q, &hashes, k);
            for pool in &pools {
                let (got_i, got_s, _) = pruned_with(&s, &q, &hashes, k, pool, true);
                prop_assert!(
                    got_i == want_i && got_s == want_s,
                    "threads={} capped selection diverges (n={n} k={k})",
                    pool.threads()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_block_bounds_are_admissible() {
        // Theorem behind the pruning: every block's bound dominates the
        // computed f32 score of every resident key — across τ extremes
        // and degenerate bucket distributions.
        check("block-bound-admissible", PropConfig { cases: 40, seed: 0xADB0 }, |rng, _| {
            let dim = gen::size(rng, 4, 40);
            let p = 1 + rng.below_usize(8);
            let tau = [1e-3f32, 0.5, 1e5][rng.below_usize(3)];
            let l = 1 + rng.below_usize(10);
            let s = SoftScorer::new(LshParams { p, l, tau }, dim, rng.next_u64());
            let n = 1 + rng.below_usize(2 * crate::lsh::simhash::BLOCK_TOKENS + 9);
            let keys = Matrix::gaussian(n, dim, rng);
            let vals = Matrix::gaussian(n, dim, rng);
            let mut hashes = s.hash_keys(&keys, &vals);
            // Half the cases extend mid-decode so the tail summary is
            // exercised in its mutated-in-place state.
            if rng.below_usize(2) == 0 {
                for _ in 0..rng.below_usize(30) {
                    let nk = rng.normal_vec(dim);
                    let buckets = s.hasher.simhash().hash_one(&nk);
                    hashes.push(&buckets, rng.next_f32() * 3.0);
                }
            }
            let q = rng.normal_vec(dim);
            let probs = s.hasher.bucket_probs(&q);
            let scores = s.scores(&probs, &hashes, None);
            let bt = crate::lsh::simhash::BLOCK_TOKENS;
            for blk in 0..hashes.n_blocks() {
                let ub = SoftScorer::block_bound(&hashes, blk, &probs.probs, probs.r);
                for j in blk * bt..blk * bt + hashes.block_len(blk) {
                    prop_assert!(
                        scores[j] <= ub,
                        "block {blk} key {j}: score {} > bound {ub} (tau={tau})",
                        scores[j]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_group_lanes_match_scalar_pruned() {
        // The GQA kernel is a pure fusion: every lane's selection must
        // equal its own scalar select_pruned_into run.
        let pools = engine_pools();
        check("gqa-group-vs-scalar", PropConfig { cases: 24, seed: 0x6A4 }, |rng, _| {
            let dim = gen::size(rng, 4, 32);
            let p = 1 + rng.below_usize(7);
            let l = 1 + rng.below_usize(8);
            let tau = rng.range_f32(0.1, 1.0);
            let s = SoftScorer::new(LshParams { p, l, tau }, dim, rng.next_u64());
            let n = 1 + rng.below_usize(2 * crate::lsh::simhash::BLOCK_TOKENS + 5);
            let keys = Matrix::gaussian(n, dim, rng);
            let vals = Matrix::gaussian(n, dim, rng);
            let hashes = s.hash_keys(&keys, &vals);
            let group = 1 + rng.below_usize(6);
            let k = 1 + rng.below_usize(n + 2);
            let queries: Vec<Vec<f32>> = (0..group).map(|_| rng.normal_vec(dim)).collect();
            let probs: Vec<BucketProbs> =
                queries.iter().map(|q| s.hasher.bucket_probs(q)).collect();
            let r = probs[0].r;
            let mut idx = vec![Vec::new(); group];
            let mut sc = vec![Vec::new(); group];
            {
                let mut lanes: Vec<GroupLane<'_>> = probs
                    .iter()
                    .zip(idx.iter_mut().zip(sc.iter_mut()))
                    .map(|(bp, (i, sv))| GroupLane { probs: &bp.probs, indices: i, scores: sv })
                    .collect();
                s.select_pruned_group_into(r, &hashes, k, &mut lanes);
            }
            for g in 0..group {
                let (want_i, want_s, _) = pruned(&s, &queries[g], &hashes, k);
                prop_assert!(idx[g] == want_i, "lane {g} indices diverge (n={n} k={k})");
                prop_assert!(sc[g] == want_s, "lane {g} scores diverge");
            }
            // The fused group kernel must also be invariant across pool
            // sizes and orderings — the blocks x lanes tiling at work.
            for pool in &pools {
                for ordered in [false, true] {
                    let mut idx2 = vec![Vec::new(); group];
                    let mut sc2 = vec![Vec::new(); group];
                    {
                        let mut lanes: Vec<GroupLane<'_>> = probs
                            .iter()
                            .zip(idx2.iter_mut().zip(sc2.iter_mut()))
                            .map(|(bp, (i, sv))| GroupLane {
                                probs: &bp.probs,
                                indices: i,
                                scores: sv,
                            })
                            .collect();
                        s.select_pruned_group_with(r, &hashes, k, &mut lanes, pool, ordered);
                    }
                    prop_assert!(
                        idx2 == idx && sc2 == sc,
                        "group threads={} ordered={ordered} diverges (n={n} k={k} group={group})",
                        pool.threads()
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pruning_skips_dominated_blocks() {
        // Deterministic pruning witness (serial pool — parallel prune
        // counts depend on shared-threshold timing): identical keys
        // everywhere mean every later block's bound ties the held
        // entry's score at a higher base index, which the tie-aware
        // predicate prunes.
        let dim = 24;
        let s = scorer(6, 8, 0.5, dim);
        let serial = WorkerPool::new(1);
        let mut rng = Pcg64::seeded(77);
        let proto = rng.normal_vec(dim);
        let n = 4 * crate::lsh::simhash::BLOCK_TOKENS;
        let mut keys = Matrix::zeros(n, dim);
        for j in 0..n {
            keys.row_mut(j).copy_from_slice(&proto);
        }
        let vals = Matrix::from_vec(n, dim, vec![1.0; n * dim]);
        let hashes = s.hash_keys(&keys, &vals);
        let q = rng.normal_vec(dim);
        for ordered in [false, true] {
            let (idx, sc, stats) = pruned_with(&s, &q, &hashes, 1, &serial, ordered);
            assert_eq!(stats.blocks, 4, "ordered={ordered}");
            assert_eq!(stats.pruned, 3, "blocks 1..3 must be bounded out (ordered={ordered})");
            assert_eq!(stats.warmup, 1, "only block 0 scored before the first prune");
            let (want_i, want_s) = exhaustive_reference(&s, &q, &hashes, 1);
            assert_eq!(idx, want_i);
            assert_eq!(sc, want_s);
        }
        // The parallel engines agree on the selection (stats may not be
        // deterministic there).
        let (idx, sc, _) = pruned(&s, &q, &hashes, 1);
        let (want_i, want_s) = exhaustive_reference(&s, &q, &hashes, 1);
        assert_eq!(idx, want_i);
        assert_eq!(sc, want_s);
    }

    #[test]
    fn bound_order_warms_threshold_faster_than_storage_order() {
        // Deterministic ordering witness (serial pool): block value
        // norms ascend, so in storage order every block strictly beats
        // the current threshold and is scored — the threshold never
        // warms enough to prune. Bound order visits the best block
        // first and prunes everything after it.
        let dim = 16;
        let s = scorer(5, 6, 0.5, dim);
        let serial = WorkerPool::new(1);
        let mut rng = Pcg64::seeded(99);
        let proto = rng.normal_vec(dim);
        let bt = crate::lsh::simhash::BLOCK_TOKENS;
        let n = 6 * bt;
        let mut keys = Matrix::zeros(n, dim);
        let mut vals = Matrix::zeros(n, dim);
        for j in 0..n {
            keys.row_mut(j).copy_from_slice(&proto);
            vals.set(j, 0, (j / bt + 1) as f32);
        }
        let hashes = s.hash_keys(&keys, &vals);
        let q = rng.normal_vec(dim);
        let (_, _, storage) = pruned_with(&s, &q, &hashes, 4, &serial, false);
        let (_, _, ordered) = pruned_with(&s, &q, &hashes, 4, &serial, true);
        assert!(
            ordered.warmup < storage.warmup,
            "bound order should warm faster: ordered {} vs storage {}",
            ordered.warmup,
            storage.warmup
        );
        assert!(ordered.pruned > storage.pruned, "and prune more");
        // Same selection either way, of course.
        let (i1, s1, _) = pruned_with(&s, &q, &hashes, 4, &serial, false);
        let (i2, s2, _) = pruned_with(&s, &q, &hashes, 4, &serial, true);
        assert_eq!(i1, i2);
        assert_eq!(s1, s2);
    }

    #[test]
    fn pooled_pipeline_matches_serial() {
        // The worker-pool variants must be bit-identical to the serial
        // hot path: chunked fills reduce nothing across threads.
        let dim = 48;
        let s = scorer(8, 24, 0.5, dim);
        let pool = WorkerPool::new(4);
        let mut rng = Pcg64::seeded(21);
        let keys = Matrix::gaussian(2000, dim, &mut rng);
        let vals = Matrix::gaussian(2000, dim, &mut rng);
        let hashes = s.hash_keys(&keys, &vals);
        let q = rng.normal_vec(dim);
        let probs_serial = s.hasher.bucket_probs(&q);
        let probs_pooled = s.hasher.bucket_probs_with(&q, &pool);
        assert_eq!(probs_serial.probs, probs_pooled.probs);
        assert_eq!(
            s.raw_scores(&probs_serial, &hashes),
            s.raw_scores_with(&probs_pooled, &hashes, &pool)
        );
        let mask: Vec<bool> = (0..2000).map(|j| j % 3 != 0).collect();
        assert_eq!(
            s.scores(&probs_serial, &hashes, Some(&mask)),
            s.scores_with(&probs_pooled, &hashes, Some(&mask), &pool)
        );
        assert_eq!(
            s.select_top_k(&q, &hashes, 64),
            s.select_top_k_with(&q, &hashes, 64, &pool)
        );
    }

    #[test]
    fn into_buffers_match_allocating_paths() {
        // The zero-alloc entry points (bucket_probs_into / scores_into)
        // must be bit-identical to the allocating ones, including when
        // handed dirty, wrong-sized buffers.
        let dim = 32;
        let s = scorer(6, 10, 0.5, dim);
        let pool = WorkerPool::new(3);
        let mut rng = Pcg64::seeded(33);
        let keys = Matrix::gaussian(400, dim, &mut rng);
        let vals = Matrix::gaussian(400, dim, &mut rng);
        let hashes = s.hash_keys(&keys, &vals);
        let q = rng.normal_vec(dim);
        let want_probs = s.hasher.bucket_probs(&q);
        let mut probs = vec![7.5f32; 3]; // stale, wrong size
        let (l, r) = s.hasher.bucket_probs_into(&q, &mut probs, &pool);
        assert_eq!((l, r), (10, 64));
        assert_eq!(probs, want_probs.probs);
        let want_scores = s.scores(&want_probs, &hashes, None);
        let mut scores = vec![-1.0f32; 9999]; // stale, wrong size
        s.scores_into(&probs, r, &hashes, &pool, &mut scores);
        assert_eq!(scores, want_scores);
    }

    #[test]
    fn prop_dispatch_modes_bit_identical() {
        // The full soft path — hashing, bucket probabilities, and the
        // fused group selection (scores AND indices) — must be
        // bit-identical between auto dispatch and the forced scalar
        // reference. This is the SIMD contract, not a tolerance check.
        check("soft-dispatch-modes", PropConfig { cases: 16, seed: 0xD15 }, |rng, _| {
            let dim = gen::size(rng, 4, 32);
            let p = 1 + rng.below_usize(7);
            let l = 1 + rng.below_usize(8);
            let tau = rng.range_f32(0.1, 1.0);
            let seed = rng.next_u64();
            let n = 1 + rng.below_usize(2 * crate::lsh::simhash::BLOCK_TOKENS + 5);
            let keys = Matrix::gaussian(n, dim, rng);
            let vals = Matrix::gaussian(n, dim, rng);
            let group = 1 + rng.below_usize(4);
            let k = 1 + rng.below_usize(n + 2);
            let queries: Vec<Vec<f32>> = (0..group).map(|_| rng.normal_vec(dim)).collect();
            let run = || {
                let s = SoftScorer::new(LshParams { p, l, tau }, dim, seed);
                let hashes = s.hash_keys(&keys, &vals);
                let probs: Vec<BucketProbs> =
                    queries.iter().map(|q| s.hasher.bucket_probs(q)).collect();
                let r = probs[0].r;
                let mut idx = vec![Vec::new(); group];
                let mut sc = vec![Vec::new(); group];
                {
                    let mut lanes: Vec<GroupLane<'_>> = probs
                        .iter()
                        .zip(idx.iter_mut().zip(sc.iter_mut()))
                        .map(|(bp, (i, sv))| GroupLane {
                            probs: &bp.probs,
                            indices: i,
                            scores: sv,
                        })
                        .collect();
                    s.select_pruned_group_into(r, &hashes, k, &mut lanes);
                }
                let prob_bits: Vec<Vec<u32>> = probs
                    .iter()
                    .map(|bp| bp.probs.iter().map(|x| x.to_bits()).collect())
                    .collect();
                let score_bits: Vec<Vec<u32>> = sc
                    .iter()
                    .map(|sv| sv.iter().map(|x| x.to_bits()).collect())
                    .collect();
                (prob_bits, idx, score_bits)
            };
            let auto = crate::simd::dispatch::with_auto(&run);
            let scalar = crate::simd::dispatch::with_forced_scalar(&run);
            prop_assert!(
                auto.0 == scalar.0,
                "bucket probs diverge across tiers (p={p} l={l} dim={dim})"
            );
            prop_assert!(
                auto.1 == scalar.1,
                "selected indices diverge across tiers (n={n} k={k} group={group})"
            );
            prop_assert!(
                auto.2 == scalar.2,
                "selected scores diverge across tiers (n={n} k={k} group={group})"
            );
            Ok(())
        });
    }
}
