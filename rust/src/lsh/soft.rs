//! The SOCKET soft collision kernel (Algorithms 2–4).
//!
//! * [`SoftHasher::bucket_probs`] — Algorithm 2: the query induces a
//!   softmax distribution over the `R = 2^P` buckets of each table,
//!   `p_τ(r | q) ∝ exp(u·c_r / τ)` with `u = tanh(Wq)/√d`.
//! * [`SoftScorer::scores`] — Algorithm 4: every key's score is the
//!   probability mass its cached buckets receive, summed over tables and
//!   weighted by the value norm.
//! * [`SoftScorer::select_top_k`] — Algorithm 3: deterministic top-k over
//!   `ŵ_j · ‖v_j‖₂`.

use crate::linalg::TopK;
use crate::lsh::params::LshParams;
use crate::lsh::simhash::{KeyHashes, SimHash};
use crate::util::pool::WorkerPool;

/// Query-side soft hashing (Algorithm 2).
#[derive(Clone, Debug)]
pub struct SoftHasher {
    hash: SimHash,
}

/// The per-table bucket distributions of one query: row-major `L x R`.
#[derive(Clone, Debug)]
pub struct BucketProbs {
    pub l: usize,
    pub r: usize,
    pub probs: Vec<f32>,
}

impl BucketProbs {
    #[inline]
    pub fn table(&self, t: usize) -> &[f32] {
        &self.probs[t * self.r..(t + 1) * self.r]
    }
}

impl SoftHasher {
    pub fn new(hash: SimHash) -> SoftHasher {
        SoftHasher { hash }
    }

    pub fn simhash(&self) -> &SimHash {
        &self.hash
    }

    pub fn params(&self) -> LshParams {
        self.hash.params
    }

    /// Algorithm 2 for one table ℓ: `u = tanh(W^(ℓ) q) / √d`,
    /// `logit_r = u·c_r / τ`, softmax over r, written into `w` (len R).
    ///
    /// The corner inner products are computed without materializing the
    /// `P x R` corner matrix: a Gray-code-free butterfly — logit over
    /// corners is separable, `u·c_r = Σ_i ±u_i` — built by iterative
    /// doubling in O(R·P) adds but cache-friendly (R ≤ 2^16).
    fn table_probs(&self, t: usize, q: &[f32], w: &mut [f32]) {
        let p = self.hash.params.p;
        let tau = self.hash.params.tau;
        let inv_sqrt_d = 1.0 / (self.hash.dim as f32).sqrt();
        let proj = self.hash.project(t, q);
        // Multiplicative butterfly: exp(Σ ±u_i/τ) = Π exp(±u_i/τ),
        // so only 2P exps are needed per table instead of R = 2^P —
        // after step i, w[0..2^(i+1)] hold all sign combinations of
        // u_0..u_i. Safe without max-subtraction: |u_i| ≤ 1/√d, so
        // every factor is bounded by e^(P/(√d·τ)).
        // (§Perf: 3.2x faster scoring at (P=10, L=60); see
        // EXPERIMENTS.md.)
        w[0] = 1.0;
        let mut width = 1usize;
        for i in 0..p {
            let u = proj[i].tanh() * inv_sqrt_d / tau;
            // Normalize the pair so factors are ≤ 1: equivalent up
            // to the final normalization, and overflow-free even at
            // tiny τ (the dominated corner underflows to 0, which
            // is its correct limit).
            let e_plus = (u - u.abs()).exp();
            let e_minus = (-u - u.abs()).exp();
            for b in 0..width {
                // bit i set => +u ; cleared => -u.
                w[b + width] = w[b] * e_plus;
                w[b] *= e_minus;
            }
            width *= 2;
        }
        let sum: f32 = w.iter().sum();
        let inv = 1.0 / sum;
        for x in w.iter_mut() {
            *x *= inv;
        }
    }

    /// Algorithm 2: the per-table bucket distributions of one query.
    pub fn bucket_probs(&self, q: &[f32]) -> BucketProbs {
        let l = self.hash.params.l;
        let r = 1usize << self.hash.params.p;
        let mut probs = vec![0.0f32; l * r];
        for (t, w) in probs.chunks_mut(r).enumerate() {
            self.table_probs(t, q, w);
        }
        BucketProbs { l, r, probs }
    }

    /// Algorithm 2 across a worker pool: tables are independent, so
    /// threads fill disjoint blocks of per-table distributions. Output
    /// is bit-identical to [`SoftHasher::bucket_probs`].
    pub fn bucket_probs_with(&self, q: &[f32], pool: &WorkerPool) -> BucketProbs {
        let mut probs = Vec::new();
        let (l, r) = self.bucket_probs_into(q, &mut probs, pool);
        BucketProbs { l, r, probs }
    }

    /// Algorithm 2 into a reusable buffer: fills `out` with the
    /// flattened `L x R` per-table distributions (capacity persists
    /// across calls — the decode hot path's zero-alloc entry point).
    /// Returns `(L, R)`. Bit-identical to [`SoftHasher::bucket_probs`].
    pub fn bucket_probs_into(
        &self,
        q: &[f32],
        out: &mut Vec<f32>,
        pool: &WorkerPool,
    ) -> (usize, usize) {
        let l = self.hash.params.l;
        let r = 1usize << self.hash.params.p;
        out.clear();
        out.resize(l * r, 0.0);
        pool.fill_rows(out, r, |t, w| self.table_probs(t, q, w));
        (l, r)
    }
}

/// Key scoring + selection over a hashed KV cache (Algorithms 3–4).
#[derive(Clone, Debug)]
pub struct SoftScorer {
    pub hasher: SoftHasher,
}

impl SoftScorer {
    pub fn new(params: LshParams, dim: usize, seed: u64) -> SoftScorer {
        SoftScorer { hasher: SoftHasher::new(SimHash::new(params, dim, seed)) }
    }

    pub fn params(&self) -> LshParams {
        self.hasher.params()
    }

    /// Algorithm 1 delegate: hash keys at prefill.
    pub fn hash_keys(
        &self,
        keys: &crate::linalg::Matrix,
        values: &crate::linalg::Matrix,
    ) -> KeyHashes {
        self.hasher.simhash().hash_keys(keys, values)
    }

    /// One key's soft collision mass against a query's prob table.
    /// `table` is the flattened `L x R` distributions; `row` the key's
    /// `L` bucket ids. Bounds checks are hoisted: bucket ids are
    /// produced by `pack_signs` (< 2^P = R by construction) and row
    /// length == L, so the unchecked accesses are provably in range
    /// (§Perf).
    #[inline]
    fn score_key(table: &[f32], r: usize, row: &[u16]) -> f32 {
        let mut acc = 0.0f32;
        for (t, &b) in row.iter().enumerate() {
            debug_assert!((b as usize) < r);
            acc += unsafe { *table.get_unchecked(t * r + (b as usize & (r - 1))) };
        }
        acc
    }

    /// Raw soft collision scores `ŵ_j = Σ_ℓ p_τ(b_j^(ℓ) | q)` (eq. 3),
    /// *without* the value-norm weighting.
    pub fn raw_scores(&self, probs: &BucketProbs, hashes: &KeyHashes) -> Vec<f32> {
        assert_eq!(probs.l, hashes.l);
        let l = hashes.l;
        let mut out = vec![0.0f32; hashes.n];
        // Hot path: iterate keys outer, tables inner; the prob table is
        // L x R and stays in cache (R*L*4 bytes, e.g. 60*1024*4 = 240KB).
        let r = probs.r;
        let table = &probs.probs[..l * r];
        for (j, slot) in out.iter_mut().enumerate() {
            *slot = Self::score_key(table, r, hashes.key_row(j));
        }
        out
    }

    /// [`SoftScorer::raw_scores`] across a worker pool: keys are
    /// independent and the `L x R` prob table is read-only, so threads
    /// score disjoint key ranges. Output is bit-identical to the serial
    /// path (no cross-chunk reductions).
    pub fn raw_scores_with(
        &self,
        probs: &BucketProbs,
        hashes: &KeyHashes,
        pool: &WorkerPool,
    ) -> Vec<f32> {
        assert_eq!(probs.l, hashes.l);
        let l = hashes.l;
        let r = probs.r;
        let table = &probs.probs[..l * r];
        let mut out = vec![0.0f32; hashes.n];
        pool.fill(&mut out, |j| Self::score_key(table, r, hashes.key_row(j)));
        out
    }

    /// Apply Algorithm 4's value-norm weighting + optional validity mask
    /// (`false` entries score -inf) to raw scores, in place.
    fn weight_scores(s: &mut [f32], hashes: &KeyHashes, mask: Option<&[bool]>) {
        for j in 0..s.len() {
            let valid = mask.map(|m| m[j]).unwrap_or(true);
            s[j] = if valid { s[j] * hashes.value_norms[j] } else { f32::NEG_INFINITY };
        }
    }

    /// Algorithm 4: value-aware scores `ŵ_j · ‖v_j‖₂`, with an optional
    /// validity mask (`false` entries score -inf).
    pub fn scores(&self, probs: &BucketProbs, hashes: &KeyHashes, mask: Option<&[bool]>) -> Vec<f32> {
        let mut s = self.raw_scores(probs, hashes);
        Self::weight_scores(&mut s, hashes, mask);
        s
    }

    /// Algorithm 4 into a reusable buffer: value-norm-weighted soft
    /// collision scores over a flattened `L x R` prob table (as filled
    /// by [`SoftHasher::bucket_probs_into`]), pooled. Bit-identical to
    /// [`SoftScorer::scores_with`] without the per-call allocation —
    /// the selector hot path's entry point.
    pub fn scores_into(
        &self,
        probs: &[f32],
        r: usize,
        hashes: &KeyHashes,
        pool: &WorkerPool,
        out: &mut Vec<f32>,
    ) {
        let l = hashes.l;
        assert_eq!(probs.len(), l * r, "prob table shape mismatch");
        out.clear();
        out.resize(hashes.n, 0.0);
        let table = &probs[..l * r];
        pool.fill(out, |j| Self::score_key(table, r, hashes.key_row(j)));
        Self::weight_scores(out, hashes, None);
    }

    /// [`SoftScorer::scores`] with the scoring loop on a worker pool.
    pub fn scores_with(
        &self,
        probs: &BucketProbs,
        hashes: &KeyHashes,
        mask: Option<&[bool]>,
        pool: &WorkerPool,
    ) -> Vec<f32> {
        let mut s = self.raw_scores_with(probs, hashes, pool);
        Self::weight_scores(&mut s, hashes, mask);
        s
    }

    /// Full decode-side pipeline (Algorithms 2→4→3): soft-hash the query,
    /// score every key, return the top-k key indices (descending score).
    pub fn select_top_k(&self, q: &[f32], hashes: &KeyHashes, k: usize) -> Vec<usize> {
        let probs = self.hasher.bucket_probs(q);
        let scores = self.scores(&probs, hashes, None);
        Self::top_k_of(&scores, k, hashes.n)
    }

    /// [`SoftScorer::select_top_k`] with soft-hashing and scoring
    /// parallelized on `pool` — the serving hot path. Selection is
    /// identical to the serial pipeline (chunked fills reduce nothing
    /// across threads, and top-k stays serial).
    pub fn select_top_k_with(
        &self,
        q: &[f32],
        hashes: &KeyHashes,
        k: usize,
        pool: &WorkerPool,
    ) -> Vec<usize> {
        let probs = self.hasher.bucket_probs_with(q, pool);
        let scores = self.scores_with(&probs, hashes, None, pool);
        Self::top_k_of(&scores, k, hashes.n)
    }

    fn top_k_of(scores: &[f32], k: usize, n: usize) -> Vec<usize> {
        let mut tk = TopK::new(k.min(n).max(1));
        for (j, &s) in scores.iter().enumerate() {
            tk.push(s, j);
        }
        tk.into_indices()
    }

    /// Normalized soft weights `ã_j = w̃_j / Z̃` (Section 5.1) — the proxy
    /// attention distribution used by the sampling estimator and the
    /// Theorem-3 validation bench.
    pub fn normalized_weights(&self, q: &[f32], hashes: &KeyHashes) -> Vec<f32> {
        let probs = self.hasher.bucket_probs(q);
        let mut w = self.raw_scores(&probs, hashes);
        let l = hashes.l as f32;
        let mut z = 0.0f32;
        for x in w.iter_mut() {
            *x /= l;
            z += *x;
        }
        if z > 0.0 {
            for x in w.iter_mut() {
                *x /= z;
            }
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::prop_assert;
    use crate::testing::{check, check_default, gen, PropConfig};
    use crate::util::rng::Pcg64;

    fn scorer(p: usize, l: usize, tau: f32, dim: usize) -> SoftScorer {
        SoftScorer::new(LshParams { p, l, tau }, dim, 1234)
    }

    #[test]
    fn bucket_probs_are_distributions() {
        let s = scorer(8, 10, 0.5, 64);
        let mut rng = Pcg64::seeded(1);
        let q = rng.normal_vec(64);
        let probs = s.hasher.bucket_probs(&q);
        assert_eq!(probs.l, 10);
        assert_eq!(probs.r, 256);
        for t in 0..probs.l {
            let sum: f32 = probs.table(t).iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "table {t} sums to {sum}");
            assert!(probs.table(t).iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn dominant_soft_bucket_is_hard_bucket() {
        // Section B.1: argmax_r p_τ(r|q) must equal the hard SRP bucket
        // because tanh is strictly increasing.
        let s = scorer(10, 30, 0.4, 48);
        let mut rng = Pcg64::seeded(2);
        for _ in 0..20 {
            let q = rng.normal_vec(48);
            let probs = s.hasher.bucket_probs(&q);
            for t in 0..probs.l {
                let hard = s.hasher.simhash().bucket_of(t, &q) as usize;
                let soft_argmax = crate::linalg::argmax(probs.table(t));
                assert_eq!(soft_argmax, hard, "table {t}");
            }
        }
    }

    #[test]
    fn tau_to_zero_recovers_hard_lsh() {
        // As τ→0 the soft distribution peaks on the hard bucket (ε_τ→0).
        let dim = 32;
        let mut rng = Pcg64::seeded(3);
        let q = rng.normal_vec(dim);
        let sharp = scorer(6, 5, 0.01, dim);
        let probs = sharp.hasher.bucket_probs(&q);
        for t in 0..probs.l {
            let hard = sharp.hasher.simhash().bucket_of(t, &q) as usize;
            assert!(probs.table(t)[hard] > 0.95, "mass={}", probs.table(t)[hard]);
        }
    }

    #[test]
    fn tau_to_infinity_uniformizes() {
        let dim = 32;
        let mut rng = Pcg64::seeded(4);
        let q = rng.normal_vec(dim);
        let smooth = scorer(6, 5, 1e4, dim);
        let probs = smooth.hasher.bucket_probs(&q);
        let r = probs.r as f32;
        for t in 0..probs.l {
            for &p in probs.table(t) {
                assert!((p - 1.0 / r).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn raw_scores_bounded_by_l() {
        // Each per-table contribution is a probability, so 0 ≤ ŵ_j ≤ L.
        let s = scorer(8, 24, 0.5, 32);
        let mut rng = Pcg64::seeded(5);
        let keys = Matrix::gaussian(100, 32, &mut rng);
        let vals = Matrix::gaussian(100, 32, &mut rng);
        let hashes = s.hash_keys(&keys, &vals);
        let q = rng.normal_vec(32);
        let probs = s.hasher.bucket_probs(&q);
        for &w in &s.raw_scores(&probs, &hashes) {
            assert!((0.0..=24.0).contains(&w), "w={w}");
        }
    }

    #[test]
    fn closer_key_scores_higher() {
        // Fig. 1's claim: score(q,k1) > score(q,k2) when cos(q,k1) >
        // cos(q,k2). Holds in expectation; test with a wide margin.
        let dim = 64;
        let s = scorer(10, 60, 0.5, dim);
        let mut rng = Pcg64::seeded(6);
        let q = gen::unit_vec(&mut rng, dim);
        let k_near = gen::key_with_cosine(&mut rng, &q, 0.9);
        let k_far = gen::key_with_cosine(&mut rng, &q, 0.1);
        let mut keys = Matrix::zeros(2, dim);
        keys.row_mut(0).copy_from_slice(&k_near);
        keys.row_mut(1).copy_from_slice(&k_far);
        let vals = Matrix::from_vec(2, dim, vec![1.0; 2 * dim]); // equal norms
        let hashes = s.hash_keys(&keys, &vals);
        let probs = s.hasher.bucket_probs(&q);
        let w = s.raw_scores(&probs, &hashes);
        assert!(w[0] > w[1], "near={} far={}", w[0], w[1]);
    }

    #[test]
    fn value_norm_weighting_applies() {
        let dim = 16;
        let s = scorer(6, 12, 0.5, dim);
        let mut rng = Pcg64::seeded(7);
        let key = rng.normal_vec(dim);
        let mut keys = Matrix::zeros(2, dim);
        keys.row_mut(0).copy_from_slice(&key);
        keys.row_mut(1).copy_from_slice(&key); // identical keys
        let mut vals = Matrix::zeros(2, dim);
        vals.set(0, 0, 1.0);
        vals.set(1, 0, 5.0); // 5x larger value norm
        let hashes = s.hash_keys(&keys, &vals);
        let q = rng.normal_vec(dim);
        let probs = s.hasher.bucket_probs(&q);
        let sc = s.scores(&probs, &hashes, None);
        assert!((sc[1] / sc[0] - 5.0).abs() < 1e-3, "ratio={}", sc[1] / sc[0]);
    }

    #[test]
    fn mask_excludes_keys() {
        let dim = 16;
        let s = scorer(6, 12, 0.5, dim);
        let mut rng = Pcg64::seeded(8);
        let keys = Matrix::gaussian(5, dim, &mut rng);
        let vals = Matrix::gaussian(5, dim, &mut rng);
        let hashes = s.hash_keys(&keys, &vals);
        let q = rng.normal_vec(dim);
        let probs = s.hasher.bucket_probs(&q);
        let mask = [true, false, true, false, true];
        let sc = s.scores(&probs, &hashes, Some(&mask));
        assert_eq!(sc[1], f32::NEG_INFINITY);
        assert_eq!(sc[3], f32::NEG_INFINITY);
        assert!(sc[0].is_finite());
    }

    #[test]
    fn select_top_k_returns_k_distinct() {
        let dim = 32;
        let s = scorer(8, 20, 0.5, dim);
        let mut rng = Pcg64::seeded(9);
        let keys = Matrix::gaussian(200, dim, &mut rng);
        let vals = Matrix::gaussian(200, dim, &mut rng);
        let hashes = s.hash_keys(&keys, &vals);
        let q = rng.normal_vec(dim);
        let sel = s.select_top_k(&q, &hashes, 16);
        assert_eq!(sel.len(), 16);
        let distinct: std::collections::HashSet<usize> = sel.iter().copied().collect();
        assert_eq!(distinct.len(), 16);
    }

    #[test]
    fn normalized_weights_form_distribution() {
        let dim = 24;
        let s = scorer(6, 15, 0.5, dim);
        let mut rng = Pcg64::seeded(10);
        let keys = Matrix::gaussian(64, dim, &mut rng);
        let vals = Matrix::gaussian(64, dim, &mut rng);
        let hashes = s.hash_keys(&keys, &vals);
        let q = rng.normal_vec(dim);
        let a = s.normalized_weights(&q, &hashes);
        let sum: f32 = a.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4);
        assert!(a.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn prop_butterfly_matches_naive_corners() {
        // The iterative-doubling logit construction must equal the naive
        // u·c_r computation for every corner.
        check_default("butterfly-vs-naive", |rng, _| {
            let p = 1 + rng.below_usize(8);
            let dim = gen::size(rng, 2, 48);
            let tau = rng.range_f32(0.1, 2.0);
            let s = SoftScorer::new(LshParams { p, l: 1, tau }, dim, rng.next_u64());
            let q = rng.normal_vec(dim);
            let probs = s.hasher.bucket_probs(&q);
            // Naive reference.
            let proj = s.hasher.simhash().project(0, &q);
            let inv = 1.0 / (dim as f32).sqrt();
            let u: Vec<f32> = proj.iter().map(|x| x.tanh() * inv).collect();
            let r = 1usize << p;
            let mut logits = vec![0.0f32; r];
            for cid in 0..r {
                let c = crate::lsh::simhash::corner(cid as u16, p);
                logits[cid] = u.iter().zip(&c).map(|(a, b)| a * b).sum::<f32>() / tau;
            }
            crate::linalg::softmax_inplace(&mut logits);
            for cid in 0..r {
                prop_assert!(
                    (probs.table(0)[cid] - logits[cid]).abs() < 1e-4,
                    "p={p} corner={cid}: {} vs {}",
                    probs.table(0)[cid],
                    logits[cid]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_collision_mass_monotone_in_cosine() {
        // Theorem 1's substance: the expected soft collision mass grows
        // with cos(q, k). With a wide cosine gap and many tables the
        // ordering holds for every seeded draw, not just on average.
        check("soft-monotone-cosine", PropConfig { cases: 24, seed: 0x50F7 }, |rng, _| {
            let dim = gen::size(rng, 24, 64);
            let params =
                LshParams { p: 6 + rng.below_usize(4), l: 150, tau: rng.range_f32(0.3, 0.8) };
            let s = SoftScorer::new(params, dim, rng.next_u64());
            let q = gen::unit_vec(rng, dim);
            let c_hi = rng.range_f32(0.85, 0.95);
            let c_lo = rng.range_f32(-0.1, 0.15);
            let mut keys = Matrix::zeros(2, dim);
            keys.row_mut(0).copy_from_slice(&gen::key_with_cosine(rng, &q, c_hi));
            keys.row_mut(1).copy_from_slice(&gen::key_with_cosine(rng, &q, c_lo));
            let vals = Matrix::from_vec(2, dim, vec![1.0; 2 * dim]);
            let hashes = s.hash_keys(&keys, &vals);
            let probs = s.hasher.bucket_probs(&q);
            let w = s.raw_scores(&probs, &hashes);
            prop_assert!(
                w[0] > w[1],
                "cos {c_hi:.2} scored {} <= cos {c_lo:.2} scored {}",
                w[0],
                w[1]
            );
            Ok(())
        });
    }

    #[test]
    fn prop_negated_query_mirrors_buckets() {
        // Exact symmetry of the soft kernel: tanh is odd, so
        // p_τ(r | -q) = p_τ(~r | q) (bitwise-complement bucket), table
        // by table — the soft analog of SimHash's antipodal symmetry.
        check_default("soft-sign-symmetry", |rng, _| {
            let p = 1 + rng.below_usize(8);
            let dim = gen::size(rng, 2, 48);
            let tau = rng.range_f32(0.1, 2.0);
            let s = SoftScorer::new(LshParams { p, l: 3, tau }, dim, rng.next_u64());
            let q = rng.normal_vec(dim);
            let neg: Vec<f32> = q.iter().map(|x| -x).collect();
            let pq = s.hasher.bucket_probs(&q);
            let pn = s.hasher.bucket_probs(&neg);
            let r = 1usize << p;
            for t in 0..3 {
                for b in 0..r {
                    let mirrored = pn.table(t)[b ^ (r - 1)];
                    prop_assert!(
                        (pq.table(t)[b] - mirrored).abs() < 1e-4,
                        "t={t} b={b}: {} vs {}",
                        pq.table(t)[b],
                        mirrored
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_collision_kernel_symmetric_in_expectation() {
        // κ(q, k) = κ(k, q): swapping the query and key roles yields the
        // same collision mass up to finite-L fluctuation. Coarse buckets
        // (P=3) and many tables keep the fluctuation far below the slack.
        check("soft-exchange-symmetry", PropConfig { cases: 12, seed: 0xE4C4 }, |rng, _| {
            let dim = gen::size(rng, 16, 48);
            let params = LshParams { p: 3, l: 600, tau: 0.7 };
            let s = SoftScorer::new(params, dim, rng.next_u64());
            let q = gen::unit_vec(rng, dim);
            let k = gen::key_with_cosine(rng, &q, rng.range_f32(0.4, 0.8));
            let mass = |query: &[f32], key: &[f32]| -> f32 {
                let keys = Matrix::from_vec(1, dim, key.to_vec());
                let vals = Matrix::from_vec(1, dim, vec![1.0; dim]);
                let hashes = s.hash_keys(&keys, &vals);
                let probs = s.hasher.bucket_probs(query);
                s.raw_scores(&probs, &hashes)[0]
            };
            let qk = mass(&q, &k);
            let kq = mass(&k, &q);
            let mid = 0.5 * (qk + kq);
            prop_assert!((qk - kq).abs() < 0.5 * mid + 5.0, "w(q,k)={qk} w(k,q)={kq}");
            Ok(())
        });
    }

    #[test]
    fn prop_tau_boundary_behaviour() {
        // τ→0 recovers hard LSH (all mass on the hard bucket); τ→∞ is
        // the uniform distribution — the two ends of Section 4's knob.
        check("tau-boundary", PropConfig { cases: 32, seed: 0x7A0 }, |rng, _| {
            let dim = gen::size(rng, 8, 48);
            let p = 2 + rng.below_usize(6);
            let seed = rng.next_u64();
            let q = rng.normal_vec(dim);
            let r = 1usize << p;
            // Sharp limit. Tables where the smallest |u_i| leaves less
            // than e^-28 of margin are skipped: a near-zero projection
            // genuinely splits mass between two adjacent buckets.
            let tau_sharp = 1e-3f32;
            let sharp = SoftScorer::new(LshParams { p, l: 6, tau: tau_sharp }, dim, seed);
            let probs = sharp.hasher.bucket_probs(&q);
            let inv_sqrt_d = 1.0 / (dim as f32).sqrt();
            for t in 0..6 {
                let proj = sharp.hasher.simhash().project(t, &q);
                let min_u = proj
                    .iter()
                    .map(|x| x.tanh().abs() * inv_sqrt_d)
                    .fold(f32::INFINITY, f32::min);
                if min_u / tau_sharp < 14.0 {
                    continue;
                }
                let hard = sharp.hasher.simhash().bucket_of(t, &q) as usize;
                prop_assert!(probs.table(t)[hard] > 0.99, "t={t} mass={}", probs.table(t)[hard]);
            }
            // Smooth limit: every bucket within 1% of uniform.
            let smooth = SoftScorer::new(LshParams { p, l: 6, tau: 1e5 }, dim, seed);
            let probs = smooth.hasher.bucket_probs(&q);
            for t in 0..6 {
                for &pr in probs.table(t) {
                    prop_assert!((pr * r as f32 - 1.0).abs() < 1e-2, "t={t} p={pr}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pooled_pipeline_matches_serial() {
        // The worker-pool variants must be bit-identical to the serial
        // hot path: chunked fills reduce nothing across threads.
        let dim = 48;
        let s = scorer(8, 24, 0.5, dim);
        let pool = WorkerPool::new(4);
        let mut rng = Pcg64::seeded(21);
        let keys = Matrix::gaussian(2000, dim, &mut rng);
        let vals = Matrix::gaussian(2000, dim, &mut rng);
        let hashes = s.hash_keys(&keys, &vals);
        let q = rng.normal_vec(dim);
        let probs_serial = s.hasher.bucket_probs(&q);
        let probs_pooled = s.hasher.bucket_probs_with(&q, &pool);
        assert_eq!(probs_serial.probs, probs_pooled.probs);
        assert_eq!(
            s.raw_scores(&probs_serial, &hashes),
            s.raw_scores_with(&probs_pooled, &hashes, &pool)
        );
        let mask: Vec<bool> = (0..2000).map(|j| j % 3 != 0).collect();
        assert_eq!(
            s.scores(&probs_serial, &hashes, Some(&mask)),
            s.scores_with(&probs_pooled, &hashes, Some(&mask), &pool)
        );
        assert_eq!(
            s.select_top_k(&q, &hashes, 64),
            s.select_top_k_with(&q, &hashes, 64, &pool)
        );
    }

    #[test]
    fn into_buffers_match_allocating_paths() {
        // The zero-alloc entry points (bucket_probs_into / scores_into)
        // must be bit-identical to the allocating ones, including when
        // handed dirty, wrong-sized buffers.
        let dim = 32;
        let s = scorer(6, 10, 0.5, dim);
        let pool = WorkerPool::new(3);
        let mut rng = Pcg64::seeded(33);
        let keys = Matrix::gaussian(400, dim, &mut rng);
        let vals = Matrix::gaussian(400, dim, &mut rng);
        let hashes = s.hash_keys(&keys, &vals);
        let q = rng.normal_vec(dim);
        let want_probs = s.hasher.bucket_probs(&q);
        let mut probs = vec![7.5f32; 3]; // stale, wrong size
        let (l, r) = s.hasher.bucket_probs_into(&q, &mut probs, &pool);
        assert_eq!((l, r), (10, 64));
        assert_eq!(probs, want_probs.probs);
        let want_scores = s.scores(&want_probs, &hashes, None);
        let mut scores = vec![-1.0f32; 9999]; // stale, wrong size
        s.scores_into(&probs, r, &hashes, &pool, &mut scores);
        assert_eq!(scores, want_scores);
    }
}
