//! Traditional hard-LSH collision scoring — the paper's primary ablation
//! baseline (eq. 3 left, Table 2, Table 7, Fig. 2).
//!
//! A key's score is the number of tables in which its bucket equals the
//! query's bucket: `s_hard(k_j, q) = Σ_ℓ 𝟙[b_j^(ℓ) = b_q^(ℓ)]`.

use crate::linalg::TopK;
use crate::lsh::bnb;
use crate::lsh::params::LshParams;
use crate::lsh::simhash::{KeyHashes, SimHash, BLOCK_TOKENS};
use crate::lsh::soft::PruneStats;
use crate::util::pool::{self, WorkerPool};

/// Hard collision scorer over the same cached [`KeyHashes`] as SOCKET —
/// identical memory footprint at identical (P, L).
#[derive(Clone, Debug)]
pub struct HardScorer {
    pub hash: SimHash,
}

impl HardScorer {
    pub fn new(params: LshParams, dim: usize, seed: u64) -> HardScorer {
        HardScorer { hash: SimHash::new(params, dim, seed) }
    }

    pub fn params(&self) -> LshParams {
        self.hash.params
    }

    pub fn hash_keys(
        &self,
        keys: &crate::linalg::Matrix,
        values: &crate::linalg::Matrix,
    ) -> KeyHashes {
        self.hash.hash_keys(keys, values)
    }

    /// Collision counts of every key against the query (integer-valued,
    /// returned as f32 for interface parity with the soft scorer).
    pub fn raw_scores(&self, q: &[f32], hashes: &KeyHashes) -> Vec<f32> {
        let qb = self.hash.hash_one(q);
        let mut out = Vec::new();
        hashes.collision_counts_into(&qb, &mut out);
        out
    }

    /// Value-aware scores (same weighting as SOCKET for fair comparison).
    pub fn scores(&self, q: &[f32], hashes: &KeyHashes) -> Vec<f32> {
        let mut out = Vec::new();
        self.scores_into(q, hashes, &mut out);
        out
    }

    /// [`HardScorer::scores`] into a reusable buffer (the selector hot
    /// path's zero-alloc entry point). Bit-identical: the per-key score
    /// is the same `count as f32 * ‖v_j‖` product.
    pub fn scores_into(&self, q: &[f32], hashes: &KeyHashes, out: &mut Vec<f32>) {
        let qb = self.hash.hash_one(q);
        hashes.collision_counts_into(&qb, out);
        for (slot, norm) in out.iter_mut().zip(hashes.value_norms.iter()) {
            *slot *= norm;
        }
    }

    /// Top-k selection by hard collision count x value norm.
    pub fn select_top_k(&self, q: &[f32], hashes: &KeyHashes, k: usize) -> Vec<usize> {
        let scores = self.scores(q, hashes);
        let mut tk = TopK::new(k.min(hashes.n).max(1));
        for (j, &s) in scores.iter().enumerate() {
            tk.push(s, j);
        }
        tk.into_indices()
    }

    /// Block-pruned top-k over `count_j · ‖v_j‖`: the SoA port of the
    /// shared collision kernel on the same pool-parallel
    /// branch-and-bound walk as `SoftScorer::select_pruned_into`
    /// (`lsh::bnb`). A block's bound is the number of tables whose
    /// summary contains the query's bucket (saturated summaries count
    /// unconditionally), times the block max norm — counts are small
    /// integers (exact in f32) and f32 products are monotone on
    /// non-negative operands, so the bound dominates every resident
    /// key's computed score and pruning is lossless. Bit-identical
    /// (indices and scores) to the exhaustive
    /// [`HardScorer::scores_into`] + `top_k` pipeline, for every pool
    /// size and traversal order. Runs bound-ordered on the shared
    /// global pool.
    pub fn select_pruned_into(
        &self,
        q: &[f32],
        hashes: &KeyHashes,
        k: usize,
        indices: &mut Vec<usize>,
        scores: &mut Vec<f32>,
    ) -> PruneStats {
        self.select_pruned_with(q, hashes, k, indices, scores, pool::global(), true)
    }

    /// [`HardScorer::select_pruned_into`] with an explicit pool and
    /// traversal order (the bench/test engine matrix).
    pub fn select_pruned_with(
        &self,
        q: &[f32],
        hashes: &KeyHashes,
        k: usize,
        indices: &mut Vec<usize>,
        scores: &mut Vec<f32>,
        pool: &WorkerPool,
        ordered: bool,
    ) -> PruneStats {
        indices.clear();
        scores.clear();
        if hashes.n == 0 || k == 0 {
            return PruneStats::default();
        }
        let n_blocks = hashes.n_blocks();
        let qb = self.hash.hash_one(q);
        pool::with_bnb_plan(|plan| {
            let crate::util::pool::BnbPlanScratch { bounds, order, walk, .. } = plan;
            bounds.clear();
            bounds.resize(n_blocks, 0.0);
            // Per-block bounds fanned over the pool (pure computation;
            // the fill degrades to a serial loop below its element
            // threshold and inside workers, bit-identically).
            pool.fill(bounds, |blk| {
                hashes.block_collision_bound(blk, &qb) * hashes.block_max_norm(blk)
            });
            if ordered && n_blocks > 1 {
                bnb::bound_order(bounds, order);
            } else {
                bnb::identity_order(n_blocks, order);
            }
            let norms = &hashes.value_norms;
            let score_block = |_lane: usize, blk: usize, acc: &mut [f32; BLOCK_TOKENS]| {
                let blen = hashes.block_len(blk);
                let base = blk * BLOCK_TOKENS;
                hashes.block_collision_counts(blk, &qb, acc.as_mut_slice());
                let (acc, _) = acc.split_at_mut(blen);
                crate::simd::mul_assign(acc, norms.get(base..).unwrap_or(&[]));
            };
            let mut outs = [(indices, scores)];
            bnb::run_walk(hashes, k, bounds, order, pool, score_block, &mut outs, walk)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::lsh::soft::SoftScorer;
    use crate::prop_assert;
    use crate::testing::{check_default, gen};
    use crate::util::rng::Pcg64;

    #[test]
    fn identical_key_collides_in_every_table() {
        let dim = 32;
        let h = HardScorer::new(LshParams { p: 8, l: 25, tau: 0.5 }, dim, 77);
        let mut rng = Pcg64::seeded(1);
        let q = rng.normal_vec(dim);
        let keys = Matrix::from_vec(1, dim, q.clone());
        let hashes = h.hash_keys(&keys, &keys);
        let s = h.raw_scores(&q, &hashes);
        assert_eq!(s[0], 25.0);
    }

    #[test]
    fn scores_are_integers_in_range() {
        let dim = 24;
        let h = HardScorer::new(LshParams { p: 4, l: 30, tau: 0.5 }, dim, 3);
        let mut rng = Pcg64::seeded(2);
        let keys = Matrix::gaussian(50, dim, &mut rng);
        let hashes = h.hash_keys(&keys, &keys);
        let q = rng.normal_vec(dim);
        for &s in &h.raw_scores(&q, &hashes) {
            assert!(s >= 0.0 && s <= 30.0 && s.fract() == 0.0);
        }
    }

    #[test]
    fn hard_scores_coarser_than_soft() {
        // The motivating observation (Fig. 2): at equal (P, L), hard
        // scores take few distinct values while soft scores are ~all
        // distinct — the granularity gap that breaks ranking.
        let dim = 64;
        let params = LshParams { p: 10, l: 20, tau: 0.5 };
        let hard = HardScorer::new(params, dim, 11);
        let soft = SoftScorer::new(params, dim, 11);
        let mut rng = Pcg64::seeded(3);
        let n = 300;
        let keys = Matrix::gaussian(n, dim, &mut rng);
        let hashes = hard.hash_keys(&keys, &keys);
        let q = rng.normal_vec(dim);
        let hs = hard.raw_scores(&q, &hashes);
        let probs = soft.hasher.bucket_probs(&q);
        let ss = soft.raw_scores(&probs, &hashes);
        let distinct = |v: &[f32]| {
            let mut u: Vec<u32> = v.iter().map(|x| x.to_bits()).collect();
            u.sort_unstable();
            u.dedup();
            u.len()
        };
        assert!(
            distinct(&hs) * 4 < distinct(&ss),
            "hard={} soft={}",
            distinct(&hs),
            distinct(&ss)
        );
    }

    #[test]
    fn prop_hard_score_equals_naive_count() {
        check_default("hard-count", |rng, _| {
            let dim = gen::size(rng, 4, 48);
            let params = LshParams { p: 1 + rng.below_usize(10), l: 1 + rng.below_usize(20), tau: 0.5 };
            let h = HardScorer::new(params, dim, rng.next_u64());
            let n = gen::size(rng, 1, 40);
            let keys = Matrix::gaussian(n, dim, rng);
            let hashes = h.hash_keys(&keys, &keys);
            let q = rng.normal_vec(dim);
            let qb = h.hash.hash_one(&q);
            let s = h.raw_scores(&q, &hashes);
            for j in 0..n {
                let manual = (0..params.l).filter(|&t| hashes.bucket(j, t) == qb[t]).count();
                prop_assert!(s[j] == manual as f32, "j={j}: {} vs {manual}", s[j]);
            }
            Ok(())
        });
    }

    #[test]
    fn scores_into_matches_raw_times_norm() {
        let dim = 16;
        let h = HardScorer::new(LshParams { p: 5, l: 12, tau: 0.5 }, dim, 8);
        let mut rng = Pcg64::seeded(6);
        let keys = Matrix::gaussian(40, dim, &mut rng);
        let vals = Matrix::gaussian(40, dim, &mut rng);
        let hashes = h.hash_keys(&keys, &vals);
        let q = rng.normal_vec(dim);
        let raw = h.raw_scores(&q, &hashes);
        let mut got = vec![5.0f32; 3]; // stale, wrong size
        h.scores_into(&q, &hashes, &mut got);
        assert_eq!(got.len(), 40);
        for j in 0..40 {
            assert_eq!(got[j], raw[j] * hashes.value_norms[j], "key {j}");
        }
    }

    #[test]
    fn prop_pruned_select_matches_exhaustive() {
        // The SoA/pruned port of the shared collision kernel must be
        // bit-identical (indices and scores) to the scalar reference —
        // across block-straddling sizes, ragged tails, mid-decode
        // appends that mutate the tail summary, and the whole engine
        // matrix (pool sizes 1/2/8 x storage/bound order).
        let pools =
            [WorkerPool::new(1), WorkerPool::new(2), WorkerPool::new(8)];
        check_default("hard-pruned-vs-exhaustive", |rng, _| {
            let dim = gen::size(rng, 4, 32);
            let p = 1 + rng.below_usize(8);
            let l = 1 + rng.below_usize(16);
            let h = HardScorer::new(LshParams { p, l, tau: 0.5 }, dim, rng.next_u64());
            let n = 1 + rng.below_usize(2 * crate::lsh::simhash::BLOCK_TOKENS + 11);
            let keys = Matrix::gaussian(n, dim, rng);
            let vals = Matrix::gaussian(n, dim, rng);
            let mut hashes = h.hash_keys(&keys, &vals);
            if rng.below_usize(2) == 0 {
                for _ in 0..rng.below_usize(24) {
                    let nk = rng.normal_vec(dim);
                    hashes.push(&h.hash.hash_one(&nk), rng.next_f32() * 2.0);
                }
            }
            let q = rng.normal_vec(dim);
            let k = 1 + rng.below_usize(hashes.n + 2);
            // Exhaustive reference: full scores + plain TopK.
            let scores = h.scores(&q, &hashes);
            let mut tk = TopK::new(k.min(hashes.n));
            for (j, &s) in scores.iter().enumerate() {
                tk.push(s, j);
            }
            let want = tk.into_sorted();
            let mut idx = vec![9usize; 3]; // stale
            let mut sc = vec![0.5f32; 7];
            h.select_pruned_into(&q, &hashes, k, &mut idx, &mut sc);
            let got: Vec<(usize, f32)> = idx.into_iter().zip(sc).collect();
            prop_assert!(got == want, "n={} k={k}: {got:?} vs {want:?}", hashes.n);
            for pool in &pools {
                for ordered in [false, true] {
                    let mut idx = vec![9usize; 3]; // stale
                    let mut sc = vec![0.5f32; 7];
                    h.select_pruned_with(&q, &hashes, k, &mut idx, &mut sc, pool, ordered);
                    let got: Vec<(usize, f32)> = idx.into_iter().zip(sc).collect();
                    prop_assert!(
                        got == want,
                        "threads={} ordered={ordered} (n={} k={k}): {got:?} vs {want:?}",
                        pool.threads(),
                        hashes.n
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_dispatch_modes_bit_identical() {
        // Hard-count selection (simd::count_eq + simd::mul_assign under
        // the bnb walk) must return bit-identical indices AND scores
        // whether the SIMD tier or the forced scalar reference runs.
        check_default("hard-dispatch-modes", |rng, _| {
            let dim = gen::size(rng, 4, 32);
            let p = 1 + rng.below_usize(8);
            let l = 1 + rng.below_usize(16);
            let h = HardScorer::new(LshParams { p, l, tau: 0.5 }, dim, rng.next_u64());
            let n = 1 + rng.below_usize(2 * BLOCK_TOKENS + 11);
            let keys = Matrix::gaussian(n, dim, rng);
            let vals = Matrix::gaussian(n, dim, rng);
            let hashes = h.hash_keys(&keys, &vals);
            let q = rng.normal_vec(dim);
            let k = 1 + rng.below_usize(n + 2);
            let run = || {
                let mut idx = Vec::new();
                let mut sc = Vec::new();
                h.select_pruned_into(&q, &hashes, k, &mut idx, &mut sc);
                (idx, sc.iter().map(|s| s.to_bits()).collect::<Vec<u32>>())
            };
            let auto = crate::simd::dispatch::with_auto(&run);
            let scalar = crate::simd::dispatch::with_forced_scalar(&run);
            prop_assert!(
                auto == scalar,
                "dispatch tiers diverge (n={n} k={k} p={p} l={l})"
            );
            Ok(())
        });
    }

    #[test]
    fn select_top_k_prefers_colliding_keys() {
        let dim = 48;
        let h = HardScorer::new(LshParams { p: 6, l: 40, tau: 0.5 }, dim, 5);
        let mut rng = Pcg64::seeded(4);
        let q = gen::unit_vec(&mut rng, dim);
        // key 0 = near-duplicate of q; rest random.
        let mut keys = Matrix::gaussian(64, dim, &mut rng);
        let near = gen::key_with_cosine(&mut rng, &q, 0.98);
        keys.row_mut(0).copy_from_slice(&near);
        let vals = Matrix::from_vec(64, 1, vec![1.0; 64]);
        let hashes = h.hash_keys(&keys, &vals);
        let sel = h.select_top_k(&q, &hashes, 8);
        assert!(sel.contains(&0), "near-duplicate not retrieved: {sel:?}");
    }
}
