//! Continuous batcher: assembles each scheduler iteration's work — which
//! waiting requests to prefill (token-budgeted) and which running
//! sequences to step (batch-size-capped), decode-priority so tokens keep
//! streaming while prefills are amortized (the Orca/vLLM policy).

use std::collections::VecDeque;

/// Batch assembly policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max sequences stepped per iteration.
    pub max_decode_batch: usize,
    /// Max prefill tokens admitted per iteration.
    pub prefill_token_budget: usize,
    /// Max new sequences admitted per iteration.
    pub max_prefills: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_decode_batch: 16, prefill_token_budget: 8192, max_prefills: 2 }
    }
}

/// One iteration's work.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Batch {
    /// (seq_id, context_len) to prefill.
    pub prefills: Vec<(u64, usize)>,
    /// Sequences to run one decode step.
    pub decodes: Vec<u64>,
}

impl Batch {
    pub fn is_empty(&self) -> bool {
        self.prefills.is_empty() && self.decodes.is_empty()
    }
}

/// Queue state + assembly. The batcher owns the waiting queue and the
/// running set; the scheduler feeds completions back.
#[derive(Debug, Default)]
pub struct Batcher {
    pub policy: BatchPolicy,
    waiting: VecDeque<(u64, usize)>,
    running: VecDeque<u64>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { policy, waiting: VecDeque::new(), running: VecDeque::new() }
    }

    pub fn enqueue(&mut self, seq_id: u64, context_len: usize) {
        self.waiting.push_back((seq_id, context_len));
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Mark a prefilled sequence as running.
    pub fn started(&mut self, seq_id: u64) {
        self.running.push_back(seq_id);
    }

    /// Remove a finished sequence.
    pub fn finished(&mut self, seq_id: u64) {
        self.running.retain(|&s| s != seq_id);
    }

    /// Requeue a prefill that failed admission (backpressure) — goes to
    /// the *front* to preserve FIFO fairness.
    pub fn requeue(&mut self, seq_id: u64, context_len: usize) {
        self.waiting.push_front((seq_id, context_len));
    }

    /// Assemble the next iteration's batch. Decode-priority: running
    /// sequences always step (round-robin rotation for fairness across
    /// iterations); prefills fill the remaining admission budget.
    pub fn next_batch(&mut self) -> Batch {
        let mut batch = Batch::default();
        // Decodes: up to max_decode_batch, rotating so all sequences
        // progress even when running > batch size.
        let n_dec = self.running.len().min(self.policy.max_decode_batch);
        for _ in 0..n_dec {
            let s = self.running.pop_front().unwrap();
            batch.decodes.push(s);
            self.running.push_back(s);
        }
        // Prefills under token budget. The first prefill of an
        // iteration is exempt: a context longer than the whole budget
        // must still be offered (alone) or it would block the queue
        // head forever — the token-budget twin of the KV livelock.
        let mut budget = self.policy.prefill_token_budget;
        while batch.prefills.len() < self.policy.max_prefills {
            match self.waiting.front() {
                Some(&(_, ctx)) if ctx <= budget || batch.prefills.is_empty() => {
                    let (id, ctx) = self.waiting.pop_front().unwrap();
                    budget = budget.saturating_sub(ctx);
                    batch.prefills.push((id, ctx));
                }
                _ => break,
            }
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BatchPolicy {
        BatchPolicy { max_decode_batch: 2, prefill_token_budget: 1000, max_prefills: 2 }
    }

    #[test]
    fn decode_priority_and_rotation() {
        let mut b = Batcher::new(policy());
        for s in 0..3u64 {
            b.started(s);
        }
        let b1 = b.next_batch();
        assert_eq!(b1.decodes, vec![0, 1]);
        let b2 = b.next_batch();
        assert_eq!(b2.decodes, vec![2, 0], "round-robin rotation");
    }

    #[test]
    fn prefill_token_budget_enforced() {
        let mut b = Batcher::new(policy());
        b.enqueue(1, 600);
        b.enqueue(2, 600); // would exceed 1000 budget
        b.enqueue(3, 100);
        let batch = b.next_batch();
        assert_eq!(batch.prefills, vec![(1, 600)]); // 2 blocks the queue (FIFO)
        let batch2 = b.next_batch();
        assert_eq!(batch2.prefills, vec![(2, 600), (3, 100)]);
    }

    #[test]
    fn oversized_context_is_offered_alone() {
        // A context longer than the whole token budget is still offered
        // as the sole prefill of its iteration (otherwise it would pin
        // the queue head forever).
        let mut b = Batcher::new(policy());
        b.enqueue(1, 5000); // budget is 1000
        b.enqueue(2, 100);
        let batch = b.next_batch();
        assert_eq!(batch.prefills, vec![(1, 5000)]);
        let batch2 = b.next_batch();
        assert_eq!(batch2.prefills, vec![(2, 100)]);
    }

    #[test]
    fn max_prefills_cap() {
        let mut b = Batcher::new(policy());
        for s in 0..5u64 {
            b.enqueue(s, 10);
        }
        let batch = b.next_batch();
        assert_eq!(batch.prefills.len(), 2);
        assert_eq!(b.waiting_len(), 3);
    }

    #[test]
    fn requeue_preserves_order() {
        let mut b = Batcher::new(policy());
        b.enqueue(1, 400);
        b.enqueue(2, 400);
        let batch = b.next_batch();
        assert_eq!(batch.prefills.len(), 2);
        // Admission of 2 failed (e.g. KV pool full) — requeue.
        b.requeue(2, 400);
        let batch2 = b.next_batch();
        assert_eq!(batch2.prefills, vec![(2, 400)]);
    }

    #[test]
    fn finished_removes_from_running() {
        let mut b = Batcher::new(policy());
        b.started(1);
        b.started(2);
        b.finished(1);
        assert_eq!(b.running_len(), 1);
        assert_eq!(b.next_batch().decodes, vec![2]);
    }
}
