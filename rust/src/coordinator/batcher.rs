//! Continuous batcher: assembles each scheduler iteration's work — which
//! waiting requests to prefill (token-budgeted) and which running
//! sequences to step (batch-size-capped), decode-priority so tokens keep
//! streaming while prefills are amortized (the Orca/vLLM policy).
//!
//! Under memory pressure the batcher is the policy layer:
//!
//! - **Per-class queues with weighted admission.** Waiting requests
//!   queue by [`Priority`] class; prefill slots are handed out by a
//!   weighted round-robin credit scheme (interactive 4 : normal 2 :
//!   batch 1), so latency-sensitive traffic goes first without ever
//!   starving background work.
//! - **Chunked prefill.** A context longer than the remaining token
//!   budget is offered as a budget-sized *chunk*; the scheduler feeds
//!   the chunk to the engine's resumable partial prefill and parks the
//!   remainder on the continuation queue, which is always served first
//!   next iteration (a partial holds committed pages — finishing it is
//!   the fastest way to relieve contention). This retires the old
//!   first-prefill budget exemption: long prefills now interleave with
//!   running decodes instead of monopolizing an iteration.
//! - **Bounded waiting.** `try_enqueue` refuses work past
//!   [`BatchPolicy::max_waiting`]; the scheduler sheds the refused
//!   request with a typed `queue_full` completion instead of letting
//!   the queue grow without limit.
//! - **Indexed membership.** `finished`/shed removal are O(1) map
//!   updates; queue entries they orphan are skipped lazily during
//!   assembly, so per-iteration cost stays flat at large running and
//!   waiting sets (the old `retain` walked every running sequence per
//!   completion).

use crate::workload::trace::Priority;
use std::collections::{HashMap, VecDeque};

/// Prefill slots granted per replenish, by class index (batch, normal,
/// interactive): the weighted-admission ratio under saturation.
const CLASS_WEIGHT: [usize; 3] = [1, 2, 4];

/// Batch assembly policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max sequences stepped per iteration.
    pub max_decode_batch: usize,
    /// Max prefill tokens admitted per iteration.
    pub prefill_token_budget: usize,
    /// Max prefill jobs (fresh or chunk continuations) per iteration.
    pub max_prefills: usize,
    /// Bound on the waiting queue across all classes; submissions past
    /// it are shed with a `queue_full` error completion.
    pub max_waiting: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_decode_batch: 16,
            prefill_token_budget: 8192,
            max_prefills: 2,
            max_waiting: 1024,
        }
    }
}

/// One iteration's work.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Batch {
    /// (seq_id, chunk_tokens) to prefill. `chunk_tokens` is the number
    /// of *new* context tokens to make resident this iteration — the
    /// full context for small requests, a budget-sized slice of it for
    /// chunked ones.
    pub prefills: Vec<(u64, usize)>,
    /// Sequences to run one decode step.
    pub decodes: Vec<u64>,
}

impl Batch {
    pub fn is_empty(&self) -> bool {
        self.prefills.is_empty() && self.decodes.is_empty()
    }
}

/// A waiting request: how many context tokens remain to prefill, and
/// whether it must be offered whole (resumed session turns — a
/// `session_extend` appends in one shot, so it follows the old
/// offered-alone exemption instead of chunking).
#[derive(Clone, Copy, Debug)]
struct WaitEntry {
    seq: u64,
    remaining: usize,
    whole: bool,
}

/// Queue state + assembly. The batcher owns the waiting queues and the
/// running set; the scheduler feeds admission outcomes and completions
/// back.
#[derive(Debug, Default)]
pub struct Batcher {
    pub policy: BatchPolicy,
    /// Per-class FIFO queues, indexed by [`Priority::index`]. May hold
    /// stale entries for shed requests — `waiting` is authoritative.
    classes: [VecDeque<WaitEntry>; 3],
    /// Live waiting membership: seq -> class index. O(1) shed/lookup.
    waiting: HashMap<u64, usize>,
    /// Weighted round-robin credits per class (replenished from
    /// [`CLASS_WEIGHT`] when every available class is spent).
    credits: [usize; 3],
    /// Partially-prefilled sequences awaiting their next chunk
    /// (seq, remaining tokens). Served before any class queue.
    continuations: VecDeque<(u64, usize)>,
    /// Decode rotation order. May hold stale (finished/preempted)
    /// entries — `running` epochs below are authoritative.
    rotation: VecDeque<(u64, u64)>,
    /// Live running membership: seq -> the epoch of its current run.
    /// A re-started sequence (preempt → readmit) gets a fresh epoch, so
    /// its stale rotation entry can never double-step it.
    running: HashMap<u64, u64>,
    next_epoch: u64,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { policy, ..Batcher::default() }
    }

    /// Accept a request into its class queue, or refuse it when the
    /// waiting set is at [`BatchPolicy::max_waiting`] (the caller sheds
    /// it with a `queue_full` completion). Continuations and running
    /// sequences don't count against the bound — they already hold
    /// committed pages.
    #[must_use]
    pub fn try_enqueue(&mut self, seq: u64, context_len: usize, prio: Priority, whole: bool) -> bool {
        if self.waiting.len() >= self.policy.max_waiting {
            return false;
        }
        let c = prio.index();
        self.waiting.insert(seq, c);
        self.classes[c].push_back(WaitEntry { seq, remaining: context_len, whole });
        true
    }

    /// Requeue a prefill that failed admission (backpressure) or was
    /// preempted — goes to the *front* of its class to preserve FIFO
    /// fairness within the class. Never bounced: the request was
    /// already accepted once.
    pub fn requeue(&mut self, seq: u64, context_len: usize, prio: Priority, whole: bool) {
        let c = prio.index();
        self.waiting.insert(seq, c);
        self.classes[c].push_front(WaitEntry { seq, remaining: context_len, whole });
    }

    /// Park a partially-prefilled sequence until the next iteration
    /// offers its next chunk. Continuations outrank every class queue.
    pub fn continue_prefill(&mut self, seq: u64, remaining: usize) {
        self.continuations.push_back((seq, remaining));
    }

    /// Drop a request from the waiting set (deadline shed). Returns
    /// whether it was actually waiting — running sequences and chunk
    /// continuations are not sheddable. O(1): the queue entry goes
    /// stale and is skipped during assembly.
    pub fn remove_waiting(&mut self, seq: u64) -> bool {
        self.waiting.remove(&seq).is_some()
    }

    /// Waiting requests plus chunk continuations — everything that
    /// still needs prefill work before it can decode.
    pub fn waiting_len(&self) -> usize {
        self.waiting.len() + self.continuations.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Live running sequence ids, unordered — the scheduler's victim
    /// scan for priority preemption.
    pub fn running_seqs(&self) -> Vec<u64> {
        self.running.keys().copied().collect()
    }

    /// Mark a prefilled sequence as running.
    pub fn started(&mut self, seq: u64) {
        let epoch = self.next_epoch;
        self.next_epoch += 1;
        self.running.insert(seq, epoch);
        self.rotation.push_back((seq, epoch));
    }

    /// Remove a finished (or preempted) sequence. O(1): its rotation
    /// entry goes stale and is dropped during assembly.
    pub fn finished(&mut self, seq: u64) {
        self.running.remove(&seq);
    }

    /// Drop stale entries (shed requests) off the front of class `c`.
    fn skim(&mut self, c: usize) {
        while let Some(e) = self.classes[c].front() {
            if self.waiting.get(&e.seq) == Some(&c) {
                return;
            }
            self.classes[c].pop_front();
        }
    }

    /// Pick the class to draw the next prefill from: the highest class
    /// with an offerable head and a credit, replenishing all credits
    /// when every available class is spent. A `whole` head longer than
    /// the remaining budget is only offerable as the iteration's first
    /// prefill (the resumed-turn exemption); it blocks its class
    /// otherwise, exactly like the old FIFO head did.
    fn pick_class(&mut self, budget: usize, first: bool) -> Option<usize> {
        let mut avail = [false; 3];
        let mut any = false;
        for c in 0..3 {
            self.skim(c);
            if let Some(e) = self.classes[c].front() {
                avail[c] = !e.whole || e.remaining <= budget || first;
                any |= avail[c];
            }
        }
        if !any {
            return None;
        }
        for _ in 0..2 {
            for c in (0..3).rev() {
                if avail[c] && self.credits[c] > 0 {
                    self.credits[c] -= 1;
                    return Some(c);
                }
            }
            self.credits = CLASS_WEIGHT;
        }
        unreachable!("an available class must win after a credit replenish")
    }

    /// Assemble the next iteration's batch. Decode-priority: running
    /// sequences always step (round-robin rotation for fairness across
    /// iterations); prefill slots go to chunk continuations first, then
    /// to the class queues under the weighted credit scheme, all inside
    /// the shared token budget.
    pub fn next_batch(&mut self) -> Batch {
        let mut batch = Batch::default();
        // Decodes: up to max_decode_batch live sequences, rotating so
        // all progress even when running > batch size. Stale rotation
        // entries (finished/preempted) drop out here.
        let quota = self.running.len().min(self.policy.max_decode_batch);
        while batch.decodes.len() < quota {
            let Some((seq, epoch)) = self.rotation.pop_front() else { break };
            if self.running.get(&seq) != Some(&epoch) {
                continue; // stale: finished, or re-started under a new epoch
            }
            batch.decodes.push(seq);
            self.rotation.push_back((seq, epoch));
        }
        // Prefills under the shared token budget: continuations first.
        let mut budget = self.policy.prefill_token_budget;
        while batch.prefills.len() < self.policy.max_prefills && budget > 0 {
            let Some(&(seq, remaining)) = self.continuations.front() else { break };
            self.continuations.pop_front();
            let chunk = remaining.min(budget);
            budget -= chunk;
            batch.prefills.push((seq, chunk));
        }
        // Then the class queues, weighted-round-robin.
        while batch.prefills.len() < self.policy.max_prefills && budget > 0 {
            let Some(c) = self.pick_class(budget, batch.prefills.is_empty()) else { break };
            let e = self.classes[c].pop_front().expect("pick_class saw a head");
            self.waiting.remove(&e.seq);
            let chunk = if e.whole { e.remaining } else { e.remaining.min(budget) };
            budget = budget.saturating_sub(chunk);
            batch.prefills.push((e.seq, chunk));
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BatchPolicy {
        BatchPolicy {
            max_decode_batch: 2,
            prefill_token_budget: 1000,
            max_prefills: 2,
            max_waiting: 1024,
        }
    }

    fn enq(b: &mut Batcher, seq: u64, ctx: usize) {
        assert!(b.try_enqueue(seq, ctx, Priority::Normal, false));
    }

    #[test]
    fn decode_priority_and_rotation() {
        let mut b = Batcher::new(policy());
        for s in 0..3u64 {
            b.started(s);
        }
        let b1 = b.next_batch();
        assert_eq!(b1.decodes, vec![0, 1]);
        let b2 = b.next_batch();
        assert_eq!(b2.decodes, vec![2, 0], "round-robin rotation");
    }

    #[test]
    fn prefill_token_budget_chunks_the_overflow() {
        let mut b = Batcher::new(policy());
        enq(&mut b, 1, 600);
        enq(&mut b, 2, 600); // overflows the 1000 budget -> 400-token chunk
        enq(&mut b, 3, 100);
        let batch = b.next_batch();
        assert_eq!(batch.prefills, vec![(1, 600), (2, 400)]);
        // The engine reports 200 tokens still unfilled; the scheduler
        // parks the remainder as a continuation.
        b.continue_prefill(2, 200);
        let batch2 = b.next_batch();
        assert_eq!(batch2.prefills, vec![(2, 200), (3, 100)], "continuation outranks the queue");
    }

    #[test]
    fn oversized_context_is_chunked_not_exempted() {
        // Pre-chunking, a 5000-token context was offered alone under a
        // 1000-token budget (the first-prefill exemption). Now it is
        // split into budget-sized chunks that leave room for decodes
        // every iteration.
        let mut b = Batcher::new(policy());
        enq(&mut b, 1, 5000);
        enq(&mut b, 2, 100);
        b.started(9);
        let mut offered = 0usize;
        let mut remaining = 5000usize;
        for _ in 0..5 {
            let batch = b.next_batch();
            assert_eq!(batch.decodes, vec![9], "decodes never stall behind the long prefill");
            let &(seq, chunk) = batch.prefills.first().expect("a chunk every iteration");
            assert_eq!(seq, 1);
            assert!(chunk <= 1000, "chunk {chunk} exceeds the budget");
            offered += chunk;
            remaining -= chunk;
            if remaining > 0 {
                b.continue_prefill(1, remaining);
            }
        }
        assert_eq!(offered, 5000, "the whole context is offered across iterations");
        let batch = b.next_batch();
        assert_eq!(batch.prefills, vec![(2, 100)], "queue drains after the chunked prefill");
    }

    #[test]
    fn whole_entries_keep_the_offered_alone_exemption() {
        // Resumed session turns extend in one shot; an over-budget one
        // is offered alone (first slot of its iteration), like the old
        // exemption — never chunked.
        let mut b = Batcher::new(policy());
        assert!(b.try_enqueue(1, 5000, Priority::Normal, true));
        enq(&mut b, 2, 100);
        let batch = b.next_batch();
        assert_eq!(batch.prefills, vec![(1, 5000)], "whole entry offered alone, unchunked");
        assert_eq!(b.next_batch().prefills, vec![(2, 100)]);
    }

    #[test]
    fn max_prefills_cap() {
        let mut b = Batcher::new(policy());
        for s in 0..5u64 {
            enq(&mut b, s, 10);
        }
        let batch = b.next_batch();
        assert_eq!(batch.prefills.len(), 2);
        assert_eq!(b.waiting_len(), 3);
    }

    #[test]
    fn requeue_preserves_order_within_class() {
        let mut b = Batcher::new(policy());
        enq(&mut b, 1, 400);
        enq(&mut b, 2, 400);
        let batch = b.next_batch();
        assert_eq!(batch.prefills.len(), 2);
        // Admission of 2 failed (e.g. KV pool full) — requeue.
        b.requeue(2, 400, Priority::Normal, false);
        let batch2 = b.next_batch();
        assert_eq!(batch2.prefills, vec![(2, 400)]);
    }

    #[test]
    fn finished_removes_from_running() {
        let mut b = Batcher::new(policy());
        b.started(1);
        b.started(2);
        b.finished(1);
        assert_eq!(b.running_len(), 1);
        assert_eq!(b.next_batch().decodes, vec![2]);
    }

    #[test]
    fn restarted_sequence_is_stepped_exactly_once() {
        // Preempt → readmit leaves a stale rotation entry under the old
        // epoch; the fresh epoch must be the only one that steps.
        let mut b = Batcher::new(policy());
        b.started(1);
        b.started(2);
        b.finished(1); // preempted
        b.started(1); // readmitted
        let batch = b.next_batch();
        let mut decodes = batch.decodes.clone();
        decodes.sort_unstable();
        assert_eq!(decodes, vec![1, 2], "each live sequence steps exactly once");
    }

    #[test]
    fn waiting_queue_is_bounded() {
        let mut b = Batcher::new(BatchPolicy { max_waiting: 2, ..policy() });
        assert!(b.try_enqueue(1, 10, Priority::Normal, false));
        assert!(b.try_enqueue(2, 10, Priority::Interactive, false));
        assert!(!b.try_enqueue(3, 10, Priority::Interactive, false), "over max_waiting");
        // Requeues bypass the bound (already-accepted work).
        b.requeue(4, 10, Priority::Batch, false);
        assert_eq!(b.waiting_len(), 3);
    }

    #[test]
    fn shed_requests_are_skipped_lazily() {
        let mut b = Batcher::new(policy());
        enq(&mut b, 1, 100);
        enq(&mut b, 2, 100);
        assert!(b.remove_waiting(1), "waiting request is sheddable");
        assert!(!b.remove_waiting(1), "second shed is a no-op");
        assert_eq!(b.waiting_len(), 1);
        assert_eq!(b.next_batch().prefills, vec![(2, 100)], "stale head skipped");
    }

    #[test]
    fn weighted_admission_prefers_interactive_without_starving_batch() {
        let mut b = Batcher::new(BatchPolicy { max_prefills: 1, ..policy() });
        for s in 0..16u64 {
            assert!(b.try_enqueue(s, 10, Priority::Interactive, false));
            assert!(b.try_enqueue(100 + s, 10, Priority::Normal, false));
            assert!(b.try_enqueue(200 + s, 10, Priority::Batch, false));
        }
        let mut picks = [0usize; 3];
        for _ in 0..14 {
            let batch = b.next_batch();
            let &(seq, _) = batch.prefills.first().expect("one pick per iteration");
            let class = if seq >= 200 { 0 } else if seq >= 100 { 1 } else { 2 };
            picks[class] += 1;
        }
        // Two full credit cycles of 4:2:1.
        assert_eq!(picks, [2, 4, 8], "weighted round-robin must hold under saturation");
    }

    #[test]
    fn drained_class_cedes_its_credits() {
        let mut b = Batcher::new(BatchPolicy { max_prefills: 1, ..policy() });
        assert!(b.try_enqueue(1, 10, Priority::Batch, false));
        assert!(b.try_enqueue(2, 10, Priority::Batch, false));
        // No interactive/normal traffic: batch is served immediately,
        // not held hostage to absent higher classes.
        assert_eq!(b.next_batch().prefills, vec![(1, 10)]);
        assert_eq!(b.next_batch().prefills, vec![(2, 10)]);
    }
}
