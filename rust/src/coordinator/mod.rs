//! The serving coordinator (Layer 3): request router, continuous
//! batcher and prefill/decode scheduler over the SOCKET sparse-attention
//! engine — the vLLM-router-shaped system the paper's efficiency section
//! (GPT-Fast + custom scoring kernel) corresponds to.
//!
//! Dataflow:
//!
//! ```text
//! submit() ─→ [router queue] ─→ scheduler loop (worker thread)
//!                 │   admit: prefill (hash K/V, Alg. 1; paged KV store)
//!                 │   step:  continuous batch of decode-ready seqs
//!                 │          soft-hash q (Alg. 2) → score+top-k (Alg. 3/4)
//!                 │          → flash-decode over selected ∪ sink ∪ local
//!                 └─→ completion channel → RequestHandle::wait()
//! ```

pub mod batcher;
pub mod engine;
pub mod scheduler;

pub use batcher::{Batch, BatchPolicy, Batcher};
pub use engine::{AttentionMode, DecodeEngine, EngineConfig};
pub use scheduler::{Completion, Coordinator, RequestHandle, SchedulerStats};
