//! The serving coordinator (Layer 3): request router, continuous
//! batcher and prefill/decode scheduler over the SOCKET sparse-attention
//! engine — the vLLM-router-shaped system the paper's efficiency section
//! (GPT-Fast + custom scoring kernel) corresponds to.
//!
//! Dataflow:
//!
//! ```text
//! submit() ─→ [router queue] ─→ scheduler loop (worker thread)
//!                 │   admit: prefill (paged KV store + the request's
//!                 │          selector index, built over the pool view —
//!                 │          any `selector::registry` method, per request)
//!                 │   step:  continuous batch of decode-ready seqs
//!                 │          selector.select_into (per-worker scratch)
//!                 │          → flash-decode over selected ∪ sink ∪ local
//!                 │          → extend KV pages + selector index
//!                 └─→ completion channel → RequestHandle::wait()
//! ```

pub mod batcher;
pub mod engine;
pub mod scheduler;

pub use batcher::{Batch, BatchPolicy, Batcher};
pub use engine::{AttentionMode, DecodeEngine, EngineConfig, PrefixStats};
pub use scheduler::{
    Completion, Coordinator, EngineSnapshot, RequestHandle, SchedulerStats, Submission, TokenEvent,
};
