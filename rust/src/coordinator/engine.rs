//! The per-sequence decode engine: owns the paged KV cache and the
//! per-sequence selector indexes, executes prefill and single-token
//! decode steps. One engine serves many sequences (state is
//! per-sequence), and *any* registered selection method is servable —
//! per request — over the same zero-copy paged hot path.

use crate::attention::{flash_decode_into, SelectionPolicy};
use crate::kvcache::{PageTable, PagedKvCache, PrefixTree, PromptSpec, PAGE_TOKENS};
use crate::lsh::{HashBlock, LshParams, PruneStats, BLOCK_TOKENS};
use crate::model::{ModelConfig, SyntheticModel};
use crate::selector::{self, Selector, SelectorConfig, SelectorError};
#[cfg(test)]
use crate::testing::faults::{FaultInjector, FaultPlan};
use crate::util::pool::with_decode_scratch;
use std::collections::HashMap;
use std::sync::Arc;

pub use crate::selector::AttentionMode;

/// Pages per selector hash block (64-token blocks over 16-token pages):
/// a prefix-shared page run on a block boundary also shares its frozen
/// hash block through the tree.
const PAGES_PER_BLOCK: usize = BLOCK_TOKENS / PAGE_TOKENS;

/// Seed for the per-head selector hyperplanes. Content-independent (no
/// `seq_id` folded in) so that two requests hashing the same key
/// content produce bit-identical hash blocks — the invariant that lets
/// the prefix cache share frozen blocks across sequences. Per-head
/// variation keeps GQA streams' tables independent.
const SELECTOR_SEED: u64 = 0x50C4_E701;

/// Prefix-cache telemetry, drained by the scheduler into the metrics
/// registry after each prefill wave.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixStats {
    /// Cache-enabled prompted prefills that consulted the tree.
    pub lookups: usize,
    /// Lookups that shared at least one page.
    pub hits: usize,
    /// Pages mapped from the tree instead of being recomputed
    /// (across kv heads).
    pub shared_pages: usize,
    /// Pages written privately by cache-enabled prompted prefills
    /// (across kv heads).
    pub private_pages: usize,
    /// Context tokens whose prefill attention + hashing were skipped
    /// (request-level, not multiplied by kv heads).
    pub tokens_saved: usize,
    /// Frozen selector hash blocks attached instead of re-hashed
    /// (across kv heads).
    pub hash_blocks_reused: usize,
}

impl PrefixStats {
    pub fn absorb(&mut self, other: PrefixStats) {
        self.lookups += other.lookups;
        self.hits += other.hits;
        self.shared_pages += other.shared_pages;
        self.private_pages += other.private_pages;
        self.tokens_saved += other.tokens_saved;
        self.hash_blocks_reused += other.hash_blocks_reused;
    }
}

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub model: ModelConfig,
    pub lsh: LshParams,
    /// Default attention mode; requests may override per sequence.
    pub mode: AttentionMode,
    /// Paged-KV pool capacity (pages shared across sequences).
    pub capacity_pages: usize,
    pub sink: usize,
    pub local: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            model: ModelConfig::tiny(),
            lsh: LshParams::paper_default(),
            mode: AttentionMode::socket(33.0),
            capacity_pages: 16 * 1024,
            sink: 64,
            local: 64,
        }
    }
}

/// Per-sequence state: one KV page table per kv-head stream, plus —
/// for sparse modes — one selector index per stream, built at prefill
/// from the paged view and *extended* per decoded token (single
/// representative layer — the decode cost of all layers scales linearly
/// and is reported as such).
struct SequenceState {
    tables: Vec<PageTable>,
    /// One selector per kv-head stream; empty in dense mode.
    selectors: Vec<Box<dyn Selector>>,
    /// The resolved mode this sequence attends under.
    mode: AttentionMode,
    model: SyntheticModel,
    decoded: usize,
}

/// The read-only half of a decode step: per-*query-head* attention
/// outputs (`n_heads` of them — the GQA group of each kv head attends
/// through its shared KV stream) plus the new token's (key, value) per
/// *kv head*, ready to be committed.
struct StepResult {
    outputs: Vec<Vec<f32>>,
    appends: Vec<(Vec<f32>, Vec<f32>)>,
}

/// Outcome of one [`DecodeEngine::prefill_chunk`] call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefillProgress {
    /// The pool cannot cover the full commitment (backpressure, or a
    /// forced test fault). Nothing was committed; the caller requeues —
    /// or preempts a lower-priority sequence and retries.
    Rejected,
    /// The chunk was applied; `filled` of `context_len` tokens are now
    /// resident. Call again next iteration with the next chunk budget.
    InProgress { filled: usize },
    /// The full context is resident and the sequence is decodable.
    Complete,
}

/// A prefill paused between chunks. Pages for the *whole* context plus
/// decode headroom were committed up front (admission happens once, on
/// the first chunk), so continuation appends can never fail; tree
/// publication is deferred to completion so no partially written page
/// is ever shared.
struct PartialPrefill {
    tables: Vec<PageTable>,
    selectors: Vec<Box<dyn Selector>>,
    mode: AttentionMode,
    model: SyntheticModel,
    context_len: usize,
    /// Context tokens resident so far (shared-mapped + generated).
    filled: usize,
    /// Owned prompt for deferred tree publication.
    prompt: Option<PromptSpec>,
    /// Shared-prefix walk results from the first chunk, replayed at
    /// publication time.
    path: Vec<usize>,
    tail_node: Option<usize>,
    /// Frozen hash blocks completed by the first chunk's index build
    /// (later chunks extend the index token-at-a-time; their blocks are
    /// simply not published — a sharing-efficiency tradeoff, not a
    /// correctness one).
    published: Vec<Vec<(usize, Arc<HashBlock>)>>,
    use_cache: bool,
}

/// The decode engine: paged KV pool + per-sequence selector indexes.
pub struct DecodeEngine {
    pub config: EngineConfig,
    kv: PagedKvCache,
    sequences: HashMap<u64, SequenceState>,
    /// Pages committed to admitted sequences (context + decode
    /// headroom) — admission control that guarantees decode appends
    /// never hit an exhausted pool.
    committed_pages: usize,
    /// Per-sequence committed page count (for release bookkeeping).
    commitments: HashMap<u64, usize>,
    /// Pruning telemetry drained from *released* sequences' selectors
    /// (live ones are scanned on demand by `take_prune_stats`).
    prune_stats: PruneStats,
    /// Radix index over token-aligned prompt prefixes: nodes hold page
    /// refcounts + frozen hash blocks, so prompted requests map shared
    /// pages by incref instead of recomputing prefill.
    tree: PrefixTree,
    /// Prefix-cache telemetry since the last drain.
    prefix_stats: PrefixStats,
    /// Prefills paused between chunks (seq -> resumable state).
    partials: HashMap<u64, PartialPrefill>,
    /// Deterministic admission-failure injection — test builds only;
    /// release hot paths carry no hook.
    #[cfg(test)]
    injector: FaultInjector,
}

impl DecodeEngine {
    pub fn new(config: EngineConfig) -> DecodeEngine {
        // A malformed head layout must fail at construction, not panic
        // mid-serving on the first decode step.
        assert!(
            config.model.n_kv_heads > 0 && config.model.n_heads % config.model.n_kv_heads == 0,
            "n_heads {} must be a multiple of n_kv_heads {}",
            config.model.n_heads,
            config.model.n_kv_heads
        );
        DecodeEngine {
            kv: PagedKvCache::new(config.capacity_pages, config.model.head_dim),
            tree: PrefixTree::new(config.model.n_kv_heads),
            config,
            sequences: HashMap::new(),
            committed_pages: 0,
            commitments: HashMap::new(),
            prune_stats: PruneStats::default(),
            prefix_stats: PrefixStats::default(),
            partials: HashMap::new(),
            #[cfg(test)]
            injector: FaultInjector::default(),
        }
    }

    /// Arm a deterministic admission-failure plan (test builds only).
    /// The next matching `prefill_chunk` admissions report
    /// [`PrefillProgress::Rejected`] as if the pool were exhausted.
    #[cfg(test)]
    pub fn inject_faults(&mut self, plan: FaultPlan) {
        self.injector.arm(plan);
    }

    /// Forced admission failures delivered so far (test builds only).
    #[cfg(test)]
    pub fn faults_fired(&self) -> u64 {
        self.injector.fired()
    }

    pub fn n_sequences(&self) -> usize {
        self.sequences.len()
    }

    pub fn free_pages(&self) -> usize {
        self.kv.free_pages()
    }

    /// Whether a request of this shape can *ever* be admitted: its full
    /// page commitment must fit an empty pool. The scheduler rejects
    /// inadmissible requests up front with a failed completion instead
    /// of requeueing them forever (no running sequence can release
    /// enough pages to make them fit).
    pub fn admissible(&self, context_len: usize, max_new_tokens: usize) -> bool {
        self.config.model.n_kv_heads * PagedKvCache::pages_for(context_len + max_new_tokens)
            <= self.kv.total_pages()
    }

    /// Check that a request's attention mode (or the engine default
    /// when `None`) names a registered selector. The scheduler fails
    /// such requests up front — like inadmissible shapes, they could
    /// never be served.
    pub fn validate_mode(&self, mode: Option<&AttentionMode>) -> Result<(), SelectorError> {
        match mode.unwrap_or(&self.config.mode) {
            AttentionMode::Dense => Ok(()),
            AttentionMode::Sparse { method, .. } => selector::lookup(method).map(|_| ()),
        }
    }

    /// Admit a sequence under the engine's default mode. See
    /// [`DecodeEngine::prefill_as`].
    pub fn prefill(&mut self, seq_id: u64, context_len: usize, max_new_tokens: usize) -> bool {
        self.prefill_as(seq_id, context_len, max_new_tokens, None)
            .expect("engine default mode must name a registered selector")
    }

    /// Admit a sequence: prefill `context_len` tokens (KV pages + the
    /// selector index, built in place over the paged view) and commit
    /// page headroom for up to `max_new_tokens` decode appends. `mode`
    /// overrides the engine default for this sequence — any registered
    /// method is servable per request. `Ok(false)` means the pool
    /// cannot guarantee the commitment (backpressure — caller
    /// requeues); `Err` means the mode names no registered selector
    /// (never admittable; nothing was committed).
    pub fn prefill_as(
        &mut self,
        seq_id: u64,
        context_len: usize,
        max_new_tokens: usize,
        mode: Option<&AttentionMode>,
    ) -> Result<bool, SelectorError> {
        self.prefill_opts(seq_id, context_len, max_new_tokens, mode, None)
    }

    /// [`DecodeEngine::prefill_as`] with an optional [`PromptSpec`]
    /// declaring the prompt's content segments. A prompted request is
    /// eligible for prefix sharing (unless its spec opts out): pages
    /// whose content matches a resident tree prefix are *mapped* by
    /// incref instead of recomputed — skipping their K/V generation,
    /// prefill attention, and (on hash-block boundaries) Algorithm-1
    /// hashing — and the request's own freshly written full pages are
    /// published back to the tree. Decode outputs are bit-identical to
    /// an isolated build: shared pages hold exactly the bytes the
    /// request would have written, and appends onto a shared tail page
    /// copy it private first (pool COW).
    pub fn prefill_opts(
        &mut self,
        seq_id: u64,
        context_len: usize,
        max_new_tokens: usize,
        mode: Option<&AttentionMode>,
        prompt: Option<&PromptSpec>,
    ) -> Result<bool, SelectorError> {
        match self.prefill_chunk(seq_id, context_len, max_new_tokens, mode, prompt, usize::MAX)? {
            PrefillProgress::Rejected => Ok(false),
            PrefillProgress::Complete => Ok(true),
            PrefillProgress::InProgress { .. } => unreachable!("unbounded chunk must complete"),
        }
    }

    /// Chunked prefill: make at most `max_tokens` further context tokens
    /// resident this call, resuming a paused partial if one exists for
    /// `seq_id`. The first call does everything irreversible once —
    /// prefix-tree walk, shared-page mapping, admission of the *full*
    /// commitment (context + decode headroom, so continuations never
    /// fail), model + selector construction — and later calls only
    /// append K/V + extend the index, which is bit-identical to a
    /// one-shot build (the same append path session resume uses). Tree
    /// publication waits for completion so no half-written page is ever
    /// shared. Shared-mapped tokens are free and don't count against
    /// `max_tokens`.
    pub fn prefill_chunk(
        &mut self,
        seq_id: u64,
        context_len: usize,
        max_new_tokens: usize,
        mode: Option<&AttentionMode>,
        prompt: Option<&PromptSpec>,
        max_tokens: usize,
    ) -> Result<PrefillProgress, SelectorError> {
        assert!(max_tokens > 0, "a chunk must make progress");
        if self.partials.contains_key(&seq_id) {
            return Ok(self.continue_chunk(seq_id, max_tokens));
        }
        let mode = mode.unwrap_or(&self.config.mode).clone();
        // Resolve the method before committing any pages.
        let spec = match &mode {
            AttentionMode::Dense => None,
            AttentionMode::Sparse { method, .. } => Some(selector::lookup(method)?),
        };
        let heads = self.config.model.n_kv_heads;
        let prompt = match prompt {
            Some(p) if !p.segments.is_empty() => {
                assert_eq!(
                    p.total_len(),
                    context_len,
                    "prompt segments must cover the context exactly"
                );
                Some(p)
            }
            _ => None,
        };
        let use_cache = matches!(prompt, Some(p) if p.cache);
        let full_pages = context_len / PAGE_TOKENS;
        let tail_tokens = context_len % PAGE_TOKENS;

        // Walk the tree for the longest resident page-aligned prefix,
        // plus a shareable frozen partial tail when every full page
        // matched.
        let path: Vec<usize> = match prompt {
            Some(p) if use_cache => self.tree.walk(p, full_pages),
            _ => Vec::new(),
        };
        let shared_full = path.len();
        let tail_node = match prompt {
            Some(p) if use_cache && tail_tokens > 0 && shared_full == full_pages => {
                self.tree.partial_tail(path.last().copied(), p, full_pages, tail_tokens)
            }
            _ => None,
        };

        // Map the shared run into per-head tables *before* admission:
        // the increfs pin these pages against LRU eviction below.
        let mut tables: Vec<PageTable> = (0..heads).map(|_| PageTable::default()).collect();
        for (h, table) in tables.iter_mut().enumerate() {
            for &node in &path {
                let page = self.tree.node_pages(node)[h];
                self.kv.map_shared(table, page, PAGE_TOKENS);
            }
            if let Some(tn) = tail_node {
                let page = self.tree.node_pages(tn)[h];
                self.kv.map_shared(table, page, tail_tokens);
            }
        }

        // Admission: shared full pages ride the tree's references, so
        // they come off the request's commitment; the tail page stays
        // committed as the COW reserve. `held_refs` conservatively
        // charges every tree page (including ones also inside live
        // commitments) — an underestimate of availability, never an
        // overestimate.
        let needed = heads * (PagedKvCache::pages_for(context_len + max_new_tokens) - shared_full);
        let mut available =
            self.kv.total_pages().saturating_sub(self.committed_pages + self.tree.held_refs());
        if available < needed {
            // Pool pressure: evict least-recently-hit tree leaves no
            // live sequence maps (the run we just pinned is ref >= 2
            // and therefore safe).
            self.tree.evict_lru(&mut self.kv, needed - available);
            available =
                self.kv.total_pages().saturating_sub(self.committed_pages + self.tree.held_refs());
        }
        // Deterministic fault hook: a forced failure takes the exact
        // path a real shortfall takes (release the mapped run, report
        // Rejected) — test builds only.
        #[cfg(test)]
        let forced = self.injector.should_fail(seq_id);
        #[cfg(not(test))]
        let forced = false;
        if forced || available < needed {
            for table in tables.iter_mut() {
                self.kv.release(table);
            }
            return Ok(PrefillProgress::Rejected);
        }
        self.committed_pages += needed;
        self.commitments.insert(seq_id, needed);

        let tail_seed = seq_id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let model = match prompt {
            // Prompted content streams from the spec's segment seeds
            // (identical across requests sharing a prefix); queries and
            // decoded tokens keep the per-sequence tail seed.
            Some(p) => SyntheticModel::with_segments(self.config.model, &p.segment_pairs(), tail_seed),
            None => SyntheticModel::new(self.config.model, tail_seed),
        };
        // The first chunk covers the shared run (free) plus up to
        // `max_tokens` generated tokens.
        let shared_start = tables[0].n_tokens;
        let end = context_len.min(shared_start.saturating_add(max_tokens));
        let mut selectors = Vec::with_capacity(heads);
        let mut published: Vec<Vec<(usize, Arc<HashBlock>)>> = Vec::with_capacity(heads);
        for (h, table) in tables.iter_mut().enumerate() {
            let start = table.n_tokens;
            if start == 0 {
                let (keys, values) = model.kv_matrix(h, end);
                let written = self.kv.append_many(table, &keys.data, &values.data);
                debug_assert_eq!(written, end);
            } else {
                // Generate and append only past the shared run.
                for t in start..end {
                    let (k, v) = model.kv_at(h, t);
                    let ok = self.kv.append(table, &k, &v);
                    assert!(ok, "KV pool exhausted during prefill (commitment violated)");
                }
            }
            if let Some(spec) = spec {
                // Paged-native prefill (Alg. 1 for SOCKET; page
                // min/max, PQ codes, channel stats... for the rest):
                // the index is built straight off the pool view — the
                // same bytes the decode kernels read — and extended per
                // decoded token thereafter, never rebuilt.
                let cfg = SelectorConfig::new(
                    self.config.model.head_dim,
                    SELECTOR_SEED ^ ((h as u64) << 11),
                )
                .with_lsh(self.config.lsh);
                let mut s = (spec.build)(&cfg);
                if use_cache {
                    // The contiguous run of frozen hash blocks carried
                    // by the shared path (one per 4 pages) attaches by
                    // handle; only the remainder is hashed.
                    let mut shared_blocks: Vec<Arc<HashBlock>> = Vec::new();
                    for b in 0.. {
                        let page_idx = b * PAGES_PER_BLOCK + PAGES_PER_BLOCK - 1;
                        let Some(&node) = path.get(page_idx) else { break };
                        let Some(blk) = self.tree.hash_block(node, h) else { break };
                        shared_blocks.push(blk);
                    }
                    self.prefix_stats.hash_blocks_reused += shared_blocks.len();
                    published.push(s.build_shared(&self.kv.view(table), &shared_blocks));
                } else {
                    s.build(&self.kv.view(table));
                }
                selectors.push(s);
            }
        }

        let partial = PartialPrefill {
            tables,
            selectors,
            mode,
            model,
            context_len,
            filled: end,
            prompt: prompt.cloned(),
            path,
            tail_node,
            published,
            use_cache,
        };
        if end < context_len {
            self.partials.insert(seq_id, partial);
            return Ok(PrefillProgress::InProgress { filled: end });
        }
        self.finish_partial(seq_id, partial);
        Ok(PrefillProgress::Complete)
    }

    /// Append the next chunk of a paused prefill. Admission already
    /// covered the whole context, so appends cannot fail; the selector
    /// index extends token-at-a-time exactly like session resume.
    fn continue_chunk(&mut self, seq_id: u64, max_tokens: usize) -> PrefillProgress {
        let mut p = self.partials.remove(&seq_id).expect("continue_chunk without a partial");
        let end = p.context_len.min(p.filled.saturating_add(max_tokens));
        for (h, table) in p.tables.iter_mut().enumerate() {
            for t in p.filled..end {
                let (k, v) = p.model.kv_at(h, t);
                let ok = self.kv.append(table, &k, &v);
                assert!(ok, "KV pool exhausted during chunked prefill (commitment violated)");
                if let Some(s) = p.selectors.get_mut(h) {
                    s.append(&k, &v).expect("selector index built at first chunk");
                }
            }
        }
        p.filled = end;
        if end < p.context_len {
            self.partials.insert(seq_id, p);
            return PrefillProgress::InProgress { filled: end };
        }
        self.finish_partial(seq_id, p);
        PrefillProgress::Complete
    }

    /// Completion of a prefill (one-shot or final chunk): publish the
    /// freshly written pages to the prefix tree, record cache telemetry,
    /// and install the decodable sequence state.
    fn finish_partial(&mut self, seq_id: u64, p: PartialPrefill) {
        debug_assert_eq!(p.filled, p.context_len);
        let heads = self.config.model.n_kv_heads;
        let full_pages = p.context_len / PAGE_TOKENS;
        let tail_tokens = p.context_len % PAGE_TOKENS;
        let shared_full = p.path.len();
        if p.use_cache {
            if let Some(spec) = &p.prompt {
                // Publish the missed full pages (and their frozen hash
                // blocks) so later requests share what this one built.
                let mut node_ids = p.path.clone();
                let mut parent = p.path.last().copied();
                for page in shared_full..full_pages {
                    let key = spec.page_key(page).expect("full page inside the covered context");
                    let run: Vec<usize> = p.tables.iter().map(|t| t.pages[page]).collect();
                    let id = self.tree.insert_child(parent, key, &run, &mut self.kv);
                    node_ids.push(id);
                    parent = Some(id);
                }
                // Freeze the partial tail page too (if it wasn't itself
                // shared): the tree's reference makes this sequence's
                // own first decode append copy-on-write, keeping the
                // snapshot immutable for future partial matches.
                if tail_tokens > 0 && p.tail_node.is_none() {
                    let key =
                        spec.tail_key(full_pages, tail_tokens).expect("tail inside the context");
                    let run: Vec<usize> = p.tables.iter().map(|t| t.pages[full_pages]).collect();
                    self.tree.insert_tail(parent, key, tail_tokens, &run, &mut self.kv);
                }
                for (h, frozen) in p.published.iter().enumerate() {
                    for (blk, arc) in frozen {
                        let page_idx = blk * PAGES_PER_BLOCK + PAGES_PER_BLOCK - 1;
                        if let Some(&node) = node_ids.get(page_idx) {
                            self.tree.set_hash_block(node, h, arc.clone());
                        }
                    }
                }
            }
            self.prefix_stats.lookups += 1;
            let tail_shared = usize::from(p.tail_node.is_some());
            if shared_full > 0 || tail_shared > 0 {
                self.prefix_stats.hits += 1;
            }
            let shared_per_head = shared_full + tail_shared;
            self.prefix_stats.shared_pages += heads * shared_per_head;
            self.prefix_stats.private_pages +=
                heads * (PagedKvCache::pages_for(p.context_len) - shared_per_head);
            self.prefix_stats.tokens_saved +=
                shared_full * PAGE_TOKENS + tail_shared * tail_tokens;
        }
        self.sequences.insert(
            seq_id,
            SequenceState {
                tables: p.tables,
                selectors: p.selectors,
                mode: p.mode,
                model: p.model,
                decoded: 0,
            },
        );
    }

    /// One decode step for a sequence; returns the attention outputs
    /// (one per *query* head — each kv head's GQA group is scored in a
    /// single pass over its shared index) and appends the new token's
    /// K/V per kv head. Panics if the sequence was never prefilled.
    pub fn decode_step(&mut self, seq_id: u64) -> Vec<Vec<f32>> {
        let state = self.sequences.get(&seq_id).expect("decode before prefill");
        let computed = self.compute_step(state);
        self.apply_step(seq_id, computed)
    }

    /// One decode step for each sequence in `seq_ids`, with the
    /// compute phase (selector scoring, top-k, attention — all reads)
    /// fanned out across the shared worker pool, then the KV/index
    /// appends committed serially in `seq_ids` order. Outputs are
    /// identical to calling [`DecodeEngine::decode_step`] per sequence.
    pub fn decode_batch(&mut self, seq_ids: &[u64]) -> Vec<Vec<Vec<f32>>> {
        // A duplicated id would compute both steps from the same
        // pre-step snapshot, breaking the serial equivalence.
        debug_assert!(
            {
                let mut ids = seq_ids.to_vec();
                ids.sort_unstable();
                ids.dedup();
                ids.len() == seq_ids.len()
            },
            "decode_batch requires distinct sequence ids"
        );
        let computed: Vec<StepResult> = {
            let eng: &DecodeEngine = &*self;
            crate::util::pool::global().map(seq_ids.len(), |i| {
                let state = eng.sequences.get(&seq_ids[i]).expect("decode before prefill");
                eng.compute_step(state)
            })
        };
        seq_ids.iter().zip(computed).map(|(&seq, result)| self.apply_step(seq, result)).collect()
    }

    /// Query heads sharing each kv head's KV stream (the GQA group).
    /// Divisibility is validated at [`DecodeEngine::new`].
    fn gqa_group(&self) -> usize {
        self.config.model.n_heads / self.config.model.n_kv_heads
    }

    /// Immutable phase of one decode step: per-query-head attention
    /// outputs plus the new token's K/V per kv head, computed without
    /// touching engine state.
    ///
    /// Each kv head serves its whole GQA group in one lane: the group's
    /// queries are selected together (`Selector::select_group_into` —
    /// for SOCKET the pool-parallel branch-and-bound walk, which fans
    /// blocks x lanes across idle workers when this step runs on the
    /// caller thread, and runs inline when `decode_batch` has already
    /// fanned sequences across the pool), then each query head attends
    /// over its own merged selection. Output `g` of kv head `h` lands
    /// at query-head index `h * group + g`.
    fn compute_step(&self, state: &SequenceState) -> StepResult {
        let heads = self.config.model.n_kv_heads;
        let group = self.gqa_group();
        let dim = self.config.model.head_dim;
        let scale = 1.0 / (dim as f32).sqrt();
        let mut outputs = Vec::with_capacity(heads * group);
        let mut appends = Vec::with_capacity(heads);
        // Queries are drawn at the sequence's *absolute* token position,
        // not the per-turn decode counter. The synthetic K/V stream is
        // already purely position-based (`kv_at`), so with position-based
        // queries a resumed session (prefill → decode → session_extend →
        // decode) is bit-identical to a from-scratch prefill over the
        // concatenated context — the property the session tests pin.
        let step = state.tables[0].n_tokens;
        for h in 0..heads {
            let n = state.tables[h].n_tokens;
            let queries: Vec<Vec<f32>> =
                (0..group).map(|g| state.model.query_at(h * group + g, step)).collect();
            // Attend in place over the paged cache: the view addresses
            // pages through the page table, so no K/V row is copied and
            // no dense matrix is allocated per step. Selector scoring
            // and the merged selection live in per-worker scratch.
            let view = self.kv.view(&state.tables[h]);
            match &state.mode {
                AttentionMode::Dense => {
                    for q in &queries {
                        let mut out = Vec::new();
                        flash_decode_into(q, &view, None, scale, &mut out);
                        outputs.push(out);
                    }
                }
                AttentionMode::Sparse { sparsity, .. } => {
                    let policy = SelectionPolicy::from_sparsity(
                        n,
                        *sparsity,
                        self.config.sink,
                        self.config.local,
                    );
                    with_decode_scratch(|scratch| {
                        let sels = scratch.group_selections(group);
                        state.selectors[h]
                            .select_group_into(&queries, policy.k, sels)
                            .expect("selector index built at prefill");
                        for (q, sel) in queries.iter().zip(scratch.selections.iter()) {
                            policy.merge_into(&sel.indices, n, &mut scratch.indices);
                            let mut out = Vec::new();
                            flash_decode_into(q, &view, Some(&scratch.indices), scale, &mut out);
                            outputs.push(out);
                        }
                    });
                }
            }
            appends.push(state.model.kv_at(h, n));
        }
        StepResult { outputs, appends }
    }

    /// Mutable phase: commit the new token's K/V to the paged cache and
    /// extend the selector indexes, advance the decode counter.
    fn apply_step(&mut self, seq_id: u64, result: StepResult) -> Vec<Vec<f32>> {
        let state = self.sequences.get_mut(&seq_id).expect("decode before prefill");
        for (h, (k_new, v_new)) in result.appends.iter().enumerate() {
            let ok = self.kv.append(&mut state.tables[h], k_new, v_new);
            assert!(ok, "KV pool exhausted mid-decode");
            if let Some(s) = state.selectors.get_mut(h) {
                s.append(k_new, v_new).expect("selector index built at prefill");
            }
        }
        state.decoded += 1;
        result.outputs
    }

    pub fn decoded(&self, seq_id: u64) -> usize {
        self.sequences.get(&seq_id).map(|s| s.decoded).unwrap_or(0)
    }

    /// Whether the engine holds state (pages + selector index) for this
    /// sequence — live or parked between session turns.
    pub fn has_sequence(&self, seq_id: u64) -> bool {
        self.sequences.contains_key(&seq_id)
    }

    /// Total tokens cached for a sequence (prefill + session extends +
    /// decoded), or `None` if unknown.
    pub fn sequence_tokens(&self, seq_id: u64) -> Option<usize> {
        self.sequences.get(&seq_id).map(|s| s.tables[0].n_tokens)
    }

    /// The method label a sequence attends under (its resolved mode),
    /// or `None` if unknown.
    pub fn sequence_method_label(&self, seq_id: u64) -> Option<&str> {
        self.sequences.get(&seq_id).map(|s| s.mode.method_label())
    }

    /// Extend a live (parked) sequence with `new_context` further
    /// context tokens and re-commit decode headroom for up to
    /// `max_new_tokens` more appends — the multi-turn session path.
    /// The new tokens are *appended* to the existing KV pages and
    /// selector index in place; nothing is re-prefilled, so a resumed
    /// turn costs `O(new_context)`, not `O(total context)`. Returns
    /// `false` (backpressure; nothing changed) when the pool cannot
    /// cover the grown commitment. Panics if the sequence was never
    /// prefilled — the scheduler checks membership at accept.
    pub fn session_extend(
        &mut self,
        seq_id: u64,
        new_context: usize,
        max_new_tokens: usize,
    ) -> bool {
        let heads = self.config.model.n_kv_heads;
        let current = self
            .sequences
            .get(&seq_id)
            .expect("session_extend before prefill")
            .tables[0]
            .n_tokens;
        let needed = heads * PagedKvCache::pages_for(current + new_context + max_new_tokens);
        let held = self.commitments.get(&seq_id).copied().unwrap_or(0);
        // A short turn can fit entirely in the previous turn's unused
        // headroom (needed <= held): keep the larger commitment.
        let extra = needed.saturating_sub(held);
        let mut available =
            self.kv.total_pages().saturating_sub(self.committed_pages + self.tree.held_refs());
        if available < extra {
            self.tree.evict_lru(&mut self.kv, extra - available);
            available =
                self.kv.total_pages().saturating_sub(self.committed_pages + self.tree.held_refs());
        }
        if available < extra {
            return false;
        }
        self.committed_pages += extra;
        self.commitments.insert(seq_id, held.max(needed));
        let state = self.sequences.get_mut(&seq_id).expect("session_extend before prefill");
        for h in 0..heads {
            for t in current..current + new_context {
                let (k, v) = state.model.kv_at(h, t);
                let ok = self.kv.append(&mut state.tables[h], &k, &v);
                assert!(ok, "KV pool exhausted during session extend");
                if let Some(s) = state.selectors.get_mut(h) {
                    s.append(&k, &v).expect("selector index built at prefill");
                }
            }
        }
        true
    }

    /// Drain pruning telemetry accumulated since the last call, across
    /// live sequences' selectors plus whatever released sequences left
    /// behind. Feeds the metrics registry's prune-rate gauges.
    pub fn take_prune_stats(&mut self) -> PruneStats {
        let mut total = std::mem::take(&mut self.prune_stats);
        for state in self.sequences.values() {
            for sel in &state.selectors {
                total.absorb(sel.take_prune_stats());
            }
        }
        for p in self.partials.values() {
            for sel in &p.selectors {
                total.absorb(sel.take_prune_stats());
            }
        }
        total
    }

    /// Drain the accumulated prefix-cache counters (scheduler drains
    /// them into the metrics registry alongside prune stats).
    pub fn take_prefix_stats(&mut self) -> PrefixStats {
        std::mem::take(&mut self.prefix_stats)
    }

    /// Number of resident prefix-tree nodes (one shared page run each).
    pub fn prefix_nodes(&self) -> usize {
        self.tree.n_nodes()
    }

    /// Physical pages currently pinned by the prefix tree's own
    /// references (shared pages also mapped by live sequences count
    /// once here and once per mapping table).
    pub fn prefix_held_pages(&self) -> usize {
        self.tree.held_refs()
    }

    /// Audit the pool's refcounts against every live reference holder:
    /// each physical page's refcount must equal (tree references) +
    /// (occurrences across live sequences' page tables), and the
    /// number of referenced pages must match the pool's in-use count.
    /// Any drift means a leak (page never freed) or a double-free in
    /// waiting; the scheduler asserts this at idle drain points.
    pub fn page_accounting(&self) -> Result<(), String> {
        let mut expected: HashMap<usize, usize> = HashMap::new();
        self.tree.for_each_held_page(|page| {
            *expected.entry(page).or_insert(0) += 1;
        });
        for state in self.sequences.values() {
            for table in &state.tables {
                for &page in &table.pages {
                    *expected.entry(page).or_insert(0) += 1;
                }
            }
        }
        // Paused partial prefills hold page references too — a
        // preempted or shed partial that leaked would surface here.
        for p in self.partials.values() {
            for table in &p.tables {
                for &page in &table.pages {
                    *expected.entry(page).or_insert(0) += 1;
                }
            }
        }
        for (&page, &want) in &expected {
            let got = self.kv.ref_count(page);
            if got != want {
                return Err(format!(
                    "page {page}: refcount {got} but {want} live references"
                ));
            }
        }
        let in_use = self.kv.pages_in_use();
        if expected.len() != in_use {
            return Err(format!(
                "{} referenced pages but pool reports {in_use} in use (leak)",
                expected.len()
            ));
        }
        Ok(())
    }

    /// Release a finished (or preempted) sequence's pages and its
    /// commitment — including a prefill still paused between chunks.
    /// Pages the prefix tree also references survive resident, so a
    /// preempted sequence readmits through the PR-8 hit path.
    pub fn release(&mut self, seq_id: u64) {
        if let Some(mut state) = self.sequences.remove(&seq_id) {
            // Keep the sequence's pruning telemetry for the next drain.
            for sel in &state.selectors {
                self.prune_stats.absorb(sel.take_prune_stats());
            }
            for table in state.tables.iter_mut() {
                self.kv.release(table);
            }
        }
        if let Some(mut p) = self.partials.remove(&seq_id) {
            for sel in &p.selectors {
                self.prune_stats.absorb(sel.take_prune_stats());
            }
            for table in p.tables.iter_mut() {
                self.kv.release(table);
            }
        }
        if let Some(c) = self.commitments.remove(&seq_id) {
            self.committed_pages -= c;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(mode: AttentionMode) -> EngineConfig {
        EngineConfig {
            model: ModelConfig { head_dim: 32, n_kv_heads: 2, ..ModelConfig::tiny() },
            lsh: LshParams { p: 8, l: 20, tau: 0.5 },
            mode,
            capacity_pages: 512,
            sink: 4,
            local: 4,
        }
    }

    #[test]
    fn prefill_decode_release_roundtrip() {
        let mut e = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        assert!(e.prefill(1, 300, 8));
        assert_eq!(e.n_sequences(), 1);
        let out = e.decode_step(1);
        // One output per *query* head: the 2 kv heads each serve their
        // 4-head GQA group.
        assert_eq!(out.len(), e.config.model.n_heads);
        assert_eq!(out.len(), 8);
        assert_eq!(out[0].len(), 32);
        assert!(out[0].iter().any(|&x| x != 0.0));
        assert_eq!(e.decoded(1), 1);
        let free_before = e.free_pages();
        e.release(1);
        assert!(e.free_pages() > free_before);
        assert_eq!(e.n_sequences(), 0);
    }

    #[test]
    fn admissible_matches_pool_capacity() {
        let e = DecodeEngine::new(EngineConfig { capacity_pages: 8, ..cfg(AttentionMode::Dense) });
        // 2 kv-heads x pages_for(ctx + dec) must fit the 8-page pool.
        assert!(e.admissible(32, 16)); // 2 * 3 = 6
        assert!(e.admissible(48, 16)); // 2 * 4 = 8
        assert!(!e.admissible(64, 16)); // 2 * 5 = 10
    }

    #[test]
    fn backpressure_on_pool_exhaustion() {
        let mut e = DecodeEngine::new(EngineConfig { capacity_pages: 8, ..cfg(AttentionMode::Dense) });
        // 2 heads x ceil(300/16) pages >> 8.
        assert!(!e.prefill(1, 300, 8));
        assert_eq!(e.n_sequences(), 0);
        // A small context fits.
        assert!(e.prefill(2, 32, 8));
    }

    #[test]
    fn socket_output_close_to_dense() {
        // The whole point: sparse decode ≈ dense decode outputs.
        let mut dense = DecodeEngine::new(cfg(AttentionMode::Dense));
        let mut sparse = DecodeEngine::new(cfg(AttentionMode::socket(4.0)));
        assert!(dense.prefill(7, 400, 4));
        assert!(sparse.prefill(7, 400, 4));
        let yd = dense.decode_step(7);
        let ys = sparse.decode_step(7);
        assert_eq!(yd.len(), 8);
        for h in 0..8 {
            let rel = crate::metrics::output_relative_error(&ys[h], &yd[h]);
            assert!(rel < 0.5, "query head {h} rel err {rel}");
        }
    }

    #[test]
    fn every_registered_method_is_servable() {
        // The redesign's acceptance bar: any registry method decodes
        // over the paged pool — prefill builds its index from the view,
        // decode steps select + attend + extend the index.
        for spec in crate::selector::registry() {
            let mut e = DecodeEngine::new(cfg(AttentionMode::sparse(spec.name, 4.0)));
            assert!(e.prefill(1, 200, 4), "{} prefill", spec.name);
            for step in 0..2 {
                let out = e.decode_step(1);
                assert_eq!(out.len(), 8, "{} step {step}", spec.name);
                assert_eq!(out[0].len(), 32, "{}", spec.name);
                assert!(
                    out.iter().all(|y| y.iter().all(|x| x.is_finite())),
                    "{} non-finite output",
                    spec.name
                );
                assert!(
                    out[0].iter().any(|&x| x != 0.0),
                    "{} all-zero output",
                    spec.name
                );
            }
            assert_eq!(e.decoded(1), 2, "{}", spec.name);
            e.release(1);
        }
    }

    #[test]
    fn per_request_mode_overrides_engine_default() {
        // One engine, three sequences, three different modes — the
        // per-request configuration surface the server exposes.
        let mut e = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        assert!(e.prefill_as(1, 100, 4, None).unwrap());
        assert!(e.prefill_as(2, 100, 4, Some(&AttentionMode::Dense)).unwrap());
        assert!(e.prefill_as(3, 100, 4, Some(&AttentionMode::sparse("quest", 8.0))).unwrap());
        for seq in [1, 2, 3] {
            let out = e.decode_step(seq);
            assert_eq!(out.len(), 8);
            assert!(out[0].iter().any(|&x| x != 0.0), "seq {seq}");
        }
        // Identical sequence under the default mode on a fresh engine
        // matches seq 1 (override of None == engine default).
        let mut e2 = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        assert!(e2.prefill(1, 100, 4));
        assert_eq!(e2.decode_step(1), {
            let mut e3 = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
            assert!(e3.prefill(1, 100, 4));
            e3.decode_step(1)
        });
    }

    #[test]
    fn unknown_method_is_an_error_before_any_commitment() {
        let mut e = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        let free = e.free_pages();
        let bad = AttentionMode::sparse("definitely-not-a-method", 8.0);
        assert!(e.validate_mode(Some(&bad)).is_err());
        let err = e.prefill_as(1, 100, 4, Some(&bad)).unwrap_err();
        assert!(err.to_string().contains("unknown method"), "{err}");
        assert_eq!(e.free_pages(), free, "no pages may be committed");
        assert_eq!(e.n_sequences(), 0);
        // Engine default is valid.
        assert!(e.validate_mode(None).is_ok());
    }

    #[test]
    fn multi_sequence_isolation() {
        let mut e = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        assert!(e.prefill(1, 100, 8));
        assert!(e.prefill(2, 150, 8));
        let o1a = e.decode_step(1);
        let _ = e.decode_step(2);
        // Re-running seq 1's step-0 computation via a fresh engine gives
        // identical output (determinism + isolation).
        let mut e2 = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        assert!(e2.prefill(1, 100, 8));
        let o1b = e2.decode_step(1);
        assert_eq!(o1a, o1b);
    }

    #[test]
    fn mha_config_group_of_one_still_serves() {
        // n_kv_heads == n_heads is plain MHA: every GQA group has one
        // query head and the lane degrades to the scalar path.
        let mut e = DecodeEngine::new(EngineConfig {
            model: ModelConfig { head_dim: 32, n_kv_heads: 8, ..ModelConfig::tiny() },
            ..cfg(AttentionMode::socket(8.0))
        });
        assert!(e.prefill(1, 100, 4));
        let out = e.decode_step(1);
        assert_eq!(out.len(), 8);
        assert!(out.iter().all(|y| y.iter().all(|x| x.is_finite())));
    }

    #[test]
    #[should_panic(expected = "decode before prefill")]
    fn decode_unknown_sequence_panics() {
        let mut e = DecodeEngine::new(cfg(AttentionMode::Dense));
        e.decode_step(42);
    }

    #[test]
    fn session_extend_is_bit_identical_to_from_scratch_concat() {
        // The session tentpole's core property: turn 1 (prefill N1,
        // decode M1) + session_extend(N2) + turn-2 decode must produce
        // *bit-identical* outputs to a fresh sequence prefilled over the
        // concatenated N1 + M1 + N2 context. Output equality pins the
        // selected indices and scores too: flash-decode attends only
        // over the selector's merged selection, so any index or score
        // divergence shows up in the outputs. Checked for socket and
        // oracle (the issue's pair), plus dense as the control.
        for mode in
            [AttentionMode::socket(4.0), AttentionMode::sparse("oracle", 4.0), AttentionMode::Dense]
        {
            let (n1, m1, n2, m2) = (150usize, 3usize, 80usize, 4usize);
            let mut sess = DecodeEngine::new(cfg(mode.clone()));
            assert!(sess.prefill(5, n1, m1), "{mode:?} turn-1 prefill");
            for _ in 0..m1 {
                sess.decode_step(5);
            }
            assert!(sess.session_extend(5, n2, m2), "{mode:?} extend");
            assert_eq!(sess.sequence_tokens(5), Some(n1 + m1 + n2));
            let got: Vec<_> = (0..m2).map(|_| sess.decode_step(5)).collect();

            let mut fresh = DecodeEngine::new(cfg(mode.clone()));
            assert!(fresh.prefill(5, n1 + m1 + n2, m2), "{mode:?} from-scratch prefill");
            let want: Vec<_> = (0..m2).map(|_| fresh.decode_step(5)).collect();
            assert_eq!(got, want, "{mode:?} resumed decode diverged from from-scratch");
        }
    }

    #[test]
    fn session_extend_backpressure_and_release() {
        // 16 pages x 16 tokens / 2 kv-heads = 128 cacheable tokens per
        // head stream. A 64-token turn fits; extending past the pool's
        // commitment capacity must refuse without touching state.
        let mut e =
            DecodeEngine::new(EngineConfig { capacity_pages: 16, ..cfg(AttentionMode::socket(4.0)) });
        assert!(e.prefill(1, 64, 4));
        let tokens_before = e.sequence_tokens(1).unwrap();
        let free_before = e.free_pages();
        assert!(!e.session_extend(1, 4096, 4), "oversized extend must refuse");
        assert_eq!(e.sequence_tokens(1), Some(tokens_before), "refused extend must not append");
        assert_eq!(e.free_pages(), free_before);
        // A small extend within the pool succeeds and appends.
        assert!(e.session_extend(1, 32, 4));
        assert_eq!(e.sequence_tokens(1), Some(96));
        // Release returns everything (extend's commitment included).
        let total_free = e.free_pages();
        e.release(1);
        assert!(e.free_pages() > total_free);
        assert!(!e.has_sequence(1));
        assert!(e.prefill(2, 64, 4), "pool must be reusable after release");
    }

    #[test]
    fn prune_stats_drain_from_live_and_released_sequences() {
        let mut e = DecodeEngine::new(cfg(AttentionMode::socket(4.0)));
        assert!(e.prefill(1, 300, 4));
        e.decode_step(1);
        let live = e.take_prune_stats();
        assert!(live.blocks > 0, "socket decode must record visited blocks: {live:?}");
        assert_eq!(e.take_prune_stats(), PruneStats::default(), "drain must reset");
        // Telemetry from a released sequence survives until drained.
        e.decode_step(1);
        e.release(1);
        assert!(e.take_prune_stats().blocks > 0, "release must keep undrained telemetry");
    }

    #[test]
    fn decode_batch_matches_serial_steps() {
        // The pooled batch path must be step-for-step identical to
        // serial decode_step calls (same selection, same outputs, same
        // cache state afterwards) — including with mixed per-sequence
        // methods in one batch.
        let mut serial = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        let mut batched = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        let seqs = [1u64, 2, 3];
        let modes: [Option<AttentionMode>; 3] =
            [None, Some(AttentionMode::sparse("quest", 8.0)), Some(AttentionMode::Dense)];
        for (&(seq, ctx), mode) in [(1u64, 120usize), (2, 200), (3, 64)].iter().zip(&modes) {
            assert!(serial.prefill_as(seq, ctx, 4, mode.as_ref()).unwrap());
            assert!(batched.prefill_as(seq, ctx, 4, mode.as_ref()).unwrap());
        }
        for _ in 0..3 {
            let want: Vec<Vec<Vec<f32>>> = seqs.iter().map(|&s| serial.decode_step(s)).collect();
            let got = batched.decode_batch(&seqs);
            assert_eq!(got, want);
        }
        for &s in &seqs {
            assert_eq!(serial.decoded(s), 3);
            assert_eq!(batched.decoded(s), 3);
        }
    }

    #[test]
    fn prefix_shared_decode_is_bit_identical_to_isolated() {
        let mut shared = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        // 300 tokens = 18 full pages + a 12-token tail; 4 full hash
        // blocks (64 tokens each) with a 44-token hashed remainder.
        let prompt = PromptSpec::from_seed(0xABCD, 300);
        // Seq 1 populates the tree: a lookup, but a cold miss.
        assert!(shared.prefill_opts(1, 300, 8, None, Some(&prompt)).unwrap());
        assert_eq!(shared.prefix_nodes(), 19, "18 full pages + frozen tail");
        // Mid-decode appends fork seq 1 off its own frozen tail (COW):
        // the tree's snapshot must stay immutable underneath.
        shared.decode_step(1);
        shared.decode_step(1);
        let cold = shared.take_prefix_stats();
        assert_eq!((cold.lookups, cold.hits, cold.hash_blocks_reused), (1, 0, 0));
        assert!(cold.tokens_saved == 0 && cold.shared_pages == 0);

        // Seq 2, same prompt: full prefix hit — every page mapped, all
        // 4 frozen hash blocks attached per kv head, zero K/V recompute.
        assert!(shared.prefill_opts(2, 300, 8, None, Some(&prompt)).unwrap());
        let hit = shared.take_prefix_stats();
        assert_eq!((hit.lookups, hit.hits), (1, 1));
        assert_eq!(hit.tokens_saved, 300, "18 full pages x 16 + 12-token tail");
        assert_eq!(hit.shared_pages, 2 * 19);
        assert_eq!(hit.private_pages, 0);
        assert_eq!(hit.hash_blocks_reused, 2 * 4);
        shared.page_accounting().expect("refcounts after shared admit");

        // Isolated control: fresh engine, same seq id and prompt, no
        // resident tree. Selection indices, scores, and outputs all
        // feed these vectors — any divergence shows up here.
        let mut isolated = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        assert!(isolated.prefill_opts(2, 300, 8, None, Some(&prompt)).unwrap());
        for step in 0..5 {
            let want = isolated.decode_step(2);
            let got = shared.decode_step(2);
            assert_eq!(got, want, "shared decode diverged at step {step}");
        }
        shared.page_accounting().expect("refcounts after COW decode");

        // cache:"off" requests serve identically but bypass the tree.
        let nodes = shared.prefix_nodes();
        let mut opt_out = prompt.clone();
        opt_out.cache = false;
        let mut control = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        assert!(control.prefill_opts(3, 300, 8, None, Some(&opt_out)).unwrap());
        assert!(shared.prefill_opts(3, 300, 8, None, Some(&opt_out)).unwrap());
        assert_eq!(shared.prefix_nodes(), nodes, "cache-off must not touch the tree");
        assert_eq!(shared.take_prefix_stats(), PrefixStats::default());
        assert_eq!(shared.decode_step(3), control.decode_step(3));
        shared.page_accounting().expect("refcounts with cache-off sequence live");
    }

    #[test]
    fn prefix_release_and_readmission_share_resident_pages() {
        let mut e = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        // 200 tokens = 12 full pages + an 8-token tail.
        let prompt = PromptSpec::from_seed(7, 200);
        assert!(e.prefill_opts(1, 200, 4, None, Some(&prompt)).unwrap());
        e.decode_step(1);
        e.release(1);
        e.page_accounting().expect("refcounts after release");
        // The tree keeps the whole prefix resident past the release.
        assert_eq!(e.prefix_held_pages(), 2 * 13);
        let free_parked = e.free_pages();

        // Re-admission maps the parked pages back in by incref.
        assert!(e.prefill_opts(2, 200, 4, None, Some(&prompt)).unwrap());
        let s = e.take_prefix_stats();
        assert_eq!((s.hits, s.tokens_saved), (1, 200));
        e.page_accounting().expect("refcounts after readmission");
        e.decode_step(2);
        e.release(2);
        e.page_accounting().expect("refcounts after final release");
        // Decode COW'd a private tail which release freed again: the
        // pool must return exactly to its parked level (no leaks).
        assert_eq!(e.free_pages(), free_parked);
    }

    #[test]
    fn chunked_prefill_is_bit_identical_to_one_shot() {
        // The chunking tentpole's core property: prefilling in
        // budget-sized chunks (first chunk builds the index, later
        // chunks append token-at-a-time) must leave the sequence in a
        // state bit-identical to a one-shot prefill — outputs pin the
        // selected indices and scores too.
        for mode in
            [AttentionMode::socket(4.0), AttentionMode::sparse("oracle", 4.0), AttentionMode::Dense]
        {
            let ctx = 300usize;
            let mut chunked = DecodeEngine::new(cfg(mode.clone()));
            let mut progress = chunked
                .prefill_chunk(1, ctx, 4, None, None, 64)
                .expect("mode registered");
            assert_eq!(progress, PrefillProgress::InProgress { filled: 64 }, "{mode:?}");
            let mut calls = 1;
            while let PrefillProgress::InProgress { filled } = progress {
                assert!(filled < ctx);
                progress = chunked.prefill_chunk(1, ctx, 4, None, None, 64).unwrap();
                calls += 1;
            }
            assert_eq!(progress, PrefillProgress::Complete, "{mode:?}");
            assert_eq!(calls, 5, "ceil(300/64) chunks");
            chunked.page_accounting().expect("refcounts after chunked prefill");

            let mut oneshot = DecodeEngine::new(cfg(mode.clone()));
            assert!(oneshot.prefill(1, ctx, 4), "{mode:?} one-shot");
            for step in 0..4 {
                let want = oneshot.decode_step(1);
                let got = chunked.decode_step(1);
                assert_eq!(got, want, "{mode:?} diverged at step {step}");
            }
        }
    }

    #[test]
    fn partial_prefill_releases_cleanly_midway() {
        // Preempting (or shedding) a sequence paused between chunks
        // must return every page — the no-leak acceptance bar.
        let mut e = DecodeEngine::new(cfg(AttentionMode::socket(4.0)));
        let free0 = e.free_pages();
        assert_eq!(
            e.prefill_chunk(1, 256, 4, None, None, 64).unwrap(),
            PrefillProgress::InProgress { filled: 64 }
        );
        assert!(e.free_pages() < free0, "chunk holds pages");
        e.page_accounting().expect("refcounts with a paused partial");
        e.release(1);
        e.page_accounting().expect("refcounts after partial release");
        assert_eq!(e.free_pages(), free0, "partial release must return every page");
        // The id is reusable from scratch afterwards.
        assert!(e.prefill(1, 64, 4));
    }

    #[test]
    fn chunked_prompted_prefill_publishes_only_at_completion() {
        let mut e = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        // 200 tokens = 12 full pages + an 8-token tail.
        let prompt = PromptSpec::from_seed(11, 200);
        assert_eq!(
            e.prefill_chunk(1, 200, 8, None, Some(&prompt), 80).unwrap(),
            PrefillProgress::InProgress { filled: 80 }
        );
        assert_eq!(e.prefix_nodes(), 0, "no half-written page may be published");
        assert_eq!(
            e.prefill_chunk(1, 200, 8, None, Some(&prompt), 80).unwrap(),
            PrefillProgress::InProgress { filled: 160 }
        );
        assert_eq!(
            e.prefill_chunk(1, 200, 8, None, Some(&prompt), 80).unwrap(),
            PrefillProgress::Complete
        );
        assert_eq!(e.prefix_nodes(), 13, "12 full pages + frozen tail published");
        e.page_accounting().expect("refcounts after chunked publication");
        // A second request with the same prompt takes the hit path and
        // decodes bit-identically to an isolated build.
        e.take_prefix_stats();
        assert!(e.prefill_opts(2, 200, 8, None, Some(&prompt)).unwrap());
        let s = e.take_prefix_stats();
        assert_eq!((s.hits, s.tokens_saved), (1, 200), "chunk-built prefix must be sharable");
        let mut isolated = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        assert!(isolated.prefill_opts(2, 200, 8, None, Some(&prompt)).unwrap());
        for _ in 0..3 {
            assert_eq!(e.decode_step(2), isolated.decode_step(2));
        }
        e.page_accounting().expect("refcounts after shared decode");
    }

    #[test]
    fn forced_fault_rejects_like_real_exhaustion() {
        use crate::testing::faults::FaultPlan;
        let mut e = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        let free0 = e.free_pages();
        e.inject_faults(FaultPlan::new().fail_first(1, 2));
        assert_eq!(
            e.prefill_chunk(1, 100, 4, None, None, usize::MAX).unwrap(),
            PrefillProgress::Rejected
        );
        assert_eq!(e.free_pages(), free0, "forced rejection must not leak");
        assert_eq!(e.n_sequences(), 0);
        e.page_accounting().expect("refcounts after forced rejection");
        // Bystanders are untouched while seq 1's budget lasts.
        assert!(e.prefill(2, 100, 4));
        assert!(!e.prefill(1, 100, 4), "second charge still armed");
        assert!(e.prefill(1, 100, 4), "plan exhausted — admission recovers");
        assert_eq!(e.faults_fired(), 2);
        e.page_accounting().expect("refcounts after recovery");
    }

    #[test]
    fn prefix_tree_evicts_under_pressure_but_never_a_mapped_page() {
        // Pool sized so two distinct resident prefixes cannot coexist.
        let mut e = DecodeEngine::new(EngineConfig {
            capacity_pages: 24,
            ..cfg(AttentionMode::Dense)
        });
        // A: 128 tokens = 8 pages x 2 heads held by the tree after release.
        let a = PromptSpec::from_seed(1, 128);
        assert!(e.prefill_opts(1, 128, 16, None, Some(&a)).unwrap());
        e.release(1);
        assert_eq!(e.prefix_held_pages(), 16);
        // B needs 2 x pages_for(144) = 18 > the 8 unheld pages: the
        // admission path must evict A's cold leaves to make room.
        let b = PromptSpec::from_seed(2, 128);
        assert!(e.prefill_opts(2, 128, 16, None, Some(&b)).unwrap());
        assert!(e.prefix_held_pages() < 32, "A partially evicted");
        e.page_accounting().expect("refcounts after eviction");
        // C cannot fit while B is live, and eviction may only take A's
        // leftovers — B's pages are mapped (ref >= 2) and untouchable.
        let c = PromptSpec::from_seed(3, 128);
        assert!(!e.prefill_opts(3, 128, 16, None, Some(&c)).unwrap());
        assert!(e.has_sequence(2));
        assert_eq!(e.sequence_tokens(2), Some(128));
        e.page_accounting().expect("refcounts after refused admission");
        // B still decodes into its commitment despite the full pool.
        for _ in 0..16 {
            e.decode_step(2);
        }
        e.release(2);
        e.page_accounting().expect("refcounts after final release");
    }

    /// PR 9 acceptance: a sequence preempted mid-decode (recompute-style
    /// release) and readmitted through the prefix tree produces output
    /// bit-identical to an uncontended run — across modes, context
    /// lengths, and preemption points, with no pages leaked.
    #[test]
    fn preempt_readmit_output_is_bit_identical_property() {
        use crate::prop_assert;
        use crate::testing::{check, PropConfig};
        check("preempt-readmit-identity", PropConfig { cases: 10, seed: 0x9E9E }, |rng, case| {
            let ctx = 48 + (rng.next_u64() % 200) as usize;
            let k = 1 + (rng.next_u64() % 4) as usize; // decoded before preemption
            let total = k + 1 + (rng.next_u64() % 5) as usize;
            let mode = match rng.next_u64() % 3 {
                0 => AttentionMode::socket(6.0),
                1 => AttentionMode::sparse("oracle", 6.0),
                _ => AttentionMode::Dense,
            };
            let prompt = PromptSpec::from_seed(0x7E5 + case as u64, ctx);

            // Contended run: prefill, decode k tokens, preempt (the
            // prefix tree keeps the prompt resident), readmit, recompute
            // the whole turn.
            let mut e = DecodeEngine::new(cfg(mode.clone()));
            prop_assert!(
                e.prefill_opts(1, ctx, total, None, Some(&prompt)).unwrap(),
                "admission failed (ctx={ctx})"
            );
            for _ in 0..k {
                e.decode_step(1);
            }
            e.release(1); // preemption
            e.take_prefix_stats();
            prop_assert!(
                e.prefill_opts(1, ctx, total, None, Some(&prompt)).unwrap(),
                "readmission failed (ctx={ctx})"
            );
            let s = e.take_prefix_stats();
            prop_assert!(s.hits == 1, "readmission must hit the prefix tree (ctx={ctx})");
            let got: Vec<_> = (0..total).map(|_| e.decode_step(1)).collect();
            e.page_accounting().map_err(|err| format!("leak after preempt cycle: {err}"))?;

            // Uncontended control: same prompt on a fresh engine.
            let mut u = DecodeEngine::new(cfg(mode));
            prop_assert!(
                u.prefill_opts(1, ctx, total, None, Some(&prompt)).unwrap(),
                "control admission failed (ctx={ctx})"
            );
            let want: Vec<_> = (0..total).map(|_| u.decode_step(1)).collect();
            prop_assert!(
                got == want,
                "resumed output diverged (ctx={ctx} k={k} total={total} case={case})"
            );
            Ok(())
        });
    }
}
