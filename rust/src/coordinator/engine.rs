//! The per-sequence decode engine: owns the paged KV cache and the
//! per-sequence selector indexes, executes prefill and single-token
//! decode steps. One engine serves many sequences (state is
//! per-sequence), and *any* registered selection method is servable —
//! per request — over the same zero-copy paged hot path.

use crate::attention::{flash_decode_into, SelectionPolicy};
use crate::kvcache::{PageTable, PagedKvCache};
use crate::lsh::{LshParams, PruneStats};
use crate::model::{ModelConfig, SyntheticModel};
use crate::selector::{self, Selector, SelectorConfig, SelectorError};
use crate::util::pool::with_decode_scratch;
use std::collections::HashMap;

pub use crate::selector::AttentionMode;

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub model: ModelConfig,
    pub lsh: LshParams,
    /// Default attention mode; requests may override per sequence.
    pub mode: AttentionMode,
    /// Paged-KV pool capacity (pages shared across sequences).
    pub capacity_pages: usize,
    pub sink: usize,
    pub local: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            model: ModelConfig::tiny(),
            lsh: LshParams::paper_default(),
            mode: AttentionMode::socket(33.0),
            capacity_pages: 16 * 1024,
            sink: 64,
            local: 64,
        }
    }
}

/// Per-sequence state: one KV page table per kv-head stream, plus —
/// for sparse modes — one selector index per stream, built at prefill
/// from the paged view and *extended* per decoded token (single
/// representative layer — the decode cost of all layers scales linearly
/// and is reported as such).
struct SequenceState {
    tables: Vec<PageTable>,
    /// One selector per kv-head stream; empty in dense mode.
    selectors: Vec<Box<dyn Selector>>,
    /// The resolved mode this sequence attends under.
    mode: AttentionMode,
    model: SyntheticModel,
    decoded: usize,
}

/// The read-only half of a decode step: per-*query-head* attention
/// outputs (`n_heads` of them — the GQA group of each kv head attends
/// through its shared KV stream) plus the new token's (key, value) per
/// *kv head*, ready to be committed.
struct StepResult {
    outputs: Vec<Vec<f32>>,
    appends: Vec<(Vec<f32>, Vec<f32>)>,
}

/// The decode engine: paged KV pool + per-sequence selector indexes.
pub struct DecodeEngine {
    pub config: EngineConfig,
    kv: PagedKvCache,
    sequences: HashMap<u64, SequenceState>,
    /// Pages committed to admitted sequences (context + decode
    /// headroom) — admission control that guarantees decode appends
    /// never hit an exhausted pool.
    committed_pages: usize,
    /// Per-sequence committed page count (for release bookkeeping).
    commitments: HashMap<u64, usize>,
    /// Pruning telemetry drained from *released* sequences' selectors
    /// (live ones are scanned on demand by `take_prune_stats`).
    prune_stats: PruneStats,
}

impl DecodeEngine {
    pub fn new(config: EngineConfig) -> DecodeEngine {
        // A malformed head layout must fail at construction, not panic
        // mid-serving on the first decode step.
        assert!(
            config.model.n_kv_heads > 0 && config.model.n_heads % config.model.n_kv_heads == 0,
            "n_heads {} must be a multiple of n_kv_heads {}",
            config.model.n_heads,
            config.model.n_kv_heads
        );
        DecodeEngine {
            kv: PagedKvCache::new(config.capacity_pages, config.model.head_dim),
            config,
            sequences: HashMap::new(),
            committed_pages: 0,
            commitments: HashMap::new(),
            prune_stats: PruneStats::default(),
        }
    }

    pub fn n_sequences(&self) -> usize {
        self.sequences.len()
    }

    pub fn free_pages(&self) -> usize {
        self.kv.free_pages()
    }

    /// Whether a request of this shape can *ever* be admitted: its full
    /// page commitment must fit an empty pool. The scheduler rejects
    /// inadmissible requests up front with a failed completion instead
    /// of requeueing them forever (no running sequence can release
    /// enough pages to make them fit).
    pub fn admissible(&self, context_len: usize, max_new_tokens: usize) -> bool {
        self.config.model.n_kv_heads * PagedKvCache::pages_for(context_len + max_new_tokens)
            <= self.kv.total_pages()
    }

    /// Check that a request's attention mode (or the engine default
    /// when `None`) names a registered selector. The scheduler fails
    /// such requests up front — like inadmissible shapes, they could
    /// never be served.
    pub fn validate_mode(&self, mode: Option<&AttentionMode>) -> Result<(), SelectorError> {
        match mode.unwrap_or(&self.config.mode) {
            AttentionMode::Dense => Ok(()),
            AttentionMode::Sparse { method, .. } => selector::lookup(method).map(|_| ()),
        }
    }

    /// Admit a sequence under the engine's default mode. See
    /// [`DecodeEngine::prefill_as`].
    pub fn prefill(&mut self, seq_id: u64, context_len: usize, max_new_tokens: usize) -> bool {
        self.prefill_as(seq_id, context_len, max_new_tokens, None)
            .expect("engine default mode must name a registered selector")
    }

    /// Admit a sequence: prefill `context_len` tokens (KV pages + the
    /// selector index, built in place over the paged view) and commit
    /// page headroom for up to `max_new_tokens` decode appends. `mode`
    /// overrides the engine default for this sequence — any registered
    /// method is servable per request. `Ok(false)` means the pool
    /// cannot guarantee the commitment (backpressure — caller
    /// requeues); `Err` means the mode names no registered selector
    /// (never admittable; nothing was committed).
    pub fn prefill_as(
        &mut self,
        seq_id: u64,
        context_len: usize,
        max_new_tokens: usize,
        mode: Option<&AttentionMode>,
    ) -> Result<bool, SelectorError> {
        let mode = mode.unwrap_or(&self.config.mode).clone();
        // Resolve the method before committing any pages.
        let spec = match &mode {
            AttentionMode::Dense => None,
            AttentionMode::Sparse { method, .. } => Some(selector::lookup(method)?),
        };
        let heads = self.config.model.n_kv_heads;
        let needed = heads * PagedKvCache::pages_for(context_len + max_new_tokens);
        if self.kv.total_pages() - self.committed_pages < needed {
            return Ok(false);
        }
        self.committed_pages += needed;
        self.commitments.insert(seq_id, needed);
        let model = SyntheticModel::new(self.config.model, seq_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut tables = Vec::with_capacity(heads);
        let mut selectors = Vec::with_capacity(heads);
        for h in 0..heads {
            let mut table = PageTable::default();
            let (keys, values) = model.kv_matrix(h, context_len);
            let written = self.kv.append_many(&mut table, &keys.data, &values.data);
            debug_assert_eq!(written, context_len);
            if let Some(spec) = spec {
                // Paged-native prefill (Alg. 1 for SOCKET; page
                // min/max, PQ codes, channel stats... for the rest):
                // the index is built straight off the pool view — the
                // same bytes the decode kernels read — and extended per
                // decoded token thereafter, never rebuilt.
                let cfg = SelectorConfig::new(self.config.model.head_dim, seq_id ^ (h as u64) << 11)
                    .with_lsh(self.config.lsh);
                let mut s = (spec.build)(&cfg);
                s.build(&self.kv.view(&table));
                selectors.push(s);
            }
            tables.push(table);
        }
        self.sequences
            .insert(seq_id, SequenceState { tables, selectors, mode, model, decoded: 0 });
        Ok(true)
    }

    /// One decode step for a sequence; returns the attention outputs
    /// (one per *query* head — each kv head's GQA group is scored in a
    /// single pass over its shared index) and appends the new token's
    /// K/V per kv head. Panics if the sequence was never prefilled.
    pub fn decode_step(&mut self, seq_id: u64) -> Vec<Vec<f32>> {
        let state = self.sequences.get(&seq_id).expect("decode before prefill");
        let computed = self.compute_step(state);
        self.apply_step(seq_id, computed)
    }

    /// One decode step for each sequence in `seq_ids`, with the
    /// compute phase (selector scoring, top-k, attention — all reads)
    /// fanned out across the shared worker pool, then the KV/index
    /// appends committed serially in `seq_ids` order. Outputs are
    /// identical to calling [`DecodeEngine::decode_step`] per sequence.
    pub fn decode_batch(&mut self, seq_ids: &[u64]) -> Vec<Vec<Vec<f32>>> {
        // A duplicated id would compute both steps from the same
        // pre-step snapshot, breaking the serial equivalence.
        debug_assert!(
            {
                let mut ids = seq_ids.to_vec();
                ids.sort_unstable();
                ids.dedup();
                ids.len() == seq_ids.len()
            },
            "decode_batch requires distinct sequence ids"
        );
        let computed: Vec<StepResult> = {
            let eng: &DecodeEngine = &*self;
            crate::util::pool::global().map(seq_ids.len(), |i| {
                let state = eng.sequences.get(&seq_ids[i]).expect("decode before prefill");
                eng.compute_step(state)
            })
        };
        seq_ids.iter().zip(computed).map(|(&seq, result)| self.apply_step(seq, result)).collect()
    }

    /// Query heads sharing each kv head's KV stream (the GQA group).
    /// Divisibility is validated at [`DecodeEngine::new`].
    fn gqa_group(&self) -> usize {
        self.config.model.n_heads / self.config.model.n_kv_heads
    }

    /// Immutable phase of one decode step: per-query-head attention
    /// outputs plus the new token's K/V per kv head, computed without
    /// touching engine state.
    ///
    /// Each kv head serves its whole GQA group in one lane: the group's
    /// queries are selected together (`Selector::select_group_into` —
    /// for SOCKET the pool-parallel branch-and-bound walk, which fans
    /// blocks x lanes across idle workers when this step runs on the
    /// caller thread, and runs inline when `decode_batch` has already
    /// fanned sequences across the pool), then each query head attends
    /// over its own merged selection. Output `g` of kv head `h` lands
    /// at query-head index `h * group + g`.
    fn compute_step(&self, state: &SequenceState) -> StepResult {
        let heads = self.config.model.n_kv_heads;
        let group = self.gqa_group();
        let dim = self.config.model.head_dim;
        let scale = 1.0 / (dim as f32).sqrt();
        let mut outputs = Vec::with_capacity(heads * group);
        let mut appends = Vec::with_capacity(heads);
        // Queries are drawn at the sequence's *absolute* token position,
        // not the per-turn decode counter. The synthetic K/V stream is
        // already purely position-based (`kv_at`), so with position-based
        // queries a resumed session (prefill → decode → session_extend →
        // decode) is bit-identical to a from-scratch prefill over the
        // concatenated context — the property the session tests pin.
        let step = state.tables[0].n_tokens;
        for h in 0..heads {
            let n = state.tables[h].n_tokens;
            let queries: Vec<Vec<f32>> =
                (0..group).map(|g| state.model.query_at(h * group + g, step)).collect();
            // Attend in place over the paged cache: the view addresses
            // pages through the page table, so no K/V row is copied and
            // no dense matrix is allocated per step. Selector scoring
            // and the merged selection live in per-worker scratch.
            let view = self.kv.view(&state.tables[h]);
            match &state.mode {
                AttentionMode::Dense => {
                    for q in &queries {
                        let mut out = Vec::new();
                        flash_decode_into(q, &view, None, scale, &mut out);
                        outputs.push(out);
                    }
                }
                AttentionMode::Sparse { sparsity, .. } => {
                    let policy = SelectionPolicy::from_sparsity(
                        n,
                        *sparsity,
                        self.config.sink,
                        self.config.local,
                    );
                    with_decode_scratch(|scratch| {
                        let sels = scratch.group_selections(group);
                        state.selectors[h]
                            .select_group_into(&queries, policy.k, sels)
                            .expect("selector index built at prefill");
                        for (q, sel) in queries.iter().zip(scratch.selections.iter()) {
                            policy.merge_into(&sel.indices, n, &mut scratch.indices);
                            let mut out = Vec::new();
                            flash_decode_into(q, &view, Some(&scratch.indices), scale, &mut out);
                            outputs.push(out);
                        }
                    });
                }
            }
            appends.push(state.model.kv_at(h, n));
        }
        StepResult { outputs, appends }
    }

    /// Mutable phase: commit the new token's K/V to the paged cache and
    /// extend the selector indexes, advance the decode counter.
    fn apply_step(&mut self, seq_id: u64, result: StepResult) -> Vec<Vec<f32>> {
        let state = self.sequences.get_mut(&seq_id).expect("decode before prefill");
        for (h, (k_new, v_new)) in result.appends.iter().enumerate() {
            let ok = self.kv.append(&mut state.tables[h], k_new, v_new);
            assert!(ok, "KV pool exhausted mid-decode");
            if let Some(s) = state.selectors.get_mut(h) {
                s.append(k_new, v_new).expect("selector index built at prefill");
            }
        }
        state.decoded += 1;
        result.outputs
    }

    pub fn decoded(&self, seq_id: u64) -> usize {
        self.sequences.get(&seq_id).map(|s| s.decoded).unwrap_or(0)
    }

    /// Whether the engine holds state (pages + selector index) for this
    /// sequence — live or parked between session turns.
    pub fn has_sequence(&self, seq_id: u64) -> bool {
        self.sequences.contains_key(&seq_id)
    }

    /// Total tokens cached for a sequence (prefill + session extends +
    /// decoded), or `None` if unknown.
    pub fn sequence_tokens(&self, seq_id: u64) -> Option<usize> {
        self.sequences.get(&seq_id).map(|s| s.tables[0].n_tokens)
    }

    /// The method label a sequence attends under (its resolved mode),
    /// or `None` if unknown.
    pub fn sequence_method_label(&self, seq_id: u64) -> Option<&str> {
        self.sequences.get(&seq_id).map(|s| s.mode.method_label())
    }

    /// Extend a live (parked) sequence with `new_context` further
    /// context tokens and re-commit decode headroom for up to
    /// `max_new_tokens` more appends — the multi-turn session path.
    /// The new tokens are *appended* to the existing KV pages and
    /// selector index in place; nothing is re-prefilled, so a resumed
    /// turn costs `O(new_context)`, not `O(total context)`. Returns
    /// `false` (backpressure; nothing changed) when the pool cannot
    /// cover the grown commitment. Panics if the sequence was never
    /// prefilled — the scheduler checks membership at accept.
    pub fn session_extend(
        &mut self,
        seq_id: u64,
        new_context: usize,
        max_new_tokens: usize,
    ) -> bool {
        let heads = self.config.model.n_kv_heads;
        let current = self
            .sequences
            .get(&seq_id)
            .expect("session_extend before prefill")
            .tables[0]
            .n_tokens;
        let needed = heads * PagedKvCache::pages_for(current + new_context + max_new_tokens);
        let held = self.commitments.get(&seq_id).copied().unwrap_or(0);
        // A short turn can fit entirely in the previous turn's unused
        // headroom (needed <= held): keep the larger commitment.
        let extra = needed.saturating_sub(held);
        if self.kv.total_pages() - self.committed_pages < extra {
            return false;
        }
        self.committed_pages += extra;
        self.commitments.insert(seq_id, held.max(needed));
        let state = self.sequences.get_mut(&seq_id).expect("session_extend before prefill");
        for h in 0..heads {
            for t in current..current + new_context {
                let (k, v) = state.model.kv_at(h, t);
                let ok = self.kv.append(&mut state.tables[h], &k, &v);
                assert!(ok, "KV pool exhausted during session extend");
                if let Some(s) = state.selectors.get_mut(h) {
                    s.append(&k, &v).expect("selector index built at prefill");
                }
            }
        }
        true
    }

    /// Drain pruning telemetry accumulated since the last call, across
    /// live sequences' selectors plus whatever released sequences left
    /// behind. Feeds the metrics registry's prune-rate gauges.
    pub fn take_prune_stats(&mut self) -> PruneStats {
        let mut total = std::mem::take(&mut self.prune_stats);
        for state in self.sequences.values() {
            for sel in &state.selectors {
                total.absorb(sel.take_prune_stats());
            }
        }
        total
    }

    /// Release a finished sequence's pages and its commitment.
    pub fn release(&mut self, seq_id: u64) {
        if let Some(mut state) = self.sequences.remove(&seq_id) {
            // Keep the sequence's pruning telemetry for the next drain.
            for sel in &state.selectors {
                self.prune_stats.absorb(sel.take_prune_stats());
            }
            for table in state.tables.iter_mut() {
                self.kv.release(table);
            }
        }
        if let Some(c) = self.commitments.remove(&seq_id) {
            self.committed_pages -= c;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(mode: AttentionMode) -> EngineConfig {
        EngineConfig {
            model: ModelConfig { head_dim: 32, n_kv_heads: 2, ..ModelConfig::tiny() },
            lsh: LshParams { p: 8, l: 20, tau: 0.5 },
            mode,
            capacity_pages: 512,
            sink: 4,
            local: 4,
        }
    }

    #[test]
    fn prefill_decode_release_roundtrip() {
        let mut e = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        assert!(e.prefill(1, 300, 8));
        assert_eq!(e.n_sequences(), 1);
        let out = e.decode_step(1);
        // One output per *query* head: the 2 kv heads each serve their
        // 4-head GQA group.
        assert_eq!(out.len(), e.config.model.n_heads);
        assert_eq!(out.len(), 8);
        assert_eq!(out[0].len(), 32);
        assert!(out[0].iter().any(|&x| x != 0.0));
        assert_eq!(e.decoded(1), 1);
        let free_before = e.free_pages();
        e.release(1);
        assert!(e.free_pages() > free_before);
        assert_eq!(e.n_sequences(), 0);
    }

    #[test]
    fn admissible_matches_pool_capacity() {
        let e = DecodeEngine::new(EngineConfig { capacity_pages: 8, ..cfg(AttentionMode::Dense) });
        // 2 kv-heads x pages_for(ctx + dec) must fit the 8-page pool.
        assert!(e.admissible(32, 16)); // 2 * 3 = 6
        assert!(e.admissible(48, 16)); // 2 * 4 = 8
        assert!(!e.admissible(64, 16)); // 2 * 5 = 10
    }

    #[test]
    fn backpressure_on_pool_exhaustion() {
        let mut e = DecodeEngine::new(EngineConfig { capacity_pages: 8, ..cfg(AttentionMode::Dense) });
        // 2 heads x ceil(300/16) pages >> 8.
        assert!(!e.prefill(1, 300, 8));
        assert_eq!(e.n_sequences(), 0);
        // A small context fits.
        assert!(e.prefill(2, 32, 8));
    }

    #[test]
    fn socket_output_close_to_dense() {
        // The whole point: sparse decode ≈ dense decode outputs.
        let mut dense = DecodeEngine::new(cfg(AttentionMode::Dense));
        let mut sparse = DecodeEngine::new(cfg(AttentionMode::socket(4.0)));
        assert!(dense.prefill(7, 400, 4));
        assert!(sparse.prefill(7, 400, 4));
        let yd = dense.decode_step(7);
        let ys = sparse.decode_step(7);
        assert_eq!(yd.len(), 8);
        for h in 0..8 {
            let rel = crate::metrics::output_relative_error(&ys[h], &yd[h]);
            assert!(rel < 0.5, "query head {h} rel err {rel}");
        }
    }

    #[test]
    fn every_registered_method_is_servable() {
        // The redesign's acceptance bar: any registry method decodes
        // over the paged pool — prefill builds its index from the view,
        // decode steps select + attend + extend the index.
        for spec in crate::selector::registry() {
            let mut e = DecodeEngine::new(cfg(AttentionMode::sparse(spec.name, 4.0)));
            assert!(e.prefill(1, 200, 4), "{} prefill", spec.name);
            for step in 0..2 {
                let out = e.decode_step(1);
                assert_eq!(out.len(), 8, "{} step {step}", spec.name);
                assert_eq!(out[0].len(), 32, "{}", spec.name);
                assert!(
                    out.iter().all(|y| y.iter().all(|x| x.is_finite())),
                    "{} non-finite output",
                    spec.name
                );
                assert!(
                    out[0].iter().any(|&x| x != 0.0),
                    "{} all-zero output",
                    spec.name
                );
            }
            assert_eq!(e.decoded(1), 2, "{}", spec.name);
            e.release(1);
        }
    }

    #[test]
    fn per_request_mode_overrides_engine_default() {
        // One engine, three sequences, three different modes — the
        // per-request configuration surface the server exposes.
        let mut e = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        assert!(e.prefill_as(1, 100, 4, None).unwrap());
        assert!(e.prefill_as(2, 100, 4, Some(&AttentionMode::Dense)).unwrap());
        assert!(e.prefill_as(3, 100, 4, Some(&AttentionMode::sparse("quest", 8.0))).unwrap());
        for seq in [1, 2, 3] {
            let out = e.decode_step(seq);
            assert_eq!(out.len(), 8);
            assert!(out[0].iter().any(|&x| x != 0.0), "seq {seq}");
        }
        // Identical sequence under the default mode on a fresh engine
        // matches seq 1 (override of None == engine default).
        let mut e2 = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        assert!(e2.prefill(1, 100, 4));
        assert_eq!(e2.decode_step(1), {
            let mut e3 = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
            assert!(e3.prefill(1, 100, 4));
            e3.decode_step(1)
        });
    }

    #[test]
    fn unknown_method_is_an_error_before_any_commitment() {
        let mut e = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        let free = e.free_pages();
        let bad = AttentionMode::sparse("definitely-not-a-method", 8.0);
        assert!(e.validate_mode(Some(&bad)).is_err());
        let err = e.prefill_as(1, 100, 4, Some(&bad)).unwrap_err();
        assert!(err.to_string().contains("unknown method"), "{err}");
        assert_eq!(e.free_pages(), free, "no pages may be committed");
        assert_eq!(e.n_sequences(), 0);
        // Engine default is valid.
        assert!(e.validate_mode(None).is_ok());
    }

    #[test]
    fn multi_sequence_isolation() {
        let mut e = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        assert!(e.prefill(1, 100, 8));
        assert!(e.prefill(2, 150, 8));
        let o1a = e.decode_step(1);
        let _ = e.decode_step(2);
        // Re-running seq 1's step-0 computation via a fresh engine gives
        // identical output (determinism + isolation).
        let mut e2 = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        assert!(e2.prefill(1, 100, 8));
        let o1b = e2.decode_step(1);
        assert_eq!(o1a, o1b);
    }

    #[test]
    fn mha_config_group_of_one_still_serves() {
        // n_kv_heads == n_heads is plain MHA: every GQA group has one
        // query head and the lane degrades to the scalar path.
        let mut e = DecodeEngine::new(EngineConfig {
            model: ModelConfig { head_dim: 32, n_kv_heads: 8, ..ModelConfig::tiny() },
            ..cfg(AttentionMode::socket(8.0))
        });
        assert!(e.prefill(1, 100, 4));
        let out = e.decode_step(1);
        assert_eq!(out.len(), 8);
        assert!(out.iter().all(|y| y.iter().all(|x| x.is_finite())));
    }

    #[test]
    #[should_panic(expected = "decode before prefill")]
    fn decode_unknown_sequence_panics() {
        let mut e = DecodeEngine::new(cfg(AttentionMode::Dense));
        e.decode_step(42);
    }

    #[test]
    fn session_extend_is_bit_identical_to_from_scratch_concat() {
        // The session tentpole's core property: turn 1 (prefill N1,
        // decode M1) + session_extend(N2) + turn-2 decode must produce
        // *bit-identical* outputs to a fresh sequence prefilled over the
        // concatenated N1 + M1 + N2 context. Output equality pins the
        // selected indices and scores too: flash-decode attends only
        // over the selector's merged selection, so any index or score
        // divergence shows up in the outputs. Checked for socket and
        // oracle (the issue's pair), plus dense as the control.
        for mode in
            [AttentionMode::socket(4.0), AttentionMode::sparse("oracle", 4.0), AttentionMode::Dense]
        {
            let (n1, m1, n2, m2) = (150usize, 3usize, 80usize, 4usize);
            let mut sess = DecodeEngine::new(cfg(mode.clone()));
            assert!(sess.prefill(5, n1, m1), "{mode:?} turn-1 prefill");
            for _ in 0..m1 {
                sess.decode_step(5);
            }
            assert!(sess.session_extend(5, n2, m2), "{mode:?} extend");
            assert_eq!(sess.sequence_tokens(5), Some(n1 + m1 + n2));
            let got: Vec<_> = (0..m2).map(|_| sess.decode_step(5)).collect();

            let mut fresh = DecodeEngine::new(cfg(mode.clone()));
            assert!(fresh.prefill(5, n1 + m1 + n2, m2), "{mode:?} from-scratch prefill");
            let want: Vec<_> = (0..m2).map(|_| fresh.decode_step(5)).collect();
            assert_eq!(got, want, "{mode:?} resumed decode diverged from from-scratch");
        }
    }

    #[test]
    fn session_extend_backpressure_and_release() {
        // 16 pages x 16 tokens / 2 kv-heads = 128 cacheable tokens per
        // head stream. A 64-token turn fits; extending past the pool's
        // commitment capacity must refuse without touching state.
        let mut e =
            DecodeEngine::new(EngineConfig { capacity_pages: 16, ..cfg(AttentionMode::socket(4.0)) });
        assert!(e.prefill(1, 64, 4));
        let tokens_before = e.sequence_tokens(1).unwrap();
        let free_before = e.free_pages();
        assert!(!e.session_extend(1, 4096, 4), "oversized extend must refuse");
        assert_eq!(e.sequence_tokens(1), Some(tokens_before), "refused extend must not append");
        assert_eq!(e.free_pages(), free_before);
        // A small extend within the pool succeeds and appends.
        assert!(e.session_extend(1, 32, 4));
        assert_eq!(e.sequence_tokens(1), Some(96));
        // Release returns everything (extend's commitment included).
        let total_free = e.free_pages();
        e.release(1);
        assert!(e.free_pages() > total_free);
        assert!(!e.has_sequence(1));
        assert!(e.prefill(2, 64, 4), "pool must be reusable after release");
    }

    #[test]
    fn prune_stats_drain_from_live_and_released_sequences() {
        let mut e = DecodeEngine::new(cfg(AttentionMode::socket(4.0)));
        assert!(e.prefill(1, 300, 4));
        e.decode_step(1);
        let live = e.take_prune_stats();
        assert!(live.blocks > 0, "socket decode must record visited blocks: {live:?}");
        assert_eq!(e.take_prune_stats(), PruneStats::default(), "drain must reset");
        // Telemetry from a released sequence survives until drained.
        e.decode_step(1);
        e.release(1);
        assert!(e.take_prune_stats().blocks > 0, "release must keep undrained telemetry");
    }

    #[test]
    fn decode_batch_matches_serial_steps() {
        // The pooled batch path must be step-for-step identical to
        // serial decode_step calls (same selection, same outputs, same
        // cache state afterwards) — including with mixed per-sequence
        // methods in one batch.
        let mut serial = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        let mut batched = DecodeEngine::new(cfg(AttentionMode::socket(8.0)));
        let seqs = [1u64, 2, 3];
        let modes: [Option<AttentionMode>; 3] =
            [None, Some(AttentionMode::sparse("quest", 8.0)), Some(AttentionMode::Dense)];
        for (&(seq, ctx), mode) in [(1u64, 120usize), (2, 200), (3, 64)].iter().zip(&modes) {
            assert!(serial.prefill_as(seq, ctx, 4, mode.as_ref()).unwrap());
            assert!(batched.prefill_as(seq, ctx, 4, mode.as_ref()).unwrap());
        }
        for _ in 0..3 {
            let want: Vec<Vec<Vec<f32>>> = seqs.iter().map(|&s| serial.decode_step(s)).collect();
            let got = batched.decode_batch(&seqs);
            assert_eq!(got, want);
        }
        for &s in &seqs {
            assert_eq!(serial.decoded(s), 3);
            assert_eq!(batched.decoded(s), 3);
        }
    }
}
