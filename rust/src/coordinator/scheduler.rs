//! The scheduler: a worker thread driving admit → step iterations over
//! the [`DecodeEngine`], with an mpsc submission queue and per-request
//! completion channels. This is the leader loop of the serving stack.

use super::batcher::{BatchPolicy, Batcher};
use super::engine::{DecodeEngine, EngineConfig};
use crate::workload::trace::Request;
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::Instant;

/// Completion record returned for every finished request.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub context_len: usize,
    pub decode_len: usize,
    /// Time from submission to first decoded token, ms.
    pub ttft_ms: f64,
    /// Time from submission to completion, ms.
    pub total_ms: f64,
}

/// Aggregate scheduler statistics.
#[derive(Clone, Debug, Default)]
pub struct SchedulerStats {
    pub completed: usize,
    pub decode_steps: u64,
    pub prefill_tokens: u64,
    pub rejected_admissions: u64,
}

enum Msg {
    Submit(Request, Sender<Completion>),
    Shutdown,
}

/// Handle for awaiting one request's completion.
pub struct RequestHandle {
    rx: Receiver<Completion>,
}

impl RequestHandle {
    /// Block until the request completes.
    pub fn wait(self) -> Completion {
        self.rx.recv().expect("scheduler dropped before completing request")
    }
}

/// The coordinator: spawns the scheduler thread, routes requests in.
pub struct Coordinator {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<SchedulerStats>>,
}

struct Inflight {
    req: Request,
    submitted: Instant,
    first_token: Option<Instant>,
    done_tx: Sender<Completion>,
}

impl Coordinator {
    /// Spawn the scheduler over a fresh engine.
    pub fn spawn(config: EngineConfig, policy: BatchPolicy) -> Coordinator {
        let (tx, rx) = channel::<Msg>();
        let worker = std::thread::spawn(move || scheduler_loop(config, policy, rx));
        Coordinator { tx, worker: Some(worker) }
    }

    /// Submit a request; returns a handle to await completion.
    pub fn submit(&self, req: Request) -> RequestHandle {
        let (done_tx, done_rx) = channel();
        self.tx.send(Msg::Submit(req, done_tx)).expect("scheduler gone");
        RequestHandle { rx: done_rx }
    }

    /// Stop the scheduler (after draining in-flight work) and return
    /// aggregate stats.
    pub fn shutdown(mut self) -> SchedulerStats {
        let _ = self.tx.send(Msg::Shutdown);
        self.worker.take().unwrap().join().expect("scheduler panicked")
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            let _ = self.tx.send(Msg::Shutdown);
            let _ = w.join();
        }
    }
}

fn scheduler_loop(config: EngineConfig, policy: BatchPolicy, rx: Receiver<Msg>) -> SchedulerStats {
    let mut engine = DecodeEngine::new(config);
    let mut batcher = Batcher::new(policy);
    let mut inflight: HashMap<u64, Inflight> = HashMap::new();
    let mut stats = SchedulerStats::default();
    let mut draining = false;

    loop {
        // Drain the submission queue without blocking (block only when
        // fully idle to avoid a busy-spin).
        loop {
            let idle = batcher.waiting_len() == 0 && batcher.running_len() == 0;
            if idle && !draining {
                match rx.recv() {
                    Ok(Msg::Submit(req, done_tx)) => {
                        batcher.enqueue(req.id, req.context_len);
                        inflight.insert(
                            req.id,
                            Inflight { req, submitted: Instant::now(), first_token: None, done_tx },
                        );
                    }
                    Ok(Msg::Shutdown) | Err(_) => draining = true,
                }
                continue;
            }
            match rx.try_recv() {
                Ok(Msg::Submit(req, done_tx)) => {
                    batcher.enqueue(req.id, req.context_len);
                    inflight.insert(
                        req.id,
                        Inflight { req, submitted: Instant::now(), first_token: None, done_tx },
                    );
                }
                Ok(Msg::Shutdown) => draining = true,
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => draining = true,
            }
            if draining {
                break;
            }
        }
        if draining && batcher.waiting_len() == 0 && batcher.running_len() == 0 {
            return stats;
        }

        let batch = batcher.next_batch();
        if batch.is_empty() {
            if draining {
                return stats;
            }
            continue;
        }
        // Prefills (admission may fail under KV pressure → requeue).
        for &(seq, ctx) in batch.prefills.iter() {
            let decode_len = inflight.get(&seq).map(|f| f.req.decode_len).unwrap_or(0);
            if engine.prefill(seq, ctx, decode_len) {
                batcher.started(seq);
                stats.prefill_tokens += ctx as u64;
            } else {
                stats.rejected_admissions += 1;
                batcher.requeue(seq, ctx);
            }
        }
        // Decode steps: one batched call — sequences score their keys
        // across the shared worker pool, appends commit in batch order.
        if !batch.decodes.is_empty() {
            let _outputs = engine.decode_batch(&batch.decodes);
        }
        for &seq in batch.decodes.iter() {
            stats.decode_steps += 1;
            let fl = inflight.get_mut(&seq).expect("decode for unknown request");
            if fl.first_token.is_none() {
                fl.first_token = Some(Instant::now());
            }
            if engine.decoded(seq) >= fl.req.decode_len {
                // Finished.
                let fl = inflight.remove(&seq).unwrap();
                let now = Instant::now();
                let completion = Completion {
                    id: seq,
                    context_len: fl.req.context_len,
                    decode_len: fl.req.decode_len,
                    ttft_ms: fl
                        .first_token
                        .unwrap_or(now)
                        .duration_since(fl.submitted)
                        .as_secs_f64()
                        * 1e3,
                    total_ms: now.duration_since(fl.submitted).as_secs_f64() * 1e3,
                };
                let _ = fl.done_tx.send(completion);
                batcher.finished(seq);
                engine.release(seq);
                stats.completed += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::AttentionMode;
    use crate::lsh::LshParams;
    use crate::model::ModelConfig;

    fn small_config() -> EngineConfig {
        EngineConfig {
            model: ModelConfig { head_dim: 16, n_kv_heads: 1, ..ModelConfig::tiny() },
            lsh: LshParams { p: 6, l: 8, tau: 0.5 },
            mode: AttentionMode::Socket { sparsity: 8.0 },
            capacity_pages: 2048,
            sink: 4,
            local: 4,
        }
    }

    fn req(id: u64, ctx: usize, dec: usize) -> Request {
        Request { id, arrival_ms: 0.0, context_len: ctx, decode_len: dec }
    }

    #[test]
    fn single_request_completes() {
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let h = coord.submit(req(1, 128, 4));
        let c = h.wait();
        assert_eq!(c.id, 1);
        assert_eq!(c.decode_len, 4);
        assert!(c.ttft_ms <= c.total_ms);
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.decode_steps, 4);
        assert_eq!(stats.prefill_tokens, 128);
    }

    #[test]
    fn concurrent_requests_all_complete() {
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let handles: Vec<RequestHandle> =
            (0..8).map(|i| coord.submit(req(i, 64 + 16 * i as usize, 3))).collect();
        let mut ids: Vec<u64> = handles.into_iter().map(|h| h.wait().id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 8);
        assert_eq!(stats.decode_steps, 24);
    }

    #[test]
    fn backpressure_requeues_and_eventually_admits() {
        // Tiny pool: only ~2 sequences fit at once; the rest must wait
        // for releases.
        let config = EngineConfig { capacity_pages: 24, ..small_config() };
        let coord = Coordinator::spawn(config, BatchPolicy { max_prefills: 4, ..Default::default() });
        let handles: Vec<RequestHandle> =
            (0..6).map(|i| coord.submit(req(i, 128, 2))).collect();
        for h in handles {
            h.wait();
        }
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 6);
        assert!(stats.rejected_admissions > 0, "expected KV backpressure");
    }

    #[test]
    fn shutdown_drains_inflight() {
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let h = coord.submit(req(9, 64, 10));
        let stats = coord.shutdown(); // shutdown while decoding
        assert_eq!(stats.completed, 1, "in-flight request must drain");
        let c = h.wait();
        assert_eq!(c.decode_len, 10);
    }
}
