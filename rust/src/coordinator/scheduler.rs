//! The scheduler: a worker thread driving admit → step iterations over
//! the [`DecodeEngine`], with an mpsc submission queue and per-request
//! completion channels. This is the leader loop of the serving stack.
//!
//! Beyond one-shot requests, the loop serves the session/streaming
//! surface: a [`Submission`] may ask to *keep* its sequence alive after
//! the turn (`keep_alive` — the pages and selector index park in the
//! scheduler until resumed or released), to *resume* a parked sequence
//! (`resume` — the turn's context is appended via
//! [`DecodeEngine::session_extend`], never re-prefilled), and to stream
//! per-token [`TokenEvent`]s as they decode. Latency telemetry (TTFT,
//! inter-token gaps, per-method outcomes, pruning counters) feeds the
//! shared [`Registry`] as a side effect of the loop — no extra locks on
//! the hot path.

// lint:allow-file(atomics-allowlist): the loop's only atomics are the
// Registry's own outcome counters (fed in place to avoid a lock); the
// cells and their memory-ordering contract live in metrics/registry.rs.

use super::batcher::{BatchPolicy, Batcher};
use super::engine::{AttentionMode, DecodeEngine, EngineConfig, PrefillProgress};
use crate::metrics::registry::Registry;
use crate::selector;
#[cfg(test)]
use crate::testing::faults::FaultPlan;
use crate::util::Json;
use crate::workload::trace::{Priority, Request};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Completion record returned for every finished request — served or
/// failed (`ok` distinguishes; failed completions carry `error`).
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub context_len: usize,
    pub decode_len: usize,
    /// Time from submission to first decoded token, ms.
    pub ttft_ms: f64,
    /// Time from submission to completion, ms.
    pub total_ms: f64,
    /// Whether the request was actually served. False for requests the
    /// scheduler rejected up front (e.g. a KV commitment that could
    /// never fit the pool).
    pub ok: bool,
    /// Failure reason when `ok` is false.
    pub error: Option<String>,
}

/// Aggregate scheduler statistics.
#[derive(Clone, Debug, Default)]
pub struct SchedulerStats {
    pub completed: usize,
    pub decode_steps: u64,
    /// Context tokens prefilled for *fresh* sequences. Resumed session
    /// turns never add here — that is the point of sessions.
    pub prefill_tokens: u64,
    pub rejected_admissions: u64,
    /// Requests failed up front: their full KV commitment exceeds the
    /// pool, so no amount of waiting could ever admit them.
    pub failed_requests: u64,
    /// Context tokens appended to parked sessions by resumed turns
    /// (the tokens that did *not* re-prefill).
    pub session_tokens: u64,
    /// Resumed session turns admitted.
    pub resumed_turns: u64,
    /// Parked sessions released via [`Coordinator::release`] (TTL
    /// eviction or explicit teardown).
    pub sessions_released: u64,
}

impl SchedulerStats {
    /// The metrics-schema `scheduler` section.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("completed", self.completed)
            .set("decode_steps", self.decode_steps)
            .set("prefill_tokens", self.prefill_tokens)
            .set("rejected_admissions", self.rejected_admissions)
            .set("failed_requests", self.failed_requests)
            .set("session_tokens", self.session_tokens)
            .set("resumed_turns", self.resumed_turns)
            .set("sessions_released", self.sessions_released)
    }
}

/// One decoded token's notification on a streaming submission.
#[derive(Clone, Copy, Debug)]
pub struct TokenEvent {
    /// 0-based index of the token within its turn.
    pub index: usize,
    /// Milliseconds since the turn was submitted.
    pub ms: f64,
}

/// A request plus its serving options — the full submission surface
/// (sessions, streaming) over the plain [`Request`] shape.
pub struct Submission {
    pub req: Request,
    /// Park the sequence (KV pages + selector index stay committed)
    /// after the turn completes instead of releasing it, so a later
    /// `resume` submission can extend it. Parked sequences are freed
    /// with [`Coordinator::release`].
    pub keep_alive: bool,
    /// Resume a parked sequence: `req.id` names it, `req.context_len`
    /// is the *additional* context this turn appends (0 = continue
    /// decoding). No prefill runs; `req.mode` is ignored — a sequence's
    /// attention mode is fixed when it is first prefilled.
    pub resume: bool,
    /// Per-token stream: the scheduler sends one event per decoded
    /// token. The channel disconnects after the turn's completion is
    /// delivered, so receivers can drain it to exhaustion safely.
    pub tokens: Option<Sender<TokenEvent>>,
}

impl Submission {
    /// A plain one-shot submission (no session, no streaming).
    pub fn oneshot(req: Request) -> Submission {
        Submission { req, keep_alive: false, resume: false, tokens: None }
    }
}

/// Point-in-time view of the engine + scheduler, for the metrics
/// endpoint (served without stopping the loop).
#[derive(Clone, Debug)]
pub struct EngineSnapshot {
    pub free_pages: usize,
    pub total_pages: usize,
    /// Sequences holding pages right now — running and parked.
    pub live_sequences: usize,
    /// Sequences parked between session turns.
    pub parked_sessions: usize,
    pub stats: SchedulerStats,
}

enum Msg {
    Submit(Submission, Sender<Completion>),
    /// Release a parked session's pages (idle-TTL eviction path).
    Release(u64),
    Snapshot(Sender<EngineSnapshot>),
    /// Swap the batch-assembly policy in place (hot reload). Applies
    /// from the next iteration; queued and running work is unaffected.
    SetPolicy(BatchPolicy),
    /// Arm a deterministic admission-fault plan on the engine (test
    /// builds only — the degradation paths' test harness).
    #[cfg(test)]
    SetFaults(FaultPlan),
    Shutdown,
}

/// Handle for awaiting one request's completion.
pub struct RequestHandle {
    rx: Receiver<Completion>,
    id: u64,
    context_len: usize,
    decode_len: usize,
}

impl RequestHandle {
    /// The error completion reported when the scheduler disappears
    /// without answering — a serving failure, never a caller panic.
    fn lost(&self) -> Completion {
        Completion {
            id: self.id,
            context_len: self.context_len,
            decode_len: self.decode_len,
            ttft_ms: 0.0,
            total_ms: 0.0,
            ok: false,
            error: Some("scheduler dropped before completing request".to_string()),
        }
    }

    /// Block until the request completes. If the scheduler thread is
    /// gone, returns a failed completion instead of panicking (a dead
    /// scheduler must not take connection handlers down with it).
    pub fn wait(self) -> Completion {
        self.rx.recv().unwrap_or_else(|_| self.lost())
    }

    /// Block until the request completes or `timeout` elapses. `None`
    /// on timeout — the request is still in flight and the handle
    /// remains usable for another wait. A vanished scheduler yields a
    /// failed completion, not a panic.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Completion> {
        match self.rx.recv_timeout(timeout) {
            Ok(c) => Some(c),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => Some(self.lost()),
        }
    }
}

/// The coordinator: spawns the scheduler thread, routes requests in.
pub struct Coordinator {
    tx: Sender<Msg>,
    metrics: Arc<Registry>,
    worker: Option<JoinHandle<SchedulerStats>>,
}

struct Inflight {
    req: Request,
    submitted: Instant,
    first_token: Option<Instant>,
    last_token: Option<Instant>,
    /// Tokens already decoded when this turn started (non-zero for
    /// resumed sessions — completion is measured per turn).
    base_decoded: usize,
    keep_alive: bool,
    resume: bool,
    /// Canonical method label for the metrics registry.
    label: String,
    /// Context tokens made resident so far (chunked prefill progress;
    /// reset to 0 when the sequence is preempted for recompute).
    filled: usize,
    /// Token events already delivered on the stream. A preempted
    /// sequence recomputes its decoded tokens bit-identically, so this
    /// high-water mark is what keeps the stream free of duplicates.
    emitted: usize,
    tokens: Option<Sender<TokenEvent>>,
    done_tx: Sender<Completion>,
}

impl Coordinator {
    /// Spawn the scheduler over a fresh engine.
    pub fn spawn(config: EngineConfig, policy: BatchPolicy) -> Coordinator {
        let (tx, rx) = channel::<Msg>();
        let metrics = Arc::new(Registry::new());
        let loop_metrics = Arc::clone(&metrics);
        let worker = std::thread::spawn(move || scheduler_loop(config, policy, rx, loop_metrics));
        Coordinator { tx, metrics, worker: Some(worker) }
    }

    /// The shared metrics registry the scheduler feeds.
    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    /// Submit a one-shot request; returns a handle to await completion.
    pub fn submit(&self, req: Request) -> RequestHandle {
        self.submit_opts(Submission::oneshot(req))
    }

    /// Submit with full serving options (sessions, streaming). If the
    /// scheduler thread is gone the returned handle resolves to a
    /// failed completion — submission never panics.
    pub fn submit_opts(&self, sub: Submission) -> RequestHandle {
        let (done_tx, done_rx) = channel();
        let handle = RequestHandle {
            rx: done_rx,
            id: sub.req.id,
            context_len: sub.req.context_len,
            decode_len: sub.req.decode_len,
        };
        if self.tx.send(Msg::Submit(sub, done_tx.clone())).is_err() {
            let _ = done_tx.send(Completion {
                error: Some("scheduler unavailable".to_string()),
                ok: false,
                ..handle.lost()
            });
        }
        handle
    }

    /// Release a parked session's pages back to the pool (the idle-TTL
    /// eviction path). Unknown or busy ids are ignored.
    pub fn release(&self, seq_id: u64) {
        let _ = self.tx.send(Msg::Release(seq_id));
    }

    /// Replace the scheduler's batch policy without restarting it (the
    /// server's hot-reload path). Takes effect from the next iteration.
    pub fn set_policy(&self, policy: BatchPolicy) {
        let _ = self.tx.send(Msg::SetPolicy(policy));
    }

    /// Arm a deterministic admission-fault plan on the scheduler's
    /// engine (test builds only). Ordered with submissions on the same
    /// queue, so arm-then-submit is race-free.
    #[cfg(test)]
    pub fn inject_faults(&self, plan: FaultPlan) {
        let _ = self.tx.send(Msg::SetFaults(plan));
    }

    /// Snapshot engine occupancy + scheduler stats without stopping the
    /// loop. `None` if the scheduler thread is gone. Ordered after any
    /// earlier `release`/`submit` from this coordinator (same queue).
    pub fn snapshot(&self) -> Option<EngineSnapshot> {
        let (tx, rx) = channel();
        self.tx.send(Msg::Snapshot(tx)).ok()?;
        rx.recv().ok()
    }

    /// Signal shutdown without waiting: the loop finishes draining its
    /// in-flight work, then exits. Unlike [`Coordinator::shutdown`]
    /// this borrows, so other threads may still hold the coordinator —
    /// the shutdown-while-submitting race is part of the contract.
    /// Submissions that lose the race never hang: a queued-but-unread
    /// submission resolves to a failed completion when the worker's
    /// queue receiver drops, and a post-exit submission fails at send
    /// time (see [`Coordinator::submit_opts`]).
    pub fn begin_shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }

    /// Stop the scheduler (after draining in-flight work) and return
    /// aggregate stats.
    pub fn shutdown(mut self) -> SchedulerStats {
        let _ = self.tx.send(Msg::Shutdown);
        self.worker.take().unwrap().join().expect("scheduler panicked")
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            let _ = self.tx.send(Msg::Shutdown);
            let _ = w.join();
        }
    }
}

/// Canonical metrics label for a mode: the registry's canonical method
/// name (aliases folded), `"dense"`, or the raw label when unregistered
/// (the registry buckets those under `other`).
fn canonical_label(mode: &AttentionMode) -> String {
    match mode {
        AttentionMode::Dense => "dense".to_string(),
        AttentionMode::Sparse { method, .. } => selector::lookup(method)
            .map(|spec| spec.name.to_string())
            .unwrap_or_else(|_| method.clone()),
    }
}

/// Fail a request with an error completion (the one shape both the
/// accept-time and prefill-time failure paths emit).
fn send_failure(
    done_tx: &Sender<Completion>,
    req: &Request,
    error: String,
    stats: &mut SchedulerStats,
    metrics: &Registry,
    label: &str,
) {
    stats.failed_requests += 1;
    // Relaxed: independent outcome counter; nothing orders against it.
    metrics.method(label).failed.fetch_add(1, Ordering::Relaxed);
    let _ = done_tx.send(Completion {
        id: req.id,
        context_len: req.context_len,
        decode_len: req.decode_len,
        ttft_ms: 0.0,
        total_ms: 0.0,
        ok: false,
        error: Some(error),
    });
}

/// Accept a submission into the waiting queue, or fail it immediately
/// when it could never be served: a KV commitment that cannot fit the
/// pool (pre-fix, such a request was requeued by every iteration
/// forever — no running sequence can release enough pages to make it
/// fit, so the scheduler livelocked in a hot spin), an attention mode
/// naming no registered selector, or a resume of a sequence the
/// scheduler is not holding parked.
#[allow(clippy::too_many_arguments)]
fn accept(
    engine: &DecodeEngine,
    batcher: &mut Batcher,
    inflight: &mut HashMap<u64, Inflight>,
    parked: &mut HashSet<u64>,
    deadlines: &mut BinaryHeap<Reverse<(Instant, u64, Instant)>>,
    stats: &mut SchedulerStats,
    metrics: &Registry,
    sub: Submission,
    done_tx: Sender<Completion>,
) {
    let Submission { req, keep_alive, resume, tokens } = sub;
    if resume {
        // The sequence must be parked — not running, not unknown. All
        // session state lives on this thread, so there is no window
        // where an eviction races a resume.
        if !parked.remove(&req.id) {
            let label = engine
                .sequence_method_label(req.id)
                .map(|l| canonical_label(&AttentionMode::sparse(l, 1.0)))
                .unwrap_or_else(|| "other".to_string());
            let error = format!("sequence {} is not a parked session (unknown or busy)", req.id);
            send_failure(&done_tx, &req, error, stats, metrics, &label);
            return;
        }
        let current = engine.sequence_tokens(req.id).unwrap_or(0);
        if !engine.admissible(current + req.context_len, req.decode_len) {
            // The turn can never fit, but the session itself is fine:
            // re-park it so smaller follow-up turns still work.
            parked.insert(req.id);
            let label = match engine.sequence_method_label(req.id) {
                Some("dense") => "dense".to_string(),
                Some(l) => canonical_label(&AttentionMode::sparse(l, 1.0)),
                None => "other".to_string(),
            };
            let error = format!(
                "never admittable: session holds {} tokens; +{} context +{} decode exceeds the {}-page KV pool",
                current, req.context_len, req.decode_len, engine.config.capacity_pages
            );
            send_failure(&done_tx, &req, error, stats, metrics, &label);
            return;
        }
        let label = match engine.sequence_method_label(req.id) {
            Some("dense") | None => "dense".to_string(),
            Some(l) => canonical_label(&AttentionMode::sparse(l, 1.0)),
        };
        // `whole = true`: a resumed turn extends in one shot
        // (session_extend), so it keeps the offered-alone exemption
        // instead of chunking.
        if !batcher.try_enqueue(req.id, req.context_len, req.priority, true) {
            // Shed — but the session itself survives, re-parked.
            parked.insert(req.id);
            // Relaxed: independent monotone counter; read only by the
            // metrics endpoint, nothing orders against it.
            metrics.pressure.shed.fetch_add(1, Ordering::Relaxed);
            let error = format!(
                "queue_full: waiting queue at its {}-request bound",
                batcher.policy.max_waiting
            );
            send_failure(&done_tx, &req, error, stats, metrics, &label);
            return;
        }
        let submitted = Instant::now();
        push_deadline(deadlines, &req, submitted);
        inflight.insert(
            req.id,
            Inflight {
                base_decoded: engine.decoded(req.id),
                submitted,
                first_token: None,
                last_token: None,
                keep_alive,
                resume: true,
                label,
                filled: 0,
                emitted: 0,
                tokens,
                done_tx,
                req,
            },
        );
        return;
    }
    let label = canonical_label(req.mode.as_ref().unwrap_or(&engine.config.mode));
    if let Err(e) = engine.validate_mode(req.mode.as_ref()) {
        send_failure(&done_tx, &req, e.to_string(), stats, metrics, &label);
        return;
    }
    if inflight.contains_key(&req.id) || engine.has_sequence(req.id) {
        let error = format!("sequence id {} is already in use", req.id);
        send_failure(&done_tx, &req, error, stats, metrics, &label);
        return;
    }
    if !engine.admissible(req.context_len, req.decode_len) {
        let error = format!(
            "never admittable: {} context + {} decode tokens exceed the {}-page KV pool",
            req.context_len, req.decode_len, engine.config.capacity_pages
        );
        send_failure(&done_tx, &req, error, stats, metrics, &label);
        return;
    }
    if !batcher.try_enqueue(req.id, req.context_len, req.priority, false) {
        // Relaxed: independent monotone counter; read only by the
        // metrics endpoint, nothing orders against it.
        metrics.pressure.shed.fetch_add(1, Ordering::Relaxed);
        let error = format!(
            "queue_full: waiting queue at its {}-request bound",
            batcher.policy.max_waiting
        );
        send_failure(&done_tx, &req, error, stats, metrics, &label);
        return;
    }
    let submitted = Instant::now();
    push_deadline(deadlines, &req, submitted);
    inflight.insert(
        req.id,
        Inflight {
            submitted,
            first_token: None,
            last_token: None,
            base_decoded: 0,
            keep_alive,
            resume: false,
            label,
            filled: 0,
            emitted: 0,
            tokens,
            done_tx,
            req,
        },
    );
}

/// Register a request's scheduling deadline, if it carries one.
/// `deadline_ms` bounds *time to first schedule*: a request still
/// waiting when it expires is shed; once its prefill starts it runs to
/// completion (abandoning admitted work would waste the pages already
/// spent on it). The submitted instant rides along as an identity check
/// so a reused sequence id can never be shed by a stale entry.
fn push_deadline(
    deadlines: &mut BinaryHeap<Reverse<(Instant, u64, Instant)>>,
    req: &Request,
    submitted: Instant,
) {
    if let Some(ms) = req.deadline_ms {
        if ms.is_finite() {
            let expires = submitted + Duration::from_secs_f64(ms.max(0.0) / 1e3);
            deadlines.push(Reverse((expires, req.id, submitted)));
        }
    }
}

/// Shed every request whose scheduling deadline expired while it was
/// still waiting. Started, finished, and re-submitted sequences are
/// skipped (their heap entries are stale).
fn shed_expired(
    batcher: &mut Batcher,
    inflight: &mut HashMap<u64, Inflight>,
    parked: &mut HashSet<u64>,
    deadlines: &mut BinaryHeap<Reverse<(Instant, u64, Instant)>>,
    stats: &mut SchedulerStats,
    metrics: &Registry,
) {
    let now = Instant::now();
    while let Some(&Reverse((expires, seq, submitted))) = deadlines.peek() {
        if expires > now {
            break;
        }
        deadlines.pop();
        // Identity check: the entry only applies to the submission it
        // was pushed for, and only while that submission still waits.
        if inflight.get(&seq).map(|fl| fl.submitted) != Some(submitted) {
            continue;
        }
        if inflight.get(&seq).is_some_and(|fl| fl.first_token.is_some()) {
            // A preempted sequence back in the queue already had its
            // first schedule (and streamed tokens); the TTFS bound no
            // longer applies — it runs to completion.
            continue;
        }
        if !batcher.remove_waiting(seq) {
            continue; // already prefilling or decoding — runs to completion
        }
        let fl = inflight.remove(&seq).expect("checked above");
        if fl.resume {
            // The turn is shed; the parked session survives.
            parked.insert(seq);
        }
        // Relaxed: independent monotone counter; read only by the
        // metrics endpoint, nothing orders against it.
        metrics.pressure.deadline_missed.fetch_add(1, Ordering::Relaxed);
        let waited = now.duration_since(fl.submitted).as_secs_f64() * 1e3;
        let error = format!(
            "deadline_missed: still queued after {waited:.1} ms (deadline {:.1} ms)",
            fl.req.deadline_ms.unwrap_or(0.0)
        );
        send_failure(&fl.done_tx, &fl.req, error, stats, metrics, &fl.label);
    }
}

/// Choose a preemption victim for a `prio`-class admission that found
/// the pool exhausted: the lowest-priority running sequence strictly
/// below `prio`; among equals, the latest-submitted (least sunk cost).
/// Sessions — parked-to-be (`keep_alive`) or resumed turns — are never
/// preempted: their multi-turn state is not reconstructible by the
/// recompute path.
fn pick_victim(
    batcher: &Batcher,
    inflight: &HashMap<u64, Inflight>,
    prio: Priority,
) -> Option<u64> {
    batcher
        .running_seqs()
        .into_iter()
        .filter_map(|seq| inflight.get(&seq).map(|fl| (seq, fl)))
        .filter(|(_, fl)| !fl.keep_alive && !fl.resume && fl.req.priority < prio)
        .min_by_key(|(_, fl)| (fl.req.priority, Reverse(fl.submitted)))
        .map(|(seq, _)| seq)
}

fn snapshot_of(
    engine: &DecodeEngine,
    parked: &HashSet<u64>,
    stats: &SchedulerStats,
) -> EngineSnapshot {
    EngineSnapshot {
        free_pages: engine.free_pages(),
        total_pages: engine.config.capacity_pages,
        live_sequences: engine.n_sequences(),
        parked_sessions: parked.len(),
        stats: stats.clone(),
    }
}

/// Deliver a finished turn: completion out, sequence parked or
/// released, counters updated. (The token channel, if any, disconnects
/// when `fl` drops — *after* the completion is in the channel, so
/// streaming consumers can drain tokens then read the summary.)
fn finish_turn(
    engine: &mut DecodeEngine,
    parked: &mut HashSet<u64>,
    stats: &mut SchedulerStats,
    metrics: &Registry,
    seq: u64,
    fl: Inflight,
    ttft_ms: f64,
    total_ms: f64,
) {
    let _ = fl.done_tx.send(Completion {
        id: seq,
        context_len: fl.req.context_len,
        decode_len: fl.req.decode_len,
        ttft_ms,
        total_ms,
        ok: true,
        error: None,
    });
    if fl.keep_alive {
        parked.insert(seq);
    } else {
        engine.release(seq);
    }
    stats.completed += 1;
    // Relaxed: independent outcome counter; nothing orders against it.
    metrics.method(&fl.label).served.fetch_add(1, Ordering::Relaxed);
}

fn scheduler_loop(
    config: EngineConfig,
    policy: BatchPolicy,
    rx: Receiver<Msg>,
    metrics: Arc<Registry>,
) -> SchedulerStats {
    let mut engine = DecodeEngine::new(config);
    let mut batcher = Batcher::new(policy);
    let mut inflight: HashMap<u64, Inflight> = HashMap::new();
    let mut parked: HashSet<u64> = HashSet::new();
    // Min-heap of (expiry, seq, submitted-identity) scheduling
    // deadlines, swept before every batch.
    let mut deadlines: BinaryHeap<Reverse<(Instant, u64, Instant)>> = BinaryHeap::new();
    let mut stats = SchedulerStats::default();
    let mut draining = false;
    // One accounting audit per drain-to-idle transition (re-armed by
    // every batch that runs), not per queue-poll iteration.
    let mut audited = false;

    loop {
        // Drain the submission queue without blocking (block only when
        // fully idle to avoid a busy-spin).
        loop {
            let idle = batcher.waiting_len() == 0 && batcher.running_len() == 0;
            if idle && !audited {
                // Fully drained: every page must be accounted for by
                // the prefix tree or a live sequence (parked included).
                // Drift here is a refcount leak — fail loudly, now.
                engine.page_accounting().expect("page accounting after scheduler drain");
                audited = true;
            }
            let msg = if idle && !draining {
                match rx.recv() {
                    Ok(m) => Some(m),
                    Err(_) => {
                        draining = true;
                        None
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        draining = true;
                        None
                    }
                }
            };
            match msg {
                Some(Msg::Submit(sub, done_tx)) => accept(
                    &engine,
                    &mut batcher,
                    &mut inflight,
                    &mut parked,
                    &mut deadlines,
                    &mut stats,
                    &metrics,
                    sub,
                    done_tx,
                ),
                Some(Msg::Release(seq)) => {
                    if parked.remove(&seq) {
                        engine.release(seq);
                        stats.sessions_released += 1;
                    }
                }
                Some(Msg::Snapshot(tx)) => {
                    let _ = tx.send(snapshot_of(&engine, &parked, &stats));
                }
                Some(Msg::SetPolicy(p)) => batcher.policy = p,
                #[cfg(test)]
                Some(Msg::SetFaults(plan)) => engine.inject_faults(plan),
                Some(Msg::Shutdown) => draining = true,
                None => {}
            }
            if draining {
                break;
            }
        }
        if draining && batcher.waiting_len() == 0 && batcher.running_len() == 0 {
            engine.page_accounting().expect("page accounting at shutdown");
            return stats;
        }

        // Shed deadline-expired waiters before spending this
        // iteration's budget on anything else.
        shed_expired(&mut batcher, &mut inflight, &mut parked, &mut deadlines, &mut stats, &metrics);

        let mut batch = batcher.next_batch();
        if batch.is_empty() {
            if draining {
                engine.page_accounting().expect("page accounting at shutdown");
                return stats;
            }
            continue;
        }
        audited = false;
        let mut progressed = !batch.decodes.is_empty();
        // Sequences preempted while assembling this batch: they were
        // already collected into `batch.decodes`, but their pages are
        // gone — stepping them would panic. Filtered out below.
        let mut preempted: HashSet<u64> = HashSet::new();
        // Prefills / session extends (admission may fail under KV
        // pressure → preempt a lower-priority sequence or requeue).
        for &(seq, chunk) in batch.prefills.iter() {
            let (total, decode_len, prio, mode, prompt, resume) = inflight
                .get(&seq)
                .map(|f| {
                    (
                        f.req.context_len,
                        f.req.decode_len,
                        f.req.priority,
                        f.req.mode.clone(),
                        f.req.prompt.clone(),
                        f.resume,
                    )
                })
                .unwrap_or((chunk, 0, Priority::Normal, None, None, false));
            let progress = if resume {
                // Resumed turn: append to the parked index in place.
                // Zero prefill tokens — `session_tokens` counts these.
                if engine.session_extend(seq, chunk, decode_len) {
                    Ok(PrefillProgress::Complete)
                } else {
                    Ok(PrefillProgress::Rejected)
                }
            } else {
                engine.prefill_chunk(seq, total, decode_len, mode.as_ref(), prompt.as_ref(), chunk)
            };
            match progress {
                Err(e) => {
                    // Defensive: accept() validates modes up front, so
                    // this only fires on direct-API misuse. Fail the
                    // request instead of spinning on it.
                    if let Some(fl) = inflight.remove(&seq) {
                        send_failure(&fl.done_tx, &fl.req, e.to_string(), &mut stats, &metrics, &fl.label);
                    } else {
                        stats.failed_requests += 1;
                    }
                    progressed = true;
                }
                Ok(PrefillProgress::InProgress { filled }) => {
                    // Chunk applied; the remainder rides the
                    // continuation queue to the next iteration, so
                    // running decodes never stall behind a long prefill.
                    if let Some(fl) = inflight.get_mut(&seq) {
                        stats.prefill_tokens += (filled - fl.filled) as u64;
                        fl.filled = filled;
                    }
                    // Relaxed: independent monotone counter; read only
                    // by the metrics endpoint.
                    metrics.pressure.chunked_prefills.fetch_add(1, Ordering::Relaxed);
                    batcher.continue_prefill(seq, total - filled);
                    progressed = true;
                }
                Ok(PrefillProgress::Complete) => {
                    if resume {
                        stats.session_tokens += chunk as u64;
                        stats.resumed_turns += 1;
                    } else if let Some(fl) = inflight.get_mut(&seq) {
                        stats.prefill_tokens += (total - fl.filled) as u64;
                        fl.filled = total;
                    }
                    progressed = true;
                    if decode_len == 0 {
                        // Zero-length decode: complete at prefill time. No
                        // decode step runs and no token is appended, so
                        // `decode_steps` stays untouched and the cache holds
                        // exactly the context that was requested.
                        let fl = inflight.remove(&seq).expect("prefill for unknown request");
                        let ms = fl.submitted.elapsed().as_secs_f64() * 1e3;
                        finish_turn(&mut engine, &mut parked, &mut stats, &metrics, seq, fl, ms, ms);
                    } else {
                        batcher.started(seq);
                    }
                }
                Ok(PrefillProgress::Rejected) => {
                    stats.rejected_admissions += 1;
                    // Page exhaustion: preempt the lowest-priority
                    // running sequence strictly below this request's
                    // class, if any. Recompute-style (vLLM): release
                    // the victim's pages (prefix-shared ones stay
                    // resident in the tree, so readmission re-prefills
                    // cheaply) and requeue it for a fresh prefill; its
                    // decoded tokens recompute bit-identically and the
                    // `emitted` mark keeps its stream duplicate-free.
                    if let Some(victim) = pick_victim(&batcher, &inflight, prio) {
                        engine.release(victim);
                        batcher.finished(victim);
                        preempted.insert(victim);
                        let vfl = inflight.get_mut(&victim).expect("victim is inflight");
                        vfl.base_decoded = 0;
                        vfl.filled = 0;
                        vfl.last_token = None;
                        batcher.requeue(victim, vfl.req.context_len, vfl.req.priority, false);
                        // Relaxed: independent monotone counter; read
                        // only by the metrics endpoint.
                        metrics.pressure.preemptions.fetch_add(1, Ordering::Relaxed);
                        progressed = true;
                    }
                    batcher.requeue(seq, if resume { chunk } else { total }, prio, resume);
                }
            }
        }
        batch.decodes.retain(|seq| !preempted.contains(seq));
        // Decode steps: one batched call — sequences score their keys
        // across the shared worker pool, appends commit in batch order.
        if !batch.decodes.is_empty() {
            let _outputs = engine.decode_batch(&batch.decodes);
        }
        for &seq in batch.decodes.iter() {
            stats.decode_steps += 1;
            let fl = inflight.get_mut(&seq).expect("decode for unknown request");
            let now = Instant::now();
            let since_submit = now.duration_since(fl.submitted).as_secs_f64() * 1e3;
            let class = metrics.class(fl.req.priority.index());
            if fl.first_token.is_none() {
                fl.first_token = Some(now);
                metrics.method(&fl.label).ttft.record_ms(since_submit);
                class.ttft.record_ms(since_submit);
            } else if let Some(prev) = fl.last_token {
                let gap_ms = now.duration_since(prev).as_secs_f64() * 1e3;
                metrics.method(&fl.label).tbt.record_ms(gap_ms);
                class.tbt.record_ms(gap_ms);
            }
            fl.last_token = Some(now);
            let turn_tokens = engine.decoded(seq) - fl.base_decoded;
            if turn_tokens > fl.emitted {
                // Past the high-water mark: genuinely new (a preempted
                // sequence re-decodes tokens it already streamed; those
                // stay suppressed).
                if let Some(tx) = &fl.tokens {
                    let _ = tx.send(TokenEvent { index: turn_tokens - 1, ms: since_submit });
                }
                fl.emitted = turn_tokens;
            }
            if turn_tokens >= fl.req.decode_len {
                // Finished.
                let fl = inflight.remove(&seq).unwrap();
                let ttft_ms = fl
                    .first_token
                    .unwrap_or(now)
                    .duration_since(fl.submitted)
                    .as_secs_f64()
                    * 1e3;
                batcher.finished(seq);
                finish_turn(
                    &mut engine,
                    &mut parked,
                    &mut stats,
                    &metrics,
                    seq,
                    fl,
                    ttft_ms,
                    since_submit,
                );
            }
        }
        if !batch.prefills.is_empty() {
            // Fold the iteration's prefix-cache lookups into the
            // registry (hits, page sharing, tokens the cache absorbed).
            metrics.absorb_prefix(engine.take_prefix_stats());
        }
        if !batch.decodes.is_empty() {
            // Fold the step's pruning telemetry into the registry while
            // it is still warm (live selectors are drained in place).
            metrics.absorb_prune(engine.take_prune_stats());
        }
        if !progressed {
            // Every admission was requeued and nothing decoded. Pages
            // only free when a future iteration completes a request, so
            // spinning is pure waste — park briefly instead of burning
            // a core re-offering the same batch.
            std::thread::sleep(Duration::from_micros(200));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::AttentionMode;
    use crate::lsh::LshParams;
    use crate::model::ModelConfig;

    fn small_config() -> EngineConfig {
        EngineConfig {
            model: ModelConfig { head_dim: 16, n_kv_heads: 1, ..ModelConfig::tiny() },
            lsh: LshParams { p: 6, l: 8, tau: 0.5 },
            mode: AttentionMode::socket(8.0),
            capacity_pages: 2048,
            sink: 4,
            local: 4,
        }
    }

    fn req(id: u64, ctx: usize, dec: usize) -> Request {
        Request { id, context_len: ctx, decode_len: dec, ..Request::default() }
    }

    fn req_as(id: u64, ctx: usize, dec: usize, mode: AttentionMode) -> Request {
        Request { mode: Some(mode), ..req(id, ctx, dec) }
    }

    fn req_pri(id: u64, ctx: usize, dec: usize, prio: Priority) -> Request {
        Request { priority: prio, ..req(id, ctx, dec) }
    }

    fn session_turn(id: u64, ctx: usize, dec: usize, resume: bool) -> Submission {
        Submission { req: req(id, ctx, dec), keep_alive: true, resume, tokens: None }
    }

    #[test]
    fn single_request_completes() {
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let h = coord.submit(req(1, 128, 4));
        let c = h.wait();
        assert_eq!(c.id, 1);
        assert!(c.ok, "{:?}", c.error);
        assert_eq!(c.decode_len, 4);
        assert!(c.ttft_ms <= c.total_ms);
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.decode_steps, 4);
        assert_eq!(stats.prefill_tokens, 128);
    }

    #[test]
    fn concurrent_requests_all_complete() {
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let handles: Vec<RequestHandle> =
            (0..8).map(|i| coord.submit(req(i, 64 + 16 * i as usize, 3))).collect();
        let mut ids: Vec<u64> = handles.into_iter().map(|h| h.wait().id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 8);
        assert_eq!(stats.decode_steps, 24);
    }

    #[test]
    fn backpressure_requeues_and_eventually_admits() {
        // Tiny pool: only ~2 sequences fit at once; the rest must wait
        // for releases.
        let config = EngineConfig { capacity_pages: 24, ..small_config() };
        let coord = Coordinator::spawn(config, BatchPolicy { max_prefills: 4, ..Default::default() });
        let handles: Vec<RequestHandle> =
            (0..6).map(|i| coord.submit(req(i, 128, 2))).collect();
        for h in handles {
            h.wait();
        }
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 6);
        assert!(stats.rejected_admissions > 0, "expected KV backpressure");
    }

    #[test]
    fn oversized_request_fails_fast_instead_of_livelocking() {
        // 8-page pool x 16 tokens x 1 kv-head = 128 cacheable tokens; a
        // 1024-token request can never be admitted. Pre-fix the
        // scheduler requeued it forever in a hot spin (nothing running,
        // so no pages could ever free). Now it must complete with an
        // error, and later requests must still be served.
        let config = EngineConfig { capacity_pages: 8, ..small_config() };
        let coord = Coordinator::spawn(config, BatchPolicy::default());
        let h_big = coord.submit(req(1, 1024, 4));
        let h_ok = coord.submit(req(2, 48, 2));
        let c_big = h_big
            .wait_timeout(std::time::Duration::from_secs(30))
            .expect("oversized request must fail fast, not livelock");
        assert!(!c_big.ok);
        assert!(
            c_big.error.as_deref().unwrap_or("").contains("never admittable"),
            "{:?}",
            c_big.error
        );
        let c_ok = h_ok
            .wait_timeout(std::time::Duration::from_secs(30))
            .expect("small request must still be served");
        assert!(c_ok.ok);
        let stats = coord.shutdown();
        assert_eq!(stats.failed_requests, 1);
        assert_eq!(stats.completed, 1);
    }

    #[test]
    fn unknown_method_fails_fast_with_error_completion() {
        // An unregistered method can never be served: like an oversized
        // request it must complete with an error, not hang or panic a
        // worker, and later requests must be unaffected.
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let h_bad = coord.submit(req_as(1, 64, 2, AttentionMode::sparse("nope", 8.0)));
        let h_ok = coord.submit(req(2, 64, 2));
        let c_bad = h_bad
            .wait_timeout(std::time::Duration::from_secs(30))
            .expect("unknown method must fail fast");
        assert!(!c_bad.ok);
        assert!(
            c_bad.error.as_deref().unwrap_or("").contains("unknown method"),
            "{:?}",
            c_bad.error
        );
        let c_ok = h_ok.wait_timeout(std::time::Duration::from_secs(30)).expect("served");
        assert!(c_ok.ok, "{:?}", c_ok.error);
        let stats = coord.shutdown();
        assert_eq!(stats.failed_requests, 1);
        assert_eq!(stats.completed, 1);
    }

    #[test]
    fn per_request_methods_served_through_one_scheduler() {
        // Quest and MagicPIG end-to-end through the continuous batcher
        // — every baseline is servable, per request, on one engine.
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let handles = vec![
            coord.submit(req_as(1, 96, 3, AttentionMode::sparse("quest", 8.0))),
            coord.submit(req_as(2, 96, 3, AttentionMode::sparse("magicpig", 8.0))),
            coord.submit(req_as(3, 96, 3, AttentionMode::Dense)),
            coord.submit(req(4, 96, 3)),
        ];
        for h in handles {
            let c = h.wait();
            assert!(c.ok, "{:?}", c.error);
            assert_eq!(c.decode_len, 3);
        }
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 4);
        assert_eq!(stats.decode_steps, 12);
        assert_eq!(stats.failed_requests, 0);
    }

    #[test]
    fn zero_length_decode_completes_at_prefill() {
        // Pre-fix, a decode_len == 0 request still ran one decode step
        // (appending a token nobody asked for) before the completion
        // check fired. It must now finish at prefill time with zero
        // decode steps on the books.
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let c = coord.submit(req(5, 64, 0)).wait();
        assert!(c.ok, "{:?}", c.error);
        assert_eq!(c.decode_len, 0);
        assert!(c.ttft_ms <= c.total_ms + 1e-9);
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.decode_steps, 0, "no decode step may run for decode_len=0");
        assert_eq!(stats.prefill_tokens, 64);
    }

    #[test]
    fn context_longer_than_prefill_budget_still_served() {
        // The token-budget twin of the KV livelock: a context longer
        // than prefill_token_budget must be offered alone, not pinned
        // at the queue head forever.
        let policy = BatchPolicy { prefill_token_budget: 64, ..Default::default() };
        let coord = Coordinator::spawn(small_config(), policy);
        let c = coord
            .submit(req(3, 256, 2))
            .wait_timeout(std::time::Duration::from_secs(30))
            .expect("oversized context must be admitted alone");
        assert!(c.ok, "{:?}", c.error);
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 1);
    }

    #[test]
    fn shutdown_drains_inflight() {
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let h = coord.submit(req(9, 64, 10));
        let stats = coord.shutdown(); // shutdown while decoding
        assert_eq!(stats.completed, 1, "in-flight request must drain");
        let c = h.wait();
        assert_eq!(c.decode_len, 10);
    }

    #[test]
    fn session_second_turn_runs_zero_prefill() {
        // The tentpole acceptance criterion: turn 2 on a parked session
        // must not add a single prefill token — its context is appended
        // via session_extend and counted in session_tokens.
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let base_free = coord.snapshot().expect("live scheduler").free_pages;
        let c1 = coord.submit_opts(session_turn(7, 128, 2, false)).wait();
        assert!(c1.ok, "{:?}", c1.error);
        let snap1 = coord.snapshot().unwrap();
        assert_eq!(snap1.stats.prefill_tokens, 128);
        assert_eq!(snap1.parked_sessions, 1);
        assert_eq!(snap1.live_sequences, 1, "parked session must keep its pages");
        assert!(snap1.free_pages < base_free);

        let c2 = coord.submit_opts(session_turn(7, 64, 2, true)).wait();
        assert!(c2.ok, "{:?}", c2.error);
        let snap2 = coord.snapshot().unwrap();
        assert_eq!(snap2.stats.prefill_tokens, 128, "turn 2 must prefill zero tokens");
        assert_eq!(snap2.stats.session_tokens, 64);
        assert_eq!(snap2.stats.resumed_turns, 1);
        assert_eq!(snap2.parked_sessions, 1);

        // Release (the TTL-eviction path) returns every page.
        coord.release(7);
        let snap3 = coord.snapshot().unwrap();
        assert_eq!(snap3.free_pages, base_free, "release must return the session's pages");
        assert_eq!(snap3.parked_sessions, 0);
        assert_eq!(snap3.stats.sessions_released, 1);
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 2);
    }

    #[test]
    fn resume_of_unknown_or_busy_sequence_fails_cleanly() {
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        // Unknown session.
        let c = coord.submit_opts(session_turn(42, 32, 1, true)).wait();
        assert!(!c.ok);
        assert!(c.error.as_deref().unwrap_or("").contains("not a parked session"), "{:?}", c.error);
        // An oversized resumed turn re-parks the session instead of
        // destroying it.
        let c1 = coord.submit_opts(session_turn(8, 64, 1, false)).wait();
        assert!(c1.ok, "{:?}", c1.error);
        let c_big = coord.submit_opts(session_turn(8, 1 << 20, 1, true)).wait();
        assert!(!c_big.ok);
        assert!(c_big.error.as_deref().unwrap_or("").contains("never admittable"), "{:?}", c_big.error);
        let c2 = coord.submit_opts(session_turn(8, 16, 1, true)).wait();
        assert!(c2.ok, "session must survive a failed oversized turn: {:?}", c2.error);
        coord.shutdown();
    }

    #[test]
    fn duplicate_sequence_id_is_rejected() {
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let c1 = coord.submit_opts(session_turn(3, 64, 1, false)).wait();
        assert!(c1.ok, "{:?}", c1.error);
        // Seq 3 is parked; a fresh (non-resume) submission colliding
        // with it must fail instead of clobbering the parked state.
        let c2 = coord.submit(req(3, 64, 1)).wait();
        assert!(!c2.ok);
        assert!(c2.error.as_deref().unwrap_or("").contains("already in use"), "{:?}", c2.error);
        coord.shutdown();
    }

    #[test]
    fn streaming_emits_one_event_per_token_then_disconnects() {
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let (tx, rx) = channel();
        let handle = coord.submit_opts(Submission {
            req: req(1, 64, 5),
            keep_alive: false,
            resume: false,
            tokens: Some(tx),
        });
        let events: Vec<TokenEvent> = rx.iter().collect(); // drains until disconnect
        assert_eq!(events.len(), 5, "exactly decode_len token events");
        for (i, ev) in events.iter().enumerate() {
            assert_eq!(ev.index, i, "token indices must be ordered");
            assert!(ev.ms >= 0.0);
        }
        assert!(
            events.windows(2).all(|w| w[0].ms <= w[1].ms),
            "token timestamps must be monotone"
        );
        // The completion was sent before the channel disconnected.
        let c = handle.wait_timeout(Duration::from_secs(30)).expect("completion after stream");
        assert!(c.ok, "{:?}", c.error);
        coord.shutdown();
    }

    #[test]
    fn shutdown_while_submitting_resolves_every_handle() {
        // Regression for the shutdown/submit race: submissions racing a
        // concurrent begin_shutdown must each resolve — served, failed,
        // or reported lost — never hang on a handle whose message the
        // drained loop will never read.
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let handles = std::sync::Mutex::new(Vec::new());
        std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..16u64 {
                    let h = coord.submit(req(i, 32, 1));
                    handles.lock().unwrap().push(h);
                }
            });
            s.spawn(|| {
                std::thread::yield_now();
                coord.begin_shutdown();
            });
        });
        let handles = handles.into_inner().unwrap();
        assert_eq!(handles.len(), 16);
        let mut served = 0usize;
        let mut unserved = 0usize;
        for h in handles {
            let c = h
                .wait_timeout(Duration::from_secs(30))
                .expect("every racing handle must resolve after shutdown");
            if c.ok {
                served += 1;
            } else {
                unserved += 1;
            }
        }
        assert_eq!(served + unserved, 16);
        let stats = coord.shutdown();
        assert_eq!(stats.completed, served, "stats must agree with delivered completions");
    }

    /// Exhaustive model of the drain protocol above: submissions and
    /// the shutdown signal share one queue; the loop serves until it
    /// reads the shutdown sentinel; whatever is still queued is lost —
    /// but every accepted submission is accounted for as exactly one of
    /// served or lost, on every interleaving.
    #[test]
    fn drain_protocol_model_all_schedules() {
        use crate::testing::interleave::{self, Pop};
        const SHUTDOWN: u64 = 99;
        let report = interleave::explore("sched-drain", |sim| {
            let q = sim.queue();
            let (qs, qx, ql) = (q.clone(), q.clone(), q.clone());
            let submitter = sim.spawn(move || qs.push(1) as u64 + qs.push(2) as u64);
            let stopper = sim.spawn(move || qx.push(SHUTDOWN) as u64);
            let the_loop = sim.spawn(move || {
                let mut served = 0u64;
                loop {
                    match ql.pop() {
                        Pop::Item(SHUTDOWN) => break,
                        Pop::Item(_) => served += 1,
                        Pop::Closed => break,
                    }
                }
                served
            });
            let accepted = submitter.join();
            let _ = stopper.join();
            let served = the_loop.join();
            // Count what the loop never read (the real system resolves
            // these as lost completions when the receiver drops).
            q.close();
            let mut lost = 0u64;
            loop {
                match q.pop() {
                    Pop::Item(SHUTDOWN) => {}
                    Pop::Item(_) => lost += 1,
                    Pop::Closed => break,
                }
            }
            assert_eq!(
                served + lost,
                accepted,
                "a submission vanished or was double-served (served {served}, lost {lost}, accepted {accepted})"
            );
        });
        assert!(report.exhaustive);
        assert!(report.schedules > 1);
    }

    #[test]
    fn dead_scheduler_yields_error_completions_not_panics() {
        let mut coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        // Swap the real queue for one whose receiver is already gone:
        // every send now fails exactly as it would after a scheduler
        // crash, deterministically.
        let (dead_tx, dead_rx) = channel::<Msg>();
        drop(dead_rx);
        let real_tx = std::mem::replace(&mut coord.tx, dead_tx);
        let c = coord.submit(req(1, 64, 2)).wait();
        assert!(!c.ok);
        assert!(c.error.as_deref().unwrap_or("").contains("scheduler"), "{:?}", c.error);
        assert_eq!(
            coord.submit(req(2, 64, 2)).wait_timeout(Duration::from_secs(1)).map(|c| c.ok),
            Some(false),
            "wait_timeout must report the failure, not panic"
        );
        assert!(coord.snapshot().is_none(), "snapshot of a dead scheduler is None");
        coord.release(9); // must be a no-op, not a panic
        // Restore the real queue so drop can shut the worker down.
        coord.tx = real_tx;
    }

    #[test]
    fn handle_outliving_scheduler_reports_loss() {
        // A handle whose completion channel disconnects (scheduler gone
        // mid-request) resolves to a failed completion.
        let (done_tx, done_rx) = channel::<Completion>();
        drop(done_tx);
        let h = RequestHandle { rx: done_rx, id: 7, context_len: 64, decode_len: 2 };
        let c = h.wait_timeout(Duration::from_millis(10)).expect("disconnect resolves");
        assert!(!c.ok);
        assert_eq!(c.id, 7);
        let h = RequestHandle {
            rx: {
                let (tx, rx) = channel::<Completion>();
                drop(tx);
                rx
            },
            id: 8,
            context_len: 64,
            decode_len: 2,
        };
        assert!(!h.wait().ok, "wait must not panic on disconnect");
    }

    #[test]
    fn shared_prefix_requests_hit_the_cache_end_to_end() {
        use crate::kvcache::PromptSpec;
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let prompt = PromptSpec::from_text("You are a helpful assistant.", 128);
        for id in 1..=3u64 {
            let c = coord
                .submit(Request { prompt: Some(prompt.clone()), ..req(id, 128, 2) })
                .wait();
            assert!(c.ok, "{:?}", c.error);
        }
        let j = coord.metrics().prefix_json();
        assert_eq!(j.get("lookups").unwrap().as_usize(), Some(3), "{j}");
        assert_eq!(j.get("hits").unwrap().as_usize(), Some(2), "first is cold, rest hit");
        assert!(j.get("prefill_tokens_saved").unwrap().as_usize().unwrap() >= 2 * 128, "{j}");
        assert!(j.get("shared_page_ratio").unwrap().as_f64().unwrap() > 0.5, "{j}");
        // An opted-out request is served but leaves the gauges alone.
        let mut opt_out = prompt.clone();
        opt_out.cache = false;
        let c = coord.submit(Request { prompt: Some(opt_out), ..req(9, 128, 2) }).wait();
        assert!(c.ok, "{:?}", c.error);
        let j2 = coord.metrics().prefix_json();
        assert_eq!(j2.get("lookups").unwrap().as_usize(), Some(3), "cache-off must not look up");
        // The drain audit in shutdown re-checks refcounts one last time.
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 4);
    }

    #[test]
    fn set_policy_swaps_batching_without_restart() {
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        // Throttle to one prefill per iteration mid-flight; the change
        // must take without dropping queued or future work.
        coord.set_policy(BatchPolicy { max_prefills: 1, ..BatchPolicy::default() });
        let handles: Vec<RequestHandle> = (0..4).map(|i| coord.submit(req(i, 64, 2))).collect();
        for h in handles {
            assert!(h.wait().ok);
        }
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 4);
    }

    #[test]
    fn metrics_registry_fed_by_the_loop() {
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let c = coord.submit(req(1, 96, 4)).wait();
        assert!(c.ok, "{:?}", c.error);
        let m = coord.metrics();
        let series = m.method("socket");
        assert_eq!(series.served.load(Ordering::Relaxed), 1);
        assert_eq!(series.ttft.count(), 1, "one TTFT sample per served request");
        assert_eq!(series.tbt.count(), 3, "decode_len - 1 inter-token gaps");
        let prune = m.prune_json();
        assert!(prune.get("blocks").unwrap().as_usize().unwrap() > 0, "{prune}");
        coord.shutdown();
    }

    #[test]
    fn forced_fault_preempts_lowest_priority_and_both_complete() {
        // PR 9 acceptance round trip: a forced page-exhaustion fault on
        // an interactive admission preempts the running batch-class
        // sequence; the victim restarts from a fresh prefill and its
        // stream still carries every token exactly once.
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let (tx, rx) = channel();
        let h_victim = coord.submit_opts(Submission {
            req: req_pri(1, 64, 600, Priority::Batch),
            keep_alive: false,
            resume: false,
            tokens: Some(tx),
        });
        // Wait for the first token so the victim is decoding (running,
        // hence preemptible) when the interactive request lands.
        let first = rx.recv_timeout(Duration::from_secs(30)).expect("victim must start");
        assert_eq!(first.index, 0);
        // Arm-then-submit rides the same queue as the submission, so
        // the fault deterministically hits seq 2's first admission.
        coord.inject_faults(FaultPlan::new().fail_first(2, 1));
        let h_inter = coord.submit(req_pri(2, 64, 2, Priority::Interactive));
        let c_inter = h_inter.wait_timeout(Duration::from_secs(30)).expect("interactive resolves");
        assert!(c_inter.ok, "{:?}", c_inter.error);
        let events: Vec<TokenEvent> = std::iter::once(first).chain(rx.iter()).collect();
        let c_victim = h_victim.wait_timeout(Duration::from_secs(30)).expect("victim resolves");
        assert!(c_victim.ok, "preempted request must be re-served: {:?}", c_victim.error);
        assert_eq!(events.len(), 600, "restart must not duplicate or drop token events");
        for (i, ev) in events.iter().enumerate() {
            assert_eq!(ev.index, i, "token indices must stay ordered across the restart");
        }
        let m = coord.metrics();
        assert!(
            m.pressure.preemptions.load(Ordering::Relaxed) >= 1,
            "the batch-class victim must have been preempted"
        );
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 2);
        assert_eq!(stats.failed_requests, 0);
        assert!(
            stats.prefill_tokens >= 64 + 64 + 64,
            "the victim's re-prefill must be counted honestly, got {}",
            stats.prefill_tokens
        );
    }

    #[test]
    fn full_waiting_queue_sheds_with_typed_error() {
        // max_waiting = 0: every fresh submission bounces immediately —
        // the deterministic way to exercise the shed path.
        let coord =
            Coordinator::spawn(small_config(), BatchPolicy { max_waiting: 0, ..Default::default() });
        let c = coord
            .submit(req(1, 64, 2))
            .wait_timeout(Duration::from_secs(30))
            .expect("shed request resolves immediately");
        assert!(!c.ok);
        assert!(c.error.as_deref().unwrap_or("").starts_with("queue_full"), "{:?}", c.error);
        assert_eq!(coord.metrics().pressure.shed.load(Ordering::Relaxed), 1);
        // Raising the bound at runtime restores service without a restart.
        coord.set_policy(BatchPolicy::default());
        let c2 = coord.submit(req(2, 64, 2)).wait();
        assert!(c2.ok, "{:?}", c2.error);
        let stats = coord.shutdown();
        assert_eq!(stats.failed_requests, 1);
        assert_eq!(stats.completed, 1);
    }

    #[test]
    fn deadline_expired_waiters_are_shed_with_typed_error() {
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        // Pin seq 1 out of admission indefinitely; its deadline lapses
        // in the queue and the sweep sheds it.
        coord.inject_faults(FaultPlan::new().fail_first(1, u32::MAX));
        let c = coord
            .submit(Request { deadline_ms: Some(5.0), ..req(1, 64, 2) })
            .wait_timeout(Duration::from_secs(30))
            .expect("expired request resolves");
        assert!(!c.ok);
        assert!(c.error.as_deref().unwrap_or("").starts_with("deadline_missed"), "{:?}", c.error);
        assert!(coord.metrics().pressure.deadline_missed.load(Ordering::Relaxed) >= 1);
        // A generous deadline on an unconstrained request is met.
        let c2 = coord.submit(Request { deadline_ms: Some(60_000.0), ..req(2, 64, 2) }).wait();
        assert!(c2.ok, "{:?}", c2.error);
        let stats = coord.shutdown();
        assert_eq!(stats.failed_requests, 1);
        assert_eq!(stats.completed, 1);
    }

    #[test]
    fn chunked_prefill_shares_iterations_with_decodes() {
        // A context 4x the token budget must take >= 3 partial chunks,
        // and a concurrent short request must still be served promptly
        // (chunking exists so long prefills cannot monopolize the loop).
        let policy = BatchPolicy { prefill_token_budget: 64, ..Default::default() };
        let coord = Coordinator::spawn(small_config(), policy);
        let h_long = coord.submit(req(1, 256, 2));
        let h_short = coord.submit(req(2, 32, 8));
        let c_long = h_long.wait_timeout(Duration::from_secs(30)).expect("long resolves");
        let c_short = h_short.wait_timeout(Duration::from_secs(30)).expect("short resolves");
        assert!(c_long.ok, "{:?}", c_long.error);
        assert!(c_short.ok, "{:?}", c_short.error);
        let chunked = coord.metrics().pressure.chunked_prefills.load(Ordering::Relaxed);
        assert!(chunked >= 3, "4x-budget context must take >= 3 partial chunks, saw {chunked}");
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 2);
        assert_eq!(stats.prefill_tokens, 256 + 32, "chunk accounting must not double-count");
    }

    /// Satellite: completion accounting under forced preempt/readmit —
    /// every accepted request resolves as exactly one of served, shed,
    /// or failed, and the page pool drains to empty, across randomized
    /// priorities, sizes, and fault plans.
    #[test]
    fn completion_accounting_holds_under_forced_faults() {
        use crate::prop_assert;
        use crate::testing::{check, PropConfig};
        check("preempt-accounting", PropConfig { cases: 6, seed: 0x50C4E7 }, |rng, _| {
            let config = EngineConfig { capacity_pages: 96, ..small_config() };
            let policy = BatchPolicy { max_waiting: 6, max_prefills: 2, ..Default::default() };
            let coord = Coordinator::spawn(config, policy);
            let n = 8 + (rng.next_u64() % 8) as usize;
            let mut plan = FaultPlan::new();
            for i in 0..n as u64 {
                if rng.next_u64() % 3 == 0 {
                    plan = plan.fail_first(i, 1);
                }
            }
            coord.inject_faults(plan);
            let handles: Vec<RequestHandle> = (0..n as u64)
                .map(|i| {
                    let prio = Priority::ALL[(rng.next_u64() % 3) as usize];
                    let ctx = 32 + 16 * (rng.next_u64() % 4) as usize;
                    let dec = 1 + (rng.next_u64() % 4) as usize;
                    coord.submit(Request {
                        priority: prio,
                        ..req(i, ctx, dec)
                    })
                })
                .collect();
            let mut served = 0usize;
            let mut unserved = 0usize;
            for h in handles {
                let c = h
                    .wait_timeout(Duration::from_secs(60))
                    .ok_or_else(|| "a handle hung past 60s".to_string())?;
                if c.ok {
                    served += 1;
                } else {
                    unserved += 1;
                }
            }
            prop_assert!(served + unserved == n, "a request vanished: {served}+{unserved} != {n}");
            let snap = coord.snapshot().ok_or_else(|| "scheduler died".to_string())?;
            prop_assert!(
                snap.free_pages == snap.total_pages,
                "pages leaked: {} free of {}",
                snap.free_pages,
                snap.total_pages
            );
            prop_assert!(
                snap.stats.completed == served,
                "stats disagree with delivered completions: {} != {served}",
                snap.stats.completed
            );
            prop_assert!(
                snap.stats.failed_requests == unserved,
                "failures unaccounted: {} != {unserved}",
                snap.stats.failed_requests
            );
            // shutdown re-runs the page audit; a refcount leak panics here.
            let stats = coord.shutdown();
            prop_assert!(stats.completed == served, "shutdown stats drifted");
            Ok(())
        });
    }

    /// Model of the preemption decision racing a concurrent release, on
    /// every interleaving: the scheduler's *decision* may read a stale
    /// free-page count (causing an unnecessary preemption), but the
    /// *admission* is an RMW on the authoritative balance — it can never
    /// admit pages that are not there, and the victim is requeued
    /// exactly once, never lost.
    #[test]
    fn preemption_vs_release_model_all_schedules() {
        use crate::testing::interleave;
        const NEED: u64 = 3;
        let report = interleave::explore("preempt-vs-release", |sim| {
            let free = sim.atomic(2); // insufficient for NEED
            let victim = sim.atomic(0); // 0 = running, 1 = requeued
            let (fr, fs) = (free.clone(), free.clone());
            let vs = victim.clone();
            // A finishing sequence hands its 2 pages back at any point.
            let releaser = sim.spawn(move || fr.fetch_add(2));
            let sched = sim.spawn(move || {
                let seen = fs.load(); // the decision: may be stale
                if seen < NEED {
                    // Preempt: requeue the victim exactly once and
                    // reclaim its 3 pages.
                    let was = vs.swap(1);
                    assert_eq!(was, 0, "victim preempted twice");
                    fs.fetch_add(3);
                }
                // Admission charges the authoritative balance (RMW),
                // never the stale read.
                let before = fs.fetch_add(0u64.wrapping_sub(NEED));
                assert!(before >= NEED, "admitted on insufficient pages: {before}");
                u64::from(seen < NEED)
            });
            let _ = releaser.join();
            let preempted = sched.join();
            assert_eq!(
                free.load(),
                2 + 2 + 3 * preempted - NEED,
                "page conservation across preempt/release"
            );
            assert_eq!(victim.load(), preempted, "victim requeued iff preempted");
        });
        assert!(report.exhaustive);
        assert!(report.schedules > 1);
    }
}
