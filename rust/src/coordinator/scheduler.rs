//! The scheduler: a worker thread driving admit → step iterations over
//! the [`DecodeEngine`], with an mpsc submission queue and per-request
//! completion channels. This is the leader loop of the serving stack.

use super::batcher::{BatchPolicy, Batcher};
use super::engine::{DecodeEngine, EngineConfig};
use crate::workload::trace::Request;
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Completion record returned for every finished request — served or
/// failed (`ok` distinguishes; failed completions carry `error`).
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub context_len: usize,
    pub decode_len: usize,
    /// Time from submission to first decoded token, ms.
    pub ttft_ms: f64,
    /// Time from submission to completion, ms.
    pub total_ms: f64,
    /// Whether the request was actually served. False for requests the
    /// scheduler rejected up front (e.g. a KV commitment that could
    /// never fit the pool).
    pub ok: bool,
    /// Failure reason when `ok` is false.
    pub error: Option<String>,
}

/// Aggregate scheduler statistics.
#[derive(Clone, Debug, Default)]
pub struct SchedulerStats {
    pub completed: usize,
    pub decode_steps: u64,
    pub prefill_tokens: u64,
    pub rejected_admissions: u64,
    /// Requests failed up front: their full KV commitment exceeds the
    /// pool, so no amount of waiting could ever admit them.
    pub failed_requests: u64,
}

enum Msg {
    Submit(Request, Sender<Completion>),
    Shutdown,
}

/// Handle for awaiting one request's completion.
pub struct RequestHandle {
    rx: Receiver<Completion>,
}

impl RequestHandle {
    /// Block until the request completes.
    pub fn wait(self) -> Completion {
        self.rx.recv().expect("scheduler dropped before completing request")
    }

    /// Block until the request completes or `timeout` elapses. `None`
    /// on timeout — the request is still in flight and the handle
    /// remains usable for another wait. Panics if the scheduler
    /// dropped without completing the request.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Completion> {
        match self.rx.recv_timeout(timeout) {
            Ok(c) => Some(c),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => {
                panic!("scheduler dropped before completing request")
            }
        }
    }
}

/// The coordinator: spawns the scheduler thread, routes requests in.
pub struct Coordinator {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<SchedulerStats>>,
}

struct Inflight {
    req: Request,
    submitted: Instant,
    first_token: Option<Instant>,
    done_tx: Sender<Completion>,
}

impl Coordinator {
    /// Spawn the scheduler over a fresh engine.
    pub fn spawn(config: EngineConfig, policy: BatchPolicy) -> Coordinator {
        let (tx, rx) = channel::<Msg>();
        let worker = std::thread::spawn(move || scheduler_loop(config, policy, rx));
        Coordinator { tx, worker: Some(worker) }
    }

    /// Submit a request; returns a handle to await completion.
    pub fn submit(&self, req: Request) -> RequestHandle {
        let (done_tx, done_rx) = channel();
        self.tx.send(Msg::Submit(req, done_tx)).expect("scheduler gone");
        RequestHandle { rx: done_rx }
    }

    /// Stop the scheduler (after draining in-flight work) and return
    /// aggregate stats.
    pub fn shutdown(mut self) -> SchedulerStats {
        let _ = self.tx.send(Msg::Shutdown);
        self.worker.take().unwrap().join().expect("scheduler panicked")
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            let _ = self.tx.send(Msg::Shutdown);
            let _ = w.join();
        }
    }
}

/// Fail a request with an error completion (the one shape both the
/// accept-time and prefill-time failure paths emit).
fn send_failure(
    done_tx: &Sender<Completion>,
    req: &Request,
    error: String,
    stats: &mut SchedulerStats,
) {
    stats.failed_requests += 1;
    let _ = done_tx.send(Completion {
        id: req.id,
        context_len: req.context_len,
        decode_len: req.decode_len,
        ttft_ms: 0.0,
        total_ms: 0.0,
        ok: false,
        error: Some(error),
    });
}

/// Accept a submission into the waiting queue, or fail it immediately
/// when it could never be served: a KV commitment that cannot fit the
/// pool (pre-fix, such a request was requeued by every iteration
/// forever — no running sequence can release enough pages to make it
/// fit, so the scheduler livelocked in a hot spin), or an attention
/// mode naming no registered selector.
fn accept(
    engine: &DecodeEngine,
    batcher: &mut Batcher,
    inflight: &mut HashMap<u64, Inflight>,
    stats: &mut SchedulerStats,
    req: Request,
    done_tx: Sender<Completion>,
) {
    if let Err(e) = engine.validate_mode(req.mode.as_ref()) {
        send_failure(&done_tx, &req, e.to_string(), stats);
        return;
    }
    if !engine.admissible(req.context_len, req.decode_len) {
        let error = format!(
            "never admittable: {} context + {} decode tokens exceed the {}-page KV pool",
            req.context_len, req.decode_len, engine.config.capacity_pages
        );
        send_failure(&done_tx, &req, error, stats);
        return;
    }
    batcher.enqueue(req.id, req.context_len);
    inflight
        .insert(req.id, Inflight { req, submitted: Instant::now(), first_token: None, done_tx });
}

fn scheduler_loop(config: EngineConfig, policy: BatchPolicy, rx: Receiver<Msg>) -> SchedulerStats {
    let mut engine = DecodeEngine::new(config);
    let mut batcher = Batcher::new(policy);
    let mut inflight: HashMap<u64, Inflight> = HashMap::new();
    let mut stats = SchedulerStats::default();
    let mut draining = false;

    loop {
        // Drain the submission queue without blocking (block only when
        // fully idle to avoid a busy-spin).
        loop {
            let idle = batcher.waiting_len() == 0 && batcher.running_len() == 0;
            if idle && !draining {
                match rx.recv() {
                    Ok(Msg::Submit(req, done_tx)) => {
                        accept(&engine, &mut batcher, &mut inflight, &mut stats, req, done_tx);
                    }
                    Ok(Msg::Shutdown) | Err(_) => draining = true,
                }
                continue;
            }
            match rx.try_recv() {
                Ok(Msg::Submit(req, done_tx)) => {
                    accept(&engine, &mut batcher, &mut inflight, &mut stats, req, done_tx);
                }
                Ok(Msg::Shutdown) => draining = true,
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => draining = true,
            }
            if draining {
                break;
            }
        }
        if draining && batcher.waiting_len() == 0 && batcher.running_len() == 0 {
            return stats;
        }

        let batch = batcher.next_batch();
        if batch.is_empty() {
            if draining {
                return stats;
            }
            continue;
        }
        let mut progressed = !batch.decodes.is_empty();
        // Prefills (admission may fail under KV pressure → requeue).
        for &(seq, ctx) in batch.prefills.iter() {
            let (decode_len, mode) = inflight
                .get(&seq)
                .map(|f| (f.req.decode_len, f.req.mode.clone()))
                .unwrap_or((0, None));
            let admitted = match engine.prefill_as(seq, ctx, decode_len, mode.as_ref()) {
                Ok(admitted) => admitted,
                Err(e) => {
                    // Defensive: accept() validates modes up front, so
                    // this only fires on direct-API misuse. Fail the
                    // request instead of spinning on it.
                    if let Some(fl) = inflight.remove(&seq) {
                        send_failure(&fl.done_tx, &fl.req, e.to_string(), &mut stats);
                    } else {
                        stats.failed_requests += 1;
                    }
                    progressed = true;
                    continue;
                }
            };
            if admitted {
                stats.prefill_tokens += ctx as u64;
                progressed = true;
                if decode_len == 0 {
                    // Zero-length decode: complete at prefill time. No
                    // decode step runs and no token is appended, so
                    // `decode_steps` stays untouched and the cache holds
                    // exactly the context that was requested.
                    let fl = inflight.remove(&seq).expect("prefill for unknown request");
                    let now = Instant::now();
                    let ms = now.duration_since(fl.submitted).as_secs_f64() * 1e3;
                    let _ = fl.done_tx.send(Completion {
                        id: seq,
                        context_len: fl.req.context_len,
                        decode_len: 0,
                        ttft_ms: ms,
                        total_ms: ms,
                        ok: true,
                        error: None,
                    });
                    engine.release(seq);
                    stats.completed += 1;
                } else {
                    batcher.started(seq);
                }
            } else {
                stats.rejected_admissions += 1;
                batcher.requeue(seq, ctx);
            }
        }
        // Decode steps: one batched call — sequences score their keys
        // across the shared worker pool, appends commit in batch order.
        if !batch.decodes.is_empty() {
            let _outputs = engine.decode_batch(&batch.decodes);
        }
        for &seq in batch.decodes.iter() {
            stats.decode_steps += 1;
            let fl = inflight.get_mut(&seq).expect("decode for unknown request");
            if fl.first_token.is_none() {
                fl.first_token = Some(Instant::now());
            }
            if engine.decoded(seq) >= fl.req.decode_len {
                // Finished.
                let fl = inflight.remove(&seq).unwrap();
                let now = Instant::now();
                let completion = Completion {
                    id: seq,
                    context_len: fl.req.context_len,
                    decode_len: fl.req.decode_len,
                    ttft_ms: fl
                        .first_token
                        .unwrap_or(now)
                        .duration_since(fl.submitted)
                        .as_secs_f64()
                        * 1e3,
                    total_ms: now.duration_since(fl.submitted).as_secs_f64() * 1e3,
                    ok: true,
                    error: None,
                };
                let _ = fl.done_tx.send(completion);
                batcher.finished(seq);
                engine.release(seq);
                stats.completed += 1;
            }
        }
        if !progressed {
            // Every admission was requeued and nothing decoded. Pages
            // only free when a future iteration completes a request, so
            // spinning is pure waste — park briefly instead of burning
            // a core re-offering the same batch.
            std::thread::sleep(Duration::from_micros(200));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::AttentionMode;
    use crate::lsh::LshParams;
    use crate::model::ModelConfig;

    fn small_config() -> EngineConfig {
        EngineConfig {
            model: ModelConfig { head_dim: 16, n_kv_heads: 1, ..ModelConfig::tiny() },
            lsh: LshParams { p: 6, l: 8, tau: 0.5 },
            mode: AttentionMode::socket(8.0),
            capacity_pages: 2048,
            sink: 4,
            local: 4,
        }
    }

    fn req(id: u64, ctx: usize, dec: usize) -> Request {
        Request { id, arrival_ms: 0.0, context_len: ctx, decode_len: dec, mode: None }
    }

    fn req_as(id: u64, ctx: usize, dec: usize, mode: AttentionMode) -> Request {
        Request { id, arrival_ms: 0.0, context_len: ctx, decode_len: dec, mode: Some(mode) }
    }

    #[test]
    fn single_request_completes() {
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let h = coord.submit(req(1, 128, 4));
        let c = h.wait();
        assert_eq!(c.id, 1);
        assert!(c.ok, "{:?}", c.error);
        assert_eq!(c.decode_len, 4);
        assert!(c.ttft_ms <= c.total_ms);
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.decode_steps, 4);
        assert_eq!(stats.prefill_tokens, 128);
    }

    #[test]
    fn concurrent_requests_all_complete() {
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let handles: Vec<RequestHandle> =
            (0..8).map(|i| coord.submit(req(i, 64 + 16 * i as usize, 3))).collect();
        let mut ids: Vec<u64> = handles.into_iter().map(|h| h.wait().id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 8);
        assert_eq!(stats.decode_steps, 24);
    }

    #[test]
    fn backpressure_requeues_and_eventually_admits() {
        // Tiny pool: only ~2 sequences fit at once; the rest must wait
        // for releases.
        let config = EngineConfig { capacity_pages: 24, ..small_config() };
        let coord = Coordinator::spawn(config, BatchPolicy { max_prefills: 4, ..Default::default() });
        let handles: Vec<RequestHandle> =
            (0..6).map(|i| coord.submit(req(i, 128, 2))).collect();
        for h in handles {
            h.wait();
        }
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 6);
        assert!(stats.rejected_admissions > 0, "expected KV backpressure");
    }

    #[test]
    fn oversized_request_fails_fast_instead_of_livelocking() {
        // 8-page pool x 16 tokens x 1 kv-head = 128 cacheable tokens; a
        // 1024-token request can never be admitted. Pre-fix the
        // scheduler requeued it forever in a hot spin (nothing running,
        // so no pages could ever free). Now it must complete with an
        // error, and later requests must still be served.
        let config = EngineConfig { capacity_pages: 8, ..small_config() };
        let coord = Coordinator::spawn(config, BatchPolicy::default());
        let h_big = coord.submit(req(1, 1024, 4));
        let h_ok = coord.submit(req(2, 48, 2));
        let c_big = h_big
            .wait_timeout(std::time::Duration::from_secs(30))
            .expect("oversized request must fail fast, not livelock");
        assert!(!c_big.ok);
        assert!(
            c_big.error.as_deref().unwrap_or("").contains("never admittable"),
            "{:?}",
            c_big.error
        );
        let c_ok = h_ok
            .wait_timeout(std::time::Duration::from_secs(30))
            .expect("small request must still be served");
        assert!(c_ok.ok);
        let stats = coord.shutdown();
        assert_eq!(stats.failed_requests, 1);
        assert_eq!(stats.completed, 1);
    }

    #[test]
    fn unknown_method_fails_fast_with_error_completion() {
        // An unregistered method can never be served: like an oversized
        // request it must complete with an error, not hang or panic a
        // worker, and later requests must be unaffected.
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let h_bad = coord.submit(req_as(1, 64, 2, AttentionMode::sparse("nope", 8.0)));
        let h_ok = coord.submit(req(2, 64, 2));
        let c_bad = h_bad
            .wait_timeout(std::time::Duration::from_secs(30))
            .expect("unknown method must fail fast");
        assert!(!c_bad.ok);
        assert!(
            c_bad.error.as_deref().unwrap_or("").contains("unknown method"),
            "{:?}",
            c_bad.error
        );
        let c_ok = h_ok.wait_timeout(std::time::Duration::from_secs(30)).expect("served");
        assert!(c_ok.ok, "{:?}", c_ok.error);
        let stats = coord.shutdown();
        assert_eq!(stats.failed_requests, 1);
        assert_eq!(stats.completed, 1);
    }

    #[test]
    fn per_request_methods_served_through_one_scheduler() {
        // Quest and MagicPIG end-to-end through the continuous batcher
        // — every baseline is servable, per request, on one engine.
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let handles = vec![
            coord.submit(req_as(1, 96, 3, AttentionMode::sparse("quest", 8.0))),
            coord.submit(req_as(2, 96, 3, AttentionMode::sparse("magicpig", 8.0))),
            coord.submit(req_as(3, 96, 3, AttentionMode::Dense)),
            coord.submit(req(4, 96, 3)),
        ];
        for h in handles {
            let c = h.wait();
            assert!(c.ok, "{:?}", c.error);
            assert_eq!(c.decode_len, 3);
        }
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 4);
        assert_eq!(stats.decode_steps, 12);
        assert_eq!(stats.failed_requests, 0);
    }

    #[test]
    fn zero_length_decode_completes_at_prefill() {
        // Pre-fix, a decode_len == 0 request still ran one decode step
        // (appending a token nobody asked for) before the completion
        // check fired. It must now finish at prefill time with zero
        // decode steps on the books.
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let c = coord.submit(req(5, 64, 0)).wait();
        assert!(c.ok, "{:?}", c.error);
        assert_eq!(c.decode_len, 0);
        assert!(c.ttft_ms <= c.total_ms + 1e-9);
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.decode_steps, 0, "no decode step may run for decode_len=0");
        assert_eq!(stats.prefill_tokens, 64);
    }

    #[test]
    fn context_longer_than_prefill_budget_still_served() {
        // The token-budget twin of the KV livelock: a context longer
        // than prefill_token_budget must be offered alone, not pinned
        // at the queue head forever.
        let policy = BatchPolicy { prefill_token_budget: 64, ..Default::default() };
        let coord = Coordinator::spawn(small_config(), policy);
        let c = coord
            .submit(req(3, 256, 2))
            .wait_timeout(std::time::Duration::from_secs(30))
            .expect("oversized context must be admitted alone");
        assert!(c.ok, "{:?}", c.error);
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 1);
    }

    #[test]
    fn shutdown_drains_inflight() {
        let coord = Coordinator::spawn(small_config(), BatchPolicy::default());
        let h = coord.submit(req(9, 64, 10));
        let stats = coord.shutdown(); // shutdown while decoding
        assert_eq!(stats.completed, 1, "in-flight request must drain");
        let c = h.wait();
        assert_eq!(c.decode_len, 10);
    }
}
