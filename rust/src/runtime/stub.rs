//! Offline stand-in for the PJRT engine, compiled when the `pjrt`
//! feature is off (the default). Mirrors the engine API so callers
//! compile unchanged; every path that would execute on PJRT returns
//! [`RuntimeUnavailable`]. Benches and tests gate on
//! `runtime::artifact_available`, so in practice the stub's errors are
//! never hit — they exist to make misuse loud instead of silent.

pub use super::tensor::{Input, Tensor, TensorData, TensorSpec};

use std::fmt;
use std::path::PathBuf;

/// Error returned by every stub operation.
#[derive(Debug, Clone)]
pub struct RuntimeUnavailable;

impl fmt::Display for RuntimeUnavailable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "PJRT runtime not compiled in; rebuild with `--features pjrt`")
    }
}

impl std::error::Error for RuntimeUnavailable {}

/// API-shaped stub of the compile-once / run-many engine.
pub struct Engine {
    _private: (),
}

impl Engine {
    /// Always fails in the stub build.
    pub fn cpu(_artifacts_dir: PathBuf) -> Result<Engine, RuntimeUnavailable> {
        Err(RuntimeUnavailable)
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn load(&mut self, _name: &str) -> Result<(), RuntimeUnavailable> {
        Err(RuntimeUnavailable)
    }

    pub fn is_loaded(&self, _name: &str) -> bool {
        false
    }

    pub fn run_with(
        &self,
        _name: &str,
        _inputs: &[Input],
    ) -> Result<Vec<Tensor>, RuntimeUnavailable> {
        Err(RuntimeUnavailable)
    }

    pub fn run(
        &self,
        _name: &str,
        _inputs: &[TensorSpec],
    ) -> Result<Vec<TensorSpec>, RuntimeUnavailable> {
        Err(RuntimeUnavailable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_engine_reports_unavailable() {
        let err = Engine::cpu(PathBuf::from("artifacts")).err().expect("stub must fail");
        assert!(err.to_string().contains("--features pjrt"));
    }
}
