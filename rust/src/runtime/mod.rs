//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod engine;

pub use engine::{Engine, Input, Tensor, TensorData, TensorSpec};

use std::path::{Path, PathBuf};

/// Resolve the artifacts directory: `$SOCKET_ARTIFACTS` or `artifacts/`
/// relative to the workspace root.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("SOCKET_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // Try cwd and the crate root.
    for base in [".", env!("CARGO_MANIFEST_DIR")] {
        let p = Path::new(base).join("artifacts");
        if p.is_dir() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

/// Whether the named artifact exists (benches skip PJRT paths if not).
pub fn artifact_available(name: &str) -> bool {
    artifacts_dir().join(name).is_file()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_resolves() {
        let d = artifacts_dir();
        assert!(d.to_string_lossy().contains("artifacts"));
    }
}
