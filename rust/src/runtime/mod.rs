//! Execution runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! The PJRT-backed engine (and its `xla`/`anyhow` dependencies) only
//! builds with `--features pjrt`; the default build substitutes an
//! API-compatible stub whose constructor reports that the runtime is
//! unavailable, so the pure-Rust Layer-3 stack builds and tests fully
//! offline. [`PJRT_ENABLED`] tells callers which engine they got.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod tensor;

#[cfg(feature = "pjrt")]
pub mod engine;

#[cfg(not(feature = "pjrt"))]
#[path = "stub.rs"]
pub mod engine;

pub use engine::Engine;
pub use tensor::{Input, Tensor, TensorData, TensorSpec};

/// True when the crate was built with the PJRT runtime.
pub const PJRT_ENABLED: bool = cfg!(feature = "pjrt");

use std::path::{Path, PathBuf};

/// Resolve the artifacts directory: `$SOCKET_ARTIFACTS` or `artifacts/`
/// relative to the workspace root.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("SOCKET_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // Try cwd and the crate root.
    for base in [".", env!("CARGO_MANIFEST_DIR")] {
        let p = Path::new(base).join("artifacts");
        if p.is_dir() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

/// Whether the named artifact exists (benches skip PJRT paths if not).
pub fn artifact_available(name: &str) -> bool {
    artifacts_dir().join(name).is_file()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_resolves() {
        let d = artifacts_dir();
        assert!(d.to_string_lossy().contains("artifacts"));
    }

    #[test]
    fn pjrt_flag_matches_build() {
        assert_eq!(PJRT_ENABLED, cfg!(feature = "pjrt"));
    }
}
