//! Typed host tensors exchanged with the execution engine. Shared by the
//! real PJRT engine (`--features pjrt`) and the offline stub, so callers
//! compile identically in both configurations.

/// Typed input tensor for `Engine::run_with`.
#[derive(Clone, Debug)]
pub enum Input {
    F32(Vec<i64>, Vec<f32>),
    I32(Vec<i64>, Vec<i32>),
    Bool(Vec<i64>, Vec<bool>),
}

impl Input {
    /// Reuse a previous output as the next call's input (the cache
    /// chaining pattern of the decode loop).
    pub fn from_tensor(t: &Tensor) -> Input {
        match &t.data {
            TensorData::F32(v) => Input::F32(t.dims.clone(), v.clone()),
            TensorData::I32(v) => Input::I32(t.dims.clone(), v.clone()),
            TensorData::Pred(v) => Input::Bool(t.dims.clone(), v.clone()),
        }
    }
}

/// Typed output tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<i64>,
    pub data: TensorData,
}

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Pred(Vec<bool>),
}

impl Tensor {
    /// f32 view (panics on non-f32 — use for known-float outputs).
    pub fn f32s(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            other => panic!("expected f32 tensor, got {other:?}"),
        }
    }

    pub fn i32s(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            other => panic!("expected i32 tensor, got {other:?}"),
        }
    }
}

/// Back-compat f32-only spec (kept for simple artifacts + tests).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub dims: Vec<i64>,
    pub data: Vec<f32>,
}

impl TensorSpec {
    pub fn new(dims: Vec<i64>, data: Vec<f32>) -> TensorSpec {
        let want: i64 = dims.iter().product();
        assert_eq!(want as usize, data.len(), "shape/data mismatch");
        TensorSpec { dims, data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_spec_validates_shape() {
        let t = TensorSpec::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.dims, vec![2, 3]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn tensor_spec_rejects_bad_shape() {
        TensorSpec::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn input_round_trips_tensor() {
        let t = Tensor { dims: vec![2], data: TensorData::I32(vec![1, 2]) };
        match Input::from_tensor(&t) {
            Input::I32(dims, v) => {
                assert_eq!(dims, vec![2]);
                assert_eq!(v, vec![1, 2]);
            }
            other => panic!("{other:?}"),
        }
    }
}
