//! The PJRT execution engine: compile-once, execute-many wrapper around
//! the `xla` crate. One [`Engine`] owns a CPU PJRT client and a cache of
//! compiled executables keyed by artifact name, so the decode hot loop
//! never touches the filesystem or recompiles.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;

/// Typed input tensor for [`Engine::run_with`].
#[derive(Clone, Debug)]
pub enum Input {
    F32(Vec<i64>, Vec<f32>),
    I32(Vec<i64>, Vec<i32>),
    Bool(Vec<i64>, Vec<bool>),
}

impl Input {
    /// Reuse a previous output as the next call's input (the cache
    /// chaining pattern of the decode loop).
    pub fn from_tensor(t: &Tensor) -> Input {
        match &t.data {
            TensorData::F32(v) => Input::F32(t.dims.clone(), v.clone()),
            TensorData::I32(v) => Input::I32(t.dims.clone(), v.clone()),
            TensorData::Pred(v) => Input::Bool(t.dims.clone(), v.clone()),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let reshape = |lit: xla::Literal, dims: &[i64]| -> Result<xla::Literal> {
            if dims.is_empty() {
                // vec1 of len 1 -> scalar: reshape to rank 0.
                Ok(lit.reshape(&[])?)
            } else {
                Ok(lit.reshape(dims)?)
            }
        };
        match self {
            Input::F32(dims, data) => reshape(xla::Literal::vec1(data), dims),
            Input::I32(dims, data) => reshape(xla::Literal::vec1(data), dims),
            Input::Bool(dims, data) => {
                // No bool NativeType in the crate: build u32, convert to PRED.
                let words: Vec<u32> = data.iter().map(|&b| b as u32).collect();
                let lit = xla::Literal::vec1(&words).convert(xla::PrimitiveType::Pred)?;
                reshape(lit, dims)
            }
        }
    }
}

/// Typed output tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<i64>,
    pub data: TensorData,
}

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Pred(Vec<bool>),
}

impl Tensor {
    /// f32 view (panics on non-f32 — use for known-float outputs).
    pub fn f32s(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            other => panic!("expected f32 tensor, got {other:?}"),
        }
    }

    pub fn i32s(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            other => panic!("expected i32 tensor, got {other:?}"),
        }
    }
}

/// Back-compat f32-only spec (kept for simple artifacts + tests).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub dims: Vec<i64>,
    pub data: Vec<f32>,
}

impl TensorSpec {
    pub fn new(dims: Vec<i64>, data: Vec<f32>) -> TensorSpec {
        let want: i64 = dims.iter().product();
        assert_eq!(want as usize, data.len().max(1).min(data.len()), "shape/data mismatch");
        assert_eq!(want as usize, data.len(), "shape/data mismatch");
        TensorSpec { dims, data }
    }
}

/// Compile-once / run-many PJRT engine.
pub struct Engine {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create an engine over the CPU PJRT client.
    pub fn cpu(artifacts_dir: PathBuf) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine { client, artifacts_dir, executables: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by name).
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.artifacts_dir.join(name);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute with typed inputs; returns the flattened output tuple.
    pub fn run_with(&self, name: &str, inputs: &[Input]) -> Result<Vec<Tensor>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result =
            exe.execute::<xla::Literal>(&literals).map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let out = result[0][0].to_literal_sync().map_err(|e| anyhow!("sync {name}: {e:?}"))?;
        let parts = out.to_tuple().map_err(|e| anyhow!("tuple {name}: {e:?}"))?;
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e:?}"))?;
                let dims: Vec<i64> = shape.dims().to_vec();
                let data = match shape.ty() {
                    xla::ElementType::F32 => {
                        TensorData::F32(lit.to_vec::<f32>().map_err(|e| anyhow!("f32: {e:?}"))?)
                    }
                    xla::ElementType::S32 => {
                        TensorData::I32(lit.to_vec::<i32>().map_err(|e| anyhow!("i32: {e:?}"))?)
                    }
                    xla::ElementType::Pred => {
                        let as_u8 = lit
                            .convert(xla::PrimitiveType::U8)
                            .map_err(|e| anyhow!("pred: {e:?}"))?;
                        TensorData::Pred(
                            as_u8
                                .to_vec::<u8>()
                                .map_err(|e| anyhow!("pred vec: {e:?}"))?
                                .into_iter()
                                .map(|b| b != 0)
                                .collect(),
                        )
                    }
                    other => return Err(anyhow!("unsupported output element type {other:?}")),
                };
                Ok(Tensor { dims, data })
            })
            .collect()
    }

    /// f32-only convenience wrapper around [`Engine::run_with`].
    pub fn run(&self, name: &str, inputs: &[TensorSpec]) -> Result<Vec<TensorSpec>> {
        let typed: Vec<Input> =
            inputs.iter().map(|t| Input::F32(t.dims.clone(), t.data.clone())).collect();
        self.run_with(name, &typed)?
            .into_iter()
            .map(|t| match t.data {
                TensorData::F32(v) => Ok(TensorSpec { dims: t.dims, data: v }),
                other => Err(anyhow!("non-f32 output {other:?}; use run_with")),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_spec_validates_shape() {
        let t = TensorSpec::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.dims, vec![2, 3]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn tensor_spec_rejects_bad_shape() {
        TensorSpec::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn input_round_trips_tensor() {
        let t = Tensor { dims: vec![2], data: TensorData::I32(vec![1, 2]) };
        match Input::from_tensor(&t) {
            Input::I32(dims, v) => {
                assert_eq!(dims, vec![2]);
                assert_eq!(v, vec![1, 2]);
            }
            other => panic!("{other:?}"),
        }
    }

    // PJRT round-trip tests live in rust/tests/runtime_pjrt.rs (they
    // need the artifacts built by `make artifacts`).
}
