//! The PJRT execution engine: compile-once, execute-many wrapper around
//! the `xla` crate. One [`Engine`] owns a CPU PJRT client and a cache of
//! compiled executables keyed by artifact name, so the decode hot loop
//! never touches the filesystem or recompiles.
//!
//! Built only with `--features pjrt`; the default build substitutes the
//! API-compatible stub in `runtime/stub.rs`.

pub use super::tensor::{Input, Tensor, TensorData, TensorSpec};

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;

fn to_literal(input: &Input) -> Result<xla::Literal> {
    let reshape = |lit: xla::Literal, dims: &[i64]| -> Result<xla::Literal> {
        if dims.is_empty() {
            // vec1 of len 1 -> scalar: reshape to rank 0.
            Ok(lit.reshape(&[])?)
        } else {
            Ok(lit.reshape(dims)?)
        }
    };
    match input {
        Input::F32(dims, data) => reshape(xla::Literal::vec1(data), dims),
        Input::I32(dims, data) => reshape(xla::Literal::vec1(data), dims),
        Input::Bool(dims, data) => {
            // No bool NativeType in the crate: build u32, convert to PRED.
            let words: Vec<u32> = data.iter().map(|&b| b as u32).collect();
            let lit = xla::Literal::vec1(&words).convert(xla::PrimitiveType::Pred)?;
            reshape(lit, dims)
        }
    }
}

/// Compile-once / run-many PJRT engine.
pub struct Engine {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create an engine over the CPU PJRT client.
    pub fn cpu(artifacts_dir: PathBuf) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine { client, artifacts_dir, executables: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by name).
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.artifacts_dir.join(name);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute with typed inputs; returns the flattened output tuple.
    pub fn run_with(&self, name: &str, inputs: &[Input]) -> Result<Vec<Tensor>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let result =
            exe.execute::<xla::Literal>(&literals).map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let out = result[0][0].to_literal_sync().map_err(|e| anyhow!("sync {name}: {e:?}"))?;
        let parts = out.to_tuple().map_err(|e| anyhow!("tuple {name}: {e:?}"))?;
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e:?}"))?;
                let dims: Vec<i64> = shape.dims().to_vec();
                let data = match shape.ty() {
                    xla::ElementType::F32 => {
                        TensorData::F32(lit.to_vec::<f32>().map_err(|e| anyhow!("f32: {e:?}"))?)
                    }
                    xla::ElementType::S32 => {
                        TensorData::I32(lit.to_vec::<i32>().map_err(|e| anyhow!("i32: {e:?}"))?)
                    }
                    xla::ElementType::Pred => {
                        let as_u8 = lit
                            .convert(xla::PrimitiveType::U8)
                            .map_err(|e| anyhow!("pred: {e:?}"))?;
                        TensorData::Pred(
                            as_u8
                                .to_vec::<u8>()
                                .map_err(|e| anyhow!("pred vec: {e:?}"))?
                                .into_iter()
                                .map(|b| b != 0)
                                .collect(),
                        )
                    }
                    other => return Err(anyhow!("unsupported output element type {other:?}")),
                };
                Ok(Tensor { dims, data })
            })
            .collect()
    }

    /// f32-only convenience wrapper around [`Engine::run_with`].
    pub fn run(&self, name: &str, inputs: &[TensorSpec]) -> Result<Vec<TensorSpec>> {
        let typed: Vec<Input> =
            inputs.iter().map(|t| Input::F32(t.dims.clone(), t.data.clone())).collect();
        self.run_with(name, &typed)?
            .into_iter()
            .map(|t| match t.data {
                TensorData::F32(v) => Ok(TensorSpec { dims: t.dims, data: v }),
                other => Err(anyhow!("non-f32 output {other:?}; use run_with")),
            })
            .collect()
    }
}

// PJRT round-trip tests live in rust/tests/runtime_pjrt.rs (they need
// the artifacts built by `make artifacts`); the shared tensor types are
// tested in runtime/tensor.rs.
