//! `socketd` — the SOCKET sparse-attention serving daemon + experiment
//! launcher.
//!
//! ```text
//! socketd serve   [--port 7411] [--method socket|quest|...] [--sparsity 33]
//!                 [--dense] [--workers 4] [--session-ttl 300]
//!                 [--config reload.json]   # hot-reload watcher
//! socketd bench   <ruler|overhead|ranking|ttft|throughput|correlation|
//!                  longbench|ablation|magicpig|models|theory|all>
//!                 [--full] [--n N] [--dim D] [--instances I] [--seed S]
//! socketd demo    [--n 4096] [--sparsity 33]   # quick one-shot decode
//! socketd info                                  # config & memory report
//! ```

use socket_attn::coordinator::{AttentionMode, BatchPolicy, EngineConfig};
use socket_attn::experiments::{self, Scale};
use socket_attn::lsh::LshParams;
use socket_attn::model::ModelConfig;
use socket_attn::server::Server;
use socket_attn::util::Args;
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    match args.subcommand() {
        Some("serve") => serve(&args),
        Some("bench") => bench(&args),
        Some("demo") => demo(&args),
        Some("info") => info(),
        _ => {
            eprintln!(
                "usage: socketd <serve|bench|demo|info> [options]\n\
                 bench targets: ruler overhead ranking ttft throughput\n\
                 correlation longbench ablation magicpig models theory all"
            );
            std::process::exit(2);
        }
    }
}

fn engine_config(args: &Args) -> EngineConfig {
    // Any registered selector serves as the default: --method quest...
    // Validated here so a typo'd name fails at startup with the
    // registry listing, not on the first request.
    let mode = if args.flag("dense") {
        AttentionMode::Dense
    } else {
        let method = args.get_or("method", "socket");
        if let Err(e) = socket_attn::selector::lookup(&method) {
            eprintln!("{e}");
            std::process::exit(2);
        }
        AttentionMode::sparse(method.as_str(), args.f64_or("sparsity", 33.0))
    };
    EngineConfig {
        model: ModelConfig::tiny(),
        lsh: LshParams {
            p: args.usize_or("p", 10),
            l: args.usize_or("l", 60),
            tau: args.f32_or("tau", 0.5),
        },
        mode,
        capacity_pages: args.usize_or("capacity-pages", 64 * 1024),
        sink: args.usize_or("sink", 64),
        local: args.usize_or("local", 64),
    }
}

fn serve(args: &Args) {
    let port = args.usize_or("port", 7411);
    let workers = args.usize_or("workers", 4);
    let ttl = std::time::Duration::from_secs(args.usize_or("session-ttl", 300) as u64);
    let server = Arc::new(
        Server::new(engine_config(args), BatchPolicy::default()).with_session_ttl(ttl),
    );
    let handle = server.serve(&format!("127.0.0.1:{port}"), workers).expect("bind failed");
    // --config <path>: hot-reload serving defaults / batch policy /
    // session TTL from a JSON file without restarting (see
    // server::reloader for the schema). The watcher lives as long as
    // the server does.
    let _watcher = {
        let config_path = args.get_or("config", "");
        if config_path.is_empty() {
            None
        } else {
            let w = socket_attn::server::reloader::watch(
                Arc::clone(&server),
                config_path.clone().into(),
                std::time::Duration::from_millis(200),
            )
            .expect("config watcher failed to start");
            println!("watching {config_path} for config reloads");
            Some(w)
        }
    };
    println!("socketd listening on {} ({workers} workers)", handle.addr());
    println!("protocol: one JSON per line, e.g.");
    println!("  {{\"op\":\"generate\",\"context_len\":4096,\"decode_len\":64,\"method\":\"quest\"}}");
    println!("  {{\"op\":\"generate\",\"session\":\"chat-1\",\"context_len\":512,\"decode_len\":64,\"stream\":true}}");
    println!("  {{\"op\":\"metrics\"}}");
    handle.wait();
}

fn demo(args: &Args) {
    let n = args.usize_or("n", 4096);
    let sparsity = args.f64_or("sparsity", 33.0);
    let p = experiments::throughput::measure(n, args.usize_or("dim", 128), sparsity, 32, 7);
    println!("context {n}, sparsity {sparsity}x:");
    println!("  dense  : {:8.1} tok/s", p.dense_tps);
    println!("  SOCKET : {:8.1} tok/s ({:.2}x)", p.socket_tps, p.socket_tps / p.dense_tps);
}

fn info() {
    let tiny = ModelConfig::tiny();
    let big = ModelConfig::paper_8b();
    let lsh = LshParams::paper_default();
    println!("== socket-attn configuration ==");
    println!("tiny model   : {tiny:?} (~{:.1}M params)", tiny.param_count() as f64 / 1e6);
    println!(
        "paper analog : {:.1}B params, KV {:.0} KiB/token",
        big.param_count() as f64 / 1e9,
        big.kv_bytes_per_token() as f64 / 1024.0
    );
    println!(
        "LSH default  : P={} L={} tau={} -> {} bits/token (~{}% of bf16 KV)",
        lsh.p,
        lsh.l,
        lsh.tau,
        lsh.memory().bits_per_token,
        (100 * lsh.memory().bits_per_token) / (big.kv_bytes_per_token() * 8 / 2)
    );
    println!("artifacts dir: {}", socket_attn::runtime::artifacts_dir().display());
    for art in ["socket_decode.hlo.txt", "dense_decode.hlo.txt", "prefill_hash.hlo.txt"] {
        println!(
            "  {:24} {}",
            art,
            if socket_attn::runtime::artifact_available(art) {
                "present"
            } else {
                "missing (run `make artifacts`)"
            }
        );
    }
}

fn bench(args: &Args) {
    let scale = Scale::from_args(args);
    let which = args.positional().get(1).map(|s| s.as_str()).unwrap_or("all");
    let run = |name: &str| -> bool { which == "all" || which == name };
    if run("ruler") {
        experiments::ruler::reproduce(scale).print();
    }
    if run("overhead") {
        experiments::overhead::table(&experiments::overhead::run(scale)).print();
    }
    if run("ranking") {
        experiments::ranking::table(&experiments::ranking::run(scale)).print();
    }
    if run("ttft") {
        let pts = experiments::ttft::run(scale, &[1024, 4096, 16 * 1024]);
        experiments::ttft::table(&pts).print();
    }
    if run("throughput") {
        let ctxs = [4 * 1024, 16 * 1024, 32 * 1024, 64 * 1024];
        let pts = experiments::throughput::run(scale, &ctxs, 33.0);
        experiments::throughput::table(&pts, "CPU substrate, 33x").print();
    }
    if run("correlation") {
        experiments::correlation::table(&experiments::correlation::run(scale)).print();
    }
    if run("longbench") {
        experiments::longbench::table(&experiments::longbench::run(scale), "proxy").print();
    }
    if run("ablation") {
        experiments::ablation::table("Table 6a: SOCKET varying P", "P", &experiments::ablation::socket_vary_p(scale)).print();
        experiments::ablation::table("Table 6b: SOCKET varying L", "L", &experiments::ablation::socket_vary_l(scale)).print();
        experiments::ablation::table("Table 6c: SOCKET varying tau", "tau", &experiments::ablation::socket_vary_tau(scale)).print();
        experiments::ablation::table("Table 7a: hard LSH varying P", "P", &experiments::ablation::hard_vary_p(scale)).print();
        experiments::ablation::table("Table 7b/c: hard LSH varying L", "L", &experiments::ablation::hard_vary_l(scale)).print();
    }
    if run("magicpig") {
        experiments::magicpig::table(&experiments::magicpig::run(scale)).print();
    }
    if run("models") {
        experiments::models::table("Table 10: RULER-16K methods", &experiments::models::run_ruler16k(scale)).print();
        for m in experiments::models::MODELS.iter().skip(1) {
            experiments::models::table(
                &format!("Tables 11/12: SOCKET across sparsity ({})", m.name),
                &experiments::models::run_model_sweep(scale, m, &[5.0, 10.0, 20.0, 50.0]),
            )
            .print();
        }
    }
    if run("theory") {
        let pts = experiments::theory::finite_l_sweep(scale, &[5, 10, 20, 40, 80], 0.5, 6);
        experiments::theory::finite_l_table(&pts).print();
        let lem = experiments::theory::lemma4_check(scale, &[2, 4, 8, 16]);
        experiments::theory::lemma4_table(&lem).print();
        println!("epsilon_tau (P=8): {:?}", experiments::theory::epsilon_tau(scale, 8, &[0.05, 0.2, 0.5, 1.0, 5.0]));
        println!("sampling error vs M: {:?}", experiments::theory::sampling_sweep(scale, &[8, 32, 128, 512]));
    }
}
