//! # socket-attn
//!
//! Full-system reproduction of **SOCKET: SOft Collision Kernel EsTimator
//! for Sparse Attention** (Joshi et al., 2026).
//!
//! The crate is the Layer-3 (coordination) half of a three-layer stack:
//!
//! * **L1** — Pallas scoring / soft-hash / flash-decode kernels
//!   (`python/compile/kernels/`, build time only).
//! * **L2** — JAX transformer decode graph calling the kernels, lowered
//!   once to HLO text artifacts (`python/compile/model.py`, `aot.py`).
//! * **L3** — this crate: request router, continuous batcher,
//!   prefill/decode scheduler, paged KV + hash-table cache, and a PJRT
//!   runtime that loads the artifacts and executes them on the hot path
//!   (Python is never on the request path).
//!
//! In addition to the SOCKET scorer itself, the crate implements every
//! substrate the paper's evaluation depends on: hard-LSH and the five
//! other sparse-attention baselines — all behind the unified
//! [`selector::Selector`] trait, paged-native and registry-driven, so
//! any method is servable over the zero-copy paged decode path by name
//! (`"quest"`, `"magicpig"`, ...) — plus ranking/attention metrics,
//! synthetic RULER/LongBench-analog workloads, and one experiment
//! driver per paper table and figure (see `experiments`).
//!
//! ## Build matrix
//!
//! **L3 builds standalone**: the default `cargo build` / `cargo test`
//! needs no network, no Python, and no PJRT — the `runtime` module
//! compiles against an API-compatible stub and every pure-Rust test and
//! bench runs offline. Building with `--features pjrt` swaps in the
//! real PJRT engine (the `xla` bindings + `anyhow`, vendored offline
//! stand-ins by default); its integration tests additionally skip
//! per-test unless `make artifacts` has produced the HLO artifacts. The
//! scoring hot paths fan out over a shared worker pool
//! (`util::pool::global`, sized by `SOCKET_THREADS` or the machine's
//! parallelism). See `rust/README.md` for the full matrix.
//!
//! ## Static analysis
//!
//! The crate is gated by `socket-lint` (workspace member `lint/`), a
//! repo-native analyzer enforcing SAFETY comments on `unsafe`,
//! ordering rationale on atomics, and panic-/allocation-freedom on the
//! scoring hot paths — rule catalog in `rust/docs/ANALYSIS.md`. The
//! attribute below makes each `unsafe` operation inside an `unsafe fn`
//! require its own block (and therefore its own SAFETY justification).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod attention;
pub mod coordinator;
pub mod experiments;
pub mod kvcache;
pub mod linalg;
pub mod lsh;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod selector;
pub mod server;
pub mod simd;
pub mod testing;
pub mod util;
pub mod workload;
