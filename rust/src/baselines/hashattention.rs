//! HashAttention (Desai et al., ICML 2025): Hamming-space signatures.
//!
//! The original learns query/key mapping networks into Hamming space;
//! lacking the trained mappings offline, we use the data-agnostic analog
//! the paper itself ablates against: a random-rotation sign signature of
//! `bits` bits per token (the paper's Table 1 lists HashAttention at 128
//! bits/token). Scoring = negative Hamming distance between query and
//! key signatures, evaluated with popcount over packed u64 words.

use super::TokenSelector;
use crate::linalg::{Matrix, TopK};
use crate::util::rng::Pcg64;

pub struct HashAttentionSelector {
    pub bits: usize,
    seed: u64,
    planes: Option<Matrix>, // bits x dim random rotation
    sigs: Vec<u64>,         // n x words packed signatures
    words: usize,
    n: usize,
}

impl HashAttentionSelector {
    /// Paper's setting: 128-bit signatures.
    pub fn new(bits: usize, seed: u64) -> HashAttentionSelector {
        HashAttentionSelector { bits, seed, planes: None, sigs: Vec::new(), words: bits.div_ceil(64), n: 0 }
    }

    fn signature(&self, x: &[f32]) -> Vec<u64> {
        let planes = self.planes.as_ref().expect("build() not called");
        let proj = planes.matvec(x);
        let mut sig = vec![0u64; self.words];
        for (i, &v) in proj.iter().enumerate() {
            if v >= 0.0 {
                sig[i / 64] |= 1u64 << (i % 64);
            }
        }
        sig
    }
}

impl TokenSelector for HashAttentionSelector {
    fn name(&self) -> &'static str {
        "HashAttn"
    }

    fn build(&mut self, keys: &Matrix, _values: &Matrix) {
        self.n = keys.rows;
        let mut rng = Pcg64::new(self.seed, 23);
        self.planes = Some(Matrix::gaussian(self.bits, keys.cols, &mut rng));
        self.sigs = vec![0u64; self.n * self.words];
        for j in 0..self.n {
            let sig = self.signature(keys.row(j));
            self.sigs[j * self.words..(j + 1) * self.words].copy_from_slice(&sig);
        }
    }

    fn select(&self, q: &[f32], k: usize) -> Vec<usize> {
        let qsig = self.signature(q);
        let mut tk = TopK::new(k.min(self.n).max(1));
        for j in 0..self.n {
            let mut ham = 0u32;
            for w in 0..self.words {
                ham += (self.sigs[j * self.words + w] ^ qsig[w]).count_ones();
            }
            tk.push(-(ham as f32), j);
        }
        tk.into_indices()
    }

    fn bits_per_token(&self) -> usize {
        self.bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::gen;

    #[test]
    fn identical_key_has_zero_distance_rank_first() {
        let mut rng = Pcg64::seeded(1);
        let dim = 32;
        let q = rng.normal_vec(dim);
        let mut keys = Matrix::gaussian(100, dim, &mut rng);
        keys.row_mut(5).copy_from_slice(&q);
        let vals = Matrix::gaussian(100, dim, &mut rng);
        let mut h = HashAttentionSelector::new(128, 9);
        h.build(&keys, &vals);
        let sel = h.select(&q, 1);
        assert_eq!(sel, vec![5]);
    }

    #[test]
    fn hamming_distance_monotone_in_cosine() {
        let mut rng = Pcg64::seeded(2);
        let dim = 64;
        let q = gen::unit_vec(&mut rng, dim);
        let mut keys = Matrix::zeros(2, dim);
        keys.row_mut(0).copy_from_slice(&gen::key_with_cosine(&mut rng, &q, 0.9));
        keys.row_mut(1).copy_from_slice(&gen::key_with_cosine(&mut rng, &q, 0.0));
        let vals = Matrix::from_vec(2, 1, vec![1.0, 1.0]);
        let mut h = HashAttentionSelector::new(256, 3);
        h.build(&keys, &vals);
        assert_eq!(h.select(&q, 1), vec![0]);
    }

    #[test]
    fn memory_is_bits_per_token() {
        let h = HashAttentionSelector::new(128, 0);
        assert_eq!(h.bits_per_token(), 128);
        assert_eq!(h.words, 2);
        let h = HashAttentionSelector::new(100, 0);
        assert_eq!(h.words, 2); // rounds up
    }
}
