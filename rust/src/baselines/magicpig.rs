//! MagicPIG (Chen et al., ICLR 2025): LSH *sampling* for attention.
//!
//! Unlike SOCKET's deterministic retrieval, MagicPig samples candidate
//! keys — a key is a candidate if it collides with the query in at least
//! `min_matches` of the L tables — and estimates attention with an
//! importance-sampling correction `exp(q·k_j) / p_j` where `p_j` is the
//! key's collision probability. The candidate set's size is *not*
//! query-controllable, which is exactly why the paper finds it brittle
//! under a fully-sparse evaluation (Table 8): when the question tokens
//! are also processed sparsely, low-collision regimes leave the sampler
//! with few or no candidates.
//!
//! `dense_layers` reproduces the original's (0,16)-dense fallback.

use super::TokenSelector;
use crate::linalg::{Matrix, TopK};
use crate::lsh::{KeyHashes, LshParams, SimHash};

pub struct MagicPigSelector {
    pub params: LshParams,
    /// Minimum table collisions to become a candidate (paper: 2).
    pub min_matches: u32,
    hash: Option<SimHash>,
    hashes: Option<KeyHashes>,
    keys: Option<Matrix>,
    seed: u64,
    dim: usize,
}

impl MagicPigSelector {
    /// Paper setting: K=10 planes x L=150 tables (≈1024+ bits/token is
    /// the Table-1 accounting), min 2 collisions.
    pub fn new(params: LshParams, seed: u64) -> MagicPigSelector {
        MagicPigSelector { params, min_matches: 2, hash: None, hashes: None, keys: None, seed, dim: 0 }
    }

    /// Collision-count distribution of all keys for q (diagnostics).
    pub fn collision_counts(&self, q: &[f32]) -> Vec<u32> {
        let hash = self.hash.as_ref().expect("build() not called");
        let hashes = self.hashes.as_ref().unwrap();
        let qb = hash.hash_one(q);
        (0..hashes.n)
            .map(|j| {
                let row = hashes.key_row(j);
                (0..hashes.l).filter(|&t| row[t] == qb[t]).count() as u32
            })
            .collect()
    }
}

impl TokenSelector for MagicPigSelector {
    fn name(&self) -> &'static str {
        "MagicPig"
    }

    fn build(&mut self, keys: &Matrix, values: &Matrix) {
        self.dim = keys.cols;
        let hash = SimHash::new(self.params, keys.cols, self.seed);
        self.hashes = Some(hash.hash_keys(keys, values));
        self.hash = Some(hash);
        self.keys = Some(keys.clone());
    }

    /// "Selection" = the sampled candidate set, truncated to the budget
    /// by importance weight. If no candidates collide (the failure mode
    /// the paper demonstrates), only the most-recent token is returned —
    /// mirroring the original implementation's sink/recent fallback.
    fn select(&self, q: &[f32], k: usize) -> Vec<usize> {
        let counts = self.collision_counts(q);
        let hashes = self.hashes.as_ref().unwrap();
        let keys = self.keys.as_ref().unwrap();
        let n = hashes.n;
        let mut candidates: Vec<usize> =
            (0..n).filter(|&j| counts[j] >= self.min_matches).collect();
        if candidates.is_empty() {
            return vec![n - 1];
        }
        if candidates.len() <= k {
            return candidates;
        }
        // Importance weights: exp(q·k_j)/p_j with p_j ∝ collision rate.
        let mut tk = TopK::new(k);
        let l = hashes.l as f32;
        for &j in &candidates {
            let p_j = (counts[j] as f32 / l).max(1e-6);
            let logit = crate::linalg::dot(keys.row(j), q);
            // Work in log space: log w = logit - log p_j.
            tk.push(logit - p_j.ln(), j);
        }
        candidates = tk.into_indices();
        candidates
    }

    fn bits_per_token(&self) -> usize {
        self.params.memory().bits_per_token
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::gen;
    use crate::util::rng::Pcg64;

    fn params() -> LshParams {
        LshParams { p: 8, l: 75, tau: 0.5 }
    }

    #[test]
    fn near_duplicate_is_candidate() {
        let mut rng = Pcg64::seeded(1);
        let dim = 48;
        let q = gen::unit_vec(&mut rng, dim);
        let mut keys = Matrix::gaussian(100, dim, &mut rng);
        let near = gen::key_with_cosine(&mut rng, &q, 0.97);
        keys.row_mut(10).copy_from_slice(&near);
        let vals = Matrix::gaussian(100, dim, &mut rng);
        let mut mp = MagicPigSelector::new(params(), 3);
        mp.build(&keys, &vals);
        let sel = mp.select(&q, 20);
        assert!(sel.contains(&10), "{sel:?}");
    }

    #[test]
    fn orthogonal_context_collapses_to_fallback() {
        // The brittleness MagicPig shows in Table 8: when nothing
        // collides ≥ min_matches, selection degenerates.
        let mut rng = Pcg64::seeded(2);
        let dim = 64;
        let q = gen::unit_vec(&mut rng, dim);
        // Keys all nearly opposite to q => collision count ~0 at P=8.
        let mut keys = Matrix::zeros(20, dim);
        for j in 0..20 {
            let k = gen::key_with_cosine(&mut rng, &q, -0.95);
            keys.row_mut(j).copy_from_slice(&k);
        }
        let vals = Matrix::gaussian(20, dim, &mut rng);
        let mut mp = MagicPigSelector::new(LshParams { p: 10, l: 20, tau: 0.5 }, 4);
        mp.build(&keys, &vals);
        let sel = mp.select(&q, 10);
        assert_eq!(sel, vec![19], "expected fallback to last token: {sel:?}");
    }

    #[test]
    fn candidate_count_not_budget_controlled() {
        // Documents the sampling (vs retrieval) semantics: with highly
        // similar context, candidates overflow the budget and must be
        // truncated by importance.
        let mut rng = Pcg64::seeded(3);
        let dim = 32;
        let q = gen::unit_vec(&mut rng, dim);
        let mut keys = Matrix::zeros(50, dim);
        for j in 0..50 {
            let k = gen::key_with_cosine(&mut rng, &q, 0.9);
            keys.row_mut(j).copy_from_slice(&k);
        }
        let vals = Matrix::gaussian(50, dim, &mut rng);
        let mut mp = MagicPigSelector::new(params(), 5);
        mp.build(&keys, &vals);
        let counts = mp.collision_counts(&q);
        let n_cand = counts.iter().filter(|&&c| c >= 2).count();
        assert!(n_cand > 10, "n_cand={n_cand}");
        let sel = mp.select(&q, 10);
        assert_eq!(sel.len(), 10);
    }
}
