//! PQCache (Zhang et al., SIGMOD 2025): product-quantization scoring.
//!
//! Keys are split into `m` sub-vectors; per sub-space, k-means learns a
//! codebook of `2^nbits` centroids over this context's keys; each key
//! stores one code per sub-space. At decode time the query builds an ADC
//! (asymmetric distance computation) table of `q_sub·centroid` inner
//! products and scores every key by summing table lookups — the standard
//! IVF-free PQ retrieval PQCache uses, including its data-dependent
//! (clustering) TTFT cost which Fig. 3a measures.

use super::TokenSelector;
use crate::linalg::{Matrix, TopK};
use crate::util::rng::Pcg64;

pub struct PqCacheSelector {
    /// Sub-quantizers (sub-vector count).
    pub m: usize,
    /// Bits per code (centroids per sub-space = 2^nbits).
    pub nbits: usize,
    /// k-means iterations (TTFT-relevant).
    pub kmeans_iters: usize,
    seed: u64,
    dim: usize,
    sub_dim: usize,
    /// Per sub-space: centroids (2^nbits x sub_dim), row-major.
    codebooks: Vec<Matrix>,
    /// Per key: m codes.
    codes: Vec<u8>,
    n: usize,
}

impl PqCacheSelector {
    /// Paper-ish setting: m=16 sub-vectors, 6-bit codes.
    pub fn new(m: usize, nbits: usize, seed: u64) -> PqCacheSelector {
        assert!(nbits <= 8, "codes stored as u8");
        PqCacheSelector {
            m,
            nbits,
            kmeans_iters: 8,
            seed,
            dim: 0,
            sub_dim: 0,
            codebooks: Vec::new(),
            codes: Vec::new(),
            n: 0,
        }
    }

    fn ncentroids(&self) -> usize {
        1usize << self.nbits
    }

    /// Lloyd's k-means over rows of `data` (n x sub_dim).
    fn kmeans(&self, data: &[f32], n: usize, rng: &mut Pcg64) -> Matrix {
        let d = self.sub_dim;
        let kc = self.ncentroids().min(n.max(1));
        // Init: random distinct rows.
        let picks = rng.sample_indices(n, kc);
        let mut centroids = Matrix::zeros(self.ncentroids(), d);
        for (c, &row) in picks.iter().enumerate() {
            centroids.row_mut(c).copy_from_slice(&data[row * d..(row + 1) * d]);
        }
        let mut assign = vec![0usize; n];
        for _ in 0..self.kmeans_iters {
            // Assign.
            for j in 0..n {
                let x = &data[j * d..(j + 1) * d];
                let mut best = 0usize;
                let mut best_d = f32::INFINITY;
                for c in 0..kc {
                    let cent = centroids.row(c);
                    let mut dist = 0.0f32;
                    for i in 0..d {
                        let t = x[i] - cent[i];
                        dist += t * t;
                    }
                    if dist < best_d {
                        best_d = dist;
                        best = c;
                    }
                }
                assign[j] = best;
            }
            // Update.
            let mut sums = vec![0.0f32; kc * d];
            let mut counts = vec![0usize; kc];
            for j in 0..n {
                let c = assign[j];
                counts[c] += 1;
                for i in 0..d {
                    sums[c * d + i] += data[j * d + i];
                }
            }
            for c in 0..kc {
                if counts[c] > 0 {
                    for i in 0..d {
                        centroids.set(c, i, sums[c * d + i] / counts[c] as f32);
                    }
                }
            }
        }
        centroids
    }
}

impl TokenSelector for PqCacheSelector {
    fn name(&self) -> &'static str {
        "PQcache"
    }

    fn build(&mut self, keys: &Matrix, _values: &Matrix) {
        self.n = keys.rows;
        self.dim = keys.cols;
        assert!(self.dim % self.m == 0, "dim {} not divisible by m {}", self.dim, self.m);
        self.sub_dim = self.dim / self.m;
        self.codebooks.clear();
        self.codes = vec![0u8; self.n * self.m];
        let mut rng = Pcg64::new(self.seed, 17);
        for s in 0..self.m {
            // Slice sub-vectors.
            let mut sub = vec![0.0f32; self.n * self.sub_dim];
            for j in 0..self.n {
                let row = keys.row(j);
                sub[j * self.sub_dim..(j + 1) * self.sub_dim]
                    .copy_from_slice(&row[s * self.sub_dim..(s + 1) * self.sub_dim]);
            }
            let cb = self.kmeans(&sub, self.n, &mut rng);
            // Encode.
            for j in 0..self.n {
                let x = &sub[j * self.sub_dim..(j + 1) * self.sub_dim];
                let mut best = 0usize;
                let mut best_d = f32::INFINITY;
                for c in 0..self.ncentroids() {
                    let cent = cb.row(c);
                    let mut dist = 0.0f32;
                    for i in 0..self.sub_dim {
                        let t = x[i] - cent[i];
                        dist += t * t;
                    }
                    if dist < best_d {
                        best_d = dist;
                        best = c;
                    }
                }
                self.codes[j * self.m + s] = best as u8;
            }
            self.codebooks.push(cb);
        }
    }

    fn select(&self, q: &[f32], k: usize) -> Vec<usize> {
        // ADC tables: m x ncentroids inner products.
        let nc = self.ncentroids();
        let mut adc = vec![0.0f32; self.m * nc];
        for s in 0..self.m {
            let qs = &q[s * self.sub_dim..(s + 1) * self.sub_dim];
            let cb = &self.codebooks[s];
            for c in 0..nc {
                adc[s * nc + c] = crate::linalg::dot(qs, cb.row(c));
            }
        }
        // Score all keys by table lookups.
        let mut tk = TopK::new(k.min(self.n).max(1));
        for j in 0..self.n {
            let mut score = 0.0f32;
            for s in 0..self.m {
                score += adc[s * nc + self.codes[j * self.m + s] as usize];
            }
            tk.push(score, j);
        }
        tk.into_indices()
    }

    fn bits_per_token(&self) -> usize {
        self.m * self.nbits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pq_retrieves_planted_key() {
        let mut rng = Pcg64::seeded(1);
        let mut keys = Matrix::gaussian(256, 32, &mut rng);
        let vals = Matrix::gaussian(256, 32, &mut rng);
        let q = rng.normal_vec(32);
        for c in 0..32 {
            keys.set(100, c, 4.0 * q[c]);
        }
        let mut sel = PqCacheSelector::new(8, 4, 7);
        sel.build(&keys, &vals);
        let chosen = sel.select(&q, 16);
        assert!(chosen.contains(&100), "planted key not retrieved: {chosen:?}");
    }

    #[test]
    fn memory_matches_paper_scale() {
        // Paper Table 1 lists PQcache at 256 bits/token: m=16, 16 nbits
        // total split e.g. (16,16) -> here m*nbits.
        let sel = PqCacheSelector::new(16, 8, 0);
        assert_eq!(sel.bits_per_token(), 128);
        let sel = PqCacheSelector::new(32, 8, 0);
        assert_eq!(sel.bits_per_token(), 256);
    }

    #[test]
    fn adc_score_correlates_with_dot() {
        let mut rng = Pcg64::seeded(2);
        let keys = Matrix::gaussian(200, 16, &mut rng);
        let vals = Matrix::gaussian(200, 16, &mut rng);
        let mut sel = PqCacheSelector::new(4, 5, 3);
        sel.build(&keys, &vals);
        let q = rng.normal_vec(16);
        // Correlate true dot with PQ score over all keys.
        let nc = sel.ncentroids();
        let mut adc = vec![0.0f32; sel.m * nc];
        for s in 0..sel.m {
            let qs = &q[s * sel.sub_dim..(s + 1) * sel.sub_dim];
            for c in 0..nc {
                adc[s * nc + c] = crate::linalg::dot(qs, sel.codebooks[s].row(c));
            }
        }
        let mut truth = Vec::new();
        let mut approx = Vec::new();
        for j in 0..200 {
            truth.push(crate::linalg::dot(keys.row(j), &q) as f64);
            let mut sc = 0.0f32;
            for s in 0..sel.m {
                sc += adc[s * nc + sel.codes[j * sel.m + s] as usize];
            }
            approx.push(sc as f64);
        }
        let corr = crate::util::stats::pearson(&truth, &approx);
        assert!(corr > 0.7, "corr={corr}");
    }

    #[test]
    fn handles_tiny_contexts() {
        // Fewer keys than centroids must not panic.
        let mut rng = Pcg64::seeded(3);
        let keys = Matrix::gaussian(5, 8, &mut rng);
        let vals = Matrix::gaussian(5, 8, &mut rng);
        let mut sel = PqCacheSelector::new(2, 6, 1);
        sel.build(&keys, &vals);
        let chosen = sel.select(&rng.normal_vec(8), 3);
        assert_eq!(chosen.len(), 3);
    }
}
