//! Double Sparsity (Yang et al., 2024): token + channel sparsity.
//!
//! Offline calibration picks the `r` highest-magnitude key channels
//! (channel norms over a calibration pass — here: over the prefill keys,
//! matching the paper's offline AWQ-style calibration). Decode-time
//! token selection scores keys using only those channels ("label cache"),
//! cutting the feature dimension before the top-k.

use super::TokenSelector;
use crate::linalg::{Matrix, TopK};

pub struct DoubleSparsitySelector {
    /// Number of important channels kept (paper: d/8 … d/4).
    pub r_channels: usize,
    channels: Vec<usize>,
    /// Label cache: n x r_channels reduced keys.
    labels: Vec<f32>,
    n: usize,
}

impl DoubleSparsitySelector {
    pub fn new(r_channels: usize) -> DoubleSparsitySelector {
        DoubleSparsitySelector { r_channels, channels: Vec::new(), labels: Vec::new(), n: 0 }
    }

    pub fn selected_channels(&self) -> &[usize] {
        &self.channels
    }
}

impl TokenSelector for DoubleSparsitySelector {
    fn name(&self) -> &'static str {
        "DS"
    }

    fn build(&mut self, keys: &Matrix, _values: &Matrix) {
        self.n = keys.rows;
        let d = keys.cols;
        let r = self.r_channels.min(d);
        // Channel importance = sum of squared activations (calibration).
        let mut importance = vec![0.0f64; d];
        for j in 0..keys.rows {
            let row = keys.row(j);
            for c in 0..d {
                importance[c] += (row[c] as f64).powi(2);
            }
        }
        let mut idx: Vec<usize> = (0..d).collect();
        idx.sort_by(|&a, &b| importance[b].partial_cmp(&importance[a]).unwrap());
        idx.truncate(r);
        idx.sort_unstable();
        self.channels = idx;
        // Build label cache.
        self.labels = vec![0.0f32; self.n * r];
        for j in 0..self.n {
            let row = keys.row(j);
            for (i, &c) in self.channels.iter().enumerate() {
                self.labels[j * r + i] = row[c];
            }
        }
    }

    fn select(&self, q: &[f32], k: usize) -> Vec<usize> {
        let r = self.channels.len();
        let q_red: Vec<f32> = self.channels.iter().map(|&c| q[c]).collect();
        let mut tk = TopK::new(k.min(self.n).max(1));
        for j in 0..self.n {
            let score = crate::linalg::dot(&self.labels[j * r..(j + 1) * r], &q_red);
            tk.push(score, j);
        }
        tk.into_indices()
    }

    fn bits_per_token(&self) -> usize {
        // Label cache stores r_channels bf16 values per token (the paper
        // quantizes labels to 4-8 bits; we count 16 to be conservative).
        self.channels.len() * 16
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn picks_high_energy_channels() {
        let mut rng = Pcg64::seeded(1);
        let mut keys = Matrix::gaussian(50, 16, &mut rng);
        // Blow up channels 3 and 11.
        for j in 0..50 {
            keys.set(j, 3, keys.get(j, 3) * 10.0);
            keys.set(j, 11, keys.get(j, 11) * 10.0);
        }
        let vals = Matrix::gaussian(50, 16, &mut rng);
        let mut ds = DoubleSparsitySelector::new(2);
        ds.build(&keys, &vals);
        assert_eq!(ds.selected_channels(), &[3, 11]);
    }

    #[test]
    fn reduced_scores_retrieve_planted_key() {
        let mut rng = Pcg64::seeded(2);
        let mut keys = Matrix::gaussian(128, 32, &mut rng);
        let vals = Matrix::gaussian(128, 32, &mut rng);
        let q = rng.normal_vec(32);
        for c in 0..32 {
            keys.set(60, c, 5.0 * q[c]);
        }
        let mut ds = DoubleSparsitySelector::new(8);
        ds.build(&keys, &vals);
        let sel = ds.select(&q, 16);
        assert!(sel.contains(&60), "{sel:?}");
    }

    #[test]
    fn full_channels_equals_oracle_order() {
        let mut rng = Pcg64::seeded(3);
        let keys = Matrix::gaussian(40, 8, &mut rng);
        let vals = Matrix::gaussian(40, 8, &mut rng);
        let q = rng.normal_vec(8);
        let mut ds = DoubleSparsitySelector::new(8); // r = d: no reduction
        ds.build(&keys, &vals);
        let mut oracle = super::super::oracle::OracleSelector::new(false);
        oracle.build(&keys, &vals);
        assert_eq!(ds.select(&q, 5), oracle.select(&q, 5));
    }
}
