//! Oracle top-k — exact `q·k_j` (optionally value-norm weighted)
//! selection. The retrieval upper bound ("oracle-top-k" in Table 10);
//! also serves as the ground truth for Fig. 2's ranking metrics.

use super::TokenSelector;
use crate::linalg::{dot, Matrix, TopK};

/// Exact top-k selector. `value_aware = true` ranks by `(q·k_j)·‖v_j‖₂`,
/// the hindsight-optimal criterion of [13] cited in the introduction.
pub struct OracleSelector {
    pub value_aware: bool,
    keys: Option<Matrix>,
    value_norms: Vec<f32>,
}

impl OracleSelector {
    pub fn new(value_aware: bool) -> OracleSelector {
        OracleSelector { value_aware, keys: None, value_norms: Vec::new() }
    }

    /// Ranked scores for every key (used as Fig. 2 ground truth).
    pub fn scores(&self, q: &[f32]) -> Vec<f32> {
        let keys = self.keys.as_ref().expect("build() not called");
        (0..keys.rows)
            .map(|j| {
                let s = dot(keys.row(j), q);
                if self.value_aware {
                    s * self.value_norms[j]
                } else {
                    s
                }
            })
            .collect()
    }

    /// Full descending ranking of all keys.
    pub fn ranking(&self, q: &[f32]) -> Vec<usize> {
        let scores = self.scores(q);
        let mut idx: Vec<usize> = (0..scores.len()).collect();
        idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b)));
        idx
    }
}

impl TokenSelector for OracleSelector {
    fn name(&self) -> &'static str {
        if self.value_aware {
            "Oracle-VA"
        } else {
            "Oracle"
        }
    }

    fn build(&mut self, keys: &Matrix, values: &Matrix) {
        self.value_norms = values.row_norms();
        self.keys = Some(keys.clone());
    }

    fn select(&self, q: &[f32], k: usize) -> Vec<usize> {
        let scores = self.scores(q);
        let mut tk = TopK::new(k.min(scores.len()).max(1));
        for (j, &s) in scores.iter().enumerate() {
            tk.push(s, j);
        }
        tk.into_indices()
    }

    fn bits_per_token(&self) -> usize {
        // Reads full keys: d * 16 bits (bf16 in the paper's accounting).
        self.keys.as_ref().map(|k| k.cols * 16).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn oracle_finds_planted_key() {
        let mut rng = Pcg64::seeded(1);
        let mut keys = Matrix::gaussian(100, 16, &mut rng);
        let vals = Matrix::gaussian(100, 16, &mut rng);
        let q = rng.normal_vec(16);
        for c in 0..16 {
            keys.set(42, c, 5.0 * q[c]); // plant a dominant key
        }
        let mut o = OracleSelector::new(false);
        o.build(&keys, &vals);
        let sel = o.select(&q, 5);
        assert_eq!(sel[0], 42);
    }

    #[test]
    fn value_aware_reranks() {
        let mut keys = Matrix::zeros(2, 2);
        keys.set(0, 0, 1.0);
        keys.set(1, 0, 0.9); // slightly lower dot product
        let mut vals = Matrix::zeros(2, 2);
        vals.set(0, 0, 1.0);
        vals.set(1, 0, 10.0); // much larger value norm
        let q = [1.0, 0.0];
        let mut plain = OracleSelector::new(false);
        plain.build(&keys, &vals);
        assert_eq!(plain.select(&q, 1), vec![0]);
        let mut va = OracleSelector::new(true);
        va.build(&keys, &vals);
        assert_eq!(va.select(&q, 1), vec![1]);
    }

    #[test]
    fn ranking_is_total_order() {
        let mut rng = Pcg64::seeded(2);
        let keys = Matrix::gaussian(30, 8, &mut rng);
        let vals = Matrix::gaussian(30, 8, &mut rng);
        let mut o = OracleSelector::new(true);
        o.build(&keys, &vals);
        let r = o.ranking(&rng.normal_vec(8));
        let mut sorted = r.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..30).collect::<Vec<_>>());
    }
}
