//! Sparse-attention baselines the paper compares against (Section 6).
//!
//! Each baseline implements [`TokenSelector`]: given a query and the
//! cached K/V, return the indices to attend over under a top-k budget.
//! These are faithful reimplementations of the published algorithms
//! (the authors' CUDA/Python code is unavailable offline; see DESIGN.md
//! for the substitution notes):
//!
//! * [`oracle`] — exact top-k by `q·k_j` (+ value-norm variant) — the
//!   upper bound ("oracle-top-k" in Table 10).
//! * [`quest`] — page-level min/max bound scoring (Quest, ICML'24).
//! * [`pqcache`] — product-quantization ADC scoring (PQCache, SIGMOD'25).
//! * [`double_sparsity`] — offline channel selection + approximate
//!   scores over important channels (Double Sparsity, 2024).
//! * [`hashattention`] — Hamming-space signature scoring standing in for
//!   the learned mapping of HashAttention (ICML'25).
//! * [`magicpig`] — LSH importance sampling with optional dense-layer
//!   fallback (MagicPIG, ICLR'25).
//!
//! SOCKET and hard LSH themselves also get [`TokenSelector`] adapters
//! here ([`SocketSelector`], [`HardLshSelector`]) so every experiment
//! driver can sweep methods uniformly.

pub mod double_sparsity;
pub mod hashattention;
pub mod magicpig;
pub mod oracle;
pub mod pqcache;
pub mod quest;

use crate::linalg::Matrix;
use crate::lsh::{HardScorer, KeyHashes, LshParams, SoftScorer};
use crate::util::pool;

/// A sparse-attention token-selection method.
///
/// Selectors are `Send + Sync` (they hold only plain index data), so
/// the serving layer can score many queries across the shared worker
/// pool through [`TokenSelector::select_batch`].
pub trait TokenSelector: Send + Sync {
    /// Human-readable method name (bench tables).
    fn name(&self) -> &'static str;

    /// Build any per-context index state for the given K/V cache
    /// (hashing, clustering, page metadata...). Called once at prefill.
    fn build(&mut self, keys: &Matrix, values: &Matrix);

    /// Select up to `k` token indices for query `q`.
    fn select(&self, q: &[f32], k: usize) -> Vec<usize>;

    /// Batch path: select for many queries at once. The default scores
    /// queries in parallel on the shared worker pool (long-lived
    /// threads — no per-call spawning); results are identical to
    /// calling [`TokenSelector::select`] per query.
    fn select_batch(&self, queries: &[Vec<f32>], k: usize) -> Vec<Vec<usize>> {
        pool::global().map(queries.len(), |i| self.select(&queries[i], k))
    }

    /// Additional memory used by the index, bits per token (the paper's
    /// "Mem" column). Reported by benches.
    fn bits_per_token(&self) -> usize;
}

/// SOCKET as a [`TokenSelector`].
pub struct SocketSelector {
    scorer: SoftScorer,
    hashes: Option<KeyHashes>,
}

impl SocketSelector {
    pub fn new(params: LshParams, dim: usize, seed: u64) -> SocketSelector {
        SocketSelector { scorer: SoftScorer::new(params, dim, seed), hashes: None }
    }
}

impl TokenSelector for SocketSelector {
    fn name(&self) -> &'static str {
        "SOCKET"
    }

    fn build(&mut self, keys: &Matrix, values: &Matrix) {
        // Prefill-time hashing (Alg. 1) chunks keys across the pool.
        self.hashes =
            Some(self.scorer.hasher.simhash().hash_keys_with(keys, values, pool::global()));
    }

    fn select(&self, q: &[f32], k: usize) -> Vec<usize> {
        let hashes = self.hashes.as_ref().expect("build() not called");
        // Decode-time scoring (Alg. 2-4) runs on the shared pool; for
        // small caches (or from inside a pool worker, as in
        // select_batch) it degrades to the serial hot path.
        self.scorer.select_top_k_with(q, hashes, k, pool::global())
    }

    fn bits_per_token(&self) -> usize {
        self.scorer.params().memory().bits_per_token
    }
}

/// Traditional hard LSH as a [`TokenSelector`].
pub struct HardLshSelector {
    scorer: HardScorer,
    hashes: Option<KeyHashes>,
}

impl HardLshSelector {
    pub fn new(params: LshParams, dim: usize, seed: u64) -> HardLshSelector {
        HardLshSelector { scorer: HardScorer::new(params, dim, seed), hashes: None }
    }
}

impl TokenSelector for HardLshSelector {
    fn name(&self) -> &'static str {
        "LSH"
    }

    fn build(&mut self, keys: &Matrix, values: &Matrix) {
        self.hashes = Some(self.scorer.hash_keys(keys, values));
    }

    fn select(&self, q: &[f32], k: usize) -> Vec<usize> {
        let hashes = self.hashes.as_ref().expect("build() not called");
        self.scorer.select_top_k(q, hashes, k)
    }

    fn bits_per_token(&self) -> usize {
        self.scorer.params().memory().bits_per_token
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn adapters_round_trip() {
        let mut rng = Pcg64::seeded(1);
        let keys = Matrix::gaussian(64, 16, &mut rng);
        let vals = Matrix::gaussian(64, 16, &mut rng);
        let q = rng.normal_vec(16);
        let params = LshParams { p: 6, l: 10, tau: 0.5 };
        let mut soft = SocketSelector::new(params, 16, 7);
        let mut hard = HardLshSelector::new(params, 16, 7);
        soft.build(&keys, &vals);
        hard.build(&keys, &vals);
        assert_eq!(soft.select(&q, 8).len(), 8);
        assert_eq!(hard.select(&q, 8).len(), 8);
        assert_eq!(soft.bits_per_token(), 60);
        assert_eq!(hard.bits_per_token(), 60);
    }

    #[test]
    #[should_panic(expected = "build() not called")]
    fn select_before_build_panics() {
        let s = SocketSelector::new(LshParams::paper_default(), 8, 1);
        s.select(&[0.0; 8], 4);
    }

    #[test]
    fn batch_select_matches_serial() {
        let mut rng = Pcg64::seeded(2);
        let keys = Matrix::gaussian(512, 16, &mut rng);
        let vals = Matrix::gaussian(512, 16, &mut rng);
        let params = LshParams { p: 6, l: 10, tau: 0.5 };
        let mut soft = SocketSelector::new(params, 16, 7);
        let mut hard = HardLshSelector::new(params, 16, 7);
        soft.build(&keys, &vals);
        hard.build(&keys, &vals);
        let queries: Vec<Vec<f32>> = (0..12).map(|_| rng.normal_vec(16)).collect();
        for sel in [&soft as &dyn TokenSelector, &hard as &dyn TokenSelector] {
            let batch = sel.select_batch(&queries, 16);
            assert_eq!(batch.len(), queries.len());
            for (q, got) in queries.iter().zip(&batch) {
                assert_eq!(*got, sel.select(q, 16), "{} batch/serial diverge", sel.name());
            }
        }
    }
}
